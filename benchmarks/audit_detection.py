"""§4 + §5.4(3): empirical on-chain detection vs the closed form, and the
on-chain scoreboard footprint (§4.1 "modest bandwidth and gas costs")."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import economics as E
from repro.core.audit import AuditParams, Scoreboard
from repro.core.simulation import honest_population, run_sim
from repro.storage.sp import SPBehavior


def run():
    params = AuditParams(p_a=0.6, auditors_per_audit=4, C=50, p_ata=0.3)
    for pf in (0.1, 0.3, 0.5):
        closed = E.detection_probability(pf, params.C)
        detected = 0
        trials = 6
        for t in range(trials):
            pop = honest_population(8)
            pop[0] = SPBehavior(drop_fraction=pf)
            res = run_sim(pop, params=params, epochs=1, num_blobs=5, seed=t)
            detected += (res.slashed[0] > 0) or (0 in res.ejected)
        row(f"audit_detection/fake_{int(pf * 100)}pct", 0.0,
            f"empirical={detected}/{trials};closed_form>={closed:.2f}")

    # scoreboard on-chain footprint: 1000 audits over 63 peers
    sb = Scoreboard(owner=0)
    rng = np.random.default_rng(0)
    for _ in range(1000):
        sb.record(int(rng.integers(1, 64)), bool(rng.random() < 0.98))
    t = timeit(lambda: sb.packed(), repeats=3)
    _, nbytes = sb.packed()
    row("audit_detection/scoreboard_pack", t * 1e6, f"{nbytes}B_for_1000_audits")


if __name__ == "__main__":
    run()
