"""§Roofline: three-term roofline per (arch x shape) from the dry-run.

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = collective_wire_bytes_per_device / ICI_bandwidth

(the post-SPMD HLO is a per-device program, so per-device numbers divided
by per-chip rates equal the brief's global/(chips*rate) formulation).

MODEL_FLOPS = 6*N*D for training (fwd+bwd), 2*N*D for inference, with
N = active parameters (MoE: routed top-k + shared only).  The ratio
MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat/redundancy waste.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import row
from repro.configs import ALL_ARCHS, get
from repro.configs.base import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"


def model_flops(arch: str, shape) -> float:
    cfg = get(arch)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    per_token = 6 * n_active if shape.kind == "train" else 2 * n_active
    return per_token * tokens


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for arch in ALL_ARCHS:
        for shape in SHAPES:
            p = RESULTS / f"{arch}__{shape.name}__{mesh}.json"
            if not p.exists():
                continue
            r = json.loads(p.read_text())
            if r["status"] != "ok":
                continue
            r["_shape"] = shape
            cells.append(r)
    return cells


def roofline_terms(rec: dict) -> dict:
    shape = rec["_shape"]
    chips = rec["devices"]
    t_comp = rec["hlo_flops"] / PEAK_FLOPS
    t_mem = rec["hlo_bytes"] / HBM_BW
    t_coll = rec.get("collective_wire_bytes", 0.0) / ICI_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))[1]
    mf = model_flops(rec["arch"], shape)
    useful = mf / max(rec["hlo_flops"] * chips, 1.0)
    # roofline fraction: useful-compute time over the dominated step time
    t_step = max(t_comp, t_mem, t_coll)
    frac = (mf / chips / PEAK_FLOPS) / t_step if t_step > 0 else 0.0
    return {
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant, "model_flops": mf, "useful_ratio": useful,
        "roofline_frac": frac,
    }


SUGGEST = {
    "compute": "reduce recompute (remat policy) / fuse attention to cut non-model FLOPs",
    "memory": "raise arithmetic intensity: larger per-step tiles, bf16 intermediates, fewer fusion-boundary round-trips",
    "collective": "reshard to cut per-layer all-reduce volume (bf16 reductions, 2D sharding, overlap with compute)",
}


def run(mesh: str = "single", csv_out: str | None = "results/roofline.csv"):
    cells = load_cells(mesh)
    lines = ["arch,shape,chips,compute_s,memory_s,collective_s,dominant,"
             "model_flops,hlo_flops_dev,useful_ratio,roofline_frac"]
    for rec in cells:
        t = roofline_terms(rec)
        name = f"roofline/{rec['arch']}/{rec['shape']}"
        row(name, t[t["dominant"] + "_s"] * 1e6,
            f"dom={t['dominant']};frac={t['roofline_frac']:.3f};useful={t['useful_ratio']:.2f}")
        lines.append(
            f"{rec['arch']},{rec['shape']},{rec['devices']},{t['compute_s']:.4e},"
            f"{t['memory_s']:.4e},{t['collective_s']:.4e},{t['dominant']},"
            f"{t['model_flops']:.3e},{rec['hlo_flops']:.3e},{t['useful_ratio']:.3f},"
            f"{t['roofline_frac']:.4f}"
        )
    if csv_out:
        p = pathlib.Path(csv_out)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text("\n".join(lines) + "\n")
    return cells


if __name__ == "__main__":
    run()
