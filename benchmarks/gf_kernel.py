"""§3.5 "Erasure coding acceleration": the GF(2^8) matmul kernel.

On this CPU container the Pallas kernel runs in interpret mode (correctness,
not speed), so the table reports (a) the numpy-path CPU throughput that the
storage stack actually achieves here and (b) the kernel's *derived* TPU
roofline: 8*K vector int-ops per byte of B on the VPU, bandwidth-bound below
~K=4 — mirroring the paper's claim that vectorized GF coding outruns NIC
line rate.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import gf
from repro.kernels import ops

# v5e VPU: 4 MXU-independent vector units, ~1e12 int32 op/s effective (est.)
VPU_INT_OPS = 1.0e12
HBM_BW = 819e9


def run():
    rng = np.random.default_rng(0)
    for k, n in [(10, 1 << 20), (16, 1 << 20)]:
        a = rng.integers(0, 256, (6, k), dtype=np.uint8)
        b = rng.integers(0, 256, (k, n), dtype=np.uint8)
        t_np = timeit(lambda: gf.matmul_np(a, b), repeats=2)
        row(f"gf_kernel/numpy_k{k}_1MiB", t_np * 1e6, f"{n / 1e6 / t_np:.0f}MB/s_cpu")
        # TPU roofline for the same op
        ops_needed = 8 * k * n  # unrolled clmul steps per output row set
        t_compute = ops_needed / VPU_INT_OPS
        t_mem = (n * k + 6 * n) / HBM_BW
        bound = "compute" if t_compute > t_mem else "memory"
        row(f"gf_kernel/tpu_roofline_k{k}", 0.0,
            f"{n / max(t_compute, t_mem) / 1e9:.1f}GB/s_derived;{bound}-bound")
    # correctness spot-check of the kernel on a big tile
    a = rng.integers(0, 256, (6, 10), dtype=np.uint8)
    b = rng.integers(0, 256, (10, 65536), dtype=np.uint8)
    t_kern = timeit(lambda: np.asarray(ops.gf_matmul(a, b)), repeats=1, warmup=1)
    ok = np.array_equal(np.asarray(ops.gf_matmul(a, b)), gf.matmul_np(a, b))
    row("gf_kernel/pallas_interpret_64KiB", t_kern * 1e6, f"allclose={ok}")


if __name__ == "__main__":
    run()
