"""§3.5 "Erasure coding acceleration": the GF(2^8) matmul kernel.

On this CPU container the Pallas kernel runs in interpret mode (correctness,
not speed), so the table reports (a) the numpy-path CPU throughput that the
storage stack actually achieves here and (b) the kernel's *derived* TPU
roofline: 8*K vector int-ops per byte of B on the VPU, bandwidth-bound below
~K=4 — mirroring the paper's claim that vectorized GF coding outruns NIC
line rate.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit_json, row, timeit
from repro.core import gf
from repro.core.extend2d import Extend2D
from repro.kernels import ops

SMOKE = bool(int(os.environ.get("GF_SMOKE", "0")))

# v5e VPU: 4 MXU-independent vector units, ~1e12 int32 op/s effective (est.)
VPU_INT_OPS = 1.0e12
HBM_BW = 819e9


def run():
    rng = np.random.default_rng(0)
    for k, n in [(10, 1 << 20), (16, 1 << 20)]:
        a = rng.integers(0, 256, (6, k), dtype=np.uint8)
        b = rng.integers(0, 256, (k, n), dtype=np.uint8)
        t_np = timeit(lambda: gf.matmul_np(a, b), repeats=2)
        row(f"gf_kernel/numpy_k{k}_1MiB", t_np * 1e6, f"{n / 1e6 / t_np:.0f}MB/s_cpu")
        # TPU roofline for the same op
        ops_needed = 8 * k * n  # unrolled clmul steps per output row set
        t_compute = ops_needed / VPU_INT_OPS
        t_mem = (n * k + 6 * n) / HBM_BW
        bound = "compute" if t_compute > t_mem else "memory"
        row(f"gf_kernel/tpu_roofline_k{k}", 0.0,
            f"{n / max(t_compute, t_mem) / 1e9:.1f}GB/s_derived;{bound}-bound")
    # correctness spot-check of the kernel on a big tile
    a = rng.integers(0, 256, (6, 10), dtype=np.uint8)
    b = rng.integers(0, 256, (10, 65536), dtype=np.uint8)
    t_kern = timeit(lambda: np.asarray(ops.gf_matmul(a, b)), repeats=1, warmup=1)
    ok = np.array_equal(np.asarray(ops.gf_matmul(a, b)), gf.matmul_np(a, b))
    row("gf_kernel/pallas_interpret_64KiB", t_kern * 1e6, f"allclose={ok}")


def run_tiny_batch():
    """The DAS small-and-wide regime (§3.5 meets ``storage/das.py``).

    A light-client sampling plane issues thousands of *tiny* GF ops —
    a k x k extension or a 1 x k share reconstruction over S=512-byte
    shares — where the fixed per-call overhead (table lookups, kernel
    launch) dominates the O(k*S) arithmetic.  The sweep times B looped
    tiny calls against ONE wide call on the horizontally stacked operand
    (identical bytes out), numpy and Pallas paths: exactly how
    ``Extend2D.extend_batch`` extends many blobs' squares per axis and
    how the sampler's decode path amortizes verification math.
    """
    rng = np.random.default_rng(1)
    k, S = 4, 512
    lay = Extend2D(k=k)
    E = lay.code.encode_matrix  # (k, k): the per-axis extension op
    Rrow = E[:1]  # (1, k): reconstruct ONE share from k knowns
    batches = (256, 1024) if SMOKE else (256, 1024, 4096)
    sweep = {}
    for name, A in (("extend_kxk", E), ("recover_1xk", Rrow)):
        for batch in batches:
            shares = rng.integers(0, 256, (batch, k, S), dtype=np.uint8)
            wide = np.ascontiguousarray(
                shares.transpose(1, 0, 2).reshape(k, batch * S)
            )
            t_loop = timeit(lambda: [gf.matmul_np(A, s) for s in shares],
                            repeats=2)
            t_wide = timeit(lambda: gf.matmul_np(A, wide), repeats=2)
            got = np.concatenate([gf.matmul_np(A, s) for s in shares], axis=1)
            assert np.array_equal(got, gf.matmul_np(A, wide)), (
                f"wide != looped for {name} b{batch}"
            )
            mb = batch * k * S / 1e6
            speedup = t_loop / t_wide
            row(f"gf_kernel/tiny_{name}_loop_b{batch}", t_loop * 1e6 / batch,
                f"{mb / t_loop:.0f}MB/s_cpu")
            row(f"gf_kernel/tiny_{name}_wide_b{batch}", t_wide * 1e6,
                f"{mb / t_wide:.0f}MB/s_cpu;speedup={speedup:.1f}x")
            sweep[f"{name}_b{batch}"] = {
                "loop_s": t_loop, "wide_s": t_wide, "speedup": speedup,
                "mb": mb,
            }
    # batching must actually pay: the widest numpy call beats the loop
    widest = sweep[f"extend_kxk_b{batches[-1]}"]
    assert widest["speedup"] > 1.0, (
        f"wide call no faster than {batches[-1]} tiny calls "
        f"({widest['speedup']:.2f}x)"
    )
    # Pallas path on the same wide operand (interpret mode off-TPU:
    # correctness + the call shape the Mosaic kernel would get)
    batch = batches[0]
    shares = rng.integers(0, 256, (batch, k, S), dtype=np.uint8)
    wide = np.ascontiguousarray(shares.transpose(1, 0, 2).reshape(k, batch * S))
    t_pal = timeit(lambda: np.asarray(ops.gf_matmul(E, wide)),
                   repeats=1, warmup=1)
    ok = np.array_equal(np.asarray(ops.gf_matmul(E, wide)),
                        gf.matmul_np(E, wide))
    assert ok, "Pallas wide tiny-batch call diverged from numpy"
    row(f"gf_kernel/tiny_pallas_wide_b{batch}", t_pal * 1e6, f"allclose={ok}")
    sweep[f"pallas_wide_b{batch}"] = {"wide_s": t_pal, "allclose": ok}
    emit_json("gf_tiny_batch", sweep)


if __name__ == "__main__":
    run()
    run_tiny_batch()
