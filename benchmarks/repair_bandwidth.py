"""§3.3: Clay repair bandwidth vs Reed-Solomon ("60% less").

Measured end to end on the storage stack: helper bytes actually served
during repair (MSR path) vs the RS/MDS fallback path, per code geometry.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core.clay import ClayCode
from repro.core.rs import MDSCode


def run():
    rng = np.random.default_rng(0)
    for k, m in [(4, 2), (6, 3), (10, 6)]:
        code = ClayCode(k=k, m=m)
        w = max(4096 // code.alpha, 4)
        data = rng.integers(0, 256, (k, code.alpha, w), dtype=np.uint8)
        cw = code.encode(data)
        chunk_bytes = code.alpha * w

        ids = code.repair_subchunk_ids(0)
        helpers = {i: cw[i][ids] for i in range(1, code.n)}
        t_rep = timeit(lambda: code.repair(0, helpers), repeats=2)
        clay_bytes = sum(h.nbytes for h in helpers.values())

        rs = MDSCode(n=code.n, k=k)
        shards = {i: cw[i].reshape(code.alpha * w) for i in range(1, k + 1)}
        rs_bytes = sum(s.nbytes for s in shards.values())

        saving = 1 - clay_bytes / rs_bytes
        row(f"repair_bandwidth/clay_{k}_{m}", t_rep * 1e6,
            f"helper_bytes={clay_bytes};rs_bytes={rs_bytes};saving={saving:.1%}")
    # the paper's production geometry beats the claimed 60 %
    assert 1 - ClayCode(10, 6).repair_bandwidth_bytes(1000) / MDSCode(16, 10).repair_bandwidth_bytes(1000) >= 0.60


if __name__ == "__main__":
    run()
