"""Event-engine scale ramp — thin CLI over the ``engine`` scenario
(``src/repro/scenarios/engine.py``).  The ramp never shrinks under
``BACKBONE_SMOKE``; its wall budget is enforced by the CI smoke loop."""
from __future__ import annotations

from repro.scenarios import load_builtin, run_scenario


def run():
    load_builtin()
    run_scenario("engine")


if __name__ == "__main__":
    run()
