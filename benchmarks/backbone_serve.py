"""Backbone data-plane serving benchmark — thin CLI over the scenario
registry (``repro.scenarios``).

The five serving regimes live as registered scenarios in
``src/repro/scenarios/serving.py`` (see ``docs/CATALOG.md``); this shim
keeps the historical entry points alive:

    python benchmarks/backbone_serve.py               # every serving scenario
    python benchmarks/backbone_serve.py concurrent    # one regime
    python -m benchmarks.run backbone_serve ...       # harness dispatch (serve_grid)

``BACKBONE_SMOKE=1`` shrinks traffic and ``BENCH_JSON`` points the
sidecar, exactly as before.  Prefer ``python -m repro.scenarios run`` for
new tooling.
"""
from __future__ import annotations

import sys

from repro.scenarios import load_builtin, run_scenario

# historical CLI keyword -> scenario name
_SECTIONS = {
    "concurrent": "concurrent",
    "background": "background",
    "churn": "churn",
    "das": "das",
}


def run():
    """Harness entry point (``python -m benchmarks.run backbone_serve``):
    the sequential policy x workload serving grid."""
    load_builtin()
    run_scenario("serve_grid")


def run_all():
    load_builtin()
    for name in ("serve_grid", "concurrent", "background", "churn", "das"):
        run_scenario(name)


if __name__ == "__main__":
    load_builtin()
    picked = [s for kw, s in _SECTIONS.items() if kw in sys.argv[1:]]
    if picked:
        for name in picked:
            run_scenario(name)
    else:
        run_all()
