"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run roofline   # one suite
"""
from __future__ import annotations

import sys

SUITES = [
    "replication_overhead",  # Table 1
    "repair_bandwidth",  # §3.3 Clay vs RS
    "write_path",  # Figure 2
    "read_throughput",  # §1 4K-streaming bar
    "backbone_serve",  # §2.3 data plane: fleet x workload serving grid
    "audit_detection",  # §4 / §5.4(3)
    "incentives",  # §5.4 calibration table
    "durability_bench",  # Appendix A
    "gf_kernel",  # §3.5 erasure-coding acceleration
    "roofline",  # dry-run roofline (EXPERIMENTS §Roofline)
]


def main() -> None:
    wanted = sys.argv[1:] or SUITES
    print("name,us_per_call,derived")
    for name in wanted:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        try:
            mod.run()
        except Exception as e:  # keep the harness going; report the failure
            print(f"{name}/FAILED,0.0,{type(e).__name__}:{e}")
            raise


if __name__ == "__main__":
    main()
