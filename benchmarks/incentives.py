"""§5.4 "Reality Based Incentives": every calibration bound as a number."""
from __future__ import annotations

from benchmarks.common import row
from repro.core import economics as E


def run():
    cm = E.CostModel()  # AWS S3 numbers from the paper
    p_a = E.min_audit_probability(cm)
    row("incentives/min_pa_per_day", 0.0, f"{p_a:.4f}(paper:0.0076)")
    row("incentives/audit_every_days", 0.0, f"{1 / p_a:.0f}(paper:~130)")

    for pf in (0.05, 0.1, 0.25):
        row(f"incentives/P_Sa_fake{int(pf * 100)}", 0.0,
            f"{E.detection_probability(pf, 50):.3f}")

    s_ata = E.min_ata_slashing(rwd_au=0.01, p_ata=0.02, eps=0.01)
    row("incentives/min_S_ata", 0.0, f"{s_ata:.0f}(rwd_au=0.01,p_ata=0.02,eps=0.01)")

    s_a = E.fake_storage_slashing_bound(p_a=0.05, rwd_st=1.0, prct_fake=0.1,
                                        total_committed=10_000, C=50)
    row("incentives/min_S_a_fake10pct_10k", 0.0, f"{s_a:.0f}")

    n_a = E.audits_per_gb_month(0.05, 1024, 4, 30)
    rwd_st = E.fee_split(W=0.023, n_a=n_a, rwd_au=1e-9)
    row("incentives/fee_split_rwd_st", 0.0, f"{rwd_st:.6f}$/GB/mo_of_W=0.023")


if __name__ == "__main__":
    run()
