"""§1 / Table 1: read path throughput (the 4K-streaming 40 Mbps bar).

Measures the client read path (session -> fleet route -> hedged fetch ->
verify -> Clay decode -> pay on delivery) per chunkset, cold and cached,
through the seekable `BlobReader` streaming path, and with a dead SP and a
straggler injected — the exact serving scenario the paper optimizes for.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.storage.blob import BlobLayout
from repro.storage.rpc import RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider


def run():
    layout = BlobLayout(k=10, m=6, chunkset_bytes_target=1024 * 1024)
    contract = ShelbyContract()
    sps = {}
    for i in range(20):
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 5}"))
        sps[i] = StorageProvider(i)
    rpc = RPCNode("rpc0", contract, sps, layout, hedge=2, cache_chunksets=2)
    client = ShelbyClient(contract, rpc, deposit=1e6)
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, 4 * layout.chunkset_bytes, dtype=np.uint8).tobytes()
    meta = client.put(data)
    mb = layout.chunkset_bytes / 1e6
    cs = layout.chunkset_bytes

    def cold():
        rpc._cache.clear()
        client.read(meta.blob_id, 0, cs)

    t_cold = timeit(cold, repeats=3)
    row("read_throughput/cold_chunkset", t_cold * 1e6,
        f"{mb / t_cold:.1f}MB/s;{8 * mb / t_cold:.0f}Mbps_1cpu")

    client.read(meta.blob_id, cs, cs)
    t_hot = timeit(lambda: client.read(meta.blob_id, cs, cs), repeats=5)
    row("read_throughput/cached_chunkset", t_hot * 1e6, f"{mb / t_hot:.0f}MB/s")

    # sequential streaming through the file-like reader (paid per segment)
    def stream():
        rpc._cache.clear()
        with client.open(meta.blob_id) as f:
            while f.read(cs):
                pass

    t_stream = timeit(stream, repeats=2)
    full_mb = meta.size_bytes / 1e6
    row("read_throughput/blobreader_stream", t_stream * 1e6,
        f"{full_mb / t_stream:.1f}MB/s;{8 * full_mb / t_stream:.0f}Mbps_1cpu")

    # adversity: dead SP + 500 ms straggler; hedging keeps the path clean
    sps[meta.placement[(2, 0)]].crash()
    sps[meta.placement[(2, 1)]].behavior.latency_ms = 500.0

    def adverse():
        rpc._cache.clear()
        client.read(meta.blob_id, 2 * cs, cs)

    t_adv = timeit(adverse, repeats=3)
    row("read_throughput/under_failures", t_adv * 1e6,
        f"{mb / t_adv:.1f}MB/s;slowdown={t_adv / t_cold:.2f}x")
    client.settle()
    # 40 Mbps 4K bar met even on a single CPU core doing the GF math
    assert 8 * mb / t_cold > 40


if __name__ == "__main__":
    run()
