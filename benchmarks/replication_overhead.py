"""Table 1: replication overhead — Shelby vs published systems.

Ours is MEASURED on the real write path (stored bytes / user bytes,
including sub-packetization padding and the zero-padded final chunkset);
the comparison rows are the paper's published figures.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.storage.blob import BlobLayout

PUBLISHED = {  # paper Table 1
    "aws-s3": 1.4, "gcs": 1.4, "filecoin": 4.5, "greenfield": 2.5,
    "celestia": 4.0, "walrus": 4.5, "arweave": 15.0,
}


def measured_overhead(layout: BlobLayout, blob_bytes: int) -> float:
    ncs = layout.num_chunksets(blob_bytes)
    stored = ncs * layout.n * layout.chunk_bytes
    return stored / blob_bytes


def run():
    layout = BlobLayout(k=10, m=6, chunkset_bytes_target=10 * 1024 * 1024)
    rng = np.random.default_rng(0)
    # measured on the actual encoder for a 1-chunkset blob (scaled-down w)
    small = BlobLayout(k=10, m=6, chunkset_bytes_target=256 * 1024)
    data = rng.integers(0, 256, small.chunkset_bytes, dtype=np.uint8).tobytes()
    t = timeit(lambda: small.partition(data), repeats=2)
    for blob_mb in (10, 100, 1000):
        ov = measured_overhead(layout, blob_mb * 1024 * 1024)
        row(f"replication_overhead/shelby_{blob_mb}MB", t * 1e6,
            f"{ov:.3f}x(<2x:{ov < 2.0})")
    asym = layout.n / layout.k
    row("replication_overhead/shelby_asymptotic", 0.0, f"{asym:.2f}x")
    for name, factor in PUBLISHED.items():
        row(f"replication_overhead/{name}", 0.0, f"{factor}x(published)")
    assert asym < 2.0  # Table 1 claim


if __name__ == "__main__":
    run()
