"""Shared benchmark helpers: timing + CSV row emission."""
from __future__ import annotations

import time


def timeit(fn, *, repeats: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
