"""Shared benchmark reporting — re-exported from ``repro.scenarios.report``
so scenario code under ``src/`` never imports the top-level ``benchmarks``
package.  Existing benchmarks keep importing from here unchanged."""
from repro.scenarios.report import emit_json, metric_path, row, timeit

__all__ = ["emit_json", "metric_path", "row", "timeit"]
