"""Figure 2: client-SDK data preparation (partition -> encode -> commit).

Per-stage wall time + MB/s on one chunkset, numpy GF path vs the Pallas
kernel path (interpret mode on CPU; the kernel's TPU roofline is derived in
benchmarks/gf_kernel.py).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import commitments as cm
from repro.storage.blob import BlobLayout


def run():
    layout = BlobLayout(k=10, m=6, chunkset_bytes_target=1024 * 1024)
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, layout.chunkset_bytes, dtype=np.uint8).tobytes()
    mb = len(data) / 1e6

    t_part = timeit(lambda: layout.partition(data), repeats=3)
    chunksets = layout.partition(data)
    t_enc = timeit(lambda: layout.code.encode(chunksets[0]), repeats=2)
    coded = layout.code.encode(chunksets[0])
    t_commit = timeit(lambda: [cm.commit_chunk(coded[i]) for i in range(layout.n)], repeats=2)

    row("write_path/partition", t_part * 1e6, f"{mb / t_part:.0f}MB/s")
    row("write_path/clay_encode", t_enc * 1e6, f"{mb / t_enc:.1f}MB/s")
    row("write_path/merkle_commit", t_commit * 1e6, f"{mb / t_commit:.1f}MB/s")
    total = t_part + t_enc + t_commit
    row("write_path/total_prepare", total * 1e6, f"{mb / total:.1f}MB/s_1cpu")


if __name__ == "__main__":
    run()
