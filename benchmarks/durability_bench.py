"""Appendix A: durability / availability derivations (the paper's tables)."""
from __future__ import annotations

from benchmarks.common import row
from repro.core import durability as D


def run():
    p = D.DurabilityParams()  # the (10,6) worked example
    row("durability/p_data_loss", 0.0, f"{D.p_data_loss(p):.3e}(paper:3.01e-12)")
    row("durability/nines", 0.0, f"{D.durability_nines(p):.1f}")
    row("durability/p_unavailable", 0.0, f"{D.p_unavailable(p):.3e}(paper:1.35e-4)")
    row("durability/availability", 0.0, f"{D.availability(p):.6f}(paper:0.999865)")
    for m in (4, 6, 8):
        q = D.DurabilityParams(m=m)
        row(f"durability/sweep_m{m}", 0.0, f"loss={D.p_data_loss(q):.2e}")
    for mttd in (1.0, 24.0, 168.0):
        q = D.DurabilityParams(mttd_hours=mttd)
        row(f"durability/sweep_mttd{int(mttd)}h", 0.0, f"loss={D.p_data_loss(q):.2e}")


if __name__ == "__main__":
    run()
