"""SLO auto-tuning driver: sweep / hill-climb a scenario's knob space.

    PYTHONPATH=src python scripts/perf_hillclimb.py tune_admission
    PYTHONPATH=src python scripts/perf_hillclimb.py tune_admission --sweep
    PYTHONPATH=src python scripts/perf_hillclimb.py concurrent \
        --objective 5000rps.admitted.goodput_mbps \
        --axis rpc_max_inflight_fetches=4,6,8,12

Searches the named scenario's knob space for "max <objective> s.t. the
scenario's declared SLOs hold" via ``repro.scenarios.sweep``
(coordinate-descent hill-climb by default, exhaustive grid with
``--sweep``).  Every evaluated point runs headless with ``emit=False``
— searched points never touch BENCH_backbone.json — and records its
deterministic replay digest, so any number in the tuning report can be
reproduced bit-for-bit by re-running that scenario at those knobs.

Without ``--axis``, axes default to :data:`DEFAULT_AXES` for the
scenario (curated candidate lists around each registered default).
Results land in ``results/perf/<scenario>_tune.json``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.scenarios import REGISTRY, KnobAxis, ScenarioProblem, load_builtin

OUT = pathlib.Path("results/perf")

# Curated default search spaces.  Candidates bracket the registered
# default (include it explicitly: the search must be free to keep it).
DEFAULT_AXES = {
    "tune_admission": (
        KnobAxis("rpc_single_flight", (False, True)),
        KnobAxis("rpc_max_inflight_fetches", (None, 3, 6, 12, 24)),
        KnobAxis("rpc_shed_deadline_ms", (None, 100.0, 200.0)),
        KnobAxis("rpc_hedge_deadline_factor", (2.0, 3.0, 5.0)),
    ),
}

DEFAULT_OBJECTIVE = {
    "tune_admission": "goodput_mbps",
}


def _parse_value(tok: str):
    if tok in ("None", "none", "null"):
        return None
    if tok in ("True", "true"):
        return True
    if tok in ("False", "false"):
        return False
    for cast in (int, float):
        try:
            return cast(tok)
        except ValueError:
            continue
    return tok


def _parse_axis(spec: str) -> KnobAxis:
    name, _, csv = spec.partition("=")
    if not csv:
        raise SystemExit(f"--axis wants name=v1,v2,...; got {spec!r}")
    return KnobAxis(name, tuple(_parse_value(t) for t in csv.split(",")))


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        description="sweep/hill-climb a scenario's knobs against its SLOs")
    parser.add_argument("scenario", help="registered scenario name")
    parser.add_argument("--objective", default=None,
                        help="dotted payload path to maximize")
    parser.add_argument("--minimize", action="store_true")
    parser.add_argument("--axis", action="append", default=[],
                        metavar="KNOB=V1,V2,...",
                        help="searched axis (repeatable); defaults to the "
                             "curated DEFAULT_AXES for the scenario")
    parser.add_argument("--sweep", action="store_true",
                        help="exhaustive grid instead of hill-climb")
    parser.add_argument("--full", action="store_true",
                        help="full-size traffic (default: smoke-size runs)")
    parser.add_argument("--out", default=None, help="result JSON path")
    args = parser.parse_args(argv)

    load_builtin()
    scenario = REGISTRY.get(args.scenario)
    axes = tuple(_parse_axis(s) for s in args.axis)
    if not axes:
        axes = DEFAULT_AXES.get(scenario.name)
        if axes is None:
            raise SystemExit(
                f"no default axes for {scenario.name!r} "
                f"(tunable: {list(scenario.tunable)}); give --axis"
            )
    objective = args.objective or DEFAULT_OBJECTIVE.get(scenario.name)
    if objective is None:
        raise SystemExit(f"no default objective for {scenario.name!r}; "
                         f"give --objective (headline paths: "
                         f"{list(scenario.headline)})")

    problem = ScenarioProblem(scenario, axes, objective,
                              maximize=not args.minimize,
                              smoke=not args.full)
    result = problem.sweep() if args.sweep else problem.hill_climb()

    OUT.mkdir(parents=True, exist_ok=True)
    out = pathlib.Path(args.out) if args.out else OUT / f"{scenario.name}_tune.json"
    result.dump(out)
    doc = result.to_json()
    print(json.dumps({k: doc[k] for k in
                      ("scenario", "objective", "evaluations",
                       "baseline", "best", "improved")}, indent=2))
    print(f"# wrote {out}")
    return 0 if result.best.feasible else 1


if __name__ == "__main__":
    sys.exit(main())
