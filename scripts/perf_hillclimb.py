import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run tagged variants of the three chosen pairs,
re-lower + re-analyze, and append records to results/perf/.

  PYTHONPATH=src python scripts/perf_hillclimb.py <variant-name>

Variants encode one hypothesis each (see EXPERIMENTS.md §Perf)."""
import json
import pathlib
import sys

import jax

from repro.launch.dryrun import run_cell

OUT = pathlib.Path("results/perf")

VARIANTS = {
    # --- granite-8b / train_4k (representative pair) -------------------------
    "granite_base": dict(arch="granite-8b", shape_name="train_4k", mesh_kind="single"),
    # H1: reduce-scatter grad accumulation instead of 8x full all-reduce
    "granite_gradshard": dict(arch="granite-8b", shape_name="train_4k", mesh_kind="single",
                              shard_grad_accum=True),
    # H2: + save dot outputs in remat (less recompute traffic)
    "granite_gradshard_dots": dict(arch="granite-8b", shape_name="train_4k", mesh_kind="single",
                                   shard_grad_accum=True,
                                   remat_policy="dots"),
    # H3: + sequence-parallel activations (stored carries / norms sharded)
    "granite_gradshard_seq": dict(arch="granite-8b", shape_name="train_4k", mesh_kind="single",
                                  shard_grad_accum=True,
                                  rules_override={"seq": ("model",)}),
    # H4: fewer microbatches (4 instead of 8): fewer grad reductions
    "granite_gradshard_mb4": dict(arch="granite-8b", shape_name="train_4k", mesh_kind="single",
                                  shard_grad_accum=True, microbatch_override=4),

    # --- command-r-plus-104b / decode_32k (most collective-bound) ------------
    "cr_decode_base": dict(arch="command-r-plus-104b", shape_name="decode_32k",
                           mesh_kind="single"),
    # H1: weights TP-only over 'model' (row-parallel partial sums) instead of
    # 2D ('data','model') sharding that makes XLA gather 400 GB of weights
    "cr_decode_tp": dict(arch="command-r-plus-104b", shape_name="decode_32k",
                         mesh_kind="single",
                         rules_override={"embed": ("model",), "vocab": ("model",),
                                         "expert_embed": None}),
    # H2: TP weights + batch over data only (pod axis free for batch in multi)
    "cr_decode_tp_multi": dict(arch="command-r-plus-104b", shape_name="decode_32k",
                               mesh_kind="multi",
                               rules_override={"embed": ("model",), "vocab": ("model",),
                                               "expert_embed": None}),

    # --- hymba-1.5b / prefill_32k (worst roofline fraction) ------------------
    "hymba_prefill_base": dict(arch="hymba-1.5b", shape_name="prefill_32k",
                               mesh_kind="single"),
    # H1: sequence parallelism — shard the 32k seq dim over 'model' so the
    # replicated-25-head attention and SSM activations split 16 ways
    "hymba_prefill_seq": dict(arch="hymba-1.5b", shape_name="prefill_32k",
                              mesh_kind="single",
                              rules_override={"seq": ("model",)}),
    # H2: seq-sharding + ssm_inner over model (default) is kept; also shard
    # the flash-attn kv chunk bigger via rules? (structural no-op) — instead
    # try batch over ('pod','data') + seq over 'model' with heads replicated
    "hymba_prefill_seq_b": dict(arch="hymba-1.5b", shape_name="prefill_32k",
                                mesh_kind="single",
                                rules_override={"seq": ("model",), "embed": None}),
}


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    names = sys.argv[1:] or list(VARIANTS)
    for name in names:
        kw = dict(VARIANTS[name])
        if kw.get("remat_policy") == "dots":
            kw["remat_policy"] = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        path = OUT / f"{name}.json"
        if path.exists():
            print(f"[{name}] cached")
            continue
        print(f"[{name}] running...", flush=True)
        rec = run_cell(tag=name, **{k: v for k, v in kw.items()})
        rec.pop("traceback", None)
        path.write_text(json.dumps(rec, indent=1))
        if rec["status"] == "ok":
            print(f"[{name}] ok: flops={rec['hlo_flops']:.3e} bytes={rec['hlo_bytes']:.3e} "
                  f"coll_wire={rec['collective_wire_bytes']:.3e}")
        else:
            print(f"[{name}] {rec['status']}: {rec.get('error','')}")


if __name__ == "__main__":
    main()
