#!/usr/bin/env bash
# CI gate: lint + tier-1 tests + a time-budgeted smoke pass of the serving
# benchmarks.  Exits nonzero on regression-shaped failures: lint errors,
# test failures, benchmark assertion bars (p99 shielded from stragglers,
# bounded admitted p99 + nonzero shed rate past saturation, 40 Mbps 4K
# bar), or blowing a smoke time budget (exit 124 is reported as exactly
# that, so the log says WHICH budget blew, not just "tests failed").
#
#   scripts/ci.sh                 # default 600 s benchmark budget
#   SMOKE_BUDGET_S=120 scripts/ci.sh
#
# Benchmark metrics are also written to ${BENCH_JSON:-BENCH_backbone.json}
# (machine-readable; the GitHub Actions workflow uploads it as an artifact
# so the bench trajectory is tracked across PRs instead of scraped from
# stdout).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export BENCH_JSON="${BENCH_JSON:-BENCH_backbone.json}"

# run a smoke under `timeout`, distinguishing "budget exceeded" (timeout
# kills with 124) from an assertion/regression failure inside the smoke
run_budgeted() {
    local budget="$1" what="$2"; shift 2
    local status=0
    timeout "$budget" "$@" || status=$?
    if [ "$status" -eq 124 ]; then
        echo "FAIL: $what smoke budget exceeded (${budget}s)" >&2
        exit 124
    elif [ "$status" -ne 0 ]; then
        echo "FAIL: $what failed (exit $status)" >&2
        exit "$status"
    fi
}

echo "== lint: ruff =="
# config lives in pyproject.toml; the container image may not ship ruff
# (no network installs allowed there), so skip with a loud note — the
# GitHub Actions workflow installs it and enforces the gate on every PR
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks examples scripts
else
    echo "ruff not installed; lint gate skipped (enforced in GitHub Actions)"
fi

echo "== tier-1: pytest =="
# test_distributed_equivalence_8dev needs jax.shard_map, absent from the
# pinned jax in this image (fails at seed too) — deselected so the gate
# trips only on NEW failures.
python -m pytest -q \
    --deselect tests/test_sharding.py::test_distributed_equivalence_8dev

# NOTE: no `rm -f "$BENCH_JSON"` here — emit_json merges sections
# read-modify-write, so a pre-existing sidecar (earlier partial run, a
# caller accumulating several suites into one file) keeps its other
# sections instead of being clobbered; corrupt files are tolerated and
# rewritten atomically by benchmarks/common.py.
echo "== benchmark smoke (budget: ${SMOKE_BUDGET_S:-600}s) =="
BACKBONE_SMOKE=1 run_budgeted "${SMOKE_BUDGET_S:-600}" "serving benchmarks" \
    python -m benchmarks.run backbone_serve read_throughput

echo "== concurrent-workload smoke (budget: ${CONCURRENT_BUDGET_S:-180}s) =="
# open-loop Poisson zipf storm on the SHARED event engine: asserts the
# determinism digest (two identical runs -> byte-identical per-request
# timings + link utilization), then ramps offered load with and without
# admission control — the free-running fleet's p99 must blow up past the
# knee while the admitted fleet sheds (nonzero shed rate), keeps p99
# bounded below it, and single-flight dedup collapses the hot set
BACKBONE_SMOKE=1 run_budgeted "${CONCURRENT_BUDGET_S:-180}" "concurrent ramp" \
    python -m benchmarks.backbone_serve concurrent

echo "== background-plane smoke (budget: ${BACKGROUND_BUDGET_S:-180}s) =="
# audits + repair as paced background tasks on the SAME event loop as a
# paid Poisson storm: asserts serving p99 inflation stays within the
# configured background budget, that no foreground read is starved, and
# that audit-proof/repair bytes actually land on NIC/trunk counters
BACKBONE_SMOKE=1 run_budgeted "${BACKGROUND_BUDGET_S:-180}" "background planes" \
    python -m benchmarks.backbone_serve background

echo "== membership-churn smoke (budget: ${CHURN_BUDGET_S:-240}s) =="
# epoch-scale churn under a live storm: scripted departures/crashes/joins,
# boundary reconfigurations, and the re-dispersal backlog draining within
# the configured budget — asserts zero loss at tolerable churn, bit-exact
# decode through the SAME fleet, bounded p99 through the change, the
# monotone measured-durability series, and same-seed digest equality
BACKBONE_SMOKE=1 run_budgeted "${CHURN_BUDGET_S:-240}" "membership churn" \
    python -m benchmarks.backbone_serve churn

echo "== DAS-sampling smoke (budget: ${DAS_BUDGET_S:-180}s) =="
# the proof-carrying light-client read regime: measured withholding
# detection on the analytic 1-(1-q)^s curve (seeded exact-count
# adversaries, zero-withholding control), detection cheaper in bytes than
# a full-chunk audit, and a cache-hostile uniform sample storm riding the
# event engine concurrently with streaming — cache_bypass keeps the
# streaming hit rate intact, p99 stays in budget, digests replay equal
BACKBONE_SMOKE=1 run_budgeted "${DAS_BUDGET_S:-180}" "das sampling" \
    python -m benchmarks.backbone_serve das

echo "== engine-scale smoke (budget: ${ENGINE_BUDGET_S:-420}s) =="
# the million-request ramp: 10k -> 100k -> 1M requests against a 500-SP /
# 50-RPC world through the cohort fast path — asserts the fast digest is
# deterministic and byte-identical to task mode at 10k, >= 10x engine
# events/sec over the binary-heap task baseline at 100k, and that the 1M
# world completes inside the budget
BACKBONE_SMOKE=1 run_budgeted "${ENGINE_BUDGET_S:-420}" "engine scale" \
    python -m benchmarks.engine_scale

echo "== streaming smoke: video through BlobReader (budget: ${VIDEO_BUDGET_S:-120}s) =="
# exercises the session API end to end: open/stream receipts, pay-on-delivery,
# settlement conservation, and the 40 Mbps 4K bar under failures
VIDEO_SMOKE=1 run_budgeted "${VIDEO_BUDGET_S:-120}" "video streaming" \
    python examples/video_streaming.py

echo "== bench trajectory: $BENCH_JSON =="
python - <<'EOF'
import json, os
path = os.environ["BENCH_JSON"]
with open(path) as f:
    doc = json.load(f)
for section in ("serve_grid", "concurrent_ramp", "background", "churn", "das",
                "engine"):
    assert section in doc, f"{path} missing section {section!r}"
print(f"{path}: {', '.join(sorted(doc))} OK")
EOF

echo "CI OK"
