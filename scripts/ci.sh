#!/usr/bin/env bash
# CI gate: tier-1 tests + a time-budgeted smoke pass of the serving
# benchmarks.  Exits nonzero on regression-shaped failures: test failures,
# benchmark assertion bars (p99 shielded from stragglers, 40 Mbps 4K bar),
# or blowing the smoke time budget.
#
#   scripts/ci.sh                 # default 600 s benchmark budget
#   SMOKE_BUDGET_S=120 scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1: pytest =="
# test_distributed_equivalence_8dev needs jax.shard_map, absent from the
# pinned jax in this image (fails at seed too) — deselected so the gate
# trips only on NEW failures.
python -m pytest -q \
    --deselect tests/test_sharding.py::test_distributed_equivalence_8dev

echo "== benchmark smoke (budget: ${SMOKE_BUDGET_S:-600}s) =="
BACKBONE_SMOKE=1 timeout "${SMOKE_BUDGET_S:-600}" \
    python -m benchmarks.run backbone_serve read_throughput

echo "== concurrent-workload smoke (budget: ${CONCURRENT_BUDGET_S:-180}s) =="
# open-loop Poisson zipf storm on the SHARED event engine: asserts the
# determinism digest (two identical runs -> byte-identical per-request
# timings + link utilization) and prints open-loop p50/p99 under a rising
# offered-load ramp, so the bench trajectory captures contention
BACKBONE_SMOKE=1 timeout "${CONCURRENT_BUDGET_S:-180}" \
    python -m benchmarks.backbone_serve concurrent

echo "== streaming smoke: video through BlobReader (budget: ${VIDEO_BUDGET_S:-120}s) =="
# exercises the session API end to end: open/stream receipts, pay-on-delivery,
# settlement conservation, and the 40 Mbps 4K bar under failures
VIDEO_SMOKE=1 timeout "${VIDEO_BUDGET_S:-120}" \
    python examples/video_streaming.py

echo "CI OK"
