#!/usr/bin/env bash
# CI gate: lint + tier-1 tests + catalog freshness + a time-budgeted smoke
# pass of every registered scenario.  Exits nonzero on regression-shaped
# failures: lint errors, test failures, a stale scenario catalog, scenario
# SLO violations (p99 shielded from stragglers, bounded admitted p99 +
# nonzero shed rate past saturation, zero lost chunksets, ...), the 40 Mbps
# 4K bar, or blowing a smoke time budget (exit 124 is reported as exactly
# that, so the log says WHICH budget blew, not just "tests failed").
#
#   scripts/ci.sh                      # registry budgets per scenario
#   SCENARIO_BUDGET_SCALE=2 scripts/ci.sh   # slow runner: double budgets
#
# Scenario budgets live ON the registry entries (budget_s in
# src/repro/scenarios/*.py); the loop below reads them via
# `python -m repro.scenarios budgets` and SCENARIO_BUDGET_SCALE scales
# them uniformly.  Benchmark metrics are written to
# ${BENCH_JSON:-BENCH_backbone.json} (machine-readable; the GitHub Actions
# workflow uploads it as an artifact so the bench trajectory is tracked
# across PRs instead of scraped from stdout).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export BENCH_JSON="${BENCH_JSON:-BENCH_backbone.json}"

# run a smoke under `timeout`, distinguishing "budget exceeded" (timeout
# kills with 124) from an assertion/regression failure inside the smoke
run_budgeted() {
    local budget="$1" what="$2"; shift 2
    local status=0
    timeout "$budget" "$@" || status=$?
    if [ "$status" -eq 124 ]; then
        echo "FAIL: $what smoke budget exceeded (${budget}s)" >&2
        exit 124
    elif [ "$status" -ne 0 ]; then
        echo "FAIL: $what failed (exit $status)" >&2
        exit "$status"
    fi
}

echo "== lint: ruff =="
# config lives in pyproject.toml; the container image may not ship ruff
# (no network installs allowed there), so skip with a loud note — the
# GitHub Actions workflow installs it and enforces the gate on every PR
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks examples scripts
else
    echo "ruff not installed; lint gate skipped (enforced in GitHub Actions)"
fi

echo "== lint: simlint =="
# determinism linter over the sim path (net/ storage/ core/ scenarios/):
# exit 0 clean, 1 on new findings or stale baseline entries, 2 on internal
# error — so CI distinguishes "gate found problems" from "gate is broken".
# Stdlib-only (ast), so unlike ruff it always runs here.  Rule catalog and
# the pragma/baseline workflow: docs/simlint.md
python -m repro.analysis --check

echo "== tier-1: pytest =="
# test_distributed_equivalence_8dev needs jax.shard_map, absent from the
# pinned jax in this image (fails at seed too) — deselected so the gate
# trips only on NEW failures.
python -m pytest -q \
    --deselect tests/test_sharding.py::test_distributed_equivalence_8dev

echo "== scenario catalog freshness =="
# docs/CATALOG.md is generated from the registry + the COMMITTED bench
# sidecar; this gate runs BEFORE the smokes below rewrite $BENCH_JSON so
# freshness is always judged against what is committed
python scripts/gen_scenario_catalog.py --check

# NOTE: no `rm -f "$BENCH_JSON"` here — emit_json merges sections
# read-modify-write, so a pre-existing sidecar (earlier partial run, a
# caller accumulating several suites into one file) keeps its other
# sections instead of being clobbered; corrupt files are tolerated and
# rewritten atomically by repro.scenarios.report.
echo "== scenario smokes (registry budgets x SCENARIO_BUDGET_SCALE=${SCENARIO_BUDGET_SCALE:-1.0}) =="
# every registered scenario runs headless at smoke size: the runner
# resolves its knobs, replays its workload, asserts its declared SLOs
# (violations name the scenario), and merges its section into $BENCH_JSON
python -m repro.scenarios budgets | while read -r name budget; do
    echo "-- scenario: $name (budget: ${budget}s) --"
    BACKBONE_SMOKE=1 run_budgeted "$budget" "scenario $name" \
        python -m repro.scenarios run "$name"
done

echo "== simsan smoke: background scenario under the sanitizer (budget: ${SIMSAN_BUDGET_S:-240}s) =="
# re-run one full scenario with the event-loop sanitizer armed
# (SHELBY_SIMSAN=1): pop-order audits, slot-leak detection at drain,
# off-loop mutation guards, per-epoch payment conservation.  The sanitizer
# only observes — the scenario's results (and its $BENCH_JSON section) are
# byte-identical to the plain run above — so a nonzero exit here means a
# real simulation-safety violation, not flake.
SHELBY_SIMSAN=1 BACKBONE_SMOKE=1 run_budgeted "${SIMSAN_BUDGET_S:-240}" "simsan background" \
    python -m repro.scenarios run background

echo "== read-throughput smoke (budget: ${SMOKE_BUDGET_S:-600}s) =="
BACKBONE_SMOKE=1 run_budgeted "${SMOKE_BUDGET_S:-600}" "read throughput" \
    python -m benchmarks.run read_throughput

echo "== streaming smoke: video through BlobReader (budget: ${VIDEO_BUDGET_S:-120}s) =="
# exercises the session API end to end: open/stream receipts, pay-on-delivery,
# settlement conservation, and the 40 Mbps 4K bar under failures
VIDEO_SMOKE=1 run_budgeted "${VIDEO_BUDGET_S:-120}" "video streaming" \
    python examples/video_streaming.py

echo "== bench trajectory: $BENCH_JSON =="
python - <<'EOF'
import json, os
path = os.environ["BENCH_JSON"]
with open(path) as f:
    doc = json.load(f)
for section in ("serve_grid", "concurrent_ramp", "background", "churn", "das",
                "tune_admission", "engine"):
    assert section in doc, f"{path} missing section {section!r}"
print(f"{path}: {', '.join(sorted(doc))} OK")
EOF

echo "CI OK"
