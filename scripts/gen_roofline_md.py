"""Generate the EXPERIMENTS.md §Roofline + §Perf sections from results/."""
import json
import pathlib
import sys

sys.path.insert(0, "src")
from benchmarks.roofline import ICI_BW, HBM_BW, PEAK_FLOPS, load_cells, model_flops, roofline_terms  # noqa: E402
from repro.configs import ALL_ARCHS, get  # noqa: E402
from repro.configs.base import SHAPES, cell_applicable  # noqa: E402

OUT = []


def main():
    OUT.append("## §Roofline — single-pod (16x16 = 256 chips), per (arch x shape)\n")
    OUT.append("All terms in seconds/step per the brief's formulas (197 TFLOP/s bf16, "
               "819 GB/s HBM, 50 GB/s ICI). `useful` = MODEL_FLOPS / (HLO_FLOPs x chips) "
               "(remat/redundancy waste); `frac` = useful-compute time / dominant-term time "
               "(the roofline fraction). Memory/collective terms carry the XLA:CPU "
               "measurement caveats discussed under the table.\n")
    OUT.append("| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful | frac | what would move the dominant term |")
    OUT.append("|---|---|---|---|---|---|---|---|---|---|")
    cells = {(r["arch"], r["shape"]): r for r in load_cells("single")}
    notes = {
        "train": "fuse attention (Pallas kernel, implemented) + native-bf16 activations halve boundary traffic",
        "prefill": "fused attention removes the dominant score-block round-trips",
        "decode": "TP-only weight sharding (optimized default) removes weight gathers; next: KV-cache quantization",
    }
    for arch in ALL_ARCHS:
        for shape in SHAPES:
            ok, why = cell_applicable(get(arch), shape)
            if not ok:
                OUT.append(f"| {arch} | {shape.name} | — | — | — | skipped | — | — | — | {why.split(':')[0]} |")
                continue
            r = cells.get((arch, shape.name))
            if r is None:
                continue
            t = roofline_terms(r)
            OUT.append(
                f"| {arch} | {shape.name} | {t['compute_s']:.3f} | {t['memory_s']:.3f} | "
                f"{t['collective_s']:.3f} | **{t['dominant']}** | {t['model_flops']:.2e} | "
                f"{t['useful_ratio']:.2f} | {t['roofline_frac']:.3f} | {notes[shape.kind]} |"
            )
    OUT.append("")

    # optimized decode comparison
    opt_dir = pathlib.Path("results/dryrun_opt")
    if opt_dir.exists():
        OUT.append("### Optimized decode cells (beyond-paper resharding, re-lowered)\n")
        OUT.append("| arch | shape | mesh | coll s (base → opt) | mem s (base → opt) | step est (base → opt) |")
        OUT.append("|---|---|---|---|---|---|")
        for p in sorted(opt_dir.glob("*.json")):
            o = json.loads(p.read_text())
            if o["status"] != "ok":
                continue
            b_path = pathlib.Path("results/dryrun") / p.name
            if not b_path.exists():
                continue
            b = json.loads(b_path.read_text())
            if b["status"] != "ok":
                continue
            bc, oc = b["collective_wire_bytes"] / ICI_BW, o["collective_wire_bytes"] / ICI_BW
            bm, om = b["hlo_bytes"] / HBM_BW, o["hlo_bytes"] / HBM_BW
            bstep = max(bc, bm, b["hlo_flops"] / PEAK_FLOPS)
            ostep = max(oc, om, o["hlo_flops"] / PEAK_FLOPS)
            OUT.append(f"| {o['arch']} | {o['shape']} | {o['mesh']} | {bc:.3f} → {oc:.3f} | "
                       f"{bm:.3f} → {om:.3f} | {bstep:.3f} → {ostep:.3f} ({bstep/max(ostep,1e-9):.1f}x) |")
        OUT.append("")
    print("\n".join(OUT))


if __name__ == "__main__":
    main()
