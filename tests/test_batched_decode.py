"""Batched Clay decode: byte-identical to the per-chunkset path (§3.5)."""
import numpy as np
import pytest

from repro.core.clay import ClayCode


def _codeword_sets(code, rng, trials, w=16):
    sets, refs = [], []
    for _ in range(trials):
        data = rng.integers(0, 256, (code.k, code.alpha, w), dtype=np.uint8)
        cw = code.encode(data)
        drop = rng.choice(code.n, size=int(rng.integers(0, code.m + 1)), replace=False)
        shards = {i: cw[i] for i in range(code.n) if i not in drop}
        sets.append(shards)
        refs.append(code.decode(shards))
    return sets, refs


def test_decode_batch_matches_per_chunkset(rng):
    code = ClayCode(k=4, m=2)
    sets, refs = _codeword_sets(code, rng, trials=8)
    for ref, got in zip(refs, code.decode_batch(sets)):
        assert np.array_equal(ref, got)


def test_decode_batch_mixed_erasure_patterns_grouped(rng):
    """Distinct erasure patterns land in distinct stacked solves."""
    code = ClayCode(k=3, m=3)
    sets, refs = _codeword_sets(code, rng, trials=10, w=8)
    patterns = {frozenset(s) for s in sets}
    assert len(patterns) > 1  # the grouping is actually exercised
    for ref, got in zip(refs, code.decode_batch(sets)):
        assert np.array_equal(ref, got)


def test_decode_batch_through_pallas_kernel(rng):
    from repro.kernels import ops

    code = ClayCode(k=4, m=2)
    sets, refs = _codeword_sets(code, rng, trials=4, w=8)
    for ref, got in zip(refs, code.decode_batch(sets, matmul=ops.gf_matmul_np)):
        assert np.array_equal(ref, got)


def test_decode_batch_rejects_too_few_shards(rng):
    code = ClayCode(k=4, m=2)
    sets, _ = _codeword_sets(code, rng, trials=1)
    sets[0] = {k: v for k, v in list(sets[0].items())[: code.k - 1]}
    with pytest.raises(ValueError):
        code.decode_batch(sets)


def test_rpc_batched_path_byte_identical(cluster, rng):
    """Acceptance: batched decode == per-chunkset decode == put() input."""
    contract, sps, rpc, client = cluster
    data = rng.integers(0, 256, 300_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    # inject failures so the batch spans multiple erasure patterns
    sps[meta.placement[(0, 0)]].crash()
    sps[meta.placement[(1, 1)]].behavior.corrupt = True

    rpc.batch_decode = True
    rpc._cache.clear()
    batched = rpc.read_blob(meta.blob_id)

    rpc.batch_decode = False
    rpc._cache.clear()
    per_chunkset = rpc.read_blob(meta.blob_id)

    assert batched == per_chunkset == data
    rpc.batch_decode = True
