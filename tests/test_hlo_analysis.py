"""Unit tests for the post-SPMD HLO analyzer (roofline accounting)."""
import textwrap

from repro.launch import hlo_analysis as H

TOY = textwrap.dedent("""\
    HloModule jit_toy, num_partitions=8

    %add.clone (x: f32[], y: f32[]) -> f32[] {
      %x = f32[] parameter(0)
      %y = f32[] parameter(1)
      ROOT %add.9 = f32[] add(%x, %y)
    }

    %fused_slice (param_0: f32[4,32,128], param_1: s32[]) -> f32[1,32,128] {
      %param_0 = f32[4,32,128]{2,1,0} parameter(0)
      %param_1 = s32[] parameter(1)
      %c0 = s32[] constant(0)
      ROOT %dynamic-slice.1 = f32[1,32,128]{2,1,0} dynamic-slice(%param_0, %param_1, %c0, %c0), dynamic_slice_sizes={1,32,128}
    }

    %body (p: (s32[], f32[16,32], f32[4,32,128])) -> (s32[], f32[16,32], f32[4,32,128]) {
      %p = (s32[], f32[16,32], f32[4,32,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %h = f32[16,32]{1,0} get-tuple-element(%p), index=1
      %ws = f32[4,32,128]{2,1,0} get-tuple-element(%p), index=2
      %w = f32[1,32,128]{2,1,0} fusion(%ws, %i), kind=kLoop, calls=%fused_slice
      %wb = f32[32,128]{1,0} bitcast(%w)
      %dot.1 = f32[16,128]{1,0} dot(%h, %wb), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[16,128]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add.clone
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      %h2 = f32[16,32]{1,0} slice(%ar), slice={[0:16],[0:32]}
      ROOT %t = (s32[], f32[16,32], f32[4,32,128]) tuple(%i2, %h2, %ws)
    }

    %cond (p: (s32[], f32[16,32], f32[4,32,128])) -> pred[] {
      %p = (s32[], f32[16,32], f32[4,32,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(4)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (a: f32[16,32], ws: f32[4,32,128]) -> f32[16,32] {
      %a = f32[16,32]{1,0} parameter(0)
      %ws = f32[4,32,128]{2,1,0} parameter(1)
      %c0 = s32[] constant(0)
      %t0 = (s32[], f32[16,32], f32[4,32,128]) tuple(%c0, %a, %ws)
      %w = (s32[], f32[16,32], f32[4,32,128]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
      ROOT %out = f32[16,32]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_shape_bytes():
    assert H._shape_bytes("f32[4,32,128]") == 4 * 32 * 128 * 4
    assert H._shape_bytes("bf16[2,3]") == 12
    assert H._shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert H._shape_bytes("pred[]") == 1


def test_split_computations():
    comps = H._split_computations(TOY)
    assert {"add.clone", "fused_slice", "body", "cond", "main"} <= set(comps)
    assert H._entry_name(TOY) == "main"


def test_trip_count_from_backend_config():
    line = '%w = (s32[]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}'
    assert H._trip_count(line, []) == 7


def test_trip_count_fallback_constant():
    assert H._trip_count("%w = while(...), condition=%c, body=%b",
                         ["%n = s32[] constant(12)", "compare"]) == 12


def test_loop_multiplied_collectives_and_flops():
    r = H.analyze_hlo(TOY, total_devices=8)
    ar = r["collectives"]["all-reduce"]
    assert ar["count"] == 4  # one per loop iteration
    # each all-reduce: 16*128*4 = 8192 B; ring wire = 2*B*(4-1)/4
    assert ar["bytes"] == 4 * 8192
    assert abs(ar["wire_bytes"] - 4 * 2 * 8192 * 3 / 4) < 1e-6
    # dot: 2 * (16*128) * K=32 per iteration, 4 iterations
    assert r["hlo_flops"] == 4 * 2 * 16 * 128 * 32


def test_fusion_param_slice_adjustment():
    """The fusion slicing (4,32,128) stacked weights charges the slice,
    not the whole stack."""
    r = H.analyze_hlo(TOY, total_devices=8)
    # naive accounting charges the full (4,32,128) ws stack (64 KiB) per
    # iteration; slice-aware accounting charges the (1,32,128) slice (16 KiB)
    full_ws, slice_ws = 4 * 32 * 128 * 4, 32 * 128 * 4
    naive_floor = 4 * full_ws  # just the ws reads under naive accounting
    assert r["hlo_bytes"] < naive_floor + 200_000
    assert r["hlo_bytes"] < 450_000  # empirically ~385 KB with slice-aware


def test_group_size_parsing():
    assert H._group_size("replica_groups=[2,4]<=[8]", 8) == 4
    assert H._group_size("replica_groups={{0,1,2,3}}", 8) == 4
    assert H._group_size("no groups here", 8) == 8
