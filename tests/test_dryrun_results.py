"""The recorded dry-run matrix must be complete and green.

Skips cleanly if the matrix hasn't been produced yet (results/dryrun);
CI-style gate once it has.
"""
import json
import pathlib

import pytest

from repro.configs import ALL_ARCHS

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"

V5E_HBM = 16 * 1024**3


def _load():
    if not RESULTS.exists():
        pytest.skip("dry-run matrix not generated yet")
    recs = {}
    for p in RESULTS.glob("*.json"):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    if len(recs) < 80:
        pytest.skip(f"matrix incomplete ({len(recs)}/80 cells)")
    return recs


def test_all_cells_green():
    recs = _load()
    bad = [k for k, r in recs.items() if r["status"] == "error"]
    assert not bad, f"failed cells: {bad}"


def test_expected_skips_only():
    recs = _load()
    skipped = {k for k, r in recs.items() if r["status"] == "skipped"}
    expect_skip = {
        (a, "long_500k", m)
        for a in ALL_ARCHS if a not in ("hymba-1.5b", "falcon-mamba-7b")
        for m in ("single", "multi")
    }
    assert skipped == expect_skip


def test_multi_pod_cells_use_512_devices():
    recs = _load()
    for (a, s, m), r in recs.items():
        if r["status"] != "ok":
            continue
        assert r["devices"] == (512 if m == "multi" else 256), (a, s, m)


def test_memory_within_hbm_budget():
    """args + corrected temp must fit a 16 GiB v5e chip (DESIGN.md notes the
    CPU-backend bf16->f32 inflation we subtract)."""
    recs = _load()
    over = []
    for key, r in recs.items():
        if r["status"] != "ok":
            continue
        mem = r["memory"]
        corrected = (mem["argument_bytes"] + mem["temp_bytes"]
                     - r.get("cpu_bf16_inflation_bytes", 0))
        # the f32-twin heuristic can over-subtract when XLA reuses buffers;
        # arguments are always resident, so clamp there
        corrected = max(corrected, mem["argument_bytes"])
        if corrected > V5E_HBM * 1.05:
            over.append((key, corrected / 1e9))
    assert not over, f"cells over HBM: {over}"


def test_collectives_present_in_distributed_cells():
    recs = _load()
    for key, r in recs.items():
        if r["status"] != "ok":
            continue
        assert r["collective_count"] > 0, f"{key} compiled with no collectives?"
