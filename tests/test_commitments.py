"""Merkle vector-commitment properties (§3.4)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import commitments as cm


@given(st.lists(st.binary(min_size=1, max_size=64), min_size=1, max_size=40))
@settings(max_examples=40, deadline=None)
def test_prove_verify_roundtrip(leaves):
    tree = cm.MerkleTree(leaves)
    for i, leaf in enumerate(leaves):
        assert cm.verify(tree.root, leaf, tree.prove(i))


def test_wrong_leaf_fails():
    tree = cm.MerkleTree([b"a", b"b", b"c"])
    proof = tree.prove(1)
    assert not cm.verify(tree.root, b"x", proof)


def test_wrong_index_fails():
    tree = cm.MerkleTree([b"a", b"b", b"c", b"d"])
    p1 = tree.prove(1)
    bad = cm.MerkleProof(index=2, path=p1.path)
    assert not cm.verify(tree.root, b"b", bad)


def test_any_bit_flip_detected(rng):
    chunk = rng.integers(0, 256, (16, 64), dtype=np.uint8)
    commit, tree = cm.commit_chunk(chunk)
    samples = cm.chunk_samples(chunk)
    # tamper one byte of one sample
    tampered = bytearray(samples[0])
    tampered[10] ^= 1
    assert not cm.verify(commit.root, bytes(tampered), tree.prove(0))


def test_chunk_commit_deterministic(rng):
    chunk = rng.integers(0, 256, (8, 513), dtype=np.uint8)
    c1, _ = cm.commit_chunk(chunk)
    c2, _ = cm.commit_chunk(chunk.copy())
    assert c1.root == c2.root


def test_samples_are_1kib(rng):
    chunk = rng.integers(0, 256, 5000, dtype=np.uint8)
    samples = cm.chunk_samples(chunk)
    assert all(len(s) == cm.SAMPLE_BYTES for s in samples)
    joined = b"".join(samples)
    assert joined[:5000] == chunk.tobytes()


def test_bulk_digests_match_shape(rng):
    samples = rng.integers(0, 256, (33, cm.SAMPLE_BYTES), dtype=np.uint8)
    d = cm.bulk_sample_digests(samples)
    assert d.shape == (33,) and d.dtype == np.uint32
    assert len(np.unique(d)) == 33  # distinct samples -> distinct digests


def test_proof_size_logarithmic():
    leaves = [bytes([i % 256]) for i in range(1024)]
    tree = cm.MerkleTree(leaves)
    assert len(tree.prove(0).path) == 10  # log2(1024)
