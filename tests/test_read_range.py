"""RPCNode.read_range boundary conditions (chunkset edges, final padding)."""
import numpy as np
import pytest


@pytest.fixture
def stored(cluster, rng):
    contract, sps, rpc, client = cluster
    cs = rpc.layout.chunkset_bytes
    data = rng.integers(0, 256, int(2.5 * cs), dtype=np.uint8).tobytes()
    meta = client.put(data)
    return rpc, client, meta, data


def test_read_spanning_chunkset_boundary(stored):
    rpc, client, meta, data = stored
    cs = rpc.layout.chunkset_bytes
    for off, ln in [(cs - 1, 2), (cs - 100, 200), (2 * cs - 1, 2), (0, 2 * cs)]:
        assert rpc.read_range(meta.blob_id, off, ln) == data[off : off + ln]


def test_read_ending_inside_padded_final_chunkset(stored):
    rpc, client, meta, data = stored
    cs = rpc.layout.chunkset_bytes
    # the blob ends mid-chunkset: reads must stop at size_bytes, padding invisible
    off = 2 * cs + 100
    assert rpc.read_range(meta.blob_id, off, 10_000) == data[off : off + 10_000]
    # a read whose requested length overruns the blob is clipped at the end
    tail = rpc.read_range(meta.blob_id, len(data) - 50, 10_000)
    assert tail == data[-50:]


def test_last_byte_and_single_bytes(stored):
    rpc, client, meta, data = stored
    assert rpc.read_range(meta.blob_id, len(data) - 1, 1) == data[-1:]
    cs = rpc.layout.chunkset_bytes
    for off in (0, cs - 1, cs, 2 * cs):
        assert rpc.read_range(meta.blob_id, off, 1) == data[off : off + 1]


def test_full_blob_equals_put_input(stored):
    rpc, client, meta, data = stored
    assert rpc.read_blob(meta.blob_id) == data
    assert client.get(meta.blob_id) == data


def test_zero_or_negative_length_rejected(stored):
    rpc, client, meta, data = stored
    with pytest.raises(ValueError):
        rpc.read_range(meta.blob_id, 0, 0)
