"""Empirical incentive-compatibility checks (Theorems 1-3 + §5.4 bounds)."""
import pytest

from repro.core import economics as E
from repro.core.audit import AuditParams
from repro.core.simulation import SimResult, honest_population, run_sim
from repro.storage.sp import SPBehavior

PARAMS = AuditParams(p_a=0.5, auditors_per_audit=4, C=50, p_ata=0.3)


def _with_deviant(n: int, behavior: SPBehavior) -> dict[int, SPBehavior]:
    pop = honest_population(n)
    pop[0] = behavior
    return pop


class TestTheorem1HonestIsNash:
    """No unilateral deviation from the honest profile improves utility."""

    N = 10

    @pytest.fixture(scope="class")
    def honest_result(self) -> SimResult:
        return run_sim(honest_population(self.N), params=PARAMS, epochs=2)

    @pytest.mark.parametrize("deviation", [
        SPBehavior(drop_fraction=0.3),  # fake 30% of storage
        SPBehavior(drop_fraction=1.0),  # store nothing
        SPBehavior(lazy_auditor=True, retain_proofs=False),  # blind 1s, no proofs
        SPBehavior(crashed=True),  # do nothing at all
    ])
    def test_deviation_not_profitable(self, honest_result, deviation):
        dev = run_sim(_with_deviant(self.N, deviation), params=PARAMS, epochs=2)
        assert dev.utility(0) < honest_result.utility(0), (
            f"deviation {deviation} profits: {dev.utility(0):.2f} >= "
            f"{honest_result.utility(0):.2f}"
        )

    def test_honest_sps_score_high_and_unslashed(self, honest_result):
        assert all(s >= 0.99 for s in honest_result.scores.values())
        assert all(v == 0 for v in honest_result.slashed.values())
        assert not honest_result.ejected


class TestTheorem2MutualDishonestyNotNash:
    """All-dishonest: each SP stores nothing and blindly reports success.
    Per-'1' expected utility is negative (p_ata*S_ata >> rwd_au), so a
    deviator that abstains from false reporting does strictly better."""

    N = 9

    def test_dishonest_lose_and_deviation_improves(self):
        dishonest = {i: SPBehavior(drop_fraction=1.0, lazy_auditor=True,
                                   retain_proofs=False) for i in range(self.N)}
        all_bad = run_sim(dishonest, params=PARAMS, epochs=2)
        # the mutual-dishonesty payoff is strongly negative (ATA slashing)
        assert all_bad.utility(0) < 0
        # deviator: still stores nothing, but doesn't file false reports
        deviant = dict(dishonest)
        deviant[0] = SPBehavior(drop_fraction=1.0, crashed=True)
        dev = run_sim(deviant, params=PARAMS, epochs=2)
        assert dev.utility(0) > all_bad.utility(0)

    def test_ata_calibration_inequality(self):
        """S_ata >= rwd_au / (p_ata * eps) (§5.4-4) holds for defaults."""
        p = PARAMS
        assert p.S_ata >= E.min_ata_slashing(p.rwd_au, p.p_ata, p.eps)


class TestTheorem3CoalitionResistance:
    """A coalition of f < n/3 SPs rating each other perfectly cannot lift a
    misbehaving member's trimmed score or meaningfully raise group utility."""

    N = 10  # f = 3

    def test_coalition_cannot_shield_member(self):
        pop = honest_population(self.N)
        pop[0] = SPBehavior(drop_fraction=1.0, lazy_auditor=True)  # shielded member
        pop[1] = SPBehavior(lazy_auditor=True)  # colluders report 1 for everyone
        pop[2] = SPBehavior(lazy_auditor=True)
        res = run_sim(pop, params=PARAMS, epochs=2)
        honest = run_sim(honest_population(self.N), params=PARAMS, epochs=2)
        # the misbehaving member's score collapses despite f-1 friendly raters
        assert res.scores.get(0, 1.0) < 0.7 or 0 in res.ejected
        coalition_dev = sum(res.utility(i) for i in (0, 1, 2))
        coalition_honest = sum(honest.utility(i) for i in (0, 1, 2))
        assert coalition_dev < coalition_honest + 1e-6


class TestSection54Calibration:
    def test_paper_pa_bound(self):
        assert E.min_audit_probability(E.CostModel()) == pytest.approx(0.0076, abs=1e-4)

    def test_paper_detection_probability(self):
        assert E.detection_probability(0.1, 50) == pytest.approx(0.632, abs=1e-3)
        assert E.detection_probability(0.1, 50) > 0.63  # the paper's claim

    def test_lemma1_retention_rational(self):
        cm = E.CostModel()
        p_a = 0.008  # just above the bound
        assert E.retrieval_strategy_cost(p_a, cm) >= E.storage_strategy_cost(cm)

    def test_fee_split_normalization(self):
        n_a = E.audits_per_gb_month(0.05, 1024, 4, 30)
        rwd_st = E.fee_split(W=0.023, n_a=n_a, rwd_au=1e-9)
        assert 0 < rwd_st < 0.023

    def test_fake_storage_slashing_bound_positive(self):
        s = E.fake_storage_slashing_bound(0.05, 1.0, 0.1, 1000, 50)
        assert s > 0
