"""Data pipeline + fault-tolerant training loop integration."""
import numpy as np

from repro.configs import get_smoke
from repro.data.pipeline import BlobTokenDataset, write_token_corpus
from repro.storage.checkpoint import CheckpointManager
from repro.storage.repair import RepairCoordinator
from repro.train.loop import Trainer


def test_dataset_batches_shift_labels(cluster):
    _, _, _, client = cluster
    toks = np.arange(50_000, dtype=np.int32) % 97
    bid = write_token_corpus(client, toks)
    ds = BlobTokenDataset(client, bid, batch=4, seq_len=16)
    for x, y in ds.batches(5, background=False):
        assert x.shape == (4, 16) and y.shape == (4, 16)
        assert np.array_equal(x[:, 1:], y[:, :-1])


def test_dataset_sharding_disjoint(cluster):
    _, _, _, client = cluster
    toks = np.arange(50_000, dtype=np.int32)
    bid = write_token_corpus(client, toks)
    d0 = BlobTokenDataset(client, bid, batch=2, seq_len=8, shard=0, num_shards=2)
    d1 = BlobTokenDataset(client, bid, batch=2, seq_len=8, shard=1, num_shards=2)
    x0, _ = next(d0.batches(1, background=False))
    x1, _ = next(d1.batches(1, background=False))
    assert not np.array_equal(x0, x1)


def test_dataset_survives_sp_crash(cluster):
    contract, sps, rpc, client = cluster
    toks = np.arange(50_000, dtype=np.int32)
    bid = write_token_corpus(client, toks)
    sps[contract.blobs[bid].placement[(0, 0)]].crash()
    rpc._cache.clear()
    ds = BlobTokenDataset(client, bid, batch=2, seq_len=8)
    x, y = next(ds.batches(1, background=False))
    assert x.shape == (2, 8)


def test_trainer_loss_decreases_and_restarts(cluster):
    contract, sps, rpc, client = cluster
    cfg = get_smoke("granite-8b")
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, 100_000, dtype=np.int32)
    bid = write_token_corpus(client, toks)
    ds = BlobTokenDataset(client, bid, batch=4, seq_len=32)
    ckpt = CheckpointManager(client, num_host_shards=2)
    repair = RepairCoordinator(contract, sps, rpc.layout)
    tr = Trainer(cfg, ckpt=ckpt, repair=repair, ckpt_every=4)

    state = tr.init_state()
    batches = ds.batches(40, background=False)
    state, rep = tr.run(state, batches, 10)
    assert rep.losses[-1] < rep.losses[0]

    # crash an SP, restore from the coded checkpoint, keep training
    victim = next(iter(sps))
    sps[victim].crash()
    rpc._cache.clear()
    restored, step0 = tr.restore_latest(state)
    assert restored is not None and step0 == 8
    sps[victim].recover()
    sps[victim].wipe()
    assert len(repair.repair_all()) > 0
    state2, rep2 = tr.run(restored, batches, 4, start_step=step0)
    assert np.isfinite(rep2.final_loss)
    assert tr.restarts == 1
