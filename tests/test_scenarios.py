"""Scenario registry + SLO + sweep driver contracts.

Synthetic scenarios (cheap run callables, standalone Scenario objects
that never touch the module REGISTRY) cover the registry/runner/sweep
logic; one real smoke run of ``tune_admission`` pins the end-to-end
digest-reproducibility claim the optimiser rests on.
"""
from __future__ import annotations

import dataclasses
import hashlib

import pytest

from repro.configs.shelby import CONFIG, KNOB_DOCS, ShelbyConfig, knob_doc
from repro.scenarios import load_builtin
from repro.scenarios.registry import (
    REGISTRY,
    SLO,
    DuplicateScenarioError,
    Scenario,
    ScenarioError,
    ScenarioRegistry,
    SLOViolation,
    UnknownKnobError,
    UnknownScenarioError,
)
from repro.scenarios.report import metric_path
from repro.scenarios.runner import run_scenario
from repro.scenarios.sweep import KnobAxis, ScenarioProblem, SearchError


def _knob_digest(cfg) -> str:
    """A deterministic stand-in for the replay digest: any function of
    the resolved knobs works for driver-logic tests."""
    key = f"{cfg.rpc_max_inflight_fetches}|{cfg.rpc_shed_deadline_ms}"
    return hashlib.sha256(key.encode()).hexdigest()[:16]


def _toy(name="toy", slos=(), knobs=None, run=None, **kw):
    """A standalone Scenario (NOT registered in the module REGISTRY)."""
    def default_run(ctx):
        cfg = ctx.config
        budget = cfg.rpc_max_inflight_fetches
        # saturating response: goodput grows with the fetch budget until
        # the tail blows past it — gives the optimiser a real landscape
        if budget is None:
            goodput, p99 = 500.0, 400.0   # free-running: fast but infeasible
        else:
            goodput = 100.0 + 20.0 * min(budget, 12)
            p99 = 40.0 + 8.0 * budget
        return {"goodput": goodput, "p99": p99,
                "nested": {"budget": budget if budget is not None else -1},
                "digest": _knob_digest(cfg)}
    return Scenario(
        name=name, description="toy", workload="none", section="toy",
        run=run or default_run, knobs=knobs or {}, slos=tuple(slos), **kw)


# -- registry ----------------------------------------------------------------

def test_duplicate_name_rejected():
    reg = ScenarioRegistry()
    reg.register(_toy("dup"))
    with pytest.raises(DuplicateScenarioError, match="dup"):
        reg.register(_toy("dup"))


def test_unknown_scenario_lists_names():
    reg = ScenarioRegistry()
    reg.register(_toy("present"))
    with pytest.raises(UnknownScenarioError, match="present"):
        reg.get("absent")


def test_unknown_knob_rejected_at_registration():
    with pytest.raises(UnknownKnobError, match="not_a_knob"):
        _toy(knobs={"not_a_knob": 1})
    with pytest.raises(UnknownKnobError, match="not_a_knob"):
        Scenario(name="t", description="", workload="", section="t",
                 run=lambda ctx: {}, tunable=("not_a_knob",))


def test_unknown_knob_rejected_at_call_time():
    sc = _toy()
    with pytest.raises(UnknownKnobError, match="typo_knob"):
        sc.config({"typo_knob": 3})
    with pytest.raises(UnknownKnobError):
        run_scenario(sc, overrides={"typo_knob": 3}, smoke=True, emit=False)


def test_knob_resolution_order():
    """defaults < scenario.knobs < call-time overrides."""
    sc = _toy(knobs={"rpc_max_inflight_fetches": 6,
                     "rpc_shed_deadline_ms": 100.0})
    # default layer
    assert CONFIG.rpc_max_inflight_fetches is None
    # scenario layer wins over defaults
    cfg = sc.config()
    assert cfg.rpc_max_inflight_fetches == 6
    assert cfg.rpc_shed_deadline_ms == 100.0
    # override layer wins over scenario, untouched knobs keep lower layers
    cfg = sc.config({"rpc_max_inflight_fetches": 12})
    assert cfg.rpc_max_inflight_fetches == 12
    assert cfg.rpc_shed_deadline_ms == 100.0
    assert cfg.rpc_single_flight == CONFIG.rpc_single_flight


def test_builtin_registry_contents():
    load_builtin()
    names = REGISTRY.names()
    for expected in ("serve_grid", "concurrent", "background", "churn",
                     "das", "engine", "tune_admission"):
        assert expected in names, names
    # sections are unique: two scenarios must never clobber one BENCH key
    sections = [sc.section for sc in REGISTRY]
    assert len(sections) == len(set(sections))


# -- SLOs --------------------------------------------------------------------

def test_slo_ops_and_bounds():
    payload = {"p99_ms": 120.0, "limit": 150.0, "nested": {"v": 2}}
    assert SLO("p99_ms", "<=", 150.0).check(payload, CONFIG).ok
    assert not SLO("p99_ms", ">", 150.0).check(payload, CONFIG).ok
    # bound as another metric path
    assert SLO("p99_ms", "<", "limit").check(payload, CONFIG).ok
    # bound as a config knob name
    cfg = dataclasses.replace(CONFIG, bg_p99_budget=1.5)
    res = SLO("nested.v", "<=", "bg_p99_budget").check(payload, cfg)
    assert not res.ok and res.bound == 1.5
    # atol slack direction: loosens <=, tightens side for >= is symmetric
    assert SLO("p99_ms", "<=", 119.0, atol=2.0).check(payload, CONFIG).ok
    assert SLO("p99_ms", ">=", 121.0, atol=2.0).check(payload, CONFIG).ok
    with pytest.raises(ScenarioError, match="op"):
        SLO("p99_ms", "==", 1.0)


def test_slo_violation_names_scenario():
    sc = _toy("sat_storm", slos=(SLO("p99", "<=", 150.0),),
              knobs={"rpc_max_inflight_fetches": 24})  # p99 = 232 > 150
    with pytest.raises(SLOViolation) as ei:
        run_scenario(sc, smoke=True, emit=False)
    msg = str(ei.value)
    assert "sat_storm" in msg and "p99" in msg and "150" in msg
    # SLOViolation must trip plain assert-catching harnesses too
    assert isinstance(ei.value, AssertionError)
    # raise_on_violation=False records instead of raising
    res = run_scenario(sc, smoke=True, emit=False, raise_on_violation=False)
    assert not res.slos_ok and not res.slo_results[0].ok


def test_metric_path_errors_name_the_segment():
    with pytest.raises(KeyError, match="missing"):
        metric_path({"a": {"b": 1}}, "a.missing")
    assert metric_path({"a": [{"x": 5}]}, "a.0.x") == 5


# -- sweep driver ------------------------------------------------------------

AXES = (KnobAxis("rpc_max_inflight_fetches", (None, 3, 6, 12, 24)),)


def test_sweep_memoizes_and_scores_infeasible():
    calls = []
    base = _toy(slos=(SLO("p99", "<=", 150.0),),
                knobs={"rpc_max_inflight_fetches": 6})
    counted = dataclasses.replace(
        base, run=lambda ctx: (calls.append(1), base.run(ctx))[1])
    prob = ScenarioProblem(counted, AXES, "goodput", smoke=True,
                           verbose=False)
    result = prob.sweep()
    # baseline {} and the None axis candidate are distinct memo keys but
    # the 5-candidate grid itself evaluates each point exactly once
    assert len(calls) == len(result.history) == 6
    prob.evaluate({"rpc_max_inflight_fetches": 3})  # memoized: no new run
    assert len(calls) == 6
    # feasible argmax is budget=12 (goodput 340, p99 136); None and 24
    # are infeasible and must never win despite higher raw goodput
    assert result.best.knobs == {"rpc_max_inflight_fetches": 12}
    assert result.best.feasible and result.improved
    infeasible = [p for p in result.history if not p.feasible]
    assert infeasible and all(p.violations for p in infeasible)


def test_hill_climb_escapes_infeasible_start_and_improves():
    sc = _toy(slos=(SLO("p99", "<=", 150.0),),
              knobs={"rpc_max_inflight_fetches": 6})
    prob = ScenarioProblem(sc, AXES, "goodput", smoke=True, verbose=False)
    # start at the ShelbyConfig default (admission off -> infeasible)
    result = prob.hill_climb(start={"rpc_max_inflight_fetches": None})
    assert result.best.feasible
    assert result.best.knobs == {"rpc_max_inflight_fetches": 12}
    # improvement is against the scenario's registered default (budget=6)
    assert result.baseline.value == pytest.approx(220.0)
    assert result.best.value == pytest.approx(340.0)
    assert result.improved
    # every evaluated point carries its reproducibility digest
    assert all(p.digest for p in result.history)
    doc = result.to_json()
    assert doc["improved"] and doc["best"]["digest"]


def test_sweep_requires_digest_and_real_axes():
    no_digest = _toy(run=lambda ctx: {"goodput": 1.0, "p99": 1.0})
    prob = ScenarioProblem(no_digest, AXES, "goodput", smoke=True,
                           verbose=False)
    with pytest.raises(SearchError, match="digest"):
        prob.evaluate({})
    with pytest.raises(UnknownKnobError):
        ScenarioProblem(_toy(), (KnobAxis("bogus_knob", (1,)),), "goodput")
    with pytest.raises(SearchError, match="candidates"):
        KnobAxis("rpc_hedge", ())


# -- knob docs (satellite 4's cross-check) -----------------------------------

def test_every_knob_documented():
    fields = {f.name for f in dataclasses.fields(ShelbyConfig)}
    assert set(KNOB_DOCS) == fields, (
        f"KNOB_DOCS out of sync: missing={sorted(fields - set(KNOB_DOCS))} "
        f"stale={sorted(set(KNOB_DOCS) - fields)}"
    )
    for name, doc in KNOB_DOCS.items():
        assert "unit:" in doc and "default:" in doc and "Exercised by" in doc, (
            f"{name}: doc must state unit, default, and exercising scenario"
        )
    assert "unit:" in knob_doc("rpc_hedge")
    with pytest.raises(KeyError, match="nonexistent_knob"):
        knob_doc("nonexistent_knob")


def test_registry_references_only_documented_knobs():
    load_builtin()
    for sc in REGISTRY:
        for k in list(sc.knobs) + list(sc.tunable):
            assert k in KNOB_DOCS, f"{sc.name}: undocumented knob {k}"
        for slo in sc.slos:
            if isinstance(slo.bound, str) and slo.bound in {
                    f.name for f in dataclasses.fields(ShelbyConfig)}:
                assert slo.bound in KNOB_DOCS


# -- the real thing: digest reproducibility ----------------------------------

def test_tune_admission_same_seed_same_digest():
    """Two smoke evaluations of the registered tune_admission scenario
    (fresh worlds, fresh fleets) produce the SAME replay digest — the
    property every sweep-result number leans on."""
    load_builtin()
    a = run_scenario("tune_admission", smoke=True, emit=False)
    b = run_scenario("tune_admission", smoke=True, emit=False)
    assert a.digest and a.digest == b.digest
    assert a.payload["goodput_mbps"] == b.payload["goodput_mbps"]
    assert a.slos_ok and b.slos_ok
    # overrides change the resolved config AND the digest (the knobs are
    # genuinely load-bearing, not cosmetic)
    c = run_scenario("tune_admission", smoke=True, emit=False,
                     overrides={"rpc_max_inflight_fetches": 3})
    assert c.config.rpc_max_inflight_fetches == 3
    assert c.digest != a.digest
