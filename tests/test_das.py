"""Data-availability sampling (ISSUE 7): the 2-D extension, proof-carrying
tiny reads, and the light-client sampling plane.

Covers the tentpole — the k x k -> 2k x 2k extension (any k rows/columns
reconstruct the square bit-exact), coordinate-bound share proofs, the
sampler's measured detection rate against the analytic ``1-(1-q)^s`` over
multiple seeds AND withholding fractions — plus the satellites: pay-per-
sample receipts under settlement conservation, the ``cache_bypass``
steering hint, storm determinism, the batched small-and-wide GF path
(numpy == Pallas), and the config plumbing into ``run_sim``.
"""
import numpy as np
import pytest

from repro.configs.shelby import ShelbyConfig
from repro.core import extend2d
from repro.core.extend2d import Extend2D, commit_square, detection_probability
from repro.core.simulation import honest_population, run_sim
from repro.kernels import ops
from repro.net.workloads import das_storm
from repro.storage import das
from repro.storage.das import (
    DASSpec,
    LightClientSampler,
    measure_detection,
    seed_withholding,
)

SPEC = DASSpec(k=4, share_bytes=64, samples_per_epoch=16)


def _square(k=4, share_bytes=64, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, (k, k, share_bytes), dtype=np.uint8)


# -- the 2-D extension --------------------------------------------------------
def test_extension_is_systematic():
    lay = Extend2D(k=4)
    sq = _square()
    ext = lay.extend(sq)
    assert ext.shape == (8, 8, 64)
    assert np.array_equal(ext[:4, :4], sq)  # data survives in the corner


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_any_k_rows_reconstruct_bit_exact(seed):
    lay = Extend2D(k=4)
    ext = lay.extend(_square(seed=seed))
    rng = np.random.default_rng(seed + 100)
    for _ in range(4):
        rows = sorted(rng.choice(lay.side, size=lay.k, replace=False))
        got = lay.reconstruct_from_rows(
            {int(r): np.ascontiguousarray(ext[r]) for r in rows}
        )
        assert np.array_equal(got, ext)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_any_k_cols_reconstruct_bit_exact(seed):
    lay = Extend2D(k=4)
    ext = lay.extend(_square(seed=seed))
    rng = np.random.default_rng(seed + 200)
    for _ in range(4):
        cols = sorted(rng.choice(lay.side, size=lay.k, replace=False))
        got = lay.reconstruct_from_cols(
            {int(c): np.ascontiguousarray(ext[:, c]) for c in cols}
        )
        assert np.array_equal(got, ext)


def test_extend_batch_matches_single_and_pallas():
    lay = Extend2D(k=4)
    squares = [_square(seed=s) for s in range(5)]
    batched = lay.extend_batch(squares)
    for sq, ext in zip(squares, batched):
        assert np.array_equal(ext, lay.extend(sq))
    # the Pallas GF matmul (interpret mode off-TPU) is byte-identical
    pallas = lay.extend_batch(squares, matmul=ops.gf_matmul_np)
    for a, b in zip(batched, pallas):
        assert np.array_equal(a, b)


# -- proof-carrying shares ----------------------------------------------------
def test_share_proofs_verify_on_both_axes():
    lay = Extend2D(k=4)
    csq = commit_square(lay.extend(_square()))
    root = csq.commitment.das_root
    for axis in ("row", "col"):
        proof = csq.prove(2, 5, axis=axis)
        assert proof.nbytes > 0
        assert extend2d.verify_share(root, lay.side, csq.share(2, 5).tobytes(),
                                     proof)


def test_proof_rejects_tamper_and_replay():
    lay = Extend2D(k=4)
    csq = commit_square(lay.extend(_square()))
    root = csq.commitment.das_root
    proof = csq.prove(2, 5, axis="row")
    # tampered share bytes
    bad = bytearray(csq.share(2, 5).tobytes())
    bad[0] ^= 0xFF
    assert not extend2d.verify_share(root, lay.side, bytes(bad), proof)
    # a valid proof replayed at another coordinate (coordinate binding)
    forged = extend2d.ShareProof(row=3, col=5, axis="row",
                                 axis_root=proof.axis_root,
                                 leaf_path=proof.leaf_path,
                                 root_path=proof.root_path)
    assert not extend2d.verify_share(root, lay.side,
                                     csq.share(2, 5).tobytes(), forged)
    # wrong root
    assert not extend2d.verify_share(b"\x00" * 32, lay.side,
                                     csq.share(2, 5).tobytes(), proof)


# -- detection: measured vs analytic -----------------------------------------
def test_detection_matches_analytic_across_seeds_and_fractions():
    # >= 3 fractions x >= 3 seeds; exact-count withholding + with-
    # replacement draws make 1-(1-q)^s exact, so tolerance is pure
    # Monte-Carlo noise on 64 Bernoulli trials per cell (~3 sigma)
    points = measure_detection(
        fractions=(0.05, 0.15, 0.30), seeds=(0, 1, 2),
        spec=SPEC, num_blobs=8, rounds=8,
    )
    assert len(points) == 9
    for pt in points:
        assert pt.analytic == detection_probability(pt.q_effective, pt.samples)
        assert abs(pt.measured - pt.analytic) <= 0.2, (
            f"q={pt.q_effective:.3f}: measured {pt.measured:.3f} "
            f"vs analytic {pt.analytic:.3f}"
        )


def test_zero_withholding_never_detects():
    points = measure_detection(fractions=(0.0,), seeds=(0,), spec=SPEC,
                               num_blobs=4, rounds=4)
    (pt,) = points
    assert pt.q_effective == 0.0 and pt.analytic == 0.0
    assert pt.detected == 0, "false positive with nothing withheld"


def test_detection_cheaper_than_full_chunk_audit():
    # a withholding SP RETAINS the data, so possession audits never fire;
    # the sampler catches it for less than one full-chunk audit read
    points = measure_detection(fractions=(0.30,), seeds=(0,), spec=SPEC,
                               num_blobs=6, rounds=6)
    (pt,) = points
    assert pt.detected > 0
    chunk_bytes = 64 * 1024 // 4  # the mini-world layout's full chunk
    assert pt.mean_samples_to_detect * pt.mean_sample_bytes < chunk_bytes


# -- the serving path: pay-per-sample, steering, receipts ---------------------
def test_sample_availability_pays_and_conserves():
    contract, sps, client, blob_ids = das._mini_world(6, SPEC, 2, seed=0)
    session = client.current_session
    before = len(session.receipts)
    verdicts = session.sample_availability(blob_ids, epoch=0, samples=8, seed=1)
    assert len(verdicts) == 2
    for v in verdicts:
        assert v.available and v.failures == 0
        assert v.verified == 8 and v.samples == 8
        assert v.sample_bytes > 0 and v.proof_bytes > 0
        assert v.paid > 0.0
    recs = session.receipts[before:]
    assert len(recs) == 16 and all(r.verified for r in recs)
    rec = contract.das[blob_ids[0]]
    assert all(r.nbytes == SPEC.share_bytes + rec.proof_bytes for r in recs)
    client.settle()  # conservation asserted inside close()


def test_withheld_samples_detect_and_debit_nothing():
    contract, sps, client, blob_ids = das._mini_world(6, SPEC, 1, seed=0)
    w = seed_withholding(contract, sps, blob_ids[0], 1.0)
    assert w == SPEC.side * SPEC.side
    session = client.current_session
    (v,) = session.sample_availability(blob_ids, epoch=0, samples=4, seed=2)
    assert not v.available and v.failures == 4 and v.verified == 0
    assert v.first_failure == 0
    assert v.paid == 0.0 and v.sample_bytes == 0
    client.settle()


def test_cache_bypass_steers_the_hot_cache():
    # default (bypass): repeated sampling of the same epoch re-fetches and
    # re-pays — nothing of the storm lands in the hot cache
    contract, sps, client, blob_ids = das._mini_world(6, SPEC, 1, seed=0)
    session = client.current_session
    node = client.fleet.primary
    session.sample_availability(blob_ids, epoch=0, samples=6, seed=3)
    session.sample_availability(blob_ids, epoch=0, samples=6, seed=3)
    assert node.stats.das_cache_hits == 0
    # counterfactual: the hint off -> the identical second round is served
    # from cache (free, proof already verified)
    contract2, sps2, client2, blob_ids2 = das._mini_world(6, SPEC, 1, seed=0)
    session2 = client2.current_session
    node2 = client2.fleet.primary
    session2.sample_availability(blob_ids2, epoch=0, samples=6, seed=3,
                                 cache_bypass=False)
    session2.sample_availability(blob_ids2, epoch=0, samples=6, seed=3,
                                 cache_bypass=False)
    assert node2.stats.das_cache_hits > 0
    cached = [r for r in session2.receipts if getattr(r, "cache_hit", False)]
    assert cached and all(r.proof_bytes == 0 for r in cached)
    client.settle()
    client2.settle()


def test_light_client_sampler_detections():
    contract, sps, client, blob_ids = das._mini_world(6, SPEC, 2, seed=0)
    seed_withholding(contract, sps, blob_ids[1], 0.5)
    sampler = LightClientSampler(client.current_session, SPEC, seed=0)
    verdicts = sampler.sample_epoch(0, blob_ids)
    assert len(verdicts) == 2
    by_blob = {v.blob_id: v for v in verdicts}
    assert by_blob[blob_ids[0]].available
    # q=0.5, s=16: detection probability 1 - 2^-16 — this must fire
    assert not by_blob[blob_ids[1]].available
    assert sampler.detections == 1
    client.settle()


# -- determinism --------------------------------------------------------------
def test_das_storm_is_a_pure_function_of_its_seed():
    contract, sps, client, blob_ids = das._mini_world(6, SPEC, 2, seed=0)
    recs = [contract.das[b] for b in blob_ids]
    a = das_storm(recs, clients=["c0", "c1"], num_requests=40, seed=9)
    b = das_storm(recs, clients=["c0", "c1"], num_requests=40, seed=9)
    assert a == b
    c = das_storm(recs, clients=["c0", "c1"], num_requests=40, seed=10)
    assert a != c
    assert all(0 <= r.row < SPEC.side and 0 <= r.col < SPEC.side for r in a)
    assert all(r.cache_bypass for r in a)


def test_draw_coords_deterministic_and_in_range():
    a = das.draw_coords(5, blob_id=1, epoch=3, s=32, side=8)
    b = das.draw_coords(5, blob_id=1, epoch=3, s=32, side=8)
    assert a == b and len(a) == 32
    assert das.draw_coords(5, blob_id=1, epoch=4, s=32, side=8) != a
    assert all(0 <= r < 8 and 0 <= c < 8 for r, c in a)


def test_session_replay_counts_das_records():
    contract, sps, client, blob_ids = das._mini_world(6, SPEC, 2, seed=0)
    recs = [contract.das[b] for b in blob_ids]
    reqs = das_storm(recs, clients=["c0"], num_requests=30, seed=4)

    def one():
        c = das._mini_world(6, SPEC, 2, seed=0)[2]
        with c.session() as session:
            _, result = session.replay(reqs)
        return result

    ra, rb = one(), one()
    assert ra.das_samples == 30 and ra.das_detections == 0
    assert ra.digest() == rb.digest()


# -- config + simulation plumbing --------------------------------------------
def test_config_das_spec_roundtrip():
    cfg = ShelbyConfig(das_k=2, das_share_bytes=128, das_samples_per_epoch=4,
                       das_proof_bytes_per_share=99)
    spec = cfg.das()
    assert spec == DASSpec(k=2, share_bytes=128, samples_per_epoch=4,
                           proof_bytes_per_share=99)
    assert ShelbyConfig(das_extension=False).das() is None


def test_proof_bytes_override_lands_on_the_record():
    spec = DASSpec(k=2, share_bytes=32, proof_bytes_per_share=1234)
    contract, sps, client, blob_ids = das._mini_world(6, spec, 1, seed=0)
    assert contract.das[blob_ids[0]].proof_bytes == 1234


def test_put_disperses_shares_when_das_enabled():
    contract, sps, client, blob_ids = das._mini_world(6, SPEC, 1, seed=0)
    rec = contract.das[blob_ids[0]]
    assert rec.side == SPEC.side
    assert set(rec.placement) == {
        (r, c) for r in range(rec.side) for c in range(rec.side)
    }
    stored = sum(sp.stored_shares() for sp in sps.values())
    assert stored == rec.side * rec.side


def test_run_sim_with_das_plane():
    spec = DASSpec(k=2, share_bytes=64, samples_per_epoch=4)
    res = run_sim(honest_population(6), epochs=2, num_blobs=2,
                  blob_bytes=2 * 2 * 64, das=spec, seed=1)
    assert res.das_samples == 2 * 2 * 4  # epochs x blobs x samples
    assert res.das_detections == 0
    assert res.das_proof_bytes > 0
    # the switch off: no dispersal, no sampling
    res_off = run_sim(honest_population(6), epochs=1, num_blobs=2,
                      blob_bytes=2 * 2 * 64, das=None, seed=1)
    assert res_off.das_samples == 0 and res_off.das_proof_bytes == 0
