"""Micropayment-channel safety (§3.2)."""
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core.payments import ChannelError, MicropaymentChannel, PaymentLedger


def test_basic_flow():
    ch = MicropaymentChannel(deposit=10.0)
    ch.pay(1.0)
    tx = ch.pay(2.5)
    assert tx.refund_amount == pytest.approx(6.5)
    client, server = ch.settle(tx)
    assert client == pytest.approx(6.5) and server == pytest.approx(3.5)


def test_cannot_exceed_deposit():
    ch = MicropaymentChannel(deposit=1.0)
    ch.pay(0.9)
    with pytest.raises(ChannelError):
        ch.pay(0.2)


def test_stale_refund_rejected():
    """The freshest refund preempts older ones — an uncooperative party
    cannot roll back payments (the paper's core channel-safety argument)."""
    ch = MicropaymentChannel(deposit=5.0)
    old = ch.pay(1.0)
    ch.pay(1.0)
    with pytest.raises(ChannelError):
        ch.settle(old)


def test_forged_signature_rejected():
    import dataclasses

    ch = MicropaymentChannel(deposit=5.0)
    tx = ch.pay(1.0)
    forged = dataclasses.replace(tx, refund_amount=5.0)
    with pytest.raises(ChannelError):
        ch.settle(forged)


def test_settle_twice_rejected():
    ch = MicropaymentChannel(deposit=5.0)
    tx = ch.pay(1.0)
    ch.settle(tx)
    with pytest.raises(ChannelError):
        ch.settle(tx)


def test_settle_times_strictly_decrease():
    ch = MicropaymentChannel(deposit=5.0)
    t_prev = ch.latest_refund.settle_time
    for _ in range(5):
        tx = ch.pay(0.5)
        assert tx.settle_time < t_prev  # newer refund enforceable earlier
        t_prev = tx.settle_time


@given(st.lists(st.floats(0.001, 0.2), min_size=1, max_size=50))
@settings(max_examples=30, deadline=None)
def test_conservation(payments):
    """client_refund + server_payout == deposit, payments monotone."""
    ch = MicropaymentChannel(deposit=sum(payments) + 1.0)
    for p in payments:
        ch.pay(p)
    client, server = ch.settle(ch.latest_refund)
    assert client + server == pytest.approx(ch.deposit)
    assert server == pytest.approx(sum(payments))


def test_ledger_totals():
    led = PaymentLedger()
    led.open("sp1", 10.0)
    led.open("sp2", 10.0)
    for _ in range(10):
        led.pay("sp1", 1e-6)
    led.pay("sp2", 5e-6)
    assert led.total_paid() == pytest.approx(15e-6)
