"""Logical-axis rules + multi-device equivalence (8 host devices, subprocess)."""
import os
import subprocess
import sys
import textwrap


from repro.sharding import DECODE_RULES, TRAIN_RULES, logical_to_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_divisible_dims_shard():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = logical_to_spec(("vocab", "embed"), (102400, 2048), TRAIN_RULES, mesh)
    assert spec == __import__("jax").sharding.PartitionSpec("model", "data")


def test_non_divisible_dims_replicate():
    mesh = FakeMesh({"data": 16, "model": 16})
    # hymba: 25 heads don't divide 16 -> replicated (trailing Nones trimmed)
    spec = logical_to_spec(("embed", "heads", "head_dim"), (1600, 25, 64), TRAIN_RULES, mesh)
    assert len(spec) < 2 or spec[1] is None


def test_axis_never_used_twice():
    mesh = FakeMesh({"data": 16, "model": 16})
    # decode rules put ('data','model') on embed and vocab: second one drops
    spec = logical_to_spec(("vocab", "embed"), (256000, 12288), DECODE_RULES, mesh)
    used = [a for part in spec if part for a in (part if isinstance(part, tuple) else (part,))]
    assert len(used) == len(set(used))


def test_missing_mesh_axes_filtered():
    mesh = FakeMesh({"data": 4, "model": 2})  # no 'pod'
    spec = logical_to_spec(("batch", "seq"), (32, 128), TRAIN_RULES, mesh)
    assert spec == __import__("jax").sharding.PartitionSpec("data")


_DISTRIBUTED_DRIVER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.models.model import build
    from repro.models import moe as moe_mod
    from repro.sharding import AxisCtx, TRAIN_RULES, DECODE_RULES, init_params, tree_shardings
    import dataclasses

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)

    # --- MoE: shard_map EP vs pure-local path (no-drop capacity) ---
    cfg = get_smoke("qwen3-moe-30b-a3b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    specs = moe_mod.moe_specs(cfg, layers=1)
    params = init_params(specs, jax.random.PRNGKey(1))
    params_l = jax.tree.map(lambda x: x[0], params)
    x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)).astype(np.float32) * 0.3, jnp.bfloat16)
    y_local, aux_l = jax.jit(lambda p, x: moe_mod.apply_moe(p, x, cfg, AxisCtx()))(params_l, x)
    ctx = AxisCtx(dict(TRAIN_RULES), mesh)
    y_dist, aux_d = jax.jit(lambda p, x: moe_mod.apply_moe(p, x, cfg, ctx))(params_l, x)
    d = np.abs(np.asarray(y_local, np.float32) - np.asarray(y_dist, np.float32)).max()
    assert d < 0.05, f"moe mismatch {d}"
    print("MOE_OK", d)

    # --- decode on mesh (incl. shard_map cache update) vs single-device ---
    cfg2 = get_smoke("granite-8b")
    model = build(cfg2)
    p2 = init_params(model.param_specs(), jax.random.PRNGKey(2))
    cache = init_params(model.cache_specs(4, 16), jax.random.PRNGKey(0))
    toks = rng.integers(0, cfg2.vocab, (4, 1)).astype(np.int32)
    lg_local, nc_local = jax.jit(lambda p,c,t: model.decode_step(p,c,t,jnp.int32(3), AxisCtx()))(p2, cache, toks)
    ctx2 = AxisCtx(dict(DECODE_RULES), mesh)
    lg_dist, nc_dist = jax.jit(lambda p,c,t: model.decode_step(p,c,t,jnp.int32(3), ctx2))(p2, cache, toks)
    d2 = np.abs(np.asarray(lg_local, np.float32) - np.asarray(lg_dist, np.float32)).max()
    ck = np.abs(np.asarray(nc_local["k"], np.float32) - np.asarray(nc_dist["k"], np.float32)).max()
    assert d2 < 0.05 and ck < 1e-6, f"decode mismatch {d2} {ck}"
    print("DECODE_OK", d2, ck)

    # --- train step on mesh: loss matches single-device ---
    from repro.train.step import make_train_step
    from repro.train.optimizer import init_state
    batch = {"tokens": rng.integers(0, cfg2.vocab, (4, 16)).astype(np.int32),
             "labels": rng.integers(0, cfg2.vocab, (4, 16)).astype(np.int32)}
    st = init_state(p2)
    _, m_local = jax.jit(make_train_step(cfg2, AxisCtx()))(st, batch)
    st2 = init_state(p2)
    _, m_dist = jax.jit(make_train_step(cfg2, AxisCtx(dict(TRAIN_RULES), mesh)))(st2, batch)
    dl = abs(float(m_local["loss"]) - float(m_dist["loss"]))
    assert dl < 0.02, f"train loss mismatch {dl}"
    print("TRAIN_OK", dl)
""")


def test_distributed_equivalence_8dev():
    """shard_map MoE, sharded-cache decode and distributed train_step match
    their single-device counterparts on an 8-device host mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _DISTRIBUTED_DRIVER],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "MOE_OK" in res.stdout and "DECODE_OK" in res.stdout and "TRAIN_OK" in res.stdout
