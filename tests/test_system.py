"""End-to-end behaviour of the whole system (driver-level)."""
import numpy as np


def test_e2e_train_driver_with_failure_and_restart():
    """The full launch/train.py flow: Shelby-backed corpus, coded
    checkpoints, SP failure, restart, MSR repair, loss decreasing."""
    from repro.launch.train import main

    losses = main([
        "--arch", "granite-8b", "--smoke", "--steps", "16", "--batch", "4",
        "--seq", "48", "--ckpt-every", "4", "--fail-at", "6",
    ])
    assert len(losses) >= 16
    k = len(losses) // 4
    assert sum(losses[-k:]) / k < sum(losses[:k]) / k


def test_e2e_serve_through_shelby():
    import jax

    from repro.configs import get_smoke
    from repro.launch.train import build_cluster
    from repro.models.model import build
    from repro.serve.engine import ServeEngine
    from repro.sharding import init_params
    from repro.storage.checkpoint import CheckpointManager

    cfg = get_smoke("yi-9b")
    contract, sps, rpc, client = build_cluster(num_sps=8)
    model = build(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(3))
    mgr = CheckpointManager(client, num_host_shards=2)
    mgr.save(1, params)
    # weight download under SP failure
    rec = mgr.records[1]
    victim = contract.blobs[rec.shard_blob_ids[0]].placement[(0, 0)]
    sps[victim].crash()
    served = jax.tree.map(jax.numpy.asarray, mgr.restore(1, params))
    engine = ServeEngine(cfg, served, max_len=32)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 4)).astype(np.int32)
    out = engine.generate(prompts, num_tokens=8)
    assert out.shape == (2, 12)
    assert (out[:, :4] == prompts).all()
