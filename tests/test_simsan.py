"""simsan: the runtime sanitizer for the event loop.

Injected-fault coverage (the acceptance criteria of ISSUE 10): a leaked
resource slot and an off-loop resource mutation are each caught with an
error naming the task and sim-time; a cancelled task's slots are
reclaimed (regression for the narrowed GeneratorExit handling); the
pop-order audit, payment-conservation check, and — crucially — that a
sanitized run is behaviourally identical to an unsanitized one (same
digest), so CI can run smokes under SHELBY_SIMSAN=1 for free.
"""
import numpy as np
import pytest

from repro.analysis.simsan import SanitizerError, check_payment_conservation
from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.backbone import Backbone
from repro.net.events import Acquire, EventLoop, Release, Sleep
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.net.workloads import replay_open_loop, zipf_hotset
from repro.storage.blob import BlobLayout
from repro.storage.rpc import BackboneTransport, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import ServiceSpec, StorageProvider


# -- injected fault (a): leaked resource slot ------------------------------------
def test_leaked_slot_detected_at_drain():
    loop = EventLoop(sanitize=True)

    def leaker():
        yield Acquire(("sp", 3), 2)
        yield Sleep(5.0)
        # returns while still holding the slot

    loop.spawn(leaker(), label="reader/blob7")
    with pytest.raises(SanitizerError) as err:
        loop.run()
    msg = str(err.value)
    assert "leak" in msg
    assert "('sp', 3)" in msg          # resource key
    assert "reader/blob7" in msg       # holder task
    assert "t=0" in msg                # acquire sim-time

    # same program on an unsanitized loop: silent (that's the point)
    loop2 = EventLoop()

    def leaker2():
        yield Acquire(("sp", 3), 2)
        yield Sleep(5.0)

    loop2.spawn(leaker2(), label="reader/blob7")
    loop2.run()
    assert loop2.resource(("sp", 3)).in_use == 1


def test_release_without_acquire_detected():
    loop = EventLoop(sanitize=True)

    def over_releaser():
        yield Sleep(2.0)
        yield Release(("disk", 0))

    loop.spawn(over_releaser(), label="over/0")
    with pytest.raises(SanitizerError, match="release without acquire"):
        loop.run()
    msg_loop = EventLoop(sanitize=True)
    try:
        def again():
            yield Sleep(2.0)
            yield Release(("disk", 0))
        msg_loop.spawn(again(), label="over/1")
        msg_loop.run()
    except SanitizerError as e:
        assert "over/1" in str(e) and "t=2" in str(e)


# -- injected fault (b): off-loop mutation ---------------------------------------
def test_off_loop_scalar_mutation_names_task_and_time():
    loop = EventLoop(sanitize=True)

    def mutator():
        yield Sleep(7.0)
        res = loop.resource(("sp", 1), 4)
        res.in_use += 1  # bypassing Acquire

    loop.spawn(mutator(), label="rogue/writer")
    with pytest.raises(SanitizerError) as err:
        loop.run()
    msg = str(err.value)
    assert "off-loop mutation" in msg
    assert "rogue/writer" in msg       # the task
    assert "t=7" in msg                # the sim-time
    assert "in_use" in msg and "('sp', 1)" in msg


def test_off_loop_dict_mutation_detected_in_window():
    loop = EventLoop(sanitize=True)

    def legit():
        yield Acquire(("sp", 2), 4)
        yield Sleep(1.0)
        yield Release(("sp", 2))

    def rogue():
        yield Sleep(3.0)
        # dict-valued accounting can't be guarded by __setattr__; the
        # shadow check catches it at the next engine touch / drain
        loop.resource(("sp", 2)).in_use_by_class[0] += 1

    loop.spawn(legit(), label="legit")
    loop.spawn(rogue(), label="rogue/dict")
    with pytest.raises(SanitizerError) as err:
        loop.run()
    msg = str(err.value)
    assert "off-loop mutation" in msg and "in_use_by_class" in msg
    assert "('sp', 2)" in msg


# -- regression: a cancelled task never leaks its slots --------------------------
def test_cancelled_task_slots_are_reclaimed():
    loop = EventLoop(sanitize=True)
    granted_at = []

    def holder():
        yield Acquire(("disk", 0), 1)
        yield Sleep(100.0)
        yield Release(("disk", 0))

    def waiter():
        yield Acquire(("disk", 0), 1)
        granted_at.append(loop.now)
        yield Release(("disk", 0))

    h = loop.spawn(holder(), label="holder")

    def canceller():
        yield Sleep(5.0)
        h.cancel()

    loop.spawn(waiter(), at_ms=1.0, label="waiter")
    loop.spawn(canceller(), label="canceller")
    # a leak would deadlock the waiter AND trip the sanitizer at drain;
    # instead the cancel hands the slot over at t=5
    loop.run()
    assert granted_at == [5.0]
    assert loop.resource(("disk", 0)).in_use == 0
    assert h.held == []


def test_cancel_reclaim_works_without_sanitizer():
    loop = EventLoop()

    def holder():
        yield Acquire(("disk", 0), 1)
        yield Sleep(100.0)

    h = loop.spawn(holder(), label="holder")

    def canceller():
        yield Sleep(5.0)
        h.cancel()

    loop.spawn(canceller(), label="canceller")
    loop.run()
    assert loop.resource(("disk", 0)).in_use == 0


# -- pop-order / causality audit -------------------------------------------------
def test_pop_order_audit_unit():
    loop = EventLoop(sanitize=True)
    san = loop._san
    san.on_pop(5.0, 10)
    with pytest.raises(SanitizerError, match="same-timestamp"):
        san.on_pop(5.0, 10)  # seq must strictly ascend within a timestamp
    with pytest.raises(SanitizerError, match="backwards"):
        san.on_pop(4.0, 11)
    with pytest.raises(SanitizerError, match="non-finite"):
        san.on_push(float("nan"), type("H", (), {"label": "x"})())


def test_scheduling_into_the_past_is_a_causality_violation():
    loop = EventLoop(sanitize=True)

    def child():
        yield Sleep(0.0)

    def parent():
        yield Sleep(10.0)
        loop.spawn(child(), at_ms=1.0, label="too-late")

    loop.spawn(parent(), label="parent")
    with pytest.raises(SanitizerError, match="causality"):
        loop.run()


# -- sanitize must not perturb behaviour -----------------------------------------
def _world(num_sps=6, slots=4):
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract()
    bb = Backbone.mesh(3, base_latency_ms=4.0, gbps=10.0)
    sps = {}
    for i in range(num_sps):
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 3}"))
        sps[i] = StorageProvider(i, service=ServiceSpec(slots=slots))
        bb.register_node(f"sp{i}", f"dc{i % 3}")
    bb.register_node("rpc0", "dc0")
    rpc = RPCNode("rpc0", contract, sps, layout,
                  transport=BackboneTransport(sps, bb, "rpc0"))
    bb.register_node("client", "dc0")
    fleet = RPCFleet([rpc], CacheAffinityPolicy(), backbone=bb)
    client = ShelbyClient(contract, fleet, deposit=1e9)
    return fleet, client


def _digest_of_replay(monkeypatch, sanitized: bool) -> str:
    if sanitized:
        monkeypatch.setenv("SHELBY_SIMSAN", "1")
    else:
        monkeypatch.delenv("SHELBY_SIMSAN", raising=False)
    fleet, client = _world()
    rng = np.random.default_rng(0)
    metas = [client.put(rng.integers(0, 256, 50_000, dtype=np.uint8).tobytes())
             for _ in range(3)]
    reqs = zipf_hotset(metas, clients=["client"], num_requests=60, seed=11)
    result = replay_open_loop(fleet, reqs)
    assert all(r.ok for r in result.records)
    return result.digest()


def test_sanitized_replay_digest_identical(monkeypatch):
    """EventLoop(sanitize=True) observes; it must never move an event —
    the determinism digest of a sanitized replay equals the plain one."""
    assert (_digest_of_replay(monkeypatch, sanitized=True)
            == _digest_of_replay(monkeypatch, sanitized=False))


# -- payment conservation --------------------------------------------------------
class _Chan:
    def __init__(self, deposit, paid):
        self.deposit = deposit
        self.paid = paid


class _Receipt:
    def __init__(self, payments):
        self.payments = payments


class _Session:
    def __init__(self, receipts, channels):
        self.receipts = receipts
        self.receipt_batches = []
        self.channels = channels


def test_payment_conservation_clean():
    session = _Session(
        receipts=[_Receipt({"rpc0": 0.25}), _Receipt({"rpc0": 0.25, "rpc1": 0.1})],
        channels={"rpc0": _Chan(10.0, 0.5), "rpc1": _Chan(10.0, 0.1)},
    )
    check_payment_conservation(session)  # no raise


def test_payment_conservation_catches_unreceipted_debit():
    session = _Session(
        receipts=[_Receipt({"rpc0": 0.25})],
        channels={"rpc0": _Chan(10.0, 0.40)},  # 0.15 paid with no receipt
    )
    with pytest.raises(SanitizerError, match="payment conservation"):
        check_payment_conservation(session, where="epoch 1")
    with pytest.raises(SanitizerError, match="epoch 1"):
        check_payment_conservation(session, where="epoch 1")


def test_payment_conservation_catches_receipt_without_channel():
    session = _Session(receipts=[_Receipt({"ghost": 0.1})], channels={})
    with pytest.raises(SanitizerError, match="no\\s+channel"):
        check_payment_conservation(session)


def test_run_sim_per_epoch_conservation_wired():
    from repro.core.simulation import run_sim
    from repro.storage.sp import SPBehavior
    res = run_sim({i: SPBehavior() for i in range(6)}, epochs=1,
                  read_requests_per_epoch=20, seed=5, sanitize=True)
    assert res.client_read_payments > 0
