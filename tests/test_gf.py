"""GF(2^8) field axioms + matrix algebra (hypothesis property tests)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import gf

bytes_ = st.integers(0, 255)
nz_bytes = st.integers(1, 255)


@given(bytes_, bytes_)
def test_mul_commutative(a, b):
    assert gf.mul(a, b) == gf.mul(b, a)


@given(bytes_, bytes_, bytes_)
def test_mul_associative(a, b, c):
    assert gf.mul(gf.mul(a, b), c) == gf.mul(a, gf.mul(b, c))


@given(bytes_, bytes_, bytes_)
def test_distributive(a, b, c):
    assert gf.mul(a, b ^ c) == int(gf.mul(a, b)) ^ int(gf.mul(a, c))


@given(nz_bytes)
def test_inverse(a):
    assert gf.mul(a, gf.inv(a)) == 1


@given(bytes_)
def test_identity_and_zero(a):
    assert gf.mul(a, 1) == a
    assert gf.mul(a, 0) == 0


def test_inv_zero_raises():
    with pytest.raises(ZeroDivisionError):
        gf.inv(np.uint8(0))


@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 16), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_matmul_matches_naive(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, (m, k), dtype=np.uint8)
    b = rng.integers(0, 256, (k, n), dtype=np.uint8)
    out = gf.matmul_np(a, b)
    ref = np.zeros((m, n), np.uint8)
    for i in range(m):
        for j in range(n):
            acc = 0
            for x in range(k):
                acc ^= int(gf.mul(a[i, x], b[x, j]))
            ref[i, j] = acc
    assert np.array_equal(out, ref)


@given(st.integers(1, 6), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_mat_inv(n, seed):
    rng = np.random.default_rng(seed)
    for _ in range(20):  # find an invertible matrix
        a = rng.integers(0, 256, (n, n), dtype=np.uint8)
        try:
            ainv = gf.mat_inv(a)
            break
        except np.linalg.LinAlgError:
            continue
    else:
        pytest.skip("no invertible matrix found")
    assert np.array_equal(gf.matmul_np(a, ainv), np.eye(n, dtype=np.uint8))


def test_vandermonde_mds_property():
    """Every square submatrix of a row-prefix is invertible (MDS witness)."""
    import itertools

    v = gf.vandermonde(4, 8)
    for cols in itertools.combinations(range(8), 4):
        gf.mat_inv(v[:, list(cols)])  # raises if singular


def test_jnp_paths_match_numpy(rng):
    import jax.numpy as jnp

    a = rng.integers(0, 256, (5, 7), dtype=np.uint8)
    b = rng.integers(0, 256, (7, 33), dtype=np.uint8)
    out = np.asarray(gf.matmul_jnp(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32)))
    assert np.array_equal(out.astype(np.uint8), gf.matmul_np(a, b))
