"""Engine equivalence: the calendar queue, the cohort fast path, and
batched settlement must be *invisible* to every determinism digest.

Three families of bars:

* ``CalendarQueue`` vs ``_BinaryHeap`` pop-order identity — a seeded
  hand-rolled property sweep (hypothesis is not in the image) over random
  push/pop interleavings with exact-time ties, far-future timestamps, and
  zero-delay self-wakes, plus digest equality of full replays across the
  workload families (zipf streaming, DAS storm, membership churn,
  background planes) with ``engine="heap"`` vs ``engine="calendar"``.
* ``replay_open_loop_fast`` vs task-per-request replay — byte-identical
  digests and identical fleet/node counters on the single-chunkset worlds
  the fast path guarantees float-exactness for, and loud, reasoned
  fallbacks everywhere else.
* Batched settlement — one-debit-per-node cohort payments conserve value
  against the per-receipt task path and the contract's realized incomes.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.backbone import Backbone
from repro.net.events import CalendarQueue, EventLoop, _BinaryHeap
from repro.net.fastpath import fastpath_fallback_reason, replay_open_loop_fast
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.net.workloads import (
    das_storm,
    replay_open_loop,
    zipf_hotset,
    zipf_hotset_batch,
)
from repro.core import audit as audit_mod
from repro.storage.background import AuditPlane, RepairPlane
from repro.storage.blob import BlobLayout
from repro.storage.das import DASSpec, extend_and_disperse_many
from repro.storage.membership import ChurnSpec, MembershipPlane
from repro.storage.repair import RepairCoordinator
from repro.storage.rpc import AdmissionSpec, BackboneTransport, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import BackgroundSpec, ServiceSpec, StorageProvider


# ---------------------------------------------------------------------------
# calendar queue vs binary heap: pop-order identity (property sweep)
# ---------------------------------------------------------------------------
def _drain_equal(items, *, width_ms=1.0):
    """Push the same items into both disciplines, pop everything, and
    assert the sequences are identical element-for-element."""
    cal, heap = CalendarQueue(width_ms=width_ms), _BinaryHeap()
    for it in items:
        cal.push(it)
        heap.push(it)
    assert len(cal) == len(heap) == len(items)
    got = [cal.pop() for _ in range(len(items))]
    want = [heap.pop() for _ in range(len(items))]
    assert got == want
    assert len(cal) == 0
    with pytest.raises(IndexError):  # empty-pop contract matches heappop
        cal.pop()


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("width_ms", [0.25, 1.0, 16.0])
def test_calendar_pop_order_matches_heap_property_sweep(seed, width_ms):
    """Seeded stand-in for a hypothesis property test: random (t, seq)
    streams with heavy exact-time ties and day-boundary times."""
    rng = np.random.default_rng(seed)
    n = 400
    # a small palette of times forces many exact ties; day-boundary
    # multiples of width land items right on bucket edges
    palette = np.concatenate([
        rng.uniform(0.0, 50.0, 8),
        np.arange(6) * width_ms,           # exact day boundaries
        [0.0, 0.0, 13.125],                # repeated zeros: tie storms
    ])
    ts = rng.choice(palette, n)
    seqs = rng.permutation(n)  # unique seqs, shuffled vs time order
    items = [(float(t), int(s), f"task{s}") for t, s in zip(ts, seqs)]
    _drain_equal(items, width_ms=width_ms)


def test_calendar_interleaved_push_pop_matches_heap():
    """Pops interleave with pushes (as a live loop does): after each pop
    both disciplines must agree, including pushes at the just-popped time
    (zero-delay self-wakes land in the current day)."""
    rng = np.random.default_rng(42)
    cal, heap = CalendarQueue(), _BinaryHeap()
    seq = 0
    now = 0.0
    for _ in range(200):
        for _ in range(rng.integers(1, 4)):
            t = now + float(rng.exponential(2.0))
            if rng.random() < 0.3:
                t = now  # zero-delay self-wake: same time, later seq
            cal.push((t, seq, None))
            heap.push((t, seq, None))
            seq += 1
        if len(heap) and rng.random() < 0.8:
            a, b = cal.pop(), heap.pop()
            assert a == b
            now = a[0]
    while len(heap):
        assert cal.pop() == heap.pop()


def test_calendar_far_future_and_sparse_days():
    """Dict-keyed days: timestamps out at 1e12 ms (a classic modulo-ring
    year wrap hazard) order correctly against near-term events, and
    all-sparse streams (every event its own day) stay exact."""
    items = [(1e12, 1, "far"), (0.0, 0, "now"), (1e12, 0, "far-tie"),
             (5e11 + 0.5, 2, "mid"), (1e12 + 1e-9, 3, "epsilon-later")]
    _drain_equal(items)
    sparse = [(float(i) * 1e6, i, None) for i in range(64)][::-1]
    _drain_equal(sparse)


def test_calendar_rejects_nonpositive_width():
    with pytest.raises(ValueError):
        CalendarQueue(width_ms=0.0)


# ---------------------------------------------------------------------------
# heap vs calendar digests across the workload families
# ---------------------------------------------------------------------------
def _bb_world(*, num_sps=9, num_rpcs=2, cache=8, seed=0, num_blobs=4,
              blob_bytes=150_000, crash_sp=None, single_flight=True):
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract()
    bb = Backbone.mesh(3, base_latency_ms=4.0, gbps=10.0)
    sps = {}
    for i in range(num_sps):
        dc = f"dc{i % 3}"
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=dc, rack=f"r{i % 4}"))
        sps[i] = StorageProvider(i, service=ServiceSpec(
            disk_ms_per_chunk=1.0, slots=2, background=BackgroundSpec()))
        bb.register_node(f"sp{i}", dc)
    rpcs = []
    for r in range(num_rpcs):
        node = f"rpc{r}"
        bb.register_node(node, f"dc{r % 3}")
        rpcs.append(RPCNode(node, contract, sps, layout, cache_chunksets=cache,
                            transport=BackboneTransport(sps, bb, node),
                            single_flight=single_flight))
    bb.register_node("client", "dc0")
    bb.register_node("repairer", "dc1")
    fleet = RPCFleet(rpcs, CacheAffinityPolicy(), backbone=bb)
    client = ShelbyClient(contract, fleet, deposit=1e9)
    rng = np.random.default_rng(seed)
    metas = [client.put(rng.integers(0, 256, blob_bytes, dtype=np.uint8).tobytes())
             for _ in range(num_blobs)]
    if crash_sp is not None:
        sps[crash_sp].crash()  # after the writes: its chunks are repair work
    return layout, contract, bb, sps, fleet, client, metas


def _family_zipf(engine):
    *_, fleet, _, metas = _bb_world()
    reqs = zipf_hotset(metas, clients=["client"], num_requests=80,
                       interarrival_ms=2.0, seed=3, arrival="poisson")
    return replay_open_loop(fleet, reqs, engine=engine).digest()


def _family_das(engine):
    layout, contract, _, sps, fleet, client, metas = _bb_world()
    spec = DASSpec(k=4, share_bytes=512, samples_per_epoch=8)
    rng = np.random.default_rng(1)
    records = extend_and_disperse_many(
        contract, sps,
        [(m.blob_id, rng.integers(0, 256, 4096, dtype=np.uint8).tobytes())
         for m in metas[:2]],
        spec,
    )
    reqs = das_storm(records, clients=["client"], num_requests=60, seed=9,
                     interarrival_ms=1.0)
    reader = ShelbyClient(contract, fleet, deposit=1e9, das=spec)
    with reader.session() as session:
        _, result = session.replay(reqs, engine=engine)
    return result.digest()


def _family_churn(engine):
    layout, contract, _, sps, fleet, client, metas = _bb_world(num_sps=10)
    rc = RepairCoordinator(contract, sps, layout)
    plane = MembershipPlane(
        contract, sps, layout,
        ChurnSpec(p_crash=0.1, p_leave=0.1, joins_per_epoch=1, seed=4),
        repair=rc, fleet=fleet, epochs=2, epoch_ms=60.0,
    )
    reqs = zipf_hotset(metas, clients=["client"], num_requests=40,
                       interarrival_ms=3.0, seed=8, arrival="poisson")
    with client.session() as session:
        _, result = session.replay(reqs, background=plane.planes(),
                                   engine=engine)
    return result.digest()


def _family_background(engine):
    layout, contract, _, sps, fleet, _, metas = _bb_world(crash_sp=5)
    sp_nodes = {i: f"sp{i}" for i in sps}
    sp_ids = [s.sp_id for s in contract.active_sps()]
    challenges = audit_mod.derive_challenges(
        contract.epoch_seed(0), 0, contract.holdings(), sp_ids,
        p_a=1.0, auditors_per_audit=3,
    )
    audits = AuditPlane(contract, sps, challenges, nodes=sp_nodes)
    rc = RepairCoordinator(contract, sps, layout, nodes=sp_nodes,
                           coordinator_node="repairer")
    reqs = zipf_hotset(metas, clients=["client"], num_requests=50,
                       interarrival_ms=2.0, seed=3, arrival="poisson")
    return replay_open_loop(fleet, reqs,
                            background=[audits, RepairPlane(rc)],
                            engine=engine).digest()


@pytest.mark.parametrize("family", [
    _family_zipf, _family_das, _family_churn, _family_background,
], ids=["zipf_streaming", "das_storm", "membership_churn", "background_planes"])
def test_heap_and_calendar_digests_identical(family):
    assert family("heap") == family("calendar")


def test_default_engine_is_calendar():
    assert EventLoop().engine == "calendar"
    # an unknown discipline fails loudly, not silently-heap
    with pytest.raises(ValueError):
        EventLoop(engine="fibonacci")


# ---------------------------------------------------------------------------
# cohort fast path vs task-per-request replay
# ---------------------------------------------------------------------------
def _fast_world(*, num_rpcs=2, cache=64, admission=None):
    """Single-chunkset blobs + whole-blob reads: the float-exact regime."""
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract()
    bb = Backbone.mesh(3, base_latency_ms=4.0, gbps=10.0)
    sps = {}
    for i in range(8):
        dc = f"dc{i % 3}"
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=dc, rack=f"r{i % 4}"))
        sps[i] = StorageProvider(i, service=ServiceSpec(disk_ms_per_chunk=0.5,
                                                        slots=4))
        bb.register_node(f"sp{i}", dc)
    rpcs = []
    for r in range(num_rpcs):
        node = f"rpc{r}"
        bb.register_node(node, f"dc{r % 3}")
        rpcs.append(RPCNode(node, contract, sps, layout, cache_chunksets=cache,
                            transport=BackboneTransport(sps, bb, node),
                            admission=admission))
    bb.register_node("client", "dc0")
    fleet = RPCFleet(rpcs, CacheAffinityPolicy(), backbone=bb)
    client = ShelbyClient(contract, fleet, deposit=1e9)
    rng = np.random.default_rng(7)
    metas = [client.put(rng.integers(0, 256, 50_000, dtype=np.uint8).tobytes())
             for _ in range(12)]
    for n in rpcs:
        n._cache.clear()  # cold start: the PUT path warmed the writer's cache
    return fleet, client, metas


def _fast_batch(metas, n=1500, seed=3):
    return zipf_hotset_batch(metas, clients=["client"], num_requests=n,
                             read_bytes=64 * 1024, interarrival_ms=0.2,
                             seed=seed, arrival="poisson")


def test_fast_path_digest_and_counters_match_task_mode():
    fleet_t, _, metas = _fast_world()
    batch = _fast_batch(metas)
    r_task = replay_open_loop(fleet_t, batch.to_requests())

    fleet_f, _, _ = _fast_world()
    r_fast = replay_open_loop_fast(fleet_f, batch)

    assert r_fast.cohort.fallback_reason is None
    assert r_fast.cohort.vec_requests > 0
    assert r_fast.cohort.deopt_requests > 0  # cold keys de-opted to tasks
    assert r_task.digest() == r_fast.digest()
    # the digest covers per-request rows; the fleet/node books must agree too
    assert fleet_t.routed == fleet_f.routed
    assert fleet_t.chunkset_reads == fleet_f.chunkset_reads
    assert fleet_t.bytes_served == fleet_f.bytes_served
    assert ([n.stats.cache_hits for n in fleet_t.rpcs]
            == [n.stats.cache_hits for n in fleet_f.rpcs])
    assert ([n.stats.coalesced for n in fleet_t.rpcs]
            == [n.stats.coalesced for n in fleet_f.rpcs])
    assert (sorted(fleet_t.request_latencies_ms)
            == sorted(fleet_f.request_latencies_ms))


def test_fast_path_is_deterministic_across_replays():
    _, _, metas = _fast_world()
    batch = _fast_batch(metas)
    digests = set()
    for _ in range(2):
        fleet, _, _ = _fast_world()
        digests.add(replay_open_loop_fast(fleet, batch).digest())
    assert len(digests) == 1


def test_fast_path_falls_back_with_a_reason():
    # admission control individuates requests -> whole batch de-opts
    fleet, _, metas = _fast_world(
        admission=AdmissionSpec(max_queued_requests=64))
    batch = _fast_batch(metas, n=200)
    reason = fastpath_fallback_reason(fleet, batch)
    assert reason is not None and "admission" in reason
    res = replay_open_loop_fast(fleet, batch)
    assert res.cohort.fallback_reason == reason
    assert res.cohort.vec_requests == 0
    assert res.cohort.deopt_requests == len(batch)
    # the fallback replay is the task path: digest matches it exactly
    fleet_t, _, _ = _fast_world(
        admission=AdmissionSpec(max_queued_requests=64))
    assert res.digest() == replay_open_loop(fleet_t, batch.to_requests()).digest()


def test_fast_path_fallback_on_stateful_policy():
    from repro.net.fleet import PowerOfTwoPolicy

    fleet, _, metas = _fast_world()
    fleet.policy = PowerOfTwoPolicy()
    reason = fastpath_fallback_reason(fleet, _fast_batch(metas, n=50))
    assert reason is not None and "stateful" in reason


# ---------------------------------------------------------------------------
# batched settlement conservation
# ---------------------------------------------------------------------------
def test_batched_settlement_conserves_value_vs_task_path():
    fleet_t, client_t, metas = _fast_world()
    batch = _fast_batch(metas, n=1000, seed=11)
    with client_t.session(deposit_per_node=1e6) as s_task:
        _, r_task = s_task.replay(batch.to_requests())
        paid_task = s_task.total_paid
    set_task = s_task.settlement

    fleet_f, client_f, _ = _fast_world()
    with client_f.session(deposit_per_node=1e6) as s_fast:
        rb, r_fast = s_fast.replay(batch)
        paid_fast = s_fast.total_paid
    set_fast = s_fast.settlement

    assert r_task.digest() == r_fast.digest()
    assert len(rb) == r_fast.cohort.vec_requests
    # value conservation: batched one-debit-per-node totals equal the task
    # path's per-receipt debits, node by node
    assert paid_fast == pytest.approx(paid_task, rel=1e-9)
    for nid, income in set_task.node_income.items():
        assert set_fast.node_income.get(nid, 0.0) == pytest.approx(income,
                                                                   rel=1e-9)
    # the cohort's recorded debits are exactly what the channels saw
    assert (rb.total_paid + sum(r.total_paid for r in s_fast.receipts)
            == pytest.approx(set_fast.total_node_income, abs=1e-9))
    assert np.all(rb.paid > 0.0)
    assert np.all(rb.latency_ms >= 0.0)
