"""Appendix A closed forms reproduce the paper's numbers exactly."""

import pytest

from repro.core import durability as D


def test_paper_data_loss_number():
    p = D.DurabilityParams()  # the appendix's (10,6) worked example
    assert D.p_data_loss(p) == pytest.approx(3.01e-12, rel=0.01)


def test_eleven_nines():
    assert D.durability_nines(D.DurabilityParams()) > 11


def test_paper_availability_number():
    p = D.DurabilityParams()
    assert D.p_unavailable(p) == pytest.approx(1.35e-4, rel=0.01)
    assert D.availability(p) == pytest.approx(0.999865, abs=1e-6)


def test_dc_quorum_formula():
    # 1 - [0.98^5 + 5*0.98^4*0.02 + 10*0.98^3*0.02^2] from the appendix
    expect = 1 - (0.98**5 + 5 * 0.98**4 * 0.02 + 10 * 0.98**3 * 0.02**2)
    assert D.p_fewer_than_k_dcs(5, 0.98, 3) == pytest.approx(expect)


def test_durability_improves_with_more_parity():
    base = D.p_data_loss(D.DurabilityParams(k=10, m=4))
    more = D.p_data_loss(D.DurabilityParams(k=10, m=6))
    assert more < base


def test_durability_worsens_with_slow_detection():
    fast = D.p_data_loss(D.DurabilityParams(mttd_hours=1))
    slow = D.p_data_loss(D.DurabilityParams(mttd_hours=240))
    assert slow > fast
