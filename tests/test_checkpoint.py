"""Coded distributed checkpointing: roundtrip, elasticity, failures."""
import jax
import numpy as np
import pytest

from repro.storage.checkpoint import (
    CheckpointManager,
    deserialize_pytree,
    serialize_pytree,
    shard_bytes,
)


def _state(rng):
    return {
        "params": {"w": rng.normal(size=(64, 32)).astype(np.float32),
                   "b": rng.normal(size=(32,)).astype(np.float32)},
        "m": {"w": np.zeros((64, 32), np.float32), "b": np.zeros((32,), np.float32)},
        "step": np.int32(17),
        "nested": [np.arange(5, dtype=np.int64), np.float16(2.5)],
    }


def _trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


def test_serialize_roundtrip(rng):
    s = _state(rng)
    assert _trees_equal(s, deserialize_pytree(serialize_pytree(s), s))


def test_shard_bytes_reassemble(rng):
    data = rng.integers(0, 256, 10_001, dtype=np.uint8).tobytes()
    for n in (1, 2, 3, 7):
        assert b"".join(shard_bytes(data, n)) == data


def test_checkpoint_through_shelby(cluster, rng):
    _, sps, rpc, client = cluster
    mgr = CheckpointManager(client, num_host_shards=3)
    s = _state(rng)
    mgr.save(10, s)
    assert _trees_equal(s, mgr.restore(10, s))


def test_elastic_restore_different_host_count(cluster, rng):
    _, sps, rpc, client = cluster
    mgr = CheckpointManager(client, num_host_shards=4)
    s = _state(rng)
    mgr.save(10, s)
    for hosts in (1, 2, 3, 8):
        assert _trees_equal(s, mgr.restore(10, s, reading_hosts=hosts))


def test_restore_survives_sp_failures(cluster, rng):
    contract, sps, rpc, client = cluster
    mgr = CheckpointManager(client, num_host_shards=2)
    s = _state(rng)
    rec = mgr.save(10, s)
    meta = contract.blobs[rec.shard_blob_ids[0]]
    sps[meta.placement[(0, 0)]].crash()
    sps[meta.placement[(0, 1)]].crash()
    rpc._cache.clear()
    assert _trees_equal(s, mgr.restore(10, s))


def test_keep_policy_evicts_old(cluster, rng):
    _, _, _, client = cluster
    mgr = CheckpointManager(client, keep=2)
    s = {"x": np.zeros(4, np.float32)}
    for step in (1, 2, 3, 4):
        mgr.save(step, s)
    assert sorted(mgr.records) == [3, 4]
    assert mgr.latest_step() == 4


def test_shape_mismatch_rejected(cluster, rng):
    _, _, _, client = cluster
    mgr = CheckpointManager(client)
    s = {"x": np.zeros((4, 4), np.float32)}
    mgr.save(1, s)
    with pytest.raises(ValueError):
        mgr.restore(1, {"x": np.zeros((2, 2), np.float32)})


def test_not_a_checkpoint_rejected():
    with pytest.raises(ValueError):
        deserialize_pytree(b"garbage-bytes", {"x": np.zeros(1)})
