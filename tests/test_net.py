"""Backbone data plane: topology accounting, hedged scheduler, fleet routing."""
import numpy as np
import pytest

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.backbone import Backbone
from repro.net.fleet import (
    CacheAffinityPolicy,
    LatencyAwarePolicy,
    PowerOfTwoPolicy,
    RPCFleet,
)
from repro.net.scheduler import HedgedScheduler
from repro.net.workloads import training_epoch, video_streaming, zipf_hotset
from repro.storage.rpc import BackboneTransport, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider


# -- backbone ---------------------------------------------------------------------
def _bb():
    bb = Backbone.mesh(3, base_latency_ms=10.0, gbps=1.0)
    bb.register_node("a", "dc0")
    bb.register_node("b", "dc1")
    bb.register_node("c", "dc2")
    return bb


def test_backbone_propagation_scales_with_distance():
    bb = _bb()
    assert bb.propagation_ms("a", "b") == 10.0
    assert bb.propagation_ms("a", "c") == 20.0
    assert bb.propagation_ms("a", "a") == pytest.approx(0.2)  # intra-DC


def test_backbone_transfer_accounts_serialization_and_fifo():
    bb = _bb()
    nbytes = 1_000_000  # 8 Mbit over 1 Gbps = 8 ms serialization
    t1 = bb.transfer("a", "b", nbytes, 0.0)
    assert t1 == pytest.approx(8.0 + 10.0)
    # second transfer on the same trunk queues behind the first
    t2 = bb.transfer("a", "b", nbytes, 0.0)
    assert t2 == pytest.approx(16.0 + 10.0)
    # reverse direction is a different trunk: no queueing
    t3 = bb.transfer("b", "a", nbytes, 0.0)
    assert t3 == pytest.approx(8.0 + 10.0)
    assert bb.utilization()[("dc0", "dc1")] == 2 * nbytes


def test_backbone_is_deterministic():
    def run():
        bb = _bb()
        return [bb.transfer("a", "b", 10_000, float(i)) for i in range(5)]

    assert run() == run()


# -- scheduler --------------------------------------------------------------------
def _issue_from(latencies, fail=(), log=None):
    def issue(key, sp_id, t_ms):
        if log is not None:
            log.append((key, t_ms))
        if key in fail:
            return None, t_ms + latencies[key]
        return f"shard{key}", t_ms + latencies[key]

    return issue


def test_scheduler_healthy_issues_exactly_k():
    lat = {i: 1.0 for i in range(6)}
    res = HedgedScheduler(hedge=2).fetch(
        4, [(i, i, lat[i]) for i in range(6)], _issue_from(lat)
    )
    assert res.issued == 4 and res.wasted == 0 and res.latency_ms == 1.0


def test_scheduler_hedges_around_straggler():
    # candidate 0 estimated fast but actually takes 500 ms
    est = [(i, i, 1.0) for i in range(6)]
    actual = {i: 1.0 for i in range(6)}
    actual[0] = 500.0
    res = HedgedScheduler(hedge=2, min_deadline_ms=5.0).fetch(4, est, _issue_from(actual))
    assert len(res.shards) == 4
    assert res.hedges >= 1  # deadline fired
    assert res.latency_ms < 10.0  # hedge completed long before the straggler
    assert res.wasted >= 1  # the straggler's request was paid but unused


def test_scheduler_recovers_from_failures():
    est = [(i, i, 1.0) for i in range(6)]
    actual = {i: 1.0 for i in range(6)}
    res = HedgedScheduler(hedge=2).fetch(
        4, est, _issue_from(actual, fail={0, 1})
    )
    assert len(res.shards) == 4 and res.failed == 2
    assert res.latency_ms == pytest.approx(2.0)  # one replacement round


def test_scheduler_partial_when_not_enough_valid():
    est = [(i, i, 1.0) for i in range(5)]
    actual = {i: 1.0 for i in range(5)}
    res = HedgedScheduler().fetch(4, est, _issue_from(actual, fail={0, 1, 2}))
    assert len(res.shards) == 2  # caller raises ReadError


# -- backbone transport through a real cluster ------------------------------------
def _backbone_cluster(layout, policy=None, num_rpcs=1):
    contract = ShelbyContract()
    bb = Backbone.mesh(3, base_latency_ms=4.0, gbps=10.0)
    sps = {}
    for i in range(8):
        dc = f"dc{i % 3}"
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=dc, rack=f"r{i % 4}"))
        sps[i] = StorageProvider(i)
        bb.register_node(f"sp{i}", dc)
    rpcs = []
    for r in range(num_rpcs):
        node = f"rpc{r}"
        bb.register_node(node, f"dc{r % 3}")
        rpcs.append(
            RPCNode(node, contract, sps, layout,
                    transport=BackboneTransport(sps, bb, node))
        )
    bb.register_node("client", "dc0")
    fleet = RPCFleet(rpcs, policy or CacheAffinityPolicy(), backbone=bb)
    client = ShelbyClient(contract, rpcs[0], deposit=1e9)
    return contract, bb, sps, rpcs, fleet, client


def test_backbone_transport_end_to_end(small_layout, rng):
    contract, bb, sps, rpcs, fleet, client = _backbone_cluster(small_layout)
    data = rng.integers(0, 256, 200_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    rpcs[0]._cache.clear()
    got, ms = rpcs[0].read_range_timed(meta.blob_id, 0, len(data))
    assert got == data
    assert ms > 0.0  # simulated network time, not wall-clock
    assert bb.transfers > 0


def test_backbone_transport_survives_straggler_and_crash(small_layout, rng):
    contract, bb, sps, rpcs, fleet, client = _backbone_cluster(small_layout)
    data = rng.integers(0, 256, 200_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    sps[meta.placement[(0, 0)]].crash()
    sps[meta.placement[(0, 1)]].behavior.latency_ms = 500.0
    rpcs[0]._cache.clear()
    got, ms = rpcs[0].read_range_timed(meta.blob_id, 0, len(data))
    assert got == data
    assert ms < 500.0  # the straggler never gated the read


# -- fleet routing ----------------------------------------------------------------
def test_cache_affinity_routes_stably_and_hits(small_layout, rng):
    contract, bb, sps, rpcs, fleet, client = _backbone_cluster(
        small_layout, policy=CacheAffinityPolicy(), num_rpcs=3
    )
    data = rng.integers(0, 256, 200_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    for r in rpcs:
        r._cache.clear()
        r.stats.cache_hits = 0
    got, _ = fleet.read_range(meta.blob_id, 0, len(data), client="client")
    assert got == data
    # replay: every chunkset has a stable home node -> pure cache hits
    reads_before = fleet.chunkset_reads
    got2, ms2 = fleet.read_range(meta.blob_id, 0, len(data), client="client")
    assert got2 == data
    hits = sum(r.stats.cache_hits for r in rpcs)
    assert hits == fleet.chunkset_reads - reads_before


def test_power_of_two_balances_load(small_layout, rng):
    contract, bb, sps, rpcs, fleet, client = _backbone_cluster(
        small_layout, policy=PowerOfTwoPolicy(seed=1), num_rpcs=3
    )
    data = rng.integers(0, 256, 200_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    for _ in range(30):
        fleet.read_range(meta.blob_id, 0, 1000, client="client")
    assert max(fleet.routed) - min(fleet.routed) <= 10  # near-uniform


def test_latency_aware_prefers_near_node(small_layout, rng):
    contract, bb, sps, rpcs, fleet, client = _backbone_cluster(
        small_layout, policy=LatencyAwarePolicy(), num_rpcs=3
    )
    data = rng.integers(0, 256, 100_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    for _ in range(10):
        fleet.read_range(meta.blob_id, 0, 1000, client="client")  # client in dc0
    # rpc0 lives in dc0 with the client; it should dominate routing
    assert fleet.routed[0] > fleet.routed[1] and fleet.routed[0] > fleet.routed[2]


# -- workloads --------------------------------------------------------------------
class _Meta:
    def __init__(self, blob_id, size):
        self.blob_id, self.size_bytes = blob_id, size


def test_workloads_are_deterministic():
    metas = [_Meta(i, 500_000) for i in range(4)]
    a = zipf_hotset(metas, clients=["c0", "c1"], num_requests=50, seed=7)
    b = zipf_hotset(metas, clients=["c0", "c1"], num_requests=50, seed=7)
    assert a == b
    assert training_epoch(metas, client="c0", seed=3) == training_epoch(
        metas, client="c0", seed=3
    )


def test_video_streaming_is_sequential_and_paced():
    reqs = video_streaming(_Meta(0, 500_000), client="c0", segment_bytes=100_000)
    assert [r.offset for r in reqs] == [0, 100_000, 200_000, 300_000, 400_000]
    assert all(b.t_ms > a.t_ms for a, b in zip(reqs, reqs[1:]))
    assert sum(r.length for r in reqs) == 500_000


def test_zipf_hotset_is_skewed():
    metas = [_Meta(i, 200_000) for i in range(8)]
    reqs = zipf_hotset(metas, clients=["c0"], num_requests=400, exponent=1.4, seed=0)
    counts = {}
    for r in reqs:
        counts[r.blob_id] = counts.get(r.blob_id, 0) + 1
    # the hottest object takes a disproportionate share of the traffic
    assert max(counts.values()) > 2 * (400 / len(metas))


def test_run_sim_fleet_serves_reads():
    from repro.core.simulation import honest_population, run_sim

    res = run_sim(
        honest_population(8), epochs=1, num_blobs=2, blob_bytes=100_000,
        num_rpcs=3, read_requests_per_epoch=10,
    )
    assert res.bytes_served > 0
    assert all(u > 0 for u in res.utilities.values())  # honest SPs profit
