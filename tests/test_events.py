"""Shared event engine: primitives, NIC/queue accounting, concurrency gates.

The three acceptance-shaped tests at the bottom are the ones the ISSUE
demands: a determinism gate (same workload twice -> byte-identical
latencies and link utilization), an interleaved-hedge regression (two
concurrent requests on shared SPs hedge differently than when run
sequentially, with their events interleaved on the shared heap), and SP
service queueing (p99 grows monotonically with offered load).
"""
import numpy as np
import pytest

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.backbone import Backbone, NICSpec
from repro.net.events import Acquire, EventLoop, Join, Release, Sleep
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.net.scheduler import HedgedScheduler
from repro.net.workloads import (
    ReadRequest,
    replay_closed_loop,
    replay_open_loop,
    zipf_hotset,
)
from repro.storage.blob import BlobLayout
from repro.storage.rpc import BackboneTransport, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import ServiceSpec, StorageProvider


# -- engine primitives -------------------------------------------------------------
def test_sleep_resumes_in_time_then_spawn_order():
    loop = EventLoop()
    order = []

    def t(name):
        yield Sleep(5.0)
        order.append((loop.now, name))

    for name in "abc":
        loop.spawn(t(name))
    loop.run()
    assert order == [(5.0, "a"), (5.0, "b"), (5.0, "c")]


def test_acquire_queues_fifo_and_accounts_waits():
    loop = EventLoop()
    spans = {}

    def worker(name):
        yield Acquire("disk", 1)
        start = loop.now
        yield Sleep(10.0)
        yield Release("disk")
        spans[name] = (start, loop.now)

    for name in ("w0", "w1", "w2"):
        loop.spawn(worker(name))
    loop.run()
    assert spans == {"w0": (0.0, 10.0), "w1": (10.0, 20.0), "w2": (20.0, 30.0)}
    res = loop.resource("disk")
    assert res.acquired == 3
    assert res.wait_ms_total == pytest.approx(10.0 + 20.0)
    assert res.max_queue == 2


def test_join_returns_value_and_propagates_error():
    loop = EventLoop()
    got = {}

    def child():
        yield Sleep(1.0)
        return 42

    def boom():
        yield Sleep(1.0)
        raise ValueError("no")

    def parent():
        h1 = loop.spawn(child())
        h2 = loop.spawn(boom())
        got["v"] = yield Join(h1)
        try:
            yield Join(h2)
        except ValueError as e:
            got["e"] = str(e)

    loop.spawn(parent())
    loop.run()
    assert got == {"v": 42, "e": "no"}


def test_undelivered_task_error_surfaces_in_run():
    loop = EventLoop()

    def boom():
        yield Sleep(1.0)
        raise RuntimeError("detached failure")

    loop.spawn(boom())
    with pytest.raises(RuntimeError, match="detached failure"):
        loop.run()


def test_nic_egress_serializes_transfers():
    bb = Backbone.mesh(2, base_latency_ms=1.0, gbps=100.0)
    bb.register_node("src", "dc0", nic=NICSpec(egress_gbps=1.0, ingress_gbps=1.0))
    bb.register_node("a", "dc1")
    bb.register_node("b", "dc1")
    nbytes = 1_000_000  # 8 ms on the 1 Gbps NIC, 0.08 ms on the 100 Gbps trunk
    t1 = bb.transfer("src", "a", nbytes, 0.0)
    t2 = bb.transfer("src", "b", nbytes, 0.0)
    # the NIC — not the trunk — is the bottleneck, and the second transfer
    # serializes behind the first on the shared egress
    assert t1 == pytest.approx(8.0 + 1.0)
    assert t2 == pytest.approx(16.0 + 1.0)
    assert bb.nic_bytes[("out", "src")] == 2 * nbytes
    # nodes without a NIC spec keep the pre-NIC arithmetic exactly
    t3 = bb.transfer("a", "b", nbytes, 0.0)
    assert t3 == pytest.approx(0.08 + 0.2)  # intra-DC fabric, no NIC stage


# -- a small backbone world --------------------------------------------------------
def _world(num_sps=8, *, slots=4, service_ms=None, nic=None, num_rpcs=2,
           cache=16, scheduler_kw=None, single_flight=True, admission=None):
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract()
    bb = Backbone.mesh(3, base_latency_ms=4.0, gbps=10.0)
    sps = {}
    for i in range(num_sps):
        dc = f"dc{i % 3}"
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=dc, rack=f"r{i % 4}"))
        sps[i] = StorageProvider(
            i, service=ServiceSpec(disk_ms_per_chunk=service_ms, slots=slots)
        )
        bb.register_node(f"sp{i}", dc, nic=nic)
    rpcs = []
    for r in range(num_rpcs):
        node = f"rpc{r}"
        bb.register_node(node, f"dc{r % 3}", nic=nic)
        rpcs.append(
            RPCNode(node, contract, sps, layout, cache_chunksets=cache,
                    transport=BackboneTransport(sps, bb, node),
                    scheduler=HedgedScheduler(**(scheduler_kw or {})),
                    single_flight=single_flight, admission=admission)
        )
    bb.register_node("client", "dc0")
    fleet = RPCFleet(rpcs, CacheAffinityPolicy(), backbone=bb)
    client = ShelbyClient(contract, fleet, deposit=1e9)
    return contract, bb, sps, fleet, client


# -- acceptance gates --------------------------------------------------------------
def test_open_loop_replay_is_deterministic():
    """Same workload, fresh world, twice -> byte-identical latency lists,
    link utilization, and digest."""

    def run_once():
        contract, bb, sps, fleet, client = _world(nic=NICSpec(10.0, 10.0))
        rng = np.random.default_rng(7)
        metas = [
            client.put(rng.integers(0, 256, 150_000, dtype=np.uint8).tobytes())
            for _ in range(3)
        ]
        bb.reset_accounting()
        reqs = zipf_hotset(metas, clients=["client"], num_requests=40,
                           interarrival_ms=2.0, arrival="poisson", seed=3)
        receipts, result = client.replay(reqs)
        client.settle()
        return result

    a, b = run_once(), run_once()
    assert [r.latency_ms for r in a.records] == [r.latency_ms for r in b.records]
    assert a.link_bytes == b.link_bytes
    assert a.digest() == b.digest()


def test_concurrent_hedges_interleave_and_differ_from_sequential(rng):
    """Two requests on overlapping SP sets: sequentially neither hedges;
    concurrently their legs queue on shared single-slot disks, the hedge
    deadline fires, and the shared heap interleaves their events."""

    def world():
        # n == num_sps == 6 -> every chunkset holds a chunk on every SP, so
        # any two chunksets' primary sets overlap on >= 2 SPs
        return _world(num_sps=6, slots=1, service_ms=20.0, num_rpcs=2, cache=0,
                      scheduler_kw=dict(hedge=2, deadline_factor=1.1,
                                        min_deadline_ms=2.0))

    data = rng.integers(0, 256, 130_000, dtype=np.uint8).tobytes()  # 2 chunksets
    cs_bytes = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024).chunkset_bytes

    # sequential baseline: each request runs its fetch to completion alone
    contract, bb, sps, fleet, client = world()
    meta = client.put(data)
    fleet.serve_ranges([(meta.blob_id, 0, 1000)], client="client", t_ms=0.0)
    fleet.serve_ranges([(meta.blob_id, cs_bytes, 1000)], client="client", t_ms=0.5)
    sequential_hedges = fleet.hedges_launched()
    assert sequential_hedges == 0  # nothing queues; deadlines never fire

    # concurrent: same two requests on ONE shared heap
    contract, bb, sps, fleet, client = world()
    meta = client.put(data)
    reqs = [
        ReadRequest(0.0, "client", meta.blob_id, 0, 1000),
        ReadRequest(0.5, "client", meta.blob_id, cs_bytes, 1000),
    ]
    result = replay_open_loop(fleet, reqs, trace=True)
    assert all(r.ok for r in result.records)
    r0, r1 = result.records
    # the two requests genuinely overlap in simulated time …
    assert r0.t_ms < r1.finish_ms and r1.t_ms < r0.finish_ms
    # … their queues made hedge deadlines fire where sequential never did …
    assert fleet.hedges_launched() > sequential_hedges
    # … and their events interleave on the shared heap
    seq = [label.split("/")[0] for _, label, _ in result.trace
           if label.startswith("req")]
    assert {"req0", "req1"} <= set(seq)
    first0, last0 = seq.index("req0"), len(seq) - 1 - seq[::-1].index("req0")
    first1, last1 = seq.index("req1"), len(seq) - 1 - seq[::-1].index("req1")
    assert first0 < last1 and first1 < last0


def test_sp_queue_p99_grows_with_offered_load():
    """A single hot chunkset hammered open-loop: every request's legs land
    on the same four single-slot SPs, so tail latency is queueing delay and
    must rise monotonically with the arrival rate.  Single-flight dedup is
    OFF here — it would (correctly) collapse the identical concurrent
    misses into one fetch and erase the very queueing this test measures;
    tests/test_overload.py asserts that collapse explicitly."""
    p99s = []
    for interarrival_ms in (50.0, 5.0, 1.0):
        contract, bb, sps, fleet, client = _world(
            num_sps=6, slots=1, service_ms=8.0, num_rpcs=1, cache=0,
            single_flight=False,
        )
        rng = np.random.default_rng(1)
        meta = client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
        reqs = [
            ReadRequest(i * interarrival_ms, "client", meta.blob_id, 0, 1000)
            for i in range(30)
        ]
        result = replay_open_loop(fleet, reqs)
        assert all(r.ok for r in result.records)
        p99s.append(result.percentile(99.0))
    assert p99s[0] < p99s[1] < p99s[2], f"p99 not monotone in load: {p99s}"


def test_closed_loop_clients_self_throttle():
    contract, bb, sps, fleet, client = _world(num_rpcs=1)
    bb.register_node("client2", "dc1")
    rng = np.random.default_rng(2)
    meta = client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
    schedules = [
        ("client", [(meta.blob_id, 0, 500)] * 4),
        ("client2", [(meta.blob_id, 100, 500)] * 4),
    ]
    result = replay_closed_loop(fleet, schedules, think_ms=2.0)
    assert all(r.ok for r in result.records)
    assert len(result.records) == 8
    # within a client, request i+1 starts only after i finished (+ think)
    by_client: dict[str, list] = {}
    for r in result.records:
        by_client.setdefault(r.client, []).append(r)
    assert set(by_client) == {"client", "client2"}
    for recs in by_client.values():
        recs.sort(key=lambda r: r.t_ms)
        for prev, nxt in zip(recs, recs[1:]):
            assert nxt.t_ms >= prev.finish_ms + 2.0 - 1e-9


def test_bare_node_with_backbone_transport_reads_through_client(rng):
    """A bare RPCNode on a BackboneTransport wrapped into a fleet of one
    (ShelbyClient does this) must still route Transfers over the
    transport's backbone — the fleet has no backbone of its own."""
    contract, bb, sps, fleet, _ = _world(num_rpcs=1)
    node = fleet.primary
    client = ShelbyClient(contract, node, deposit=1e9)  # fleet of one
    assert client.fleet.backbone is None
    assert client.fleet.network is bb
    data = rng.integers(0, 256, 100_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    node._cache.clear()
    receipt = client.read(meta.blob_id, 0, len(data))
    assert receipt.data == data
    assert receipt.latency_ms > 0.0  # simulated network time was accounted
    client.settle()


# -- cache TTL / admission (satellite) ---------------------------------------------
def test_cache_ttl_expires_on_sim_clock(cluster, small_layout, rng):
    contract, sps, rpc, client = cluster
    meta = client.put(rng.integers(0, 256, 100_000, dtype=np.uint8).tobytes())
    node = RPCNode("rpc_ttl", contract, sps, small_layout, cache_ttl_ms=50.0)
    node.read_items_detailed([(meta.blob_id, 0)], start_ms=0.0)
    assert node.stats.cache_hits == 0
    node.read_items_detailed([(meta.blob_id, 0)], start_ms=10.0)
    assert node.stats.cache_hits == 1  # fresh entry
    node.read_items_detailed([(meta.blob_id, 0)], start_ms=120.0)
    assert node.stats.cache_hits == 1  # TTL lapsed on the sim clock -> refetch


def test_cache_admission_threshold_skips_large_objects(cluster, small_layout, rng):
    contract, sps, rpc, client = cluster
    meta = client.put(rng.integers(0, 256, 100_000, dtype=np.uint8).tobytes())
    tiny = RPCNode("rpc_adm", contract, sps, small_layout, cache_admit_bytes=16)
    tiny.read_items_detailed([(meta.blob_id, 0)], start_ms=0.0)
    tiny.read_items_detailed([(meta.blob_id, 0)], start_ms=0.0)
    assert tiny.stats.cache_hits == 0  # decoded chunkset exceeds the bar
    assert len(tiny._cache) == 0


# -- BlobReader readahead (satellite) ----------------------------------------------
def test_blob_reader_readahead_overlaps_and_buffers(cluster, small_layout, rng):
    contract, sps, rpc, client = cluster
    cs = small_layout.chunkset_bytes
    data = rng.integers(0, 256, 4 * cs, dtype=np.uint8).tobytes()
    meta = client.put(data)
    reader = client.open(meta.blob_id, readahead=2)
    fleet = client.fleet
    chunks = []
    while True:
        before = fleet.chunkset_reads
        part = reader.read(cs)
        if not part:
            break
        chunks.append((part, fleet.chunkset_reads - before))
    assert b"".join(c for c, _ in chunks) == data
    assert reader.prefetches_issued == 2
    assert reader.prefetch_hits == 2
    # buffered reads never touched the fleet again
    assert sum(1 for _, delta in chunks if delta == 0) == 2
    receipts = client.current_session.receipts
    assert sum(1 for r in receipts if r.prefetched) == 2
    assert receipts[0].prefetches_launched == 2
    # every prefetch was paid on delivery and settles cleanly (tolerance:
    # income is recovered as deposit - refund against a 1e9 deposit)
    settlement = client.settle()
    assert settlement.total_node_income == pytest.approx(
        sum(r.total_paid for r in receipts), abs=1e-5
    )


def test_blob_reader_buffered_reads_stop_after_settle(cluster, small_layout, rng):
    from repro.core.payments import ChannelError

    contract, sps, rpc, client = cluster
    cs = small_layout.chunkset_bytes
    data = rng.integers(0, 256, 3 * cs, dtype=np.uint8).tobytes()
    meta = client.put(data)
    reader = client.open(meta.blob_id, readahead=2)
    assert reader.read(cs)  # buffers the next two windows
    client.settle()
    with pytest.raises(ChannelError):  # even a buffer hit needs a live session
        reader.read(cs)
