"""Coordination-layer (smart contract) behaviour (§2.5)."""
import numpy as np
import pytest

from repro.core.contract import BlobState, ShelbyContract
from repro.core.placement import SPInfo, assign_chunkset
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st


def test_write_requires_payment(cluster, rng):
    contract, _, _, client = cluster
    with pytest.raises(ValueError):
        client.put(b"data", payment=0.0)


def test_blob_lifecycle(cluster, rng):
    contract, _, rpc, client = cluster
    meta = client.put(rng.integers(0, 256, 100_000, dtype=np.uint8).tobytes())
    assert meta.state is BlobState.READY
    assert contract.blobs[meta.blob_id].paid_epochs == 10
    assert contract.treasury > 0


def test_epoch_seed_deterministic_and_distinct():
    c = ShelbyContract()
    assert c.epoch_seed(5) == c.epoch_seed(5)
    assert c.epoch_seed(5) != c.epoch_seed(6)


def test_holdings_reflect_placement(cluster, rng):
    contract, _, _, client = cluster
    meta = client.put(rng.integers(0, 256, 150_000, dtype=np.uint8).tobytes())
    held = contract.holdings()
    keys = {(b, cs, ck) for (_, b, cs, ck, _) in held}
    assert {(meta.blob_id, cs, ck) for (cs, ck) in meta.placement} <= keys


def test_reassign_chunk_avoids_current_holders(cluster, rng):
    contract, _, _, client = cluster
    meta = client.put(rng.integers(0, 256, 100_000, dtype=np.uint8).tobytes())
    current = {meta.placement[(0, ck)] for ck in range(meta.n)}
    new_sp = contract.reassign_chunk(meta.blob_id, 0, 0)
    assert new_sp not in (current - {meta.placement[(0, 0)]})


def test_slashing_ejects_at_zero_stake():
    c = ShelbyContract()
    c.register_sp(SPInfo(sp_id=0, stake=50.0))
    c._slash(0, 60.0)
    assert 0 in c.ejected


def test_evidence_rejected_for_valid_proof(cluster, rng):
    """Honest SPs are safe: valid proofs can't be used as slashing evidence."""
    contract, sps, _, client = cluster
    meta = client.put(rng.integers(0, 256, 100_000, dtype=np.uint8).tobytes())
    sp_id = meta.placement[(0, 0)]
    from repro.core.audit import Challenge

    ch = Challenge(0, sp_id, meta.blob_id, 0, 0, 0, ())
    proof = sps[sp_id].respond_challenge(ch)
    ok = contract.submit_evidence(1, sp_id, meta.blob_id, 0, 0, proof.sample, proof.proof)
    assert not ok
    assert contract.stakes[sp_id] == 1000.0  # unslashed


def test_sp_must_stake():
    c = ShelbyContract()
    with pytest.raises(ValueError):
        c.register_sp(SPInfo(sp_id=0, stake=0.0))


@given(st.integers(6, 30), st.integers(2, 6), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_placement_properties(num_sps, n, seed):
    """Placement: n distinct SPs, deterministic in the seed, max DC spread."""
    sps = [SPInfo(sp_id=i, stake=1.0, dc=f"dc{i % 3}", rack=f"r{i % 4}") for i in range(num_sps)]
    if num_sps < n:
        return
    a1 = assign_chunkset(seed.to_bytes(4, "little"), 1, 0, sps, n)
    a2 = assign_chunkset(seed.to_bytes(4, "little"), 1, 0, sps, n)
    assert a1 == a2  # deterministic in the public randomness
    assert len(set(a1)) == n  # distinct SPs
    dcs_used = {sps[i].dc for i in a1}
    assert len(dcs_used) == min(n, 3)  # max failure-domain spread


def test_placement_respects_capacity():
    sps = [SPInfo(sp_id=i, stake=1.0, capacity_chunks=1) for i in range(4)]
    used = {0: 1, 1: 1, 2: 1}  # three SPs full
    with pytest.raises(ValueError):
        assign_chunkset(b"s", 0, 0, sps, n=2, used=used)
