"""Clay code properties: systematic, MDS (any k of n), optimal repair."""
import itertools
import random

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core.clay import ClayCode
from repro.core.rs import MDSCode

PARAMS = [(2, 2), (4, 2), (3, 3), (4, 3), (6, 3), (10, 6)]


def _codeword(k, m, w=6, seed=0):
    code = ClayCode(k=k, m=m)
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, (k, code.alpha, w), dtype=np.uint8)
    return code, data, code.encode(data)


@pytest.mark.parametrize("k,m", PARAMS)
def test_systematic(k, m):
    code, data, cw = _codeword(k, m)
    assert np.array_equal(cw[:k], data)


@pytest.mark.parametrize("k,m", [(2, 2), (4, 2), (3, 3)])
def test_mds_exhaustive(k, m):
    """EVERY k-subset of the n chunks reconstructs the data."""
    code, data, cw = _codeword(k, m)
    for subset in itertools.combinations(range(code.n), k):
        rec = code.reconstruct_data({i: cw[i] for i in subset})
        assert np.array_equal(rec, data), subset


@pytest.mark.parametrize("k,m", [(4, 3), (6, 3), (10, 6)])
def test_mds_sampled(k, m):
    code, data, cw = _codeword(k, m)
    r = random.Random(42)
    for _ in range(12):
        subset = r.sample(range(code.n), k)
        rec = code.reconstruct_data({i: cw[i] for i in subset})
        assert np.array_equal(rec, data), subset


@pytest.mark.parametrize("k,m", PARAMS)
def test_decode_with_extra_shards(k, m):
    code, data, cw = _codeword(k, m)
    full = code.decode({i: cw[i] for i in range(code.n)})
    assert np.array_equal(full, cw)


@pytest.mark.parametrize("k,m", PARAMS)
def test_repair_every_node(k, m):
    """Single-node repair from repair-plane sub-chunks only, for all nodes."""
    code, data, cw = _codeword(k, m)
    ids = None
    for failed in range(code.n):
        ids = code.repair_subchunk_ids(failed)
        assert len(ids) == code.alpha // code.q  # alpha/q sub-chunks per helper
        helpers = {i: cw[i][ids] for i in range(code.n) if i != failed}
        rep = code.repair(failed, helpers)
        assert np.array_equal(rep, cw[failed]), failed


@pytest.mark.parametrize("k,m", PARAMS)
def test_repair_bandwidth_optimal(k, m):
    """MSR: clay repair reads (n-1)/(k*q) of what RS reads; always less for q>1."""
    code = ClayCode(k=k, m=m)
    rs = MDSCode(n=code.n, k=k)
    chunk = code.alpha * 8
    clay_bw = code.repair_bandwidth_bytes(chunk)
    rs_bw = rs.repair_bandwidth_bytes(chunk)
    assert clay_bw == (code.n - 1) * chunk // code.q
    if code.q > 1:
        assert clay_bw < rs_bw


def test_paper_production_code_saving():
    """(10,6): 75% repair-bandwidth saving >= the paper's '60% less than RS'."""
    code = ClayCode(k=10, m=6)
    chunk = code.alpha * 16
    saving = 1 - code.repair_bandwidth_bytes(chunk) / MDSCode(n=16, k=10).repair_bandwidth_bytes(chunk)
    assert saving >= 0.60
    assert abs(saving - 0.75) < 1e-9


def test_replication_overhead_below_2x():
    assert ClayCode(k=10, m=6).n / 10 == 1.6 < 2.0  # Table 1 claim


@given(st.integers(2, 4), st.integers(2, 3), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_roundtrip_random_params(k, m, seed):
    code, data, cw = _codeword(k, m, w=4, seed=seed)
    r = random.Random(seed)
    erased = set(r.sample(range(code.n), m))
    shards = {i: cw[i] for i in range(code.n) if i not in erased}
    assert np.array_equal(code.decode(shards), cw)


def test_too_few_shards_raises():
    code, data, cw = _codeword(4, 2)
    with pytest.raises(ValueError):
        code.decode({0: cw[0], 1: cw[1], 2: cw[2]})


def test_repair_needs_all_helpers():
    code, data, cw = _codeword(4, 2)
    ids = code.repair_subchunk_ids(0)
    helpers = {i: cw[i][ids] for i in range(1, code.n - 1)}  # one missing
    with pytest.raises(ValueError):
        code.repair(0, helpers)
