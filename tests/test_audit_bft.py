"""BFT properties of audit-score aggregation (§4.3, hypothesis)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core.audit import aggregate_scores, trim_f


@given(
    st.integers(4, 30),  # number of SPs
    st.floats(0.0, 1.0),  # honest rate for target SP j
    st.integers(0, 2**31),
)
@settings(max_examples=60, deadline=None)
def test_trimmed_score_within_honest_range(n, true_rate, seed):
    """With f < n/3 Byzantine raters, score_j stays within [min,max] of
    honest evaluations — Byzantine raters cannot drag it outside."""
    rng = np.random.default_rng(seed)
    f = trim_f(n - 1)
    target = 0
    honest_noise = rng.uniform(-0.05, 0.05, n - 1 - f)
    honest_evals = np.clip(true_rate + honest_noise, 0.0, 1.0)
    byz_evals = rng.choice([0.0, 1.0], f)  # worst-case liars
    rates = {}
    raters = [i for i in range(1, n)]
    for i, r in zip(raters[: len(honest_evals)], honest_evals):
        rates[i] = {target: float(r)}
    for i, r in zip(raters[len(honest_evals):], byz_evals):
        rates[i] = {target: float(r)}
    scores = aggregate_scores(rates, sp_ids=list(range(n)))
    lo, hi = honest_evals.min(), honest_evals.max()
    assert lo - 1e-9 <= scores[target] <= hi + 1e-9


@given(st.integers(4, 20), st.integers(0, 2**31))
@settings(max_examples=40, deadline=None)
def test_honest_sp_scores_high(n, seed):
    """All-honest population: every SP scores ~1."""
    rng = np.random.default_rng(seed)
    rates = {i: {j: 1.0 for j in range(n) if j != i} for i in range(n)}
    scores = aggregate_scores(rates, sp_ids=list(range(n)))
    assert all(s == 1.0 for s in scores.values())


def test_faulty_sp_cannot_inflate():
    """A faulty SP rated 0 by all honest peers scores 0 even if f colluders
    rate it 1."""
    n = 10
    f = trim_f(n - 1)
    rates = {}
    for i in range(1, n):
        rates[i] = {0: 1.0 if i <= f else 0.0}
    scores = aggregate_scores(rates, sp_ids=list(range(n)))
    assert scores[0] == 0.0


def test_no_evaluations_defaults_to_one():
    scores = aggregate_scores({}, sp_ids=[0, 1])
    assert scores == {0: 1.0, 1: 1.0}


def test_self_ratings_ignored():
    rates = {0: {0: 1.0}, 1: {0: 0.0}, 2: {0: 0.0}, 3: {0: 0.0}}
    scores = aggregate_scores(rates, sp_ids=[0, 1, 2, 3])
    assert scores[0] == 0.0  # own 1.0 never counted
