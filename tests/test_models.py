"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get, get_smoke
from repro.configs.base import SHAPES, cell_applicable
from repro.models.model import build
from repro.sharding import AxisCtx, init_params

KEY = jax.random.PRNGKey(0)
CTX = AxisCtx()
B, S = 2, 24


def _batch(cfg, rng, b=B, s=S):
    if cfg.is_encdec:
        return {"frames": rng.normal(size=(b, cfg.enc_seq, cfg.d_model)).astype(np.float32),
                "tokens": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
                "labels": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)}
    if cfg.input_mode == "embeddings":
        return {"embeddings": rng.normal(size=(b, s, cfg.d_model)).astype(np.float32),
                "labels": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)}
    return {"tokens": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32),
            "labels": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_train_step(arch, rng):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    from repro.train.step import make_train_step
    from repro.train.optimizer import init_state

    cfg = get_smoke(arch)
    model = build(cfg)
    params = init_params(model.param_specs(), KEY)
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b, CTX))(params, batch)
    assert np.isfinite(float(loss))
    step = jax.jit(make_train_step(cfg, CTX, num_microbatches=2))
    state, m2 = step(init_state(params), batch)
    assert np.isfinite(float(m2["loss"]))
    assert int(state["step"]) == 1
    # a step must actually change the parameters
    leaf0 = jax.tree.leaves(params)[0]
    leaf1 = jax.tree.leaves(state["params"])[0]
    assert not np.array_equal(np.asarray(leaf0), np.asarray(leaf1))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_shapes(arch, rng):
    cfg = get_smoke(arch)
    model = build(cfg)
    params = init_params(model.param_specs(), KEY)
    cache = init_params(model.cache_specs(B, 16), KEY)
    toks = rng.integers(0, cfg.vocab, (B, 1)).astype(np.int32)
    logits, nc = jax.jit(
        lambda p, c, t: model.decode_step(p, c, t, jnp.int32(2), CTX)
    )(params, cache, toks)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(nc) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["granite-8b", "starcoder2-3b", "yi-9b",
                                  "command-r-plus-104b", "qwen3-moe-30b-a3b",
                                  "deepseek-v2-lite-16b", "falcon-mamba-7b",
                                  "hymba-1.5b"])
def test_decode_matches_prefill(arch, rng):
    """Greedy decode from scratch must agree with a fresh prefill at every
    prefix — the KV-cache/decode path is numerically consistent with the
    full forward."""
    import dataclasses

    cfg = get_smoke(arch)
    if cfg.moe is not None:
        # capacity drops are batch-shape-dependent (GShard semantics); make
        # both paths drop-free so this tests the attention/MLA cache math
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    model = build(cfg)
    params = init_params(model.param_specs(), KEY)
    t = 6
    toks = rng.integers(0, cfg.vocab, (B, t)).astype(np.int32)
    cache = init_params(model.cache_specs(B, t + 1), KEY)
    decode = jax.jit(lambda p, c, tk, pos: model.decode_step(p, c, tk, pos, CTX))
    prefill = jax.jit(lambda p, b: model.prefill(p, b, CTX))
    for pos in range(t):
        dec_logits, cache = decode(params, cache, toks[:, pos : pos + 1], jnp.int32(pos))
        if cfg.input_mode == "embeddings":
            continue  # prefill consumes embeddings; decode path tested above
        ref_logits, _ = prefill(params, {"tokens": toks[:, : pos + 1]})
        d = np.asarray(dec_logits[:, 0], np.float32)
        r = np.asarray(ref_logits[:, 0], np.float32)
        top_match = (d.argmax(-1) == r.argmax(-1)).mean()
        assert np.abs(d - r).max() < 0.25 and top_match >= 0.5, (arch, pos)


def test_mamba_decode_matches_scan(rng):
    """Token-by-token SSM recurrence == full associative scan."""
    from repro.models import mamba as M

    cfg = get_smoke("falcon-mamba-7b")
    specs = M.ssm_specs(cfg)
    params = init_params(specs, KEY)
    x = jnp.asarray(rng.normal(size=(2, 10, cfg.d_model)).astype(np.float32) * 0.3,
                    jnp.bfloat16)
    full = M.apply_ssm(params, x, cfg, CTX)
    shapes = M.init_ssm_cache_shape(cfg, 2)
    cache = {"conv": jnp.zeros(shapes["conv"], jnp.bfloat16),
             "h": jnp.zeros(shapes["h"], jnp.float32)}
    outs = []
    for tpos in range(10):
        y, cache = M.apply_ssm_decode(params, x[:, tpos : tpos + 1], cache, cfg, CTX)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32), np.asarray(step, np.float32),
                               atol=0.15, rtol=0.1)


def test_flash_attention_matches_naive(rng):
    from repro.models.layers import MaskSpec, flash_attention

    b, s, h, hkv, hd = 2, 37, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    out = flash_attention(q, k, v, mask=MaskSpec(causal=True), q_chunk=16, kv_chunk=8)
    # naive reference
    g = h // hkv
    qr = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k) / np.sqrt(hd)
    mask = np.tril(np.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    ref = jnp.einsum("bhgqk,bkhd->bqhgd", jax.nn.softmax(scores, -1), v).reshape(b, s, h, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3, rtol=1e-2)


def test_sliding_window_masks_old_tokens(rng):
    from repro.models.layers import MaskSpec, flash_attention

    b, s, h, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    w = flash_attention(q, k, v, mask=MaskSpec(causal=True, window=4), q_chunk=8, kv_chunk=8)
    # last position attends only to the 4 most recent: changing k/v BEFORE
    # the window must not change the output at the last position
    k2 = k.at[:, :20].set(0.0)
    v2 = v.at[:, :20].set(0.0)
    w2 = flash_attention(q, k2, v2, mask=MaskSpec(causal=True, window=4), q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(w[:, -1]), np.asarray(w2[:, -1]), atol=1e-5)


def test_moe_capacity_drops_overflow(rng):
    """With capacity_factor tiny, outputs stay finite (dropped tokens pass
    through via residual-weighted zeros)."""
    import dataclasses

    cfg = get_smoke("qwen3-moe-30b-a3b")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    model = build(cfg)
    params = init_params(model.param_specs(), KEY)
    batch = _batch(cfg, rng)
    loss, _ = jax.jit(lambda p, b: model.loss(p, b, CTX))(params, batch)
    assert np.isfinite(float(loss))


def test_long_mode_cells_marked():
    for arch in ALL_ARCHS:
        cfg = get(arch)
        long_cell = next(s for s in SHAPES if s.name == "long_500k")
        ok, why = cell_applicable(cfg, long_cell)
        if arch in ("hymba-1.5b", "falcon-mamba-7b"):
            assert ok
        else:
            assert not ok and "full-attention" in why


def test_param_counts_match_published():
    expected = {  # billions, tolerance 12%
        "deepseek-v2-lite-16b": 15.7, "qwen3-moe-30b-a3b": 30.5, "hymba-1.5b": 1.5,
        "falcon-mamba-7b": 7.3, "starcoder2-3b": 3.0, "granite-8b": 8.1,
        "yi-9b": 8.8, "command-r-plus-104b": 104.0, "phi-3-vision-4.2b": 3.8,
    }
    for arch, exp in expected.items():
        got = get(arch).param_count() / 1e9
        assert abs(got - exp) / exp < 0.12, (arch, got, exp)


def test_ring_cache_matches_windowed_attention(rng):
    """Long-mode decode (ring KV cache, window W) must equal full attention
    with a sliding-window mask at every position, incl. past wrap-around."""
    from repro.models import attention as A
    from repro.models.layers import MaskSpec
    import dataclasses

    cfg = dataclasses.replace(get_smoke("granite-8b"), long_window=8, sub_quadratic=True)
    specs = A.attn_specs(cfg)
    params = init_params(specs, KEY)
    T, W = 20, 8
    x = jnp.asarray(rng.normal(size=(2, T, cfg.d_model)).astype(np.float32) * 0.3, jnp.bfloat16)

    full = A.attn_full(params, x, cfg, CTX, mask=MaskSpec(causal=True, window=W))

    cache = {"k": jnp.zeros((2, W, cfg.num_kv_heads, cfg.head_dim_), jnp.bfloat16),
             "v": jnp.zeros((2, W, cfg.num_kv_heads, cfg.head_dim_), jnp.bfloat16)}
    outs = []
    for pos in range(T):
        o, cache = A.attn_decode(params, x[:, pos : pos + 1], cache, jnp.int32(pos),
                                 cfg, CTX, window=W)
        outs.append(o)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32), np.asarray(stepped, np.float32),
                               atol=0.08, rtol=0.05)
