import numpy as np
import pytest

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.storage.blob import BlobLayout
from repro.storage.rpc import RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider


@pytest.fixture
def small_layout():
    return BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)


@pytest.fixture
def cluster(small_layout):
    """(contract, sps, rpc, client) with 8 healthy SPs across 3 DCs."""
    contract = ShelbyContract()
    sps = {}
    for i in range(8):
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 3}", rack=f"r{i % 4}"))
        sps[i] = StorageProvider(i)
    rpc = RPCNode("rpc0", contract, sps, small_layout, cache_chunksets=16)
    client = ShelbyClient(contract, rpc, deposit=1e9)
    return contract, sps, rpc, client


@pytest.fixture
def rng():
    return np.random.default_rng(0)
