"""Background planes on the event loop (ISSUE 5).

Covers the tentpole — audit/repair traffic as paced background tasks that
contend with paid serving for NICs, trunks and SP disk slots without ever
starving it — plus the satellite regressions: priority/class-capped
resource acquisition, the determinism digest over foreground AND
background timings, the bounded-interference bar, the MDS corrupt-helper
repair fix, the at-rest-corruption spot-check, and the hedge-timer re-arm
after an overload-gate brownout.
"""
import numpy as np
import pytest

from repro.core import audit as audit_mod
from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.backbone import Backbone
from repro.net.events import Acquire, EventLoop, Release, Sleep
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.net.scheduler import HedgedScheduler
from repro.net.workloads import replay_open_loop, zipf_hotset
from repro.storage.background import AuditPlane, RepairPlane
from repro.storage.blob import BlobLayout
from repro.storage.repair import RepairCoordinator
from repro.storage.rpc import BackboneTransport, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import BackgroundSpec, ServiceSpec, StorageProvider


# ---------------------------------------------------------------------------
# priority / class-capped resource acquisition (net/events.py)
# ---------------------------------------------------------------------------
def test_bg_class_cap_leaves_free_slots_for_foreground():
    """A background class at its slot cap queues even while slots are free;
    a foreground arrival takes the free slot immediately."""
    loop = EventLoop()
    got = []

    def bg(name):
        yield Acquire("disk", 2, priority=1, limit=1)
        got.append((name, loop.now))
        yield Sleep(10.0)
        yield Release("disk", priority=1)

    def fg(name):
        yield Acquire("disk", 2)
        got.append((name, loop.now))
        yield Sleep(2.0)
        yield Release("disk")

    loop.spawn(bg("bg1"))
    loop.spawn(bg("bg2"))  # class cap 1: must wait for bg1 despite a free slot
    loop.spawn(fg("fg"), at_ms=1.0)  # takes the free slot the cap protected
    loop.run()
    assert got == [("bg1", 0.0), ("fg", 1.0), ("bg2", 10.0)]
    res = loop.resource("disk")
    assert res.acquired_by_class[0] == 1 and res.acquired_by_class[1] == 2
    assert res.wait_ms_by_class.get(1, 0.0) == pytest.approx(10.0)
    assert res.wait_ms_by_class.get(0, 0.0) == 0.0


def test_queued_foreground_wakes_before_earlier_background_waiter():
    loop = EventLoop()
    got = []

    def holder():
        yield Acquire("disk", 1)
        yield Sleep(10.0)
        yield Release("disk")

    def waiter(name, priority):
        yield Acquire("disk", 1, priority=priority)
        got.append((name, loop.now))
        yield Sleep(2.0)
        yield Release("disk", priority=priority)

    loop.spawn(holder())
    loop.spawn(waiter("bg", 1), at_ms=0.5)  # queued first …
    loop.spawn(waiter("fg", 0), at_ms=1.0)  # … but foreground wakes first
    loop.run()
    assert got == [("fg", 10.0), ("bg", 12.0)]


# ---------------------------------------------------------------------------
# a small backbone world with repair work and full audit pressure
# ---------------------------------------------------------------------------
def _bg_world(*, num_sps=10, service_ms=4.0, slots=2, bg=None, num_rpcs=2,
              seed=0):
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract()
    bb = Backbone.mesh(3, base_latency_ms=4.0, gbps=10.0)
    bg = bg or BackgroundSpec()
    sps = {}
    for i in range(num_sps):
        dc = f"dc{i % 3}"
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=dc, rack=f"r{i % 4}"))
        sps[i] = StorageProvider(i, service=ServiceSpec(
            disk_ms_per_chunk=service_ms, slots=slots, background=bg))
        bb.register_node(f"sp{i}", dc)
    rpcs = []
    for r in range(num_rpcs):
        node = f"rpc{r}"
        bb.register_node(node, f"dc{r % 3}")
        rpcs.append(RPCNode(node, contract, sps, layout, cache_chunksets=8,
                            transport=BackboneTransport(sps, bb, node)))
    bb.register_node("client", "dc0")
    bb.register_node("repairer", "dc1")
    fleet = RPCFleet(rpcs, CacheAffinityPolicy(), backbone=bb)
    client = ShelbyClient(contract, fleet, deposit=1e9)
    rng = np.random.default_rng(seed)
    metas = [client.put(rng.integers(0, 256, 150_000, dtype=np.uint8).tobytes())
             for _ in range(4)]
    sps[5].crash()  # AFTER the writes: its chunks become repair work
    return layout, contract, bb, sps, fleet, client, metas


def _bg_planes(layout, contract, sps, *, auditors=3):
    sp_nodes = {i: f"sp{i}" for i in sps}
    sp_ids = [s.sp_id for s in contract.active_sps()]
    challenges = audit_mod.derive_challenges(
        contract.epoch_seed(0), 0, contract.holdings(), sp_ids,
        p_a=1.0, auditors_per_audit=auditors,
    )
    audits = AuditPlane(contract, sps, challenges, nodes=sp_nodes)
    rc = RepairCoordinator(contract, sps, layout, nodes=sp_nodes,
                           coordinator_node="repairer")
    return audits, RepairPlane(rc)


def _reqs(metas, n=60):
    return zipf_hotset(metas, clients=["client"], num_requests=n,
                       interarrival_ms=2.0, seed=3, arrival="poisson")


def test_replay_with_background_is_deterministic():
    """Same seed ⇒ same foreground AND background timings (the digest
    covers both), across fully rebuilt worlds."""
    digests, bg_counts = [], []
    for _ in range(2):
        layout, contract, bb, sps, fleet, client, metas = _bg_world()
        audits, repairs = _bg_planes(layout, contract, sps)
        result = replay_open_loop(fleet, _reqs(metas),
                                  background=[audits, repairs])
        digests.append(result.digest())
        bg_counts.append(result.background_ops)
    assert digests[0] == digests[1]
    assert bg_counts[0] == bg_counts[1] > 0
    # both planes actually ran
    kinds = {"audit", "repair"}
    assert kinds == {b.kind for b in result.background} & kinds


def test_background_interference_bounded_and_bytes_on_links():
    """Serving p99 under full audits+repair stays within the background
    budget's bound, the background bytes are visible on the trunk
    counters, and no foreground read is starved."""
    layout, contract, bb, sps, fleet, client, metas = _bg_world()
    quiet = replay_open_loop(fleet, _reqs(metas))
    assert quiet.background == [] and quiet.dropped == 0

    layout, contract, bb, sps, fleet, client, metas = _bg_world()
    audits, repairs = _bg_planes(layout, contract, sps)
    loaded = replay_open_loop(fleet, _reqs(metas),
                              background=[audits, repairs])
    assert loaded.dropped == 0  # background never starves paid reads
    ok_repairs = [b for b in loaded.background if b.kind == "repair" and b.ok]
    assert ok_repairs and audits.proof_bytes > 0
    # background traffic shows up on the links …
    delta = sum(loaded.link_bytes.values()) - sum(quiet.link_bytes.values())
    assert delta >= 0.5 * (audits.proof_bytes + sum(b.nbytes for b in ok_repairs))
    # … and the paced background keeps the serving tail within budget
    assert loaded.percentile(99.0) <= 1.5 * quiet.percentile(99.0) + 5.0


def test_background_disabled_is_unchanged():
    """With no planes attached the replay is byte-identical to passing
    background=None explicitly — the machinery costs nothing when off."""
    layout, contract, bb, sps, fleet, client, metas = _bg_world()
    a = replay_open_loop(fleet, _reqs(metas))
    layout, contract, bb, sps, fleet, client, metas = _bg_world()
    b = replay_open_loop(fleet, _reqs(metas), background=None)
    assert a.digest() == b.digest()
    assert a.background == [] and b.background == []


def test_audit_plane_matches_serial_outcomes():
    """The plane produces exactly the scoreboard the old serial pass did:
    honest SPs score 1s, an SP that dropped a chunk fails precisely the
    challenges on that chunk — concurrency changes timing, not outcomes."""
    layout, contract, bb, sps, fleet, client, metas = _bg_world()
    # one SP silently loses one specific chunk (not crashed: it still audits)
    victim_meta = metas[0]
    victim_sp = victim_meta.placement[(0, 0)]
    del sps[victim_sp]._chunks[(victim_meta.blob_id, 0, 0)]
    sp_ids = [s.sp_id for s in contract.active_sps()]
    challenges = audit_mod.derive_challenges(
        contract.epoch_seed(0), 0, contract.holdings(), sp_ids,
        p_a=1.0, auditors_per_audit=3,
    )
    plane = AuditPlane(contract, sps, challenges, nodes={i: f"sp{i}" for i in sps})
    loop = EventLoop(network=bb)
    plane.spawn(loop)
    loop.run()
    # expected outcome per challenge, computed serially
    expected_fail = sum(
        1 for ch in challenges
        if not sps[ch.auditee].has_chunk(ch.blob_id, ch.chunkset, ch.chunk)
        or sps[ch.auditee].behavior.crashed
    ) * 3  # every auditor records the same outcome
    recorded = [(a, bit) for sp in sps.values()
                for a, bits in sp.scoreboard.bits.items() for bit in bits]
    assert len(recorded) == 3 * len(challenges)
    assert sum(1 for _, bit in recorded if bit == 0) == expected_fail
    assert len(plane.records) == len(challenges)
    failed_ops = sum(1 for r in plane.records if not r.ok)
    assert failed_ops == expected_fail // 3 > 0


# ---------------------------------------------------------------------------
# repair satellites: corrupt helpers, per-chunk failures, spot-check
# ---------------------------------------------------------------------------
def _flip(sp, key):
    sp._chunks[key] = sp._chunks[key].copy()
    sp._chunks[key].reshape(-1)[0] ^= 0xFF


def test_mds_repair_rejects_corrupt_helper_and_retries(cluster, rng):
    """One at-rest-corrupted helper among the candidates no longer poisons
    the decode: per-chunk commitment checks reject it and the next helper
    subset is used (MSR falls back to verified MDS)."""
    contract, sps, rpc, client = cluster
    data = rng.integers(0, 256, 200_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    # lose chunk (0,0) surgically; corrupt helper (0,1) at rest
    del sps[meta.placement[(0, 0)]]._chunks[(meta.blob_id, 0, 0)]
    _flip(sps[meta.placement[(0, 1)]], (meta.blob_id, 0, 1))
    rc = RepairCoordinator(contract, sps, rpc.layout)
    rep = rc.repair_chunk(meta.blob_id, 0, 0)
    assert rep.mode == "mds" and rep.verified and rep.helpers_rejected == 1
    rpc._cache.clear()
    assert client.get(meta.blob_id) == data


def test_serve_time_corrupt_helper_is_rejected(rng):
    """The ISSUE's literal scenario: MDS fallback (a crashed SP rules out
    MSR) with a corrupt=True helper inside the first k candidates."""
    layout = BlobLayout(k=2, m=3, chunkset_bytes_target=32 * 1024)
    contract = ShelbyContract()
    sps = {}
    for i in range(8):
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 3}"))
        sps[i] = StorageProvider(i)
    rpc = RPCNode("rpc0", contract, sps, layout)
    client = ShelbyClient(contract, rpc, deposit=1e9)
    data = rng.integers(0, 256, 90_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    del sps[meta.placement[(0, 0)]]._chunks[(meta.blob_id, 0, 0)]  # the loss
    sps[meta.placement[(0, 1)]].crash()  # rules out the MSR pattern
    sps[meta.placement[(0, 2)]].behavior.corrupt = True  # first-k poisoner
    rc = RepairCoordinator(contract, sps, layout)
    rep = rc.repair_chunk(meta.blob_id, 0, 0)
    assert rep.mode == "mds" and rep.verified and rep.helpers_rejected == 1


def test_repair_all_reports_per_chunk_failures(cluster, rng):
    """An unrecoverable chunk lands in ``failures``; the remaining repairs
    still run instead of dying on the first raise."""
    contract, sps, rpc, client = cluster
    data = rng.integers(0, 256, 200_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    lay = rpc.layout
    # chunkset 0: the target is lost and 3 of its 5 helpers are corrupted
    # at rest -> 2 verified helpers < k=4, unrecoverable
    del sps[meta.placement[(0, 0)]]._chunks[(meta.blob_id, 0, 0)]
    for ck in (1, 2, 3):
        _flip(sps[meta.placement[(0, ck)]], (meta.blob_id, 0, ck))
    # chunkset 1: a plain loss, repairable at MSR bandwidth
    del sps[meta.placement[(1, 2)]]._chunks[(meta.blob_id, 1, 2)]
    rc = RepairCoordinator(contract, sps, lay)
    reports = rc.repair_all()
    assert [(r.blob_id, r.chunkset, r.chunk) for r in reports] == [(meta.blob_id, 1, 2)]
    assert len(rc.failures) == 1 and rc.failures[0][0] == (meta.blob_id, 0, 0)
    assert "unrecoverable" in rc.failures[0][1]


def test_scan_spot_check_detects_bitflip_on_live_sp(cluster, rng):
    """A bit-flipped chunk on a live, responsive SP is invisible to the
    liveness scan but caught by the sampled commitment spot-check — and
    repair relocates it."""
    contract, sps, rpc, client = cluster
    data = rng.integers(0, 256, 200_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    key = (meta.blob_id, 0, 2)
    _flip(sps[meta.placement[(0, 2)]], key)
    rc = RepairCoordinator(contract, sps, rpc.layout)
    assert rc.scan_lost_chunks() == []  # the old scan misses it entirely
    lost = rc.scan_lost_chunks(spot_check_rate=1.0)
    assert lost == [key] and rc.spot_checks > 0
    reports = rc.repair_all()  # default rate 0: repair the pinned list
    assert reports == []  # nothing "lost" without the spot check …
    rep = rc.repair_chunk(*key)  # … but the flagged chunk repairs cleanly
    assert rep.verified
    assert rc.scan_lost_chunks(spot_check_rate=1.0) == []
    rpc._cache.clear()
    assert client.get(meta.blob_id) == data


def test_repair_task_moves_bytes_and_respects_msr_bandwidth():
    """Event-loop repair reads exactly the MSR helper bytes over the
    backbone and re-disperses the rebuilt chunk."""
    layout, contract, bb, sps, fleet, client, metas = _bg_world()
    rc = RepairCoordinator(contract, sps, layout,
                           nodes={i: f"sp{i}" for i in sps},
                           coordinator_node="repairer")
    lost = rc.scan_lost_chunks()
    assert lost  # the crashed SP's chunks
    loop = EventLoop(network=bb)
    plane = RepairPlane(rc, lost=lost[:3])
    plane.spawn(loop)
    loop.run()
    assert not plane.failures
    expect = (layout.n - 1) * layout.chunk_bytes // layout.code.q
    assert all(r.helper_bytes_read == expect and r.mode == "msr"
               for r in rc.reports)
    assert all(r.sim_ms > 0 for r in rc.reports)
    # helper bytes + re-dispersal crossed real trunks
    assert sum(bb.link_bytes.values()) >= 3 * expect


# ---------------------------------------------------------------------------
# hedge-timer re-arm after overload-gate suppression (net/scheduler.py)
# ---------------------------------------------------------------------------
def test_hedge_rearms_after_gate_recovers():
    """A brownout window suppresses a hedge; once the gate recovers, the
    NEXT deadline must still fire and hedge — before the fix the timer was
    never re-armed and hedging stayed dead for the whole fetch."""
    gate_answers = [False, True, True]  # brownout, then recovered

    def gate():
        return gate_answers.pop(0) if gate_answers else True

    def issue_task(key, sp_id):
        # candidate 0 is a 500 ms straggler; every other leg answers in 5 ms
        yield Sleep(500.0 if key == 0 else 5.0)
        return f"shard{key}"

    loop = EventLoop()
    sched = HedgedScheduler(hedge=1, deadline_factor=2.0, min_deadline_ms=10.0)
    candidates = [(0, 0, 1.0), (1, 1, 2.0), (2, 2, 30.0)]
    h = loop.spawn(sched.fetch_task(loop, 2, candidates, issue_task,
                                    hedge_gate=gate))
    res = loop.run_until(h)
    assert res.hedges_suppressed >= 1  # the brownout really bit
    assert res.hedges == 1  # …but the re-armed deadline hedged after recovery
    assert len(res.shards) == 2 and res.latency_ms < 500.0


def test_suppressed_hedge_without_recovery_never_hedges():
    """The gate staying closed keeps hedges shed (only re-arming changed)."""
    def issue_task(key, sp_id):
        yield Sleep(200.0 if key == 0 else 5.0)
        return f"shard{key}"

    loop = EventLoop()
    sched = HedgedScheduler(hedge=1, deadline_factor=2.0, min_deadline_ms=10.0)
    candidates = [(0, 0, 1.0), (1, 1, 2.0), (2, 2, 30.0)]
    h = loop.spawn(sched.fetch_task(loop, 2, candidates, issue_task,
                                    hedge_gate=lambda: False))
    res = loop.run_until(h)
    assert res.hedges == 0 and res.hedges_suppressed >= 1
    assert res.latency_ms == pytest.approx(200.0)  # waited out the straggler
