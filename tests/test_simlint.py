"""simlint rule fixtures: one minimal positive + one negative snippet per
rule, the pragma/baseline workflow, path scoping, and CLI exit codes.

The baseline-exactness test at the bottom is the repo-wide gate: it fails
on any NEW finding in the sim path *and* on any stale baseline entry, so
the committed baseline can only ever shrink.
"""
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import simlint
from repro.analysis.__main__ import main as cli_main

SIM_PATH = "src/repro/net/snippet.py"  # virtual in-scope path for fixtures


def hits(source: str, path: str = SIM_PATH) -> list[str]:
    return [f.rule for f in simlint.lint_source(textwrap.dedent(source), path)]


# -- SIM001 wall clock -----------------------------------------------------------
def test_sim001_positive():
    src = """
    import time
    def service_ms():
        return time.time() * 1e3
    """
    assert hits(src) == ["SIM001"]


def test_sim001_positive_datetime_and_alias():
    src = """
    import datetime
    from time import perf_counter as clock
    def stamp():
        return datetime.datetime.now(), clock()
    """
    assert hits(src) == ["SIM001", "SIM001"]


def test_sim001_negative_loop_now():
    src = """
    def stamp(loop):
        return loop.now + 5.0
    """
    assert hits(src) == []


# -- SIM002 unseeded / global RNG ------------------------------------------------
def test_sim002_positive():
    src = """
    import random
    import numpy as np
    def pick(xs):
        np.random.shuffle(xs)
        rng = np.random.default_rng()
        return random.choice(xs)
    """
    assert hits(src) == ["SIM002", "SIM002", "SIM002"]


def test_sim002_negative_seeded_generator():
    src = """
    import numpy as np
    def pick(xs, seed):
        rng = np.random.default_rng(seed)
        return xs[rng.integers(0, len(xs))]
    """
    assert hits(src) == []


# -- SIM003 unordered iteration --------------------------------------------------
def test_sim003_positive():
    src = """
    def schedule(sps):
        out = []
        for sp in set(sps):
            out.append(sp)
        return list({1, 2, 3})
    """
    assert hits(src) == ["SIM003", "SIM003"]


def test_sim003_negative_sorted():
    src = """
    def schedule(sps):
        return [sp for sp in sorted(set(sps))]
    """
    assert hits(src) == []


# -- SIM004 identity tie-breaks --------------------------------------------------
def test_sim004_positive():
    src = """
    def key_for(task):
        return (task.t, id(task))
    """
    assert hits(src) == ["SIM004"]


def test_sim004_negative_seq_key():
    src = """
    def key_for(task, seq):
        return (task.t, seq)
    """
    assert hits(src) == []


# -- SIM005 acquire without guarded release --------------------------------------
def test_sim005_positive_no_finally():
    src = """
    def task(sp_id, slots, ms):
        yield Acquire(("sp", sp_id), slots)
        yield Sleep(ms)
        yield Release(("sp", sp_id))
    """
    assert hits(src) == ["SIM005"]


def test_sim005_positive_no_release_at_all():
    src = """
    def task(sp_id, slots, ms):
        yield Acquire(("sp", sp_id), slots)
        yield Sleep(ms)
    """
    assert hits(src) == ["SIM005"]


def test_sim005_negative_safe_release_in_finally():
    src = """
    def task(sp_id, slots, ms):
        yield Acquire(("sp", sp_id), slots)
        try:
            yield Sleep(ms)
        finally:
            yield from safe_release(Release(("sp", sp_id)))
    """
    assert hits(src) == []


# -- SIM006 swallowed GeneratorExit ----------------------------------------------
def test_sim006_positive_bare_except():
    src = """
    def harvest():
        try:
            work()
        except:
            pass
    """
    assert hits(src) == ["SIM006"]


def test_sim006_positive_broad_except_in_task():
    src = """
    def harvest(handles):
        for h in handles:
            try:
                out = yield Join(h)
            except Exception:
                continue
    """
    assert hits(src) == ["SIM006"]


def test_sim006_negative_control_flow_reraised():
    src = """
    def harvest(handles):
        for h in handles:
            try:
                out = yield Join(h)
            except (GeneratorExit, KeyboardInterrupt):
                raise
            except Exception:
                continue
    """
    assert hits(src) == []


# -- SIM007 dict-order float reductions ------------------------------------------
def test_sim007_positive():
    src = """
    def total(payments):
        return sum(payments.values())
    """
    assert hits(src) == ["SIM007"]


def test_sim007_negative_sorted_and_len():
    src = """
    def total(payments, queues):
        a = sum(payments[k] for k in sorted(payments))
        b = sum(len(q) for q in queues.values())
        return a + b
    """
    assert hits(src) == []


# -- SIM008 off-loop accounting mutation -----------------------------------------
def test_sim008_positive_outside_owner():
    src = """
    def hack(res):
        res.in_use -= 1
    """
    assert hits(src, path="src/repro/storage/snippet.py") == ["SIM008"]


def test_sim008_negative_in_owner_module():
    src = """
    def engine_release(res):
        res.in_use -= 1
    """
    assert hits(src, path="src/repro/net/events.py") == []


# -- pragma workflow -------------------------------------------------------------
def test_pragma_with_reason_suppresses():
    src = """
    import time
    def bench():
        return time.perf_counter()  # simlint: ok SIM001 wall telemetry only
    """
    assert hits(src) == []


def test_pragma_on_previous_line_suppresses():
    src = """
    import time
    def bench():
        # simlint: ok SIM001 wall telemetry only
        return time.perf_counter()
    """
    assert hits(src) == []


def test_pragma_without_reason_still_reports():
    src = """
    import time
    def bench():
        return time.perf_counter()  # simlint: ok SIM001
    """
    found = simlint.lint_source(textwrap.dedent(src), SIM_PATH)
    assert [f.rule for f in found] == ["SIM001"]
    assert "missing a" in found[0].message


def test_pragma_wrong_rule_does_not_suppress():
    src = """
    import time
    def bench():
        return time.perf_counter()  # simlint: ok SIM007 not the right rule
    """
    assert hits(src) == ["SIM001"]


# -- path scoping: sim path vs host path -----------------------------------------
def test_scope_excludes_host_path_modules():
    root = simlint.REPO_ROOT
    assert simlint.in_scope(root / "src/repro/net/events.py")
    assert simlint.in_scope(root / "src/repro/scenarios/serving.py")
    # train/launch legitimately read wall clock: out of scope by PATH,
    # not by pragma (see docs/simlint.md)
    assert not simlint.in_scope(root / "src/repro/train/loop.py")
    assert not simlint.in_scope(root / "src/repro/launch/dryrun.py")
    assert not simlint.in_scope(root / "src/repro/kernels/decode_matmul.py")
    assert not simlint.in_scope(root / "tests/test_events.py")


def test_target_files_stay_inside_sim_scope():
    for f in simlint.iter_target_files():
        rel = f.relative_to(simlint.REPO_ROOT / "src" / "repro")
        assert rel.parts[0] in simlint.SIM_SCOPE_PACKAGES


# -- baseline workflow -----------------------------------------------------------
def test_committed_baseline_is_exact():
    """No new findings anywhere in the sim path AND no stale entries: the
    committed baseline matches the tree exactly."""
    findings = simlint.lint_paths()
    new, stale = simlint.diff_baseline(findings, simlint.load_baseline())
    assert not new, "unbaselined findings:\n" + "\n".join(
        f.render() for f in new)
    assert not stale, "stale baseline entries:\n" + "\n".join(stale)


def test_baseline_roundtrip(tmp_path):
    src = textwrap.dedent("""
    import time
    def bench():
        return time.time()
    """)
    findings = simlint.lint_source(src, SIM_PATH)
    bl = tmp_path / "bl"
    simlint.write_baseline(findings, bl)
    new, stale = simlint.diff_baseline(findings, simlint.load_baseline(bl))
    assert not new and not stale
    # a fixed finding leaves its entry stale; a fresh one is reported new
    new, stale = simlint.diff_baseline([], simlint.load_baseline(bl))
    assert not new and len(stale) == 1


# -- CLI exit codes: 0 clean / 1 findings / 2 internal error ---------------------
def test_cli_clean_tree_exits_zero(capsys):
    assert cli_main(["--check"]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_findings_exit_one(capsys):
    # ignoring the baseline resurfaces the grandfathered hits
    assert cli_main(["--no-baseline"]) == 1


def test_cli_bad_usage_exits_two():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--definitely-not-a-flag"],
        capture_output=True,
        cwd=str(simlint.REPO_ROOT),
        env={"PYTHONPATH": str(simlint.REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 2


def test_unparseable_source_is_internal_error():
    # parse failures surface as exceptions (-> CLI exit 2), never findings
    with pytest.raises(SyntaxError):
        simlint.lint_source("def broken(:\n", SIM_PATH)


def test_cli_internal_error_exits_two():
    # crash the linter inside the CLI wrapper: must map to exit 2, so CI
    # can tell "the gate is broken" from "the gate found problems"
    prog = (
        "import repro.analysis.simlint as s\n"
        "def boom(*a, **k): raise RuntimeError('boom')\n"
        "s.lint_paths = boom\n"
        "import runpy, sys\n"
        "sys.argv = ['prog', '--check']\n"
        "runpy.run_module('repro.analysis', run_name='__main__')\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        cwd=str(simlint.REPO_ROOT),
        env={"PYTHONPATH": str(simlint.REPO_ROOT / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 2
    assert b"boom" in proc.stderr
