"""Overload-safe serving: single-flight fetch dedup + admission control.

The acceptance gates of ISSUE 4: N concurrent misses on one hot chunkset
collapse into exactly one SP fetch; shed requests debit nothing and settle
cleanly; admission keeps the p99 of *admitted* requests bounded under a 3x
saturation storm while the unadmitted fleet's p99 diverges; and the
determinism digest is unchanged by admission for sub-saturation workloads
(the controller only acts past the knee).
"""
import numpy as np
import pytest

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.backbone import Backbone
from repro.net.events import EventLoop, Join, SingleFlight, Sleep
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.net.scheduler import HedgedScheduler
from repro.net.workloads import ReadRequest, replay_open_loop, sweep_open_loop
from repro.storage.blob import BlobLayout
from repro.storage.rpc import AdmissionSpec, BackboneTransport, Overloaded, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import ServiceSpec, StorageProvider


def _world(num_sps=8, *, slots=4, service_ms=None, num_rpcs=1, cache=16,
           scheduler_kw=None, single_flight=True, admission=None, policy=None):
    """Small backbone world mirroring tests/test_events.py's helper."""
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract()
    bb = Backbone.mesh(3, base_latency_ms=4.0, gbps=10.0)
    sps = {}
    for i in range(num_sps):
        dc = f"dc{i % 3}"
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=dc, rack=f"r{i % 4}"))
        sps[i] = StorageProvider(
            i, service=ServiceSpec(disk_ms_per_chunk=service_ms, slots=slots)
        )
        bb.register_node(f"sp{i}", dc)
    specs = admission if isinstance(admission, list) else [admission] * num_rpcs
    rpcs = []
    for r in range(num_rpcs):
        node = f"rpc{r}"
        bb.register_node(node, f"dc{r % 3}")
        rpcs.append(
            RPCNode(node, contract, sps, layout, cache_chunksets=cache,
                    transport=BackboneTransport(sps, bb, node),
                    scheduler=HedgedScheduler(**(scheduler_kw or {})),
                    single_flight=single_flight, admission=specs[r])
        )
    bb.register_node("client", "dc0")
    fleet = RPCFleet(rpcs, policy or CacheAffinityPolicy(), backbone=bb)
    client = ShelbyClient(contract, fleet, deposit=1e9)
    return contract, bb, sps, fleet, client


class _AlwaysFirst:
    """Routing policy pinning every chunkset on node 0 (retry tests)."""

    def pick(self, key, client, fleet):
        return 0


# -- the SingleFlight primitive ----------------------------------------------------
def test_single_flight_leader_and_followers_share_one_task():
    loop = EventLoop()
    sf = SingleFlight(loop)
    runs = []

    def work():
        runs.append(loop.now)
        yield Sleep(10.0)
        return "payload"

    got = []

    def caller(name):
        h, leader = sf.flight("key", work)
        res = yield Join(h)
        got.append((name, leader, res, loop.now))

    for name in ("a", "b", "c"):
        loop.spawn(caller(name))
    loop.run()
    assert runs == [0.0]  # the work ran exactly once
    assert [g[1] for g in got] == [True, False, False]
    assert all(g[2] == "payload" and g[3] == 10.0 for g in got)
    assert sf.launched == 1 and sf.coalesced == 2
    # the key is released on completion: a later call starts a fresh flight
    loop2_calls = []

    def late():
        h, leader = sf.flight("key", work)
        loop2_calls.append(leader)
        yield Join(h)

    loop.spawn(late())
    loop.run()
    assert loop2_calls == [True] and sf.launched == 2


def test_single_flight_propagates_leader_error_to_all():
    loop = EventLoop()
    sf = SingleFlight(loop)
    errs = []

    def boom():
        yield Sleep(1.0)
        raise ValueError("fetch died")

    def caller(name):
        h, _ = sf.flight("k", boom)
        try:
            yield Join(h)
        except ValueError as e:
            errs.append((name, str(e)))

    loop.spawn(caller("a"))
    loop.spawn(caller("b"))
    loop.run()
    assert errs == [("a", "fetch died"), ("b", "fetch died")]
    assert not sf.live("k")  # released despite the error


# -- single-flight through the read path -------------------------------------------
def test_concurrent_same_chunkset_misses_fetch_once():
    """Five simultaneous requests for one chunkset -> exactly 1 SP fetch,
    4 coalesced waiters, and SP-side load of a single fetch."""
    contract, bb, sps, fleet, client = _world(num_sps=6, cache=16)
    rng = np.random.default_rng(0)
    meta = client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
    node = fleet.primary
    node._cache.clear()
    node.stats.chunks_requested = 0
    paid_before = node.stats.payments
    reqs = [ReadRequest(0.0, "client", meta.blob_id, 0, 1000) for _ in range(5)]
    receipts, result = client.replay(reqs)
    assert all(r.ok for r in result.records)
    assert node.stats.chunkset_fetches == 1  # ONE fetch hit the SPs
    assert node.stats.coalesced == 4
    assert node.stats.chunks_requested == 4  # k primaries, once
    # RPC->SP pay-on-delivery happened for one fetch, not five
    assert node.stats.payments - paid_before == pytest.approx(
        4 * node.price_per_chunk
    )
    assert sum(r.coalesced for r in receipts) == 4
    # every coalesced waiter still got verified bytes and paid the node
    assert all(len(r.data) == 1000 and r.total_paid > 0 for r in receipts)
    client.settle()


def test_coalesced_waiter_latency_is_residual():
    """A request arriving halfway through an in-flight fetch waits only
    the remaining half, not a full fetch."""
    contract, bb, sps, fleet, client = _world(num_sps=6, cache=0,
                                              service_ms=40.0, slots=1)
    rng = np.random.default_rng(1)
    meta = client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
    fleet.primary._cache.clear()
    reqs = [
        ReadRequest(0.0, "client", meta.blob_id, 0, 1000),
        ReadRequest(30.0, "client", meta.blob_id, 0, 1000),
    ]
    result = replay_open_loop(fleet, reqs)
    r0, r1 = result.records
    assert r0.ok and r1.ok
    assert fleet.coalesced() == 1
    # both finish when the shared fetch lands; the late joiner's latency is
    # the residual
    assert r1.latency_ms < r0.latency_ms
    assert r1.finish_ms == pytest.approx(r0.finish_ms)


# -- admission control / load shedding ---------------------------------------------
def test_shed_requests_debit_nothing_and_settle_cleanly():
    spec = AdmissionSpec(max_queued_requests=1)
    contract, bb, sps, fleet, client = _world(
        num_sps=6, slots=1, service_ms=20.0, cache=0,
        single_flight=False, admission=spec,
    )
    rng = np.random.default_rng(2)
    metas = [
        client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
        for _ in range(4)
    ]
    # simultaneous burst on distinct blobs: one admitted, the rest shed
    reqs = [ReadRequest(0.0, "client", m.blob_id, 0, 1000) for m in metas]
    receipts, result = client.replay(reqs)
    assert result.shed > 0 and result.shed == fleet.requests_shed()
    assert 0.0 < result.shed_rate <= 0.75
    served = [r for r in result.records if r.ok]
    shed = [r for r in result.records if r.shed]
    assert served and shed and len(served) + len(shed) == 4
    # shed requests: marked, empty, unpaid — and receipts document the NACK
    for rec in shed:
        assert not rec.ok and rec.nbytes == 0
        assert receipts[rec.index].shed
        assert receipts[rec.index].data == b""
        assert receipts[rec.index].total_paid == 0.0
    # settlement conserves: only served reads moved money
    settlement = client.settle()
    paid = sum(r.total_paid for r in client.current_session.receipts) \
        if client._session else None
    assert paid is None  # settle() cleared the implicit session
    served_paid = sum(
        receipts[r.index].total_paid for r in served
    )
    assert settlement.total_node_income == pytest.approx(served_paid, abs=1e-5)


def test_overloaded_is_a_typed_read_error():
    from repro.storage.rpc import ReadError

    err = Overloaded("rpc0", "queue")
    assert isinstance(err, ReadError)
    assert err.rpc_id == "rpc0" and err.reason == "queue"


def test_shed_leg_retries_on_sibling():
    """Node 0 always refuses; the fleet re-issues to node 1, the receipt
    names the rescuer, and payments follow the node that served."""
    contract, bb, sps, fleet, client = _world(
        num_rpcs=2, admission=[AdmissionSpec(max_queued_requests=0), None],
        policy=_AlwaysFirst(),
    )
    rng = np.random.default_rng(3)
    data = rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    receipt = client.read(meta.blob_id, 0, 2000, client="client")
    assert receipt.data == data[:2000]
    assert receipt.chunksets_by_node == {"rpc1": 1}  # rescuer served
    assert receipt.retried_nodes == {"rpc1": 1}
    assert receipt.payments.keys() == {"rpc1"}  # money follows the server
    assert fleet.shed_legs == 1 and fleet.retried_legs == 1
    assert fleet.retried_chunksets == 1
    assert fleet.rpcs[0].stats.shed_requests == 1
    client.settle()


def test_whole_fleet_overloaded_drops_request_as_shed():
    contract, bb, sps, fleet, client = _world(
        num_rpcs=2,
        admission=[AdmissionSpec(max_queued_requests=0)] * 2,
        policy=_AlwaysFirst(),
    )
    rng = np.random.default_rng(4)
    meta = client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
    with pytest.raises(Overloaded):
        client.read(meta.blob_id, 0, 1000, client="client")
    reqs = [ReadRequest(0.0, "client", meta.blob_id, 0, 1000)]
    receipts, result = client.replay(reqs)
    assert result.shed == 1 and not result.records[0].ok
    assert receipts[0].shed and receipts[0].total_paid == 0.0
    client.settle()


def test_admitted_p99_bounded_under_saturation_storm():
    """A 3x-saturation open-loop storm on single-slot SPs: without
    admission the queue grows without bound and p99 diverges; with a fetch
    budget, admitted requests keep a bounded p99 and the excess is shed."""
    rng = np.random.default_rng(5)
    data = [rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes()
            for _ in range(8)]

    def storm(admission):
        # service 20 ms on 1-slot SPs, k=4 legs/read across 6 SPs
        # -> capacity ~75 rps; offer ~225 rps (3x) for 60 requests
        contract, bb, sps, fleet, client = _world(
            num_sps=6, slots=1, service_ms=20.0, cache=0,
            single_flight=False, admission=admission,
        )
        metas = [client.put(d) for d in data]
        reqs = [
            ReadRequest(i * 4.5, "client", metas[i % len(metas)].blob_id, 0, 1000)
            for i in range(60)
        ]
        result = replay_open_loop(fleet, reqs)
        return fleet, result

    _, free = storm(None)
    assert free.shed == 0
    fleet, capped = storm(AdmissionSpec(max_inflight_fetches=4))
    assert capped.shed > 0
    assert all(r.ok or r.shed for r in capped.records)
    # the unadmitted tail diverges; the admitted tail stays bounded
    assert capped.percentile(99.0) * 2 < free.percentile(99.0), (
        f"admitted p99 {capped.percentile(99.0):.1f}ms not clearly below "
        f"unadmitted {free.percentile(99.0):.1f}ms"
    )
    # and admitted requests kept goodput flowing
    assert len(capped.latencies_ms()) >= 10


def test_sweep_open_loop_traces_the_saturation_knee():
    rng = np.random.default_rng(6)
    data = [rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes()
            for _ in range(6)]

    def make_fleet():
        contract, bb, sps, fleet, client = _world(
            num_sps=6, slots=1, service_ms=10.0, cache=0,
            single_flight=False, admission=AdmissionSpec(max_inflight_fetches=4),
        )
        make_fleet.metas = [client.put(d) for d in data]
        return fleet

    def make_requests(rate_rps):
        gap = 1e3 / rate_rps
        return [
            ReadRequest(i * gap, "client",
                        make_fleet.metas[i % len(data)].blob_id, 0, 1000)
            for i in range(40)
        ]

    sweep = sweep_open_loop(make_fleet, make_requests, [20.0, 400.0])
    assert sweep.shed_rate[0] == 0.0  # far below the knee: nothing shed
    assert sweep.shed_rate[1] > 0.0  # past it: the controller acts
    assert sweep.p99_ms()[1] < 10 * max(sweep.p99_ms()[0], 1.0)  # bounded tail
    assert len(sweep.goodput_mbps) == 2


def test_hedges_shed_first_at_the_fetch_budget():
    """With concurrent fetches at the budget, deadline fires are answered
    by suppression, not extra SP load."""

    def run(admission):
        # aggressive deadlines: they fire while all four fetches are still
        # holding the budget, so the gate (not completion luck) decides
        contract, bb, sps, fleet, client = _world(
            num_sps=6, slots=1, service_ms=25.0, cache=0, single_flight=False,
            scheduler_kw=dict(hedge=2, deadline_factor=0.3, min_deadline_ms=1.0),
            admission=admission,
        )
        rng = np.random.default_rng(7)
        metas = [client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
                 for _ in range(4)]
        reqs = [ReadRequest(i * 1.0, "client", metas[i].blob_id, 0, 1000)
                for i in range(4)]
        result = replay_open_loop(fleet, reqs)
        return fleet, result

    free_fleet, free = run(None)
    assert free_fleet.hedges_launched() > 0  # queues blow the deadline
    capped_fleet, capped = run(AdmissionSpec(max_inflight_fetches=4))
    assert capped_fleet.hedges_suppressed() > 0
    assert capped_fleet.hedges_launched() < free_fleet.hedges_launched()


def test_fetch_budget_holds_for_simultaneous_arrivals():
    """Flights count against the budget at SPAWN time: N requests landing
    in the same event step must not all slip under max_inflight_fetches
    before any flight task has stepped."""
    contract, bb, sps, fleet, client = _world(
        num_sps=6, slots=1, service_ms=20.0, cache=0, single_flight=False,
        admission=AdmissionSpec(max_inflight_fetches=1),
    )
    rng = np.random.default_rng(10)
    metas = [client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
             for _ in range(3)]
    # three distinct blobs, identical arrival time
    reqs = [ReadRequest(0.0, "client", m.blob_id, 0, 1000) for m in metas]
    result = replay_open_loop(fleet, reqs)
    assert sum(1 for r in result.records if r.ok) == 1
    assert result.shed == 2  # the budget saw the first flight immediately


def test_brownout_recovers_when_idle():
    """A latched EWMA above the SLO must not shed forever: an idle node
    admits the next request as a probe and re-measures."""
    contract, bb, sps, fleet, client = _world(
        num_sps=6, slots=1, service_ms=30.0, cache=0, single_flight=False,
        admission=AdmissionSpec(deadline_ms=1.0),  # SLO below any real fetch
    )
    rng = np.random.default_rng(11)
    meta = client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
    # first read seeds the EWMA far above the 1 ms SLO
    r1 = client.read(meta.blob_id, 0, 1000, client="client")
    assert fleet.primary._ewma_fetch_ms > 1.0
    # the node is idle again -> the next sequential read is admitted as a
    # probe instead of being shed on the stale estimate
    r2 = client.read(meta.blob_id, 0, 2000, client="client")
    assert len(r2.data) == 2000
    assert fleet.primary.stats.shed_requests == 0
    # but with work in flight the brownout DOES shed the concurrent burst
    reqs = [ReadRequest(0.0, "client", meta.blob_id, 0, 1000),
            ReadRequest(1.0, "client", meta.blob_id, 4000, 1000)]
    result = replay_open_loop(fleet, reqs)
    assert result.shed == 1
    client.settle()


def test_dropped_excludes_shed():
    contract, bb, sps, fleet, client = _world(
        admission=AdmissionSpec(max_queued_requests=0),
    )
    rng = np.random.default_rng(12)
    meta = client.put(rng.integers(0, 256, 60_000, dtype=np.uint8).tobytes())
    reqs = [ReadRequest(0.0, "client", meta.blob_id, 0, 1000)]
    receipts, result = client.replay(reqs)
    assert result.shed == 1 and result.dropped == 0  # refusals aren't drops


# -- determinism -------------------------------------------------------------------
def test_admission_leaves_sub_saturation_digest_unchanged():
    """Below the knee the controller must be a no-op: the digest of a
    gentle workload is byte-identical with and without an AdmissionSpec,
    and reproducible across runs."""

    def run_once(admission):
        contract, bb, sps, fleet, client = _world(num_sps=6, admission=admission)
        rng = np.random.default_rng(8)
        metas = [
            client.put(rng.integers(0, 256, 100_000, dtype=np.uint8).tobytes())
            for _ in range(3)
        ]
        bb.reset_accounting()
        from repro.net.workloads import zipf_hotset

        reqs = zipf_hotset(metas, clients=["client"], num_requests=30,
                           interarrival_ms=25.0, arrival="poisson", seed=9)
        receipts, result = client.replay(reqs)
        client.settle()
        return result

    generous = AdmissionSpec(max_queued_requests=10_000,
                             max_inflight_fetches=10_000, deadline_ms=1e9)
    a = run_once(None)
    b = run_once(generous)
    c = run_once(generous)
    assert a.shed == b.shed == 0
    assert a.digest() == b.digest() == c.digest()
