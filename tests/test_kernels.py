"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import gf
from repro.kernels import ops


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (2, 3, 64), (6, 10, 1000), (16, 18, 4096), (6, 16, 2049),
    (18, 18, 5000), (1, 18, 128), (8, 4, 3),
])
def test_gf_matmul_shapes(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = rng.integers(0, 256, (m, k), dtype=np.uint8)
    b = rng.integers(0, 256, (k, n), dtype=np.uint8)
    out = np.asarray(ops.gf_matmul(a, b))
    ref = np.asarray(ops.gf_matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(out, ref)
    np.testing.assert_array_equal(out, gf.matmul_np(a, b))


@pytest.mark.parametrize("block_n", [8, 128, 2048])
def test_gf_matmul_block_sizes(block_n):
    rng = np.random.default_rng(block_n)
    a = rng.integers(0, 256, (6, 10), dtype=np.uint8)
    b = rng.integers(0, 256, (10, 777), dtype=np.uint8)
    out = np.asarray(ops.gf_matmul(a, b, block_n=block_n))
    np.testing.assert_array_equal(out, gf.matmul_np(a, b))


@given(st.integers(1, 12), st.integers(1, 12), st.integers(1, 300), st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_gf_matmul_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, (m, k), dtype=np.uint8)
    b = rng.integers(0, 256, (k, n), dtype=np.uint8)
    np.testing.assert_array_equal(np.asarray(ops.gf_matmul(a, b)), gf.matmul_np(a, b))


def test_gf_matmul_linearity():
    """Kernel respects GF linearity: A(B1 ^ B2) = AB1 ^ AB2."""
    rng = np.random.default_rng(5)
    a = rng.integers(0, 256, (4, 6), dtype=np.uint8)
    b1 = rng.integers(0, 256, (6, 100), dtype=np.uint8)
    b2 = rng.integers(0, 256, (6, 100), dtype=np.uint8)
    lhs = np.asarray(ops.gf_matmul(a, b1 ^ b2))
    rhs = np.asarray(ops.gf_matmul(a, b1)) ^ np.asarray(ops.gf_matmul(a, b2))
    np.testing.assert_array_equal(lhs, rhs)


@pytest.mark.parametrize("leaves,words", [(1, 4), (7, 256), (300, 256), (1000, 16), (257, 64)])
def test_sample_hash_shapes(leaves, words):
    rng = np.random.default_rng(leaves * 7 + words)
    w = rng.integers(0, 2**32, (leaves, words), dtype=np.uint32)
    out = np.asarray(ops.sample_hash(jnp.asarray(w)))
    ref = np.asarray(ops.sample_hash_ref(jnp.asarray(w)))
    np.testing.assert_array_equal(out, ref)


def test_sample_hash_seed_sensitivity():
    w = np.zeros((10, 8), np.uint32)
    h0 = np.asarray(ops.sample_hash(jnp.asarray(w), seed=0))
    h1 = np.asarray(ops.sample_hash(jnp.asarray(w), seed=1))
    assert not np.array_equal(h0, h1)


def test_sample_hash_avalanche():
    """Flipping one input bit changes the digest (for every tested leaf)."""
    rng = np.random.default_rng(3)
    w = rng.integers(0, 2**32, (64, 32), dtype=np.uint32)
    base = np.asarray(ops.sample_hash(jnp.asarray(w)))
    w2 = w.copy()
    w2[:, 17] ^= 1
    flipped = np.asarray(ops.sample_hash(jnp.asarray(w2)))
    assert (base != flipped).all()


def test_kernel_backs_the_rs_data_path():
    """RS encode via the Pallas kernel == numpy GF path (integration)."""
    from repro.core.rs import MDSCode

    rng = np.random.default_rng(11)
    code = MDSCode(n=9, k=6)
    data = rng.integers(0, 256, (6, 5000), dtype=np.uint8)
    cw_np = code.encode(data)
    cw_kern = code.encode(data, matmul=ops.gf_matmul_np)
    np.testing.assert_array_equal(cw_np, cw_kern)


@pytest.mark.parametrize("b,sq,sk,h,hkv,hd,causal,blk", [
    (1, 64, 64, 2, 2, 16, True, 32),
    (2, 128, 128, 4, 2, 32, True, 64),
    (1, 96, 96, 3, 1, 8, False, 32),
    (2, 64, 64, 8, 8, 64, True, 16),
])
def test_flash_attention_kernel_vs_ref(b, sq, sk, h, hkv, hd, causal, blk):
    import jax

    rng = np.random.default_rng(b * 100 + sq + h)
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, sk, hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, sk, hkv, hd)).astype(np.float32))
    out = ops.flash_attention(q, k, v, causal=causal, bq=blk, bk=blk)
    ref = ops.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3, rtol=1e-2)


def test_flash_attention_kernel_dtype_sweep():
    rng = np.random.default_rng(0)
    for dt in (jnp.float32, jnp.bfloat16):
        q = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32), dt)
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32), dt)
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32), dt)
        out = ops.flash_attention(q, k, v, bq=32, bk=32)
        ref = ops.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                                   atol=3e-2, rtol=5e-2)
