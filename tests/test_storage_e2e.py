"""End-to-end storage behaviour: write/read lifecycle, failures, repair."""
import numpy as np
import pytest

from repro.core.contract import BlobState
from repro.storage.repair import RepairCoordinator, RepairError
from repro.storage.rpc import ReadError


def _blob(rng, n=200_000):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


def test_write_read_roundtrip(cluster, rng):
    contract, sps, rpc, client = cluster
    data = _blob(rng)
    meta = client.put(data)
    assert meta.state is BlobState.READY
    assert client.get(meta.blob_id) == data


def test_byte_range_reads(cluster, rng):
    contract, sps, rpc, client = cluster
    data = _blob(rng)
    meta = client.put(data)
    for off, ln in [(0, 1), (100, 50), (65_000, 70_000), (199_999, 1)]:
        assert client.get(meta.blob_id, off, ln) == data[off : off + ln]


def test_placement_spreads_failure_domains(cluster, rng):
    contract, sps, rpc, client = cluster
    meta = client.put(_blob(rng))
    for cs in range(meta.num_chunksets):
        assigned = [meta.placement[(cs, ck)] for ck in range(meta.n)]
        assert len(set(assigned)) == meta.n  # distinct SPs
        dcs = {contract.sps[s].dc for s in assigned}
        assert len(dcs) == 3  # all DCs used


def test_reads_survive_m_failures(cluster, rng):
    contract, sps, rpc, client = cluster
    data = _blob(rng)
    meta = client.put(data)
    victims = {meta.placement[(0, 0)], meta.placement[(0, 1)]}  # m = 2
    for v in victims:
        sps[v].crash()
    rpc._cache.clear()
    assert client.get(meta.blob_id) == data


def test_read_fails_beyond_m(cluster, rng):
    contract, sps, rpc, client = cluster
    meta = client.put(_blob(rng))
    for ck in range(3):  # m + 1 = 3 chunks of chunkset 0 gone
        sps[meta.placement[(0, ck)]].crash()
    rpc._cache.clear()
    with pytest.raises(ReadError):
        rpc.read_chunkset(meta.blob_id, 0)


def test_corruption_detected_and_tolerated(cluster, rng):
    contract, sps, rpc, client = cluster
    data = _blob(rng)
    meta = client.put(data)
    sps[meta.placement[(0, 0)]].behavior.corrupt = True
    rpc._cache.clear()
    assert client.get(meta.blob_id) == data
    assert rpc.stats.chunks_bad >= 1


def test_msr_repair_path(cluster, rng):
    contract, sps, rpc, client = cluster
    data = _blob(rng)
    meta = client.put(data)
    victim = meta.placement[(0, 0)]
    sps[victim].wipe()  # lost all its chunks, still alive
    rc = RepairCoordinator(contract, sps, rpc.layout)
    reports = rc.repair_all()
    assert reports and all(r.mode == "msr" and r.verified for r in reports)
    # MSR reads (n-1) * chunk/q instead of k * chunk
    lay = rpc.layout
    expect = (lay.n - 1) * lay.chunk_bytes // lay.code.q
    assert all(r.helper_bytes_read == expect for r in reports)
    assert not rc.scan_lost_chunks()
    rpc._cache.clear()
    assert client.get(meta.blob_id) == data


def test_mds_fallback_repair(cluster, rng):
    """Two losses in one chunkset: optimal pattern impossible -> MDS path."""
    contract, sps, rpc, client = cluster
    data = _blob(rng)
    meta = client.put(data)
    sps[meta.placement[(0, 0)]].wipe()
    sps[meta.placement[(0, 1)]].crash()
    rc = RepairCoordinator(contract, sps, rpc.layout)
    rep = rc.repair_chunk(meta.blob_id, 0, 0)
    assert rep.mode == "mds" and rep.verified


def test_repair_unrecoverable_raises(cluster, rng):
    contract, sps, rpc, client = cluster
    meta = client.put(_blob(rng))
    for ck in range(3):
        sps[meta.placement[(0, ck)]].crash()
    rc = RepairCoordinator(contract, sps, rpc.layout)
    with pytest.raises(RepairError):
        rc.repair_chunk(meta.blob_id, 0, 3)


def test_hedged_reads_prefer_fast_sps(cluster, rng):
    contract, sps, rpc, client = cluster
    data = _blob(rng)
    meta = client.put(data)
    slow = meta.placement[(0, 0)]
    sps[slow].behavior.latency_ms = 500.0
    rpc._cache.clear()
    before = sps[slow].earned_reads
    assert client.get(meta.blob_id) == data
    # the straggler got no traffic for chunkset 0 (sorted-by-latency hedging)
    assert sps[slow].earned_reads == before


def test_payments_flow_per_read(cluster, rng):
    contract, sps, rpc, client = cluster
    meta = client.put(_blob(rng))
    rpc._cache.clear()
    p0 = rpc.stats.payments
    client.get(meta.blob_id)
    assert rpc.stats.payments > p0
    assert sum(sp.earned_reads for sp in sps.values()) == pytest.approx(rpc.stats.payments)


def test_unknown_rpc_cannot_mark_ready(cluster, rng):
    contract, sps, rpc, client = cluster
    meta = client.put(_blob(rng))
    with pytest.raises(PermissionError):
        contract.mark_ready(meta.blob_id, "mallory")


def test_small_blob_zero_padding(cluster, rng):
    contract, sps, rpc, client = cluster
    data = b"tiny"
    meta = client.put(data)
    assert client.get(meta.blob_id) == data  # padding invisible to reader
