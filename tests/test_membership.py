"""Membership plane: churn, reconfiguration, re-dispersal (ISSUE 6).

Covers the tentpole — epoch-scale churn driven on the event loop, contract
reconfiguration remapping displaced chunks, and the queued re-dispersal
backlog draining under the background budget — plus the satellites: the
measured-durability monotonicity property, bit-exact decode after N churned
epochs, the stale-hot-cache/departed-SP payment regression, fleet expansion
on join, the scoreboard publication fee, and the analytic binomial tail.
"""
import numpy as np
import pytest

from repro.core import durability
from repro.core.audit import AuditParams, Challenge
from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo, replacement_sp
from repro.core.simulation import honest_population, run_sim
from repro.net.events import EventLoop
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.net.workloads import zipf_hotset
from repro.storage.blob import BlobLayout
from repro.storage.membership import ChurnSpec, MembershipPlane, measure_durability
from repro.storage.repair import RepairCoordinator
from repro.storage.rpc import ReadError, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import ServiceSpec, StorageProvider


def _world(*, num_sps=10, num_blobs=2, seed=0, blob_bytes=160_000,
           service_ms=2.0, num_rpcs=1):
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract()
    sps = {}
    for i in range(num_sps):
        contract.register_sp(
            SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 3}", rack=f"r{i % 2}")
        )
        sps[i] = StorageProvider(i, service=ServiceSpec(
            disk_ms_per_chunk=service_ms, slots=2))
    rpcs = [RPCNode(f"rpc{r}", contract, sps, layout, cache_chunksets=8)
            for r in range(num_rpcs)]
    fleet = RPCFleet(rpcs, CacheAffinityPolicy())
    client = ShelbyClient(contract, fleet, deposit=1e9)
    rng = np.random.default_rng(seed)
    datas = [rng.integers(0, 256, blob_bytes, dtype=np.uint8).tobytes()
             for _ in range(num_blobs)]
    metas = [client.put(d) for d in datas]
    return layout, contract, sps, fleet, client, metas, datas


def _run_plane(contract, sps, layout, spec, *, repair=True, fleet=None,
               epochs=2, epoch_ms=100.0):
    rc = RepairCoordinator(contract, sps, layout) if repair else None
    plane = MembershipPlane(contract, sps, layout, spec, repair=rc,
                            fleet=fleet, epochs=epochs, epoch_ms=epoch_ms)
    loop = EventLoop()
    for p in plane.planes():
        p.spawn(loop)
    loop.run()
    return plane


# ---------------------------------------------------------------------------
# analytic tail + measured durability series (core/durability.py)
# ---------------------------------------------------------------------------
def test_analytic_chunkset_loss_tail():
    # closed form for n=2, k=1: lost only when BOTH chunks fail -> p^2
    assert durability.p_chunkset_loss_per_epoch(2, 1, 0.3) == pytest.approx(0.09)
    assert durability.p_chunkset_loss_per_epoch(6, 4, 0.0) == 0.0
    assert durability.p_chunkset_loss_per_epoch(6, 4, 1.0) == pytest.approx(1.0)
    ps = [durability.p_chunkset_loss_per_epoch(6, 4, p)
          for p in (0.0, 0.1, 0.3, 0.5, 0.9)]
    assert all(a <= b + 1e-15 for a, b in zip(ps, ps[1:]))
    with pytest.raises(ValueError):
        durability.p_chunkset_loss_per_epoch(6, 4, 1.5)


def test_measured_loss_monotone_in_churn_rate():
    """Per-seed coupling: a higher crash rate fails a superset of SPs, so
    the MEASURED loss probability is monotone in the churn rate."""
    pts = measure_durability((0.0, 0.2, 0.4, 0.6), seeds=(0, 1, 2),
                             epochs=2, repair=False)
    probs = [p.loss_probability for p in pts]
    assert probs[0] == 0.0
    assert probs[-1] > 0.0
    assert all(a <= b + 1e-12 for a, b in zip(probs, probs[1:])), probs
    series = durability.measured_loss_series(pts)
    assert series["churn_rates"] == [0.0, 0.2, 0.4, 0.6]
    assert series["loss_probability"] == probs


def test_repair_never_hurts_durability():
    rates = (0.2, 0.35, 0.5)
    no_rep = measure_durability(rates, seeds=(0, 1), epochs=2, repair=False)
    rep = measure_durability(rates, seeds=(0, 1), epochs=2, repair=True)
    for a, b in zip(rep, no_rep):
        assert a.loss_probability <= b.loss_probability + 1e-12


# ---------------------------------------------------------------------------
# contract reconfiguration (core/contract.py + core/placement.py)
# ---------------------------------------------------------------------------
def test_replacement_sp_prefers_unused_failure_domains():
    holders = [SPInfo(sp_id=i, stake=1.0, dc="dc0", rack=f"r{i}")
               for i in range(4)]
    candidates = [
        SPInfo(sp_id=10, stake=1.0, dc="dc0", rack="r9"),  # loaded dc
        SPInfo(sp_id=11, stake=1.0, dc="dc1", rack="r0"),  # empty dc
    ]
    for ck in range(8):  # any rng draw: the empty dc must win
        assert replacement_sp(b"s", 0, 0, ck, candidates, holders) == 11
    assert replacement_sp(b"s", 0, 0, 0, [], holders) is None


def test_reconfigure_remaps_departed_sps_and_bumps_versions():
    layout, contract, sps, fleet, client, metas, _ = _world()
    victim = next(iter(contract.blobs[metas[0].blob_id].placement.values()))
    contract.announce_departure(victim)
    assert victim in contract.departing
    contract.finalize_departure(victim)
    assert victim in contract.dead_sps()
    assert all(s.sp_id != victim for s in contract.active_sps())

    displaced = {
        (b, cs, ck)
        for b, meta in contract.blobs.items()
        for (cs, ck), sp in meta.placement.items() if sp == victim
    }
    assert displaced
    v0 = dict(contract.placement_version)
    moves = contract.reconfigure_epoch(0)
    assert {(m.blob_id, m.chunkset, m.chunk) for m in moves} == displaced
    for m in moves:
        assert m.old_sp == victim
        holders = {
            sp for (cs, _), sp in
            contract.blobs[m.blob_id].placement.items() if cs == m.chunkset
        }
        assert m.new_sp != victim and m.new_sp in holders  # now placed there
        assert contract.blobs[m.blob_id].placement[(m.chunkset, m.chunk)] == m.new_sp
        key = (m.blob_id, m.chunkset)
        assert contract.placement_version[key] > v0.get(key, 0)
    # nothing anywhere still points at the departed SP
    for meta in contract.blobs.values():
        assert victim not in set(meta.placement.values())
    # each chunkset still spreads over distinct SPs
    for meta in contract.blobs.values():
        for cs in range(meta.num_chunksets):
            owners = [sp for (c, _), sp in meta.placement.items() if c == cs]
            assert len(set(owners)) == len(owners)


def test_slash_burns_stake_and_ejects():
    _, contract, sps, _, _, _, _ = _world()
    treasury0 = contract.treasury
    stake = contract.stakes[3]
    assert contract.slash(3, stake + 1.0)  # full-stake slash ejects
    assert 3 in contract.ejected and 3 in contract.dead_sps()
    assert contract.treasury == pytest.approx(treasury0 + stake)


# ---------------------------------------------------------------------------
# the membership plane end to end (storage/membership.py)
# ---------------------------------------------------------------------------
def test_backlog_drains_and_heals_after_departure():
    layout, contract, sps, fleet, client, metas, datas = _world()
    plane = _run_plane(
        contract, sps, layout,
        ChurnSpec(scripted=((0, "announce", 2, 0.3), (0, "crash", 5, 0.5))),
        epochs=1,
    )
    assert plane.lost_chunksets == 0
    assert {2, 5} <= contract.dead_sps()
    assert plane.repair.enqueued_total > 0
    assert plane.repair.backlog() == 0 and not plane.repair.failures
    # healed: every placement entry is a live SP actually holding its chunk
    for blob_id, meta in contract.blobs.items():
        for (cs, ck), sp_id in meta.placement.items():
            assert sp_id not in contract.dead_sps()
            assert sps[sp_id].has_chunk(blob_id, cs, ck)
    # the drain was measured on the simulated clock
    st = plane.epoch_stats[0]
    assert st.enqueued == plane.repair.enqueued_total
    assert st.drain_ms() > 0.0
    # graceful leaver was decommissioned only AFTER the boundary
    assert sps[2].behavior.crashed
    leave = [e for e in plane.events if e.kind == "leave"]
    assert leave and leave[0].t_ms == pytest.approx(100.0)


def test_backlog_enqueues_most_fragile_chunksets_first():
    """Re-dispersal drains in recovery-priority order: a chunkset sitting
    closer to k live holders launches before a comfortable one."""
    layout, contract, sps, fleet, client, metas, _ = _world(num_blobs=1)
    b0 = metas[0].blob_id
    meta = contract.blobs[b0]
    assert meta.num_chunksets >= 2
    rc = RepairCoordinator(contract, sps, layout)
    full = rc.live_holders(b0, 0)
    assert full == meta.n
    # degrade chunkset 1 harder than chunkset 0 by dropping stored bytes
    sps[meta.placement[(0, 0)]]._chunks.pop((b0, 0, 0))
    for ck in range(3):
        sps[meta.placement[(1, ck)]]._chunks.pop((b0, 1, ck))
    assert rc.live_holders(b0, 0) == meta.n - 1
    assert rc.live_holders(b0, 1) == meta.n - 3
    items = [(b0, 0, 0), (b0, 1, 0), (b0, 1, 1), (b0, 1, 2)]
    ordered = rc.risk_order(list(reversed(items)))
    # all of fragile chunkset 1 first (ties break on chunk id), then cs 0
    assert ordered == [(b0, 1, 0), (b0, 1, 1), (b0, 1, 2), (b0, 0, 0)]


def test_join_expands_contract_and_fleet():
    layout, contract, sps, fleet, client, metas, _ = _world(num_sps=8)
    plane = _run_plane(contract, sps, layout,
                       ChurnSpec(joins_per_epoch=2), fleet=fleet, epochs=1)
    assert len(plane.joined) == 2
    for sp_id in plane.joined:
        assert sp_id in contract.sps and sp_id in sps
        for rpc in fleet.rpcs:
            assert sp_id in rpc.sps
            assert str(sp_id) in rpc.ledger.channels  # can be paid
    # a subsequent write can place onto the expanded fleet
    data = np.random.default_rng(9).integers(
        0, 256, 160_000, dtype=np.uint8).tobytes()
    meta = client.put(data)
    assert client.get(meta.blob_id) == data


def test_min_active_floor_caps_removals():
    layout, contract, sps, fleet, client, metas, _ = _world()
    plane = _run_plane(contract, sps, layout,
                       ChurnSpec(p_crash=1.0, min_active=7, seed=1), epochs=3)
    alive = [i for i in sps if not sps[i].behavior.crashed]
    assert len(alive) == 7  # p_crash=1 would kill everyone without the floor
    assert plane.lost_chunksets == 0  # 3 removals < m per epoch, repaired


def test_nepoch_tolerable_churn_decodes_bit_exact():
    for seed in (0, 1):
        layout, contract, sps, fleet, client, metas, datas = _world(seed=seed)
        plane = _run_plane(contract, sps, layout,
                           ChurnSpec(p_crash=0.08, seed=seed, min_active=6),
                           epochs=3)
        assert plane.lost_chunksets == 0
        for meta, data in zip(metas, datas):
            assert client.get(meta.blob_id) == data, f"seed={seed}"


def test_heavy_churn_losses_match_census_and_raise_on_read():
    lost_total = 0
    for seed in (0, 1, 2):
        layout, contract, sps, fleet, client, metas, datas = _world(seed=seed)
        plane = _run_plane(contract, sps, layout,
                           ChurnSpec(p_crash=0.45, seed=seed), epochs=3)
        lost_total += plane.lost_chunksets
        for meta, data in zip(metas, datas):
            csb = layout.chunkset_bytes
            for cs in range(meta.num_chunksets):
                lo = cs * csb
                hi = min(meta.size_bytes, lo + csb)
                if (meta.blob_id, cs) in plane.lost:
                    with pytest.raises(ReadError):
                        client.get(meta.blob_id, lo, hi - lo)
                else:  # surviving chunksets decode bit-exact mid-carnage
                    assert client.get(meta.blob_id, lo, hi - lo) == data[lo:hi]
    assert lost_total > 0  # beyond the redundancy budget: losses measured


def test_churn_events_ride_the_determinism_digest():
    def one_run():
        layout, contract, sps, fleet, client, metas, _ = _world(num_rpcs=2)
        rc = RepairCoordinator(contract, sps, layout)
        plane = MembershipPlane(
            contract, sps, layout,
            ChurnSpec(p_crash=0.1, p_leave=0.1, joins_per_epoch=1, seed=4),
            repair=rc, fleet=fleet, epochs=2, epoch_ms=60.0,
        )
        reqs = zipf_hotset(metas, clients=["u"], num_requests=40,
                           interarrival_ms=3.0, seed=8, arrival="poisson")
        with client.session() as session:
            _, result = session.replay(reqs, background=plane.planes())
        return plane, result

    pa, ra = one_run()
    pb, rb = one_run()
    assert ra.membership_events > 0
    assert ra.digest() == rb.digest()
    assert [(e.kind, e.epoch, e.sp_id) for e in pa.events] == \
        [(e.kind, e.epoch, e.sp_id) for e in pb.events]
    # a DIFFERENT churn seed must change the digest (events are hashed)
    def other():
        layout, contract, sps, fleet, client, metas, _ = _world(num_rpcs=2)
        rc = RepairCoordinator(contract, sps, layout)
        plane = MembershipPlane(
            contract, sps, layout, ChurnSpec(p_crash=0.1, seed=5),
            repair=rc, fleet=fleet, epochs=2, epoch_ms=60.0,
        )
        reqs = zipf_hotset(metas, clients=["u"], num_requests=40,
                           interarrival_ms=3.0, seed=8, arrival="poisson")
        with client.session() as session:
            _, result = session.replay(reqs, background=plane.planes())
        return result

    assert other().digest() != ra.digest()


# ---------------------------------------------------------------------------
# satellite: stale hot cache + departed SPs are never paid (storage/rpc.py)
# ---------------------------------------------------------------------------
def test_post_reassignment_read_refetches_and_never_pays_departed_sp():
    layout, contract, sps, fleet, client, metas, datas = _world(num_blobs=1)
    meta, data = metas[0], datas[0]
    assert client.get(meta.blob_id) == data  # warms every RPC hot cache

    victim = contract.blobs[meta.blob_id].placement[(0, 0)]
    contract.announce_departure(victim)
    contract.finalize_departure(victim)
    moves = contract.reconfigure_epoch(0)
    assert moves  # placement changed -> cached decodes are now stale
    rc = RepairCoordinator(contract, sps, layout)
    rc.repair_all()
    assert not rc.failures
    sps[victim].decommission()

    before = {i: sp.earned_reads for i, sp in sps.items()}
    assert client.get(meta.blob_id) == data
    # the version check evicted the stale entries: the read REFETCHED
    # (someone alive was paid) and the departed SP earned nothing
    assert sps[victim].earned_reads == before[victim]
    paid_delta = sum(sp.earned_reads - before[i] for i, sp in sps.items())
    assert paid_delta > 0


def test_cache_version_check_only_invalidates_remapped_chunksets():
    layout, contract, sps, fleet, client, metas, datas = _world(num_blobs=2)
    assert client.get(metas[0].blob_id) == datas[0]
    assert client.get(metas[1].blob_id) == datas[1]
    stats = fleet.rpcs[0].stats
    hits0, fetches0 = stats.cache_hits, stats.chunkset_fetches
    # surgically remap ONE chunkset of blob 0 (bumps only its version)
    b0 = metas[0].blob_id
    contract.reassign_chunk(b0, 0, 0)
    RepairCoordinator(contract, sps, layout).repair_all()
    # untouched chunksets still serve from the hot cache …
    assert client.get(metas[1].blob_id) == datas[1]
    assert stats.cache_hits > hits0
    assert stats.chunkset_fetches == fetches0
    # … while the remapped chunkset's stale entry was evicted: refetch
    csb = layout.chunkset_bytes
    assert client.get(b0, 0, csb) == datas[0][:csb]
    assert stats.chunkset_fetches == fetches0 + 1


# ---------------------------------------------------------------------------
# satellite: scoreboard publication gas (core/audit.py + contract.close_epoch)
# ---------------------------------------------------------------------------
def test_scoreboard_publication_fee_debits_auditors():
    gas = 1e-3
    params = AuditParams(p_a=1.0, auditors_per_audit=3, C=10,
                         gas_per_scoreboard_byte=gas)
    layout, contract_, sps, fleet, client, metas, _ = _world()
    contract = ShelbyContract(params)
    # rebuild the world against the fee-carrying contract
    sps = {}
    for i in range(8):
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 3}"))
        sps[i] = StorageProvider(i)
    writer = RPCNode("w", contract, sps, layout)
    wclient = ShelbyClient(contract, writer, deposit=1e9)
    rng = np.random.default_rng(0)
    wclient.put(rng.integers(0, 256, 160_000, dtype=np.uint8).tobytes())

    for ch in contract.internal_challenges(0):
        proof = sps[ch.auditee].respond_challenge(ch)
        for a in ch.auditors:
            sps[a].audit_peer(ch, proof, contract)
    for i, sp in sps.items():
        contract.submit_scoreboard(0, sp.scoreboard)
    expected = {
        i: sp.scoreboard.packed()[1] * gas
        for i, sp in sps.items() if sp.scoreboard.bits
    }
    bal0 = {i: contract.balances[i] for i in sps}
    treasury0 = contract.treasury

    def respond_storage(sp, blob, cs, ck, sidx):
        pr = sps[sp].respond_challenge(Challenge(0, sp, blob, cs, ck, sidx, ()))
        return (pr.sample, pr.proof) if pr else None

    out = contract.close_epoch(
        0, respond_storage,
        lambda auditor, auditee, pos: sps[auditor].reproduce_proof(auditee, pos),
    )
    assert out.publish_costs and out.publish_costs == pytest.approx(expected)
    for i, cost in out.publish_costs.items():
        credited = (out.storage_rewards.get(i, 0.0)
                    + out.auditor_rewards.get(i, 0.0))
        assert contract.balances[i] == pytest.approx(bal0[i] + credited - cost)
        assert out.utility(i) == pytest.approx(
            credited - out.slashed.get(i, 0.0) - cost)
    assert contract.treasury == pytest.approx(
        treasury0 + sum(out.publish_costs.values()))


# ---------------------------------------------------------------------------
# run_sim integration (core/simulation.py)
# ---------------------------------------------------------------------------
def test_run_sim_with_churn_accounts_membership():
    res = run_sim(
        honest_population(10), epochs=3, num_blobs=3, blob_bytes=100_000,
        read_requests_per_epoch=30,
        churn=ChurnSpec(p_crash=0.05, p_leave=0.05, joins_per_epoch=1,
                        seed=3, min_active=6),
    )
    assert res.membership_events > 0
    assert res.sps_joined == 3  # one per epoch
    assert res.sps_departed > 0
    assert res.chunksets_lost == 0  # floor keeps churn tolerable
    assert res.repairs_enqueued > 0
    assert res.repairs_completed == res.repairs_enqueued
    # joiners carry utility entries (stake/levies accounted per epoch)
    assert all(i in res.utilities for i in range(10))


def test_run_sim_without_churn_is_quiet():
    res = run_sim(honest_population(6), epochs=2, num_blobs=2)
    assert res.membership_events == 0
    assert res.sps_joined == res.sps_departed == 0
    assert res.chunksets_lost == 0 and res.repairs_enqueued == 0
