"""Auditor proof retention (§4.2): positions must match scoreboard bits.

Regression for the `_retain` position bug: the retained-proof key must be
the index of the just-recorded entry in the auditor's scoreboard bit
vector for that auditee — the exact coordinate audit-the-auditor samples
from `Scoreboard.ones()` — even when the auditee's history mixes passed
and failed audits (failures occupy a bit position but retain no proof).
"""
import numpy as np

from repro.core.audit import Challenge
from repro.core.commitments import chunk_samples


def _challenge(epoch, auditee, meta, chunkset, chunk, sample, auditors):
    return Challenge(epoch, auditee, meta.blob_id, chunkset, chunk, sample,
                     tuple(auditors))


def test_retained_positions_follow_scoreboard_bits(cluster, small_layout, rng):
    contract, sps, rpc, client = cluster
    meta = client.put(rng.integers(0, 256, 150_000, dtype=np.uint8).tobytes())
    auditee = meta.placement[(0, 0)]
    auditor_id = next(i for i in sps if i != auditee)
    auditor = sps[auditor_id]

    # audit #0: valid proof -> bit 0 is a '1', proof retained at position 0
    ch0 = _challenge(0, auditee, meta, 0, 0, 3, [auditor_id])
    auditor.audit_peer(ch0, sps[auditee].respond_challenge(ch0), contract)

    # audit #1: no proof arrives -> bit 1 is a '0', nothing retained
    ch1 = _challenge(0, auditee, meta, 0, 0, 5, [auditor_id])
    auditor.audit_peer(ch1, None, contract)

    # audit #2: valid proof again -> bit 2 is a '1', retained at position 2
    ch2 = _challenge(0, auditee, meta, 0, 0, 7, [auditor_id])
    auditor.audit_peer(ch2, sps[auditee].respond_challenge(ch2), contract)

    assert auditor.scoreboard.bits[auditee] == [1, 0, 1]
    assert auditor.scoreboard.ones() == [(auditee, 0), (auditee, 2)]
    # audit-the-auditor reproduces proofs at exactly the '1' positions …
    for pos in (0, 2):
        resp = auditor.reproduce_proof(auditee, pos)
        assert resp is not None
        blob, cs, ck, sample, proof = resp
        assert contract.verify_possession_proof(blob, cs, ck, sample, proof)
    # … and has nothing at the failed position (a lazy auditor faking a
    # retained proof there would be slashed)
    assert auditor.reproduce_proof(auditee, 1) is None


def test_retained_proof_matches_the_sampled_index(cluster, small_layout, rng):
    """The retained sample is the one the challenge asked for, so an ATA
    re-verification against on-chain roots succeeds for the honest auditor."""
    contract, sps, rpc, client = cluster
    meta = client.put(rng.integers(0, 256, 80_000, dtype=np.uint8).tobytes())
    auditee = meta.placement[(0, 1)]
    auditor_id = next(i for i in sps if i != auditee)
    auditor = sps[auditor_id]
    for k, sample in enumerate([2, 9, 4]):
        ch = _challenge(0, auditee, meta, 0, 1, sample, [auditor_id])
        proof = sps[auditee].respond_challenge(ch)
        auditor.audit_peer(ch, proof, contract)
        got = auditor.reproduce_proof(auditee, k)
        assert got is not None
        chunk_data = sps[auditee]._chunks[(meta.blob_id, 0, 1)]
        expected_idx = sample % len(chunk_samples(chunk_data))
        assert got[4].index == expected_idx
