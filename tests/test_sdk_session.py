"""Fleet-first client sessions: pay-on-delivery, streaming reads, per-node
settlement, and conservation (§2.2 / §3.2 "reads are paid")."""
import numpy as np
import pytest

from repro.core.contract import ShelbyContract
from repro.core.payments import ChannelError
from repro.core.placement import SPInfo
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.storage.rpc import ReadError, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider


@pytest.fixture
def fleet_cluster(small_layout):
    """(contract, sps, fleet, client) — 3 RPC nodes over 8 SPs."""
    contract = ShelbyContract()
    sps = {}
    for i in range(8):
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 3}", rack=f"r{i % 4}"))
        sps[i] = StorageProvider(i)
    rpcs = [
        RPCNode(f"rpc{r}", contract, sps, small_layout, cache_chunksets=16)
        for r in range(3)
    ]
    fleet = RPCFleet(rpcs, CacheAffinityPolicy())
    client = ShelbyClient(contract, fleet, deposit=1e6)
    return contract, sps, fleet, client


def _blob(rng, n=300_000):
    return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


# -- pay on delivery ---------------------------------------------------------------
def test_failed_read_never_debits_the_channel(fleet_cluster, rng):
    """Regression: `get` used to pay BEFORE the read, charging the client
    for ReadErrors."""
    contract, sps, fleet, client = fleet_cluster
    meta = client.put(_blob(rng))
    for ck in range(3):  # m + 1 = 3 chunks of chunkset 0 gone
        sps[meta.placement[(0, ck)]].crash()
    for rpc in fleet.rpcs:
        rpc._cache.clear()
    session = client.current_session
    paid_before = session.total_paid
    with pytest.raises(ReadError):
        client.get(meta.blob_id)
    assert session.total_paid == paid_before
    assert not session.receipts  # no receipt for a failed read


def test_successful_read_pays_and_receipts(fleet_cluster, rng):
    contract, sps, fleet, client = fleet_cluster
    data = _blob(rng)
    meta = client.put(data)
    receipt = client.read(meta.blob_id)
    assert receipt.data == data
    assert receipt.total_paid > 0
    assert receipt.payments  # at least one serving node got paid
    assert set(receipt.payments) <= set(fleet.node_ids)
    assert sum(receipt.chunksets_by_node.values()) == meta.num_chunksets


def test_channels_open_lazily_per_serving_node(fleet_cluster, rng):
    contract, sps, fleet, client = fleet_cluster
    data = _blob(rng)
    meta = client.put(data)
    session = client.current_session
    assert not session.channels  # nothing read yet -> no channels
    receipt = session.read(meta.blob_id)
    assert set(session.channels) == set(receipt.payments)


# -- settlement conservation -------------------------------------------------------
def test_settlement_conservation_multi_node(fleet_cluster, rng):
    contract, sps, fleet, client = fleet_cluster
    metas = [client.put(_blob(rng)) for _ in range(3)]
    with client.session() as session:
        for meta in metas:
            session.read(meta.blob_id)
            session.read(meta.blob_id, 1000, 50_000)
    s = session.settlement
    assert s is not None
    # every serving node settled; refund + income == deposit, per channel
    for rpc_id, dep in s.deposits.items():
        assert s.client_refunds[rpc_id] + s.node_income[rpc_id] == pytest.approx(dep)
    assert s.total_refunded + s.total_node_income == pytest.approx(s.total_deposited)
    # per-node settlement totals match the ReadReceipt payment sums
    paid = {}
    for r in session.receipts:
        for rpc_id, amt in r.payments.items():
            paid[rpc_id] = paid.get(rpc_id, 0.0) + amt
    assert set(paid) == set(s.node_income)
    for rpc_id in paid:
        assert s.node_income[rpc_id] == pytest.approx(paid[rpc_id], abs=1e-6)
    # the RPC->SP cascade realized every accrued micropayment
    assert sum(s.sp_income.values()) == pytest.approx(
        sum(sp.settled_income for sp in sps.values())
    )
    assert sum(s.sp_income.values()) > 0


def test_stale_refund_rejected_at_settlement(fleet_cluster, rng):
    contract, sps, fleet, client = fleet_cluster
    meta = client.put(_blob(rng))
    session = client.session()
    session.read(meta.blob_id, 0, 1000)
    rpc_id, channel = next(iter(session.channels.items()))
    stale = channel.latest_refund
    session.read(meta.blob_id, 1000, 200_000)  # fresher refunds signed
    assert channel.latest_refund.seq > stale.seq
    # an uncooperative party broadcasting the stale refund on the OPEN
    # channel is preempted by the fresher one (§3.2 seq check)...
    with pytest.raises(ChannelError, match="stale"):
        channel.settle(stale)
    # ...which leaves the channel un-settled, so the honest close succeeds
    s = session.close()
    assert s.node_income[rpc_id] == pytest.approx(channel.paid)
    # and after settlement ANY further broadcast (stale or not) is rejected
    with pytest.raises(ChannelError):
        channel.settle(stale)


def test_reads_after_close_rejected_and_close_idempotent(fleet_cluster, rng):
    contract, sps, fleet, client = fleet_cluster
    meta = client.put(_blob(rng))
    session = client.session()
    session.read(meta.blob_id)
    first = session.close()
    assert session.close() is first
    with pytest.raises(ChannelError):
        session.read(meta.blob_id)


def test_sp_income_flows_only_at_settlement(fleet_cluster, rng):
    contract, sps, fleet, client = fleet_cluster
    meta = client.put(_blob(rng))
    session = client.session()
    session.read(meta.blob_id)
    assert all(sp.settled_income == 0.0 for sp in sps.values())
    accrued = sum(sp.earned_reads for sp in sps.values())
    assert accrued > 0  # micropayments accrued on delivery...
    s = session.close()
    # ...and realized exactly at settlement
    assert sum(s.sp_income.values()) == pytest.approx(accrued)


# -- streaming ---------------------------------------------------------------------
def test_blob_reader_is_seekable_file_like(fleet_cluster, rng):
    contract, sps, fleet, client = fleet_cluster
    data = _blob(rng)
    meta = client.put(data)
    with client.open(meta.blob_id) as f:
        assert f.readable() and f.seekable()
        assert f.read(100) == data[:100]
        assert f.tell() == 100
        f.seek(50_000)
        assert f.read(64) == data[50_000:50_064]
        f.seek(-100, 2)
        assert f.read() == data[-100:]
        assert f.read() == b""  # EOF
        f.seek(10, 1)  # relative seek past EOF is fine; reads return b""
        assert f.read(5) == b""
        with pytest.raises(ValueError):
            f.seek(0, 3)  # invalid whence, file-like contract
    with pytest.raises(ValueError):
        f.read(1)  # closed


def test_stream_yields_receipts_covering_the_blob(fleet_cluster, rng):
    contract, sps, fleet, client = fleet_cluster
    data = _blob(rng)
    meta = client.put(data)
    receipts = list(client.stream(meta.blob_id, chunk_size=70_000))
    assert b"".join(r.data for r in receipts) == data
    assert all(len(r.data) <= 70_000 for r in receipts)
    offsets = [r.offset for r in receipts]
    assert offsets == sorted(offsets)  # sequential


# -- batched reads -----------------------------------------------------------------
def test_get_many_routes_all_ranges_in_one_pass(fleet_cluster, rng):
    contract, sps, fleet, client = fleet_cluster
    d1, d2 = _blob(rng), _blob(rng, 150_000)
    m1, m2 = client.put(d1), client.put(d2)
    reads_before = fleet.chunkset_reads
    receipts = client.get_many(
        [(m1.blob_id, 0, 1000), (m1.blob_id, 100_000, None), (m2.blob_id, 0, None)]
    )
    assert receipts[0].data == d1[:1000]
    assert receipts[1].data == d1[100_000:]
    assert receipts[2].data == d2
    # chunksets shared between ranges are routed (and fetched) only once
    unique = set()
    lay = client.layout
    for bid, off, ln in [(m1.blob_id, 0, 1000), (m1.blob_id, 100_000, len(d1) - 100_000),
                         (m2.blob_id, 0, len(d2))]:
        first, last = lay.byte_range_to_chunksets(off, ln)
        unique |= {(bid, cs) for cs in range(first, last + 1)}
    assert fleet.chunkset_reads - reads_before == len(unique)


def test_single_node_client_is_a_fleet_of_one(cluster, rng):
    contract, sps, rpc, client = cluster
    data = _blob(rng)
    meta = client.put(data)
    assert client.fleet.node_ids == [rpc.rpc_id]
    receipt = client.read(meta.blob_id)
    assert receipt.data == data
    assert list(receipt.payments) == [rpc.rpc_id]
    s = client.settle()
    assert s.node_income[rpc.rpc_id] == pytest.approx(receipt.total_paid, abs=1e-6)
    assert rpc.serving_income == pytest.approx(s.node_income[rpc.rpc_id])


# -- simulation wiring -------------------------------------------------------------
def test_run_sim_credits_sps_through_settled_channels():
    from repro.core.simulation import honest_population, run_sim

    res = run_sim(
        honest_population(8), epochs=1, num_blobs=2, blob_bytes=100_000,
        num_rpcs=3, read_requests_per_epoch=12,
    )
    assert res.bytes_served > 0
    assert sum(res.sp_serving_income.values()) > 0
    assert res.client_read_payments > 0
    # per-node settlement totals == what the client's receipts paid
    assert sum(res.rpc_serving_income.values()) == pytest.approx(
        res.client_read_payments, abs=1e-5
    )


def test_decode_matmul_config_resolution():
    import jax

    from repro.configs.shelby import CONFIG, resolve_decode_matmul
    from repro.kernels import ops

    assert resolve_decode_matmul("numpy") is None
    assert resolve_decode_matmul("pallas") is ops.gf_matmul_np
    auto = resolve_decode_matmul("auto")
    if jax.default_backend() == "tpu":
        assert auto is ops.gf_matmul_np
    else:
        assert auto is None  # defaults to the numpy GF path on CPU
    assert CONFIG.resolve_decode_matmul() is auto
    with pytest.raises(ValueError):
        resolve_decode_matmul("cuda")
