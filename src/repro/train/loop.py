"""Fault-tolerant training loop over the Shelby storage plane.

Production behaviors implemented (and exercised by tests/examples):

* **Coded checkpointing** — every ``ckpt_every`` steps the full train state
  is serialized, Clay-encoded, Merkle-committed and dispersed to SPs via
  the Shelby client (storage/checkpoint.py).
* **Restart** — ``restore_latest`` reconstructs state from any k-of-n
  chunks per chunkset; SP failures mid-restore are absorbed by hedged
  reads; corrupted chunks are detected by commitment mismatch and excluded.
* **Elastic resume** — the restored (host-agnostic) state is re-sharded by
  the new jit'd step function, so a restart may use a different mesh.
* **Straggler mitigation** — the data pipeline issues hedged k-of-n reads,
  so a slow SP cannot stall input.
* **In-loop repair** — when the loop detects lost chunks (via the repair
  coordinator's scan), it triggers MSR repair in the background of the
  step cadence.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.sharding import AxisCtx
from repro.storage.checkpoint import CheckpointManager
from repro.storage.repair import RepairCoordinator, RepairError
from repro.train import optimizer as opt_mod
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_loss: float
    losses: list[float]
    restarts: int
    repairs: int
    wall_s: float


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        *,
        ctx: AxisCtx | None = None,
        adamw: opt_mod.AdamWConfig | None = None,
        num_microbatches: int = 1,
        ckpt: CheckpointManager | None = None,
        repair: RepairCoordinator | None = None,
        ckpt_every: int = 50,
    ):
        self.cfg = cfg
        self.ctx = ctx or AxisCtx()
        self.adamw = adamw or opt_mod.AdamWConfig(warmup_steps=10)
        self.step_fn = jax.jit(
            make_train_step(cfg, self.ctx, self.adamw, num_microbatches),
            donate_argnums=(0,),
        )
        self.ckpt = ckpt
        self.repair = repair
        self.ckpt_every = ckpt_every
        self.restarts = 0

    def init_state(self, seed: int = 0):
        from repro.models.model import build
        from repro.sharding import init_params

        params = init_params(build(self.cfg).param_specs(), jax.random.PRNGKey(seed))
        return opt_mod.init_state(params)

    def restore_latest(self, template_state):
        assert self.ckpt is not None
        step = self.ckpt.latest_step()
        if step is None:
            return None, 0
        state = self.ckpt.restore(step, template_state)
        self.restarts += 1
        return jax.tree.map(jax.numpy.asarray, state), step

    def run(
        self,
        state,
        batches: Iterator,
        num_steps: int,
        *,
        start_step: int = 0,
        on_step: Callable | None = None,
    ) -> tuple[dict, TrainReport]:
        losses = []
        repairs = 0
        t0 = time.time()
        step = start_step
        for _ in range(num_steps):
            x, y = next(batches)
            batch = self._to_batch(x, y)
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            step += 1
            if self.ckpt is not None and step % self.ckpt_every == 0:
                self.ckpt.save(step, jax.tree.map(np.asarray, state))
            if self.repair is not None and step % self.ckpt_every == 0:
                repairs += len(self.repair.repair_all())
                if self.repair.failures:
                    # checkpoint durability is the whole point of repairing
                    # mid-run: an unrecoverable chunk must abort loudly
                    raise RepairError(
                        f"{len(self.repair.failures)} chunk(s) unrecoverable "
                        f"at step {step}: {self.repair.failures[:3]}"
                    )
            if on_step:
                on_step(step, state, loss)
        report = TrainReport(
            steps_run=num_steps,
            final_loss=losses[-1] if losses else float("nan"),
            losses=losses,
            restarts=self.restarts,
            repairs=repairs,
            wall_s=time.time() - t0,
        )
        return state, report

    def _to_batch(self, x, y):
        if self.cfg.is_encdec:
            b = x.shape[0]
            frames = np.zeros((b, self.cfg.enc_seq, self.cfg.d_model), np.float32)
            return {"frames": frames, "tokens": x, "labels": y}
        if self.cfg.input_mode == "embeddings":
            # stub frontend: deterministic embedding of token ids
            emb = (x[..., None] % 17).astype(np.float32) / 17.0
            emb = np.broadcast_to(emb, x.shape + (self.cfg.d_model,))
            return {"embeddings": emb, "labels": y}
        return {"tokens": x, "labels": y}
