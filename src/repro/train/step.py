"""train_step / serve_step builders — the functions the dry-run lowers.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function with microbatched gradient accumulation (lax.scan over microbatches
keeps activation memory at one-microbatch high-water) and AdamW/ZeRO-1.

``make_prefill_step`` / ``make_decode_step`` are the serving entry points.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import build
from repro.sharding import AxisCtx
from repro.train import optimizer as opt


def make_loss_fn(cfg: ArchConfig, ctx: AxisCtx):
    model = build(cfg)

    def loss_fn(params, batch):
        return model.loss(params, batch, ctx)

    return loss_fn


def make_train_step(cfg: ArchConfig, ctx: AxisCtx, adamw: opt.AdamWConfig | None = None,
                    num_microbatches: int = 1, shard_grad_accum: bool = False):
    adamw = adamw or opt.AdamWConfig()
    loss_fn = make_loss_fn(cfg, ctx)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    constrain = lambda tree: tree
    if shard_grad_accum and ctx.mesh is not None:
        # force the accumulated grads onto the params' (FSDP x TP) sharding:
        # XLA then reduce-scatters each microbatch instead of all-reducing
        # full gradients num_microbatches times (see EXPERIMENTS.md section Perf)
        from repro.sharding import tree_shardings

        shardings = tree_shardings(build(cfg).param_specs(), ctx.rules, ctx.mesh)

        def constrain(tree):
            return jax.tree.map(jax.lax.with_sharding_constraint, tree, shardings)

    def train_step(state, batch):
        params = state["params"]
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((num_microbatches, x.shape[0] // num_microbatches) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def mb_step(acc, mb):
                (l, _), g = grad_fn(params, mb)
                acc = constrain(jax.tree.map(jnp.add, acc, g))
                return acc, l

            zero = constrain(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            grads, losses = jax.lax.scan(mb_step, zero, mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = losses.mean()
            metrics = {}
        new_state, opt_metrics = opt.update(state, grads, adamw)
        return new_state, {"loss": loss, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ArchConfig, ctx: AxisCtx):
    model = build(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, ctx)

    return prefill_step


def make_decode_step(cfg: ArchConfig, ctx: AxisCtx, *, long_mode: bool = False):
    model = build(cfg)

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, ctx, long_mode=long_mode)

    return decode_step
