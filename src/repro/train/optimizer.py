"""AdamW with ZeRO-1 semantics: optimizer moments inherit the parameters'
(FSDP x TP) sharding, so per-device optimizer state is params/N_chips.

Written against plain pytrees (no optax dependency): `init`, `update` are
pure functions suitable for pjit."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {
        "params": params,
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(state, grads, cfg: AdamWConfig):
    """One AdamW step (with global-norm clipping). Returns (new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_vec).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(state["params"])
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (
        {"params": new_p, "m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
