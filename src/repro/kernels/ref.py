"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import gf


def gf_matmul_ref(a, b):
    """GF(2^8) matmul oracle: (M,K) x (K,N) -> (M,N) uint8."""
    out = gf.matmul_jnp(a.astype(jnp.int32), b.astype(jnp.int32))
    return out.astype(jnp.uint8)


# -- sample hash oracle -------------------------------------------------------
_PRIME1 = jnp.uint32(2654435761)
_PRIME2 = jnp.uint32(2246822519)
_PRIME3 = jnp.uint32(3266489917)
_PRIME4 = jnp.uint32(668265263)


def _rotl(x, r):
    return (x << r) | (x >> (32 - r))


def sample_hash_ref(words, seed=0):
    """xxhash32-flavoured mix over the last axis.

    words: (..., W) uint32 -> (...,) uint32.  Used for bulk audit-sample
    hashing; NOT the protocol-grade hash (that is SHA-256 in
    core/commitments.py) — see DESIGN.md §3.
    """
    words = words.astype(jnp.uint32)
    acc = jnp.full(words.shape[:-1], jnp.uint32(seed) + _PRIME4, jnp.uint32)
    w = words.shape[-1]
    for i in range(w):
        acc = acc + words[..., i] * _PRIME2
        acc = _rotl(acc, 13) * _PRIME1
    acc = acc ^ (acc >> 15)
    acc = acc * _PRIME2
    acc = acc ^ (acc >> 13)
    acc = acc * _PRIME3
    acc = acc ^ (acc >> 16)
    return acc


def flash_attention_ref(q, k, v, causal=True):
    """Oracle for the fused flash-attention kernel: naive softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd) -> (B, Sq, H, hd)."""
    import math

    import jax

    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qr = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        sk = k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)
