"""Pallas TPU kernel: GF(2^8) matrix multiply for erasure coding.

This is the data-path hot spot of the paper (§3.5 "Erasure coding
acceleration"): every encode / decode / repair in the Clay/RS stack reduces to

    C (M, N) = A (M, K)  (x)  B (K, N)      over GF(2^8)

with a tiny coefficient matrix A (M, K <= ~32) and a wide byte matrix B
(N = payload bytes, MiBs).  CPUs implement the field multiply with PSHUFB /
GF-NI table lookups; TPUs have no fast gather on the VPU, so we *adapt* the
paper's insight (vectorized GF coding outrunning NIC line rate) to the TPU
ISA: a **branchless carry-less multiply** — 8 conditional XOR-accumulate
steps over `xtime`-shifted operands — which is pure shift/AND/XOR vector ALU
work and vectorizes perfectly on the VPU.

Tiling: grid over N blocks.  Per step, a (K, BN) tile of B streams
HBM -> VMEM, A lives whole in VMEM (tiny), and the kernel produces an
(M, BN) output tile.  The K and 8-bit loops are unrolled at trace time
(both static and small), so the body is a flat sequence of vector ops with
no control flow.

Arithmetic intensity: ~8*K int-ops per loaded byte of B -> compute-bound on
the VPU for K >= ~4, exactly mirroring the CPU story in the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gf import POLY

DEFAULT_BLOCK_N = 2048
_RED = POLY & 0xFF  # low 8 bits of the field polynomial


def _gf_mul_vec(a_scalar, b_vec):
    """GF(2^8) multiply of an int32 scalar against an int32 vector.

    Branchless Russian-peasant / carry-less multiply; 8 unrolled steps.
    """
    acc = jnp.zeros_like(b_vec)
    a = a_scalar
    b = b_vec
    for _ in range(8):
        bit = a & 1
        acc = acc ^ (b * bit)  # bit in {0,1}: multiply = select, no branch
        a = a >> 1
        carry = (b >> 7) & 1
        b = ((b << 1) & 0xFF) ^ (carry * _RED)
    return acc


def _kernel(a_ref, b_ref, o_ref, *, m: int, k: int):
    b = b_ref[...].astype(jnp.int32)  # (K, BN)
    a = a_ref[...].astype(jnp.int32)  # (M, K)
    rows = []
    for i in range(m):
        acc = jnp.zeros(b.shape[1:], jnp.int32)
        for j in range(k):
            acc = acc ^ _gf_mul_vec(a[i, j], b[j])
        rows.append(acc)
    o_ref[...] = jnp.stack(rows, axis=0).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gf_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jax.Array:
    """C = A (x) B over GF(2^8).  a: (M, K) uint8, b: (K, N) uint8 -> (M, N).

    N is padded up to a multiple of block_n internally.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    n_pad = -n % block_n
    if n_pad:
        b = jnp.pad(b, ((0, 0), (0, n_pad)))
    grid = (b.shape[1] // block_n,)
    out = pl.pallas_call(
        functools.partial(_kernel, m=m, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, b.shape[1]), jnp.uint8),
        interpret=interpret,
    )(a, b)
    return out[:, :n]
