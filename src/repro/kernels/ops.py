"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True``; on a
real TPU runtime they compile to Mosaic.  ``repro.core``/``repro.storage``
call only these wrappers, never `pallas_call` directly.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.kernels import gf_matmul as _gf
from repro.kernels import ref as _ref
from repro.kernels import sample_hash as _sh


@functools.lru_cache(None)
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gf_matmul(a, b, *, block_n: int | None = None):
    """GF(2^8) matmul via the Pallas kernel (interpret-mode off-TPU)."""
    kwargs = {} if block_n is None else {"block_n": block_n}
    return _gf.gf_matmul(a, b, interpret=not _on_tpu(), **kwargs)


def gf_matmul_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """numpy-in/numpy-out convenience for the storage data path."""
    return np.asarray(gf_matmul(np.asarray(a, np.uint8), np.asarray(b, np.uint8)))


def gf_matmul_ref(a, b):
    return _ref.gf_matmul_ref(a, b)


def sample_hash(words, *, seed: int = 0):
    """Bulk sample digests via the Pallas kernel (interpret-mode off-TPU)."""
    return _sh.sample_hash(words, seed=seed, interpret=not _on_tpu())


def sample_hash_ref(words, seed: int = 0):
    return _ref.sample_hash_ref(words, seed)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512, bk: int = 512):
    """Fused flash attention via the Pallas kernel (interpret-mode off-TPU)."""
    from repro.kernels import flash_attention as _fa

    return _fa.flash_attention_fused(q, k, v, causal=causal, bq=bq, bk=bk,
                                     interpret=not _on_tpu())


def flash_attention_ref(q, k, v, causal: bool = True):
    return _ref.flash_attention_ref(q, k, v, causal)
