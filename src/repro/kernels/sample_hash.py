"""Pallas TPU kernel: bulk audit-sample hashing.

Shelby's internal audits (§4.1) hash 1 KiB samples at high frequency: every
SP answers per-epoch challenges and every auditor re-hashes received samples
to verify Merkle proofs.  At production scale that is millions of 1 KiB
digests per epoch per SP — a bandwidth-bound bulk op worth a kernel.

TPU adaptation (DESIGN.md §3): TPUs have no SHA engine and byte-gather is
slow, so the *bulk* path uses an xxhash32-style word mixer over uint32 lanes
(protocol-grade SHA-256 stays on the coordination layer).  Each leaf's words
live contiguously; the kernel tiles (LEAVES_BLK, WORDS) into VMEM and mixes
along the word axis with unrolled rotate/multiply steps — pure VPU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_LEAVES = 256

_P1 = 2654435761
_P2 = 2246822519
_P3 = 3266489917
_P4 = 668265263


def _rotl(x, r):
    return (x << r) | (x >> (32 - r))


def _kernel(w_ref, o_ref, *, words: int, seed: int):
    w = w_ref[...].astype(jnp.uint32)  # (BL, W)
    acc = jnp.full((w.shape[0],), jnp.uint32(seed + _P4), jnp.uint32)
    for i in range(words):
        acc = acc + w[:, i] * jnp.uint32(_P2)
        acc = _rotl(acc, 13) * jnp.uint32(_P1)
    acc = acc ^ (acc >> 15)
    acc = acc * jnp.uint32(_P2)
    acc = acc ^ (acc >> 13)
    acc = acc * jnp.uint32(_P3)
    acc = acc ^ (acc >> 16)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("seed", "block_leaves", "interpret"))
def sample_hash(
    words: jax.Array,
    *,
    seed: int = 0,
    block_leaves: int = DEFAULT_BLOCK_LEAVES,
    interpret: bool = False,
) -> jax.Array:
    """words: (L, W) uint32 -> (L,) uint32 digests."""
    leaves, w = words.shape
    pad = -leaves % block_leaves
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    grid = (words.shape[0] // block_leaves,)
    out = pl.pallas_call(
        functools.partial(_kernel, words=w, seed=seed),
        grid=grid,
        in_specs=[pl.BlockSpec((block_leaves, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_leaves,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((words.shape[0],), jnp.uint32),
        interpret=interpret,
    )(words)
    return out[:leaves]
