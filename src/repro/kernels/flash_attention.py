"""Pallas TPU kernel: fused flash attention (beyond-paper LM-side optimization).

WHY (from the dry-run roofline, EXPERIMENTS.md §Perf): the pure-JAX chunked
attention materializes every (bq, bk) score block to HBM at fusion
boundaries — measured as the dominant memory-term contributor for the
train/prefill cells (arithmetic intensity of the score ops ~26 flop/byte vs
the v5e machine balance of ~240).  Fusing QK^T -> online-softmax -> PV into
one kernel keeps scores in VMEM; traffic drops to Q/K/V/O once each.

Grid: (batch*q_heads, Sq/bq, Sk/bk) — TPU iterates the minor-most (kv) axis
sequentially, so the online-softmax state (m, l, acc) lives in VMEM scratch
across kv steps; the output block is written once on the last kv step.
GQA is expressed in the k/v index_maps (q head -> kv head).

Validated against ``repro.kernels.ref.flash_attention_ref`` in interpret
mode (tests/test_kernels.py); on TPU it lowers to Mosaic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memories (interpret mode accepts them too)
    from jax.experimental.pallas import tpu as pltpu

    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    _SCRATCH = lambda shape: pl.MemorySpace.ANY  # type: ignore

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
    if causal:
        iq = pl.program_id(1)
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ()))
    )
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_fused(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, Hkv, hd)
    v: jax.Array,  # (B, Sk, Hkv, hd)
    *,
    causal: bool = True,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(hd)
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, "pad sequences to block multiples"
    nq, nk = sq // bq, sk // bk

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)

    def kv_head(bh):  # flat q-head id -> flat kv-head id
        return (bh // h) * hkv + (bh % h) // g

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq, bk=bk, nk=nk),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (kv_head(bh), ik, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (kv_head(bh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[_SCRATCH((bq,)), _SCRATCH((bq,)), _SCRATCH((bq, hd))],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
