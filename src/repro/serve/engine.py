"""Batched serving engine: prefill + greedy decode with a KV cache.

The read-optimized half of the framework (the paper's raison d'être):
weights arrive through Shelby verified reads (see examples/serve_llm.py),
then requests are batched, prefilled once and decoded step by step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import build
from repro.sharding import AxisCtx, init_params


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, ctx: AxisCtx | None = None,
                 max_len: int = 256, long_mode: bool = False):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or AxisCtx()
        self.max_len = max_len
        self.long_mode = long_mode
        self.model = build(cfg)
        self._decode = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos, self.ctx,
                                                        long_mode=long_mode),
            donate_argnums=(1,),
        )
        self.stats = ServeStats()

    def _empty_cache(self, batch: int):
        specs = self.model.cache_specs(batch, self.max_len, long_mode=self.long_mode)
        return init_params(specs, jax.random.PRNGKey(0))

    def generate(self, prompts: np.ndarray, num_tokens: int, *, frames=None) -> np.ndarray:
        """prompts: (B, P) int32 -> (B, P + num_tokens).  Greedy decoding via
        the decode path from position 0 (prefill-free reference flow)."""
        b, p = prompts.shape
        cache = self._empty_cache(b)
        if self.cfg.is_encdec:
            enc_out = self.model.encode(self.params, jnp.asarray(frames), self.ctx)
            cache["enc_out"] = enc_out.astype(jnp.bfloat16)
        out = [prompts[:, i] for i in range(p)]
        tok = prompts[:, :1].astype(np.int32)
        for pos in range(p + num_tokens - 1):
            logits, cache = self._decode(self.params, cache, jnp.asarray(tok), jnp.int32(pos))
            if pos + 1 < p:
                tok = prompts[:, pos + 1 : pos + 2].astype(np.int32)
            else:
                nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
                nxt = np.minimum(nxt, self.cfg.vocab - 1)
                out.append(nxt)
                tok = nxt[:, None]
            self.stats.decoded_tokens += b
        return np.stack(out, axis=1)
