"""falcon-mamba-7b [ssm]: 64L d=4096 attn-free mamba1, d_inner=8192,
ssm_state=16, vocab=65024.  Pure SSM -> long_500k runs (O(1) decode state).
[arXiv:2410.05355]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab=65024,
    norm="rmsnorm",
    ssm=SSMConfig(d_inner=8192, state=16, conv_width=4, dt_rank=256),
    sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="falcon-mamba-smoke",
    family="ssm",
    num_layers=3,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab=256,
    ssm=SSMConfig(d_inner=128, state=8, conv_width=4, dt_rank=8),
    sub_quadratic=True,
)
