"""phi-3-vision-4.2b [vlm]: 32L d=3072 32H (MHA kv=32) d_ff=8192 vocab=32064,
phi3-mini backbone + CLIP frontend STUB (input_specs supplies precomputed
patch/text embeddings for training; decode embeds generated tokens).
[hf:microsoft/Phi-3-vision-128k-instruct]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=10000.0,
    input_mode="embeddings",
)

SMOKE = ArchConfig(
    name="phi3v-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab=256,
    input_mode="embeddings",
)
