"""whisper-tiny [audio]: 4L enc + 4L dec, d=384 6H d_ff=1536 vocab=51865,
enc-dec with conv frontend STUB (input_specs supplies precomputed frame
embeddings).  [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,  # decoder layers
    enc_layers=4,
    enc_seq=1500,  # 30 s of audio at 50 Hz after the (stubbed) conv frontend
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    norm="layernorm",
    mlp="gelu",
    use_bias=True,
    input_mode="embeddings",
)

SMOKE = ArchConfig(
    name="whisper-smoke",
    family="encdec",
    num_layers=2,
    enc_layers=2,
    enc_seq=32,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab=256,
    norm="layernorm",
    mlp="gelu",
    use_bias=True,
    input_mode="embeddings",
)
