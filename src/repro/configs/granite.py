"""granite-8b [dense]: 36L d=4096 32H (GQA kv=8) d_ff=14336 vocab=49152,
llama-arch (rmsnorm + swiglu), code model.  [arXiv:2405.04324; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab=49152,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=10000.0,
)

SMOKE = ArchConfig(
    name="granite-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab=256,
)
