"""starcoder2-3b [dense]: 30L d=3072 24H (GQA kv=2) d_ff=12288 vocab=49152,
GQA + RoPE, layernorm + gelu MLP w/ bias.  [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab=49152,
    norm="layernorm",
    mlp="gelu",
    use_bias=True,
    rope_theta=100000.0,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="starcoder2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=256,
    vocab=256,
    norm="layernorm",
    mlp="gelu",
    use_bias=True,
    tie_embeddings=True,
)
