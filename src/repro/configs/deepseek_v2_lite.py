"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H MLA(kv_lora=512) MoE 64e top-6
2 shared, expert d_ff=1408, first layer dense d_ff=10944, vocab 102400.
[arXiv:2405.04434; hf]"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="mla_moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,  # used only by the first dense layer
    vocab=102400,
    norm="rmsnorm",
    mlp="swiglu",
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        expert_d_ff=1408,
        num_shared=2,
        shared_d_ff=2816,  # 2 shared experts x 1408
        first_dense_layers=1,
        first_dense_d_ff=10944,
    ),
)

SMOKE = ArchConfig(
    name="deepseek-v2-lite-smoke",
    family="mla_moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab=256,
    mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=32, num_shared=2, shared_d_ff=64,
                  first_dense_layers=1, first_dense_d_ff=128),
)
