"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the full published config; ``get_smoke(name)`` a
reduced same-family config for CPU tests.  ``ALL_ARCHS`` drives the dry-run
matrix.
"""
from __future__ import annotations

import importlib

ALL_ARCHS = [
    "deepseek-v2-lite-16b",
    "qwen3-moe-30b-a3b",
    "hymba-1.5b",
    "falcon-mamba-7b",
    "whisper-tiny",
    "starcoder2-3b",
    "granite-8b",
    "yi-9b",
    "command-r-plus-104b",
    "phi-3-vision-4.2b",
]

_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "qwen3-moe-30b-a3b": "qwen3_moe",
    "hymba-1.5b": "hymba",
    "falcon-mamba-7b": "falcon_mamba",
    "whisper-tiny": "whisper_tiny",
    "starcoder2-3b": "starcoder2",
    "granite-8b": "granite",
    "yi-9b": "yi",
    "command-r-plus-104b": "command_r_plus",
    "phi-3-vision-4.2b": "phi3_vision",
    "shelby": "shelby",
}


def get(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke(name: str):
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE
