"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4, head_dim=128, qk-norm)
128 experts top-8 expert d_ff=768, vocab 151936. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,  # informational; experts carry the FFN
    vocab=151936,
    norm="rmsnorm",
    mlp="swiglu",
    use_qk_norm=True,
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=768),
)

SMOKE = ArchConfig(
    name="qwen3-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab=256,
    use_qk_norm=True,
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=64),
)
