"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
parallel attention + mamba heads, ssm_state=16.  Long mode: SSM heads carry
global state, attention heads use a 2048 sliding window -> sub-quadratic,
so the long_500k cell runs.  [arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    norm="rmsnorm",
    mlp="swiglu",
    ssm=SSMConfig(d_inner=3200, state=16, conv_width=4, dt_rank=100),
    long_window=2048,
    sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="hymba-smoke",
    family="hybrid",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab=256,
    ssm=SSMConfig(d_inner=128, state=8, conv_width=4, dt_rank=8),
    long_window=32,
    sub_quadratic=True,
)
