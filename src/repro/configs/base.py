"""ArchConfig: one dataclass describes every assigned architecture."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared: int = 0
    shared_d_ff: int = 0
    first_dense_layers: int = 0
    first_dense_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_inner: int
    state: int = 16
    conv_width: int = 4
    dt_rank: int = 0  # 0 -> d_model // 16


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | mla_moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp: str = "swiglu"  # swiglu | gelu
    parallel_block: bool = False  # command-r style parallel attn+mlp
    use_qk_norm: bool = False  # qwen3-style per-head q/k RMSNorm
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # enc-dec only
    enc_layers: int = 0
    enc_seq: int = 1500  # stub-frontend frame count for train shape
    # inputs: 'tokens' or 'embeddings' (audio/vlm stub frontends)
    input_mode: str = "tokens"
    # long-context support: 0 = full attention only (skip long_500k);
    # >0 = sliding-window size used by attention in long mode
    long_window: int = 0
    sub_quadratic: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding to a multiple of 256 so the logits /
        embedding table shard over the model axis (whisper's 51865 and
        hymba's 32001 would otherwise replicate a multi-GB logits buffer)."""
        return -(-self.vocab // 256) * 256

    @property
    def supports_long(self) -> bool:
        return self.sub_quadratic

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def param_count(self) -> int:
        """Total parameters (exact, from the spec tree)."""
        import jax
        from repro.models.model import build
        from repro.sharding import ParamSpec

        specs = build(self).param_specs()
        leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        return sum(int(__import__("math").prod(s.shape)) for s in leaves)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared experts)."""
        total = self.param_count()
        if self.moe is None:
            return total
        import math

        moe_layers = self.num_layers - self.moe.first_dense_layers
        per_expert = 3 * self.d_model * self.moe.expert_d_ff
        inactive = (self.moe.num_experts - self.moe.top_k) * per_expert * moe_layers
        return total - inactive


# -- shape suite (assigned input shapes) ---------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = [
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
]


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §6)"
    return True, ""
