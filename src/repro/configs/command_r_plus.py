"""command-r-plus-104b [dense]: 64L d=12288 96H (GQA kv=8) d_ff=33792
vocab=256000, parallel attn+mlp block (cohere), no bias.
[hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    norm="layernorm",
    mlp="swiglu",
    parallel_block=True,
    use_bias=False,
    rope_theta=75000000.0,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="command-r-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=192,
    vocab=256,
    norm="layernorm",
    mlp="swiglu",
    parallel_block=True,
    tie_embeddings=True,
)
