"""The paper's own system configuration (not an LM arch): the production
Shelby deployment parameters used across benchmarks and examples."""
import dataclasses


from repro.core.audit import AuditParams
from repro.storage.blob import BlobLayout


def resolve_decode_matmul(choice: str = "auto"):
    """Map a config string to the GF matmul the batched Clay decode uses.

    * ``"numpy"``  -> ``None``: the pure-numpy GF(2^8) path (fastest on CPU).
    * ``"pallas"`` -> the Pallas ``gf_matmul`` kernel (Mosaic on TPU;
      interpret mode elsewhere, which is a slowdown — only force it to
      exercise the kernel).
    * ``"auto"``   -> pallas on a real TPU runtime, numpy otherwise.
    """
    if choice == "auto":
        import jax

        choice = "pallas" if jax.default_backend() == "tpu" else "numpy"
    if choice == "numpy":
        return None
    if choice == "pallas":
        from repro.kernels import ops

        return ops.gf_matmul_np
    raise ValueError(f"decode_matmul must be auto|numpy|pallas, got {choice!r}")


@dataclasses.dataclass(frozen=True)
class ShelbyConfig:
    layout: BlobLayout = BlobLayout(k=10, m=6, chunkset_bytes_target=10 * 1024 * 1024)
    audit: AuditParams = AuditParams()
    num_sps: int = 24
    num_dcs: int = 5  # Appendix A availability model
    racks_per_dc: int = 4
    rpc_hedge: int = 2
    # hedge deadline = max(min_deadline, factor x slowest primary's
    # estimated latency); lower fires hedges sooner (see net/scheduler.py)
    rpc_hedge_deadline_factor: float = 3.0
    # fleet routing policy by name: latency | affinity | p2c
    # (net.fleet.POLICY_FACTORIES; scenarios build fleets through this)
    routing_policy: str = "affinity"
    price_per_chunk_read: float = 1e-6
    storage_fee_per_gb_month: float = 0.023  # W, benchmarked against S3
    epochs_per_month: float = 30.0
    decode_matmul: str = "auto"  # auto | numpy | pallas (see resolve_decode_matmul)
    # hot-cache policy per RPC node (LRU always; these add expiry/admission)
    rpc_cache_ttl_ms: float | None = None  # sim-clock TTL for decoded entries
    rpc_cache_admit_bytes: int | None = None  # skip caching decodes larger than this
    # overload control per RPC node (all off -> no AdmissionSpec attached;
    # see storage.rpc.AdmissionSpec for exact semantics)
    rpc_single_flight: bool = True  # collapse concurrent same-chunkset misses
    rpc_max_queued_requests: int | None = None  # admitted reads per node
    rpc_max_inflight_fetches: int | None = None  # live SP fetch tasks per node
    rpc_shed_deadline_ms: float | None = None  # brownout SLO on EWMA fetch ms
    # event-engine service/network model
    # event-queue discipline: "calendar" (O(1) amortized calendar queue,
    # the default) or "heap" (the binary-heap baseline); pop order — and
    # therefore every determinism digest — is identical on both
    event_engine: str = "calendar"
    sp_service_slots: int = 4  # concurrent disk reads per SP (FIFO queue beyond)
    # per-node NIC line rate wherever a Backbone is built from this config
    # (the concurrent serving bench); None = unlimited nodes
    nic_gbps: float | None = 10.0
    # background planes (audits + repair) per SP: the share of disk slots
    # background work may hold concurrently, the pacing between background
    # ops, the audit proof disk time (None = one chunk-read interval), and
    # the serving-p99 inflation budget the bench/tests assert under full
    # audit+repair load (loaded p99 <= bg_p99_budget * quiescent p99)
    bg_slot_share: float = 0.5
    bg_pace_ms: float = 2.0
    sp_audit_ms_per_proof: float | None = None
    bg_p99_budget: float = 1.5
    # membership plane (epoch-scale churn + reconfiguration): simulated
    # wall span of one epoch, default per-SP per-epoch churn probabilities,
    # the drain budget the bench asserts on each boundary's re-dispersal
    # backlog, and the serving-p99 inflation budget asserted through a
    # membership change (churned p99 <= churn_p99_budget * quiescent p99)
    churn_epoch_ms: float = 300.0
    churn_p_crash: float = 0.0
    churn_p_leave: float = 0.0
    churn_joins_per_epoch: int = 0
    churn_drain_budget_ms: float = 300.0
    churn_p99_budget: float = 1.8
    # data-availability sampling (storage/das.py): the 2-D extension's data
    # square side (k x k -> 2k x 2k shares), per-share byte size, samples a
    # light client draws per blob per epoch, the master switch, an optional
    # override of the modeled per-share proof wire size (None = the true
    # Merkle-path size), and the streaming-p99 inflation budget the bench
    # asserts under a concurrent DAS storm
    das_k: int = 4
    das_share_bytes: int = 512
    das_samples_per_epoch: int = 16
    das_extension: bool = True
    das_proof_bytes_per_share: int | None = None
    das_p99_budget: float = 1.8

    def background(self):
        """The per-SP BackgroundSpec these knobs describe."""
        from repro.storage.sp import BackgroundSpec

        return BackgroundSpec(slot_share=self.bg_slot_share,
                              pace_ms=self.bg_pace_ms)

    def service(self, slots: int | None = None):
        """A ServiceSpec carrying the background budget + audit disk time."""
        from repro.storage.sp import ServiceSpec

        return ServiceSpec(slots=slots if slots is not None else self.sp_service_slots,
                           audit_ms_per_proof=self.sp_audit_ms_per_proof,
                           background=self.background())

    def churn(self, *, seed: int = 0, scripted=(), min_active: int | None = None):
        """The ChurnSpec these knobs describe (plus run-specific scripted
        events / seed / fleet floor)."""
        from repro.storage.membership import ChurnSpec

        return ChurnSpec(
            p_crash=self.churn_p_crash,
            p_leave=self.churn_p_leave,
            joins_per_epoch=self.churn_joins_per_epoch,
            min_active=min_active,
            seed=seed,
            scripted=tuple(scripted),
        )

    def nic(self):
        from repro.net.backbone import NICSpec

        if self.nic_gbps is None:
            return None
        return NICSpec(egress_gbps=self.nic_gbps, ingress_gbps=self.nic_gbps)

    def policy(self):
        """A fresh routing-policy instance for the ``routing_policy`` knob."""
        from repro.net.fleet import make_policy

        return make_policy(self.routing_policy)

    def scheduler(self):
        """The per-RPC-node HedgedScheduler these knobs describe."""
        from repro.net.scheduler import HedgedScheduler

        return HedgedScheduler(hedge=self.rpc_hedge,
                               deadline_factor=self.rpc_hedge_deadline_factor)

    def admission(self):
        """The per-RPC-node AdmissionSpec these knobs describe, or None
        when every limit is off (the node then never sheds)."""
        from repro.storage.rpc import AdmissionSpec

        if (self.rpc_max_queued_requests is None
                and self.rpc_max_inflight_fetches is None
                and self.rpc_shed_deadline_ms is None):
            return None
        return AdmissionSpec(
            max_queued_requests=self.rpc_max_queued_requests,
            max_inflight_fetches=self.rpc_max_inflight_fetches,
            deadline_ms=self.rpc_shed_deadline_ms,
        )

    def das(self):
        """The DASSpec these knobs describe, or None when the 2-D
        extension is switched off (no dispersal, no sampling plane)."""
        from repro.storage.das import DASSpec

        if not self.das_extension:
            return None
        return DASSpec(
            k=self.das_k,
            share_bytes=self.das_share_bytes,
            samples_per_epoch=self.das_samples_per_epoch,
            extension=True,
            proof_bytes_per_share=self.das_proof_bytes_per_share,
        )

    def resolve_decode_matmul(self):
        return resolve_decode_matmul(self.decode_matmul)


CONFIG = ShelbyConfig()
SMOKE = ShelbyConfig(
    layout=BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024),
    num_sps=8,
    num_dcs=3,
    racks_per_dc=2,
)


# Machine-readable documentation for EVERY public knob: unit, default,
# and the registered scenario / SLO that exercises it.  The scenario
# registry validates every knob it references against this table
# (tests/test_scenarios.py), and scripts/gen_scenario_catalog.py renders
# it into docs/CATALOG.md — so a new knob without a doc line, or a doc
# line for a renamed knob, fails tier-1.
KNOB_DOCS: dict[str, str] = {
    "layout": (
        "unit: BlobLayout; default: k=10, m=6, 10 MiB chunksets. The Clay "
        "erasure layout every world stores blobs under; scenario worlds "
        "shrink it to k=4/m=2/64 KiB for CI. Exercised by: every scenario."
    ),
    "audit": (
        "unit: AuditParams; default: paper §4 schedule. Audit sampling "
        "probability, fines, and gas. Exercised by: background (audit "
        "plane pacing), run_sim epochs."
    ),
    "num_sps": (
        "unit: count; default: 24. Fleet size for config-built clusters "
        "(build_cluster); scenario worlds size their own fleets. "
        "Exercised by: run_sim integration tests."
    ),
    "num_dcs": (
        "unit: count; default: 5. Datacenters in config-built topologies "
        "(Appendix A availability model). Exercised by: durability bench."
    ),
    "racks_per_dc": (
        "unit: count; default: 4. Failure-domain granularity below a DC "
        "for placement spreading. Exercised by: churn (replacement_sp "
        "domain spreading)."
    ),
    "rpc_hedge": (
        "unit: count; default: 2. Extra chunk requests the hedged "
        "scheduler may launch past k when the deadline fires. Exercised "
        "by: serve_grid (straggler-shield SLO: zipf p99 < 250 ms)."
    ),
    "rpc_hedge_deadline_factor": (
        "unit: multiplier; default: 3.0. Hedge deadline = max(min_deadline, "
        "factor x slowest primary's estimated latency); lower hedges "
        "sooner (more waste, tighter tail). Exercised by: serve_grid SLOs; "
        "tunable in tune_admission sweeps."
    ),
    "routing_policy": (
        "unit: name in net.fleet.POLICY_FACTORIES (latency|affinity|p2c); "
        "default: affinity. The fleet routing policy scenario fleets are "
        "built with. Exercised by: every scenario fleet; serve_grid "
        "iterates all three explicitly."
    ),
    "price_per_chunk_read": (
        "unit: tokens/chunk; default: 1e-6. Pay-on-delivery price a "
        "client owes per served chunk. Exercised by: settlement "
        "conservation asserts in every paid scenario."
    ),
    "storage_fee_per_gb_month": (
        "unit: $/GB-month; default: 0.023 (S3-benchmarked W). Storage "
        "fee in the economics model. Exercised by: incentives bench."
    ),
    "epochs_per_month": (
        "unit: epochs; default: 30. Converts per-epoch fees to monthly "
        "economics. Exercised by: incentives bench."
    ),
    "decode_matmul": (
        "unit: auto|numpy|pallas; default: auto (pallas on TPU, numpy "
        "elsewhere). GF matmul backend for batched Clay decode and 2-D "
        "extension. Exercised by: every decode; gf_kernel bench sweeps "
        "both backends."
    ),
    "rpc_cache_ttl_ms": (
        "unit: sim ms | None; default: None (no expiry). Sim-clock TTL "
        "on decoded hot-cache entries per RPC node. Exercised by: "
        "tune_admission sweeps (TTL axis); TTL tests in test_events.py."
    ),
    "rpc_cache_admit_bytes": (
        "unit: bytes | None; default: None (admit all). Skip caching "
        "decoded chunksets larger than this. Exercised by: cache "
        "admission tests; tunable in sweeps."
    ),
    "rpc_single_flight": (
        "unit: bool; default: True. Collapse concurrent same-chunkset "
        "cache misses onto one SP fetch (coalesced followers). Exercised "
        "by: concurrent SLO (5000rps.admitted.coalesced > 0)."
    ),
    "rpc_max_queued_requests": (
        "unit: count | None; default: None (unbounded). Admission cap on "
        "concurrently admitted reads per RPC node; past it the node "
        "sheds with a typed Overloaded NACK. Exercised by: tune_admission "
        "sweeps; overload tests."
    ),
    "rpc_max_inflight_fetches": (
        "unit: count | None; default: None (unbounded). Fetch budget per "
        "RPC node (coalesced waiters are free); the concurrent scenario "
        "sets 6 for its admitted ramp. Exercised by: concurrent SLOs "
        "(admitted p99 < free p99, shed_rate > 0 at 3x saturation)."
    ),
    "rpc_shed_deadline_ms": (
        "unit: sim ms | None; default: None (off). Brownout SLO: shed "
        "while the EWMA of observed fetch latency exceeds it. Exercised "
        "by: tune_admission sweeps; brownout tests in test_overload.py."
    ),
    "event_engine": (
        "unit: calendar|heap; default: calendar. Event-queue discipline; "
        "pop order and every determinism digest are identical on both. "
        "Exercised by: engine scenario (fast-vs-heap digest equality)."
    ),
    "sp_service_slots": (
        "unit: slots; default: 4. Concurrent disk reads per SP; FIFO "
        "queue beyond. Exercised by: concurrent (SP queueing past the "
        "knee), background (slot contention with audits)."
    ),
    "nic_gbps": (
        "unit: Gbps | None; default: 10.0. Per-node full-duplex NIC line "
        "rate wherever a Backbone is built from this config; None = "
        "unlimited. Exercised by: concurrent/background/churn/das worlds."
    ),
    "bg_slot_share": (
        "unit: fraction; default: 0.5. Max share of an SP's disk slots "
        "background work may hold concurrently. Exercised by: background "
        "SLO (p99_inflation <= bg_p99_budget)."
    ),
    "bg_pace_ms": (
        "unit: sim ms; default: 2.0. Min gap between background op "
        "launches per SP (no bursts). Exercised by: background SLO."
    ),
    "sp_audit_ms_per_proof": (
        "unit: sim ms | None; default: None (one chunk-read interval). "
        "Disk time an audit proof generation holds the auditee's slot. "
        "Exercised by: background (audit plane)."
    ),
    "bg_p99_budget": (
        "unit: multiplier; default: 1.5. Serving-p99 inflation bound "
        "under full audit+repair load. Exercised by: background SLO "
        "(p99_inflation <= bg_p99_budget)."
    ),
    "churn_epoch_ms": (
        "unit: sim ms; default: 300. Simulated wall span of one "
        "membership epoch. Exercised by: churn scenario."
    ),
    "churn_p_crash": (
        "unit: probability/SP/epoch; default: 0.0. Seeded crash draw for "
        "the churn process. Exercised by: churn durability series."
    ),
    "churn_p_leave": (
        "unit: probability/SP/epoch; default: 0.0. Seeded announced-"
        "departure draw. Exercised by: churn durability series."
    ),
    "churn_joins_per_epoch": (
        "unit: count; default: 0. New SPs registered per epoch. "
        "Exercised by: churn (join-expands-fleet path)."
    ),
    "churn_drain_budget_ms": (
        "unit: sim ms; default: 300. Bound on each boundary's "
        "re-dispersal backlog drain. Exercised by: churn (per-epoch "
        "drain assert)."
    ),
    "churn_p99_budget": (
        "unit: multiplier; default: 1.8. Serving-p99 inflation bound "
        "through a membership change. Exercised by: churn SLO "
        "(p99_inflation <= churn_p99_budget)."
    ),
    "das_k": (
        "unit: shares/axis; default: 4. Data-square side (k x k extends "
        "to 2k x 2k). Exercised by: das scenario."
    ),
    "das_share_bytes": (
        "unit: bytes; default: 512. Per-share payload size. Exercised "
        "by: das (bytes_to_detect < full_chunk_audit_bytes SLO)."
    ),
    "das_samples_per_epoch": (
        "unit: samples/blob/epoch; default: 16. Coordinates a light "
        "client draws per blob per epoch. Exercised by: das detection "
        "curve (1-(1-q)^s)."
    ),
    "das_extension": (
        "unit: bool; default: True. Master switch for the 2-D extension "
        "(dispersal + sampling plane). Exercised by: das scenario; "
        "extension-off tests."
    ),
    "das_proof_bytes_per_share": (
        "unit: bytes | None; default: None (true Merkle-path size). "
        "Override of the modeled per-share proof wire size. Exercised "
        "by: das proof-size tests."
    ),
    "das_p99_budget": (
        "unit: multiplier; default: 1.8. Streaming-p99 inflation bound "
        "under a concurrent DAS storm. Exercised by: das (streaming "
        "tail assert)."
    ),
}


def knob_doc(name: str) -> str:
    """The documented unit/default/scenario line for a knob, raising on
    unknown names so doc drift fails loudly."""
    try:
        return KNOB_DOCS[name]
    except KeyError:
        raise KeyError(
            f"knob {name!r} has no KNOB_DOCS entry (configs/shelby.py)"
        ) from None
