"""The paper's own system configuration (not an LM arch): the production
Shelby deployment parameters used across benchmarks and examples."""
import dataclasses


from repro.core.audit import AuditParams
from repro.storage.blob import BlobLayout


def resolve_decode_matmul(choice: str = "auto"):
    """Map a config string to the GF matmul the batched Clay decode uses.

    * ``"numpy"``  -> ``None``: the pure-numpy GF(2^8) path (fastest on CPU).
    * ``"pallas"`` -> the Pallas ``gf_matmul`` kernel (Mosaic on TPU;
      interpret mode elsewhere, which is a slowdown — only force it to
      exercise the kernel).
    * ``"auto"``   -> pallas on a real TPU runtime, numpy otherwise.
    """
    if choice == "auto":
        import jax

        choice = "pallas" if jax.default_backend() == "tpu" else "numpy"
    if choice == "numpy":
        return None
    if choice == "pallas":
        from repro.kernels import ops

        return ops.gf_matmul_np
    raise ValueError(f"decode_matmul must be auto|numpy|pallas, got {choice!r}")


@dataclasses.dataclass(frozen=True)
class ShelbyConfig:
    layout: BlobLayout = BlobLayout(k=10, m=6, chunkset_bytes_target=10 * 1024 * 1024)
    audit: AuditParams = AuditParams()
    num_sps: int = 24
    num_dcs: int = 5  # Appendix A availability model
    racks_per_dc: int = 4
    rpc_hedge: int = 2
    price_per_chunk_read: float = 1e-6
    storage_fee_per_gb_month: float = 0.023  # W, benchmarked against S3
    epochs_per_month: float = 30.0
    decode_matmul: str = "auto"  # auto | numpy | pallas (see resolve_decode_matmul)
    # hot-cache policy per RPC node (LRU always; these add expiry/admission)
    rpc_cache_ttl_ms: float | None = None  # sim-clock TTL for decoded entries
    rpc_cache_admit_bytes: int | None = None  # skip caching decodes larger than this
    # overload control per RPC node (all off -> no AdmissionSpec attached;
    # see storage.rpc.AdmissionSpec for exact semantics)
    rpc_single_flight: bool = True  # collapse concurrent same-chunkset misses
    rpc_max_queued_requests: int | None = None  # admitted reads per node
    rpc_max_inflight_fetches: int | None = None  # live SP fetch tasks per node
    rpc_shed_deadline_ms: float | None = None  # brownout SLO on EWMA fetch ms
    # event-engine service/network model
    # event-queue discipline: "calendar" (O(1) amortized calendar queue,
    # the default) or "heap" (the binary-heap baseline); pop order — and
    # therefore every determinism digest — is identical on both
    event_engine: str = "calendar"
    sp_service_slots: int = 4  # concurrent disk reads per SP (FIFO queue beyond)
    # per-node NIC line rate wherever a Backbone is built from this config
    # (the concurrent serving bench); None = unlimited nodes
    nic_gbps: float | None = 10.0
    # background planes (audits + repair) per SP: the share of disk slots
    # background work may hold concurrently, the pacing between background
    # ops, the audit proof disk time (None = one chunk-read interval), and
    # the serving-p99 inflation budget the bench/tests assert under full
    # audit+repair load (loaded p99 <= bg_p99_budget * quiescent p99)
    bg_slot_share: float = 0.5
    bg_pace_ms: float = 2.0
    sp_audit_ms_per_proof: float | None = None
    bg_p99_budget: float = 1.5
    # membership plane (epoch-scale churn + reconfiguration): simulated
    # wall span of one epoch, default per-SP per-epoch churn probabilities,
    # the drain budget the bench asserts on each boundary's re-dispersal
    # backlog, and the serving-p99 inflation budget asserted through a
    # membership change (churned p99 <= churn_p99_budget * quiescent p99)
    churn_epoch_ms: float = 300.0
    churn_p_crash: float = 0.0
    churn_p_leave: float = 0.0
    churn_joins_per_epoch: int = 0
    churn_drain_budget_ms: float = 300.0
    churn_p99_budget: float = 1.8
    # data-availability sampling (storage/das.py): the 2-D extension's data
    # square side (k x k -> 2k x 2k shares), per-share byte size, samples a
    # light client draws per blob per epoch, the master switch, an optional
    # override of the modeled per-share proof wire size (None = the true
    # Merkle-path size), and the streaming-p99 inflation budget the bench
    # asserts under a concurrent DAS storm
    das_k: int = 4
    das_share_bytes: int = 512
    das_samples_per_epoch: int = 16
    das_extension: bool = True
    das_proof_bytes_per_share: int | None = None
    das_p99_budget: float = 1.8

    def background(self):
        """The per-SP BackgroundSpec these knobs describe."""
        from repro.storage.sp import BackgroundSpec

        return BackgroundSpec(slot_share=self.bg_slot_share,
                              pace_ms=self.bg_pace_ms)

    def service(self, slots: int | None = None):
        """A ServiceSpec carrying the background budget + audit disk time."""
        from repro.storage.sp import ServiceSpec

        return ServiceSpec(slots=slots if slots is not None else self.sp_service_slots,
                           audit_ms_per_proof=self.sp_audit_ms_per_proof,
                           background=self.background())

    def churn(self, *, seed: int = 0, scripted=(), min_active: int | None = None):
        """The ChurnSpec these knobs describe (plus run-specific scripted
        events / seed / fleet floor)."""
        from repro.storage.membership import ChurnSpec

        return ChurnSpec(
            p_crash=self.churn_p_crash,
            p_leave=self.churn_p_leave,
            joins_per_epoch=self.churn_joins_per_epoch,
            min_active=min_active,
            seed=seed,
            scripted=tuple(scripted),
        )

    def nic(self):
        from repro.net.backbone import NICSpec

        if self.nic_gbps is None:
            return None
        return NICSpec(egress_gbps=self.nic_gbps, ingress_gbps=self.nic_gbps)

    def admission(self):
        """The per-RPC-node AdmissionSpec these knobs describe, or None
        when every limit is off (the node then never sheds)."""
        from repro.storage.rpc import AdmissionSpec

        if (self.rpc_max_queued_requests is None
                and self.rpc_max_inflight_fetches is None
                and self.rpc_shed_deadline_ms is None):
            return None
        return AdmissionSpec(
            max_queued_requests=self.rpc_max_queued_requests,
            max_inflight_fetches=self.rpc_max_inflight_fetches,
            deadline_ms=self.rpc_shed_deadline_ms,
        )

    def das(self):
        """The DASSpec these knobs describe, or None when the 2-D
        extension is switched off (no dispersal, no sampling plane)."""
        from repro.storage.das import DASSpec

        if not self.das_extension:
            return None
        return DASSpec(
            k=self.das_k,
            share_bytes=self.das_share_bytes,
            samples_per_epoch=self.das_samples_per_epoch,
            extension=True,
            proof_bytes_per_share=self.das_proof_bytes_per_share,
        )

    def resolve_decode_matmul(self):
        return resolve_decode_matmul(self.decode_matmul)


CONFIG = ShelbyConfig()
SMOKE = ShelbyConfig(
    layout=BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024),
    num_sps=8,
    num_dcs=3,
    racks_per_dc=2,
)
