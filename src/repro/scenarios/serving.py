"""The serving scenarios (§2.3 + §3.5 + §4 + §2.5): registry entries for
the five backbone regimes plus the admission-tuning target.

Each ``run_*`` body is the former hand-rolled ``benchmarks/backbone_serve``
section, refactored onto a :class:`~repro.scenarios.runner.ScenarioContext`:
every knob it reads comes from ``ctx.config`` (defaults < scenario.knobs <
sweep overrides), traffic shrinks under ``ctx.smoke``, and the metrics
payload is *returned* — the runner asserts the declared SLOs against it
and merges it into BENCH_backbone.json under the scenario's section.
Headline numeric bars are declared as :class:`SLO`s on the registrations
at the bottom of this module (violations name the scenario); structural
invariants (determinism digests, settlement conservation, counterfactual
comparisons) stay inline where the evidence lives.

Adversity baked in: heterogeneous SP service latencies, one 250 ms
straggler, one SP crashed after the write phase — the paper's serving
claims are only interesting under failures.  Latencies are workload-driven
sums on the simulated clock; wall time only bounds how long a scenario
itself runs.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.shelby import ShelbyConfig
from repro.core import audit as audit_mod
from repro.core import durability
from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.backbone import Backbone, NICSpec
from repro.net.events import engine_counters
from repro.net.fleet import POLICY_FACTORIES, RPCFleet
from repro.net.workloads import (
    analytics_scan,
    das_storm,
    training_epoch,
    video_streaming,
    zipf_hotset,
)
from repro.scenarios.registry import SLO, register
from repro.scenarios.report import row
from repro.scenarios.runner import ScenarioContext
from repro.storage.background import AuditPlane, RepairPlane
from repro.storage.blob import BlobLayout
from repro.storage.das import DASSpec, extend_and_disperse_many, measure_detection
from repro.storage.membership import ChurnSpec, MembershipPlane, measure_durability
from repro.storage.repair import RepairCoordinator
from repro.storage.rpc import AdmissionSpec, BackboneTransport, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider

NUM_SPS = 12
NUM_RPCS = 3


def _num_blobs(smoke: bool) -> int:
    return 4 if smoke else 6


def _zipf_requests(smoke: bool) -> int:
    return 80 if smoke else 250


def _engine_stats(counters0: tuple[int, float]) -> dict:
    """Engine throughput over a section: the delta of the module-wide
    (events, wall_s) counters since ``counters0`` — sections with many
    private loops (sequential grid, sweeps) get honest totals without
    threading every loop's telemetry out by hand."""
    ev0, w0 = counters0
    ev1, w1 = engine_counters()
    d_ev, d_w = ev1 - ev0, w1 - w0
    return {
        "events": d_ev,
        "wall_s": d_w,
        "events_per_sec": d_ev / d_w if d_w > 0 else 0.0,
    }


def _world(cfg: ShelbyConfig, smoke: bool,
           nic: NICSpec | None = None, sp_slots: int | None = None):
    """Contract + SPs + stored blobs + backbone — shared across combos.

    `nic`/`sp_slots` turn on the event engine's contention model (NIC
    serialization per node, FIFO disk-slot queues per SP) for the
    concurrent regimes; the sequential grid keeps them off so its numbers
    stay comparable across PRs.  Contended SPs carry the config's
    background budget (`cfg.bg_slot_share` / `bg_pace_ms` /
    `sp_audit_ms_per_proof`), which the `background` scenario exercises.
    """
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract()
    bb = Backbone.mesh(3, base_latency_ms=6.0, gbps=25.0)
    rng = np.random.default_rng(42)
    sps = {}
    for i in range(NUM_SPS):
        dc = f"dc{i % 3}"
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=dc, rack=f"r{i % 4}"))
        service = cfg.service(slots=sp_slots) if sp_slots else None
        sps[i] = StorageProvider(i, service=service)
        sps[i].behavior.latency_ms = float(rng.uniform(1.0, 12.0))
        bb.register_node(f"sp{i}", dc, nic=nic)
    for c in range(3):
        bb.register_node(f"client{c}", f"dc{c}")
    # a throwaway writer node disperses the blobs
    bb.register_node("writer", "dc0")
    writer = RPCNode("writer", contract, sps, layout)
    client = ShelbyClient(contract, writer, deposit=1e9)
    metas = []
    datas = []  # original bytes, for bit-exact decode checks after churn
    for b in range(_num_blobs(smoke)):
        size = (8 if b == 0 else 4) * layout.chunkset_bytes  # blob 0: the "video"
        data = rng.integers(0, 256, size, dtype=np.uint8).tobytes()
        datas.append(data)
        metas.append(client.put(data))
    # adversity AFTER the write phase
    sps[0].behavior.latency_ms = 250.0  # straggler
    sps[1].crash()
    return layout, contract, bb, sps, metas, datas


def _workloads(metas, smoke: bool):
    return {
        "streaming": lambda: video_streaming(
            metas[0], client="client0", segment_bytes=64 * 1024, bitrate_mbps=25.0
        ),
        "training": lambda: training_epoch(
            metas, client="client1", sample_bytes=64 * 1024, epochs=1, seed=3
        ),
        "zipf": lambda: zipf_hotset(
            metas,
            clients=["client0", "client1", "client2"],
            num_requests=_zipf_requests(smoke),
            seed=5,
        ),
        "analytics": lambda: analytics_scan(
            metas, client="client2", scan_bytes=128 * 1024
        ),
    }


def _fresh_fleet(cfg: ShelbyConfig, layout, contract, bb, sps, policy=None, *,
                 nic: NICSpec | None = None, cache_chunksets: int = 16,
                 admission: AdmissionSpec | None = None,
                 single_flight: bool = True):
    """A fleet built from the resolved config: routing policy, hedge
    deadline, cache TTL/admission, and decode backend all come off
    ``cfg`` so a sweep that moves a knob moves the fleet."""
    rpcs = []
    for r in range(NUM_RPCS):
        node = f"rpc{r}"
        if node not in bb._node_dc:
            bb.register_node(node, f"dc{r}", nic=nic)
        rpcs.append(
            RPCNode(
                node, contract, sps, layout,
                cache_chunksets=cache_chunksets,
                transport=BackboneTransport(sps, bb, node),
                scheduler=cfg.scheduler(),
                decode_matmul=cfg.resolve_decode_matmul(),
                cache_ttl_ms=cfg.rpc_cache_ttl_ms,
                cache_admit_bytes=cfg.rpc_cache_admit_bytes,
                admission=admission, single_flight=single_flight,
            )
        )
    bb.reset_accounting()
    return RPCFleet(rpcs, policy if policy is not None else cfg.policy(),
                    backbone=bb)


# --------------------------------------------------------------------------
# serve_grid: routing policy x workload sequential grid
# --------------------------------------------------------------------------

def run_serve_grid(ctx: ScenarioContext) -> dict:
    cfg, smoke = ctx.config, ctx.smoke
    layout, contract, bb, sps, metas, _ = _world(cfg, smoke)
    c0 = engine_counters()
    grid_json = {}
    for pname, policy_factory in POLICY_FACTORIES.items():
        for wname, workload in _workloads(metas, smoke).items():
            fleet = _fresh_fleet(cfg, layout, contract, bb, sps,
                                 policy_factory())
            reader = ShelbyClient(contract, fleet, deposit=1e9)
            reqs = workload()
            t0 = time.perf_counter()
            span_end = 0.0
            with reader.session() as session:
                for req in reqs:
                    receipt = session.read(
                        req.blob_id, req.offset, req.length,
                        client=req.client, t_ms=req.t_ms,
                    )
                    assert len(receipt.data) == min(
                        req.length, contract.blobs[req.blob_id].size_bytes - req.offset
                    )
                    span_end = max(span_end, req.t_ms + receipt.latency_ms)
            settlement = session.settlement
            # per-serving-node settlement matches the receipts (float-tol)
            assert abs(settlement.total_node_income
                       - sum(r.total_paid for r in session.receipts)) < 1e-3
            wall = time.perf_counter() - t0
            span_ms = span_end - reqs[0].t_ms
            goodput_mbps = fleet.bytes_served * 8e-3 / span_ms
            p50, p99 = fleet.latency_percentiles(50.0, 99.0)
            row(
                f"backbone_serve/{pname}_{wname}",
                wall * 1e6 / len(reqs),
                f"goodput={goodput_mbps:.1f}Mbps;p50={p50:.1f}ms;p99={p99:.1f}ms;"
                f"hedges={fleet.hedges_launched()};waste={fleet.hedged_wasted()};"
                f"cache_hit={fleet.cache_hit_rate():.2f}",
            )
            grid_json[f"{pname}_{wname}"] = {
                "goodput_mbps": goodput_mbps,
                "p50_ms": p50,
                "p99_ms": p99,
                "hedges_launched": fleet.hedges_launched(),
                "hedged_wasted": fleet.hedged_wasted(),
                "cache_hit_rate": fleet.cache_hit_rate(),
                "coalesced": fleet.coalesced(),
                "shed_rate": 0.0,  # sequential grid never saturates a node
            }
    grid_json["engine"] = _engine_stats(c0)
    # the straggler-shield bars (zipf p99 < 250 ms per policy) are the
    # scenario's declared SLOs — asserted by the runner against this payload
    return grid_json


# --------------------------------------------------------------------------
# concurrent: open-loop Poisson storm, free vs admitted ramp
# --------------------------------------------------------------------------

CONCURRENT_RATES_RPS = (200, 1000, 5000)  # offered load ramp


def run_concurrent(ctx: ScenarioContext) -> dict:
    """Open-loop Poisson Zipf storm through the SHARED event engine.

    All requests of a run live on one heap: hedge timers interleave, SPs
    queue on their disk slots, nodes serialize on 10 Gbps NICs.  Asserts
    the determinism digest (two identical runs on fresh fleets -> byte-
    identical per-request timings and link utilization), then ramps the
    offered load TWICE — once with no admission control, once with the
    overload controller described by ``cfg.admission()`` — so the bench
    trajectory shows the paper's serving story under stress: the
    free-running fleet's p99 explodes past the saturation knee, the
    admission-controlled fleet sheds the excess (typed NACKs that debit
    nothing) and keeps the admitted tail bounded, while single-flight
    dedup collapses hot-object stampedes (the declared SLOs).
    """
    cfg, smoke = ctx.config, ctx.smoke
    nic = cfg.nic()  # 10 Gbps full-duplex per node by default
    layout, contract, bb, sps, metas, _ = _world(cfg, smoke, nic=nic,
                                                 sp_slots=2)
    num_requests = 100 if smoke else 400
    # past the fetch budget a node sheds instead of queueing; the scenario
    # registers rpc_max_inflight_fetches=6 — sweeps move it
    admitted_spec = cfg.admission()

    def one_run(rate_rps, admission=None, single_flight=True):
        fleet = _fresh_fleet(cfg, layout, contract, bb, sps,
                             nic=nic, cache_chunksets=8, admission=admission,
                             single_flight=single_flight)
        reader = ShelbyClient(contract, fleet, deposit=1e9)
        reqs = zipf_hotset(
            metas, clients=["client0", "client1", "client2"],
            num_requests=num_requests, interarrival_ms=1000.0 / rate_rps,
            seed=11, arrival="poisson",
        )
        with reader.session() as session:
            receipts, result = session.replay(reqs)
        settlement = session.settlement
        assert abs(settlement.total_node_income
                   - sum(r.total_paid for r in session.receipts)) < 1e-3
        return fleet, result

    # determinism gate: identical workload on a fresh fleet, twice
    _, a = one_run(CONCURRENT_RATES_RPS[0])
    _, b = one_run(CONCURRENT_RATES_RPS[0])
    assert a.digest() == b.digest(), (
        f"determinism violated: {a.digest()[:16]} != {b.digest()[:16]}"
    )
    print(f"# concurrent determinism digest: {a.digest()[:16]} OK")

    ramp_json = {}
    c0 = engine_counters()
    for rate in CONCURRENT_RATES_RPS:
        per_rate = {"offered_rps": rate}
        # "free" is the PR-3 fleet (no dedup, no admission — queues grow
        # without bound); "admitted" is the overload-safe serving path
        # (single-flight stampede collapse + per-node fetch budget)
        for mode, admission, single_flight in (
            ("free", None, False),
            ("admitted", admitted_spec, cfg.rpc_single_flight),
        ):
            t0 = time.perf_counter()
            fleet, result = one_run(rate, admission, single_flight)
            wall = time.perf_counter() - t0
            p50, p99 = result.percentile(50.0), result.percentile(99.0)
            row(
                f"backbone_serve/concurrent_{mode}_{rate}rps",
                wall * 1e6 / num_requests,
                f"goodput={result.goodput_mbps:.1f}Mbps;p50={p50:.1f}ms;"
                f"p99={p99:.1f}ms;shed={result.shed};dropped={result.dropped};"
                f"hedges={fleet.hedges_launched()};waste={fleet.hedged_wasted()};"
                f"coalesced={fleet.coalesced()}",
            )
            per_rate[mode] = {
                "goodput_mbps": result.goodput_mbps,
                "p50_ms": p50,
                "p99_ms": p99,
                "shed_rate": result.shed_rate,
                "dropped": result.dropped,
                "hedges_launched": fleet.hedges_launched(),
                "hedged_wasted": fleet.hedged_wasted(),
                "coalesced": fleet.coalesced(),
                "retried_legs": fleet.retried_legs,
                "engine_events_per_sec": result.engine_events_per_sec,
            }
        ramp_json[f"{rate}rps"] = per_rate
    ramp_json["engine"] = _engine_stats(c0)
    # the saturation story is declared as SLOs on the registration below:
    # free p99 grows with offered load, single-flight coalesces the hot
    # set, the admitted fleet sheds past the knee and keeps its tail
    # below the free-running one
    return ramp_json


# --------------------------------------------------------------------------
# background: serving tail under full audit+repair load
# --------------------------------------------------------------------------

def run_background(ctx: ScenarioContext) -> dict:
    """Serving p50/p99 quiescent vs. under FULL audit+repair load — the
    quantitative "auditing does not compromise performance" reproduction.

    Two replays of the same Poisson Zipf storm on fresh fleets over one
    world: *quiescent* (foreground only), then *loaded* — every stored
    chunk is audit-challenged (p_a=1.0: proof generation holds auditee
    disk slots in the background class, proof broadcasts cross NICs and
    trunks to 3 auditors each) while the repair plane rebuilds every chunk
    of the crashed SP (helper reads + re-dispersal as background
    transfers).  The paced background must keep serving p99 inflation
    within ``cfg.bg_p99_budget`` (the declared SLO) and audit/repair
    bytes must actually show up in the NIC/link counters (no free
    background work — asserted inline).
    """
    cfg, smoke = ctx.config, ctx.smoke
    nic = cfg.nic()
    layout, contract, bb, sps, metas, _ = _world(cfg, smoke, nic=nic,
                                                 sp_slots=2)
    c0 = engine_counters()
    bb.register_node("repairer", "dc0", nic=nic)
    num_requests = 80 if smoke else 300
    rate_rps = 400.0  # busy but below the knee: contention is measurable
    sp_nodes = {i: f"sp{i}" for i in sps}

    def one_run(background=None):
        fleet = _fresh_fleet(cfg, layout, contract, bb, sps,
                             nic=nic, cache_chunksets=8)
        reader = ShelbyClient(contract, fleet, deposit=1e9)
        reqs = zipf_hotset(
            metas, clients=["client0", "client1", "client2"],
            num_requests=num_requests, interarrival_ms=1000.0 / rate_rps,
            seed=7, arrival="poisson",
        )
        t0 = time.perf_counter()
        with reader.session() as session:
            _, result = session.replay(reqs, background=background)
        return fleet, result, time.perf_counter() - t0

    # quiescent baseline FIRST (repairs mutate placement for later runs)
    _, quiet, wall_q = one_run()
    q50, q99 = quiet.percentile(50.0), quiet.percentile(99.0)
    row(
        "backbone_serve/background_quiescent",
        wall_q * 1e6 / num_requests,
        f"goodput={quiet.goodput_mbps:.1f}Mbps;p50={q50:.1f}ms;p99={q99:.1f}ms",
    )

    # full audit pressure: challenge EVERY stored chunk this epoch
    sp_ids = [s.sp_id for s in contract.active_sps()]
    challenges = audit_mod.derive_challenges(
        contract.epoch_seed(0), 0, contract.holdings(), sp_ids,
        p_a=1.0, auditors_per_audit=3,
    )
    audits = AuditPlane(contract, sps, challenges, nodes=sp_nodes)
    rc = RepairCoordinator(contract, sps, layout, nodes=sp_nodes,
                           coordinator_node="repairer")
    repairs = RepairPlane(rc)  # scans at spawn: the crashed SP's chunks
    _, loaded, wall_l = one_run(background=[audits, repairs])
    l50, l99 = loaded.percentile(50.0), loaded.percentile(99.0)
    audit_recs = [b for b in loaded.background if b.kind == "audit"]
    repair_recs = [b for b in loaded.background if b.kind == "repair"]
    repaired_ok = sum(1 for b in repair_recs if b.ok)
    row(
        "backbone_serve/background_loaded",
        wall_l * 1e6 / num_requests,
        f"goodput={loaded.goodput_mbps:.1f}Mbps;p50={l50:.1f}ms;p99={l99:.1f}ms;"
        f"audits={len(audit_recs)};repairs={repaired_ok};"
        f"bg_bytes={loaded.background_bytes}",
    )

    # background work is real: it moved bytes over NICs and trunks …
    assert audits.proof_bytes > 0, "audit proofs crossed no link"
    assert repaired_ok > 0 and sum(b.nbytes for b in repair_recs) > 0, (
        "repair plane moved no bytes"
    )
    repairer_in = bb.nic_bytes.get(("in", "repairer"), 0)
    assert repairer_in > 0, "helper bytes never crossed the repairer's NIC"
    link_delta = sum(loaded.link_bytes.values()) - sum(quiet.link_bytes.values())
    bg_net_bytes = audits.proof_bytes + repairer_in
    assert link_delta >= 0.5 * bg_net_bytes, (
        f"background bytes missing from link counters: delta={link_delta} "
        f"vs bg={bg_net_bytes}"
    )
    # … and every foreground read was still served (background never
    # starves paid traffic: bg waiters yield to queued reads)
    assert loaded.dropped == quiet.dropped == 0, (
        f"reads dropped: loaded={loaded.dropped} quiescent={quiet.dropped}"
    )
    # the paper's bar — paced audits+repair inflate serving p99 only
    # within the configured budget — is the declared p99_inflation SLO

    return {
        "quiescent": {"goodput_mbps": quiet.goodput_mbps, "p50_ms": q50,
                      "p99_ms": q99,
                      "engine_events_per_sec": quiet.engine_events_per_sec},
        "loaded": {"goodput_mbps": loaded.goodput_mbps, "p50_ms": l50,
                   "p99_ms": l99,
                   "engine_events_per_sec": loaded.engine_events_per_sec},
        "p99_inflation": l99 / q99 if q99 > 0 else 1.0,
        "p99_budget": cfg.bg_p99_budget,
        "audit_ops": len(audit_recs),
        "audit_proof_bytes": audits.proof_bytes,
        "repairs_ok": repaired_ok,
        "repair_failures": len(repairs.failures),
        "background_bytes": loaded.background_bytes,
        "bg_p99_ms": loaded.background_percentile(99.0),
        "repairer_nic_in_bytes": repairer_in,
        "engine": _engine_stats(c0),
    }


# --------------------------------------------------------------------------
# churn: serving through a membership change + measured durability
# --------------------------------------------------------------------------

def run_churn(ctx: ScenarioContext) -> dict:
    """Serving p99 THROUGH a membership change, plus the reproduction's
    two durability metrics — the §2.5 epoch-reconfiguration story.

    A scripted tolerable churn scenario (never more than m simultaneous
    failures per chunkset: one SP is already crashed from the write phase,
    then one announced departure / crash per epoch plus a mid-epoch join)
    runs UNDER a live Poisson Zipf storm: the membership plane finalizes
    departures at epoch boundaries, the contract remaps the displaced
    placement entries, and the re-dispersal backlog drains through the
    repair plane while paid reads keep flowing.  Declared SLOs: zero lost
    chunksets, zero repair failures, p99 inflation through the change
    within ``cfg.churn_p99_budget``.  Inline: bit-exact decode through
    the SAME fleet, departed-never-paid, per-epoch drain within
    ``cfg.churn_drain_budget_ms``, same-seed digest equality, and the
    monotone measured-durability series.
    """
    cfg, smoke = ctx.config, ctx.smoke
    nic = cfg.nic()
    c0 = engine_counters()
    num_requests = 80 if smoke else 300
    rate_rps = 400.0
    epochs = 3
    epoch_ms = cfg.churn_epoch_ms
    # tolerable by construction: sp1 is crashed from the write phase, so
    # at most one scripted removal lands per epoch (<= m=2 concurrent
    # failures per chunkset), each AFTER the previous boundary's backlog
    # drained; a joiner arrives mid-run and is eligible for re-dispersal
    scripted = (
        (0, "announce", 2, 0.2),
        (1, "join", -1, 0.3),
        (1, "crash", 3, 0.6),
        (2, "announce", 4, 0.3),
    )

    def reqs_for(metas):
        return zipf_hotset(
            metas, clients=["client0", "client1", "client2"],
            num_requests=num_requests, interarrival_ms=1000.0 / rate_rps,
            seed=13, arrival="poisson",
        )

    def churn_world():
        """The shared world minus the 250 ms straggler: repair helpers
        sleep their full service time holding ONE background slot, so a
        straggler trivially dominates the drain-time metric this scenario
        asserts (the straggler story stays covered by the serve grid and
        the background scenario).  The post-write crashed SP stays — its
        chunks are exactly what the first boundary must re-disperse."""
        layout, contract, bb, sps, metas, datas = _world(cfg, smoke, nic=nic,
                                                         sp_slots=2)
        sps[0].behavior.latency_ms = 12.0
        bb.register_node("repairer", "dc0", nic=nic)
        return layout, contract, bb, sps, metas, datas

    def churn_run():
        """Fresh world + fleet + membership plane, storm replayed through
        the churn.  Returns everything the asserts below need."""
        layout, contract, bb, sps, metas, datas = churn_world()
        fleet = _fresh_fleet(cfg, layout, contract, bb, sps,
                             nic=nic, cache_chunksets=8)
        sp_nodes = {i: f"sp{i}" for i in sps}
        rc = RepairCoordinator(contract, sps, layout, nodes=sp_nodes,
                               coordinator_node="repairer")
        mplane = MembershipPlane(
            contract, sps, layout, ChurnSpec(seed=0, scripted=scripted),
            repair=rc, fleet=fleet, backbone=bb, nodes=sp_nodes, nic=nic,
            epochs=epochs, epoch_ms=epoch_ms,
            service_factory=lambda: cfg.service(slots=2),
        )
        reader = ShelbyClient(contract, fleet, deposit=1e9)
        t0 = time.perf_counter()
        with reader.session() as session:
            _, result = session.replay(reqs_for(metas),
                                       background=mplane.planes())
        wall = time.perf_counter() - t0
        return dict(contract=contract, bb=bb, sps=sps, metas=metas,
                    datas=datas, fleet=fleet, mplane=mplane, result=result,
                    reader=reader, wall=wall)

    # quiescent baseline FIRST: same world shape, same storm, no churn
    layout, contract, bb, sps, metas, _ = churn_world()
    fleet = _fresh_fleet(cfg, layout, contract, bb, sps,
                         nic=nic, cache_chunksets=8)
    reader = ShelbyClient(contract, fleet, deposit=1e9)
    with reader.session() as session:
        _, quiet = session.replay(reqs_for(metas))
    q50, q99 = quiet.percentile(50.0), quiet.percentile(99.0)
    row("backbone_serve/churn_quiescent", 0.0,
        f"goodput={quiet.goodput_mbps:.1f}Mbps;p50={q50:.1f}ms;p99={q99:.1f}ms")

    a = churn_run()
    mplane, res = a["mplane"], a["result"]
    c50, c99 = res.percentile(50.0), res.percentile(99.0)
    drains = [st.drain_ms() for st in mplane.epoch_stats]
    row(
        "backbone_serve/churn_loaded",
        a["wall"] * 1e6 / num_requests,
        f"goodput={res.goodput_mbps:.1f}Mbps;p50={c50:.1f}ms;p99={c99:.1f}ms;"
        f"events={len(mplane.events)};reassigned={mplane.reassigned_total};"
        f"lost={mplane.lost_chunksets};"
        f"drain={max(drains):.0f}ms",
    )

    # (a) at tolerable churn the backlog was real work and every blob
    # decodes bit-exact through the SAME fleet that served through the
    # reconfigurations (stale hot-cache entries must have version-
    # invalidated; no read resolves to a departed SP); zero lost
    # chunksets / zero repair failures are the declared SLOs
    assert mplane.repair is not None and mplane.repair.enqueued_total > 0
    assert res.dropped == 0 and res.shed == 0
    departed = sorted(a["contract"].dead_sps())
    assert departed, "scenario churned nobody"
    paid_before = {i: a["sps"][i].earned_reads for i in departed}
    with a["reader"].session() as session:
        for meta, data in zip(a["metas"], a["datas"]):
            got = session.read(meta.blob_id, 0, meta.size_bytes,
                               client="client0")
            assert got.data == data, f"blob {meta.blob_id} not bit-exact"
    for i in departed:
        assert a["sps"][i].earned_reads == paid_before[i], (
            f"departed sp{i} was paid after reconfiguration"
        )

    # (b) every boundary's re-dispersal backlog drained inside the budget
    assert mplane.repair.backlog() == 0, f"backlog stuck: {mplane.repair.backlog()}"
    for st, d in zip(mplane.epoch_stats, drains):
        assert d == d and d <= cfg.churn_drain_budget_ms, (
            f"epoch {st.epoch} backlog ({st.enqueued} chunks) drained in "
            f"{d:.0f}ms > budget {cfg.churn_drain_budget_ms:.0f}ms"
        )
    # re-dispersal moved real bytes through the repairer's NIC
    repairer_in = a["bb"].nic_bytes.get(("in", "repairer"), 0)
    assert repairer_in > 0, "re-dispersal crossed no link"

    # (c) serving p99 through the membership change: the p99_inflation SLO

    # (d) same-seed determinism: a fresh world + fleet churned identically
    # produces the SAME digest (membership + repair records ride it)
    b = churn_run()
    assert a["result"].digest() == b["result"].digest(), (
        f"churn determinism violated: {a['result'].digest()[:16]} != "
        f"{b['result'].digest()[:16]}"
    )
    print(f"# churn determinism digest: {res.digest()[:16]} OK")

    # measured durability series: lost-chunkset probability vs churn rate
    # (tiny seeded worlds, losses COUNTED by the boundary census, repair
    # racing the failures) — zero at tolerable rates, nonzero beyond the
    # redundancy budget, monotone under the per-seed coupling
    rates = (0.0, 0.15, 0.3, 0.5)
    seeds = (0, 1) if smoke else (0, 1, 2, 3)
    points = measure_durability(rates, seeds=seeds, epochs=2, repair=True)
    series = durability.measured_loss_series(points)
    probs = series["loss_probability"]
    for pt in points:
        print(f"# churn_rate={pt.churn_rate:.2f} "
              f"loss={pt.loss_probability:.3f} ({pt.lost}/{pt.chunksets}) "
              f"analytic_no_repair={pt.analytic_no_repair:.3f}")
    assert probs[0] == 0.0, "lost chunksets with zero churn"
    assert probs[-1] > 0.0, "no measured loss beyond the redundancy budget"
    assert all(x <= y + 1e-12 for x, y in zip(probs, probs[1:])), (
        f"loss probability not monotone in churn rate: {probs}"
    )

    return {
        "quiescent": {"goodput_mbps": quiet.goodput_mbps, "p50_ms": q50,
                      "p99_ms": q99,
                      "engine_events_per_sec": quiet.engine_events_per_sec},
        "churned": {"goodput_mbps": res.goodput_mbps, "p50_ms": c50,
                    "p99_ms": c99,
                    "engine_events_per_sec": res.engine_events_per_sec},
        "p99_inflation": c99 / q99 if q99 > 0 else 1.0,
        "p99_budget": cfg.churn_p99_budget,
        "epochs": epochs,
        "epoch_ms": epoch_ms,
        "membership_events": len(mplane.events),
        "sps_joined": len(mplane.joined),
        "sps_departed": len(departed),
        "reassigned": mplane.reassigned_total,
        "repairs_enqueued": mplane.repair.enqueued_total,
        "repair_failures": len(mplane.repair.failures),
        "drain_ms_per_epoch": drains,
        "drain_budget_ms": cfg.churn_drain_budget_ms,
        "lost_chunksets": mplane.lost_chunksets,
        "repairer_nic_in_bytes": repairer_in,
        "durability": series,
        "digest": res.digest()[:16],
        "engine": _engine_stats(c0),
    }


# --------------------------------------------------------------------------
# das: the proof-carrying light-client read regime
# --------------------------------------------------------------------------

def run_das(ctx: ScenarioContext) -> dict:
    """The proof-carrying light-client read regime (§2.3's missing corner):
    millions of tiny random reads instead of few large streams.

    Three verifiable claims:

    * **Detection math.** Over clean mini-worlds with seeded exact-count
      withholding adversaries (including a zero-withholding control), the
      measured per-epoch detection rate matches ``1-(1-q)^s`` within
      Monte-Carlo tolerance for every (fraction, seed) cell — the formula
      is exact because coordinates are drawn with replacement and the
      adversary withholds an exact share count (asserted inline per cell).
    * **Sampling beats auditing on bytes.** A withholding SP retains the
      data, so chunk-possession audits are structurally blind; the mean
      wire bytes a sampler spends until its first detection stay below
      ONE full-chunk audit read (the declared bytes_to_detect SLO).
    * **Cache steering.** A cache-hostile uniform DAS storm rides the
      shared event engine CONCURRENTLY with the Zipf streaming workload.
      With the ``cache_bypass`` hint (the default) the streaming fleet
      cache hit rate is untouched and streaming p99 stays inside
      ``cfg.das_p99_budget``; a counterfactual storm that ignores the
      hint pollutes the LRU and measurably drops the hit rate.  Two
      same-seed combined runs produce identical determinism digests
      (sample records ride the digest like reads).

    The storm runs over the shared adversity world — shares dispersed
    before the post-write straggler/crash, so samples landing on the
    crashed SP surface as detections (a crashed holder IS unavailable).
    """
    cfg, smoke = ctx.config, ctx.smoke
    spec = DASSpec(k=cfg.das_k, share_bytes=cfg.das_share_bytes,
                   samples_per_epoch=cfg.das_samples_per_epoch,
                   proof_bytes_per_share=cfg.das_proof_bytes_per_share)
    c0 = engine_counters()

    # -- (a) measured detection vs the analytic curve ------------------------
    fractions = (0.0, 0.05, 0.15, 0.30)
    seeds = (0, 1, 2)
    rounds, num_blobs = (8, 8) if smoke else (12, 12)
    tol = 0.20 if smoke else 0.15  # ~3.5 sigma of a 64/144-trial Bernoulli mean
    t0 = time.perf_counter()
    points = measure_detection(fractions, seeds, spec=spec,
                               num_blobs=num_blobs, rounds=rounds)
    wall_det = time.perf_counter() - t0
    for pt in points:
        print(f"# das q={pt.q_effective:.3f} s={pt.samples} "
              f"measured={pt.measured:.3f} analytic={pt.analytic:.3f} "
              f"({pt.detected}/{pt.trials})")
        assert abs(pt.measured - pt.analytic) <= tol, (
            f"detection off the analytic curve: q={pt.q_effective:.3f} "
            f"measured={pt.measured:.3f} vs {pt.analytic:.3f} (tol {tol})"
        )
        if pt.q_effective == 0.0:
            assert pt.detected == 0, "false positive with nothing withheld"

    # -- (b) a withholding SP costs fewer bytes to catch than one audit ------
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    worst = [pt for pt in points if pt.fraction == max(fractions) and pt.detected]
    assert worst, "no detections at the highest withholding fraction"
    detect_bytes = [pt.mean_samples_to_detect * pt.mean_sample_bytes for pt in worst]
    mean_detect_bytes = sum(detect_bytes) / len(detect_bytes)
    # mean_detect_bytes < one full-chunk audit read is the declared SLO

    # -- (c) the concurrent storm: cache steering + tail + determinism -------
    nic = cfg.nic()
    layout, contract, bb, sps, metas, datas = _world(cfg, smoke, nic=nic,
                                                     sp_slots=2)
    sps[1].recover()  # shares disperse BEFORE the post-write adversity,
    records = extend_and_disperse_many(  # exactly like the blobs themselves
        contract, sps, [(m.blob_id, d) for m, d in zip(metas, datas)], spec,
        matmul=cfg.resolve_decode_matmul(),
    )
    sps[1].crash()
    assert all(r.proof_bytes > 0 for r in records)
    num_fg = 80 if smoke else 300
    num_das = 120 if smoke else 400
    clients = ["client0", "client1", "client2"]

    def foreground():
        return zipf_hotset(metas, clients=clients, num_requests=num_fg,
                           interarrival_ms=1000.0 / 400.0, seed=19,
                           arrival="poisson")

    def storm(cache_bypass=True):
        return das_storm(records, clients=clients, num_requests=num_das,
                         interarrival_ms=0.5, seed=17,
                         cache_bypass=cache_bypass)

    def one_run(reqs, label):
        fleet = _fresh_fleet(cfg, layout, contract, bb, sps,
                             nic=nic, cache_chunksets=8)
        reader = ShelbyClient(contract, fleet, deposit=1e9, das=spec)
        t0 = time.perf_counter()
        with reader.session() as session:
            _, result = session.replay(reqs)
        settlement = session.settlement
        # pay-per-sample rides the same conservation check as paid reads
        assert abs(settlement.total_node_income
                   - sum(r.total_paid for r in session.receipts)) < 1e-3
        return fleet, result, time.perf_counter() - t0

    def fetches(f):
        return sum(n.stats.chunkset_fetches for n in f.rpcs)

    def effective_hit_rate(f):
        # a coalesced miss rides another request's in-flight fetch — like a
        # hit, it costs the SPs nothing; storm contention only shifts hits
        # into the coalesced bucket (and hedged legs may add/skip a fetch),
        # never evicts streaming entries
        hits = sum(n.stats.cache_hits for n in f.rpcs)
        total = hits + fetches(f) + f.coalesced()
        return (hits + f.coalesced()) / total if total else 0.0

    fg_only = foreground()
    combined = sorted(fg_only + storm(), key=lambda r: r.t_ms)
    polluted = sorted(fg_only + storm(cache_bypass=False), key=lambda r: r.t_ms)

    base_fleet, base, wall_b = one_run(fg_only, "baseline")
    h0, p99_0 = base_fleet.cache_hit_rate(), base.percentile(99.0, kind="read")
    fleet, res, wall_c = one_run(combined, "combined")
    h1, p99_1 = fleet.cache_hit_rate(), res.percentile(99.0, kind="read")
    pol_fleet, pol, _ = one_run(polluted, "polluted")
    h2 = pol_fleet.cache_hit_rate()

    served = fleet.samples_served()
    proof_bytes = fleet.sample_proof_bytes()
    row(
        "backbone_serve/das_storm",
        wall_c * 1e6 / len(combined),
        f"samples={served};withheld={fleet.samples_withheld()};"
        f"detections={res.das_detections};shed={res.shed};"
        f"proof_bytes={proof_bytes};stream_p99={p99_1:.1f}ms;"
        f"cache_hit={h1:.2f}(base {h0:.2f}, polluted {h2:.2f})",
    )

    assert served > 0 and proof_bytes > 0, "storm verified no proof-carrying reads"
    # the cache_bypass hint keeps the streaming hot cache untouched: the
    # storm never evicts streaming entries, so the cache's absorption
    # (hits + coalesced per lookup) is conserved and the SP fetch count
    # moves only by hedged legs firing differently under contention
    eff0, eff1 = effective_hit_rate(base_fleet), effective_hit_rate(fleet)
    assert abs(eff1 - eff0) <= 0.01, (
        f"DAS storm cost streaming cache absorption: {eff1:.4f} vs "
        f"baseline {eff0:.4f}"
    )
    assert abs(fetches(fleet) - fetches(base_fleet)) <= 2 + fleet.hedges_launched(), (
        f"DAS storm changed cache contents: {fetches(fleet)} fetches "
        f"vs baseline {fetches(base_fleet)}"
    )
    assert abs(h1 - h0) <= 0.05, (
        f"DAS storm perturbed the streaming cache hit rate: {h1:.3f} vs {h0:.3f}"
    )
    # … while ignoring the hint measurably pollutes the LRU: extra SP
    # fetches for streaming chunksets the storm evicted, a lower hit rate
    assert fetches(pol_fleet) > fetches(fleet), (
        f"cache-hostile storm without bypass did not pollute: "
        f"{fetches(pol_fleet)} fetches !> {fetches(fleet)}"
    )
    assert h2 < h1 - 0.05, (
        f"cache-hostile storm without bypass did not pollute: {h2:.3f} !< {h1:.3f}"
    )
    # streaming tail stays inside the DAS budget under the concurrent storm
    bound = cfg.das_p99_budget * p99_0 + 5.0
    assert p99_1 <= bound, (
        f"DAS storm blew the streaming tail: p99 {p99_1:.1f}ms > "
        f"bound {bound:.1f}ms (baseline {p99_0:.1f}ms)"
    )
    # same-seed determinism: the interleaved storm rides the digest
    _, res2, _ = one_run(sorted(fg_only + storm(), key=lambda r: r.t_ms), "redo")
    assert res.digest() == res2.digest(), (
        f"das determinism violated: {res.digest()[:16]} != {res2.digest()[:16]}"
    )
    print(f"# das determinism digest: {res.digest()[:16]} OK")

    share_bytes_served = served * spec.share_bytes
    return {
        "spec": {"k": spec.k, "side": spec.side, "share_bytes": spec.share_bytes,
                 "samples_per_epoch": spec.samples_per_epoch,
                 "proof_bytes_per_share": records[0].proof_bytes},
        "detection": [
            {"fraction": pt.fraction, "q_effective": pt.q_effective,
             "samples": pt.samples, "trials": pt.trials,
             "measured": pt.measured, "analytic": pt.analytic,
             "mean_samples_to_detect": (
                 pt.mean_samples_to_detect
                 if pt.mean_samples_to_detect != float("inf") else None),
             "mean_sample_bytes": pt.mean_sample_bytes}
            for pt in points
        ],
        "detection_tolerance": tol,
        "detection_wall_s": wall_det,
        "bytes_to_detect": mean_detect_bytes,
        "full_chunk_audit_bytes": layout.chunk_bytes,
        "storm": {
            "requests": num_das,
            "samples_served": served,
            "samples_withheld": fleet.samples_withheld(),
            "detections": res.das_detections,
            "shed": res.shed,
            "proof_bytes": proof_bytes,
            "proof_overhead": (proof_bytes / share_bytes_served
                               if share_bytes_served else 0.0),
            "sample_p99_ms": res.percentile(99.0, kind="das"),
            "goodput_mbps": res.goodput_mbps,
            "engine_events_per_sec": res.engine_events_per_sec,
        },
        "streaming": {
            "p99_baseline_ms": p99_0, "p99_under_storm_ms": p99_1,
            "p99_budget": cfg.das_p99_budget,
            "cache_hit_baseline": h0, "cache_hit_under_storm": h1,
            "cache_hit_polluted": h2,
            "chunkset_fetches_baseline": fetches(base_fleet),
            "chunkset_fetches_under_storm": fetches(fleet),
            "chunkset_fetches_polluted": fetches(pol_fleet),
            "effective_hit_baseline": eff0,
            "effective_hit_under_storm": eff1,
        },
        "digest": res.digest()[:16],
        "engine": _engine_stats(c0),
    }


# --------------------------------------------------------------------------
# tune_admission: the sweep/hill-climb target
# --------------------------------------------------------------------------

def run_tune_admission(ctx: ScenarioContext) -> dict:
    """ONE admitted Poisson Zipf storm at 3x saturation — the cheapest
    run whose outcome genuinely depends on the overload knobs, built as
    the optimiser's objective function.

    Every knob the overload controller owns comes off ``ctx.config``
    (``cfg.admission()``, ``cfg.rpc_single_flight``, cache TTL, hedge
    deadline, routing policy), so a sweep point IS a config.  The payload
    carries the replay determinism digest: every evaluated point is
    reproducible bit-for-bit from (scenario, knobs, seed).

    Objective shape (see ``scenarios/sweep.py`` and
    ``scripts/perf_hillclimb.py``): maximize ``goodput_mbps`` subject to
    the declared SLOs — with admission OFF (the ShelbyConfig default)
    the storm's p99 blows past the 150 ms SLO and the point is
    infeasible; the registered knobs (fetch budget 6) are a feasible
    default the optimiser must beat or match.
    """
    cfg, smoke = ctx.config, ctx.smoke
    nic = cfg.nic()
    layout, contract, bb, sps, metas, _ = _world(cfg, smoke, nic=nic,
                                                 sp_slots=2)
    num_requests = 60 if smoke else 300
    rate_rps = 5000.0  # 3x past the knee: admission is the story
    c0 = engine_counters()

    fleet = _fresh_fleet(cfg, layout, contract, bb, sps,
                         nic=nic, cache_chunksets=8,
                         admission=cfg.admission(),
                         single_flight=cfg.rpc_single_flight)
    reader = ShelbyClient(contract, fleet, deposit=1e9)
    reqs = zipf_hotset(
        metas, clients=["client0", "client1", "client2"],
        num_requests=num_requests, interarrival_ms=1000.0 / rate_rps,
        seed=29, arrival="poisson",
    )
    t0 = time.perf_counter()
    with reader.session() as session:
        _, result = session.replay(reqs)
    wall = time.perf_counter() - t0
    settlement = session.settlement
    assert abs(settlement.total_node_income
               - sum(r.total_paid for r in session.receipts)) < 1e-3
    p50, p99 = result.percentile(50.0), result.percentile(99.0)
    row(
        "backbone_serve/tune_admission",
        wall * 1e6 / num_requests,
        f"goodput={result.goodput_mbps:.1f}Mbps;p50={p50:.1f}ms;"
        f"p99={p99:.1f}ms;shed={result.shed};coalesced={fleet.coalesced()}",
    )
    return {
        "offered_rps": rate_rps,
        "requests": num_requests,
        "goodput_mbps": result.goodput_mbps,
        "p50_ms": p50,
        "p99_ms": p99,
        "shed_rate": result.shed_rate,
        "dropped": result.dropped,
        "coalesced": fleet.coalesced(),
        "hedges_launched": fleet.hedges_launched(),
        "hedged_wasted": fleet.hedged_wasted(),
        "knobs": {
            "rpc_max_inflight_fetches": cfg.rpc_max_inflight_fetches,
            "rpc_max_queued_requests": cfg.rpc_max_queued_requests,
            "rpc_shed_deadline_ms": cfg.rpc_shed_deadline_ms,
            "rpc_single_flight": cfg.rpc_single_flight,
            "rpc_cache_ttl_ms": cfg.rpc_cache_ttl_ms,
            "rpc_hedge_deadline_factor": cfg.rpc_hedge_deadline_factor,
            "routing_policy": cfg.routing_policy,
        },
        "digest": result.digest()[:16],
        "engine": _engine_stats(c0),
    }


# --------------------------------------------------------------------------
# registrations
# --------------------------------------------------------------------------

register(
    name="serve_grid",
    description=("Sequential routing-policy x workload serving grid over "
                 "the adversity world (straggler + crashed SP)"),
    workload="video/training/zipf/analytics, one request at a time",
    section="serve_grid",
    run=run_serve_grid,
    slos=(
        SLO("latency_zipf.p99_ms", "<", 250.0,
            description="hedging shields the zipf tail from the 250 ms "
                        "straggler (latency policy)"),
        SLO("affinity_zipf.p99_ms", "<", 250.0,
            description="straggler shield, affinity policy"),
        SLO("p2c_zipf.p99_ms", "<", 250.0,
            description="straggler shield, power-of-two policy"),
    ),
    tunable=("rpc_hedge", "rpc_hedge_deadline_factor", "routing_policy"),
    headline=("affinity_zipf.goodput_mbps", "affinity_zipf.p99_ms",
              "affinity_zipf.cache_hit_rate"),
    budget_s=600,
)

register(
    name="concurrent",
    description=("Open-loop Poisson Zipf storm ramped 200/1000/5000 rps, "
                 "free-running vs admission-controlled, on the shared "
                 "event engine (NICs + SP disk queues live)"),
    workload="zipf_hotset, poisson arrivals, 3-rate ramp x {free, admitted}",
    section="concurrent_ramp",
    run=run_concurrent,
    knobs={"rpc_max_inflight_fetches": 6},
    slos=(
        SLO("5000rps.free.p99_ms", ">=", "200rps.free.p99_ms",
            description="free-running tail grows with offered load"),
        SLO("5000rps.admitted.coalesced", ">", 0,
            description="single-flight collapses the hot-set stampede"),
        SLO("5000rps.admitted.shed_rate", ">", 0.0,
            description="admission sheds past the knee (typed NACKs)"),
        SLO("5000rps.admitted.p99_ms", "<", "5000rps.free.p99_ms",
            description="admitted tail bounded below free-running at 3x "
                        "saturation"),
    ),
    tunable=("rpc_max_inflight_fetches", "rpc_max_queued_requests",
             "rpc_shed_deadline_ms", "rpc_single_flight"),
    headline=("5000rps.admitted.p99_ms", "5000rps.free.p99_ms",
              "5000rps.admitted.shed_rate", "5000rps.admitted.goodput_mbps"),
    budget_s=180,
)

register(
    name="background",
    description=("Serving tail quiescent vs under FULL audit+repair load "
                 "on one world — audits hold SP disk slots in the "
                 "deferrable class, proofs broadcast over real NICs"),
    workload="zipf_hotset 400 rps + p_a=1.0 audit plane + crashed-SP repair",
    section="background",
    run=run_background,
    slos=(
        SLO("p99_inflation", "<=", "bg_p99_budget", atol=0.1,
            description="paced background keeps serving p99 inflation "
                        "within the configured budget (+slack for tiny "
                        "quiescent tails)"),
    ),
    tunable=("bg_slot_share", "bg_pace_ms", "sp_audit_ms_per_proof"),
    headline=("p99_inflation", "audit_ops", "repairs_ok",
              "background_bytes"),
    budget_s=180,
)

register(
    name="churn",
    description=("Epoch-scale membership change under a live storm: "
                 "scripted departures/crashes/joins, boundary census + "
                 "reconfiguration, re-dispersal backlog draining under "
                 "the background budget"),
    workload="zipf_hotset 400 rps through 3 epochs of scripted churn",
    section="churn",
    run=run_churn,
    slos=(
        SLO("lost_chunksets", "<=", 0,
            description="zero data loss at tolerable churn"),
        SLO("repair_failures", "<=", 0,
            description="every re-dispersal succeeded"),
        SLO("p99_inflation", "<=", "churn_p99_budget", atol=0.1,
            description="serving p99 through the membership change stays "
                        "inside the configured budget"),
    ),
    tunable=("churn_epoch_ms", "churn_drain_budget_ms", "bg_slot_share"),
    headline=("p99_inflation", "lost_chunksets", "sps_departed",
              "repairs_enqueued"),
    budget_s=240,
)

register(
    name="das",
    description=("Proof-carrying light-client sampling: measured "
                 "withholding detection on the analytic curve, plus a "
                 "cache-hostile uniform storm riding the engine "
                 "concurrently with streaming"),
    workload="das_storm (uniform, cache_bypass) + zipf streaming, interleaved",
    section="das",
    run=run_das,
    slos=(
        SLO("bytes_to_detect", "<", "full_chunk_audit_bytes",
            description="catching a withholder costs fewer wire bytes "
                        "than ONE full-chunk audit read"),
        SLO("streaming.cache_hit_polluted", "<",
            "streaming.cache_hit_under_storm",
            description="the no-bypass counterfactual measurably pollutes "
                        "the streaming LRU"),
    ),
    tunable=("das_samples_per_epoch", "das_share_bytes", "das_k"),
    headline=("bytes_to_detect", "storm.detections",
              "streaming.cache_hit_under_storm", "streaming.p99_under_storm_ms"),
    budget_s=180,
)

register(
    name="tune_admission",
    description=("One admitted Zipf storm at 3x saturation — the "
                 "optimiser's objective: max goodput s.t. p99 <= 150 ms, "
                 "every evaluated point digest-reproducible"),
    workload="zipf_hotset, poisson arrivals, 5000 rps, admitted fleet",
    section="tune_admission",
    run=run_tune_admission,
    knobs={"rpc_max_inflight_fetches": 6},
    slos=(
        SLO("p99_ms", "<=", 150.0,
            description="the tuning constraint: admitted tail at 3x "
                        "saturation stays under 150 ms"),
        SLO("goodput_mbps", ">", 0.0,
            description="the fleet actually served"),
    ),
    tunable=("rpc_max_inflight_fetches", "rpc_max_queued_requests",
             "rpc_shed_deadline_ms", "rpc_single_flight",
             "rpc_cache_ttl_ms", "rpc_hedge_deadline_factor",
             "routing_policy", "bg_slot_share"),
    headline=("goodput_mbps", "p99_ms", "shed_rate", "digest"),
    budget_s=120,
)
