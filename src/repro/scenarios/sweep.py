"""Knob-space search over registered scenarios: sweeps and hill-climbs.

The optimiser treats a scenario as a black-box objective: a
:class:`ScenarioProblem` names the scenario, the knob axes to search
(discrete candidate lists — serving knobs are budgets, policies, and
deadlines, not smooth surfaces), a dotted metric path to maximize (or
minimize), and uses the scenario's *declared SLOs* as the feasibility
constraints — "max goodput s.t. p99 <= SLO".  An infeasible point scores
``-inf`` (or ``+inf`` when minimizing), so the search can traverse
infeasible regions without ever selecting one.

Every evaluation runs headless through ``run_scenario(emit=False,
raise_on_violation=False)`` — searched points never clobber the canonical
BENCH section — and must carry the deterministic replay digest in its
payload: an :class:`EvalPoint` is reproducible bit-for-bit from
(scenario, knobs, seed), which is what makes a tuning result a citable
artifact rather than a lucky wall-clock.  Evaluations are memoized on
the knob assignment, so revisiting a point during coordinate descent is
free and the reported evaluation count is the number of *distinct*
configs run.

Two drivers:

* :meth:`ScenarioProblem.sweep` — the full cartesian grid (or any
  explicit list of points).  Exhaustive, embarrassingly parallel in
  principle, exponential in axes: for final figures.
* :meth:`ScenarioProblem.hill_climb` — cyclic coordinate descent over
  the axes: hold all knobs, try every candidate on one axis, keep the
  argmax, move to the next axis, repeat until a full cycle improves
  nothing.  Converges in O(axes x candidates x cycles) evaluations and
  is exactly the right shape for serving knobs, whose conditional
  structure (shed deadline only matters once the fetch budget binds) is
  mostly separable.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Mapping, Sequence

from repro.scenarios.registry import REGISTRY, Scenario, ScenarioError
from repro.scenarios.runner import run_scenario


class SearchError(ScenarioError):
    pass


@dataclasses.dataclass(frozen=True)
class KnobAxis:
    """One searched dimension: a ``ShelbyConfig`` field name and the
    discrete candidate values to try (include the default explicitly if
    the search should be able to keep it)."""

    name: str
    candidates: tuple

    def __post_init__(self):
        if not self.candidates:
            raise SearchError(f"axis {self.name!r} has no candidates")


@dataclasses.dataclass(frozen=True)
class EvalPoint:
    """One evaluated knob assignment: its objective value, feasibility
    (every declared SLO honored), the SLO messages when not, and the
    replay digest that makes the number reproducible."""

    knobs: Mapping[str, object]
    value: float
    feasible: bool
    violations: tuple[str, ...]
    digest: str | None
    payload: Mapping

    def score(self, maximize: bool) -> float:
        """Feasible points compare on the objective; infeasible points
        always lose (but remain in the history for the writeup)."""
        if not self.feasible:
            return -math.inf if maximize else math.inf
        return self.value

    def summary(self) -> dict:
        return {
            "knobs": dict(self.knobs),
            "value": self.value,
            "feasible": self.feasible,
            "violations": list(self.violations),
            "digest": self.digest,
        }


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """What a driver returns: the winning point, the full evaluation
    history in evaluation order, and the baseline (all-default) point
    for the improvement claim."""

    problem: "ScenarioProblem"
    best: EvalPoint
    baseline: EvalPoint
    history: tuple[EvalPoint, ...]

    @property
    def improved(self) -> bool:
        m = self.problem.maximize
        return self.best.score(m) > self.baseline.score(m)

    def to_json(self) -> dict:
        return {
            "scenario": self.problem.scenario.name,
            "objective": self.problem.objective,
            "maximize": self.problem.maximize,
            "axes": {a.name: list(a.candidates) for a in self.problem.axes},
            "evaluations": len(self.history),
            "baseline": self.baseline.summary(),
            "best": self.best.summary(),
            "improved": self.improved,
            "history": [p.summary() for p in self.history],
        }

    def dump(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")


def _freeze(knobs: Mapping[str, object]) -> tuple:
    return tuple(sorted(knobs.items()))


class ScenarioProblem:
    """max (or min) ``objective`` over the axes' cartesian knob space,
    subject to the scenario's declared SLOs."""

    def __init__(self, scenario: str | Scenario, axes: Sequence[KnobAxis],
                 objective: str, *, maximize: bool = True,
                 smoke: bool | None = None, verbose: bool = True):
        self.scenario = (scenario if isinstance(scenario, Scenario)
                         else REGISTRY.get(scenario))
        if not axes:
            raise SearchError("no axes to search")
        names = [a.name for a in axes]
        if len(set(names)) != len(names):
            raise SearchError(f"duplicate axes: {names}")
        # axis names are validated as real knobs the same way scenario
        # registration validates its overrides — a typo fails here, not
        # after an hour of evaluations
        from repro.scenarios.registry import validate_knobs
        validate_knobs({n: None for n in names},
                       where=f"problem over {self.scenario.name!r}")
        self.axes = tuple(axes)
        self.objective = objective
        self.maximize = maximize
        self.smoke = smoke
        self.verbose = verbose
        self._memo: dict[tuple, EvalPoint] = {}
        self.history: list[EvalPoint] = []

    # -- objective -----------------------------------------------------------

    def evaluate(self, knobs: Mapping[str, object]) -> EvalPoint:
        """Run the scenario at one knob assignment (memoized)."""
        key = _freeze(knobs)
        if key in self._memo:
            return self._memo[key]
        result = run_scenario(self.scenario, overrides=dict(knobs),
                              smoke=self.smoke, emit=False,
                              raise_on_violation=False)
        from repro.scenarios.report import metric_path
        value = float(metric_path(result.payload, self.objective))
        violations = tuple(r.message() for r in result.slo_results if not r.ok)
        digest = result.digest
        if digest is None:
            raise SearchError(
                f"scenario {self.scenario.name!r} payload carries no "
                f"'digest' — sweep evaluations must be replay-reproducible"
            )
        point = EvalPoint(knobs=dict(knobs), value=value,
                          feasible=not violations, violations=violations,
                          digest=digest, payload=result.payload)
        self._memo[key] = point
        self.history.append(point)
        if self.verbose:
            status = "ok" if point.feasible else "INFEASIBLE"
            print(f"# eval[{self.scenario.name}] {dict(knobs)} -> "
                  f"{self.objective}={value:.4g} [{status}] "
                  f"digest={digest}")
        return point

    def baseline(self) -> EvalPoint:
        """The all-default point: the scenario's registered knobs with no
        overrides — what the improvement claim is measured against."""
        return self.evaluate({})

    # -- drivers -------------------------------------------------------------

    def _best(self, points: Sequence[EvalPoint]) -> EvalPoint:
        return max(points, key=lambda p: (p.score(self.maximize)
                                          if self.maximize
                                          else -p.score(self.maximize)))

    def sweep(self) -> TuneResult:
        """Exhaustive cartesian grid over the axes."""
        base = self.baseline()
        assignments = [{}]
        for axis in self.axes:
            assignments = [dict(a, **{axis.name: c})
                           for a in assignments for c in axis.candidates]
        points = [self.evaluate(a) for a in assignments]
        return TuneResult(problem=self, best=self._best(points + [base]),
                          baseline=base, history=tuple(self.history))

    def hill_climb(self, start: Mapping[str, object] | None = None,
                   max_cycles: int = 4) -> TuneResult:
        """Cyclic coordinate descent from ``start`` (default: the first
        candidate on every axis).  Each step holds every other knob and
        takes the argmax over one axis' candidates; a full cycle with no
        improvement terminates."""
        current = dict(start) if start is not None else {
            a.name: a.candidates[0] for a in self.axes
        }
        missing = [a.name for a in self.axes if a.name not in current]
        if missing:
            raise SearchError(f"start point missing axes: {missing}")
        base = self.baseline()
        best = self.evaluate(current)
        for _ in range(max_cycles):
            improved = False
            for axis in self.axes:
                trials = [self.evaluate(dict(best.knobs, **{axis.name: c}))
                          for c in axis.candidates]
                cand = self._best(trials + [best])
                if cand.score(self.maximize) > best.score(self.maximize) or (
                        not best.feasible and cand.feasible):
                    improved = improved or cand.knobs != best.knobs
                    best = cand
            if not improved:
                break
        return TuneResult(problem=self, best=self._best([best, base]),
                          baseline=base, history=tuple(self.history))
