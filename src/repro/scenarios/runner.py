"""Headless scenario execution: resolve knobs, run, assert SLOs, emit.

``run_scenario`` is the one entry point every consumer shares — the
benchmark CLIs (``benchmarks/backbone_serve.py``,
``benchmarks/engine_scale.py``), the CI smoke loop
(``python -m repro.scenarios run <name>``), the sweep driver, and tests.
A scenario's ``run`` callable receives a :class:`ScenarioContext` and
returns its metrics payload; the runner then evaluates every declared
SLO against that payload (failures raise :class:`SLOViolation` naming
the scenario) and merges the payload into the BENCH sidecar.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping

from repro.configs.shelby import ShelbyConfig
from repro.scenarios.registry import (
    REGISTRY,
    Scenario,
    SLOResult,
    SLOViolation,
)
from repro.scenarios.report import emit_json


def default_smoke() -> bool:
    """CI sets ``BACKBONE_SMOKE=1`` to shrink every scenario's traffic."""
    return bool(int(os.environ.get("BACKBONE_SMOKE", "0")))


@dataclasses.dataclass(frozen=True)
class ScenarioContext:
    """What a scenario's run callable sees: its resolved config (defaults
    < scenario.knobs < call-time overrides) and the smoke flag."""

    scenario: Scenario
    config: ShelbyConfig
    smoke: bool


@dataclasses.dataclass(frozen=True)
class ScenarioResult:
    scenario: Scenario
    config: ShelbyConfig
    payload: Mapping
    slo_results: tuple[SLOResult, ...]

    @property
    def slos_ok(self) -> bool:
        return all(r.ok for r in self.slo_results)

    @property
    def digest(self) -> str | None:
        """The deterministic replay digest, when the payload carries one
        (sweep evaluations require it for reproducibility)."""
        d = self.payload.get("digest")
        return str(d) if d is not None else None


def check_slos(scenario: Scenario, payload, config: ShelbyConfig,
               *, raise_on_violation: bool = True) -> tuple[SLOResult, ...]:
    results = tuple(slo.check(payload, config) for slo in scenario.slos)
    violated = [r for r in results if not r.ok]
    for r in results:
        print(f"# slo[{scenario.name}] {r.message()}")
    if violated and raise_on_violation:
        lines = "; ".join(r.message() for r in violated)
        raise SLOViolation(
            f"scenario {scenario.name!r} violated "
            f"{len(violated)}/{len(results)} SLO(s): {lines}"
        )
    return results


def run_scenario(
    name: str | Scenario,
    *,
    overrides: Mapping[str, object] | None = None,
    smoke: bool | None = None,
    emit: bool = True,
    raise_on_violation: bool = True,
) -> ScenarioResult:
    """Run one registered scenario end to end.

    ``overrides`` layer on top of the scenario's own knobs (the sweep
    driver's handle); ``smoke`` defaults to the ``BACKBONE_SMOKE`` env;
    ``emit=False`` skips the BENCH sidecar merge (sweep evaluations
    must not clobber the canonical section with a searched point);
    ``raise_on_violation=False`` records SLO outcomes instead of
    raising (how the sweep scores infeasible points).
    """
    if isinstance(name, Scenario):
        scenario = name
    else:
        from repro.scenarios import load_builtin
        load_builtin()
        scenario = REGISTRY.get(name)
    config = scenario.config(overrides)
    ctx = ScenarioContext(
        scenario=scenario,
        config=config,
        smoke=default_smoke() if smoke is None else smoke,
    )
    payload = scenario.run(ctx)
    slo_results = check_slos(scenario, payload, config,
                             raise_on_violation=raise_on_violation)
    if emit:
        emit_json(scenario.section, payload)
    return ScenarioResult(scenario=scenario, config=config,
                          payload=payload, slo_results=slo_results)
