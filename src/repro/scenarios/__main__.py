"""Headless scenario CLI — what CI's smoke loop drives.

    python -m repro.scenarios list              # one line per scenario
    python -m repro.scenarios budgets           # "<name> <budget_s>" pairs
    python -m repro.scenarios run <name> [...]  # run + assert SLOs + emit
    python -m repro.scenarios run --all

``run`` honors ``BACKBONE_SMOKE=1`` (shrunk traffic) and ``BENCH_JSON``
(sidecar path) exactly like the historical benchmark scripts.  ``budgets``
scales each scenario's CI wall budget by ``SCENARIO_BUDGET_SCALE`` (a
float; slow runners set it > 1).
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.scenarios import REGISTRY, load_builtin, run_scenario


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.scenarios")
    sub = parser.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="one line per registered scenario")
    sub.add_parser("budgets", help="name + scaled CI budget, one per line")

    p_run = sub.add_parser("run", help="run scenario(s) headless")
    p_run.add_argument("names", nargs="*", help="scenario names")
    p_run.add_argument("--all", action="store_true", help="every scenario")
    p_run.add_argument("--no-emit", action="store_true",
                       help="skip the BENCH sidecar merge")

    args = parser.parse_args(argv)
    load_builtin()

    if args.cmd == "list":
        width = max(len(n) for n in REGISTRY.names())
        for sc in REGISTRY:
            slos = ", ".join(s.describe() for s in sc.slos) or "none"
            print(f"{sc.name:<{width}}  section={sc.section}  "
                  f"budget={sc.budget_s}s  slos: {slos}")
        return 0

    if args.cmd == "budgets":
        scale = float(os.environ.get("SCENARIO_BUDGET_SCALE", "1.0"))
        for sc in REGISTRY:
            print(f"{sc.name} {int(sc.budget_s * scale)}")
        return 0

    names = list(REGISTRY.names()) if args.all else args.names
    if not names:
        parser.error("run: give scenario names or --all")
    for name in names:
        print(f"== scenario {name} ==")
        result = run_scenario(name, emit=not args.no_emit)
        status = "ok" if result.slos_ok else "SLO VIOLATED"
        print(f"== scenario {name}: {status} ==")
    return 0


if __name__ == "__main__":
    sys.exit(main())
