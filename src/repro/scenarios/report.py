"""Benchmark reporting shared by the scenario runner and the benchmark
CLIs: CSV row emission and the machine-readable JSON sidecar CI tracks
across PRs.

Lives under ``repro.scenarios`` (not ``benchmarks/``) so registered
scenarios can emit their BENCH section headless without importing the
top-level benchmark harness; ``benchmarks.common`` re-exports these for
the suites that still print rows directly.
"""
from __future__ import annotations

import json
import os
import time


def emit_json(section: str, payload) -> None:
    """Merge ``payload`` under ``section`` into the JSON file named by the
    ``BENCH_JSON`` env var (no-op when unset).  Sections merge read-modify-
    write so several benchmark invocations in one CI run share a file —
    `scripts/ci.sh` points every suite at ``BENCH_backbone.json`` and
    uploads it as the run's bench-trajectory artifact."""
    path = os.environ.get("BENCH_JSON")
    if not path:
        return
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
            if not isinstance(doc, dict):
                doc = {}
        except (json.JSONDecodeError, OSError):
            # a corrupt/partial sidecar (killed run) must not sink the
            # whole suite: start fresh, earlier sections are lost anyway
            doc = {}
    doc[section] = payload
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)  # atomic: readers never see a half-written file


def timeit(fn, *, repeats: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def metric_path(payload, path: str):
    """Resolve a dotted path (``"5000rps.admitted.p99_ms"``) into a nested
    metrics payload.  Integer-looking segments index dict keys first (JSON
    payloads key ramp rungs by stringified counts)."""
    node = payload
    for seg in path.split("."):
        if isinstance(node, dict):
            if seg in node:
                node = node[seg]
                continue
            raise KeyError(
                f"metric path {path!r}: no key {seg!r} "
                f"(have {sorted(node)[:12]})"
            )
        if isinstance(node, (list, tuple)):
            node = node[int(seg)]
            continue
        raise KeyError(f"metric path {path!r}: {seg!r} indexes a leaf {node!r}")
    return node
