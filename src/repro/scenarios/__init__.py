"""Declarative scenario registry + SLO auto-tuning for the reproduction.

``load_builtin()`` imports every scenario module so their ``register()``
calls populate :data:`REGISTRY`; the benchmark CLIs, the CI smoke loop
(``python -m repro.scenarios``), the sweep driver, and the catalog
generator all start there.  See ``docs/scenarios.md`` for the authoring
guide and ``docs/CATALOG.md`` for the generated catalog.
"""
from repro.scenarios.registry import (
    REGISTRY,
    SLO,
    DuplicateScenarioError,
    Scenario,
    ScenarioError,
    SLOViolation,
    UnknownKnobError,
    UnknownScenarioError,
    register,
)
from repro.scenarios.runner import (
    ScenarioContext,
    ScenarioResult,
    run_scenario,
)
from repro.scenarios.sweep import (
    EvalPoint,
    KnobAxis,
    ScenarioProblem,
    TuneResult,
)

__all__ = [
    "REGISTRY", "SLO", "Scenario", "ScenarioError", "SLOViolation",
    "DuplicateScenarioError", "UnknownScenarioError", "UnknownKnobError",
    "register", "ScenarioContext", "ScenarioResult", "run_scenario",
    "KnobAxis", "ScenarioProblem", "EvalPoint", "TuneResult",
    "load_builtin",
]

_LOADED = False


def load_builtin() -> None:
    """Import the built-in scenario modules (idempotent)."""
    global _LOADED
    if _LOADED:
        return
    from repro.scenarios import serving  # noqa: F401
    from repro.scenarios import engine  # noqa: F401
    _LOADED = True
