"""The scenario registry: one declarative entry per serving regime.

A :class:`Scenario` names everything a regime needs to run headless —
the builder/workload callable, the knob overrides it applies on top of
``configs/shelby.py`` defaults, the SLOs it asserts, the BENCH section
it emits, and its CI smoke budget.  The :class:`ScenarioRegistry` maps
name -> Scenario with duplicate-name and unknown-knob rejection at
registration time, so a typo'd knob fails the import, not a CI smoke
three layers deep.

Knob resolution order (lowest to highest precedence):

    ShelbyConfig defaults  <  scenario.knobs  <  call-time overrides

Call-time overrides are how the sweep driver (``scenarios/sweep.py``)
searches knob space; every layer is validated against the dataclass
fields of ``ShelbyConfig`` and rejected with :class:`UnknownKnobError`
otherwise.

SLOs are declarative so the catalog generator and the optimiser can read
them without running anything: a dotted metric path into the scenario's
emitted payload, a comparison, and a bound that is a literal number, a
config-knob name (resolved against the scenario's *resolved* config, so
a sweep that moves the knob moves the bound), or another metric path.
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Callable, Mapping

from repro.configs.shelby import CONFIG, ShelbyConfig
from repro.scenarios.report import metric_path


class ScenarioError(Exception):
    """Base for registry misuse (bad names, bad knobs)."""


class DuplicateScenarioError(ScenarioError):
    pass


class UnknownScenarioError(ScenarioError):
    pass


class UnknownKnobError(ScenarioError):
    pass


class SLOViolation(AssertionError):
    """An asserted SLO failed.  Subclasses AssertionError so benchmark
    harnesses and CI treat it exactly like the historical inline
    asserts — but the message always leads with the scenario name."""


_KNOB_FIELDS = frozenset(f.name for f in dataclasses.fields(ShelbyConfig))

_OPS = {
    "<=": operator.le,
    "<": operator.lt,
    ">=": operator.ge,
    ">": operator.gt,
}


def validate_knobs(knobs: Mapping[str, object], *, where: str) -> None:
    """Reject any key that is not a ``ShelbyConfig`` dataclass field."""
    unknown = sorted(set(knobs) - _KNOB_FIELDS)
    if unknown:
        raise UnknownKnobError(
            f"{where}: unknown knob(s) {unknown} — not fields of "
            f"ShelbyConfig (see configs/shelby.py KNOB_DOCS)"
        )


def resolve_config(
    scenario_knobs: Mapping[str, object] | None = None,
    overrides: Mapping[str, object] | None = None,
    *,
    base: ShelbyConfig = CONFIG,
    where: str = "resolve_config",
) -> ShelbyConfig:
    """Layer knob dicts onto the base config, later layers winning:
    defaults < scenario.knobs < overrides.  Every layer is validated."""
    merged: dict[str, object] = {}
    for layer in (scenario_knobs, overrides):
        if layer:
            validate_knobs(layer, where=where)
            merged.update(layer)
    return dataclasses.replace(base, **merged) if merged else base


@dataclasses.dataclass(frozen=True)
class SLO:
    """One asserted service-level objective, evaluable from the
    scenario's emitted metrics payload alone.

    ``metric`` is a dotted path into the payload.  ``bound`` is a
    literal number, the name of a ``ShelbyConfig`` knob (resolved
    against the scenario's resolved config), or another dotted metric
    path.  ``atol`` is absolute slack on the comparison (ratio metrics
    near tiny denominators need a little)."""

    metric: str
    op: str
    bound: float | int | str
    atol: float = 0.0
    description: str = ""

    def __post_init__(self):
        if self.op not in _OPS:
            raise ScenarioError(
                f"SLO op must be one of {sorted(_OPS)}, got {self.op!r}"
            )

    def resolve_bound(self, payload, config: ShelbyConfig) -> float:
        if isinstance(self.bound, (int, float)):
            return float(self.bound)
        if self.bound in _KNOB_FIELDS:
            return float(getattr(config, self.bound))
        return float(metric_path(payload, self.bound))

    def check(self, payload, config: ShelbyConfig) -> "SLOResult":
        value = float(metric_path(payload, self.metric))
        bound = self.resolve_bound(payload, config)
        slack = self.atol if self.op in ("<=", "<") else -self.atol
        ok = bool(_OPS[self.op](value, bound + slack))
        return SLOResult(slo=self, value=value, bound=bound, ok=ok)

    def describe(self) -> str:
        return f"{self.metric} {self.op} {self.bound}"


@dataclasses.dataclass(frozen=True)
class SLOResult:
    slo: SLO
    value: float
    bound: float
    ok: bool

    def message(self) -> str:
        status = "OK" if self.ok else "VIOLATED"
        return (f"{self.slo.metric} = {self.value:.4g} {self.slo.op} "
                f"{self.bound:.4g} [{status}]")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One registry entry: everything needed to run a named regime
    headless, assert its SLOs, and emit its BENCH section."""

    name: str
    description: str
    workload: str                       # one line for the catalog
    section: str                        # BENCH_backbone.json section key
    run: Callable                       # (ScenarioContext) -> metrics dict
    knobs: Mapping[str, object] = dataclasses.field(default_factory=dict)
    slos: tuple[SLO, ...] = ()
    tunable: tuple[str, ...] = ()       # knobs a sweep typically searches
    headline: tuple[str, ...] = ()      # payload paths the catalog quotes
    budget_s: int = 180                 # CI smoke wall budget (seconds)

    def __post_init__(self):
        validate_knobs(self.knobs, where=f"scenario {self.name!r} knobs")
        validate_knobs({k: None for k in self.tunable},
                       where=f"scenario {self.name!r} tunable")

    def config(self, overrides: Mapping[str, object] | None = None,
               *, base: ShelbyConfig = CONFIG) -> ShelbyConfig:
        """The resolved config this scenario runs under (plus optional
        call-time overrides — the sweep driver's entry point)."""
        return resolve_config(self.knobs, overrides, base=base,
                              where=f"scenario {self.name!r}")


class ScenarioRegistry:
    """Name -> Scenario, insertion-ordered, duplicate-rejecting."""

    def __init__(self):
        self._scenarios: dict[str, Scenario] = {}

    def register(self, scenario: Scenario) -> Scenario:
        if scenario.name in self._scenarios:
            raise DuplicateScenarioError(
                f"scenario {scenario.name!r} already registered"
            )
        self._scenarios[scenario.name] = scenario
        return scenario

    def get(self, name: str) -> Scenario:
        try:
            return self._scenarios[name]
        except KeyError:
            raise UnknownScenarioError(
                f"no scenario {name!r}; registered: {self.names()}"
            ) from None

    def names(self) -> list[str]:
        return list(self._scenarios)

    def __iter__(self):
        return iter(self._scenarios.values())

    def __contains__(self, name: str) -> bool:
        return name in self._scenarios

    def __len__(self) -> int:
        return len(self._scenarios)


REGISTRY = ScenarioRegistry()


def register(**kwargs) -> Scenario:
    """Build a Scenario from kwargs and add it to the module registry."""
    return REGISTRY.register(Scenario(**kwargs))
