"""The engine scale ramp as a registry entry: million-request worlds.

A 500-SP / 50-RPC world serves a Zipf read storm at three sizes —
10k -> 100k -> 1M requests — through the cohort fast path
(``repro.net.fastpath``): warm-cache cohorts advance as numpy array steps,
cold-key first touchers de-opt to full generator tasks on the calendar-queue
event loop, and settlement debits each serving node's channel once per
cohort.  Three regression-shaped bars:

* **Determinism** (inline assert — structural): two fast replays of the
  same 10k batch on fresh fleets produce byte-identical digests, AND the
  digest equals a task-per-request replay of the identical schedule on
  the binary-heap baseline engine.
* **Throughput** (declared SLO): at the 100k rung the fast path clears
  >= 10x the heap-baseline engine events/sec.
* **Scale**: the 1M-request rung completes inside the scenario's CI
  budget (enforced by the smoke loop's wall clock, not an assert).

This scenario ignores the smoke flag — the ramp IS the point, and the
``engine`` BENCH section's schema must not change shape under CI.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.backbone import Backbone
from repro.net.fleet import RPCFleet
from repro.net.workloads import replay_open_loop, zipf_hotset_batch
from repro.scenarios.registry import SLO, register
from repro.scenarios.report import row
from repro.scenarios.runner import ScenarioContext
from repro.storage.blob import BlobLayout
from repro.storage.rpc import BackboneTransport, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import ServiceSpec, StorageProvider

NUM_SPS = 500
NUM_RPCS = 50
NUM_BLOBS = 192  # single-chunkset blobs: every read is exactly one leg
RAMP = (10_000, 100_000, 1_000_000)
CACHE_CHUNKSETS = 16  # x50 nodes: the whole key set fits, no eviction


def _world():
    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract()
    bb = Backbone.mesh(5, base_latency_ms=6.0, gbps=25.0)
    rng = np.random.default_rng(99)
    sps = {}
    for i in range(NUM_SPS):
        dc = f"dc{i % 5}"
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=dc,
                                    rack=f"r{i % 20}"))
        sps[i] = StorageProvider(i, service=ServiceSpec(disk_ms_per_chunk=0.5,
                                                        slots=4))
        sps[i].behavior.latency_ms = float(rng.uniform(1.0, 8.0))
        bb.register_node(f"sp{i}", dc)
    for c in range(3):
        bb.register_node(f"client{c}", f"dc{c}")
    bb.register_node("writer", "dc0")
    writer = RPCNode("writer", contract, sps, layout)
    put_client = ShelbyClient(contract, writer, deposit=1e9)
    metas = []
    for _ in range(NUM_BLOBS):
        # <= one chunkset of payload each, so offset 0 + whole-blob reads
        # never span chunksets (the fast path's exact-equality regime)
        data = rng.integers(0, 256, 50_000, dtype=np.uint8).tobytes()
        metas.append(put_client.put(data))
    return layout, contract, bb, sps, metas


def _fleet(cfg, layout, contract, bb, sps):
    rpcs = []
    for r in range(NUM_RPCS):
        node = f"rpc{r}"
        if node not in bb._node_dc:
            bb.register_node(node, f"dc{r % 5}")
        rpcs.append(RPCNode(node, contract, sps, layout,
                            cache_chunksets=CACHE_CHUNKSETS,
                            transport=BackboneTransport(sps, bb, node)))
    bb.reset_accounting()
    return RPCFleet(rpcs, cfg.policy(), backbone=bb)


def _batch(metas, n):
    return zipf_hotset_batch(
        metas, clients=["client0", "client1", "client2"], num_requests=n,
        read_bytes=64 * 1024, interarrival_ms=0.05, seed=23, arrival="poisson",
    )


def run_engine(ctx: ScenarioContext) -> dict:
    cfg = ctx.config
    t0 = time.perf_counter()
    layout, contract, bb, sps, metas = _world()
    print(f"# world: {NUM_SPS} SPs / {NUM_RPCS} RPCs / {NUM_BLOBS} blobs "
          f"({time.perf_counter() - t0:.1f}s to build)")

    ramp_json = {}
    speedup_100k = None
    digest_10k = None

    for n in RAMP:
        batch = _batch(metas, n)

        # -- fast path through the paid session (batched settlement) --------
        fleet = _fleet(cfg, layout, contract, bb, sps)
        reader = ShelbyClient(contract, fleet, deposit=1e9)
        wall0 = time.perf_counter()
        with reader.session(deposit_per_node=1e6) as session:
            rb, fast = session.replay(batch)
        wall_fast = time.perf_counter() - wall0
        settlement = session.settlement
        co = fast.cohort
        assert co.fallback_reason is None, (
            f"fast path fell back at {n}: {co.fallback_reason}"
        )
        # conservation on arrays: the cohort's one-debit-per-node totals +
        # de-opted per-request receipts == realized node income
        assert abs(settlement.total_node_income
                   - (rb.total_paid
                      + sum(r.total_paid for r in session.receipts))) < 1e-6

        entry = {
            "requests": n,
            "wall_s": wall_fast,
            "engine_events": fast.engine_events,
            "engine_wall_s": fast.engine_wall_s,
            "events_per_sec": fast.engine_events_per_sec,
            "requests_per_sec": n / wall_fast,
            "vec_requests": co.vec_requests,
            "deopt_requests": co.deopt_requests,
            "coalesced_legs": co.coalesced,
            "p50_ms": fast.percentile(50.0),
            "p99_ms": fast.percentile(99.0),
            "goodput_mbps": fast.goodput_mbps,
        }
        row(
            f"engine_scale/fast_{n}",
            wall_fast * 1e6 / n,
            f"events_per_sec={fast.engine_events_per_sec:.0f};"
            f"vec={co.vec_requests};deopt={co.deopt_requests};"
            f"p99={entry['p99_ms']:.1f}ms",
        )

        if n <= 100_000:
            # -- heap-engine task-per-request baseline on a fresh fleet ------
            fleet_h = _fleet(cfg, layout, contract, bb, sps)
            reqs = batch.to_requests()
            wall0 = time.perf_counter()
            base = replay_open_loop(fleet_h, reqs, engine="heap")
            wall_heap = time.perf_counter() - wall0
            entry["heap_baseline"] = {
                "wall_s": wall_heap,
                "engine_events": base.engine_events,
                "events_per_sec": base.engine_events_per_sec,
                "requests_per_sec": n / wall_heap,
            }
            row(
                f"engine_scale/heap_{n}",
                wall_heap * 1e6 / n,
                f"events_per_sec={base.engine_events_per_sec:.0f}",
            )
            if n == 10_000:
                # exact digest equality: fast cohort vs heap task engine,
                # plus fast-path determinism on a third fresh fleet
                assert fast.digest() == base.digest(), (
                    f"fast/task digest mismatch at {n}: "
                    f"{fast.digest()[:16]} != {base.digest()[:16]}"
                )
                from repro.net.fastpath import replay_open_loop_fast

                redo = replay_open_loop_fast(
                    _fleet(cfg, layout, contract, bb, sps), batch)
                assert redo.digest() == fast.digest(), "fast path not deterministic"
                digest_10k = fast.digest()
                print(f"# engine digest (fast == heap task): "
                      f"{digest_10k[:16]} OK")
            if n == 100_000:
                speedup_100k = (fast.engine_events_per_sec
                                / base.engine_events_per_sec)
                print(f"# engine speedup at 100k: {speedup_100k:.1f}x "
                      f"({fast.engine_events_per_sec:.0f} vs "
                      f"{base.engine_events_per_sec:.0f} events/s)")
        ramp_json[f"{n}"] = entry

    return {
        "world": {"sps": NUM_SPS, "rpcs": NUM_RPCS, "blobs": NUM_BLOBS,
                  "cache_chunksets": CACHE_CHUNKSETS},
        "ramp": ramp_json,
        "digest_10k": digest_10k[:16],
        "speedup_events_per_sec_100k": speedup_100k,
    }


register(
    name="engine",
    description=("Event-engine scale ramp: 500 SPs / 50 RPCs, Zipf batch "
                 "at 10k/100k/1M requests through the cohort fast path vs "
                 "the heap task-per-request baseline"),
    workload="zipf_hotset_batch, poisson arrivals, 3-size ramp (never shrunk)",
    section="engine",
    run=run_engine,
    slos=(
        SLO("speedup_events_per_sec_100k", ">=", 10.0,
            description="the cohort fast path clears >=10x the heap "
                        "baseline's events/sec at the 100k rung"),
    ),
    tunable=("event_engine",),
    headline=("speedup_events_per_sec_100k", "ramp.1000000.requests_per_sec",
              "ramp.1000000.wall_s"),
    budget_s=420,
)
