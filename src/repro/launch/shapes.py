"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation — the dry-run lowers against
these.  ``[audio]``/``[vlm]`` archs receive precomputed frame/patch
embeddings (the modality frontend is a stub, per the assignment)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShapeCell
from repro.models.model import build
from repro.sharding import ParamSpec, logical_to_spec

# microbatch counts for train_4k (activation-memory control; see DESIGN.md §5)
TRAIN_MICROBATCHES = {
    "command-r-plus-104b": "max",  # one sample per device per microbatch
    "yi-9b": 8,
    "granite-8b": 8,
    "falcon-mamba-7b": 8,
    "starcoder2-3b": 4,
    "phi-3-vision-4.2b": 4,
    "qwen3-moe-30b-a3b": 4,
    "deepseek-v2-lite-16b": 4,
    "hymba-1.5b": 4,
    "whisper-tiny": 1,
}


def num_microbatches(cfg: ArchConfig, shape: ShapeCell, mesh) -> int:
    if shape.kind != "train":
        return 1
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    req = TRAIN_MICROBATCHES.get(cfg.name, 1)
    cap = max(shape.global_batch // dp, 1)
    return cap if req == "max" else min(req, cap)


def _struct(shape, dtype, axes, rules, mesh):
    spec = logical_to_spec(axes, shape, rules, mesh)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ArchConfig, shape: ShapeCell, rules, mesh) -> dict:
    """The data-batch structs for a cell (train/prefill); decode handled
    separately (cache + token)."""
    gb, s = shape.global_batch, shape.seq_len
    tok = lambda: _struct((gb, s), jnp.int32, ("batch", "seq"), rules, mesh)
    lab = lambda: _struct((gb, s), jnp.int32, ("batch", "seq"), rules, mesh)
    emb = lambda: _struct((gb, s, cfg.d_model), jnp.bfloat16, ("batch", "seq", "embed_act"), rules, mesh)

    if shape.kind == "train":
        if cfg.is_encdec:
            frames = _struct((gb, cfg.enc_seq, cfg.d_model), jnp.bfloat16,
                             ("batch", "frames", "embed_act"), rules, mesh)
            return {"frames": frames, "tokens": tok(), "labels": lab()}
        if cfg.input_mode == "embeddings":
            return {"embeddings": emb(), "labels": lab()}
        return {"tokens": tok(), "labels": lab()}

    if shape.kind == "prefill":
        if cfg.is_encdec:  # prefill = encoder pass over `seq_len` frames
            frames = _struct((gb, s, cfg.d_model), jnp.bfloat16,
                             ("batch", "frames", "embed_act"), rules, mesh)
            return {"frames": frames}
        if cfg.input_mode == "embeddings":
            return {"embeddings": emb()}
        return {"tokens": tok()}

    raise ValueError(shape.kind)


def decode_specs(cfg: ArchConfig, shape: ShapeCell, rules, mesh, *, long_mode: bool):
    """(cache_structs, token_struct, pos_struct) for serve_step."""
    model = build(cfg)
    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len, long_mode=long_mode)
    cache = jax.tree.map(
        lambda sp: _struct(sp.shape, sp.dtype, sp.axes, rules, mesh),
        cache_specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    tokens = _struct((shape.global_batch, 1), jnp.int32, ("batch", None), rules, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, pos


def serve_param_specs(model):
    """bf16 inference weights (no optimizer, no master copies)."""
    return jax.tree.map(
        lambda sp: ParamSpec(sp.shape, sp.axes, dtype=jnp.bfloat16, init=sp.init, scale=sp.scale),
        model.param_specs(),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
