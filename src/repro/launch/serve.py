"""Serving driver: batched requests against weights distributed via Shelby.

The inference-node lifecycle the paper's §6 envisions: join, open payment
channels, pull the published weight blobs through verified hedged reads,
then serve batched generate requests with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --batch 4 --prompt-len 8 --gen 16 [--kill-sp]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get, get_smoke
from repro.launch.train import build_cluster
from repro.models.model import build
from repro.serve.engine import ServeEngine
from repro.sharding import init_params
from repro.storage.checkpoint import CheckpointManager


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kill-sp", action="store_true",
                    help="crash an SP between publish and serve")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    contract, sps, rpc, client = build_cluster(num_sps=8)

    # publisher pushes weights into Shelby
    model = build(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(7))
    mgr = CheckpointManager(client, num_host_shards=2)
    rec = mgr.save(step=0, state=params)
    print(f"[serve] published {rec.total_bytes} weight bytes "
          f"(blobs {rec.shard_blob_ids}, {rpc.layout.replication_overhead:.2f}x overhead)")

    if args.kill_sp:
        victim = contract.blobs[rec.shard_blob_ids[0]].placement[(0, 0)]
        sps[victim].crash()
        print(f"[serve] SP {victim} crashed; download proceeds k-of-n")

    t0 = time.time()
    served = jax.tree.map(jax.numpy.asarray, mgr.restore(0, params))
    print(f"[serve] weights restored+verified in {time.time() - t0:.2f}s; "
          f"read payments ${rpc.stats.payments:.6f}")

    engine = ServeEngine(cfg, served, max_len=args.prompt_len + args.gen + 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    t1 = time.time()
    out = engine.generate(prompts, num_tokens=args.gen)
    dt = time.time() - t1
    tok = engine.stats.decoded_tokens
    print(f"[serve] batch {out.shape}: {tok} tokens in {dt:.2f}s "
          f"({tok / dt:.1f} tok/s on CPU)")
    assert (out[:, : args.prompt_len] == prompts).all()
    return out


if __name__ == "__main__":
    main()
