"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()[:ndev]
    if len(devices) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, have {len(jax.devices())} "
            "(dry-runs must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax)"
        )
    return jax.make_mesh(shape, axes, devices=devices)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    devices = jax.devices()[: data * model]
    return jax.make_mesh((data, model), ("data", "model"), devices=devices)
