"""End-to-end training driver: Shelby storage plane + JAX compute plane.

Builds a simulated Shelby deployment (contract + SPs + RPC), writes the
token corpus into it, then trains with coded checkpointing, hedged data
reads, SP failure injection and restart.  ``--arch`` accepts any assigned
architecture (reduced configs via --smoke for CPU).

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
      --steps 40 --fail-at 25
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import ALL_ARCHS, get, get_smoke
from repro.configs.shelby import CONFIG, resolve_decode_matmul
from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.data.pipeline import BlobTokenDataset, write_token_corpus
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.storage.blob import BlobLayout
from repro.storage.checkpoint import CheckpointManager
from repro.storage.repair import RepairCoordinator
from repro.storage.rpc import RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import ServiceSpec, StorageProvider
from repro.train.loop import Trainer


def build_cluster(num_sps: int = 8, layout: BlobLayout | None = None,
                  num_rpcs: int = 1):
    """A simulated deployment fronted by the fleet-first client.

    The batched Clay decode's GF matmul comes from `configs/shelby.py`
    (numpy on CPU, the Pallas kernel on real TPU runtimes).
    """
    layout = layout or BlobLayout(k=4, m=2, chunkset_bytes_target=256 * 1024)
    contract = ShelbyContract()
    sps = {}
    for i in range(num_sps):
        contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 3}", rack=f"r{i % 4}"))
        sps[i] = StorageProvider(
            i, service=ServiceSpec(slots=CONFIG.sp_service_slots)
        )
    matmul = resolve_decode_matmul(CONFIG.decode_matmul)
    rpcs = [
        RPCNode(f"rpc{r}", contract, sps, layout, cache_chunksets=32,
                decode_matmul=matmul,
                cache_ttl_ms=CONFIG.rpc_cache_ttl_ms,
                cache_admit_bytes=CONFIG.rpc_cache_admit_bytes,
                admission=CONFIG.admission(),
                single_flight=CONFIG.rpc_single_flight)
        for r in range(num_rpcs)
    ]
    fleet = RPCFleet(rpcs, CacheAffinityPolicy())
    client = ShelbyClient(contract, fleet, deposit=1e9, das=CONFIG.das())
    return contract, sps, fleet.primary, client


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="crash an SP + restart from coded checkpoint at this step")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    contract, sps, rpc, client = build_cluster()

    # corpus lives in Shelby; the pipeline is a paying, hedged read client
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, 200_000, dtype=np.int32)
    corpus = write_token_corpus(client, tokens)
    ds = BlobTokenDataset(client, corpus, batch=args.batch, seq_len=args.seq)

    ckpt = CheckpointManager(client, num_host_shards=2)
    repair = RepairCoordinator(contract, sps, rpc.layout)
    trainer = Trainer(cfg, ckpt=ckpt, repair=repair, ckpt_every=args.ckpt_every)
    state = trainer.init_state()

    batches = ds.batches(args.steps * 2, background=False)
    if args.fail_at and args.fail_at < args.steps:
        state, rep1 = trainer.run(state, batches, args.fail_at)
        print(f"[driver] step {args.fail_at}: loss={rep1.final_loss:.4f} — injecting SP failure")
        victim = next(iter(sps))
        sps[victim].crash()
        # restart: restore from coded checkpoint (k-of-n reads absorb the loss)
        restored, step0 = trainer.restore_latest(state)
        if restored is None:
            restored, step0 = state, args.fail_at
        print(f"[driver] restarted from step {step0} with SP {victim} down")
        sps[victim].recover()
        sps[victim].wipe()
        n_rep = len(repair.repair_all())
        print(f"[driver] repaired {n_rep} chunks (MSR where possible)"
              + (f"; {len(repair.failures)} UNRECOVERABLE" if repair.failures else ""))
        state, rep2 = trainer.run(restored, batches, args.steps - step0, start_step=step0)
        losses = rep1.losses + rep2.losses
    else:
        state, rep = trainer.run(state, batches, args.steps)
        losses = rep.losses

    settlement = client.settle()  # broadcast refunds; SPs realize income
    print(f"[driver] done: steps={len(losses)} first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"reads_paid=${settlement.total_node_income:.6f} "
          f"sp_income=${sum(settlement.sp_income.values()):.6f} "
          f"cache_hits={rpc.stats.cache_hits}")
    k = max(len(losses) // 4, 1)  # head/tail means: single steps are noisy
    assert sum(losses[-k:]) / k < sum(losses[:k]) / k, "loss must decrease"
    return losses


if __name__ == "__main__":
    main()
