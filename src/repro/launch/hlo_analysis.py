"""Post-SPMD HLO analysis: collective-byte accounting for the roofline.

``cost_analysis()`` gives FLOPs and memory bytes but NOT collective traffic,
so (per the brief) we parse ``compiled.as_text()`` — the partitioned,
per-device HLO — and sum operand/result sizes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute.

Collectives inside ``while`` bodies (scan over layers / microbatches / KV
chunks) appear once in the text but execute ``trip_count`` times; we
recover each loop's trip count from the integer constant its condition
computation compares the induction variable against, and walk the call
graph (entry -> while bodies -> nested) multiplying as we go.

Wire-byte model (per device, ring algorithms, group size g):
    all-reduce       2 * bytes * (g-1)/g
    all-gather       out_bytes * (g-1)/g
    reduce-scatter   in_bytes  * (g-1)/g
    all-to-all       bytes * (g-1)/g
    collective-permute   bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    """'f32[4,128]' or tuple '(f32[..], s32[..])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    count: float = 0.0
    bytes: float = 0.0  # operand bytes (brief's definition)
    wire_bytes: float = 0.0  # ring-model bytes on the wire per device

    def as_dict(self):
        return {"count": self.count, "bytes": self.bytes, "wire_bytes": self.wire_bytes}


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Computation headers sit at column 0: ``%name (args) -> ret {`` (or
    ``ENTRY %name (...) {``); bodies are indented; ``}`` closes."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if line.startswith(("%", "ENTRY")) and line.rstrip().endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        stripped = line.strip()
        if stripped == "}" or stripped.startswith("} "):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _entry_name(hlo: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.MULTILINE)
    return m.group(1) if m else None


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_SET_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return total_devices


def _trip_count(while_line: str, cond_lines: list[str]) -> int:
    """Prefer XLA's own ``known_trip_count`` annotation on the while op;
    fall back to the largest integer constant in the condition."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', while_line)
    if m:
        return int(m.group(1))
    best = 1
    for line in cond_lines:
        for c in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(c.group(1)))
    return best


_SKIP_BYTES_OPS = (
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "while",
    "after-all", "partition-id", "replica-id", "iota",
)


def analyze_hlo(hlo: str, total_devices: int) -> dict:
    """Loop-corrected collectives + FLOPs + fusion-boundary bytes.

    XLA:CPU's cost_analysis does not multiply ``while`` bodies by their trip
    count, so scan-over-layers programs under-report ~L-fold.  We redo the
    accounting here: per-computation tallies, then a call-graph walk with
    while-trip multipliers.

    * FLOPs: 2 * numel(result) * K for every ``dot`` (fusion bodies included).
    * bytes: operand+result sizes at fusion boundaries / top-level ops — the
      standard roofline approximation of HBM traffic (fusion-internal
      values never hit HBM).
    * collectives: see module docstring.
    """
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)

    # name -> result type string (for operand size lookups)
    def_types: dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                def_types[m.group(1)] = m.group(2).split(" ", 1)[0]

    calls: dict[str, list[tuple[str, int]]] = defaultdict(list)
    fusion_bodies: set[str] = set()
    per_comp_coll: dict[str, list[tuple[str, float, float]]] = defaultdict(list)
    per_comp_flops: dict[str, float] = defaultdict(float)
    per_comp_bytes: dict[str, float] = defaultdict(float)

    op_name_re = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")

    # Pass A: params consumed ONLY via (dynamic-)slice inside each body —
    # at the call site such an operand contributes the slice bytes, not the
    # whole (possibly L-stacked) array.
    param_slice_bytes: dict[str, dict[int, float]] = {}
    for cname, lines in comps.items():
        params: dict[str, int] = {}
        for line in lines:
            pm = re.match(r"\s*%?([\w\.\-]+)\s*=\s*\S+\s+parameter\((\d+)\)", line)
            if pm:
                params[pm.group(1)] = int(pm.group(2))
        if not params:
            continue
        sliced: dict[str, float] = {p: 0.0 for p in params}
        dirty: set[str] = set()
        for line in lines:
            s = line.strip()
            m = _DEF_RE.match(s)
            if not m or " parameter(" in s:
                continue
            rhs = m.group(2)
            is_slice = re.search(r"\s(dynamic-slice|slice)\(", " " + rhs)
            out_b = _shape_bytes(rhs.split(" ", 1)[0])
            for p in params:
                if re.search(rf"%{re.escape(p)}\b", rhs):
                    if is_slice:
                        sliced[p] += out_b
                    else:
                        dirty.add(p)
        param_slice_bytes[cname] = {
            params[p]: b for p, b in sliced.items() if b > 0 and p not in dirty
        }

    for cname, lines in comps.items():
        for line in lines:
            s = line.strip()
            if not s or s.startswith("//"):
                continue
            wm = re.search(r"while\(.*?\).*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", s)
            if wm:
                trips = _trip_count(s, comps.get(wm.group(1), []))
                calls[cname].append((wm.group(2), trips))
                continue
            callee = None
            cm = re.search(r"(?:fusion|call)\(.*?\).*(?:calls|to_apply)=%?([\w\.\-]+)", s)
            if cm:
                callee = cm.group(1)
                calls[cname].append((callee, 1))
                if "fusion(" in s:
                    fusion_bodies.add(callee)

            m = _DEF_RE.match(s)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            om = op_name_re.search("= " + rhs) or op_name_re.search(s)
            opkind = om.group(1) if om else ""
            result_type = rhs.split(" ", 1)[0]
            out_bytes = _shape_bytes(result_type)
            opargs = re.search(rf"{re.escape(opkind)}\(([^)]*)\)", rhs) if opkind else None
            in_bytes = 0.0
            if opargs:
                slice_adj = param_slice_bytes.get(callee, {}) if callee else {}
                for i, op in enumerate(opargs.group(1).split(",")):
                    op = op.strip().lstrip("%")
                    if i in slice_adj:  # fusion slices this operand internally
                        in_bytes += slice_adj[i]
                    else:
                        in_bytes += _shape_bytes(def_types.get(op, ""))

            # --- FLOPs: dot ops ---
            if opkind == "dot":
                km = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", s)
                lhs_name = opargs.group(1).split(",")[0].strip().lstrip("%") if opargs else ""
                lhs_type = def_types.get(lhs_name, "")
                k = 1
                if km and lhs_type:
                    sm = _SHAPE_RE.search(lhs_type)
                    if sm and sm.group(2):
                        dims = [int(x) for x in sm.group(2).split(",")]
                        for ci in km.group(1).split(","):
                            if ci != "":
                                k *= dims[int(ci)]
                numel = out_bytes / max(_DTYPE_BYTES.get(result_type.split("[")[0], 4), 1)
                per_comp_flops[cname] += 2.0 * numel * k

            # --- bytes at fusion boundaries / top-level ops ---
            # slicing ops only touch the slice, not the whole operand (a
            # dynamic-slice of stacked (L, ...) weights inside scan reads
            # one layer, not L); copies/converts move out_bytes once.
            if opkind and opkind not in _SKIP_BYTES_OPS:
                if opkind in ("dynamic-slice", "gather", "slice"):
                    op_bytes = 2.0 * out_bytes
                elif opkind == "dynamic-update-slice":
                    # read-modify-write of the update region only
                    upd = 0.0
                    if opargs:
                        parts = [o.strip().lstrip("%") for o in opargs.group(1).split(",")]
                        if len(parts) >= 2:
                            upd = _shape_bytes(def_types.get(parts[1], ""))
                    op_bytes = 2.0 * upd
                elif opkind in ("convert", "copy", "transpose", "reshape", "broadcast"):
                    op_bytes = 2.0 * out_bytes
                elif opkind == "scatter":
                    op_bytes = in_bytes - out_bytes + 2.0 * out_bytes if in_bytes > out_bytes else 2.0 * out_bytes
                else:
                    op_bytes = in_bytes + out_bytes
                per_comp_bytes[cname] += op_bytes

            # --- collectives ---
            base = opkind.replace("-start", "")
            if base in _COLLECTIVES and not opkind.endswith("-done"):
                g = _group_size(s, total_devices)
                frac = (g - 1) / max(g, 1)
                if base == "all-reduce":
                    wire = 2 * out_bytes * frac
                elif base == "all-gather":
                    wire = out_bytes * frac
                elif base == "reduce-scatter":
                    wire = in_bytes * frac
                elif base == "all-to-all":
                    wire = max(in_bytes, out_bytes) * frac
                else:
                    wire = out_bytes
                per_comp_coll[cname].append((base, max(in_bytes, out_bytes), wire))

    totals: dict[str, CollectiveStats] = defaultdict(CollectiveStats)
    agg = {"flops": 0.0, "bytes": 0.0}
    seen_stack: set[str] = set()

    def walk(comp: str, mult: float):
        if comp in seen_stack:
            return
        seen_stack.add(comp)
        for kind, b, w in per_comp_coll.get(comp, []):
            st = totals[kind]
            st.count += mult
            st.bytes += b * mult
            st.wire_bytes += w * mult
        agg["flops"] += per_comp_flops.get(comp, 0.0) * mult
        if comp not in fusion_bodies:  # fusion-internal values never hit HBM
            agg["bytes"] += per_comp_bytes.get(comp, 0.0) * mult
        for callee, m in calls.get(comp, []):
            walk(callee, mult * m)
        seen_stack.discard(comp)

    if entry:
        walk(entry, 1.0)
    else:
        for comp in set(per_comp_coll) | set(per_comp_flops):
            walk(comp, 1.0)
    return {
        "collectives": {k: v.as_dict() for k, v in totals.items()},
        "hlo_flops": agg["flops"],
        "hlo_bytes": agg["bytes"],
    }


def analyze_collectives(hlo: str, total_devices: int) -> dict[str, dict]:
    return analyze_hlo(hlo, total_devices)["collectives"]


def cpu_bf16_inflation_bytes(hlo: str) -> int:
    """XLA:CPU has no native bf16 compute: FloatNormalization inserts
    f32 converts of whole bf16 parameters, which get hoisted out of while
    loops and show up as multi-GB temps.  A TPU compile keeps bf16 end to
    end, so for 'does it fit' we subtract the f32 copies of entry-level
    parameters.  Returns the total bytes of such hoisted f32 buffers."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    if entry is None:
        return 0
    total = 0
    for line in comps.get(entry, []):
        s = line.strip()
        m = re.match(
            r"%?[\w\.\-]+\s*=\s*(f32\[[\d,]*\])\S*\s+"
            r"(?:convert|copy|fusion)\(\s*%?(param[\w\.\-]*)\s*\)", s)
        if m:
            total += _shape_bytes(m.group(1))

    # In-loop f32 temps of bf16 buffers: XLA:CPU converts whole bf16 loop
    # carries to f32 around dynamic-update-slice etc. (e.g. a 12.9 GB
    # f32[64,1,4096,12288] copy of the bf16 remat-carry stack in the
    # command-r train cell).  On TPU the op runs on bf16 in place.  Count
    # each distinct >64 MB f32 shape that has a same-shape bf16 twin, once.
    def_types: dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                def_types[m.group(1)] = m.group(2).split(" ", 1)[0]
    bf16_shapes = {t.split("]")[0].split("[")[1] for t in def_types.values()
                   if t.startswith("bf16[")}
    seen: set[str] = set()
    for lines in comps.values():
        for line in lines:
            m = re.search(r"=\s*(f32)\[([\d,]*)\]\S*\s+convert\(", line)
            if not m:
                continue
            dims = m.group(2)
            if dims in seen or dims not in bf16_shapes:
                continue
            b = _shape_bytes(f"f32[{dims}]")
            if b > 64 * 1024 * 1024:
                seen.add(dims)
                total += b
    return total


def summarize(collectives: dict[str, dict]) -> dict[str, float]:
    return {
        "collective_bytes": sum(v["bytes"] for v in collectives.values()),
        "collective_wire_bytes": sum(v["wire_bytes"] for v in collectives.values()),
        "collective_count": sum(v["count"] for v in collectives.values()),
    }
