import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the step function (train_step / prefill_step / decode_step),
  3. ``jit(...).lower(**input_specs).compile()`` against ShapeDtypeStructs
     (no allocation),
  4. prints ``compiled.memory_analysis()`` (proves it fits) and
     ``cost_analysis()`` (FLOPs/bytes for the roofline),
  5. parses the partitioned HLO for collective bytes,
  6. appends a JSON record to --out (resumable cache keyed by cell id).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, get
from repro.configs.base import SHAPES, cell_applicable
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import batch_specs, decode_specs, num_microbatches, serve_param_specs
from repro.models.model import build
from repro.sharding import (
    DECODE_RULES,
    LONG_RULES,
    SERVE_RULES,
    TRAIN_RULES,
    AxisCtx,
    tree_shape_structs,
    tree_shardings,
)
from repro.train.step import make_decode_step, make_prefill_step, make_train_step

DEFAULT_OUT = "results/dryrun"


def _cell_id(arch: str, shape: str, mesh_kind: str) -> str:
    return f"{arch}__{shape}__{mesh_kind}"


def _opt_state_structs(param_specs, rules, mesh):
    """ShapeDtypeStructs for {params, m, v, step} with ZeRO-1 shardings."""
    shardings = tree_shardings(param_specs, rules, mesh)
    p = tree_shape_structs(param_specs, shardings)
    return {
        "params": p,
        "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jax.numpy.float32, sharding=s.sharding), p),
        "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jax.numpy.float32, sharding=s.sharding), p),
        "step": jax.ShapeDtypeStruct((), jax.numpy.int32),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, save_hlo: str | None = None,
             rules_override=None, tag: str = "", shard_grad_accum: bool = False,
             remat_policy=None, microbatch_override: int | None = None) -> dict:
    cfg = get(arch)
    shape = next(s for s in SHAPES if s.name == shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec.update({"status": "skipped", "reason": why})
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    ndev = 1
    for v in mesh.shape.values():
        ndev *= v
    long_mode = shape.name.startswith("long")
    if shape.kind == "train":
        rules = dict(TRAIN_RULES)
    elif long_mode:
        rules = dict(LONG_RULES)
    elif shape.kind == "decode":
        rules = dict(DECODE_RULES)
    else:
        rules = dict(SERVE_RULES)
    if rules_override:
        rules.update(rules_override)
    ctx = AxisCtx(rules, mesh, remat_policy=remat_policy)
    model = build(cfg)

    t0 = time.time()
    try:
        if shape.kind == "train":
            n_mb = microbatch_override or num_microbatches(cfg, shape, mesh)
            step = make_train_step(cfg, ctx, num_microbatches=n_mb,
                                   shard_grad_accum=shard_grad_accum)
            state = _opt_state_structs(model.param_specs(), rules, mesh)
            batch = batch_specs(cfg, shape, rules, mesh)
            jitted = jax.jit(step, donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
            rec["num_microbatches"] = n_mb
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, ctx)
            pspecs = serve_param_specs(model)
            params = tree_shape_structs(pspecs, tree_shardings(pspecs, rules, mesh))
            batch = batch_specs(cfg, shape, rules, mesh)
            lowered = jax.jit(step).lower(params, batch)
        else:  # decode
            step = make_decode_step(cfg, ctx, long_mode=long_mode)
            pspecs = serve_param_specs(model)
            params = tree_shape_structs(pspecs, tree_shardings(pspecs, rules, mesh))
            cache, tokens, pos = decode_specs(cfg, shape, rules, mesh, long_mode=long_mode)
            lowered = jax.jit(step, donate_argnums=(1,)).lower(params, cache, tokens, pos)
        t_lower = time.time() - t0

        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

        mem = compiled.memory_analysis()
        print(f"[{_cell_id(arch, shape_name, mesh_kind)}] memory_analysis: {mem}")
        cost = compiled.cost_analysis()
        print(f"[{_cell_id(arch, shape_name, mesh_kind)}] cost: flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e}")
        hlo = compiled.as_text()
        analysis = hlo_analysis.analyze_hlo(hlo, ndev)
        coll = analysis["collectives"]
        rec.update({
            "status": "ok",
            "devices": ndev,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            "cost": {k: v for k, v in cost.items() if isinstance(v, (int, float))},
            "collectives": coll,
            **hlo_analysis.summarize(coll),
            "cpu_bf16_inflation_bytes": hlo_analysis.cpu_bf16_inflation_bytes(hlo),
            "hlo_flops": analysis["hlo_flops"],
            "hlo_bytes": analysis["hlo_bytes"],
            "hlo_chars": len(hlo),
        })
        if save_hlo:
            p = pathlib.Path(save_hlo)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(hlo)
    except Exception as e:  # a failure here is a bug in our sharding design
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ALL_ARCHS if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in SHAPES] if args.all or not args.shape else [args.shape]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                cid = _cell_id(arch, shape, mesh_kind)
                path = outdir / f"{cid}.json"
                if path.exists() and not args.force:
                    prev = json.loads(path.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[{cid}] cached: {prev['status']}")
                        n_ok += prev["status"] == "ok"
                        n_skip += prev["status"] == "skipped"
                        continue
                print(f"[{cid}] running...", flush=True)
                rec = run_cell(arch, shape, mesh_kind, save_hlo=args.save_hlo)
                path.write_text(json.dumps(rec, indent=1))
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                print(f"[{cid}] {st}" + (f" ({rec.get('error','')})" if st == "error" else ""),
                      flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
