"""Deadline-based hedged chunk scheduler (§3.5 request hedging).

Replaces the fixed ``k + hedge`` loop that used to live in
``storage/rpc.py`` with an event-driven scheduler on the simulated clock:

1. issue the k cheapest requests (by estimated latency) at t = 0;
2. arm a *hedge deadline* — a multiple of the slowest primary's estimate;
3. on a transport failure or a verification failure, immediately re-issue
   to the next-best candidate (failure recovery, not hedging);
4. if the deadline fires before k valid responses landed, launch up to
   ``hedge`` extra requests and re-arm (straggler mitigation — the paper's
   "ignore stragglers" behaviour, with the waste made measurable).

The scheduler never peeks at a request's completion time before the
simulated clock reaches it, so its decisions are exactly the ones a real
RPC node could make — and everything is deterministic.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Callable


@dataclasses.dataclass
class FetchResult:
    """Outcome of one k-of-n hedged fetch on the simulated clock."""

    shards: dict[int, object]  # candidate key -> payload (first k valid)
    latency_ms: float  # sim time at which the k-th valid shard landed
    issued: int = 0
    used: int = 0
    bad: int = 0  # responses failing verification (corruption, §2.3)
    failed: int = 0  # transport-level failures (crashed SP, missing chunk)
    hedges: int = 0  # requests launched by the hedge deadline timer

    @property
    def wasted(self) -> int:
        """Paid requests that did not contribute a used shard."""
        return self.issued - self.used


class HedgedScheduler:
    """Issues requests through a transport-shaped callback.

    fetch() drives ``issue(key, sp_id, t_ms) -> (payload | None, done_ms)``
    — the transport must answer with the payload (or None for a failure)
    and the simulated completion time — plus an optional
    ``verify(key, payload) -> bool`` commitment check.
    """

    def __init__(
        self,
        hedge: int = 2,
        *,
        deadline_factor: float = 3.0,
        min_deadline_ms: float = 5.0,
    ):
        self.hedge = hedge
        self.deadline_factor = deadline_factor
        self.min_deadline_ms = min_deadline_ms

    def fetch(
        self,
        k: int,
        candidates: list[tuple[int, int, float]],  # (key, sp_id, est_ms)
        issue: Callable[[int, int, float], tuple[object, float]],
        verify: Callable[[int, object], bool] | None = None,
        start_ms: float = 0.0,
    ) -> FetchResult:
        """`start_ms` anchors the fetch on the global simulated clock so
        transfers from concurrent requests queue against each other."""
        if len(candidates) < k:
            raise ValueError(f"need >= {k} candidates, got {len(candidates)}")
        order = sorted(candidates, key=lambda c: (c[2], c[0]))
        queue = deque(order)
        events: list[tuple[float, int, str, object]] = []
        seq = itertools.count()
        res = FetchResult(shards={}, latency_ms=0.0)

        def launch(t_ms: float) -> None:
            key, sp_id, _est = queue.popleft()
            payload, done_ms = issue(key, sp_id, t_ms)
            res.issued += 1
            heapq.heappush(events, (done_ms, next(seq), "done", (key, payload)))

        primaries = order[:k]
        for _ in range(k):
            launch(start_ms)
        deadline = max(
            self.min_deadline_ms, self.deadline_factor * primaries[-1][2]
        )
        heapq.heappush(events, (start_ms + deadline, next(seq), "hedge", None))

        now = start_ms
        while events and len(res.shards) < k:
            now, _, kind, payload = heapq.heappop(events)
            if kind == "hedge":
                # stragglers outstanding past the deadline: hedge + re-arm
                launched = 0
                while launched < self.hedge and queue:
                    launch(now)
                    launched += 1
                res.hedges += launched
                if launched and queue:
                    heapq.heappush(
                        events, (now + deadline, next(seq), "hedge", None)
                    )
                continue
            key, data = payload
            if data is None:
                res.failed += 1
                if queue:
                    launch(now)  # instant failure recovery
                continue
            if verify is not None and not verify(key, data):
                res.bad += 1
                if queue:
                    launch(now)
                continue
            res.shards[key] = data
            res.used += 1
        res.latency_ms = now - start_ms
        return res
