"""Deadline-based hedged chunk scheduler (§3.5 request hedging).

Replaces the fixed ``k + hedge`` loop that used to live in
``storage/rpc.py`` with an event-driven scheduler on the simulated clock:

1. issue the k cheapest requests (by estimated latency) at t = 0;
2. arm a *hedge deadline* — a multiple of the slowest primary's estimate;
3. on a transport failure or a verification failure, immediately re-issue
   to the next-best candidate (failure recovery, not hedging);
4. if the deadline fires before k valid responses landed, launch up to
   ``hedge`` extra requests and re-arm (straggler mitigation — the paper's
   "ignore stragglers" behaviour, with the waste made measurable).

The scheduler is a *task* on a shared :class:`~repro.net.events.EventLoop`:
every in-flight leg is its own spawned task, and the deadline is a timer
task feeding the same :class:`~repro.net.events.Channel`, so the hedge
decisions of concurrent fetches genuinely interleave on one global heap —
a hot SP another request is queueing on delays THIS fetch's leg, which can
blow THIS fetch's deadline.  ``fetch()`` keeps the old synchronous shape by
running ``fetch_task`` on a private loop; it never peeks at a completion
time before the simulated clock reaches it, and everything is
deterministic.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

from repro.net.events import Channel, EventLoop, Recv, Sleep

_HEDGE = object()  # sentinel message the deadline timer posts


@dataclasses.dataclass(slots=True)
class FetchResult:
    """Outcome of one k-of-n hedged fetch on the simulated clock.

    ``slots=True``: a big-world replay materializes one of these per
    chunkset fetch, so the per-object footprint is kept to the fields."""

    shards: dict[int, object]  # candidate key -> payload (first k valid)
    latency_ms: float  # sim time at which the k-th valid shard landed
    issued: int = 0
    used: int = 0
    bad: int = 0  # responses failing verification (corruption, §2.3)
    failed: int = 0  # transport-level failures (crashed SP, missing chunk)
    hedges: int = 0  # requests launched by the hedge deadline timer
    hedges_suppressed: int = 0  # deadline fired but the hedge_gate said no

    @property
    def wasted(self) -> int:
        """Paid requests that did not contribute a used shard."""
        return self.issued - self.used


class HedgedScheduler:
    """Issues requests through transport-shaped task factories.

    ``fetch_task`` drives ``issue_task(key, sp_id)`` — a generator yielding
    event-loop effects (``Transfer``/``Acquire``/``Sleep``) and returning
    the payload, or ``None`` for a transport failure — plus an optional
    ``verify(key, payload) -> bool`` commitment check.
    """

    def __init__(
        self,
        hedge: int = 2,
        *,
        deadline_factor: float = 3.0,
        min_deadline_ms: float = 5.0,
    ):
        self.hedge = hedge
        self.deadline_factor = deadline_factor
        self.min_deadline_ms = min_deadline_ms

    def fetch_task(
        self,
        loop: EventLoop,
        k: int,
        candidates: list[tuple[int, int, float]],  # (key, sp_id, est_ms)
        issue_task: Callable,  # (key, sp_id) -> generator returning payload|None
        verify: Callable[[int, object], bool] | None = None,
        label: str = "fetch",
        hedge_gate: Callable[[], bool] | None = None,
    ):
        """Generator task; spawn it on the shared loop (its legs and hedge
        timer live on the same heap as every other request's).

        ``hedge_gate`` is the overload hook: consulted when the deadline
        fires, and hedges are launched only while it returns True.  Hedges
        multiply offered load exactly when the system can least afford it,
        so an overloaded node sheds its *hedges* first (counted in
        ``FetchResult.hedges_suppressed``) before shedding whole requests.
        Failure recovery is never gated — a failed primary must be
        replaced or the fetch cannot reach k shards at all.
        """
        if len(candidates) < k:
            raise ValueError(f"need >= {k} candidates, got {len(candidates)}")
        order = sorted(candidates, key=lambda c: (c[2], c[0]))
        queue = deque(order)
        res = FetchResult(shards={}, latency_ms=0.0)
        start_ms = loop.now
        chan = Channel(loop)
        outstanding = 0

        def leg(key, sp_id):
            payload = yield from issue_task(key, sp_id)
            chan.send((key, payload))

        def launch():
            nonlocal outstanding
            key, sp_id, _est = queue.popleft()
            res.issued += 1
            outstanding += 1
            loop.spawn(leg(key, sp_id), label=f"{label}/leg{key}")

        def timer(delay_ms):
            yield Sleep(delay_ms)
            chan.send((_HEDGE, None))

        primaries = order[:k]
        for _ in range(k):
            launch()
        deadline = max(
            self.min_deadline_ms, self.deadline_factor * primaries[-1][2]
        )
        timer_h = loop.spawn(timer(deadline), label=f"{label}/deadline")

        while len(res.shards) < k:
            if outstanding == 0:
                if not queue:
                    break  # exhausted: partial result, caller decides
                launch()  # defensive recovery; normally unreachable
                continue
            key, data = yield Recv(chan)
            if key is _HEDGE:
                # stragglers outstanding past the deadline: hedge + re-arm
                # (unless the overload gate says the node cannot afford it)
                launched = 0
                while launched < self.hedge and queue:
                    if hedge_gate is not None and not hedge_gate():
                        res.hedges_suppressed += 1
                        break
                    launch()
                    launched += 1
                res.hedges += launched
                # re-arm whenever candidates remain — INCLUDING when the
                # overload gate suppressed the launch: a brownout window
                # must delay hedging, not permanently disable it for this
                # fetch (the gate is consulted afresh at the next deadline)
                if queue:
                    timer_h = loop.spawn(timer(deadline), label=f"{label}/deadline")
                continue
            outstanding -= 1
            if data is None:
                res.failed += 1
                if queue:
                    launch()  # instant failure recovery
                continue
            if verify is not None and not verify(key, data):
                res.bad += 1
                if queue:
                    launch()
                continue
            res.shards[key] = data
            res.used += 1
        if timer_h is not None and not timer_h.done:
            timer_h.cancel()
        res.latency_ms = loop.now - start_ms
        return res

    def fetch(
        self,
        k: int,
        candidates: list[tuple[int, int, float]],  # (key, sp_id, est_ms)
        issue: Callable[[int, int, float], tuple[object, float]],
        verify: Callable[[int, object], bool] | None = None,
        start_ms: float = 0.0,
    ) -> FetchResult:
        """Synchronous wrapper: run ``fetch_task`` on a private loop.

        ``issue(key, sp_id, t_ms) -> (payload | None, done_ms)`` answers
        with the payload and the simulated completion time (the legacy
        transport shape); ``start_ms`` anchors the fetch on the caller's
        simulated clock.
        """
        loop = EventLoop()

        def issue_task(key, sp_id):
            payload, done_ms = issue(key, sp_id, loop.now)
            if done_ms > loop.now:
                yield Sleep(done_ms - loop.now)
            return payload

        h = loop.spawn(
            self.fetch_task(loop, k, candidates, issue_task, verify),
            at_ms=start_ms, label="fetch",
        )
        return loop.run_until(h)
