"""Backbone data plane (§2.3, §3.1): simulated dedicated network + RPC fleet.

Shelby "operates over a dedicated backbone connecting RPC and storage
nodes".  This package models that data plane deterministically so serving
claims (hedging wins under stragglers, p99 latency, goodput at scale) are
*measured* on a simulated clock, never inferred from wall-clock noise:

* ``events``    — the shared deterministic event engine (one global heap;
  generator tasks yielding Sleep/Transfer/Acquire/Join/Recv effects).
* ``backbone``  — datacenter topology, per-link latency/bandwidth and
  per-node NIC FIFO transfer accounting on a simulated clock.
* ``scheduler`` — deadline-based hedged chunk scheduler (replaces the
  fixed k+hedge loop that used to live in ``storage/rpc.py``), now a
  task on the shared heap.
* ``fleet``     — multi-RPC router with pluggable policies (latency-aware,
  cache-affinity rendezvous hashing, power-of-two-choices).
* ``workloads`` — deterministic scenario generators (video streaming,
  training epochs, analytics scans, Zipf hot-object traffic) plus the
  open-loop / closed-loop replay drivers.
"""
from repro.net.backbone import Backbone, LinkSpec, NICSpec
from repro.net.events import (
    Acquire,
    Channel,
    EventLoop,
    Join,
    Recv,
    Release,
    Sleep,
    Transfer,
)
from repro.net.fleet import (
    CacheAffinityPolicy,
    LatencyAwarePolicy,
    PowerOfTwoPolicy,
    RPCFleet,
)
from repro.net.scheduler import FetchResult, HedgedScheduler
from repro.net.workloads import (
    ReadRequest,
    ReplayResult,
    RequestRecord,
    analytics_scan,
    replay_closed_loop,
    replay_open_loop,
    training_epoch,
    video_streaming,
    zipf_hotset,
)

__all__ = [
    "Backbone",
    "LinkSpec",
    "NICSpec",
    "EventLoop",
    "Channel",
    "Sleep",
    "Transfer",
    "Acquire",
    "Release",
    "Join",
    "Recv",
    "HedgedScheduler",
    "FetchResult",
    "RPCFleet",
    "LatencyAwarePolicy",
    "CacheAffinityPolicy",
    "PowerOfTwoPolicy",
    "ReadRequest",
    "RequestRecord",
    "ReplayResult",
    "replay_open_loop",
    "replay_closed_loop",
    "video_streaming",
    "training_epoch",
    "analytics_scan",
    "zipf_hotset",
]
