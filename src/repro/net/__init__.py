"""Backbone data plane (§2.3, §3.1): simulated dedicated network + RPC fleet.

Shelby "operates over a dedicated backbone connecting RPC and storage
nodes".  This package models that data plane deterministically so serving
claims (hedging wins under stragglers, p99 latency, goodput at scale) are
*measured* on a simulated clock, never inferred from wall-clock noise:

* ``backbone``  — datacenter topology, per-link latency/bandwidth, FIFO
  transfer accounting on a simulated clock.
* ``scheduler`` — deadline-based hedged chunk scheduler (replaces the
  fixed k+hedge loop that used to live in ``storage/rpc.py``).
* ``fleet``     — multi-RPC router with pluggable policies (latency-aware,
  cache-affinity rendezvous hashing, power-of-two-choices).
* ``workloads`` — deterministic scenario generators (video streaming,
  training epochs, analytics scans, Zipf hot-object traffic).
"""
from repro.net.backbone import Backbone, LinkSpec
from repro.net.fleet import (
    CacheAffinityPolicy,
    LatencyAwarePolicy,
    PowerOfTwoPolicy,
    RPCFleet,
)
from repro.net.scheduler import FetchResult, HedgedScheduler
from repro.net.workloads import (
    ReadRequest,
    analytics_scan,
    training_epoch,
    video_streaming,
    zipf_hotset,
)

__all__ = [
    "Backbone",
    "LinkSpec",
    "HedgedScheduler",
    "FetchResult",
    "RPCFleet",
    "LatencyAwarePolicy",
    "CacheAffinityPolicy",
    "PowerOfTwoPolicy",
    "ReadRequest",
    "video_streaming",
    "training_epoch",
    "analytics_scan",
    "zipf_hotset",
]
