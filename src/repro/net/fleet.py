"""Multi-RPC serving fleet (§2.3): request routing + per-node hot caches.

One RPC node cannot serve "millions of users"; Shelby's data plane is a
*fleet* of RPC nodes behind the same contract, each with its own decoded
hot-cache.  The router decides which node serves which request; the policy
determines the cache economics:

* ``LatencyAwarePolicy``   — client->node propagation + EWMA of the node's
  recent fetch latency (greedy, CDN-edge-style).
* ``CacheAffinityPolicy``  — rendezvous (highest-random-weight) hashing on
  (blob, chunkset): every object has one home node, so the fleet's
  aggregate cache behaves like one big cache.
* ``PowerOfTwoPolicy``     — classic power-of-two-choices on routed load;
  near-uniform balance with two probes.

Routing is per *chunkset*, the cache/decode unit, so a range read spanning
chunksets may fan out across the fleet and assemble at the edge (chunkset
fetches overlap; the request's simulated latency is the slowest leg plus
the client<->node round trip when a backbone is attached).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import TYPE_CHECKING

import numpy as np

from repro.net.backbone import Backbone
from repro.net.events import EventLoop, Join, Sleep

if TYPE_CHECKING:  # avoid a cycle: storage.rpc imports repro.net.scheduler
    from repro.storage.rpc import RPCNode


@dataclasses.dataclass
class ServedRange:
    """One byte-range served by the fleet, with per-node attribution.

    `chunksets_by_node` maps rpc_id -> number of this range's chunksets that
    node served — the basis for the client's per-serving-node payments.
    """

    blob_id: int
    offset: int
    length: int
    data: bytes
    latency_ms: float
    chunksets_by_node: dict[str, int]
    cache_hits: int = 0
    hedges_launched: int = 0
    hedged_wasted: int = 0
    coalesced: int = 0  # chunksets that joined another request's fetch
    # rpc_id -> chunksets this range served on that node AFTER its routed
    # node shed the leg (retry-on-sibling); payments follow the server
    retried_nodes: dict[str, int] = dataclasses.field(default_factory=dict)


class LatencyAwarePolicy:
    """Route to the node minimizing propagation + recent-latency EWMA."""

    # routing depends on live fleet state (EWMA, routed counts): the cohort
    # fast path cannot precompute it, so batches de-opt to task mode
    static = False

    def pick(self, key: tuple[int, int], client: str | None, fleet: "RPCFleet") -> int:
        def est(i: int) -> tuple[float, int, int]:
            prop = 0.0
            if fleet.backbone is not None and client is not None:
                prop = fleet.backbone.propagation_ms(client, fleet.node_ids[i])
            return (prop + fleet.ewma_ms[i], fleet.routed[i], i)

        return min(range(len(fleet.rpcs)), key=est)


class CacheAffinityPolicy:
    """Rendezvous hashing on (blob_id, chunkset) -> stable home node.

    A pure function of (key, node set), so picks are memoized: a hot key
    re-routed a million times costs one sha256 sweep, not a million — and
    the cohort fast path can route whole batches through the same memo.
    """

    static = True  # pick depends only on (key, node set): vectorizable

    def __init__(self):
        self._memo: dict[tuple[int, int], int] = {}
        self._memo_nodes: object = None  # fleet.node_ids identity the memo is valid for

    def pick(self, key: tuple[int, int], client: str | None, fleet: "RPCFleet") -> int:
        if fleet.node_ids is not self._memo_nodes:
            self._memo.clear()
            self._memo_nodes = fleet.node_ids
        hit = self._memo.get(key)
        if hit is not None:
            return hit

        def weight(i: int) -> bytes:
            tag = f"{fleet.node_ids[i]}|{key[0]}|{key[1]}".encode()
            return hashlib.sha256(tag).digest()

        best = max(range(len(fleet.rpcs)), key=weight)
        self._memo[key] = best
        return best


class PowerOfTwoPolicy:
    """Two seeded random probes, pick the less-loaded (routed count)."""

    static = False  # consumes an rng stream in routing order

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def pick(self, key: tuple[int, int], client: str | None, fleet: "RPCFleet") -> int:
        n = len(fleet.rpcs)
        if n == 1:
            return 0
        a, b = self._rng.choice(n, size=2, replace=False)
        return int(a if fleet.routed[a] <= fleet.routed[b] else b)


# named policy factories: the routing_policy config knob and the scenario
# registry resolve policies by these names (fresh instance per fleet —
# policies carry per-fleet state: memos, rng streams, EWMA views)
POLICY_FACTORIES = {
    "latency": LatencyAwarePolicy,
    "affinity": CacheAffinityPolicy,
    "p2c": lambda: PowerOfTwoPolicy(seed=0),
}


def make_policy(name: str):
    """A fresh routing-policy instance for a registered name."""
    try:
        factory = POLICY_FACTORIES[name]
    except KeyError:
        raise ValueError(
            f"routing_policy must be one of {sorted(POLICY_FACTORIES)}, "
            f"got {name!r}"
        ) from None
    return factory()


class RPCFleet:
    """Routes chunkset reads across RPC nodes and accounts serving metrics."""

    def __init__(
        self,
        rpcs: list[RPCNode],
        policy,
        *,
        backbone: Backbone | None = None,
        ewma_alpha: float = 0.3,
    ):
        if not rpcs:
            raise ValueError("fleet needs at least one RPC node")
        self.rpcs = list(rpcs)
        self.node_ids = [r.rpc_id for r in self.rpcs]
        self.policy = policy
        self.backbone = backbone
        self._alpha = ewma_alpha
        self.ewma_ms = [0.0] * len(self.rpcs)
        self._ewma_seeded = [False] * len(self.rpcs)
        self.routed = [0] * len(self.rpcs)
        self.chunkset_reads = 0
        self.samples_routed = 0  # DAS samples (accounted apart from reads)
        self.bytes_served = 0
        self.request_latencies_ms: list[float] = []
        # overload accounting (legs = one node's share of one request)
        self.shed_legs = 0  # node legs refused at admission
        self.retried_legs = 0  # shed legs rescued by a sibling
        self.retried_chunksets = 0  # chunksets served via those retries

    @property
    def primary(self) -> RPCNode:
        """The node that fronts write dispersal (any node can; pick node 0)."""
        return self.rpcs[0]

    @property
    def network(self) -> Backbone | None:
        """The Backbone event-loop Transfers route over: the fleet's own, or
        — for a bare RPCNode wrapped into a fleet of one — the primary
        transport's."""
        return self.backbone or getattr(self.primary.transport, "backbone", None)

    def node(self, rpc_id: str) -> RPCNode:
        return self.rpcs[self.node_ids.index(rpc_id)]

    def admit_sp(self, sp_id: int, sp, node: str | None = None) -> None:
        """Fan a mid-run SP join out to every RPC node (membership plane):
        each opens its payment channel and learns the transport route, so
        reassigned chunksets are servable fleet-wide the moment the
        contract's placement points at the newcomer."""
        for rpc in self.rpcs:
            rpc.admit_sp(sp_id, sp, node)

    # -- serving ------------------------------------------------------------------
    def _route(self, blob_id: int, chunkset: int, client: str | None) -> int:
        i = self.policy.pick((blob_id, chunkset), client, self)
        self.routed[i] += 1
        self.chunkset_reads += 1
        return i

    def _observe(self, i: int, ms: float) -> None:
        if not self._ewma_seeded[i]:
            self.ewma_ms[i], self._ewma_seeded[i] = ms, True
        else:
            self.ewma_ms[i] = (1 - self._alpha) * self.ewma_ms[i] + self._alpha * ms

    def _prop(self, i: int, client: str | None) -> float:
        if self.backbone is None or client is None:
            return 0.0
        return self.backbone.propagation_ms(client, self.node_ids[i])

    def serve_ranges_task(
        self,
        loop: EventLoop,
        ranges: list[tuple[int, int, int]],  # (blob_id, offset, length)
        client: str | None = None,
        label: str = "serve",
    ):
        """Task: serve many byte ranges — possibly of different blobs — in
        ONE fleet pass on the shared event loop.

        Every (blob, chunkset) across ALL ranges is routed individually at
        the task's start time (deduplicated — two ranges sharing a chunkset
        fetch it once), then each node reads its entire share as ONE
        spawned `read_items_task`, so wide GF batch-decodes span requests
        and all node legs run concurrently on the shared heap — contending
        with every other in-flight request's legs for trunks, NICs and SP
        disk slots.  Client<->node legs are pure propagation (clients reach
        the fleet over the public internet, not the dedicated backbone): a
        range's latency is the max over its own chunksets' legs plus the
        client<->node round trip.

        Overload: a node leg refused at admission (:class:`Overloaded`) is
        retried ONCE on the least-loaded sibling — the NACK is cheap, so
        the edge re-issues: extra latency is the round trip burned on the
        refusing node plus the sibling's own propagation.  If the sibling
        sheds too, the whole request surfaces as `Overloaded` (replay
        drivers record it as *shed*, and pay-on-delivery means it debits
        nothing).  Payments follow the node that actually served.
        """
        from repro.storage.rpc import Overloaded  # deferred: import cycle

        lay = self.primary.layout
        contract = self.primary.contract
        per_range_items: list[list[tuple[int, int]]] = []
        routed_node: dict[tuple[int, int], int] = {}  # (blob, cs) -> node index
        by_node: dict[int, list[tuple[int, int]]] = {}
        for blob_id, offset, length in ranges:
            first, last = lay.byte_range_to_chunksets(offset, length)
            items = [(blob_id, cs) for cs in range(first, last + 1)]
            per_range_items.append(items)
            for key in items:
                if key not in routed_node:
                    i = self._route(key[0], key[1], client)
                    routed_node[key] = i
                    by_node.setdefault(i, []).append(key)

        decoded: dict[tuple[int, int], np.ndarray] = {}
        item_stats: dict[tuple[int, int], object] = {}
        served_by: dict[tuple[int, int], int] = {}  # who ACTUALLY served
        retried: set[tuple[int, int]] = set()
        extra_ms: dict[tuple[int, int], float] = {}  # client round trips
        handles: dict[int, object] = {}
        for i, node_items in by_node.items():
            prop = self._prop(i, client)

            def node_task(i=i, node_items=node_items, prop=prop):
                if prop > 0:
                    yield Sleep(prop)  # request reaches the serving node
                try:
                    out, stats = yield from self.rpcs[i].read_items_task(
                        loop, node_items, label=f"{label}/{self.node_ids[i]}"
                    )
                    return out, stats, i, 2.0 * prop
                except Overloaded:
                    self.shed_legs += 1
                    j = self._sibling(i)
                    if j is None:
                        raise  # fleet of one: nowhere to retry
                    # the NACK came back (prop) and the edge re-issues to
                    # the sibling (its own propagation); if the sibling
                    # sheds too, Overloaded propagates and drops the request
                    prop_j = self._prop(j, client)
                    if prop + prop_j > 0:
                        yield Sleep(prop + prop_j)
                    out, stats = yield from self.rpcs[j].read_items_task(
                        loop, node_items, label=f"{label}/{self.node_ids[j]}"
                    )
                    self.retried_legs += 1
                    self.retried_chunksets += len(node_items)
                    self.routed[j] += len(node_items)  # load landed on the sibling
                    return out, stats, j, 2.0 * prop + 2.0 * prop_j

            handles[i] = loop.spawn(
                node_task(), label=f"{label}/{self.node_ids[i]}"
            )
        first_err: Exception | None = None
        for i, h in handles.items():
            try:
                out, stats, srv, extra = yield Join(h)
            except (GeneratorExit, KeyboardInterrupt):
                # task teardown / user interrupt must never be harvested as
                # a leg failure — propagate immediately
                raise
            except Exception as e:  # harvest every node leg before raising
                if first_err is None:
                    first_err = e
                continue
            self._observe(srv, max(s.latency_ms for s in stats.values()))
            decoded.update(out)
            item_stats.update(stats)
            for key in out:
                served_by[key] = srv
                extra_ms[key] = extra
                if srv != i:
                    retried.add(key)
        if first_err is not None:
            raise first_err

        served: list[ServedRange] = []
        for (blob_id, offset, length), items in zip(ranges, per_range_items):
            meta = contract.blobs[blob_id]
            first = items[0][1]
            data = lay.extract_range(
                [decoded[key] for key in items], first, offset, length,
                meta.size_bytes,
            )
            by_node_count: dict[str, int] = {}
            retried_nodes: dict[str, int] = {}
            latency, hits, hedges, wasted, coalesced = 0.0, 0, 0, 0, 0
            for key in items:
                nid = self.node_ids[served_by[key]]
                by_node_count[nid] = by_node_count.get(nid, 0) + 1
                if key in retried:
                    retried_nodes[nid] = retried_nodes.get(nid, 0) + 1
                s = item_stats[key]
                latency = max(latency, s.latency_ms + extra_ms[key])
                hits += s.cache_hit
                hedges += s.hedges
                wasted += s.wasted
                coalesced += s.coalesced
            served.append(
                ServedRange(
                    blob_id=blob_id, offset=offset, length=length, data=data,
                    latency_ms=latency, chunksets_by_node=by_node_count,
                    cache_hits=hits, hedges_launched=hedges, hedged_wasted=wasted,
                    coalesced=coalesced, retried_nodes=retried_nodes,
                )
            )
            self.bytes_served += len(data)
            self.request_latencies_ms.append(latency)
        return served

    # -- DAS sampling (tiny proof-carrying reads) ----------------------------------
    def sample_share_task(
        self,
        loop: EventLoop,
        blob_id: int,
        row: int,
        col: int,
        *,
        client: str | None = None,
        cache_bypass: bool = True,
        label: str = "das",
    ):
        """Task: route ONE DAS sample to a node, fetch + verify it there.

        Routing uses the policy with a coordinate-derived key (each share
        is its own cache/decode unit), but samples are accounted apart
        from chunkset reads: they do not touch ``chunkset_reads`` (so the
        streaming ``cache_hit_rate`` stays a streaming metric) and do not
        feed the latency EWMA (tiny single-slot reads would make every
        node look fast to the latency-aware router).  A shed leg retries
        once on the least-loaded sibling, like any other request.
        """
        from repro.storage.rpc import Overloaded  # deferred: import cycle

        rec = self.primary.contract.das.get(blob_id)
        if rec is None:
            from repro.storage.rpc import ReadError

            raise ReadError(f"blob {blob_id} has no DAS extension")
        key = (blob_id, rec.side * rec.side + row * rec.side + col)
        i = self.policy.pick(key, client, self)
        self.routed[i] += 1
        self.samples_routed += 1
        prop = self._prop(i, client)
        if prop > 0:
            yield Sleep(prop)
        srv, extra = i, 2.0 * prop
        try:
            ss = yield from self.rpcs[i].sample_share_task(
                loop, blob_id, row, col, cache_bypass=cache_bypass,
                label=f"{label}/{self.node_ids[i]}",
            )
        except Overloaded:
            self.shed_legs += 1
            j = self._sibling(i)
            if j is None:
                raise
            prop_j = self._prop(j, client)
            if prop + prop_j > 0:
                yield Sleep(prop + prop_j)
            ss = yield from self.rpcs[j].sample_share_task(
                loop, blob_id, row, col, cache_bypass=cache_bypass,
                label=f"{label}/{self.node_ids[j]}",
            )
            self.retried_legs += 1
            self.routed[j] += 1
            srv, extra = j, 2.0 * prop + 2.0 * prop_j
        return dataclasses.replace(
            ss, latency_ms=ss.latency_ms + extra, rpc_id=self.node_ids[srv]
        )

    def _sibling(self, i: int) -> int | None:
        """Deterministic overflow target for a shed leg: the least-routed
        OTHER node (ties by index); None on a fleet of one."""
        others = [j for j in range(len(self.rpcs)) if j != i]
        if not others:
            return None
        return min(others, key=lambda j: (self.routed[j], j))

    def serve_ranges(
        self,
        ranges: list[tuple[int, int, int]],  # (blob_id, offset, length)
        *,
        client: str | None = None,
        t_ms: float = 0.0,
    ) -> list[ServedRange]:
        """Synchronous wrapper over :meth:`serve_ranges_task`.

        `t_ms` anchors the batch on the global simulated clock; trunk/NIC
        reservations persist in the shared Backbone, so sequential callers
        still queue against earlier traffic.  For genuinely concurrent
        requests, spawn `serve_ranges_task` per request on one shared loop
        (see ``repro.net.workloads.replay_open_loop``)."""
        loop = EventLoop(network=self.network)
        h = loop.spawn(
            self.serve_ranges_task(loop, ranges, client=client),
            at_ms=t_ms, label="serve",
        )
        return loop.run_until(h)

    def read_range(
        self, blob_id: int, offset: int, length: int, *, client: str | None = None,
        t_ms: float = 0.0,
    ) -> tuple[bytes, float]:
        """Serve [offset, offset+length) and return (bytes, sim_latency_ms)."""
        sr = self.serve_ranges([(blob_id, offset, length)], client=client, t_ms=t_ms)[0]
        return sr.data, sr.latency_ms

    # -- metrics -------------------------------------------------------------------
    def cache_hit_rate(self) -> float:
        hits = sum(r.stats.cache_hits for r in self.rpcs)
        return hits / self.chunkset_reads if self.chunkset_reads else 0.0

    def hedged_wasted(self) -> int:
        """Paid-but-unused requests, incl. crash-recovery replacements."""
        return sum(r.stats.hedged_wasted for r in self.rpcs)

    def hedges_launched(self) -> int:
        """Requests launched by hedge deadlines only (straggler mitigation)."""
        return sum(r.stats.hedges_launched for r in self.rpcs)

    def hedges_suppressed(self) -> int:
        """Hedge deadlines the per-node overload gate refused to act on."""
        return sum(r.stats.hedges_suppressed for r in self.rpcs)

    def coalesced(self) -> int:
        """Cache misses that piggybacked on an in-flight fetch (stampede
        collapse) instead of fetching from SPs again."""
        return sum(r.stats.coalesced for r in self.rpcs)

    def requests_shed(self) -> int:
        """Node-level admission refusals (each is one leg's Overloaded)."""
        return sum(r.stats.shed_requests for r in self.rpcs)

    def samples_served(self) -> int:
        """DAS shares delivered + verified across the fleet."""
        return sum(r.stats.samples_served for r in self.rpcs)

    def samples_withheld(self) -> int:
        """DAS samples an SP went silent on (the detection signal)."""
        return sum(r.stats.samples_withheld for r in self.rpcs)

    def sample_proof_bytes(self) -> int:
        """Proof bandwidth moved for DAS samples, fleet-wide."""
        return sum(r.stats.sample_proof_bytes for r in self.rpcs)

    def latency_percentiles(self, *qs: float) -> tuple[float, ...]:
        if not self.request_latencies_ms:
            return tuple(0.0 for _ in qs)
        arr = np.asarray(self.request_latencies_ms)
        return tuple(float(np.percentile(arr, q)) for q in qs)
