"""Cohort fast path: advance homogeneous request batches in vectorized steps.

A million-request replay spends most of its time doing the SAME thing a
million times: route a (blob, chunkset) key, probe a warm cache, charge two
propagation legs, record a latency.  The task-per-request engine pays full
generator machinery for every one of them.  This module recognises the
*cohort* — requests whose fate is decided by arithmetic alone — and advances
it through routing, cache accounting and latency bookkeeping as numpy array
operations, while any request that *individuates* (a cold-key first toucher
that must actually fetch, hedge, queue on SP disk slots and maybe NACK)
de-opts to a full :func:`repro.net.workloads._serve_one` generator task on a
real :class:`~repro.net.events.EventLoop`.

Semantics contract (matched float-for-float against task mode):

* warm-cache hit  -> latency ``0.0 + 2*prop`` — identical ops to
  ``serve_ranges_task``'s ``max(0.0, s.latency_ms + extra_ms)``;
* coalesced probe (arrives while the leader's fetch is in flight) ->
  latency ``(put_t - probe_t) + 2*prop`` where ``put_t`` comes from the
  node's ``cache_put_log`` — for single-chunkset leaders the put lands at
  exactly the flight's ``finished_ms``, which is what a real single-flight
  waiter observes, so the digest is bit-identical;
* cold first toucher (per probe-time order ``(probe_t, arrival, index)``,
  mirroring the heap's push-order tie-break) -> de-opt: a real task that
  routes, fetches, hedges and pays through the ordinary machinery.

Documented deviations from task mode (why exact-equality tests pin
single-chunkset worlds):

* a MULTI-chunkset leader decodes all its keys only after its last flight
  lands, so a probe falling in the gap between one key's flight finish and
  the leader's decode would duplicate-fetch in task mode; the fast path
  resumes such probes at ``put_t`` instead (strictly less work, slightly
  later);
* an exact float tie ``probe_t == put_t`` classifies as a hit (task mode's
  outcome depends on event seq order); latency is identical either way,
  only the per-node hit/coalesce counter attribution can differ;
* vectorized requests do not update the fleet latency EWMA
  (``_observe``) or the cache's LRU recency order — both unobservable
  under a static policy with the no-eviction guard below.

When the world is NOT cohort-safe — a stateful routing policy, admission
control, cache TTLs, admission-by-size, single-flight disabled, or enough
distinct keys that LRU eviction becomes possible — the whole batch falls
back to :func:`repro.net.workloads.replay_open_loop` and the reason is
recorded on ``ReplayResult.cohort.fallback_reason``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.net.events import ENGINE_COUNTERS, EventLoop
from repro.net.workloads import (
    RecordBatch,
    ReplayResult,
    RequestBatch,
    _serve_one,
    replay_open_loop,
)


@dataclasses.dataclass
class CohortStats:
    """How a batch split between the vectorized cohort and real tasks.

    The per-leg arrays cover ONLY vectorized legs (de-opted requests route
    and pay through the ordinary task machinery); payment batching in
    ``storage/sdk.py`` consumes them to settle whole cohorts with one
    channel debit per node."""

    vec_requests: int = 0
    deopt_requests: int = 0
    hits: int = 0  # vectorized legs served from warm cache
    coalesced: int = 0  # vectorized legs that rode an in-flight fetch
    fallback_reason: str | None = None
    # vectorized-leg attribution: request index, serving node index, and the
    # request's total leg count (payment pro-rata denominator)
    leg_req: np.ndarray | None = None
    leg_node: np.ndarray | None = None
    leg_total: np.ndarray | None = None
    # vectorized request rows (indices into the batch) and their sizes
    vec_req_idx: np.ndarray | None = None
    vec_nbytes: np.ndarray | None = None
    node_ids: list[str] | None = None


def fastpath_fallback_reason(fleet, batch: RequestBatch | None = None) -> str | None:
    """World-level checks: None when the cohort fast path preserves task
    semantics, else a human-readable reason to replay request-per-task."""
    if not getattr(fleet.policy, "static", False):
        return "routing policy is stateful (depends on live fleet load)"
    for nid, node in zip(fleet.node_ids, fleet.rpcs):
        if node.admission is not None:
            return f"admission control attached ({nid})"
        if node.cache_ttl_ms is not None:
            return f"cache TTL attached ({nid})"
        if node.cache_admit_bytes is not None:
            return f"cache admission filter attached ({nid})"
        if not node.single_flight:
            return f"single-flight disabled ({nid})"
        if node._cache_size <= 0:
            return f"cache disabled ({nid})"
    if batch is not None and len(batch) and int(batch.length.min()) <= 0:
        return "zero-length read in batch"
    return None


def _fallback(fleet, batch, reason, *, engine, on_served, on_shed, trace):
    result = replay_open_loop(
        fleet, batch.to_requests(), engine=engine,
        on_served=on_served, on_shed=on_shed, trace=trace,
    )
    result.cohort = CohortStats(
        deopt_requests=len(batch), fallback_reason=reason,
        node_ids=list(fleet.node_ids),
    )
    return result


def replay_open_loop_fast(
    fleet,
    batch: RequestBatch,
    *,
    engine: str | None = None,
    on_served=None,  # (index, request, ServedRange) — de-opted requests only
    on_shed=None,
    trace: bool = False,
) -> ReplayResult:
    """Open-loop replay of a :class:`RequestBatch` through the cohort fast
    path; drop-in for ``replay_open_loop(fleet, batch.to_requests())`` on
    cohort-safe worlds (same records, digest, counters and payments), with
    per-request cost paid only by the requests that individuate.

    Rows land in ``ReplayResult.batch`` (``records`` stays empty);
    ``ReplayResult.cohort`` carries the split plus the per-leg (request,
    node) attribution that batched settlement consumes.
    """
    t_wall0 = time.perf_counter()  # simlint: ok SIM001 engine wall telemetry only
    n = len(batch)
    reason = fastpath_fallback_reason(fleet, batch)
    if reason is not None or n == 0:
        return _fallback(fleet, batch, reason or "empty batch", engine=engine,
                         on_served=on_served, on_shed=on_shed, trace=trace)

    lay = fleet.primary.layout
    csb = lay.chunkset_bytes
    t = batch.t_ms
    ln = batch.length

    # -- leg expansion: one leg per (request, chunkset) --------------------------
    first = batch.offset // csb
    last = (batch.offset + ln - 1) // csb
    nlegs = last - first + 1
    total = int(nlegs.sum())
    req_of_leg = np.repeat(np.arange(n, dtype=np.int64), nlegs)
    starts = np.cumsum(nlegs) - nlegs
    leg_cs = first[req_of_leg] + (np.arange(total, dtype=np.int64) - starts[req_of_leg])
    leg_blob = batch.blob_id[req_of_leg]

    # -- distinct keys, routed once each (the policy is static) ------------------
    stride = int(leg_cs.max()) + 1
    codes, inv = np.unique(leg_blob * stride + leg_cs, return_inverse=True)
    ub, uc = codes // stride, codes % stride
    policy = fleet.policy
    node_of_key = np.fromiter(
        (policy.pick((int(b), int(c)), None, fleet) for b, c in zip(ub, uc)),
        dtype=np.int64, count=len(codes),
    )

    # -- warm/cold scan + no-eviction guard --------------------------------------
    # A warm entry must also survive a version check (epoch reconfiguration
    # invalidates cached decodes); stale entries are deleted exactly as the
    # first task-mode probe would.  The guard then requires every node's
    # (surviving ∪ newly-routed) key set to fit its cache, so no LRU
    # eviction can occur mid-batch — the precondition for classifying hits
    # without replaying the recency order.
    nkeys = len(codes)
    warm = np.zeros(nkeys, dtype=bool)
    stale: list[tuple[object, tuple[int, int]]] = []
    routed_keys: list[set] = [set() for _ in fleet.rpcs]
    surviving: list[set] = [set(node._cache.keys()) for node in fleet.rpcs]
    for j in range(nkeys):
        i = int(node_of_key[j])
        node = fleet.rpcs[i]
        key = (int(ub[j]), int(uc[j]))
        routed_keys[i].add(key)
        entry = node._cache.get(key)
        if entry is None:
            continue
        _, expires, version = entry
        if expires is not None:
            return _fallback(fleet, batch, f"TTL-stamped cache entry ({fleet.node_ids[i]})",
                             engine=engine, on_served=on_served, on_shed=on_shed,
                             trace=trace)
        if version != node.contract.placement_version.get(key, 0):
            stale.append((node, key))
            surviving[i].discard(key)
        else:
            warm[j] = True
    for i, node in enumerate(fleet.rpcs):
        if len(surviving[i] | routed_keys[i]) > node._cache_size:
            return _fallback(fleet, batch,
                             f"cache eviction possible ({fleet.node_ids[i]})",
                             engine=engine, on_served=on_served, on_shed=on_shed,
                             trace=trace)
    for node, key in stale:  # committed to the fast path: apply the drops
        del node._cache[key]

    # -- probe times + cold-key leader election ----------------------------------
    bb = fleet.backbone
    if bb is None:
        prop_tab = np.zeros((len(batch.clients), len(fleet.rpcs)))
    else:
        prop_tab = np.array([
            [float(bb.propagation_ms(c, nid)) for nid in fleet.node_ids]
            for c in batch.clients
        ])
    leg_node = node_of_key[inv]
    leg_prop = prop_tab[batch.client_idx[req_of_leg], leg_node]
    leg_t = t[req_of_leg]
    probe_t = leg_t + leg_prop

    # the task-mode leader of a cold key is whichever probe event pops
    # first: earliest probe time, ties broken by push order = arrival time,
    # then spawn (request index) order
    order = np.lexsort((req_of_leg, leg_t, probe_t, inv))
    sorted_inv = inv[order]
    grp_first = np.ones(total, dtype=bool)
    grp_first[1:] = sorted_inv[1:] != sorted_inv[:-1]
    leader_leg = np.zeros(total, dtype=bool)
    leader_leg[order[grp_first]] = True
    leader_leg &= ~warm[inv]
    deopt = np.zeros(n, dtype=bool)
    deopt[req_of_leg[leader_leg]] = True

    # -- de-opted requests run as real tasks, puts instrumented ------------------
    loop = EventLoop(network=fleet.network, trace=trace, engine=engine)
    records: list = [None] * n
    for node in fleet.rpcs:
        node.cache_put_log = {}
    try:
        for i in np.flatnonzero(deopt).tolist():
            req = batch.request(i)
            loop.spawn(
                _serve_one(loop, fleet, records, i, req, f"req{i}",
                           on_served, on_shed),
                at_ms=req.t_ms, label=f"req{i}",
            )
        loop.run()
        put_logs = [node.cache_put_log for node in fleet.rpcs]
    finally:
        for node in fleet.rpcs:
            node.cache_put_log = None

    put_t_key = np.full(nkeys, np.nan)
    for j in np.flatnonzero(~warm).tolist():
        pt = put_logs[int(node_of_key[j])].get((int(ub[j]), int(uc[j])))
        if pt is not None:
            put_t_key[j] = pt

    # -- vectorized classification: hit vs coalesced -----------------------------
    vec_leg = ~deopt[req_of_leg]
    leg_cold = ~warm[inv]
    unservable = vec_leg & leg_cold & ~np.isfinite(put_t_key)[inv]
    if unservable.any():
        # the leader's fetch never produced a decode (ReadError under heavy
        # failures): its followers' fates need real error propagation, which
        # arrays cannot reproduce — this world must replay request-per-task
        raise RuntimeError(
            "cohort fast path: a cold key's leader fetch failed with "
            "vectorized followers attached; replay this world with "
            "replay_open_loop (fleet state has already advanced)"
        )
    leg_put = put_t_key[inv]
    coal = vec_leg & leg_cold & (probe_t < leg_put)
    s_lat = np.zeros(total)
    s_lat[coal] = leg_put[coal] - probe_t[coal]
    contrib = s_lat + 2.0 * leg_prop
    contrib[~vec_leg] = 0.0
    lat_all = np.maximum.reduceat(contrib, starts) if total else np.zeros(0)

    # -- fold the cohort into fleet/node accounting ------------------------------
    vec_req = ~deopt
    n_nodes = len(fleet.rpcs)
    routed_cnt = np.bincount(leg_node[vec_leg], minlength=n_nodes)
    hit_leg = vec_leg & ~coal
    hits_cnt = np.bincount(leg_node[hit_leg], minlength=n_nodes)
    coal_cnt = np.bincount(leg_node[coal], minlength=n_nodes)
    for i, node in enumerate(fleet.rpcs):
        fleet.routed[i] += int(routed_cnt[i])
        node.stats.cache_hits += int(hits_cnt[i])
        node.stats.coalesced += int(coal_cnt[i])
    n_vec_legs = int(vec_leg.sum())
    fleet.chunkset_reads += n_vec_legs
    fleet.bytes_served += int(ln[vec_req].sum())
    fleet.request_latencies_ms.extend(lat_all[vec_req].tolist())

    # -- assemble the pooled record rows -----------------------------------------
    t_arr = t.astype(np.float64, copy=True)
    finish = np.empty(n)
    lat = np.empty(n)
    nbytes = np.empty(n, dtype=np.int64)
    ok = np.ones(n, dtype=bool)
    shed_arr = np.zeros(n, dtype=bool)
    finish[vec_req] = t[vec_req] + lat_all[vec_req]
    lat[vec_req] = lat_all[vec_req]
    nbytes[vec_req] = ln[vec_req]
    for i in np.flatnonzero(deopt).tolist():
        r = records[i]
        t_arr[i], finish[i], lat[i] = r.t_ms, r.finish_ms, r.latency_ms
        nbytes[i], ok[i], shed_arr[i] = r.nbytes, r.ok, r.shed
    rows = RecordBatch(
        index=np.arange(n, dtype=np.int64), t_ms=t_arr, finish_ms=finish,
        latency_ms=lat, nbytes=nbytes, ok=ok, shed=shed_arr,
        client_idx=batch.client_idx.astype(np.int64, copy=True),
        blob_id=batch.blob_id.astype(np.int64, copy=True),
        clients=list(batch.clients),
    )

    vlegs = np.flatnonzero(vec_leg)
    n_vec = int(vec_req.sum())
    cohort = CohortStats(
        vec_requests=n_vec, deopt_requests=n - n_vec,
        hits=int(hit_leg.sum()), coalesced=int(coal.sum()),
        leg_req=req_of_leg[vlegs], leg_node=leg_node[vlegs],
        leg_total=nlegs[req_of_leg[vlegs]],
        vec_req_idx=np.flatnonzero(vec_req), vec_nbytes=ln[vec_req].copy(),
        node_ids=list(fleet.node_ids),
    )

    span = float(finish.max() - t_arr.min()) if n else 0.0
    link = dict(fleet.network.link_bytes) if fleet.network is not None else {}
    # a vectorized completion counts as one engine event: the batch retired
    # n_vec requests that task mode would each have popped several events for
    elapsed = time.perf_counter() - t_wall0  # simlint: ok SIM001 engine wall telemetry only
    ENGINE_COUNTERS["events"] += n_vec
    ENGINE_COUNTERS["wall_s"] += elapsed - loop.wall_s
    return ReplayResult(
        records=[], span_ms=span, link_bytes=link, trace=loop.trace,
        background=[], engine_events=loop.events_processed + n_vec,
        engine_wall_s=elapsed, batch=rows, cohort=cohort,
    )
