"""Deterministic workload scenario generators for the serving data plane.

Each generator yields a list of :class:`ReadRequest` — (sim time, client
node, blob, byte range) — modelling one of the paper's target workloads
(§1: "video streaming, AI training, analytics"):

* ``video_streaming`` — sequential segment reads paced at the bitrate;
* ``training_epoch``  — every sample of a dataset, reshuffled per epoch;
* ``analytics_scan``  — large sequential scans over whole blobs;
* ``zipf_hotset``     — Zipf-popular random-access traffic (the CDN case
  where hot-cache policy dominates).

Generators are pure functions of their seed, so two runs of a benchmark
replay byte-for-byte identical traffic.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReadRequest:
    t_ms: float
    client: str  # backbone node id (or bare label when no backbone attached)
    blob_id: int
    offset: int
    length: int


def video_streaming(
    meta,
    *,
    client: str,
    segment_bytes: int = 128 * 1024,
    bitrate_mbps: float = 25.0,
    start_ms: float = 0.0,
) -> list[ReadRequest]:
    """Sequential range reads of one blob, paced at the playback bitrate."""
    out, t = [], start_ms
    pace_ms = segment_bytes * 8e-3 / bitrate_mbps
    for off in range(0, meta.size_bytes, segment_bytes):
        out.append(
            ReadRequest(t, client, meta.blob_id, off, min(segment_bytes, meta.size_bytes - off))
        )
        t += pace_ms
    return out


def training_epoch(
    metas,
    *,
    client: str,
    sample_bytes: int = 64 * 1024,
    epochs: int = 1,
    interarrival_ms: float = 1.0,
    seed: int = 0,
) -> list[ReadRequest]:
    """Shuffled reads of every fixed-size sample record, per epoch."""
    rng = np.random.default_rng(seed)
    samples = [
        (m.blob_id, off, min(sample_bytes, m.size_bytes - off))
        for m in metas
        for off in range(0, m.size_bytes, sample_bytes)
    ]
    out, t = [], 0.0
    for _ in range(epochs):
        order = rng.permutation(len(samples))
        for i in order:
            blob_id, off, ln = samples[i]
            out.append(ReadRequest(t, client, blob_id, off, ln))
            t += interarrival_ms
    return out


def analytics_scan(
    metas,
    *,
    client: str,
    scan_bytes: int = 512 * 1024,
    interarrival_ms: float = 0.5,
) -> list[ReadRequest]:
    """Full sequential scans of every blob in large strides."""
    out, t = [], 0.0
    for m in metas:
        for off in range(0, m.size_bytes, scan_bytes):
            out.append(
                ReadRequest(t, client, m.blob_id, off, min(scan_bytes, m.size_bytes - off))
            )
            t += interarrival_ms
    return out


def zipf_hotset(
    metas,
    *,
    clients: list[str],
    num_requests: int = 200,
    exponent: float = 1.1,
    read_bytes: int = 64 * 1024,
    interarrival_ms: float = 0.4,
    seed: int = 0,
) -> list[ReadRequest]:
    """Zipf-popular random reads: a few blobs soak up most of the traffic."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(metas) + 1, dtype=np.float64)
    popularity = ranks**-exponent
    popularity /= popularity.sum()
    blob_order = rng.permutation(len(metas))  # which blob holds which rank
    out, t = [], 0.0
    for _ in range(num_requests):
        m = metas[blob_order[rng.choice(len(metas), p=popularity)]]
        ln = min(read_bytes, m.size_bytes)
        off = int(rng.integers(0, m.size_bytes - ln + 1))
        out.append(ReadRequest(t, str(rng.choice(clients)), m.blob_id, off, ln))
        t += interarrival_ms
    return out
