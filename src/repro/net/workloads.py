"""Deterministic workload scenario generators + arrival-process drivers.

Each generator yields a list of :class:`ReadRequest` — (sim time, client
node, blob, byte range) — modelling one of the paper's target workloads
(§1: "video streaming, AI training, analytics"):

* ``video_streaming`` — sequential segment reads paced at the bitrate;
* ``training_epoch``  — every sample of a dataset, reshuffled per epoch;
* ``analytics_scan``  — large sequential scans over whole blobs;
* ``zipf_hotset``     — Zipf-popular random-access traffic (the CDN case
  where hot-cache policy dominates), with fixed or Poisson interarrivals.

Generators are pure functions of their seed, so two runs of a benchmark
replay byte-for-byte identical traffic.

The *drivers* push those requests through the shared event engine:

* ``replay_open_loop``   — one task per request, spawned at its arrival
  time regardless of whether earlier requests finished (the §2.3 serving
  regime: load does not back off when the fleet slows down);
* ``replay_closed_loop`` — one task per client, each issuing its next
  request only after the previous one completed plus a think time.

Both return a :class:`ReplayResult` whose ``digest()`` hashes every
per-request timing and the backbone's per-link byte counters — the
determinism gate CI asserts on (two identical runs -> identical digests).
Both also accept ``background=`` plane(s) (``repro.storage.background``):
audit and repair tasks spawned on the SAME loop, so background traffic
contends with the replay and its per-op timings join the digest
(:class:`BackgroundRecord`, in ``ReplayResult.background``).
Requests the fleet refuses at admission (typed ``Overloaded`` NACKs) are
recorded as *shed*, separately from hard failures; ``sweep_open_loop``
ramps the offered rate and returns the goodput / shed-rate / p99 series
that make the saturation knee measurable.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.net.events import EventLoop, Sleep


@dataclasses.dataclass(frozen=True, slots=True)
class ReadRequest:
    t_ms: float
    client: str  # backbone node id (or bare label when no backbone attached)
    blob_id: int
    offset: int
    length: int


@dataclasses.dataclass(frozen=True, slots=True)
class SampleRequest:
    """One DAS sample: a tiny proof-carrying read of share (row, col).

    ``cache_bypass`` is the cache-steering hint threaded workload ->
    fleet -> RPCNode: sample storms are cache-hostile (uniform random
    single-use coordinates), so by default they skip hot-cache insertion
    rather than churn streaming readers' entries out.
    """

    t_ms: float
    client: str
    blob_id: int
    row: int
    col: int
    cache_bypass: bool = True


@dataclasses.dataclass
class RequestBatch:
    """Struct-of-arrays block of read requests — the million-request form.

    One frozen :class:`ReadRequest` per request costs hundreds of bytes of
    Python object; a 1M-request storm held that way is ~0.5 GB of boxed
    floats before the engine even starts.  A batch keeps the five columns
    as numpy arrays (client names interned once in ``clients``), which is
    what the cohort fast path (``repro.net.fastpath``) consumes directly —
    ``to_requests()`` materializes the identical request list for the
    task-per-request drivers, so the same batch replays on either path.
    """

    t_ms: np.ndarray  # float64 arrival times
    client_idx: np.ndarray  # index into ``clients``
    blob_id: np.ndarray  # int64
    offset: np.ndarray  # int64
    length: np.ndarray  # int64
    clients: list[str]

    def __len__(self) -> int:
        return int(self.t_ms.size)

    def request(self, i: int) -> ReadRequest:
        return ReadRequest(
            float(self.t_ms[i]), self.clients[int(self.client_idx[i])],
            int(self.blob_id[i]), int(self.offset[i]), int(self.length[i]),
        )

    def to_requests(self) -> list[ReadRequest]:
        """Materialize the equivalent per-request list (task-mode replay)."""
        names = self.clients
        return [
            ReadRequest(t, names[c], b, off, ln)
            for t, c, b, off, ln in zip(
                self.t_ms.tolist(), self.client_idx.tolist(),
                self.blob_id.tolist(), self.offset.tolist(),
                self.length.tolist(),
            )
        ]


def zipf_hotset_batch(
    metas,
    *,
    clients: list[str],
    num_requests: int = 200,
    exponent: float = 1.1,
    read_bytes: int = 64 * 1024,
    interarrival_ms: float = 0.4,
    seed: int = 0,
    arrival: str = "fixed",
) -> RequestBatch:
    """Vectorized Zipf storm: every column drawn as ONE numpy array.

    Same workload *shape* as :func:`zipf_hotset` (Zipf-ranked blobs behind
    a seeded rank permutation, uniform in-blob offsets, uniform clients,
    fixed or Poisson gaps) but each column is a single vectorized draw, so
    generating 1M requests costs milliseconds, not seconds.  The draw
    *order* differs from the scalar generator's interleaved stream, so the
    two are distinct seeded workloads — existing benches keep their exact
    request sequences, big-world ramps use this.
    """
    if arrival not in ("fixed", "poisson"):
        raise ValueError(f"arrival must be fixed|poisson, got {arrival!r}")
    n = num_requests
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(metas) + 1, dtype=np.float64)
    popularity = ranks**-exponent
    popularity /= popularity.sum()
    blob_order = rng.permutation(len(metas))  # which blob holds which rank
    sizes = np.array([m.size_bytes for m in metas], dtype=np.int64)
    blob_ids = np.array([m.blob_id for m in metas], dtype=np.int64)
    picks = blob_order[rng.choice(len(metas), size=n, p=popularity)]
    sz = sizes[picks]
    length = np.minimum(read_bytes, sz)
    offset = (rng.random(n) * (sz - length + 1)).astype(np.int64)
    client_idx = rng.integers(0, len(clients), size=n)
    if arrival == "poisson":
        gaps = rng.exponential(interarrival_ms, size=n)
        gaps[0] = 0.0
        t = np.cumsum(gaps)
    else:
        t = np.arange(n, dtype=np.float64) * interarrival_ms
    return RequestBatch(
        t_ms=t, client_idx=client_idx, blob_id=blob_ids[picks],
        offset=offset, length=length, clients=list(clients),
    )


def das_storm_batch(
    das_records,
    *,
    clients: list[str],
    num_requests: int = 200,
    interarrival_ms: float = 0.3,
    seed: int = 0,
    arrival: str = "poisson",
    cache_bypass: bool = True,
) -> list[SampleRequest]:
    """Vectorized DAS storm: blobs, (row, col) coordinates, clients and
    gaps drawn as whole numpy arrays up front (cf. :func:`das_storm`, whose
    per-request scalar draws pin the existing bench sequences)."""
    if arrival not in ("fixed", "poisson"):
        raise ValueError(f"arrival must be fixed|poisson, got {arrival!r}")
    recs = list(das_records)
    n = num_requests
    rng = np.random.default_rng(seed)
    ri = rng.integers(0, len(recs), size=n)
    sides = np.array([r.side for r in recs], dtype=np.int64)[ri]
    rows = (rng.random(n) * sides).astype(np.int64)
    cols = (rng.random(n) * sides).astype(np.int64)
    ci = rng.integers(0, len(clients), size=n)
    if arrival == "poisson":
        gaps = rng.exponential(interarrival_ms, size=n)
        gaps[0] = 0.0
        t = np.cumsum(gaps)
    else:
        t = np.arange(n, dtype=np.float64) * interarrival_ms
    blob_ids = np.array([r.blob_id for r in recs], dtype=np.int64)[ri]
    return [
        SampleRequest(tt, clients[c], b, r, cc, cache_bypass=cache_bypass)
        for tt, c, b, r, cc in zip(
            t.tolist(), ci.tolist(), blob_ids.tolist(),
            rows.tolist(), cols.tolist(),
        )
    ]


def video_streaming(
    meta,
    *,
    client: str,
    segment_bytes: int = 128 * 1024,
    bitrate_mbps: float = 25.0,
    start_ms: float = 0.0,
) -> list[ReadRequest]:
    """Sequential range reads of one blob, paced at the playback bitrate."""
    out, t = [], start_ms
    pace_ms = segment_bytes * 8e-3 / bitrate_mbps
    for off in range(0, meta.size_bytes, segment_bytes):
        out.append(
            ReadRequest(t, client, meta.blob_id, off, min(segment_bytes, meta.size_bytes - off))
        )
        t += pace_ms
    return out


def training_epoch(
    metas,
    *,
    client: str,
    sample_bytes: int = 64 * 1024,
    epochs: int = 1,
    interarrival_ms: float = 1.0,
    seed: int = 0,
) -> list[ReadRequest]:
    """Shuffled reads of every fixed-size sample record, per epoch."""
    rng = np.random.default_rng(seed)
    samples = [
        (m.blob_id, off, min(sample_bytes, m.size_bytes - off))
        for m in metas
        for off in range(0, m.size_bytes, sample_bytes)
    ]
    out, t = [], 0.0
    for _ in range(epochs):
        order = rng.permutation(len(samples))
        for i in order:
            blob_id, off, ln = samples[i]
            out.append(ReadRequest(t, client, blob_id, off, ln))
            t += interarrival_ms
    return out


def analytics_scan(
    metas,
    *,
    client: str,
    scan_bytes: int = 512 * 1024,
    interarrival_ms: float = 0.5,
) -> list[ReadRequest]:
    """Full sequential scans of every blob in large strides."""
    out, t = [], 0.0
    for m in metas:
        for off in range(0, m.size_bytes, scan_bytes):
            out.append(
                ReadRequest(t, client, m.blob_id, off, min(scan_bytes, m.size_bytes - off))
            )
            t += interarrival_ms
    return out


def zipf_hotset(
    metas,
    *,
    clients: list[str],
    num_requests: int = 200,
    exponent: float = 1.1,
    read_bytes: int = 64 * 1024,
    interarrival_ms: float = 0.4,
    seed: int = 0,
    arrival: str = "fixed",
) -> list[ReadRequest]:
    """Zipf-popular random reads: a few blobs soak up most of the traffic.

    ``arrival="fixed"`` paces requests exactly ``interarrival_ms`` apart;
    ``"poisson"`` draws exponential gaps with that mean — the open-loop
    storm shape IPFS measurement studies report for real dApp traffic.
    """
    if arrival not in ("fixed", "poisson"):
        raise ValueError(f"arrival must be fixed|poisson, got {arrival!r}")
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(metas) + 1, dtype=np.float64)
    popularity = ranks**-exponent
    popularity /= popularity.sum()
    blob_order = rng.permutation(len(metas))  # which blob holds which rank
    out, t = [], 0.0
    for _ in range(num_requests):
        m = metas[blob_order[rng.choice(len(metas), p=popularity)]]
        ln = min(read_bytes, m.size_bytes)
        off = int(rng.integers(0, m.size_bytes - ln + 1))
        out.append(ReadRequest(t, str(rng.choice(clients)), m.blob_id, off, ln))
        if arrival == "poisson":
            t += float(rng.exponential(interarrival_ms))
        else:
            t += interarrival_ms
    return out


def das_storm(
    das_records,
    *,
    clients: list[str],
    num_requests: int = 200,
    interarrival_ms: float = 0.3,
    seed: int = 0,
    arrival: str = "poisson",
    cache_bypass: bool = True,
) -> list[SampleRequest]:
    """Open-loop storm of single-share DAS sample requests.

    ``das_records`` expose ``.blob_id`` and ``.side`` (the contract's
    :class:`~repro.core.contract.DASRecord`).  Blobs, coordinates and
    issuing clients are drawn uniformly — the cache-hostile opposite of
    ``zipf_hotset`` — and the generator is a pure function of its seed,
    so the storm joins the determinism digest like any other workload.
    """
    if arrival not in ("fixed", "poisson"):
        raise ValueError(f"arrival must be fixed|poisson, got {arrival!r}")
    recs = list(das_records)
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for _ in range(num_requests):
        rec = recs[int(rng.integers(0, len(recs)))]
        row = int(rng.integers(0, rec.side))
        col = int(rng.integers(0, rec.side))
        out.append(
            SampleRequest(t, str(rng.choice(clients)), rec.blob_id, row, col,
                          cache_bypass=cache_bypass)
        )
        if arrival == "poisson":
            t += float(rng.exponential(interarrival_ms))
        else:
            t += interarrival_ms
    return out


# ---------------------------------------------------------------------------
# arrival-process drivers on the shared event engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BackgroundRecord:
    """One background-plane operation (audit, repair, or a membership
    event — join/leave/crash/slash/reconfigure) on the shared clock.

    Background traffic rides the same loop, NICs, trunks and SP disk slots
    as the foreground replay, so these timings are part of the determinism
    digest: same seed ⇒ same foreground AND background schedule — including
    WHO churned and WHAT got remapped.
    """

    kind: str  # "audit" | "repair" | "member"
    key: str  # stable id, e.g. "e0/a3/b1/c0/k2"
    t_ms: float  # task start on the sim clock
    finish_ms: float
    ok: bool
    nbytes: int  # bytes the op moved over the network (0 without a backbone)

    @property
    def latency_ms(self) -> float:
        return self.finish_ms - self.t_ms


@dataclasses.dataclass(frozen=True, slots=True)
class RequestRecord:
    """One request's fate on the shared simulated clock."""

    index: int
    t_ms: float  # arrival
    finish_ms: float
    latency_ms: float
    nbytes: int
    ok: bool
    client: str
    blob_id: int
    shed: bool = False  # refused at admission (Overloaded), not a failure
    kind: str = "read"  # "read" | "das" (a single-share sample)


@dataclasses.dataclass
class RecordBatch:
    """Struct-of-arrays pool of request records (one row per request).

    The fast path's counterpart to ``list[RequestRecord]``: a 1M-request
    replay keeps nine columns instead of a million frozen dataclasses, and
    every aggregate (shed counts, goodput, percentiles, the digest rows)
    reduces over arrays.  Rows are in request-index order and cover EVERY
    request of the replay — including the ones that de-opted to real
    generator tasks, whose records are folded back in after the loop runs.
    """

    index: np.ndarray  # int64
    t_ms: np.ndarray  # float64
    finish_ms: np.ndarray
    latency_ms: np.ndarray
    nbytes: np.ndarray  # int64
    ok: np.ndarray  # bool
    shed: np.ndarray  # bool
    client_idx: np.ndarray  # index into ``clients``
    blob_id: np.ndarray  # int64
    clients: list[str]
    kind: str = "read"

    def __len__(self) -> int:
        return int(self.index.size)

    def to_records(self) -> list[RequestRecord]:
        names = self.clients
        return [
            RequestRecord(i, t, f, lat, nb, ok, names[c], b, shed, self.kind)
            for i, t, f, lat, nb, ok, c, b, shed in zip(
                self.index.tolist(), self.t_ms.tolist(),
                self.finish_ms.tolist(), self.latency_ms.tolist(),
                self.nbytes.tolist(), self.ok.tolist(),
                self.client_idx.tolist(), self.blob_id.tolist(),
                self.shed.tolist(),
            )
        ]


@dataclasses.dataclass
class ReplayResult:
    """Outcome of replaying a workload through the shared event loop.

    Task-mode drivers fill ``records``; the cohort fast path fills
    ``batch`` (one :class:`RecordBatch` row per request, de-opted task
    records folded back in) and leaves ``records`` empty.  Every aggregate
    below reads both, so callers never care which driver produced the
    result — including ``digest()``, whose per-request rows are formatted
    identically from either representation.
    """

    records: list[RequestRecord]
    span_ms: float  # first arrival -> last client-observed finish
    link_bytes: dict  # backbone trunk utilization snapshot after the run
    trace: list[tuple[float, str, str]] | None = None  # loop audit trail
    # background-plane operations (audits, repairs) that shared the loop
    background: list[BackgroundRecord] = dataclasses.field(default_factory=list)
    # pooled per-request rows from the cohort fast path (records stay empty)
    batch: RecordBatch | None = None
    # fast-path cohort accounting (repro.net.fastpath.CohortStats): which
    # requests advanced vectorized, which individuated into tasks, and the
    # per-leg (request, node) attribution payment batching consumes
    cohort: object = None
    # engine telemetry: events the loop processed + wall-clock spent (the
    # fast path adds one event per vectorized request completion)
    engine_events: int = 0
    engine_wall_s: float = 0.0

    @property
    def engine_events_per_sec(self) -> float:
        """Engine throughput (events per wall-clock second) of this replay."""
        if self.engine_wall_s <= 0:
            return 0.0
        return self.engine_events / self.engine_wall_s

    @property
    def num_requests(self) -> int:
        return len(self.records) + (len(self.batch) if self.batch is not None else 0)

    @property
    def dropped(self) -> int:
        """Hard failures only; admission refusals are counted by `shed`."""
        n = sum(1 for r in self.records if not r.ok and not r.shed)
        if self.batch is not None:
            n += int(np.count_nonzero(~self.batch.ok & ~self.batch.shed))
        return n

    @property
    def shed(self) -> int:
        """Requests the fleet refused at admission (typed Overloaded)."""
        n = sum(1 for r in self.records if r.shed)
        if self.batch is not None:
            n += int(np.count_nonzero(self.batch.shed))
        return n

    @property
    def shed_rate(self) -> float:
        total = self.num_requests
        return self.shed / total if total else 0.0

    def _arrivals(self) -> np.ndarray:
        parts = []
        if self.records:
            parts.append(np.array([r.t_ms for r in self.records]))
        if self.batch is not None and len(self.batch):
            parts.append(self.batch.t_ms)
        return np.concatenate(parts) if parts else np.empty(0)

    @property
    def offered_rps(self) -> float:
        """Offered load: arrivals over the arrival window (requests/s)."""
        t = self._arrivals()
        if t.size < 2:
            return 0.0
        window = float(t.max() - t.min())
        return (t.size - 1) * 1e3 / window if window > 0 else float("inf")

    @property
    def goodput_mbps(self) -> float:
        """Delivered bits (served requests only) over the serving span."""
        if self.span_ms <= 0:
            return 0.0
        nbytes = sum(r.nbytes for r in self.records if r.ok)
        if self.batch is not None:
            nbytes += int(self.batch.nbytes[self.batch.ok].sum())
        return nbytes * 8e-3 / self.span_ms

    def latencies_ms(self, kind: str | None = None) -> list[float]:
        lats = [
            r.latency_ms for r in self.records
            if r.ok and (kind is None or r.kind == kind)
        ]
        if self.batch is not None and (kind is None or kind == self.batch.kind):
            lats.extend(self.batch.latency_ms[self.batch.ok].tolist())
        return lats

    def percentile(self, q: float, kind: str | None = None) -> float:
        lats = self.latencies_ms(kind)
        return float(np.percentile(np.asarray(lats), q)) if lats else 0.0

    # -- DAS sampling accounting ------------------------------------------------
    @property
    def das_samples(self) -> int:
        """Sample requests that ran to a verdict (served or hard-failed)."""
        return sum(1 for r in self.records if r.kind == "das" and not r.shed)

    @property
    def das_detections(self) -> int:
        """Samples that hit a withheld/bad share (ReadError, unpaid)."""
        return sum(
            1 for r in self.records if r.kind == "das" and not r.ok and not r.shed
        )

    # -- background-plane accounting ------------------------------------------------
    @property
    def background_ops(self) -> int:
        return len(self.background)

    @property
    def background_bytes(self) -> int:
        return sum(b.nbytes for b in self.background)

    @property
    def background_failures(self) -> int:
        return sum(1 for b in self.background if not b.ok)

    @property
    def membership_events(self) -> int:
        """Membership-plane records (joins/leaves/crashes/slashes plus the
        per-epoch reconfigure/lost summaries) that rode this replay."""
        return sum(1 for b in self.background if b.kind == "member")

    def background_percentile(self, q: float) -> float:
        lats = [b.latency_ms for b in self.background if b.ok]
        return float(np.percentile(np.asarray(lats), q)) if lats else 0.0

    def digest(self) -> str:
        """Determinism fingerprint: every request's exact timings, every
        background op's timings, plus the per-link byte counters.  Two runs
        of the same workload on a fresh world must produce byte-identical
        digests — including the audit/repair schedule."""
        h = hashlib.sha256()
        for r in self.records:
            h.update(
                f"{r.index}|{r.t_ms!r}|{r.finish_ms!r}|{r.latency_ms!r}|"
                f"{r.nbytes}|{r.ok}|{r.client}|{r.blob_id}|{r.shed}|{r.kind}\n".encode()
            )
        if self.batch is not None:
            # identical row format from the pooled columns (``.tolist()``
            # yields native float/int/bool, so every !r matches the record
            # path byte for byte) — a fast replay and a task replay of the
            # same schedule digest equal
            b = self.batch
            names, kind = b.clients, b.kind
            for i, t, f, lat, nb, ok, c, blob, shed in zip(
                b.index.tolist(), b.t_ms.tolist(), b.finish_ms.tolist(),
                b.latency_ms.tolist(), b.nbytes.tolist(), b.ok.tolist(),
                b.client_idx.tolist(), b.blob_id.tolist(), b.shed.tolist(),
            ):
                h.update(
                    f"{i}|{t!r}|{f!r}|{lat!r}|{nb}|{ok}|{names[c]}|{blob}|"
                    f"{shed}|{kind}\n".encode()
                )
        for b in self.background:
            h.update(
                f"bg|{b.kind}|{b.key}|{b.t_ms!r}|{b.finish_ms!r}|{b.ok}|"
                f"{b.nbytes}\n".encode()
            )
        for key in sorted(self.link_bytes, key=str):
            h.update(f"{key}={self.link_bytes[key]}\n".encode())
        return h.hexdigest()


@dataclasses.dataclass
class LoadSweep:
    """Goodput-vs-offered-load and shed-rate series across an open-loop
    ramp: one :class:`ReplayResult` per offered rate, with the aligned
    series the saturation analysis (and `benchmarks.backbone_serve`) plots.
    The *knee* is where goodput stops tracking offered load — with
    admission control it shows up as a rising shed rate and a bounded p99
    instead of a diverging queue.
    """

    rates_rps: list[float]
    results: list[ReplayResult]

    @property
    def goodput_mbps(self) -> list[float]:
        return [r.goodput_mbps for r in self.results]

    @property
    def shed_rate(self) -> list[float]:
        return [r.shed_rate for r in self.results]

    def p99_ms(self) -> list[float]:
        return [r.percentile(99.0) for r in self.results]

    def p50_ms(self) -> list[float]:
        return [r.percentile(50.0) for r in self.results]


def sweep_open_loop(make_fleet, make_requests, rates_rps, *,
                    driver=None) -> LoadSweep:
    """Replay the same workload shape at each offered rate on a FRESH fleet
    (``make_fleet() -> fleet``, ``make_requests(rate_rps) -> [ReadRequest]``)
    and collect the aligned saturation series.  ``driver`` defaults to
    :func:`replay_open_loop`; pass a session-aware closure to keep reads
    paid (see ``ShelbySession.replay``)."""
    results = []
    for rate in rates_rps:
        fleet = make_fleet()
        reqs = make_requests(rate)
        if driver is None:
            results.append(replay_open_loop(fleet, reqs))
        else:
            results.append(driver(fleet, reqs))
    return LoadSweep(rates_rps=list(rates_rps), results=results)


def _serve_one(loop, fleet, records, i, req, label, on_served, on_shed=None):
    """Task body shared by both drivers: serve one request, record its fate."""
    # deferred imports: storage.rpc imports repro.net.scheduler
    from repro.storage.rpc import Overloaded, ReadError

    t0 = loop.now
    try:
        srs = yield from fleet.serve_ranges_task(
            loop, [(req.blob_id, req.offset, req.length)],
            client=req.client, label=label,
        )
    except Overloaded:
        # load-shed: the fleet said no before doing the work — a cheap,
        # fast NACK that debits nothing (distinct from a hard failure)
        records[i] = RequestRecord(i, t0, loop.now, loop.now - t0, 0, False,
                                   req.client, req.blob_id, shed=True)
        if on_shed is not None:
            on_shed(i, req, loop.now - t0)
        return
    except ReadError:
        # unrecoverable under current failures: the request is dropped (and
        # pay-on-delivery means it debits nothing)
        records[i] = RequestRecord(i, t0, loop.now, loop.now - t0, 0, False,
                                   req.client, req.blob_id)
        return
    sr = srs[0]
    finish = t0 + sr.latency_ms  # client-observed (includes response prop)
    records[i] = RequestRecord(i, t0, finish, sr.latency_ms, len(sr.data),
                               True, req.client, req.blob_id)
    if on_served is not None:
        on_served(i, req, sr)
    return sr


def _sample_one(loop, fleet, records, i, req, label, on_sampled, on_shed=None):
    """Task body: one DAS sample through the fleet, recorded like a read.

    A hard failure (withheld / bad share) is the sampler's DETECTION
    signal, not an error to retry: it lands as ``ok=False, kind="das"``
    and debits nothing (pay-on-delivery)."""
    from repro.storage.rpc import Overloaded, ReadError

    t0 = loop.now
    try:
        ss = yield from fleet.sample_share_task(
            loop, req.blob_id, req.row, req.col,
            client=req.client, cache_bypass=req.cache_bypass, label=label,
        )
    except Overloaded:
        records[i] = RequestRecord(i, t0, loop.now, loop.now - t0, 0, False,
                                   req.client, req.blob_id, shed=True, kind="das")
        if on_shed is not None:
            on_shed(i, req, loop.now - t0)
        return
    except ReadError:
        records[i] = RequestRecord(i, t0, loop.now, loop.now - t0, 0, False,
                                   req.client, req.blob_id, kind="das")
        return
    finish = t0 + ss.latency_ms
    records[i] = RequestRecord(i, t0, finish, ss.latency_ms, ss.nbytes,
                               True, req.client, req.blob_id, kind="das")
    if on_sampled is not None:
        on_sampled(i, req, ss)
    return ss


def _planes(background) -> list:
    """Normalize the ``background`` argument: None, one plane, or a list of
    planes — anything with ``spawn(loop)`` and a ``records`` list (see
    ``repro.storage.background``)."""
    if background is None:
        return []
    if hasattr(background, "spawn"):
        return [background]
    return list(background)


def _finish_replay(loop, records, network, planes=()) -> ReplayResult:
    """Shared result assembly: drop unserved slots, compute the span, and
    snapshot link utilization for the determinism digest."""
    done = [r for r in records if r is not None]
    span = (
        max(r.finish_ms for r in done) - min(r.t_ms for r in done) if done else 0.0
    )
    link = dict(network.link_bytes) if network is not None else {}
    bg = [rec for p in planes for rec in p.records]
    return ReplayResult(records=done, span_ms=span, link_bytes=link,
                        trace=loop.trace, background=bg,
                        engine_events=loop.events_processed,
                        engine_wall_s=loop.wall_s)


def replay_open_loop(
    fleet,
    requests: list[ReadRequest],
    *,
    on_served=None,  # (index, request, ServedRange) -> None, completion order
    on_shed=None,  # (index, request, nack_latency_ms) -> None
    on_sampled=None,  # (index, SampleRequest, SampledShare) -> None
    background=None,  # plane(s) with spawn(loop): audits/repair share the loop
    trace: bool = False,
    engine: str | None = None,  # event-queue discipline (calendar|heap)
) -> ReplayResult:
    """Open-loop replay: every request is its own task spawned at its
    arrival time on ONE shared loop, so all in-flight requests' hedge
    timers, recoveries, SP queues and NIC transfers interleave.

    ``requests`` may mix :class:`ReadRequest` and :class:`SampleRequest`
    (a streaming workload concurrent with a DAS storm is just one merged
    request list); sample outcomes land in the same records under
    ``kind="das"``.

    ``background`` plane(s) are spawned on the SAME loop before it runs:
    audit proofs and repair helper reads contend with the replay for NICs,
    trunks and SP disk slots, and their records land in
    ``ReplayResult.background`` (covered by the determinism digest)."""
    if isinstance(requests, RequestBatch):
        requests = requests.to_requests()
    loop = EventLoop(network=fleet.network, trace=trace, engine=engine)
    records: list[RequestRecord | None] = [None] * len(requests)
    for i, req in enumerate(requests):
        if isinstance(req, SampleRequest):
            task = _sample_one(loop, fleet, records, i, req, f"req{i}",
                               on_sampled, on_shed)
        else:
            task = _serve_one(loop, fleet, records, i, req, f"req{i}",
                              on_served, on_shed)
        loop.spawn(task, at_ms=req.t_ms, label=f"req{i}")
    planes = _planes(background)
    for p in planes:
        p.spawn(loop)
    loop.run()
    return _finish_replay(loop, records, loop.network, planes)


def replay_closed_loop(
    fleet,
    schedules: list[tuple[str, list[tuple[int, int, int]]]],  # (client, ranges)
    *,
    think_ms: float = 0.0,
    background=None,  # plane(s) with spawn(loop), as in replay_open_loop
    trace: bool = False,
    engine: str | None = None,  # event-queue discipline (calendar|heap)
) -> ReplayResult:
    """Closed-loop replay: one task per client, each issuing its next
    request only after the previous one finished (plus ``think_ms``) — the
    training/analytics regime where offered load self-throttles."""
    loop = EventLoop(network=fleet.network, trace=trace, engine=engine)
    records: list[RequestRecord] = []

    def client_task(cname, ranges):
        for blob_id, off, ln in ranges:
            req = ReadRequest(loop.now, cname, blob_id, off, ln)
            i = len(records)
            records.append(None)  # reserve the slot in issue order
            sr = yield from _serve_one(loop, fleet, records, i, req, cname, None)
            if sr is not None:
                # pace to the client-observed completion (the node-side join
                # lands one propagation earlier than the client sees data)
                gap = records[i].finish_ms - loop.now
                if gap > 0 or think_ms > 0:
                    yield Sleep(max(gap, 0.0) + think_ms)

    for cname, ranges in schedules:
        loop.spawn(client_task(cname, ranges), at_ms=0.0, label=cname)
    planes = _planes(background)
    for p in planes:
        p.spawn(loop)
    loop.run()
    return _finish_replay(loop, records, loop.network, planes)
