"""Simulated dedicated backbone (§2.3): topology + transfer accounting.

The paper's RPC and storage nodes talk over a *dedicated* network, so
serving performance is a property of topology and load, not of the public
internet.  This module models that network as a set of datacenters joined
by directed trunks, each with a propagation latency and a bandwidth.  All
times are **simulated milliseconds**: a transfer departs at a caller-chosen
sim time and the model returns its arrival time, accounting FIFO
serialization on every trunk it crosses.  Nothing here reads a wall clock,
so latency numbers are workload-driven and exactly reproducible.

Model, per directed DC pair (a, b):

    arrival = start_tx + serialize(nbytes) + propagation(a, b)

where ``start_tx`` is the earliest idle slot on the trunk at or after the
departure time that fits the serialization window.  Reservations are kept
as disjoint busy intervals, so accounting stays correct even when callers
replay transfers out of time order (a straggler's late response must never
block a transfer that departs while the trunk is still idle).

Intra-DC transfers use a single (fat, short) implicit link per DC with the
same accounting.  Per-link byte counters expose utilization to benchmarks.

Nodes can additionally be NIC-limited: ``register_node(..., nic=NICSpec)``
gives a node full-duplex egress/ingress line rates.  A transfer then
serializes through up to three stages — source egress NIC, DC-pair trunk,
destination ingress NIC — modelled cut-through: each stage reserves its
earliest idle window at/after the *start* of the upstream stage's window,
and the arrival is the latest window end plus propagation.  A fan-in hot
node (one RPC node pulling chunks from a dozen SPs at once) therefore
queues on its own ingress NIC even when every trunk is idle — the paper's
"serving performance is a property of topology and load" made concrete.
Nodes without a NIC spec are unlimited (the pre-NIC behaviour, bit-exact).
"""
from __future__ import annotations

import bisect
import dataclasses
from collections import defaultdict


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One directed trunk: propagation delay + line rate."""

    latency_ms: float
    gbps: float

    def serialize_ms(self, nbytes: int) -> float:
        return nbytes * 8e-6 / self.gbps  # bits / (Gbit/s) in ms


DEFAULT_INTRA_DC = LinkSpec(latency_ms=0.2, gbps=100.0)
DEFAULT_INTER_DC = LinkSpec(latency_ms=8.0, gbps=40.0)


@dataclasses.dataclass(frozen=True)
class NICSpec:
    """Per-node full-duplex line rates (egress and ingress serialize
    independently; each direction is one FIFO resource)."""

    egress_gbps: float = 10.0
    ingress_gbps: float = 10.0

    def egress_ms(self, nbytes: int) -> float:
        return nbytes * 8e-6 / self.egress_gbps

    def ingress_ms(self, nbytes: int) -> float:
        return nbytes * 8e-6 / self.ingress_gbps


class Backbone:
    """Datacenter topology with simulated-clock transfer accounting.

    Nodes (SPs, RPC nodes, clients) are registered into a DC; transfers are
    node-to-node but queue on the DC-pair trunk (or the intra-DC fabric).
    """

    def __init__(
        self,
        dcs: list[str],
        *,
        inter_dc: dict[tuple[str, str], LinkSpec] | None = None,
        default_inter: LinkSpec = DEFAULT_INTER_DC,
        intra_dc: LinkSpec = DEFAULT_INTRA_DC,
        default_nic: NICSpec | None = None,
    ):
        self.dcs = list(dcs)
        self._inter = dict(inter_dc or {})
        self._default_inter = default_inter
        self._intra = intra_dc
        self._default_nic = default_nic
        self._node_dc: dict[str, str] = {}
        self._node_nic: dict[str, NICSpec | None] = {}
        # directed (src_dc, dst_dc) trunk — or ("nic>", node) egress /
        # ("nic<", node) ingress — key -> sorted disjoint busy intervals
        self._busy: dict[tuple[str, str], list[tuple[float, float]]] = defaultdict(list)
        self.link_bytes: dict[tuple[str, str], int] = defaultdict(int)
        self.nic_bytes: dict[tuple[str, str], int] = defaultdict(int)  # ("out"|"in", node)
        self.transfers = 0

    # -- topology builders ---------------------------------------------------------
    @classmethod
    def mesh(cls, num_dcs: int = 3, *, base_latency_ms: float = 8.0,
             gbps: float = 40.0, intra_dc: LinkSpec = DEFAULT_INTRA_DC,
             default_nic: NICSpec | None = None) -> "Backbone":
        """Full mesh of `num_dcs` DCs; latency grows with DC-index distance
        (a stand-in for geographic spread)."""
        dcs = [f"dc{i}" for i in range(num_dcs)]
        inter = {}
        for i, a in enumerate(dcs):
            for j, b in enumerate(dcs):
                if a != b:
                    inter[(a, b)] = LinkSpec(base_latency_ms * abs(i - j), gbps)
        return cls(dcs, inter_dc=inter, intra_dc=intra_dc, default_nic=default_nic)

    # -- membership --------------------------------------------------------------
    def register_node(self, node_id: str, dc: str,
                      nic: NICSpec | None = None) -> None:
        if dc not in self.dcs:
            raise ValueError(f"unknown dc {dc!r} (have {self.dcs})")
        self._node_dc[node_id] = dc
        self._node_nic[node_id] = nic or self._default_nic

    def nic_of(self, node_id: str) -> NICSpec | None:
        return self._node_nic.get(node_id)

    def dc_of(self, node_id: str) -> str:
        return self._node_dc[node_id]

    def _link(self, src_dc: str, dst_dc: str) -> LinkSpec:
        if src_dc == dst_dc:
            return self._intra
        return self._inter.get((src_dc, dst_dc), self._default_inter)

    # -- latency model -------------------------------------------------------------
    def propagation_ms(self, src: str, dst: str) -> float:
        """One-way propagation between two registered nodes."""
        return self._link(self.dc_of(src), self.dc_of(dst)).latency_ms

    def estimate_ms(self, src: str, dst: str, nbytes: int) -> float:
        """Uncongested transfer estimate (no queueing) — scheduler's prior.

        Cut-through pipeline: the serialization cost is the slowest stage
        (source NIC, trunk, destination NIC), not their sum."""
        link = self._link(self.dc_of(src), self.dc_of(dst))
        tx = link.serialize_ms(nbytes)
        src_nic, dst_nic = self.nic_of(src), self.nic_of(dst)
        if src_nic is not None:
            tx = max(tx, src_nic.egress_ms(nbytes))
        if dst_nic is not None:
            tx = max(tx, dst_nic.ingress_ms(nbytes))
        return link.latency_ms + tx

    def _reserve(self, key: tuple[str, str], depart_ms: float, tx_ms: float) -> float:
        """Earliest idle slot of length `tx_ms` at/after `depart_ms`."""
        intervals = self._busy[key]
        t = depart_ms
        i = bisect.bisect_left(intervals, (t, float("-inf")))
        if i > 0 and intervals[i - 1][1] > t:  # departure lands mid-interval
            t = intervals[i - 1][1]
        while i < len(intervals) and intervals[i][0] < t + tx_ms:
            t = max(t, intervals[i][1])
            i += 1
        intervals.insert(i, (t, t + tx_ms))
        return t

    # -- the one state-mutating call -----------------------------------------------
    def transfer(self, src: str, dst: str, nbytes: int, depart_ms: float) -> float:
        """Send `nbytes` src -> dst at sim time `depart_ms`; returns arrival.

        Serialization reserves the earliest idle window on every stage the
        bytes cross — source egress NIC, DC-pair trunk, destination ingress
        NIC — cut-through (each stage may start once the upstream window
        starts); arrival is the latest window end plus propagation.
        Propagation overlaps freely (links are pipes, not buses).
        """
        a, b = self.dc_of(src), self.dc_of(dst)
        link = self._link(a, b)
        src_nic, dst_nic = self.nic_of(src), self.nic_of(dst)
        stages: list[tuple[tuple[str, str], float]] = []
        if src_nic is not None:
            stages.append((("nic>", src), src_nic.egress_ms(nbytes)))
            self.nic_bytes[("out", src)] += nbytes
        stages.append(((a, b), link.serialize_ms(nbytes)))
        if dst_nic is not None:
            stages.append((("nic<", dst), dst_nic.ingress_ms(nbytes)))
            self.nic_bytes[("in", dst)] += nbytes
        t = depart_ms
        finish = depart_ms
        for key, tx in stages:
            start = self._reserve(key, t, tx)
            t = start
            finish = max(finish, start + tx)
        self.link_bytes[(a, b)] += nbytes
        self.transfers += 1
        return finish + link.latency_ms

    # -- introspection -------------------------------------------------------------
    def utilization(self) -> dict[tuple[str, str], int]:
        """Bytes moved per directed DC pair (intra-DC under (dc, dc))."""
        return dict(self.link_bytes)

    def reset_accounting(self) -> None:
        self._busy.clear()
        self.link_bytes.clear()
        self.nic_bytes.clear()
        self.transfers = 0
