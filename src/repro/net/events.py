"""Deterministic shared discrete-event engine for the whole data plane.

Before this module existed, every ``HedgedScheduler.fetch`` ran a *private*
event heap to completion before the next request started: hedge timers and
failure recoveries of concurrent requests could never interleave, and only
trunk reservations coupled requests.  The :class:`EventLoop` here is the
single global event queue the entire read path now runs on — concurrent
requests' issue/deadline/recovery events genuinely interleave, SPs queue,
NICs serialize — while staying exactly reproducible: events are ordered by
``(time, insertion seq)`` with a monotone sequence counter, so two runs of
the same workload pop the same events in the same order.  The queue itself
is a :class:`CalendarQueue` by default (O(1) expected per op at serving
event rates); ``engine="heap"`` keeps the original binary heap, and both
disciplines pop the identical total order, so swapping them never moves a
digest (asserted by ``tests/test_engine_equivalence.py``).

Tasks are plain Python generators that yield *effects*:

* ``Sleep(ms)``                 — resume after ``ms`` simulated milliseconds;
* ``Transfer(src, dst, nbytes)`` — move bytes across the loop's attached
  :class:`~repro.net.backbone.Backbone` (NIC + trunk serialization and
  propagation accounted); resumes at the arrival time;
* ``Acquire(resource, capacity)`` / ``Release(resource)`` — counting
  semaphore with a FIFO wait queue (SP disk slots, any shared resource).
  Acquires carry a *priority class* (0 = foreground) and an optional
  per-class slot cap: waiters wake in (priority, FIFO) order, and a class
  at its cap queues even while slots are free — this is how background
  traffic (audits, repair) shares an SP's disks with paid serving without
  ever starving it;
* ``Join(handle)``              — wait for a task spawned with
  :meth:`EventLoop.spawn`; resumes with its return value, or re-raises
  its exception;
* ``Recv(channel)``             — wait for a message on a
  :class:`Channel` (how a hedged fetch hears from its in-flight legs
  *and* its deadline timer through one ordered stream).

:class:`SingleFlight` is the cache-stampede primitive built on ``Join``:
concurrent callers asking for the same key share ONE spawned task (the
first caller leads, the rest coalesce), so N simultaneous misses on a hot
object cost one fetch instead of N.

Sync callers keep working: wrap a task in a fresh loop and
``run_until`` it (see ``RPCNode.read_items_detailed``).  Concurrent
drivers (``repro.net.workloads.replay_open_loop`` /
``replay_closed_loop``) spawn one task per request on a shared loop and
``run()`` everything to completion.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import os
import sys
import time
from collections import deque
from typing import Any, Callable, Generator

#: queue discipline new loops use when ``engine`` is not given explicitly.
#: "calendar" is the production default; "heap" keeps the original binary
#: heap alive so the engine-equivalence tests can diff the two pop orders.
DEFAULT_ENGINE = "calendar"

#: process-wide engine telemetry, accumulated across EVERY loop drained in
#: this process — benchmark sections that drive many private loops (e.g. the
#: sync serve grid) report a delta of this instead of one loop's counters.
ENGINE_COUNTERS = {"events": 0, "wall_s": 0.0}


def engine_counters() -> tuple[int, float]:
    """Snapshot of (events processed, wall seconds) across all loops."""
    return ENGINE_COUNTERS["events"], ENGINE_COUNTERS["wall_s"]


# -- effects (what a task may yield) ----------------------------------------------
@dataclasses.dataclass(frozen=True)
class Sleep:
    """Resume this task after ``ms`` simulated milliseconds."""

    ms: float


@dataclasses.dataclass(frozen=True)
class Transfer:
    """Move ``nbytes`` src -> dst over the loop's attached network."""

    src: str
    dst: str
    nbytes: int


@dataclasses.dataclass(frozen=True)
class Acquire:
    """Take one slot of a shared resource; queues FIFO when saturated.

    ``capacity`` sizes the resource the first time its key is seen;
    later acquires of the same key ignore it.

    ``priority`` is the scheduling class (0 = foreground; larger numbers
    are more deferrable) and ``limit`` caps how many slots THIS class may
    hold concurrently — a background acquire at its class cap queues even
    while free slots exist, so paid serving always finds headroom.  Waiters
    wake in (priority, arrival) order: a queued foreground request is never
    overtaken by background work.
    """

    resource: Any  # hashable key, e.g. ("sp", 3)
    capacity: int = 1
    priority: int = 0
    limit: int | None = None  # max concurrent slots for this priority class


@dataclasses.dataclass(frozen=True)
class Release:
    """Give back one slot; wakes the best eligible waiter at the current
    time.  ``priority`` must match the class of the paired ``Acquire`` so
    per-class accounting stays balanced."""

    resource: Any
    priority: int = 0


def safe_release(effect: "Release") -> Generator:
    """``yield from`` this inside a ``finally:`` block to give a slot back
    on every *live* exit path — normal completion and thrown exceptions —
    of a task's critical section::

        yield Acquire(("sp", 3), slots)
        try:
            yield Sleep(service_ms)
        finally:
            yield from safe_release(Release(("sp", 3)))

    During task *teardown* (``GeneratorExit`` — the generator of a
    ``run_until`` straggler being garbage-collected, or an explicit
    ``gen.close()``) it yields nothing: a closing generator may not yield
    (``RuntimeError: generator ignored GeneratorExit``), and slot reclaim
    for cancelled tasks is the engine's job (``TaskHandle.cancel``), so
    yielding here would be both illegal and double-counted."""
    if isinstance(sys.exc_info()[1], GeneratorExit):
        return
    yield effect


@dataclasses.dataclass(frozen=True)
class Join:
    """Wait for another task; resumes with its result or raises its error."""

    handle: "TaskHandle"


@dataclasses.dataclass(frozen=True)
class Recv:
    """Wait for (or immediately take) the next message on a channel."""

    channel: "Channel"


class TaskHandle:
    """One spawned task: its generator, lifecycle state, and joiners."""

    __slots__ = (
        "gen", "label", "done", "result", "error", "error_delivered",
        "cancelled", "started_ms", "finished_ms", "_joiners",
        "held", "_loop",
    )

    def __init__(self, gen: Generator, label: str, started_ms: float):
        self.gen = gen
        self.label = label
        self.done = False
        self.result: Any = None
        self.error: BaseException | None = None
        self.error_delivered = False
        self.cancelled = False
        self.started_ms = started_ms
        self.finished_ms = float("nan")
        self._joiners: list["TaskHandle"] = []
        # resource slots this task currently holds, as (key, priority,
        # t_acquired) — lets cancel() give slots back and lets simsan name
        # leak holders at drain
        self.held: list[tuple[Any, int, float]] = []
        self._loop: "EventLoop | None" = None

    def cancel(self) -> None:
        """Drop the task: pending wakeups for it are skipped when popped,
        and any resource slots it still holds are released back to the
        loop at the current sim time.  (The generator is abandoned, not
        closed, so a `finally: yield Release` inside it can never run —
        the engine must reclaim the slots itself or they leak.)"""
        self.cancelled = True
        if self._loop is not None and not self.done and self.held:
            self._loop._reclaim(self)

    def __repr__(self) -> str:  # debugging aid only
        state = "done" if self.done else ("cancelled" if self.cancelled else "live")
        return f"<Task {self.label} {state}>"


class Resource:
    """Counting semaphore with a priority wait queue and queueing telemetry.

    Waiters are ordered by (priority class, arrival seq) — FIFO within a
    class, foreground (class 0) ahead of background.  A class with a slot
    cap (``Acquire.limit``) is skipped while at its cap, letting slots sit
    free for foreground work instead of being soaked up by background.
    """

    __slots__ = ("key", "capacity", "in_use", "waiters", "acquired",
                 "wait_ms_total", "max_queue", "in_use_by_class",
                 "wait_ms_by_class", "acquired_by_class")

    def __init__(self, key: Any, capacity: int):
        if capacity < 1:
            raise ValueError(f"resource {key!r} needs capacity >= 1")
        self.key = key
        self.capacity = capacity
        self.in_use = 0
        # priority class -> FIFO of (handle, enqueue_ms, class_limit); wake
        # order is class-ascending then FIFO, so a release is O(#classes),
        # not O(queue depth) — the foreground-only saturation path keeps
        # its old one-deque cost
        self.waiters: dict[int, deque[tuple[TaskHandle, float, int | None]]] = {}
        self.acquired = 0
        self.wait_ms_total = 0.0
        self.max_queue = 0
        self.in_use_by_class: dict[int, int] = {}
        self.wait_ms_by_class: dict[int, float] = {}
        self.acquired_by_class: dict[int, int] = {}

    def can_grant(self, priority: int, limit: int | None) -> bool:
        if self.in_use >= self.capacity:
            return False
        if limit is not None and self.in_use_by_class.get(priority, 0) >= limit:
            return False
        return True

    def grant(self, priority: int, waited_ms: float = 0.0) -> None:
        self.in_use += 1
        self.acquired += 1
        self.in_use_by_class[priority] = self.in_use_by_class.get(priority, 0) + 1
        self.acquired_by_class[priority] = self.acquired_by_class.get(priority, 0) + 1
        if waited_ms:
            self.wait_ms_total += waited_ms
            self.wait_ms_by_class[priority] = (
                self.wait_ms_by_class.get(priority, 0.0) + waited_ms
            )

    def enqueue(self, priority: int, handle: TaskHandle, t_ms: float,
                limit: int | None) -> None:
        self.waiters.setdefault(priority, deque()).append((handle, t_ms, limit))
        self.max_queue = max(
            self.max_queue, sum(len(q) for q in self.waiters.values())
        )

    def pop_eligible(self) -> tuple[int, TaskHandle, float] | None:
        """Remove and return the first live waiter in (priority class,
        FIFO) order whose class is under its cap; purge dead entries on
        the way.  A capped class head blocks its whole class (strict FIFO
        within a class), never other classes."""
        for prio in sorted(self.waiters):
            q = self.waiters[prio]
            while q:
                h, t0, limit = q[0]
                if h.cancelled or h.done:
                    q.popleft()
                    continue
                if (limit is not None
                        and self.in_use_by_class.get(prio, 0) >= limit):
                    break  # class at its cap: try the next class
                q.popleft()
                return prio, h, t0
        return None


class Channel:
    """Unbounded FIFO message queue; one waiter resumed per send."""

    def __init__(self, loop: "EventLoop"):
        self._loop = loop
        self._queue: deque[Any] = deque()
        self._waiters: deque[TaskHandle] = deque()

    def send(self, value: Any) -> None:
        """Deliver a message at the loop's current time (callable from any
        task's step — the oldest live waiter is scheduled, FIFO)."""
        while self._waiters:
            h = self._waiters.popleft()
            if h.cancelled or h.done:
                continue
            self._loop._push(self._loop.now, h, ("resume", value))
            return
        self._queue.append(value)


class SingleFlight:
    """Per-key in-flight task dedup (the classic cache-stampede collapse).

    The first caller of :meth:`flight` for a key becomes the *leader*: its
    factory generator is spawned on the loop and registered under the key.
    Every later caller while that task is live is a *follower*: it gets the
    leader's :class:`TaskHandle` back and simply ``Join``\\ s it — one fetch
    serves all concurrent waiters, and the key is released the moment the
    task finishes (success or error), so a later miss starts a fresh
    flight.  Errors propagate to every joiner, exactly like ``Join``.

    One instance is bound to one :class:`EventLoop`; holders that outlive a
    loop (e.g. an ``RPCNode`` called through many private loops) should key
    their instance by the loop (see ``RPCNode._single_flight_for``).
    """

    def __init__(self, loop: "EventLoop"):
        self.loop = loop
        self._inflight: dict[Any, TaskHandle] = {}
        self.launched = 0  # flights that actually spawned a task
        self.coalesced = 0  # callers that piggybacked on a live flight

    def live(self, key: Any) -> bool:
        """True iff a flight for ``key`` is currently in the air (a call
        to :meth:`flight` now would coalesce instead of spawning)."""
        h = self._inflight.get(key)
        return h is not None and not h.done and not h.cancelled

    def flight(self, key: Any, factory: Callable[[], Generator],
               label: str | None = None) -> tuple["TaskHandle", bool]:
        """Return ``(handle, leader)`` — ``leader`` is True iff this call
        spawned the task (the caller should Join the handle either way)."""
        live = self._inflight.get(key)
        if live is not None and not live.done and not live.cancelled:
            self.coalesced += 1
            return live, False

        def flown():
            try:
                result = yield from factory()
            finally:
                # release on the same event step the task finishes, so a
                # miss arriving any later starts a fresh flight
                if self._inflight.get(key) is h:
                    del self._inflight[key]
            return result

        h = self.loop.spawn(flown(), label=label or f"flight{key}")
        self._inflight[key] = h
        self.launched += 1
        return h, True


class _BinaryHeap:
    """The original single binary heap, kept behind the ``engine="heap"``
    knob as the reference pop order for the calendar queue."""

    __slots__ = ("_h",)

    def __init__(self):
        self._h: list[tuple[float, int, TaskHandle, tuple[str, Any]]] = []

    def __len__(self) -> int:
        return len(self._h)

    def push(self, item) -> None:
        heapq.heappush(self._h, item)

    def pop(self):
        return heapq.heappop(self._h)


class CalendarQueue:
    """Calendar queue over simulated time: events bucket into fixed-width
    *days* keyed by ``floor(t / width)``.

    Keying days in a dict (instead of the classic modulo ring) makes
    far-future timestamps safe — there is no year wrap to corrupt ordering,
    a day materializes only when an event lands in it, and it is freed the
    moment it drains.  Each day's bucket is heap-ordered by the full
    ``(t_ms, seq, …)`` tuple and a small heap of day indices finds the next
    nonempty day, so ``pop`` always returns the *global* ``(time, seq)``
    minimum: the pop order is bit-identical to the single binary heap's,
    which is what keeps every existing determinism digest unchanged.

    Cost: O(1) expected per op while buckets stay small (they do when
    ``width_ms`` is on the order of the mean event gap — sub-ms to a few ms
    for this data plane); degrades gracefully toward plain heap behaviour
    when everything lands in one day (zero-delay wake storms) or every
    event gets its own day (sparse timers), never worse than O(log n).
    """

    __slots__ = ("width", "_days", "_day_heap", "_len")

    def __init__(self, width_ms: float = 1.0):
        if width_ms <= 0:
            raise ValueError("calendar day width must be positive")
        self.width = width_ms
        # invariant: _day_heap holds exactly the keys of _days (no stale ids)
        self._days: dict[int, list] = {}
        self._day_heap: list[int] = []
        self._len = 0

    def __len__(self) -> int:
        return self._len

    def push(self, item) -> None:
        day = int(item[0] // self.width)
        bucket = self._days.get(day)
        if bucket is None:
            self._days[day] = bucket = []
            heapq.heappush(self._day_heap, day)
        heapq.heappush(bucket, item)
        self._len += 1

    def pop(self):
        day = self._day_heap[0]  # IndexError on empty, like heappop
        bucket = self._days[day]
        item = heapq.heappop(bucket)
        self._len -= 1
        if not bucket:
            del self._days[day]
            heapq.heappop(self._day_heap)
        return item


class EventLoop:
    """The shared event queue.  ``network`` (a Backbone) interprets
    ``Transfer``; ``engine`` picks the queue discipline ("calendar", the
    default, or the reference "heap") — both pop the exact same
    ``(time, seq)`` order, so the choice never changes a digest."""

    def __init__(self, network=None, *, trace: bool = False,
                 engine: str | None = None, sanitize: bool | None = None):
        self.now = 0.0
        self.network = network
        self.engine = engine or DEFAULT_ENGINE
        if self.engine == "calendar":
            self._q: CalendarQueue | _BinaryHeap = CalendarQueue()
        elif self.engine == "heap":
            self._q = _BinaryHeap()
        else:
            raise ValueError(f"engine must be calendar|heap, got {self.engine!r}")
        # simsan: opt-in runtime sanitizer (pop-order audit, slot-leak and
        # off-loop-mutation detection); SHELBY_SIMSAN=1 turns it on for
        # every loop in the process.  None when off — the hot path pays
        # one `is not None` test per hook.
        if sanitize is None:
            sanitize = bool(os.environ.get("SHELBY_SIMSAN"))
        self.sanitize = sanitize
        self._san = None
        self._current: TaskHandle | None = None
        if sanitize:
            from repro.analysis.simsan import Sanitizer
            self._san = Sanitizer(self)
        self._seq = itertools.count()
        self._resources: dict[Any, Resource] = {}
        self._tasks: list[TaskHandle] = []
        self._failures: list[TaskHandle] = []
        # engine telemetry: events popped + wall-clock spent draining, the
        # basis of ReplayResult.engine_events_per_sec
        self.events_processed = 0
        self.wall_s = 0.0
        # optional (t_ms, task label, step kind) record — the audit trail the
        # interleaving tests assert on
        self.trace: list[tuple[float, str, str]] | None = [] if trace else None

    @property
    def events_per_sec(self) -> float:
        """Engine throughput of this loop's drains (0 before any run)."""
        return self.events_processed / self.wall_s if self.wall_s > 0 else 0.0

    # -- resources -----------------------------------------------------------------
    def resource(self, key: Any, capacity: int = 1) -> Resource:
        res = self._resources.get(key)
        if res is None:
            if self._san is not None:
                from repro.analysis.simsan import GuardedResource
                res = GuardedResource(key, capacity, self._san)
            else:
                res = Resource(key, capacity)
            self._resources[key] = res
        return res

    def _reclaim(self, h: TaskHandle) -> None:
        """Release every slot a cancelled task still holds (at ``now``)."""
        while h.held:
            key, priority, _t_acq = h.held[0]
            self._do_release(key, priority, holder=h)

    def _do_release(self, key: Any, priority: int, *,
                    holder: TaskHandle | None = None) -> None:
        """Give one slot of ``key`` back and wake the best eligible waiter
        at the current time — the shared path under a task's ``Release``
        effect and ``TaskHandle.cancel``'s slot reclaim."""
        res = self.resource(key)
        if holder is not None:
            for i, (k, p, _t) in enumerate(holder.held):
                if k == key and p == priority:
                    del holder.held[i]
                    break
        san = self._san
        if san is not None:
            san.on_touch(res, holder)
            san.on_release(res, priority, holder)
            with san.engine_op():
                self._release_inner(res, priority)
            san.record(res, holder)
        else:
            self._release_inner(res, priority)

    def _release_inner(self, res: Resource, priority: int) -> None:
        res.in_use -= 1
        held = res.in_use_by_class.get(priority, 0)
        res.in_use_by_class[priority] = max(0, held - 1)
        woken = res.pop_eligible()
        if woken is not None:
            prio, w, t0 = woken
            res.grant(prio, waited_ms=self.now - t0)
            w.held.append((res.key, prio, self.now))
            self._push(self.now, w, ("resume", None))

    # -- task lifecycle ------------------------------------------------------------
    def spawn(self, gen: Generator, at_ms: float | None = None,
              label: str | None = None) -> TaskHandle:
        """Schedule a generator task; it first steps at ``at_ms`` (default:
        the current time).  Returns a handle usable with ``Join``."""
        t = self.now if at_ms is None else at_ms
        h = TaskHandle(gen, label or f"task{len(self._tasks)}", t)
        h._loop = self
        self._tasks.append(h)
        self._push(t, h, ("resume", None))
        return h

    def _push(self, t_ms: float, handle: TaskHandle, action: tuple[str, Any]) -> None:
        if self._san is not None:
            self._san.on_push(t_ms, handle)
        self._q.push((t_ms, next(self._seq), handle, action))

    def _finish(self, h: TaskHandle, *, result: Any = None,
                error: BaseException | None = None) -> None:
        h.done = True
        h.result = result
        h.error = error
        h.finished_ms = self.now
        for j in h._joiners:
            if error is not None:
                h.error_delivered = True
                self._push(self.now, j, ("throw", error))
            else:
                self._push(self.now, j, ("resume", result))
        h._joiners.clear()
        if error is not None and not h.error_delivered:
            self._failures.append(h)

    def _step(self) -> None:
        t, seq, h, (kind, value) = self._q.pop()
        self.events_processed += 1
        self.now = t
        if self._san is not None:
            self._san.on_pop(t, seq)
        if h.cancelled or h.done:
            return
        if self.trace is not None:
            self.trace.append((t, h.label, kind))
        self._current = h
        try:
            effect = h.gen.throw(value) if kind == "throw" else h.gen.send(value)
        except StopIteration as stop:
            self._finish(h, result=stop.value)
            return
        except (GeneratorExit, KeyboardInterrupt):
            # control-flow signals are never a task *result*: recording them
            # as task errors would hand teardown/interrupt to a Join'er
            # instead of the driver.  (BaseException subclasses would skip
            # the Exception clause below anyway — this clause states the
            # intent and keeps it true if the hierarchy ever shifts.)
            raise
        except Exception as err:
            self._finish(h, error=err)
            return
        finally:
            self._current = None
        self._dispatch(h, effect)

    def _dispatch(self, h: TaskHandle, effect: Any) -> None:
        if isinstance(effect, Sleep):
            self._push(self.now + max(0.0, effect.ms), h, ("resume", None))
        elif isinstance(effect, Transfer):
            if self.network is None:
                self._finish(h, error=RuntimeError(
                    f"task {h.label} yielded Transfer but the loop has no network"))
                return
            arrival = self.network.transfer(effect.src, effect.dst,
                                            effect.nbytes, self.now)
            self._push(arrival, h, ("resume", arrival))
        elif isinstance(effect, Acquire):
            res = self.resource(effect.resource, effect.capacity)
            if self._san is not None:
                self._san.on_touch(res, h)
                with self._san.engine_op():
                    if res.can_grant(effect.priority, effect.limit):
                        res.grant(effect.priority)
                        h.held.append((res.key, effect.priority, self.now))
                        self._push(self.now, h, ("resume", None))
                    else:
                        res.enqueue(effect.priority, h, self.now, effect.limit)
                self._san.record(res, h)
            elif res.can_grant(effect.priority, effect.limit):
                res.grant(effect.priority)
                h.held.append((res.key, effect.priority, self.now))
                self._push(self.now, h, ("resume", None))
            else:
                res.enqueue(effect.priority, h, self.now, effect.limit)
        elif isinstance(effect, Release):
            self._do_release(effect.resource, effect.priority, holder=h)
            self._push(self.now, h, ("resume", None))
        elif isinstance(effect, Join):
            child = effect.handle
            if child.done:
                if child.error is not None:
                    child.error_delivered = True
                    self._push(self.now, h, ("throw", child.error))
                else:
                    self._push(self.now, h, ("resume", child.result))
            else:
                child._joiners.append(h)
        elif isinstance(effect, Recv):
            ch = effect.channel
            if ch._queue:
                self._push(self.now, h, ("resume", ch._queue.popleft()))
            else:
                ch._waiters.append(h)
        else:
            self._finish(h, error=TypeError(
                f"task {h.label} yielded unknown effect {effect!r}"))

    # -- drivers -------------------------------------------------------------------
    def run(self) -> float:
        """Drain every event; returns the final simulated time.

        Raises the first exception of any task whose error was never
        delivered to a joiner, and flags deadlocks (tasks left suspended on
        a Join/Recv/Acquire that can never fire)."""
        # wall-clock here is engine telemetry (events/sec); it never feeds
        # back into simulated behaviour
        events0, t0 = self.events_processed, time.perf_counter()  # simlint: ok SIM001 engine wall telemetry only
        try:
            while self._q:
                self._step()
        finally:
            dt = time.perf_counter() - t0  # simlint: ok SIM001 engine wall telemetry only
            self.wall_s += dt
            ENGINE_COUNTERS["wall_s"] += dt
            ENGINE_COUNTERS["events"] += self.events_processed - events0
        for h in self._failures:
            if not h.error_delivered:
                raise h.error
        stuck = [h for h in self._tasks if not h.done and not h.cancelled]
        if stuck:
            names = ", ".join(s.label for s in stuck[:8])
            raise RuntimeError(
                f"event loop drained with {len(stuck)} task(s) still "
                f"suspended (deadlock?): {names}")
        if self._san is not None:
            # a full drain must leave every resource slot returned; this is
            # deliberately NOT checked in run_until, which abandons
            # stragglers like a real client dropping in-flight RPCs
            self._san.on_drain()
        return self.now

    def run_until(self, handle: TaskHandle) -> Any:
        """Process events until ``handle`` completes; returns its result (or
        raises its error).  Later events — e.g. straggler responses the
        caller stopped caring about — stay unprocessed, exactly like a real
        client abandoning in-flight RPCs."""
        events0, t0 = self.events_processed, time.perf_counter()  # simlint: ok SIM001 engine wall telemetry only
        try:
            while not handle.done and self._q:
                self._step()
        finally:
            dt = time.perf_counter() - t0  # simlint: ok SIM001 engine wall telemetry only
            self.wall_s += dt
            ENGINE_COUNTERS["wall_s"] += dt
            ENGINE_COUNTERS["events"] += self.events_processed - events0
        if not handle.done:
            raise RuntimeError(
                f"task {handle.label} never completed: event heap drained "
                f"while it was still suspended")
        if handle.error is not None:
            handle.error_delivered = True
            raise handle.error
        return handle.result
