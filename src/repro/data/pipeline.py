"""Blob-backed training-data pipeline (§6 "AI and Data Marketplaces").

Token corpora live in Shelby as blobs of little-endian int32 token ids; the
pipeline is a *paying read client*: every batch is one ``client.get_many``
call — all of the batch's example ranges are routed across the RPC fleet in
a single pass (hedged k-of-n fetches under the hood, so a slow or dead SP
never stalls the input pipeline, and the chunksets the batch misses decode
together in wide GF batch-decodes).

A background prefetch thread keeps `prefetch` batches decoded ahead of the
training loop, mirroring the paper's "RPCs maintain small caching layers".
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.storage.sdk import ShelbyClient


def write_token_corpus(client: ShelbyClient, tokens: np.ndarray) -> int:
    """tokens: 1-D int32 array -> blob id."""
    tokens = np.ascontiguousarray(tokens, dtype=np.int32)
    return client.put(tokens.tobytes()).blob_id


class BlobTokenDataset:
    """Deterministic, shardable batch iterator over a token blob."""

    def __init__(
        self,
        client: ShelbyClient,
        blob_id: int,
        batch: int,
        seq_len: int,
        *,
        shard: int = 0,
        num_shards: int = 1,
        seed: int = 0,
        prefetch: int = 2,
    ):
        self.client = client
        self.blob_id = blob_id
        self.batch = batch
        self.seq_len = seq_len
        self.shard = shard
        self.num_shards = num_shards
        meta = client.contract.blobs[blob_id]
        self.num_tokens = meta.size_bytes // 4
        self.tokens_per_example = seq_len + 1  # inputs + shifted labels
        self.num_examples = self.num_tokens // self.tokens_per_example
        self._rng = np.random.default_rng(seed)
        self._order = self._rng.permutation(self.num_examples)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._cursor = shard * batch
        self._thread: threading.Thread | None = None

    def _next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        ranges = []
        for _ in range(self.batch):
            if self._cursor >= self.num_examples:
                self._cursor = self.shard * self.batch  # wrap epoch
                self._order = self._rng.permutation(self.num_examples)
            off = int(self._order[self._cursor]) * self.tokens_per_example * 4
            ranges.append((self.blob_id, off, self.tokens_per_example * 4))
            self._cursor += self.num_shards  # stride across data-parallel shards
        # one fleet pass for the whole batch: cross-request batched decode
        receipts = self.client.get_many(ranges)
        arr = np.stack([np.frombuffer(r.data, dtype=np.int32) for r in receipts])
        return arr[:, :-1], arr[:, 1:]

    def _worker(self, n: int):
        for _ in range(n):
            self._q.put(self._next_batch())

    def batches(self, n: int, *, background: bool = True):
        """Yield n (inputs, labels) batches, prefetching in a worker thread."""
        if not background:
            for _ in range(n):
                yield self._next_batch()
            return
        self._thread = threading.Thread(target=self._worker, args=(n,), daemon=True)
        self._thread.start()
        for _ in range(n):
            yield self._q.get()
        self._thread.join()
