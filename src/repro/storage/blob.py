"""User-data layout (§2.1 + Figure 2): Blobs -> Chunksets -> Chunks -> Samples.

* Blob: arbitrary bytes (immutable once stored).
* Chunkset: fixed-size slice of the blob, ~10 MiB; the last one zero-padded.
* Chunk: one of n Clay-coded shares of a chunkset (~1 MiB at (10,6)).
* Sample: 1 KiB slice of a chunk (audit granularity).

The Clay sub-packetization (alpha sub-chunks of w bytes) forces the chunkset
size to be a multiple of k*alpha*w; we derive w from the requested chunkset
size and keep it 4-byte aligned so samples view cleanly as uint32 words for
the bulk-hash kernel.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.clay import ClayCode

DEFAULT_CHUNKSET_BYTES = 10 * 1024 * 1024  # ~10 MiB (§2.1)


@dataclasses.dataclass(frozen=True)
class BlobLayout:
    """Byte-level geometry shared by SDK, RPC nodes and SPs."""

    k: int = 10
    m: int = 6
    chunkset_bytes_target: int = DEFAULT_CHUNKSET_BYTES

    @functools.cached_property
    def code(self) -> ClayCode:
        return ClayCode(k=self.k, m=self.m)

    @property
    def n(self) -> int:
        return self.k + self.m

    @functools.cached_property
    def w(self) -> int:
        """Sub-chunk bytes: chunkset splits as (k, alpha, w)."""
        alpha = self.code.alpha
        raw = -(-self.chunkset_bytes_target // (self.k * alpha))  # ceil
        return raw + (-raw % 4)  # uint32-align for sample hashing

    @property
    def chunk_bytes(self) -> int:
        return self.code.alpha * self.w

    @property
    def chunkset_bytes(self) -> int:
        return self.k * self.chunk_bytes

    @property
    def replication_overhead(self) -> float:
        """Table 1's "replication overhead": stored bytes / user bytes."""
        return self.n / self.k

    # -- blob <-> chunkset framing ------------------------------------------------
    def partition(self, data: bytes) -> list[np.ndarray]:
        """Blob -> zero-padded chunksets, each shaped (k, alpha, w)."""
        if len(data) == 0:
            raise ValueError("empty blob")
        cs_bytes = self.chunkset_bytes
        out = []
        for off in range(0, len(data), cs_bytes):
            piece = np.frombuffer(data[off : off + cs_bytes], dtype=np.uint8)
            if piece.size < cs_bytes:  # "the final Chunkset is zero-padded" (§3.6)
                piece = np.concatenate([piece, np.zeros(cs_bytes - piece.size, np.uint8)])
            out.append(piece.reshape(self.k, self.code.alpha, self.w))
        return out

    def num_chunksets(self, blob_len: int) -> int:
        return -(-blob_len // self.chunkset_bytes)

    def assemble(self, chunksets: list[np.ndarray], blob_len: int) -> bytes:
        flat = np.concatenate([c.reshape(-1) for c in chunksets])
        return flat[:blob_len].tobytes()

    def byte_range_to_chunksets(self, offset: int, length: int) -> tuple[int, int]:
        """[offset, offset+length) -> (first_chunkset, last_chunkset_inclusive)."""
        if length <= 0:
            raise ValueError("length must be positive")
        first = offset // self.chunkset_bytes
        last = (offset + length - 1) // self.chunkset_bytes
        return first, last

    def extract_range(
        self,
        chunksets: list[np.ndarray],
        first: int,
        offset: int,
        length: int,
        blob_len: int,
    ) -> bytes:
        """Bytes [offset, offset+length) from decoded chunksets `first`..,
        clipped at `blob_len` (the final chunkset's zero padding is never
        visible to readers)."""
        buf = self.assemble(chunksets, len(chunksets) * self.chunkset_bytes)
        start = offset - first * self.chunkset_bytes
        end = min(start + length, blob_len - first * self.chunkset_bytes)
        return bytes(buf[start:end])
