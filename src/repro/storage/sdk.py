"""Client SDK (§2.2): prepare data, write blobs, fleet-first paid reads.

Writing (Figure 2): partition the blob into ~10 MiB chunksets (zero-padding
the last), Clay-encode each into n chunks, Merkle-commit every chunk, roll
chunk roots into chunkset roots and a blob root, submit commitments +
payment to the contract (placement comes back), then hand the encoded
chunks to an RPC node to disperse and mark READY.

Reading is **fleet-first** and session-scoped: a :class:`ShelbyClient`
fronts an entire :class:`~repro.net.fleet.RPCFleet` (a single ``RPCNode``
becomes a fleet of one), and a :class:`ShelbySession` lazily opens one
client->RPC micropayment channel *per serving node* (§2.2/§3.2).  Payments
are made **on delivery**: a failed read never debits a channel.  Every read
returns a :class:`ReadReceipt` — the bytes plus the simulated latency,
the per-node payments, and cache/hedge statistics — and ``close()`` (or
leaving the ``with`` block) settles every channel by broadcasting the
freshest refunds, verifying conservation (client refunds + per-node server
income == deposits) and cascading RPC->SP channel settlement so storage
providers realize their serving income.

Streaming primitives: ``client.open(blob_id)`` returns a seekable
file-like :class:`BlobReader`; ``client.stream(blob_id, chunk_size)``
yields successive receipts; ``client.get_many([...])`` routes all ranges
across the fleet in one pass so wide GF batch-decodes span requests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import commitments as cm
from repro.core.contract import BlobMetadata, ShelbyContract
from repro.core.payments import ChannelError, MicropaymentChannel
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.storage.blob import BlobLayout
from repro.storage.rpc import RPCNode


class SettlementError(Exception):
    """Conservation violated at session settlement (should never happen)."""


@dataclasses.dataclass(frozen=True)
class PreparedBlob:
    """Everything Figure 2 produces before anything touches the network."""

    size_bytes: int
    encoded_chunksets: list[np.ndarray]  # each (n, alpha, w)
    chunk_roots: dict[tuple[int, int], bytes]
    chunk_num_samples: dict[tuple[int, int], int]
    chunkset_roots: list[bytes]
    blob_root: bytes


@dataclasses.dataclass(frozen=True)
class ReadReceipt:
    """Proof-of-what-you-paid-for: one per successful read (§2.2).

    `payments` maps serving rpc_id -> the micropayment made to that node's
    channel for THIS read; cache/hedge stats cover only this read's
    chunksets.  All latencies are simulated milliseconds.

    Overload bookkeeping: ``shed=True`` marks a read the fleet refused at
    admission — it carries no data and (pay-on-delivery) debits nothing;
    ``retried_nodes`` names the sibling nodes that rescued legs a routed
    node shed; ``coalesced`` counts chunksets that rode another in-flight
    request's fetch instead of hitting SPs again.
    """

    blob_id: int
    offset: int
    length: int
    data: bytes
    latency_ms: float
    payments: dict[str, float]
    chunksets_by_node: dict[str, int]
    cache_hits: int = 0
    hedges_launched: int = 0
    hedged_wasted: int = 0
    # readahead bookkeeping (BlobReader): this read was issued as a
    # prefetch / this read overlapped N prefetches with its own fetch
    prefetched: bool = False
    prefetches_launched: int = 0
    # overload bookkeeping (admission control + single-flight dedup)
    shed: bool = False
    coalesced: int = 0
    retried_nodes: dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def total_paid(self) -> float:
        # sorted so the float sum is independent of dict insertion order
        return sum(self.payments[k] for k in sorted(self.payments))


@dataclasses.dataclass(frozen=True)
class ReceiptBatch:
    """Pooled receipts for one vectorized cohort (struct-of-arrays).

    A million warm-cache reads do not need a million :class:`ReadReceipt`
    objects and a million ``channel.pay()`` calls: the cohort fast path
    (``repro.net.fastpath``) reports which requests stayed vectorized and
    which node served each leg, and settlement charges each serving node's
    channel ONCE with the numpy-summed total.  ``paid_by_node`` holds the
    exact floats debited, so :meth:`ShelbySession.close` verifies
    conservation against them without unpacking rows.  De-opted requests
    (hedges, NACKs, cold-key leaders) still get individual receipts in
    ``session.receipts``.
    """

    req_idx: np.ndarray  # rows into the replayed RequestBatch
    blob_id: np.ndarray
    offset: np.ndarray
    length: np.ndarray
    latency_ms: np.ndarray
    nbytes: np.ndarray
    paid: np.ndarray  # per-request total micropayment
    paid_by_node: dict[str, float]  # rpc_id -> summed debit (one pay() each)

    def __len__(self) -> int:
        return int(self.req_idx.size)

    @property
    def total_paid(self) -> float:
        # sorted so the float sum is independent of dict insertion order
        return float(sum(self.paid_by_node[k] for k in sorted(self.paid_by_node)))


@dataclasses.dataclass(frozen=True)
class SessionSettlement:
    """Outcome of broadcasting every channel's freshest refund (§3.2).

    `deposits`/`client_refunds`/`node_income` cover exactly THIS session's
    client->RPC channels.  `sp_income` is what the RPC->SP cascade
    realized: those channels are node-level infrastructure shared by every
    reader of the fleet, and a settlement broadcast realizes a channel's
    entire accrued balance — on a fleet with concurrent sessions it may
    include micropayments accrued by other traffic since the last cascade.
    """

    deposits: dict[str, float]  # rpc_id -> channel deposit
    client_refunds: dict[str, float]  # rpc_id -> what came back to the client
    node_income: dict[str, float]  # rpc_id -> realized serving income
    sp_income: dict[int, float]  # sp_id -> income realized by the cascade

    @property
    def total_deposited(self) -> float:
        # sorted so these float sums are independent of dict insertion order
        return sum(self.deposits[k] for k in sorted(self.deposits))

    @property
    def total_refunded(self) -> float:
        return sum(self.client_refunds[k] for k in sorted(self.client_refunds))

    @property
    def total_node_income(self) -> float:
        return sum(self.node_income[k] for k in sorted(self.node_income))


class ShelbySession:
    """A read/payment scope over the fleet: per-node channels, receipts,
    settlement.  Use as a context manager or call ``close()`` explicitly."""

    def __init__(self, client: "ShelbyClient", deposit_per_node: float):
        self._client = client
        self._fleet = client.fleet
        self._deposit = deposit_per_node
        self._price = client.read_price_per_byte
        self.channels: dict[str, MicropaymentChannel] = {}  # rpc_id -> channel
        self.receipts: list[ReadReceipt] = []
        self.receipt_batches: list[ReceiptBatch] = []  # vectorized cohorts
        self.settlement: SessionSettlement | None = None

    # -- channels ------------------------------------------------------------------
    def _channel(self, rpc_id: str) -> MicropaymentChannel:
        """Lazily open the client->RPC channel the first time a node serves."""
        ch = self.channels.get(rpc_id)
        if ch is None:
            ch = self.channels[rpc_id] = MicropaymentChannel(self._deposit)
        return ch

    @property
    def closed(self) -> bool:
        return self.settlement is not None

    @property
    def total_paid(self) -> float:
        # sorted so the float sum is independent of channel open order
        return sum(self.channels[k].paid for k in sorted(self.channels))

    # -- reads (pay on delivery) ---------------------------------------------------
    def _settle_check(self):
        if self.closed:
            raise ChannelError("session settled; open a new one to keep reading")

    def _receipt_for(self, sr, *, prefetched: bool = False,
                     prefetches_launched: int = 0) -> ReadReceipt:
        """Pay on delivery for one ServedRange and record its receipt: the
        bytes are in hand, split the per-byte fee across serving nodes in
        proportion to chunksets served."""
        total_cs = sum(sr.chunksets_by_node.values())  # simlint: ok SIM007 integer chunkset counts, order-exact
        payments: dict[str, float] = {}
        for rpc_id, count in sr.chunksets_by_node.items():
            amount = max(
                self._price * len(sr.data) * count / total_cs, 1e-12
            )
            self._channel(rpc_id).pay(amount)
            payments[rpc_id] = amount
        receipt = ReadReceipt(
            blob_id=sr.blob_id, offset=sr.offset, length=sr.length,
            data=sr.data, latency_ms=sr.latency_ms, payments=payments,
            chunksets_by_node=dict(sr.chunksets_by_node),
            cache_hits=sr.cache_hits, hedges_launched=sr.hedges_launched,
            hedged_wasted=sr.hedged_wasted, prefetched=prefetched,
            prefetches_launched=prefetches_launched,
            coalesced=sr.coalesced, retried_nodes=dict(sr.retried_nodes),
        )
        self.receipts.append(receipt)
        return receipt

    def _resolve(self, requests):
        contract = self._client.contract
        resolved = []
        for blob_id, offset, length in requests:
            if length is None:
                length = contract.blobs[blob_id].size_bytes - offset
            resolved.append((blob_id, offset, length))
        return resolved

    def get_many(
        self,
        requests: list[tuple[int, int, int | None]],
        *,
        client: str | None = None,
        t_ms: float = 0.0,
    ) -> list[ReadReceipt]:
        """Batched reads: (blob_id, offset, length|None) triples, all routed
        across the fleet in ONE pass — nodes batch-decode across requests."""
        self._settle_check()
        served = self._fleet.serve_ranges(
            self._resolve(requests), client=client, t_ms=t_ms
        )
        return [self._receipt_for(sr) for sr in served]

    def replay(self, requests, *, background=None, trace: bool = False,
               engine: str | None = None):
        """Open-loop replay of a workload's :class:`ReadRequest` list on ONE
        shared event loop: every request is a concurrent task spawned at its
        arrival time, so hedge timers, failure recoveries, SP disk queues
        and NIC transfers of in-flight requests genuinely interleave.
        ``background`` plane(s) (audits/repair — ``repro.storage.background``)
        spawn on the same loop and contend with the paid traffic.

        Payments stay pay-on-delivery, applied at each request's completion
        time in deterministic event order; dropped requests debit nothing.
        Returns ``(receipts, ReplayResult)`` — ``receipts[i]`` is ``None``
        when request ``i`` was dropped by a hard failure.  A request the
        fleet *shed* at admission instead gets a zero-payment receipt with
        ``shed=True`` (documented refusal: you asked, the fleet NACKed,
        you paid nothing), and its record is marked ``shed`` in the
        :class:`~repro.net.workloads.ReplayResult`.

        Passing a :class:`~repro.net.workloads.RequestBatch` (and no
        ``background``) routes through the cohort fast path instead:
        returns ``(ReceiptBatch, ReplayResult)``, with de-opted requests'
        individual receipts appended to ``session.receipts`` as usual.
        """
        self._settle_check()
        from repro.net.workloads import RequestBatch, replay_open_loop

        if isinstance(requests, RequestBatch) and background is None:
            return self._replay_batch(requests, trace=trace, engine=engine)

        receipts: list[ReadReceipt | None] = [None] * len(requests)

        def on_served(i, req, sr):
            receipts[i] = self._receipt_for(sr)

        def on_shed(i, req, nack_ms):
            receipts[i] = ReadReceipt(
                blob_id=req.blob_id, offset=req.offset, length=req.length,
                data=b"", latency_ms=nack_ms, payments={},
                chunksets_by_node={}, shed=True,
            )
            self.receipts.append(receipts[i])

        def on_sampled(i, req, ss):
            from repro.storage.das import SampleReceipt

            amount = max(self._price * ss.nbytes, 1e-12)
            self._channel(ss.rpc_id).pay(amount)
            receipt = SampleReceipt(
                blob_id=req.blob_id, row=req.row, col=req.col,
                nbytes=ss.nbytes, share_bytes=ss.share_bytes,
                proof_bytes=ss.proof_bytes, latency_ms=ss.latency_ms,
                payments={ss.rpc_id: amount}, verified=True,
                cache_hit=ss.cache_hit,
            )
            receipts[i] = receipt
            self.receipts.append(receipt)

        result = replay_open_loop(self._fleet, requests, on_served=on_served,
                                  on_shed=on_shed, on_sampled=on_sampled,
                                  background=background, trace=trace,
                                  engine=engine)
        return receipts, result

    def _replay_batch(self, batch, *, trace: bool = False,
                      engine: str | None = None):
        """Cohort-fast replay of a :class:`RequestBatch` with settlement
        done on arrays: each serving node's channel is debited ONCE with the
        numpy-aggregated total of the vectorized cohort's pro-rata per-leg
        payments — the same ``max(price * bytes * legs_on_node / legs,
        1e-12)`` formula :meth:`_receipt_for` applies per request, charged
        per cohort.  De-opted requests pay per-receipt via the task path."""
        from repro.net.fastpath import replay_open_loop_fast

        def on_served(i, req, sr):
            self._receipt_for(sr)

        def on_shed(i, req, nack_ms):
            self.receipts.append(ReadReceipt(
                blob_id=req.blob_id, offset=req.offset, length=req.length,
                data=b"", latency_ms=nack_ms, payments={},
                chunksets_by_node={}, shed=True,
            ))

        result = replay_open_loop_fast(self._fleet, batch, engine=engine,
                                       on_served=on_served, on_shed=on_shed,
                                       trace=trace)
        co = result.cohort
        paid_by_node: dict[str, float] = {}
        n = len(batch)
        if co is not None and co.vec_requests:
            n_nodes = len(co.node_ids)
            # collapse legs to (request, node) groups: the pro-rata share of
            # a request's fee lands on each node in proportion to the legs
            # (chunksets) that node served
            pair = co.leg_req * n_nodes + co.leg_node
            upair, counts = np.unique(pair, return_counts=True)
            preq, pnode = upair // n_nodes, upair % n_nodes
            legs_per_req = np.bincount(co.leg_req, minlength=n)
            amounts = np.maximum(
                self._price * batch.length[preq] * counts / legs_per_req[preq],
                1e-12,
            )
            node_totals = np.bincount(pnode, weights=amounts, minlength=n_nodes)
            for i in np.flatnonzero(node_totals).tolist():
                total = float(node_totals[i])
                self._channel(co.node_ids[i]).pay(total)
                paid_by_node[co.node_ids[i]] = total
            paid_req = np.bincount(preq, weights=amounts, minlength=n)
            vec = co.vec_req_idx
        else:
            paid_req = np.zeros(n)
            vec = np.empty(0, dtype=np.int64)
        rows = result.batch
        rb = ReceiptBatch(
            req_idx=vec,
            blob_id=batch.blob_id[vec].copy(),
            offset=batch.offset[vec].copy(),
            length=batch.length[vec].copy(),
            latency_ms=(rows.latency_ms[vec].copy() if rows is not None
                        else np.zeros(len(vec))),
            nbytes=(co.vec_nbytes if co is not None and co.vec_nbytes is not None
                    else np.zeros(len(vec), dtype=np.int64)),
            paid=paid_req[vec],
            paid_by_node=paid_by_node,
        )
        self.receipt_batches.append(rb)
        return rb, result

    # -- DAS sampling (pay-per-sample light-client reads) --------------------------
    def sample_availability(
        self,
        blob_ids: list[int] | None = None,
        *,
        epoch: int = 0,
        samples: int | None = None,
        seed: int = 0,
        client: str | None = None,
        cache_bypass: bool = True,
        t_ms: float = 0.0,
    ):
        """One sampling round: draw ``samples`` uniform share coordinates
        per blob (seeded, with replacement — see
        :func:`repro.storage.das.draw_coords`), fetch them concurrently
        through the fleet as tiny paid proof-carrying reads, verify against
        each blob's on-chain DAS root, and return one
        :class:`~repro.storage.das.AvailabilityVerdict` per blob.

        Pay-per-sample: each delivered+verified share debits its serving
        node's channel by the per-byte price of share+proof wire bytes;
        withheld/bad samples debit nothing (and flip the verdict).  The
        :class:`~repro.storage.das.SampleReceipt` rows land in
        ``self.receipts``, so ``close()``'s conservation check covers the
        sampling economy unchanged."""
        self._settle_check()
        from repro.net.events import EventLoop
        from repro.storage import das as das_mod
        from repro.storage.rpc import Overloaded, ReadError

        contract = self._client.contract
        if blob_ids is None:
            blob_ids = sorted(contract.das)
        spec = getattr(self._client, "das", None)
        s = samples if samples is not None else (
            spec.samples_per_epoch if spec is not None else 16
        )
        loop = EventLoop(network=self._fleet.network)
        plan: list[tuple[int, int, int, int, object]] = []

        def one(blob_id, row, col):
            try:
                ss = yield from self._fleet.sample_share_task(
                    loop, blob_id, row, col, client=client,
                    cache_bypass=cache_bypass,
                )
            except Overloaded:
                return ("shed", None)
            except ReadError:
                return ("failed", None)
            return ("ok", ss)

        for blob_id in blob_ids:
            rec = contract.das[blob_id]
            coords = das_mod.draw_coords(seed, blob_id, epoch, s, rec.side)
            for j, (row, col) in enumerate(coords):
                h = loop.spawn(one(blob_id, row, col), at_ms=t_ms,
                               label=f"das/b{blob_id}/{j}")
                plan.append((blob_id, j, row, col, h))
        loop.run()

        verdicts = []
        by_blob: dict[int, list] = {}
        for blob_id, j, row, col, h in plan:
            by_blob.setdefault(blob_id, []).append((j, row, col, h))
        for blob_id in blob_ids:
            verified = failures = shed = 0
            first_failure = None
            sample_bytes = proof_bytes = 0
            paid = 0.0
            for j, row, col, h in by_blob.get(blob_id, []):
                outcome, ss = h.result
                if outcome == "shed":
                    shed += 1
                    self.receipts.append(das_mod.SampleReceipt(
                        blob_id=blob_id, row=row, col=col, nbytes=0,
                        share_bytes=0, proof_bytes=0, latency_ms=0.0,
                        payments={}, verified=False, shed=True,
                    ))
                    continue
                if outcome == "failed":
                    failures += 1
                    if first_failure is None:
                        first_failure = j
                    self.receipts.append(das_mod.SampleReceipt(
                        blob_id=blob_id, row=row, col=col, nbytes=0,
                        share_bytes=0, proof_bytes=0, latency_ms=0.0,
                        payments={}, verified=False,
                    ))
                    continue
                amount = max(self._price * ss.nbytes, 1e-12)
                self._channel(ss.rpc_id).pay(amount)
                paid += amount
                verified += 1
                sample_bytes += ss.nbytes
                proof_bytes += ss.proof_bytes
                self.receipts.append(das_mod.SampleReceipt(
                    blob_id=blob_id, row=row, col=col, nbytes=ss.nbytes,
                    share_bytes=ss.share_bytes, proof_bytes=ss.proof_bytes,
                    latency_ms=ss.latency_ms, payments={ss.rpc_id: amount},
                    verified=True, cache_hit=ss.cache_hit,
                ))
            verdicts.append(das_mod.AvailabilityVerdict(
                blob_id=blob_id, epoch=epoch, samples=s, verified=verified,
                failures=failures, shed=shed, first_failure=first_failure,
                available=failures == 0, sample_bytes=sample_bytes,
                proof_bytes=proof_bytes, paid=paid,
            ))
        return verdicts

    def read(
        self,
        blob_id: int,
        offset: int = 0,
        length: int | None = None,
        *,
        client: str | None = None,
        t_ms: float = 0.0,
    ) -> ReadReceipt:
        return self.get_many(
            [(blob_id, offset, length)], client=client, t_ms=t_ms
        )[0]

    def get(self, blob_id: int, offset: int = 0, length: int | None = None) -> bytes:
        return self.read(blob_id, offset, length).data

    # -- streaming -----------------------------------------------------------------
    def open(self, blob_id: int, readahead: int = 0) -> "BlobReader":
        """`readahead=N` prefetches the next N same-sized windows as
        event-loop tasks overlapping each read's own fetch (see
        :class:`BlobReader`)."""
        self._settle_check()
        return BlobReader(self, blob_id, readahead=readahead)

    def stream(self, blob_id: int, chunk_size: int | None = None):
        """Yield :class:`ReadReceipt` per chunk, sequentially through the
        blob.  `chunk_size` defaults to one chunkset (the cache/decode
        unit, so sequential streaming never re-decodes)."""
        self._settle_check()
        size = self._client.contract.blobs[blob_id].size_bytes
        chunk_size = chunk_size or self._client.layout.chunkset_bytes
        if chunk_size <= 0:
            raise ValueError("chunk_size must be positive")
        offset = 0
        while offset < size:
            length = min(chunk_size, size - offset)
            yield self.read(blob_id, offset, length)
            offset += length

    # -- settlement ----------------------------------------------------------------
    def close(self, *, settle_sp_channels: bool = True) -> SessionSettlement:
        """Broadcast the freshest refund of every channel and verify
        conservation; idempotent.  With `settle_sp_channels` (default) the
        settlement cascades: every fleet node also settles its RPC->SP
        channels, so SP serving income is realized on-chain.  The cascade
        realizes each RPC->SP channel's FULL accrued balance — on a shared
        fleet that can include other sessions' traffic (see
        :class:`SessionSettlement`); pass ``settle_sp_channels=False`` if
        another party owns the SP-side settlement schedule."""
        if self.settlement is not None:
            return self.settlement
        deposits, refunds, incomes = {}, {}, {}
        for rpc_id, ch in self.channels.items():
            client_gets, server_gets = ch.settle(ch.latest_refund)
            deposits[rpc_id] = ch.deposit
            refunds[rpc_id] = client_gets
            incomes[rpc_id] = server_gets
            self._fleet.node(rpc_id).serving_income += server_gets
        # conservation: deposits fully split between refunds and income …
        # (sorted sums: the check must not depend on channel-open order)
        total_dep = sum(deposits[k] for k in sorted(deposits))
        total_out = (sum(refunds[k] for k in sorted(refunds))
                     + sum(incomes[k] for k in sorted(incomes)))
        if abs(total_dep - total_out) > 1e-6 * max(total_dep, 1.0):
            raise SettlementError(
                f"conservation violated: deposits {total_dep} != "
                f"refunds+income {total_out}"
            )
        # … and income matches what the receipts say was paid
        paid_by_node: dict[str, float] = {}
        for r in self.receipts:
            for rpc_id, amt in r.payments.items():
                paid_by_node[rpc_id] = paid_by_node.get(rpc_id, 0.0) + amt
        for rb in self.receipt_batches:  # vectorized cohorts: exact debits
            for rpc_id, amt in rb.paid_by_node.items():
                paid_by_node[rpc_id] = paid_by_node.get(rpc_id, 0.0) + amt
        for rpc_id, income in incomes.items():
            # tolerance tracks the deposit's float granularity: income is
            # recovered as deposit - refund, a catastrophic cancellation
            # when the deposit dwarfs what was spent
            tol = max(1e-9, 128 * np.finfo(float).eps * deposits[rpc_id])
            if abs(income - paid_by_node.get(rpc_id, 0.0)) > tol:
                raise SettlementError(
                    f"node {rpc_id}: settled income {income} != receipt "
                    f"payments {paid_by_node.get(rpc_id, 0.0)}"
                )
        sp_income: dict[int, float] = {}
        if settle_sp_channels:
            for rpc in self._fleet.rpcs:
                for sp_id, amt in rpc.settle_sp_channels().items():
                    sp_income[sp_id] = sp_income.get(sp_id, 0.0) + amt
        self.settlement = SessionSettlement(
            deposits=deposits, client_refunds=refunds, node_income=incomes,
            sp_income=sp_income,
        )
        return self.settlement

    def __enter__(self) -> "ShelbySession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class BlobReader:
    """Seekable file-like view of a blob; every `read` is a paid, verified
    fleet read recorded as a receipt on the owning session.

    With ``readahead=N`` the reader prefetches the next N same-sized
    windows *in the same fleet pass* as the current read: every range in a
    ``serve_ranges`` batch is its own task on the event loop, so the
    prefetch legs overlap the current read's legs on the simulated clock
    (the current read's latency is still only its own slowest leg).
    Prefetched windows are paid on delivery like any read (their receipts
    carry ``prefetched=True``); a sequential consumer then drains them from
    the buffer without touching the fleet again.  ``prefetch_hits`` /
    ``prefetches_issued`` count the overlap on the reader; the triggering
    read's receipt records ``prefetches_launched``.
    """

    def __init__(self, session: ShelbySession, blob_id: int, readahead: int = 0):
        self._session = session
        self.blob_id = blob_id
        self.size = session._client.contract.blobs[blob_id].size_bytes
        self._pos = 0
        self._closed = False
        self._readahead = max(0, int(readahead))
        self._buffer: dict[tuple[int, int], ReadReceipt] = {}
        self.prefetches_issued = 0
        self.prefetch_hits = 0

    def readable(self) -> bool:
        return not self._closed

    def seekable(self) -> bool:
        return True

    def tell(self) -> int:
        return self._pos

    def seek(self, offset: int, whence: int = 0) -> int:
        if whence not in (0, 1, 2):
            raise ValueError(f"unsupported whence {whence}")
        base = {0: 0, 1: self._pos, 2: self.size}[whence]
        pos = base + offset
        if pos < 0:
            raise ValueError(f"negative seek position {pos}")
        self._pos = pos
        return self._pos

    def read(self, n: int = -1) -> bytes:
        if self._closed:
            raise ValueError("I/O operation on closed BlobReader")
        remaining = self.size - self._pos
        if remaining <= 0:
            return b""
        length = remaining if n is None or n < 0 else min(n, remaining)
        if length == 0:
            return b""
        self._session._settle_check()  # even buffered reads need a live session
        receipt = self._buffer.pop((self._pos, length), None)
        if receipt is not None:
            self.prefetch_hits += 1
        else:
            windows = [(self._pos, length)]
            nxt = self._pos + length
            for _ in range(self._readahead):
                if nxt >= self.size:
                    break
                w = (nxt, min(length, self.size - nxt))
                if w not in self._buffer:
                    windows.append(w)
                nxt += w[1]
            served = self._session._fleet.serve_ranges(
                [(self.blob_id, off, ln) for off, ln in windows]
            )
            receipt = self._session._receipt_for(
                served[0], prefetches_launched=len(windows) - 1
            )
            for sr in served[1:]:
                self._buffer[(sr.offset, sr.length)] = self._session._receipt_for(
                    sr, prefetched=True
                )
            self.prefetches_issued += len(windows) - 1
        self._pos += len(receipt.data)
        return receipt.data

    def close(self) -> None:
        self._closed = True

    def __enter__(self) -> "BlobReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ShelbyClient:
    """Fleet-first client: writes disperse through the fleet's primary
    node; reads flow through a session (per-node channels, receipts,
    settlement).  A bare ``RPCNode`` is accepted and becomes a fleet of
    one, so the smallest deployment and the CDN-scale one share one API."""

    def __init__(
        self,
        contract: ShelbyContract,
        fleet: RPCFleet | RPCNode,
        layout: BlobLayout | None = None,
        read_price_per_byte: float = 1e-9,
        deposit: float = 100.0,
        das=None,  # storage.das.DASSpec: auto-extend blobs on put()
    ):
        self.contract = contract
        self.fleet = (
            fleet if isinstance(fleet, RPCFleet)
            else RPCFleet([fleet], CacheAffinityPolicy())
        )
        self.layout = layout or self.fleet.primary.layout
        self.read_price_per_byte = read_price_per_byte
        self.deposit_per_node = deposit
        self.das = das
        self._session: ShelbySession | None = None

    @property
    def rpc(self) -> RPCNode:
        """The fleet's primary node (write dispersal front)."""
        return self.fleet.primary

    # -- sessions ------------------------------------------------------------------
    def session(self, deposit_per_node: float | None = None) -> ShelbySession:
        """Open a fresh read/payment session (explicit lifecycle)."""
        return ShelbySession(self, deposit_per_node or self.deposit_per_node)

    @property
    def current_session(self) -> ShelbySession:
        """The client's implicit session, opened lazily on first read."""
        if self._session is None or self._session.closed:
            self._session = self.session()
        return self._session

    def settle(self) -> SessionSettlement:
        """Settle the implicit session (no-op settlement if nothing read)."""
        settlement = self.current_session.close()
        self._session = None
        return settlement

    def __enter__(self) -> "ShelbyClient":
        return self

    def __exit__(self, *exc) -> None:
        if self._session is not None and not self._session.closed:
            self.settle()

    # -- data preparation (Figure 2) ---------------------------------------------
    def prepare(self, data: bytes) -> PreparedBlob:
        lay = self.layout
        chunksets = lay.partition(data)
        encoded, chunk_roots, nsamples, cs_roots = [], {}, {}, []
        for cs, plain in enumerate(chunksets):
            coded = lay.code.encode(plain)
            encoded.append(coded)
            roots = []
            for ck in range(lay.n):
                commit, _ = cm.commit_chunk(coded[ck])
                chunk_roots[(cs, ck)] = commit.root
                nsamples[(cs, ck)] = commit.num_samples
                roots.append(commit.root)
            cs_root, _ = cm.commit_roots(roots)
            cs_roots.append(cs_root)
        blob_root, _ = cm.commit_roots(cs_roots)
        return PreparedBlob(
            size_bytes=len(data),
            encoded_chunksets=encoded,
            chunk_roots=chunk_roots,
            chunk_num_samples=nsamples,
            chunkset_roots=cs_roots,
            blob_root=blob_root,
        )

    # -- write (§2.2) ---------------------------------------------------------------
    def put(self, data: bytes, payment: float = 1.0, epochs: int = 10) -> BlobMetadata:
        prep = self.prepare(data)
        meta = self.contract.begin_write(
            owner="client",
            size_bytes=prep.size_bytes,
            n=self.layout.n,
            k=self.layout.k,
            blob_root=prep.blob_root,
            chunkset_roots=prep.chunkset_roots,
            chunk_roots=prep.chunk_roots,
            chunk_num_samples=prep.chunk_num_samples,
            payment=payment,
            epochs=epochs,
        )
        self.fleet.primary.write_blob(meta, prep.encoded_chunksets)
        if self.das is not None and self.das.extension:
            # DAS plane: extend the blob into its 2k x 2k share square and
            # disperse it alongside the chunksets (see storage/das.py)
            from repro.storage.das import extend_and_disperse

            extend_and_disperse(
                self.contract, self.fleet.primary.sps, meta.blob_id, data,
                self.das, matmul=self.fleet.primary.decode_matmul,
            )
        return meta

    # -- reads (§2.2): pay-on-delivery via the implicit session ---------------------
    def read(
        self,
        blob_id: int,
        offset: int = 0,
        length: int | None = None,
        *,
        client: str | None = None,
        t_ms: float = 0.0,
    ) -> ReadReceipt:
        return self.current_session.read(
            blob_id, offset, length, client=client, t_ms=t_ms
        )

    def get(self, blob_id: int, offset: int = 0, length: int | None = None) -> bytes:
        return self.read(blob_id, offset, length).data

    def get_many(
        self,
        requests: list[tuple[int, int, int | None]],
        *,
        client: str | None = None,
        t_ms: float = 0.0,
    ) -> list[ReadReceipt]:
        return self.current_session.get_many(requests, client=client, t_ms=t_ms)

    def replay(self, requests, *, background=None, trace: bool = False,
               engine: str | None = None):
        """Concurrent open-loop replay through the implicit session (see
        :meth:`ShelbySession.replay`)."""
        return self.current_session.replay(requests, background=background,
                                           trace=trace, engine=engine)

    def sample_availability(self, blob_ids: list[int] | None = None, **kw):
        """One DAS sampling round through the implicit session (see
        :meth:`ShelbySession.sample_availability`)."""
        return self.current_session.sample_availability(blob_ids, **kw)

    def open(self, blob_id: int, readahead: int = 0) -> BlobReader:
        return self.current_session.open(blob_id, readahead=readahead)

    def stream(self, blob_id: int, chunk_size: int | None = None):
        return self.current_session.stream(blob_id, chunk_size)
