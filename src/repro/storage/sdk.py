"""Client SDK (§2.2): prepare data, write blobs, paid byte-range reads.

Writing (Figure 2): partition the blob into ~10 MiB chunksets (zero-padding
the last), Clay-encode each into n chunks, Merkle-commit every chunk, roll
chunk roots into chunkset roots and a blob root, submit commitments +
payment to the contract (placement comes back), then hand the encoded chunks
to an RPC node to disperse and mark READY.

Reading: open a client->RPC micropayment channel once, then mix signed
micropayments with range reads (§2.2).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import commitments as cm
from repro.core.contract import BlobMetadata, ShelbyContract
from repro.core.payments import MicropaymentChannel
from repro.storage.blob import BlobLayout
from repro.storage.rpc import RPCNode


@dataclasses.dataclass(frozen=True)
class PreparedBlob:
    """Everything Figure 2 produces before anything touches the network."""

    size_bytes: int
    encoded_chunksets: list[np.ndarray]  # each (n, alpha, w)
    chunk_roots: dict[tuple[int, int], bytes]
    chunk_num_samples: dict[tuple[int, int], int]
    chunkset_roots: list[bytes]
    blob_root: bytes


class ShelbyClient:
    def __init__(
        self,
        contract: ShelbyContract,
        rpc: RPCNode,
        layout: BlobLayout | None = None,
        read_price_per_byte: float = 1e-9,
        deposit: float = 100.0,
    ):
        self.contract = contract
        self.rpc = rpc
        self.layout = layout or rpc.layout
        self.read_price_per_byte = read_price_per_byte
        self.channel = MicropaymentChannel(deposit)  # client->RPC (§2.2)

    # -- data preparation (Figure 2) ---------------------------------------------
    def prepare(self, data: bytes) -> PreparedBlob:
        lay = self.layout
        chunksets = lay.partition(data)
        encoded, chunk_roots, nsamples, cs_roots = [], {}, {}, []
        for cs, plain in enumerate(chunksets):
            coded = lay.code.encode(plain)
            encoded.append(coded)
            roots = []
            for ck in range(lay.n):
                commit, _ = cm.commit_chunk(coded[ck])
                chunk_roots[(cs, ck)] = commit.root
                nsamples[(cs, ck)] = commit.num_samples
                roots.append(commit.root)
            cs_root, _ = cm.commit_roots(roots)
            cs_roots.append(cs_root)
        blob_root, _ = cm.commit_roots(cs_roots)
        return PreparedBlob(
            size_bytes=len(data),
            encoded_chunksets=encoded,
            chunk_roots=chunk_roots,
            chunk_num_samples=nsamples,
            chunkset_roots=cs_roots,
            blob_root=blob_root,
        )

    # -- write (§2.2) ---------------------------------------------------------------
    def put(self, data: bytes, payment: float = 1.0, epochs: int = 10) -> BlobMetadata:
        prep = self.prepare(data)
        meta = self.contract.begin_write(
            owner="client",
            size_bytes=prep.size_bytes,
            n=self.layout.n,
            k=self.layout.k,
            blob_root=prep.blob_root,
            chunkset_roots=prep.chunkset_roots,
            chunk_roots=prep.chunk_roots,
            chunk_num_samples=prep.chunk_num_samples,
            payment=payment,
            epochs=epochs,
        )
        self.rpc.write_blob(meta, prep.encoded_chunksets)
        return meta

    # -- read (§2.2): payments mixed with reads --------------------------------------
    def get(self, blob_id: int, offset: int = 0, length: int | None = None) -> bytes:
        meta = self.contract.blobs[blob_id]
        if length is None:
            length = meta.size_bytes - offset
        self.channel.pay(max(length * self.read_price_per_byte, 1e-12))
        return self.rpc.read_range(blob_id, offset, length)
