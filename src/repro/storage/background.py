"""Background planes on the shared event loop (§4 audits + §3.3 repair).

Shelby's headline claim is an audit protocol with "strong cryptoeconomic
guarantees without compromising performance".  That claim is only
measurable when audit and repair traffic *competes* with paid serving:
before this module, both ran synchronously off-band — proof broadcasts
never crossed a backbone NIC, helper reads never occupied an SP disk slot,
and serving p99 could not possibly move.  Here they become spawned
generator tasks on the same :class:`~repro.net.events.EventLoop` as the
read path:

* :class:`AuditPlane` — one task per challenge: the auditee pulls the
  sample and builds its Merkle proof holding one of its disk slots in the
  *background* scheduling class (capped by the SP's
  :class:`~repro.storage.sp.BackgroundSpec` slot share), then broadcasts
  the proof to every auditor as concurrent ``Transfer`` legs over the
  backbone (NIC + trunk reservation), and each auditor verifies and
  records its scoreboard bit in event order.
* :class:`RepairPlane` — one task per lost chunk, delegating to
  :meth:`~repro.storage.repair.RepairCoordinator.repair_chunk_task`
  (helper reads + re-dispersal as background transfers and disk holds);
  per-chunk failures are recorded, never propagated — a dead chunk must
  not take the serving plane down with it.

Both planes *pace* their task launches (``BackgroundSpec.pace_ms``) so
audits and repair trickle instead of bursting, and both append a
:class:`~repro.net.workloads.BackgroundRecord` per operation — the
records ride the replay's determinism digest, so "same seed ⇒ same
foreground AND background schedule" is testable.
"""
from __future__ import annotations

from repro.net.events import (
    Acquire, EventLoop, Join, Release, Sleep, Transfer, safe_release,
)
from repro.net.workloads import BackgroundRecord
from repro.storage.repair import RepairCoordinator, RepairError
from repro.storage.rpc import NACK_BYTES

# wire overhead alongside a broadcast proof: challenge coordinates, sample
# index, auditee signature — the payload is dominated by sample + Merkle path
PROOF_OVERHEAD_BYTES = 96


class AuditPlane:
    """Drives one epoch's challenge→proof→broadcast→verify flow as paced
    background tasks on a shared loop.

    ``nodes`` maps sp_id -> backbone node id; without it (or without a
    network on the loop) the plane still costs auditee disk time but moves
    no bytes — the ``run_sim`` direct-transport case.
    """

    def __init__(self, contract, sps, challenges, *, nodes=None,
                 pace_ms: float | None = None):
        self.contract = contract
        self.sps = sps
        self.challenges = list(challenges)
        self.nodes = nodes
        self.pace_ms = pace_ms  # None: each auditee's own BackgroundSpec pace
        self.records: list[BackgroundRecord] = []
        self.proof_bytes = 0  # proof bytes that actually crossed the network

    def spawn(self, loop: EventLoop) -> None:
        t = loop.now
        for i, ch in enumerate(self.challenges):
            loop.spawn(
                self._challenge_task(loop, ch),
                at_ms=t,
                label=f"audit/e{ch.epoch}/a{ch.auditee}/{i}",
            )
            sp = self.sps.get(ch.auditee)
            pace = self.pace_ms
            if pace is None:
                pace = sp.service.background.pace_ms if sp is not None else 2.0
            t += pace

    def _challenge_task(self, loop: EventLoop, ch):
        t0 = loop.now
        sp = self.sps.get(ch.auditee)
        proof = None
        if sp is not None and not sp.behavior.crashed:
            # proof generation = one disk read on the auditee (sample +
            # Merkle path), in the background class under its slot share
            prio = sp.service.background.priority
            yield Acquire(("sp", ch.auditee), sp.service.slots, priority=prio,
                          limit=sp.bg_slots())
            try:
                yield Sleep(sp.audit_service_ms())
            finally:
                yield from safe_release(
                    Release(("sp", ch.auditee), priority=prio))
            proof = sp.respond_challenge(ch)
        payload = (
            len(proof.sample) + proof.proof.nbytes + PROOF_OVERHEAD_BYTES
            if proof is not None else NACK_BYTES
        )
        moved = 0
        legs = []
        for auditor in ch.auditors:
            if auditor in self.contract.ejected or auditor not in self.sps:
                continue
            legs.append(loop.spawn(
                self._broadcast_leg(loop, ch, proof, auditor, payload),
                label=f"audit/e{ch.epoch}/a{ch.auditee}->{auditor}",
            ))
        for h in legs:
            moved += yield Join(h)
        self.records.append(BackgroundRecord(
            kind="audit",
            key=f"e{ch.epoch}/a{ch.auditee}/b{ch.blob_id}/c{ch.chunkset}"
                f"/k{ch.chunk}/s{ch.sample}",
            t_ms=t0, finish_ms=loop.now, ok=proof is not None, nbytes=moved,
        ))

    def _broadcast_leg(self, loop: EventLoop, ch, proof, auditor: int,
                       payload: int):
        """Ship the proof to ONE auditor and let it verify + record."""
        src = self.nodes.get(ch.auditee) if self.nodes else None
        dst = self.nodes.get(auditor) if self.nodes else None
        moved = 0
        if src is not None and dst is not None and loop.network is not None:
            yield Transfer(src, dst, payload)
            moved = payload
            self.proof_bytes += payload
        # Merkle verification is CPU, not disk — free on the sim clock
        self.sps[auditor].audit_peer(ch, proof, self.contract)
        return moved


class RepairPlane:
    """Scan-and-repair as paced background tasks.

    Wraps a :class:`RepairCoordinator` (which carries the network identity
    and the spot-check policy); ``lost`` pins the work-list explicitly,
    otherwise the plane scans at spawn time.  Unrecoverable chunks land in
    ``failures`` — the plane never raises into the serving loop.
    """

    def __init__(self, coordinator: RepairCoordinator, *,
                 lost: list[tuple[int, int, int]] | None = None,
                 pace_ms: float | None = None):
        self.rc = coordinator
        self._lost = lost
        self.pace_ms = pace_ms
        self.records: list[BackgroundRecord] = []
        self.failures: list[tuple[tuple[int, int, int], str]] = []
        # re-dispersal backlog accounting (membership plane): how many
        # repairs were queued over the plane's lifetime, and the live task
        # handles of the most recent batch (drain-time measurement)
        self.enqueued_total = 0
        self.handles: list = []

    def spawn(self, loop: EventLoop) -> None:
        lost = self._lost if self._lost is not None else self.rc.scan_lost_chunks()
        self.enqueue(loop, lost)

    def enqueue(self, loop: EventLoop, lost: list[tuple[int, int, int]]) -> list:
        """Queue a batch of repairs as paced background tasks starting NOW.

        The membership plane calls this at each epoch boundary with the
        chunks its reconfiguration displaced — the re-dispersal backlog.
        Returns the batch's task handles (``finished_ms`` gives the drain
        time once the loop runs); they are also appended to ``handles``.
        """
        t = loop.now
        batch = []
        for blob_id, cs, ck in lost:
            batch.append(loop.spawn(
                self._repair_task(loop, blob_id, cs, ck),
                at_ms=t,
                label=f"repair/b{blob_id}/c{cs}/k{ck}",
            ))
            pace = self.pace_ms
            if pace is None:
                sp = self.rc.sps.get(
                    self.rc.contract.blobs[blob_id].placement[(cs, ck)]
                )
                pace = sp.service.background.pace_ms if sp is not None else 2.0
            t += pace
        self.enqueued_total += len(batch)
        self.handles.extend(batch)
        return batch

    def backlog(self) -> int:
        """Enqueued repairs that have not yet finished (either way)."""
        return self.enqueued_total - len(self.records)

    def _repair_task(self, loop: EventLoop, blob_id: int, cs: int, ck: int):
        t0 = loop.now
        key = f"b{blob_id}/c{cs}/k{ck}"
        try:
            rep = yield from self.rc.repair_chunk_task(
                loop, blob_id, cs, ck, label=f"repair/{key}"
            )
        except RepairError as e:
            self.failures.append(((blob_id, cs, ck), str(e)))
            self.records.append(BackgroundRecord(
                kind="repair", key=key, t_ms=t0, finish_ms=loop.now,
                ok=False, nbytes=0,
            ))
            return
        # helper reads in + rebuilt chunk out (re-dispersal) — network
        # bytes only: without a backbone nothing crossed a link (the
        # record contract matches the audit plane's)
        networked = self.rc.nodes is not None and loop.network is not None
        moved = (rep.helper_bytes_read + self.rc.layout.chunk_bytes
                 if networked else 0)
        self.records.append(BackgroundRecord(
            kind="repair", key=key, t_ms=t0, finish_ms=loop.now,
            ok=True, nbytes=moved,
        ))
