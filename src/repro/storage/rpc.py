"""RPC node (§2.3): the gateway between clients and the SP layer.

Write path: verify the client's encoded chunks against the on-chain
commitments, disperse them to the contract-assigned SPs, then mark the blob
READY.

Read path ("designed to serve"): fetch any k of n chunks per chunkset with
**deadline-based request hedging** (§3.5 — issue the k best-estimated
requests, hedge extras when stragglers blow the deadline, ignore the rest),
verify every chunk against its on-chain Merkle root (altered data is
detected, §2.3), Clay-decode, and assemble.  Chunk requests travel through
a pluggable :class:`Transport` — direct in-process calls, or the simulated
dedicated backbone of ``repro.net.backbone`` with per-link latency and
bandwidth accounting on a simulated clock.  Reads spanning several
chunksets — even of *different blobs*, via ``read_items_detailed`` — take
the **batched decode path**: chunksets with the same erasure pattern are
Clay-decoded in one wide GF call (``ClayCode.decode_batch``, optionally
through the Pallas ``gf_matmul`` kernel) instead of one-at-a-time numpy.

Payments are **on delivery** (§2.2/§3.2): a chunk is paid through the
RPC->SP micropayment channel only once it arrived AND verified against its
commitment — crashed, missing, or corrupt responses earn the SP nothing.
Channel settlement (`settle_sp_channels`) broadcasts the freshest refunds
and realizes each SP's serving income; client sessions paying this node
credit `serving_income` when *their* channel settles.  A small hot-cache of
decoded chunksets fronts popular content (§5.3).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.core import commitments as cm
from repro.core.contract import BlobState, ShelbyContract
from repro.core.payments import PaymentLedger
from repro.net.scheduler import FetchResult, HedgedScheduler
from repro.storage.blob import BlobLayout
from repro.storage.sp import StorageProvider


class ReadError(Exception):
    pass


@dataclasses.dataclass
class ReadStats:
    chunks_requested: int = 0
    chunks_used: int = 0
    chunks_bad: int = 0
    bytes_paid_for: int = 0  # bytes of chunks actually paid (delivered + verified)
    payments: float = 0.0  # RPC->SP micropayments (pay-on-delivery)
    cache_hits: int = 0
    hedged_wasted: int = 0  # requests that contributed no shard (incl. failures) — unpaid
    hedges_launched: int = 0  # deadline-triggered hedge requests only
    chunkset_fetches: int = 0
    fetch_ms_total: float = 0.0  # simulated clock, not wall time


@dataclasses.dataclass(frozen=True)
class ItemStats:
    """Per-(blob, chunkset) outcome of one `read_items_detailed` call."""

    cache_hit: bool
    latency_ms: float  # simulated fetch time (0 for cache hits)
    hedges: int = 0
    wasted: int = 0


# -- transports: how chunk requests reach SPs -------------------------------------
class DirectTransport:
    """In-process calls; completion time is just the SP's service latency."""

    def __init__(self, sps: dict[int, StorageProvider]):
        self.sps = sps

    def estimate_ms(self, sp_id: int, nbytes: int) -> float:
        return self.sps[sp_id].behavior.latency_ms

    def request(
        self, sp_id: int, blob_id: int, chunkset: int, chunk: int, t_ms: float,
    ) -> tuple[np.ndarray | None, float]:
        sp = self.sps[sp_id]
        resp = sp.serve_chunk(blob_id, chunkset, chunk)
        done = t_ms + sp.behavior.latency_ms
        return (None, done) if resp is None else (resp[0], done)


class BackboneTransport:
    """Chunk requests over the simulated dedicated backbone (§2.3).

    request -> (trunk transfer) -> SP service -> (trunk transfer back);
    failures (crashed SP / missing chunk) surface as a fast NACK after one
    round trip.  All times are simulated milliseconds, with FIFO
    serialization accounted per trunk by the Backbone.
    """

    REQUEST_BYTES = 256
    NACK_BYTES = 64

    def __init__(self, sps, backbone, rpc_node: str,
                 sp_node: dict[int, str] | None = None):
        self.sps = sps
        self.backbone = backbone
        self.rpc_node = rpc_node
        self.sp_node = sp_node or {i: f"sp{i}" for i in sps}

    def estimate_ms(self, sp_id: int, nbytes: int) -> float:
        bb, sp = self.backbone, self.sp_node[sp_id]
        return (
            bb.estimate_ms(self.rpc_node, sp, self.REQUEST_BYTES)
            + self.sps[sp_id].behavior.latency_ms
            + bb.estimate_ms(sp, self.rpc_node, nbytes)
        )

    def request(
        self, sp_id: int, blob_id: int, chunkset: int, chunk: int, t_ms: float,
    ) -> tuple[np.ndarray | None, float]:
        bb, node = self.backbone, self.sp_node[sp_id]
        arrived = bb.transfer(self.rpc_node, node, self.REQUEST_BYTES, t_ms)
        sp = self.sps[sp_id]
        resp = sp.serve_chunk(blob_id, chunkset, chunk)
        if resp is None:
            return None, bb.transfer(node, self.rpc_node, self.NACK_BYTES, arrived)
        data, service_ms = resp
        done = bb.transfer(node, self.rpc_node, data.nbytes, arrived + service_ms)
        return data, done


class RPCNode:
    def __init__(
        self,
        rpc_id: str,
        contract: ShelbyContract,
        sps: dict[int, StorageProvider],
        layout: BlobLayout,
        price_per_chunk: float = 1e-6,
        hedge: int = 2,
        cache_chunksets: int = 8,
        sp_deposit: float = 10.0,
        transport=None,
        scheduler: HedgedScheduler | None = None,
        batch_decode: bool = True,
        decode_matmul=None,
    ):
        self.rpc_id = rpc_id
        self.contract = contract
        self.sps = sps
        self.layout = layout
        self.price_per_chunk = price_per_chunk
        self.hedge = hedge
        self.transport = transport or DirectTransport(sps)
        self.scheduler = scheduler or HedgedScheduler(hedge=hedge)
        self.batch_decode = batch_decode
        self.decode_matmul = decode_matmul  # e.g. repro.kernels.ops.gf_matmul_np
        self.ledger = PaymentLedger()
        self._sp_deposit = sp_deposit
        for sp_id in sps:
            self.ledger.open(str(sp_id), sp_deposit)  # channels at join time (§2.3)
        self.serving_income = 0.0  # realized when client sessions settle (§3.2)
        self._cache: OrderedDict[tuple[int, int], np.ndarray] = OrderedDict()
        self._cache_size = cache_chunksets
        self.stats = ReadStats()
        contract.register_rpc(rpc_id)

    # -- write path (§2.3) -------------------------------------------------------
    def write_blob(self, meta, encoded_chunksets: list[np.ndarray]) -> None:
        """encoded_chunksets[cs]: (n, alpha, w) — verify commitments, disperse."""
        lay = self.layout
        for cs, coded in enumerate(encoded_chunksets):
            assert coded.shape[0] == lay.n
            for ck in range(lay.n):
                root_expected = meta.chunk_roots[(cs, ck)]
                commit, _ = cm.commit_chunk(coded[ck])
                if commit.root != root_expected:
                    raise ValueError(f"commitment mismatch for chunk ({cs},{ck})")
                sp_id = meta.placement[(cs, ck)]
                if not self.sps[sp_id].store_chunk(meta.blob_id, cs, ck, coded[ck]):
                    raise IOError(f"SP {sp_id} refused chunk ({cs},{ck})")
        self.contract.mark_ready(meta.blob_id, self.rpc_id)

    # -- read path (§2.3 + §3.5 hedging) ------------------------------------------
    def _pay(self, sp_id: int) -> float:
        """Pay ONE delivered+verified chunk over the RPC->SP channel."""
        self.ledger.pay(str(sp_id), self.price_per_chunk)
        self.sps[sp_id].receive_payment(self.price_per_chunk)
        self.stats.payments += self.price_per_chunk
        self.stats.bytes_paid_for += self.layout.chunk_bytes
        return self.price_per_chunk

    def settle_sp_channels(self) -> dict[int, float]:
        """Broadcast the freshest refund of every paid RPC->SP channel.

        Each SP's `settled_income` is credited with exactly what the channel
        paid out (deposit - freshest refund); fresh channels reopen with the
        original deposit so serving continues.  Returns sp_id -> income.
        """
        income: dict[int, float] = {}
        for sp_id in list(self.sps):
            ch = self.ledger.channels[str(sp_id)]
            if ch.paid <= 0.0:
                continue
            _, server_gets = ch.settle(ch.latest_refund)
            self.sps[sp_id].credit_settlement(server_gets)
            income[sp_id] = server_gets  # one channel per SP
            self.ledger.open(str(sp_id), self._sp_deposit)  # fresh channel
        return income

    def _fetch_chunkset(
        self, blob_id: int, chunkset: int, start_ms: float = 0.0
    ) -> FetchResult:
        """Hedged k-of-n shard fetch through the transport; no decode."""
        meta = self.contract.blobs[blob_id]
        if meta.state is not BlobState.READY:
            raise ReadError(f"blob {blob_id} not ready")
        lay = self.layout
        candidates = [
            (
                ck,
                meta.placement[(chunkset, ck)],
                self.transport.estimate_ms(meta.placement[(chunkset, ck)], lay.chunk_bytes),
            )
            for ck in range(lay.n)
        ]

        def issue(ck: int, sp_id: int, t_ms: float):
            self.stats.chunks_requested += 1
            return self.transport.request(sp_id, blob_id, chunkset, ck, t_ms)

        def verify(ck: int, data) -> bool:
            commit, _ = cm.commit_chunk(data)
            if commit.root != meta.chunk_roots[(chunkset, ck)]:
                self.stats.chunks_bad += 1  # §2.3: tampering detected
                return False
            self._pay(meta.placement[(chunkset, ck)])  # pay on delivery
            return True

        result = self.scheduler.fetch(lay.k, candidates, issue, verify, start_ms=start_ms)
        if len(result.shards) < lay.k:
            raise ReadError(
                f"chunkset ({blob_id},{chunkset}): only {len(result.shards)}/{lay.k} valid chunks"
            )
        self.stats.chunks_used += result.used
        self.stats.hedged_wasted += result.wasted
        self.stats.hedges_launched += result.hedges
        self.stats.chunkset_fetches += 1
        self.stats.fetch_ms_total += result.latency_ms
        return result

    def _cache_put(self, key: tuple[int, int], decoded: np.ndarray) -> None:
        self._cache[key] = decoded
        if len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)

    def read_chunkset_timed(
        self, blob_id: int, chunkset: int, start_ms: float = 0.0
    ) -> tuple[np.ndarray, float]:
        """Decoded (k, alpha, w) data of one chunkset + simulated fetch ms."""
        parts, latency = self.read_chunksets_timed(blob_id, [chunkset], start_ms)
        return parts[0], latency

    def read_chunkset(self, blob_id: int, chunkset: int) -> np.ndarray:
        return self.read_chunkset_timed(blob_id, chunkset)[0]

    def read_items_detailed(
        self, items: list[tuple[int, int]], start_ms: float = 0.0
    ) -> tuple[dict[tuple[int, int], np.ndarray], dict[tuple[int, int], ItemStats]]:
        """Read many (blob_id, chunkset) items — possibly spanning blobs.

        Cache misses are fetched independently (hedged fetches overlap ->
        each item's latency is its own slowest leg) and decoded through the
        batched Clay path when more than one misses: chunksets of
        *different blobs* with the same erasure pattern still stack into one
        wide GF matmul, so a `get_many` spanning requests amortizes kernel
        dispatch across all of them.
        """
        out: dict[tuple[int, int], np.ndarray] = {}
        stats: dict[tuple[int, int], ItemStats] = {}
        fetched: dict[tuple[int, int], FetchResult] = {}
        for key in items:
            if key in out or key in fetched:
                continue
            if key in self._cache:
                self._cache.move_to_end(key)
                self.stats.cache_hits += 1
                out[key] = self._cache[key]
                stats[key] = ItemStats(cache_hit=True, latency_ms=0.0)
            else:
                res = self._fetch_chunkset(key[0], key[1], start_ms)
                fetched[key] = res
                stats[key] = ItemStats(
                    cache_hit=False,
                    latency_ms=res.latency_ms,
                    hedges=res.hedges,
                    wasted=res.wasted,
                )
        if fetched:
            order = sorted(fetched)
            if self.batch_decode and len(order) > 1:
                decoded = self.layout.code.reconstruct_data_batch(
                    [fetched[key].shards for key in order], matmul=self.decode_matmul
                )
            else:
                decoded = [
                    self.layout.code.reconstruct_data(fetched[key].shards)
                    for key in order
                ]
            for key, dec in zip(order, decoded):
                out[key] = dec
                self._cache_put(key, dec)
        return out, stats

    def read_chunksets_timed(
        self, blob_id: int, chunksets: list[int], start_ms: float = 0.0
    ) -> tuple[list[np.ndarray], float]:
        """Single-blob convenience over `read_items_detailed`; the returned
        latency is the slowest item's leg (hedged fetches overlap)."""
        out, stats = self.read_items_detailed(
            [(blob_id, cs) for cs in chunksets], start_ms
        )
        latency = max((s.latency_ms for s in stats.values()), default=0.0)
        return [out[(blob_id, cs)] for cs in chunksets], latency

    def read_range_timed(
        self, blob_id: int, offset: int, length: int, start_ms: float = 0.0
    ) -> tuple[bytes, float]:
        meta = self.contract.blobs[blob_id]
        lay = self.layout
        first, last = lay.byte_range_to_chunksets(offset, length)
        parts, latency = self.read_chunksets_timed(
            blob_id, list(range(first, last + 1)), start_ms
        )
        return lay.extract_range(parts, first, offset, length, meta.size_bytes), latency

    def read_range(self, blob_id: int, offset: int, length: int) -> bytes:
        return self.read_range_timed(blob_id, offset, length)[0]

    def read_blob(self, blob_id: int) -> bytes:
        meta = self.contract.blobs[blob_id]
        return self.read_range(blob_id, 0, meta.size_bytes)
