"""RPC node (§2.3): the gateway between clients and the SP layer.

Write path: verify the client's encoded chunks against the on-chain
commitments, disperse them to the contract-assigned SPs, then mark the blob
READY.

Read path ("designed to serve"): fetch any k of n chunks per chunkset with
**request hedging** (§3.5 — issue k + hedge requests, keep the first k valid
responses, ignore stragglers), verify every chunk against its on-chain
Merkle root (altered data is detected, §2.3), Clay-decode, and assemble.
Every chunk read is paid through an RPC->SP micropayment channel; a small
hot-cache of decoded chunksets fronts popular content (§5.3).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.core import commitments as cm
from repro.core.contract import BlobState, ShelbyContract
from repro.core.payments import PaymentLedger
from repro.storage.blob import BlobLayout
from repro.storage.sp import StorageProvider


class ReadError(Exception):
    pass


@dataclasses.dataclass
class ReadStats:
    chunks_requested: int = 0
    chunks_used: int = 0
    chunks_bad: int = 0
    bytes_paid_for: int = 0
    payments: float = 0.0
    cache_hits: int = 0
    hedged_wasted: int = 0


class RPCNode:
    def __init__(
        self,
        rpc_id: str,
        contract: ShelbyContract,
        sps: dict[int, StorageProvider],
        layout: BlobLayout,
        price_per_chunk: float = 1e-6,
        hedge: int = 2,
        cache_chunksets: int = 8,
        sp_deposit: float = 10.0,
    ):
        self.rpc_id = rpc_id
        self.contract = contract
        self.sps = sps
        self.layout = layout
        self.price_per_chunk = price_per_chunk
        self.hedge = hedge
        self.ledger = PaymentLedger()
        for sp_id in sps:
            self.ledger.open(str(sp_id), sp_deposit)  # channels at join time (§2.3)
        self._cache: OrderedDict[tuple[int, int], np.ndarray] = OrderedDict()
        self._cache_size = cache_chunksets
        self.stats = ReadStats()
        contract.register_rpc(rpc_id)

    # -- write path (§2.3) -------------------------------------------------------
    def write_blob(self, meta, encoded_chunksets: list[np.ndarray]) -> None:
        """encoded_chunksets[cs]: (n, alpha, w) — verify commitments, disperse."""
        lay = self.layout
        for cs, coded in enumerate(encoded_chunksets):
            assert coded.shape[0] == lay.n
            for ck in range(lay.n):
                root_expected = meta.chunk_roots[(cs, ck)]
                commit, _ = cm.commit_chunk(coded[ck])
                if commit.root != root_expected:
                    raise ValueError(f"commitment mismatch for chunk ({cs},{ck})")
                sp_id = meta.placement[(cs, ck)]
                if not self.sps[sp_id].store_chunk(meta.blob_id, cs, ck, coded[ck]):
                    raise IOError(f"SP {sp_id} refused chunk ({cs},{ck})")
        self.contract.mark_ready(meta.blob_id, self.rpc_id)

    # -- read path (§2.3 + §3.5 hedging) ------------------------------------------
    def _pay(self, sp_id: int) -> float:
        self.ledger.pay(str(sp_id), self.price_per_chunk)
        self.sps[sp_id]  # channel peer exists
        self.stats.payments += self.price_per_chunk
        return self.price_per_chunk

    def read_chunkset(self, blob_id: int, chunkset: int) -> np.ndarray:
        """Returns the decoded (k, alpha, w) data chunks of one chunkset."""
        key = (blob_id, chunkset)
        if key in self._cache:
            self._cache.move_to_end(key)
            self.stats.cache_hits += 1
            return self._cache[key]
        meta = self.contract.blobs[blob_id]
        if meta.state is not BlobState.READY:
            raise ReadError(f"blob {blob_id} not ready")
        lay = self.layout
        order = sorted(
            range(lay.n),
            key=lambda ck: self.sps[meta.placement[(chunkset, ck)]].behavior.latency_ms,
        )
        # hedging: request k + hedge chunks up-front, keep first k valid
        to_ask = order[: min(lay.n, lay.k + self.hedge)]
        shards: dict[int, np.ndarray] = {}
        asked = 0
        for ck in to_ask + [c for c in order if c not in to_ask]:
            if len(shards) == lay.k:
                break
            sp = self.sps[meta.placement[(chunkset, ck)]]
            asked += 1
            self.stats.chunks_requested += 1
            resp = sp.serve_chunk(blob_id, chunkset, ck, self._pay(meta.placement[(chunkset, ck)]))
            if resp is None:
                continue
            data, _ = resp
            commit, _ = cm.commit_chunk(data)
            if commit.root != meta.chunk_roots[(chunkset, ck)]:
                self.stats.chunks_bad += 1  # §2.3: tampering detected
                continue
            shards[ck] = data
            self.stats.chunks_used += 1
        if len(shards) < lay.k:
            raise ReadError(
                f"chunkset ({blob_id},{chunkset}): only {len(shards)}/{lay.k} valid chunks"
            )
        self.stats.hedged_wasted += asked - lay.k
        decoded = lay.code.reconstruct_data(shards)
        self._cache[key] = decoded
        if len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        return decoded

    def read_range(self, blob_id: int, offset: int, length: int) -> bytes:
        meta = self.contract.blobs[blob_id]
        lay = self.layout
        first, last = lay.byte_range_to_chunksets(offset, length)
        buf = bytearray()
        for cs in range(first, last + 1):
            buf += lay.assemble([self.read_chunkset(blob_id, cs)], lay.chunkset_bytes)
        start = offset - first * lay.chunkset_bytes
        end = min(start + length, meta.size_bytes - first * lay.chunkset_bytes)
        return bytes(buf[start:end])

    def read_blob(self, blob_id: int) -> bytes:
        meta = self.contract.blobs[blob_id]
        return self.read_range(blob_id, 0, meta.size_bytes)
