"""RPC node (§2.3): the gateway between clients and the SP layer.

Write path: verify the client's encoded chunks against the on-chain
commitments, disperse them to the contract-assigned SPs, then mark the blob
READY.

Read path ("designed to serve"): fetch any k of n chunks per chunkset with
**deadline-based request hedging** (§3.5 — issue the k best-estimated
requests, hedge extras when stragglers blow the deadline, ignore the rest),
verify every chunk against its on-chain Merkle root (altered data is
detected, §2.3), Clay-decode, and assemble.  Chunk requests travel through
a pluggable :class:`Transport` — direct in-process calls, or the simulated
dedicated backbone of ``repro.net.backbone`` with per-link latency,
per-node NIC and bandwidth accounting on a simulated clock.  The whole
read path runs as generator *tasks* on a shared
:class:`~repro.net.events.EventLoop`: every chunk request is its own task
(request transfer -> SP disk-slot queue -> service -> response transfer),
so concurrent requests' hedge timers, failure recoveries and SP queues
interleave on one global heap.  The synchronous entry points
(``read_items_detailed`` and friends) spin up a private loop per call and
stay exactly as before for sequential callers.  Reads spanning several
chunksets — even of *different blobs*, via ``read_items_detailed`` — take
the **batched decode path**: chunksets with the same erasure pattern are
Clay-decoded in one wide GF call (``ClayCode.decode_batch``, optionally
through the Pallas ``gf_matmul`` kernel) instead of one-at-a-time numpy.

Payments are **on delivery** (§2.2/§3.2): a chunk is paid through the
RPC->SP micropayment channel only once it arrived AND verified against its
commitment — crashed, missing, or corrupt responses earn the SP nothing.
Channel settlement (`settle_sp_channels`) broadcasts the freshest refunds
and realizes each SP's serving income; client sessions paying this node
credit `serving_income` when *their* channel settles.  A small hot-cache of
decoded chunksets fronts popular content (§5.3).

Overload safety: concurrent cache misses on the same chunkset collapse
onto ONE fetch through a per-node :class:`~repro.net.events.SingleFlight`
table (cache-stampede dedup), and an optional :class:`AdmissionSpec` sheds
requests with a typed :class:`Overloaded` NACK — by queue depth, in-flight
fetch budget, or a brownout latency SLO — so saturation produces a rising
shed rate with bounded tails instead of unbounded queue growth.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.core import commitments as cm
from repro.core import extend2d
from repro.core.contract import BlobState, ShelbyContract
from repro.core.payments import PaymentLedger
from repro.net.events import (
    Acquire,
    EventLoop,
    Join,
    Release,
    safe_release,
    SingleFlight,
    Sleep,
    Transfer,
)
from repro.net.scheduler import FetchResult, HedgedScheduler
from repro.storage.blob import BlobLayout
from repro.storage.sp import StorageProvider


# modeled RPC wire envelope: one chunk request / one failure NACK.  The
# single source of truth — the repair and audit planes import these so
# foreground and background traffic price the same envelope.
REQUEST_BYTES = 256
NACK_BYTES = 64


class ReadError(Exception):
    pass


class Overloaded(ReadError):
    """Typed load-shed outcome: the node refused this request at admission.

    Subclasses :class:`ReadError` so existing drop paths keep working, but
    carries enough structure (`rpc_id`, `reason`) for the fleet to retry on
    a sibling and for replay drivers to account a *shed rate* separately
    from hard failures.  ``reason`` is one of ``"queue"`` (admitted-request
    cap), ``"fetches"`` (in-flight SP fetch cap), ``"deadline"`` (EWMA
    fetch latency above the brownout SLO).
    """

    def __init__(self, rpc_id: str, reason: str):
        self.rpc_id = rpc_id
        self.reason = reason
        super().__init__(f"rpc {rpc_id} overloaded ({reason})")


@dataclasses.dataclass(frozen=True)
class AdmissionSpec:
    """Overload-control knobs for one RPC node.

    "Designed to serve" means degrading *gracefully* at saturation: past
    these limits a request is shed with :class:`Overloaded` (a cheap, fast
    NACK) instead of joining an unbounded queue and dragging every other
    request's tail latency with it.

    * ``max_queued_requests`` — concurrently *admitted* read requests on
      this node (a read counts from admission until its last chunkset is
      decoded); ``None`` = unlimited.
    * ``max_inflight_fetches`` — live chunkset fetch tasks this node may
      have outstanding toward SPs.  Coalesced (single-flight) waiters do
      not count: they add no SP load.  ``None`` = unlimited.
    * ``deadline_ms`` — brownout SLO: while the node's EWMA of recent
      fetch latency exceeds this AND fetches are in flight, new requests
      are shed before doing any work (observed latency is the honest
      congestion signal — it already includes SP disk queues and NIC
      serialization).  An idle node is always admitted as a probe, so the
      estimate re-measures and the brownout lifts when load drops instead
      of latching on a stale EWMA.  ``None`` = off.
    * ``ewma_alpha`` — smoothing for that latency estimate.
    """

    max_queued_requests: int | None = None
    max_inflight_fetches: int | None = None
    deadline_ms: float | None = None
    ewma_alpha: float = 0.2


@dataclasses.dataclass
class ReadStats:
    chunks_requested: int = 0
    chunks_used: int = 0
    chunks_bad: int = 0
    bytes_paid_for: int = 0  # bytes of chunks actually paid (delivered + verified)
    payments: float = 0.0  # RPC->SP micropayments (pay-on-delivery)
    cache_hits: int = 0
    hedged_wasted: int = 0  # requests that contributed no shard (incl. failures) — unpaid
    hedges_launched: int = 0  # deadline-triggered hedge requests only
    hedges_suppressed: int = 0  # hedge deadlines the overload gate refused
    chunkset_fetches: int = 0
    fetch_ms_total: float = 0.0  # simulated clock, not wall time
    coalesced: int = 0  # misses that piggybacked on an in-flight fetch
    shed_requests: int = 0  # reads refused at admission (Overloaded)
    # DAS sampling plane (tiny proof-carrying reads, core/extend2d.py)
    samples_served: int = 0  # shares delivered + verified (paid)
    samples_withheld: int = 0  # SP went silent — the detection signal
    samples_bad: int = 0  # share failed proof verification (unpaid)
    das_cache_hits: int = 0  # samples answered from the hot cache
    sample_proof_bytes: int = 0  # proof bandwidth moved for samples


@dataclasses.dataclass(frozen=True)
class SampledShare:
    """One verified DAS sample: the share plus what moving it cost.

    ``proof_bytes`` is 0 on a cache hit (no proof crossed the wire), but
    the share is still client-payable — the node did serve it.
    """

    blob_id: int
    row: int
    col: int
    data: np.ndarray
    share_bytes: int
    proof_bytes: int
    latency_ms: float
    cache_hit: bool = False
    rpc_id: str = ""

    @property
    def nbytes(self) -> int:
        return self.share_bytes + self.proof_bytes


@dataclasses.dataclass(frozen=True, slots=True)
class ItemStats:
    """Per-(blob, chunkset) outcome of one `read_items_detailed` call."""

    cache_hit: bool
    latency_ms: float  # simulated fetch time (0 for cache hits)
    hedges: int = 0
    wasted: int = 0
    coalesced: bool = False  # joined another request's in-flight fetch


# -- transports: how chunk requests reach SPs -------------------------------------
class DirectTransport:
    """In-process calls; completion time is the SP's queued service time.

    ``request_task`` is the event-engine path: acquire one of the SP's
    disk slots (FIFO queue when the SP is hot), hold it for the service
    time, return the chunk.  No network stages.
    """

    backbone = None  # no simulated network attached

    def __init__(self, sps: dict[int, StorageProvider]):
        self.sps = sps

    def estimate_ms(self, sp_id: int, nbytes: int) -> float:
        return self.sps[sp_id].service_ms()

    def request_task(self, sp_id: int, blob_id: int, chunkset: int, chunk: int):
        sp = self.sps[sp_id]
        resp = sp.serve_chunk(blob_id, chunkset, chunk)
        if resp is None:
            # crashed / missing: a failed probe costs one service interval
            # but never occupies a disk slot
            yield Sleep(sp.service_ms())
            return None
        data, service_ms = resp
        yield Acquire(("sp", sp_id), sp.service.slots)
        try:
            yield Sleep(service_ms)
        finally:
            yield from safe_release(Release(("sp", sp_id)))
        return data

    def das_request_task(self, sp_id: int, blob_id: int, row: int, col: int):
        """One DAS share + proof off the SP's disk (no network stages)."""
        sp = self.sps[sp_id]
        resp = sp.serve_share(blob_id, row, col)
        if resp is None:
            yield Sleep(sp.service_ms())
            return None
        share, proof, service_ms = resp
        yield Acquire(("sp", sp_id), sp.service.slots)
        try:
            yield Sleep(service_ms)
        finally:
            yield from safe_release(Release(("sp", sp_id)))
        return share, proof


class BackboneTransport:
    """Chunk requests over the simulated dedicated backbone (§2.3).

    request transfer -> SP disk-slot queue -> service -> response transfer;
    failures (crashed SP / missing chunk) surface as a fast NACK after one
    round trip.  All times are simulated milliseconds, with FIFO
    serialization accounted per trunk *and* per node NIC by the Backbone,
    and per-SP concurrency accounted by the shared event loop's disk-slot
    resources.
    """

    def __init__(self, sps, backbone, rpc_node: str,
                 sp_node: dict[int, str] | None = None):
        self.sps = sps
        self.backbone = backbone
        self.rpc_node = rpc_node
        self.sp_node = sp_node or {i: f"sp{i}" for i in sps}

    def estimate_ms(self, sp_id: int, nbytes: int) -> float:
        bb, sp = self.backbone, self.sp_node[sp_id]
        return (
            bb.estimate_ms(self.rpc_node, sp, REQUEST_BYTES)
            + self.sps[sp_id].service_ms()
            + bb.estimate_ms(sp, self.rpc_node, nbytes)
        )

    def admit_sp(self, sp_id: int, node: str | None = None) -> None:
        """A new SP joined mid-run: route its requests to `node`."""
        self.sp_node[sp_id] = node or f"sp{sp_id}"

    def request_task(self, sp_id: int, blob_id: int, chunkset: int, chunk: int):
        node = self.sp_node[sp_id]
        yield Transfer(self.rpc_node, node, REQUEST_BYTES)
        sp = self.sps[sp_id]
        resp = sp.serve_chunk(blob_id, chunkset, chunk)
        if resp is None:
            yield Transfer(node, self.rpc_node, NACK_BYTES)
            return None
        data, service_ms = resp
        yield Acquire(("sp", sp_id), sp.service.slots)
        try:
            yield Sleep(service_ms)
        finally:
            yield from safe_release(Release(("sp", sp_id)))
        yield Transfer(node, self.rpc_node, data.nbytes)
        return data

    def das_request_task(self, sp_id: int, blob_id: int, row: int, col: int):
        """One DAS share + proof over the backbone: request out, share AND
        proof bytes back — proof bandwidth rides the same NICs and trunks
        as any paid payload, so the sampling storm's overhead is real."""
        node = self.sp_node[sp_id]
        yield Transfer(self.rpc_node, node, REQUEST_BYTES)
        sp = self.sps[sp_id]
        resp = sp.serve_share(blob_id, row, col)
        if resp is None:
            yield Transfer(node, self.rpc_node, NACK_BYTES)
            return None
        share, proof, service_ms = resp
        yield Acquire(("sp", sp_id), sp.service.slots)
        try:
            yield Sleep(service_ms)
        finally:
            yield from safe_release(Release(("sp", sp_id)))
        yield Transfer(node, self.rpc_node, share.nbytes + proof.nbytes)
        return share, proof


class RPCNode:
    def __init__(
        self,
        rpc_id: str,
        contract: ShelbyContract,
        sps: dict[int, StorageProvider],
        layout: BlobLayout,
        price_per_chunk: float = 1e-6,
        hedge: int = 2,
        cache_chunksets: int = 8,
        sp_deposit: float = 10.0,
        transport=None,
        scheduler: HedgedScheduler | None = None,
        batch_decode: bool = True,
        decode_matmul=None,
        cache_ttl_ms: float | None = None,
        cache_admit_bytes: int | None = None,
        admission: AdmissionSpec | None = None,
        single_flight: bool = True,
    ):
        self.rpc_id = rpc_id
        self.contract = contract
        self.sps = sps
        self.layout = layout
        self.price_per_chunk = price_per_chunk
        self.hedge = hedge
        self.transport = transport or DirectTransport(sps)
        self.scheduler = scheduler or HedgedScheduler(hedge=hedge)
        self.batch_decode = batch_decode
        self.decode_matmul = decode_matmul  # e.g. repro.kernels.ops.gf_matmul_np
        self.ledger = PaymentLedger()
        self._sp_deposit = sp_deposit
        for sp_id in sps:
            self.ledger.open(str(sp_id), sp_deposit)  # channels at join time (§2.3)
        self.serving_income = 0.0  # realized when client sessions settle (§3.2)
        # hot-cache: key -> (decoded chunkset, expiry on the sim clock or
        # None, contract placement version at decode time — a remapped
        # chunkset invalidates on its next lookup)
        self._cache: OrderedDict[
            tuple[int, int], tuple[np.ndarray, float | None, int]
        ] = OrderedDict()
        self._cache_size = cache_chunksets
        self.cache_ttl_ms = cache_ttl_ms
        self.cache_admit_bytes = cache_admit_bytes
        self.admission = admission
        self.single_flight = single_flight
        self._sf: SingleFlight | None = None  # bound to one loop at a time
        self._admitted = 0  # reads between admission and final decode
        self._inflight_fetches = 0  # live chunkset fetch tasks toward SPs
        self._ewma_fetch_ms: float | None = None  # congestion signal
        # fast-path instrumentation: when a dict is assigned here, every
        # _cache_put records the FIRST sim time each key became servable
        # from cache — the cohort classifier's hit/coalesce boundary
        self.cache_put_log: dict[tuple, float] | None = None
        self.stats = ReadStats()
        contract.register_rpc(rpc_id)

    # -- write path (§2.3) -------------------------------------------------------
    def write_blob(self, meta, encoded_chunksets: list[np.ndarray]) -> None:
        """encoded_chunksets[cs]: (n, alpha, w) — verify commitments, disperse."""
        lay = self.layout
        for cs, coded in enumerate(encoded_chunksets):
            assert coded.shape[0] == lay.n
            for ck in range(lay.n):
                root_expected = meta.chunk_roots[(cs, ck)]
                commit, _ = cm.commit_chunk(coded[ck])
                if commit.root != root_expected:
                    raise ValueError(f"commitment mismatch for chunk ({cs},{ck})")
                sp_id = meta.placement[(cs, ck)]
                if not self.sps[sp_id].store_chunk(meta.blob_id, cs, ck, coded[ck]):
                    raise IOError(f"SP {sp_id} refused chunk ({cs},{ck})")
        self.contract.mark_ready(meta.blob_id, self.rpc_id)

    # -- read path (§2.3 + §3.5 hedging) ------------------------------------------
    def _pay(self, sp_id: int) -> float:
        """Pay ONE delivered+verified chunk over the RPC->SP channel."""
        self.ledger.pay(str(sp_id), self.price_per_chunk)
        self.sps[sp_id].receive_payment(self.price_per_chunk)
        self.stats.payments += self.price_per_chunk
        self.stats.bytes_paid_for += self.layout.chunk_bytes
        return self.price_per_chunk

    def _pay_sample(self, sp_id: int, nbytes: int) -> float:
        """Pay one delivered+verified DAS sample, pro-rated by wire bytes
        (share + proof) against the per-chunk price."""
        amount = self.price_per_chunk * nbytes / self.layout.chunk_bytes
        self.ledger.pay(str(sp_id), amount)
        self.sps[sp_id].receive_payment(amount)
        self.stats.payments += amount
        self.stats.bytes_paid_for += nbytes
        return amount

    def settle_sp_channels(self) -> dict[int, float]:
        """Broadcast the freshest refund of every paid RPC->SP channel.

        Each SP's `settled_income` is credited with exactly what the channel
        paid out (deposit - freshest refund); fresh channels reopen with the
        original deposit so serving continues.  Returns sp_id -> income.
        """
        income: dict[int, float] = {}
        for sp_id in list(self.sps):
            ch = self.ledger.channels[str(sp_id)]
            if ch.paid <= 0.0:
                continue
            _, server_gets = ch.settle(ch.latest_refund)
            self.sps[sp_id].credit_settlement(server_gets)
            income[sp_id] = server_gets  # one channel per SP
            self.ledger.open(str(sp_id), self._sp_deposit)  # fresh channel
        return income

    def admit_sp(self, sp_id: int, sp: StorageProvider,
                 node: str | None = None) -> None:
        """A new SP joined the contract mid-run (membership plane): make it
        servable from this node — shared SP table entry, a fresh RPC->SP
        payment channel (channels open at join time, §2.3), and a transport
        route when the transport keeps one."""
        self.sps[sp_id] = sp
        if str(sp_id) not in self.ledger.channels:
            self.ledger.open(str(sp_id), self._sp_deposit)
        admit = getattr(self.transport, "admit_sp", None)
        if admit is not None:
            admit(sp_id, node)

    def _fetch_chunkset_task(
        self, loop: EventLoop, blob_id: int, chunkset: int, label: str = "fetch"
    ):
        """Hedged k-of-n shard fetch as a task on the shared loop; no decode."""
        meta = self.contract.blobs[blob_id]
        if meta.state is not BlobState.READY:
            raise ReadError(f"blob {blob_id} not ready")
        lay = self.layout
        candidates = [
            (
                ck,
                meta.placement[(chunkset, ck)],
                self.transport.estimate_ms(meta.placement[(chunkset, ck)], lay.chunk_bytes),
            )
            for ck in range(lay.n)
        ]

        def issue_task(ck: int, sp_id: int):
            self.stats.chunks_requested += 1
            data = yield from self.transport.request_task(sp_id, blob_id, chunkset, ck)
            return data

        def verify(ck: int, data) -> bool:
            commit, _ = cm.commit_chunk(data)
            if commit.root != meta.chunk_roots[(chunkset, ck)]:
                self.stats.chunks_bad += 1  # §2.3: tampering detected
                return False
            self._pay(meta.placement[(chunkset, ck)])  # pay on delivery
            return True

        result = yield from self.scheduler.fetch_task(
            loop, lay.k, candidates, issue_task, verify, label=label,
            hedge_gate=self._allow_hedge if self.admission is not None else None,
        )
        if len(result.shards) < lay.k:
            raise ReadError(
                f"chunkset ({blob_id},{chunkset}): only {len(result.shards)}/{lay.k} valid chunks"
            )
        self.stats.chunks_used += result.used
        self.stats.hedged_wasted += result.wasted
        self.stats.hedges_launched += result.hedges
        self.stats.hedges_suppressed += result.hedges_suppressed
        self.stats.chunkset_fetches += 1
        self.stats.fetch_ms_total += result.latency_ms
        alpha = self.admission.ewma_alpha if self.admission is not None else 0.2
        if self._ewma_fetch_ms is None:
            self._ewma_fetch_ms = result.latency_ms
        else:
            self._ewma_fetch_ms = (
                (1 - alpha) * self._ewma_fetch_ms + alpha * result.latency_ms
            )
        return result

    def _counted_fetch(self, loop: EventLoop, key: tuple[int, int], label: str):
        """One chunkset fetch held against the node's in-flight budget.

        The CALLER increments ``_inflight_fetches`` at spawn time — before
        this generator first steps — so simultaneously-arriving requests
        see each other's flights at admission; only the decrement lives
        here (the flight knows when it lands)."""
        try:
            result = yield from self._fetch_chunkset_task(
                loop, key[0], key[1], label=label
            )
        finally:
            self._inflight_fetches -= 1
        return result

    # -- overload control (admission + single-flight) ------------------------------
    def _allow_hedge(self) -> bool:
        """Hedges are shed first: they multiply SP load exactly when the
        node is at its budget or already missing its latency SLO."""
        spec = self.admission
        if spec is None:
            return True
        if (spec.max_inflight_fetches is not None
                and self._inflight_fetches >= spec.max_inflight_fetches):
            return False
        if (spec.deadline_ms is not None and self._ewma_fetch_ms is not None
                and self._ewma_fetch_ms > spec.deadline_ms):
            return False
        return True

    def _single_flight_for(self, loop: EventLoop) -> SingleFlight | None:
        """The node's in-flight fetch table, bound to the loop it runs on.

        Sequential sync entry points each spin a private loop; a table of
        handles from a dead loop is useless, so rebind lazily.  Concurrent
        misses only ever share one loop, which is the case dedup targets.
        """
        if not self.single_flight:
            return None
        if self._sf is None or self._sf.loop is not loop:
            self._sf = SingleFlight(loop)
        return self._sf

    def _shed(self, reason: str) -> Overloaded:
        self.stats.shed_requests += 1
        return Overloaded(self.rpc_id, reason)

    def _check_admission(self, new_flights: int | None = None) -> None:
        """Raise :class:`Overloaded` if this request must be shed.

        Called twice per read: at entry (queue depth + brownout SLO — both
        known before any work) and again with ``new_flights`` once the
        cache/coalesce pass has established how many *new* fetch tasks the
        request would add."""
        spec = self.admission
        if spec is None:
            return
        if new_flights is None:
            if (spec.max_queued_requests is not None
                    and self._admitted >= spec.max_queued_requests):
                raise self._shed("queue")
            # brownout sheds only while work is in flight: an idle node is
            # always admitted as a probe — its fetch re-measures the EWMA,
            # so a node that browned out under a burst recovers once the
            # queue drains instead of shedding forever on a stale estimate
            if (spec.deadline_ms is not None and self._ewma_fetch_ms is not None
                    and self._ewma_fetch_ms > spec.deadline_ms
                    and self._inflight_fetches > 0):
                raise self._shed("deadline")
        elif (spec.max_inflight_fetches is not None and new_flights > 0
                and self._inflight_fetches + new_flights > spec.max_inflight_fetches):
            raise self._shed("fetches")

    def _cache_get(self, key: tuple[int, int], now_ms: float) -> np.ndarray | None:
        entry = self._cache.get(key)
        if entry is None:
            return None
        decoded, expires, version = entry
        if expires is not None and now_ms >= expires:
            del self._cache[key]  # TTL lapsed on the sim clock
            return None
        if version != self.contract.placement_version.get(key, 0):
            # the contract remapped this chunkset since the decode (epoch
            # reconfiguration / repair placement): the entry may front data
            # whose holders departed — drop it and re-fetch from the
            # CURRENT placement so no read is served off a stale member set
            del self._cache[key]
            return None
        self._cache.move_to_end(key)
        return decoded

    def _cache_put(self, key: tuple[int, int], decoded: np.ndarray,
                   now_ms: float = 0.0) -> None:
        if self._cache_size <= 0:
            return
        if self.cache_admit_bytes is not None and decoded.nbytes > self.cache_admit_bytes:
            return  # admission: oversized objects would evict the whole hot set
        expires = None if self.cache_ttl_ms is None else now_ms + self.cache_ttl_ms
        version = self.contract.placement_version.get(key, 0)
        if self.cache_put_log is not None and key not in self.cache_put_log:
            self.cache_put_log[key] = now_ms
        self._cache[key] = (decoded, expires, version)
        self._cache.move_to_end(key)
        if len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)

    def read_chunkset_timed(
        self, blob_id: int, chunkset: int, start_ms: float = 0.0
    ) -> tuple[np.ndarray, float]:
        """Decoded (k, alpha, w) data of one chunkset + simulated fetch ms."""
        parts, latency = self.read_chunksets_timed(blob_id, [chunkset], start_ms)
        return parts[0], latency

    def read_chunkset(self, blob_id: int, chunkset: int) -> np.ndarray:
        return self.read_chunkset_timed(blob_id, chunkset)[0]

    def read_items_task(
        self, loop: EventLoop, items: list[tuple[int, int]], label: str = "read"
    ):
        """Task: read many (blob_id, chunkset) items — possibly spanning
        blobs — on the shared event loop.

        Cache misses are *spawned* as independent fetch tasks (hedged
        fetches overlap -> each item's latency is its own slowest leg, and
        concurrent requests' fetches contend for the same SP disk slots and
        NICs), then decoded through the batched Clay path when more than
        one misses: chunksets of *different blobs* with the same erasure
        pattern still stack into one wide GF matmul, so a `get_many`
        spanning requests amortizes kernel dispatch across all of them.

        Overload safety: misses go through the node's *single-flight*
        table — a miss on a chunkset another in-flight request is already
        fetching Joins that fetch instead of duplicating it (cache-stampede
        collapse; the waiter's ItemStats is marked ``coalesced``).  With an
        :class:`AdmissionSpec` attached, the request is shed with
        :class:`Overloaded` when the node is past its queue/fetch budget or
        its brownout SLO — *before* it adds load.
        """
        self._check_admission()  # queue depth + brownout SLO (may raise)
        self._admitted += 1
        try:
            result = yield from self._read_items_admitted(loop, items, label)
        finally:
            self._admitted -= 1
        return result

    def _read_items_admitted(
        self, loop: EventLoop, items: list[tuple[int, int]], label: str
    ):
        out: dict[tuple[int, int], np.ndarray] = {}
        stats: dict[tuple[int, int], ItemStats] = {}
        fetched: dict[tuple[int, int], FetchResult] = {}
        pending: list[tuple[tuple[int, int], object, bool]] = []
        misses: list[tuple[int, int]] = []
        seen: set[tuple[int, int]] = set()
        sf = self._single_flight_for(loop)
        for key in items:
            if key in seen:
                continue
            seen.add(key)
            cached = self._cache_get(key, loop.now)
            if cached is not None:
                self.stats.cache_hits += 1
                out[key] = cached
                stats[key] = ItemStats(cache_hit=True, latency_ms=0.0)
            else:
                misses.append(key)
        # fetch-budget admission: only *new* flights add SP load — misses
        # that will coalesce onto an in-flight fetch ride along for free
        new_flights = (
            len(misses) if sf is None
            else sum(1 for key in misses if not sf.live(key))
        )
        self._check_admission(new_flights)  # may raise Overloaded
        t0 = loop.now
        for key in misses:
            if sf is None:
                h = loop.spawn(
                    self._counted_fetch(loop, key, f"{label}/cs{key}"),
                    label=f"{label}/cs{key}",
                )
                leader = True
            else:
                h, leader = sf.flight(
                    key,
                    lambda key=key: self._counted_fetch(loop, key, f"{label}/cs{key}"),
                    label=f"{label}/cs{key}",
                )
            if leader:
                # count the flight NOW (its task has not stepped yet), so
                # another request admitted later in this same event step
                # already sees it against the fetch budget
                self._inflight_fetches += 1
            else:
                self.stats.coalesced += 1
            pending.append((key, h, leader))
        first_err: Exception | None = None
        for key, h, leader in pending:
            try:
                res = yield Join(h)
            except (GeneratorExit, KeyboardInterrupt):
                # task teardown / user interrupt must never be harvested as
                # a child failure — propagate immediately
                raise
            except Exception as e:  # harvest every child before propagating
                if first_err is None:
                    first_err = e
                continue
            fetched[key] = res
            stats[key] = ItemStats(
                cache_hit=False,
                # a coalesced waiter only waited for the residual of a fetch
                # someone else started; its hedges/waste belong to the leader
                latency_ms=res.latency_ms if leader
                else max(0.0, h.finished_ms - t0),
                hedges=res.hedges if leader else 0,
                wasted=res.wasted if leader else 0,
                coalesced=not leader,
            )
        if first_err is not None:
            raise first_err
        if fetched:
            order = sorted(fetched)
            if self.batch_decode and len(order) > 1:
                decoded = self.layout.code.reconstruct_data_batch(
                    [fetched[key].shards for key in order], matmul=self.decode_matmul
                )
            else:
                decoded = [
                    self.layout.code.reconstruct_data(fetched[key].shards)
                    for key in order
                ]
            for key, dec in zip(order, decoded):
                out[key] = dec
                self._cache_put(key, dec, loop.now)
        return out, stats

    # -- DAS sampling path (tiny proof-carrying reads, core/extend2d.py) ----------
    def sample_share_task(
        self, loop: EventLoop, blob_id: int, row: int, col: int, *,
        cache_bypass: bool = True, label: str = "das",
    ):
        """Task: fetch + verify ONE DAS share through this node.

        Shares have exactly one contract-assigned holder, so there is no
        hedging and no k-of-n recovery — a silent SP *is* the signal the
        sampler exists to detect, surfaced as :class:`ReadError` (unpaid).
        Samples pass the same admission gate as reads (the storm must not
        bypass overload control), but default to ``cache_bypass=True``:
        single-use random coordinates would churn the entry-bounded hot
        cache out from under streaming readers (see the `das` bench).
        """
        self._check_admission()  # may raise Overloaded
        self._admitted += 1
        try:
            result = yield from self._sample_admitted(
                loop, blob_id, row, col, cache_bypass
            )
        finally:
            self._admitted -= 1
        return result

    def _sample_admitted(
        self, loop: EventLoop, blob_id: int, row: int, col: int, cache_bypass: bool
    ):
        rec = self.contract.das.get(blob_id)
        if rec is None:
            raise ReadError(f"blob {blob_id} has no DAS extension")
        key = ("das", blob_id, row * rec.side + col)
        cached = self._cache_get(key, loop.now)
        if cached is not None:
            self.stats.das_cache_hits += 1
            self.stats.samples_served += 1
            return SampledShare(
                blob_id=blob_id, row=row, col=col, data=cached,
                share_bytes=rec.share_bytes, proof_bytes=0, latency_ms=0.0,
                cache_hit=True, rpc_id=self.rpc_id,
            )
        sp_id = rec.placement[(row, col)]
        t0 = loop.now
        resp = yield from self.transport.das_request_task(sp_id, blob_id, row, col)
        latency_ms = loop.now - t0
        if resp is None:
            self.stats.samples_withheld += 1
            raise ReadError(f"share ({blob_id},{row},{col}) withheld by SP {sp_id}")
        share, proof = resp
        if not extend2d.verify_share(rec.das_root, rec.side, share.tobytes(), proof):
            self.stats.samples_bad += 1  # tampering detected — unpaid
            raise ReadError(f"share ({blob_id},{row},{col}) failed verification")
        self._pay_sample(sp_id, share.nbytes + proof.nbytes)  # pay on delivery
        self.stats.samples_served += 1
        self.stats.sample_proof_bytes += proof.nbytes
        if not cache_bypass:
            self._cache_put(key, share, loop.now)
        return SampledShare(
            blob_id=blob_id, row=row, col=col, data=share,
            share_bytes=share.nbytes, proof_bytes=proof.nbytes,
            latency_ms=latency_ms, rpc_id=self.rpc_id,
        )

    def read_items_detailed(
        self, items: list[tuple[int, int]], start_ms: float = 0.0
    ) -> tuple[dict[tuple[int, int], np.ndarray], dict[tuple[int, int], ItemStats]]:
        """Synchronous wrapper over :meth:`read_items_task` — runs the read
        on a private event loop anchored at ``start_ms``.  Trunk/NIC
        reservations persist in the shared Backbone, so sequential callers
        still queue against earlier traffic."""
        loop = EventLoop(network=getattr(self.transport, "backbone", None))
        h = loop.spawn(
            self.read_items_task(loop, items), at_ms=start_ms, label="read_items"
        )
        return loop.run_until(h)

    def read_chunksets_timed(
        self, blob_id: int, chunksets: list[int], start_ms: float = 0.0
    ) -> tuple[list[np.ndarray], float]:
        """Single-blob convenience over `read_items_detailed`; the returned
        latency is the slowest item's leg (hedged fetches overlap)."""
        out, stats = self.read_items_detailed(
            [(blob_id, cs) for cs in chunksets], start_ms
        )
        latency = max((s.latency_ms for s in stats.values()), default=0.0)
        return [out[(blob_id, cs)] for cs in chunksets], latency

    def read_range_timed(
        self, blob_id: int, offset: int, length: int, start_ms: float = 0.0
    ) -> tuple[bytes, float]:
        meta = self.contract.blobs[blob_id]
        lay = self.layout
        first, last = lay.byte_range_to_chunksets(offset, length)
        parts, latency = self.read_chunksets_timed(
            blob_id, list(range(first, last + 1)), start_ms
        )
        return lay.extract_range(parts, first, offset, length, meta.size_bytes), latency

    def read_range(self, blob_id: int, offset: int, length: int) -> bytes:
        return self.read_range_timed(blob_id, offset, length)[0]

    def read_blob(self, blob_id: int) -> bytes:
        meta = self.contract.blobs[blob_id]
        return self.read_range(blob_id, 0, meta.size_bytes)
