"""Data-availability sampling (DAS): the proof-carrying light-client regime.

The missing workload corner (ROADMAP item 4): instead of few large
cache-friendly streams, *millions of tiny random proof-carrying reads*.
This module glues the 2-D extension of ``core/extend2d.py`` into the
serving stack:

* :func:`extend_and_disperse` — pad a blob's bytes into a k x k data
  square, RS-extend it to 2k x 2k (one wide GF call per axis — batch
  variants stack MANY blobs into the same call), Merkle-commit rows,
  columns and the DAS root, place every share on a contract-drawn SP
  (epoch-seeded, like chunk placement), and publish a
  :class:`~repro.core.contract.DASRecord` on chain.
* :class:`LightClientSampler` / ``ShelbySession.sample_availability`` —
  each epoch draw ``s`` uniform share coordinates per blob, fetch them
  through the fleet as tiny paid reads (share + commitment path over the
  backbone NICs), verify locally against the DAS root alone, and return
  an :class:`AvailabilityVerdict`.
* :func:`seed_withholding` — the adversary: mark an exact fraction of a
  blob's shares withheld (data *retained* — chunk-possession audits are
  structurally blind to this; refusing samplers is the only tell).
* :func:`measure_detection` — the verifiable claim: with a withheld
  fraction ``q`` and ``s`` with-replacement samples, detection happens
  with probability exactly ``1 - (1-q)^s``
  (:func:`~repro.core.extend2d.detection_probability`); measured rates
  over seeded adversaries must match the analytic curve.

Sampling coordinates are drawn WITH replacement and withholding marks an
EXACT share count, so the analytic formula is exact — measurement
tolerance covers Monte-Carlo noise only, not model mismatch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import extend2d
from repro.core import placement as placement_mod
from repro.core.contract import DASRecord, ShelbyContract


@dataclasses.dataclass(frozen=True)
class DASSpec:
    """Knobs of the DAS regime (see ``configs/shelby.py``).

    ``proof_bytes_per_share=None`` uses the true modeled proof size
    (coordinates + two Merkle paths + the axis root, a function of the
    square side); a number overrides it on the contract record, e.g. to
    model fancier vector commitments.
    """

    k: int = 4  # data square is k x k; extended square 2k x 2k
    share_bytes: int = 512
    samples_per_epoch: int = 16
    extension: bool = True  # master switch: off = no dispersal, no sampling
    proof_bytes_per_share: int | None = None

    @property
    def side(self) -> int:
        return 2 * self.k

    def layout(self) -> extend2d.Extend2D:
        return extend2d.Extend2D(k=self.k)

    def detection_probability(self, q: float, samples: int | None = None) -> float:
        return extend2d.detection_probability(
            q, self.samples_per_epoch if samples is None else samples
        )


@dataclasses.dataclass(frozen=True)
class SampleReceipt:
    """Pay-per-sample record, session-conservation compatible: settlement
    sums ``payments`` per node exactly like a read receipt's."""

    blob_id: int
    row: int
    col: int
    nbytes: int  # wire bytes paid for (share + proof; 0 if failed/shed)
    share_bytes: int
    proof_bytes: int
    latency_ms: float
    payments: dict[str, float]
    verified: bool
    shed: bool = False
    cache_hit: bool = False

    @property
    def total_paid(self) -> float:
        # sorted so the float sum is independent of dict insertion order
        return sum(self.payments[k] for k in sorted(self.payments))


@dataclasses.dataclass(frozen=True)
class AvailabilityVerdict:
    """One blob's verdict after an epoch's sampling round.

    ``available`` is False the moment ANY sample hard-fails (withheld or
    unverifiable share) — that single failure is the detection event the
    ``1-(1-q)^s`` math prices.  Shed samples are inconclusive (the fleet
    refused at admission; nothing was learned about the SP) and counted
    apart.
    """

    blob_id: int
    epoch: int
    samples: int  # coordinates drawn
    verified: int
    failures: int  # withheld / bad shares (detection events)
    shed: int
    first_failure: int | None  # draw-order index of the first detection
    available: bool
    sample_bytes: int  # total wire bytes (shares + proofs)
    proof_bytes: int
    paid: float


def draw_coords(seed: int, blob_id: int, epoch: int, s: int,
                side: int) -> list[tuple[int, int]]:
    """``s`` uniform share coordinates, WITH replacement (pure in its
    arguments — the sampler's storm is deterministic per seed)."""
    rng = placement_mod._rng(
        seed.to_bytes(8, "little", signed=True), b"das-draw", blob_id, epoch
    )
    flat = rng.integers(0, side * side, size=s)
    return [(int(i) // side, int(i) % side) for i in flat]


# -- dispersal ----------------------------------------------------------------
def extend_and_disperse_many(
    contract: ShelbyContract,
    sps: dict,
    blobs: list[tuple[int, bytes]],  # (blob_id, data)
    spec: DASSpec,
    *,
    matmul=None,
) -> list[DASRecord]:
    """Extend + commit + place MANY blobs' squares; the two RS extension
    stages run as ONE wide GF matmul each across all of them (the
    small-and-wide kernel regime — see ``benchmarks/gf_kernel.py``)."""
    lay = spec.layout()
    squares = [lay.pad_square(data, spec.share_bytes) for _, data in blobs]
    exts = lay.extend_batch(squares, matmul=matmul)
    active = [info.sp_id for info in contract.active_sps()]
    if not active:
        raise RuntimeError("no active SPs to hold DAS shares")
    records = []
    for (blob_id, _), ext in zip(blobs, exts):
        csq = extend2d.commit_square(ext)
        rng = placement_mod._rng(
            contract.epoch_seed(contract.epoch), b"das", blob_id
        )
        placement: dict[tuple[int, int], int] = {}
        proof_bytes = None
        for r in range(lay.side):
            for c in range(lay.side):
                sp_id = int(active[int(rng.integers(0, len(active)))])
                placement[(r, c)] = sp_id
                proof = csq.prove(r, c, axis="row" if (r + c) % 2 == 0 else "col")
                if proof_bytes is None:
                    proof_bytes = proof.nbytes
                sps[sp_id].store_share(blob_id, r, c, csq.share(r, c), proof)
        record = DASRecord(
            blob_id=blob_id,
            side=lay.side,
            share_bytes=spec.share_bytes,
            das_root=csq.commitment.das_root,
            placement=placement,
            proof_bytes=(
                spec.proof_bytes_per_share
                if spec.proof_bytes_per_share is not None else proof_bytes
            ),
        )
        contract.register_das(record)
        records.append(record)
    return records


def extend_and_disperse(
    contract: ShelbyContract, sps: dict, blob_id: int, data: bytes,
    spec: DASSpec, *, matmul=None,
) -> DASRecord:
    return extend_and_disperse_many(
        contract, sps, [(blob_id, data)], spec, matmul=matmul
    )[0]


# -- the adversary ------------------------------------------------------------
def seed_withholding(
    contract: ShelbyContract, sps: dict, blob_id: int, fraction: float,
    seed: int = 0,
) -> int:
    """Withhold an EXACT ``round(fraction * side^2)`` of a blob's shares
    (seeded, without replacement), marking their holders silent on those
    coordinates.  Returns the withheld count W; the effective per-sample
    hit probability is exactly ``W / side^2``."""
    rec = contract.das[blob_id]
    total = rec.side * rec.side
    w = int(round(fraction * total))
    if w == 0:
        return 0
    rng = placement_mod._rng(
        seed.to_bytes(8, "little", signed=True), b"das-withhold", blob_id
    )
    chosen = rng.choice(total, size=w, replace=False)
    for flat in chosen:
        r, c = int(flat) // rec.side, int(flat) % rec.side
        sps[rec.placement[(r, c)]].withhold_share(blob_id, r, c)
    return w


class LightClientSampler:
    """The light client: a seeded per-epoch sampling schedule over a
    session.  Holding only each blob's DAS root (via the contract), it
    draws ``spec.samples_per_epoch`` coordinates per blob per epoch,
    pays per delivered sample, and keeps the availability verdicts."""

    def __init__(self, session, spec: DASSpec, *, seed: int = 0):
        self.session = session
        self.spec = spec
        self.seed = seed
        self.verdicts: list[AvailabilityVerdict] = []

    def sample_epoch(self, epoch: int, blob_ids: list[int] | None = None,
                     **kw) -> list[AvailabilityVerdict]:
        out = self.session.sample_availability(
            blob_ids, epoch=epoch, samples=self.spec.samples_per_epoch,
            seed=self.seed, **kw,
        )
        self.verdicts.extend(out)
        return out

    @property
    def detections(self) -> int:
        return sum(1 for v in self.verdicts if not v.available)


# -- the verifiable claim: measured vs analytic detection ---------------------
@dataclasses.dataclass(frozen=True)
class DetectionPoint:
    """One (withholding fraction, seed) cell of the detection sweep."""

    fraction: float  # requested withholding fraction
    q_effective: float  # exact withheld share fraction (W / side^2)
    samples: int  # s, per trial
    trials: int
    detected: int
    measured: float  # detected / trials
    analytic: float  # 1 - (1 - q_effective)^s
    mean_samples_to_detect: float  # draw-order index of first failure + 1
    mean_sample_bytes: float  # wire bytes per sample (share + proof)


def _mini_world(num_sps: int, spec: DASSpec, num_blobs: int, seed: int):
    """A tiny DirectTransport world carrying only the DAS plane."""
    from repro.core.audit import AuditParams
    from repro.core.placement import SPInfo
    from repro.storage.blob import BlobLayout
    from repro.storage.rpc import RPCNode
    from repro.storage.sdk import ShelbyClient
    from repro.storage.sp import StorageProvider

    layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    contract = ShelbyContract(AuditParams())
    sps: dict[int, StorageProvider] = {}
    for i in range(num_sps):
        contract.register_sp(SPInfo(sp_id=i, stake=10_000.0, dc=f"dc{i % 3}"))
        sps[i] = StorageProvider(i)
    rpc = RPCNode("rpc0", contract, sps, layout)
    client = ShelbyClient(contract, rpc, deposit=1e6, das=spec)
    rng = np.random.default_rng(seed)
    blob_ids = []
    for _ in range(num_blobs):
        data = rng.integers(0, 256, spec.k * spec.k * spec.share_bytes,
                            dtype=np.uint8).tobytes()
        blob_ids.append(client.put(data).blob_id)
    return contract, sps, client, blob_ids


def measure_detection(
    fractions=(0.05, 0.15, 0.30),
    seeds=(0, 1, 2),
    *,
    spec: DASSpec | None = None,
    num_blobs: int = 12,
    rounds: int = 12,
    num_sps: int = 6,
    samples: int | None = None,
) -> list[DetectionPoint]:
    """Measured withholding-detection rate vs the analytic ``1-(1-q)^s``.

    Per (fraction, seed): a fresh world, every blob's shares dispersed,
    an exact-count withholding adversary seeded on every blob, then
    ``rounds`` independent sampling epochs per blob — each epoch's draw
    is one Bernoulli trial whose success probability is the analytic
    curve.  Sessions settle, so pay-per-sample conservation is exercised
    on every run."""
    spec = spec or DASSpec()
    s = samples or spec.samples_per_epoch
    points = []
    for fraction in fractions:
        for seed in seeds:
            contract, sps, client, blob_ids = _mini_world(
                num_sps, spec, num_blobs, seed
            )
            total = spec.side * spec.side
            w = None
            for blob_id in blob_ids:
                w = seed_withholding(contract, sps, blob_id, fraction,
                                     seed=seed * 1013 + blob_id)
            q_eff = (w or 0) / total
            trials = detected = 0
            first_sum = 0
            bytes_sum = bytes_n = 0
            session = client.current_session
            for epoch in range(rounds):
                verdicts = session.sample_availability(
                    blob_ids, epoch=epoch, samples=s, seed=seed * 733 + epoch
                )
                for v in verdicts:
                    trials += 1
                    if not v.available:
                        detected += 1
                        first_sum += (v.first_failure or 0) + 1
                    if v.verified:
                        bytes_sum += v.sample_bytes
                        bytes_n += v.verified
            client.settle()  # conservation checked inside close()
            points.append(
                DetectionPoint(
                    fraction=fraction,
                    q_effective=q_eff,
                    samples=s,
                    trials=trials,
                    detected=detected,
                    measured=detected / trials if trials else 0.0,
                    analytic=extend2d.detection_probability(q_eff, s),
                    mean_samples_to_detect=(
                        first_sum / detected if detected else float("inf")
                    ),
                    mean_sample_bytes=bytes_sum / bytes_n if bytes_n else 0.0,
                )
            )
    return points
