"""Erasure-coded distributed checkpointing (the paper's §6 "model weights,
checkpoints, logs" use case, built on the §2/§3 machinery).

Training state is serialized into a self-describing byte stream (JSON header
with per-leaf shape/dtype + raw little-endian buffers — no pickle), split
into per-host shards, and each shard is written as a Shelby blob
(Clay-coded, Merkle-committed, dispersed to SPs).  Consequences the tests
exercise:

* loss of up to m SPs per chunkset is survivable without re-writing
  (MDS reads), and single-SP loss repairs at MSR bandwidth;
* corrupted checkpoint bytes are *detected* (commitment mismatch) rather
  than silently loaded;
* **elastic restore**: a restart may use a different host count / mesh —
  shards are byte streams, so any host can read any byte range; the caller
  re-shards with the new mesh's shardings.

Restore is template-based (`restore(template)`), the standard JAX practice:
the tree structure comes from the caller, bytes come from Shelby.
"""
from __future__ import annotations

import dataclasses
import io
import json

import jax
import numpy as np

from repro.storage.sdk import ShelbyClient

_MAGIC = b"SHLBYCKP1"


def serialize_pytree(tree) -> bytes:
    leaves = jax.tree_util.tree_leaves(tree)
    metas, bufs = [], []
    for leaf in leaves:
        arr = np.asarray(leaf)
        metas.append({"shape": list(arr.shape), "dtype": arr.dtype.str})
        bufs.append(arr.tobytes())  # tobytes() C-orders without reshaping 0-d
    header = json.dumps({"leaves": metas}).encode()
    out = io.BytesIO()
    out.write(_MAGIC)
    out.write(len(header).to_bytes(8, "little"))
    out.write(header)
    for b in bufs:
        out.write(b)
    return out.getvalue()


def deserialize_pytree(data: bytes, template):
    if data[: len(_MAGIC)] != _MAGIC:
        raise ValueError("not a shelby checkpoint")
    off = len(_MAGIC)
    hlen = int.from_bytes(data[off : off + 8], "little")
    off += 8
    metas = json.loads(data[off : off + hlen].decode())["leaves"]
    off += hlen
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(t_leaves) != len(metas):
        raise ValueError(f"template has {len(t_leaves)} leaves, checkpoint {len(metas)}")
    leaves = []
    for meta, t in zip(metas, t_leaves):
        dt = np.dtype(meta["dtype"])
        shape = tuple(meta["shape"])
        n = dt.itemsize * int(np.prod(shape)) if shape else dt.itemsize
        arr = np.frombuffer(data[off : off + n], dtype=dt).reshape(shape)
        off += n
        t_arr = np.asarray(t)
        if t_arr.shape != arr.shape:
            raise ValueError(f"shape mismatch: template {t_arr.shape} vs ckpt {arr.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def shard_bytes(data: bytes, num_shards: int) -> list[bytes]:
    per = -(-len(data) // num_shards)
    return [data[i * per : (i + 1) * per] for i in range(num_shards)]


@dataclasses.dataclass
class CheckpointRecord:
    step: int
    shard_blob_ids: list[int]
    total_bytes: int


class CheckpointManager:
    """Writes/reads checkpoints through the Shelby client; keeps last `keep`."""

    def __init__(self, client: ShelbyClient, keep: int = 3, num_host_shards: int = 1):
        self.client = client
        self.keep = keep
        self.num_host_shards = num_host_shards
        self.records: dict[int, CheckpointRecord] = {}

    def save(self, step: int, state) -> CheckpointRecord:
        data = serialize_pytree(state)
        shards = shard_bytes(data, self.num_host_shards)
        blob_ids = [self.client.put(s).blob_id for s in shards]
        rec = CheckpointRecord(step=step, shard_blob_ids=blob_ids, total_bytes=len(data))
        self.records[step] = rec
        for old in sorted(self.records)[: -self.keep]:
            del self.records[old]
        return rec

    def latest_step(self) -> int | None:
        return max(self.records) if self.records else None

    def restore(self, step: int, template, *, reading_hosts: int | None = None):
        """Elastic restore: `reading_hosts` may differ from writer shard count;
        each reading host pulls a byte range that may span writer shards."""
        rec = self.records[step]
        # all shards in one fleet pass: their chunksets batch-decode together
        receipts = self.client.get_many(
            [(bid, 0, None) for bid in rec.shard_blob_ids]
        )
        data = b"".join(r.data for r in receipts)[: rec.total_bytes]
        if reading_hosts is not None and reading_hosts != self.num_host_shards:
            # emulate: each reading host fetches its own byte range, then the
            # ranges concatenate to the full stream (any k chunks suffice).
            per = -(-len(data) // reading_hosts)
            parts = [data[i * per : (i + 1) * per] for i in range(reading_hosts)]
            data = b"".join(parts)
        return deserialize_pytree(data, template)
