"""Repair planner (§3.3 "Flexible Repair Structure" + "Repair Coordination").

Detect-and-repair for lost/corrupted chunks:

* **MSR path** — when all d = n-1 helpers are alive, read only the
  alpha/q repair-plane sub-chunks from each helper (the Clay optimum; the
  coordination layer "allows planning for bandwidth-optimal recoveries").
* **MDS fallback** — "when the optimal repair pattern cannot be followed,
  Shelby can fall back to the MDS property (any k chunks recover data) even
  if it must temporarily sacrifice repair bandwidth efficiency."

Every MDS helper chunk is verified against its on-chain commitment as it
arrives — one corrupt helper among the first k no longer poisons the
decode; the planner simply reads the next candidate (retry with a
different helper subset).  ``repair_all`` records per-chunk failures in
``failures`` instead of aborting the remaining repairs on the first raise.
Detection covers *corrupted-at-rest* data too: ``scan_lost_chunks`` can
spot-check a sampled fraction of live chunks against their commitments
(an audit-shaped cost — reads + hashes — so the scan itself shows up as
background load once it runs on the event loop).

The planner also re-verifies the repaired chunk against its on-chain root
before re-dispersal, and reports exact helper-bytes-read so the repair
bandwidth benchmark measures the real data path, not a formula.

**On the event loop** (the background plane): :meth:`repair_chunk_task`
is the same repair as a generator task — helper reads travel as real
``Transfer``\\ s over the attached :class:`~repro.net.backbone.Backbone`
(request out, sub-chunks/chunks back), each helper read holds one of the
helper SP's disk slots *in the background scheduling class* (capped by the
SP's :class:`~repro.storage.sp.BackgroundSpec` slot share, woken after any
queued paid read), and the re-dispersal write ships the rebuilt chunk to
the new SP and occupies its disk too.  Repair bandwidth therefore shows up
on NIC/trunk counters and can delay — but never starve — paid serving.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core import commitments as cm
from repro.core.contract import ShelbyContract
from repro.net.events import (
    Acquire, EventLoop, Join, Release, Sleep, Transfer, safe_release,
)
from repro.storage.blob import BlobLayout
from repro.storage.rpc import NACK_BYTES, REQUEST_BYTES
from repro.storage.sp import StorageProvider


class RepairError(Exception):
    pass


@dataclasses.dataclass
class RepairReport:
    blob_id: int
    chunkset: int
    chunk: int
    mode: str  # "msr" | "mds"
    helper_bytes_read: int
    new_sp: int
    verified: bool
    helpers_rejected: int = 0  # helper chunks failing their commitment check
    sim_ms: float = 0.0  # simulated duration when run as an event-loop task


class RepairCoordinator:
    """Plans and executes repairs, synchronously or as event-loop tasks.

    ``nodes`` maps sp_id -> backbone node id (e.g. ``{3: "sp3"}``); when
    given together with a loop whose network is attached, task-based
    repairs move real bytes from ``coordinator_node``.  ``spot_check_rate``
    samples that fraction of *live* chunks per scan for commitment
    verification, catching bit rot that a pure liveness scan misses.
    """

    def __init__(
        self,
        contract: ShelbyContract,
        sps: dict[int, StorageProvider],
        layout: BlobLayout,
        *,
        spot_check_rate: float = 0.0,
        seed: int = 0,
        nodes: dict[int, str] | None = None,
        coordinator_node: str = "repairer",
    ):
        self.contract = contract
        self.sps = sps
        self.layout = layout
        self.nodes = nodes
        self.coordinator_node = coordinator_node
        self.spot_check_rate = spot_check_rate
        self._scan_rng = np.random.default_rng(seed * 6151 + 17)
        self.reports: list[RepairReport] = []
        # per-run_all failure list (reset each call) + cumulative counter —
        # a permanently unrecoverable chunk re-appears every scan, so the
        # list alone would grow duplicates unboundedly
        self.failures: list[tuple[tuple[int, int, int], str]] = []
        self.failures_total = 0
        self.spot_checks = 0  # live chunks sampled for commitment verification
        self.spot_check_bytes = 0

    # -- detection (§2.4 audits / Appendix A "trivial to detect") -----------------
    def scan_lost_chunks(self, *, spot_check_rate: float | None = None
                         ) -> list[tuple[int, int, int]]:
        """Missing/crashed chunks, plus — at ``spot_check_rate`` — live
        chunks whose served bytes fail their on-chain commitment (bit rot
        or a corrupt SP would otherwise never be scheduled for repair)."""
        rate = self.spot_check_rate if spot_check_rate is None else spot_check_rate
        lost = []
        for meta in self.contract.blobs.values():
            for (cs, ck), sp_id in meta.placement.items():
                sp = self.sps.get(sp_id)
                if sp is None or sp.behavior.crashed or not sp.has_chunk(meta.blob_id, cs, ck):
                    lost.append((meta.blob_id, cs, ck))
                    continue
                if rate > 0 and self._scan_rng.random() < rate:
                    self.spot_checks += 1
                    resp = sp.serve_chunk(meta.blob_id, cs, ck)
                    if resp is None:
                        lost.append((meta.blob_id, cs, ck))
                        continue
                    self.spot_check_bytes += resp[0].nbytes
                    commit, _ = cm.commit_chunk(resp[0])
                    if commit.root != meta.chunk_roots[(cs, ck)]:
                        lost.append((meta.blob_id, cs, ck))
        return lost

    # -- shared repair planning ------------------------------------------------------
    def live_holders(self, blob_id: int, chunkset: int) -> int:
        """How many of a chunkset's placed chunks sit on a live SP that
        actually holds the bytes (the boundary-census liveness count)."""
        meta = self.contract.blobs[blob_id]
        alive = 0
        for ck in range(meta.n):
            sp = self.sps.get(meta.placement.get((chunkset, ck)))
            if (sp is not None and not sp.behavior.crashed
                    and sp.has_chunk(blob_id, chunkset, ck)):
                alive += 1
        return alive

    def risk_order(self, items: list[tuple[int, int, int]]
                   ) -> list[tuple[int, int, int]]:
        """Most-fragile-first ordering for a repair backlog: chunks of
        chunksets with the fewest live holders launch first — a chunkset
        sitting at exactly k is one failure away from data loss, so it
        must not wait behind comfortable re-dispersals (Appendix A
        recovery priority).  Ties break on ids, keeping the paced launch
        schedule — and the determinism digest — reproducible."""
        return sorted(
            items, key=lambda it: (self.live_holders(it[0], it[1]),) + it
        )

    def _alive_helpers(self, meta, blob_id: int, chunkset: int, chunk: int
                       ) -> dict[int, StorageProvider]:
        helpers = {}
        for ck in range(self.layout.n):
            if ck == chunk:
                continue
            sp = self.sps.get(meta.placement[(chunkset, ck)])
            if sp is not None and not sp.behavior.crashed and sp.has_chunk(blob_id, chunkset, ck):
                helpers[ck] = sp
        return helpers

    def _verify_chunk(self, meta, chunkset: int, ck: int, data) -> bool:
        commit, _ = cm.commit_chunk(data)
        return commit.root == meta.chunk_roots[(chunkset, ck)]

    def _place(self, meta, blob_id: int, chunkset: int, chunk: int) -> int:
        """Pick where the rebuilt chunk lives (restore in place when the
        original SP merely lost it; otherwise contract randomness)."""
        old_sp = meta.placement[(chunkset, chunk)]
        old = self.sps.get(old_sp)
        if (old is not None and not old.behavior.crashed
                and not old.has_chunk(blob_id, chunkset, chunk)):
            return old_sp  # same SP lost one chunk: restore in place
        return self.contract.reassign_chunk(blob_id, chunkset, chunk)

    # -- synchronous repair ---------------------------------------------------------
    def repair_chunk(self, blob_id: int, chunkset: int, chunk: int) -> RepairReport:
        """Synchronous wrapper: run :meth:`repair_chunk_task` on a private
        event loop — ONE implementation of the MSR-first/MDS-fallback plan.
        The private loop has no network attached, so no transfers are
        modelled (byte movement needs a shared loop with a Backbone); the
        helper-bytes accounting is identical either way."""
        loop = EventLoop()
        h = loop.spawn(
            self.repair_chunk_task(loop, blob_id, chunkset, chunk),
            label=f"repair/b{blob_id}/c{chunkset}/k{chunk}",
        )
        return loop.run_until(h)

    def repair_all(self) -> list[RepairReport]:
        """Repair every lost chunk; an unrecoverable chunk is recorded in
        ``failures`` (this call's list — check it after every sweep) instead
        of aborting the remaining repairs on the first raise."""
        reports = []
        self.failures = []
        for lost in self.scan_lost_chunks():
            try:
                reports.append(self.repair_chunk(*lost))
            except RepairError as e:
                self.failures.append((lost, str(e)))
                self.failures_total += 1
        return reports

    # -- event-loop repair (the background plane) ------------------------------------
    def _node_of(self, sp_id: int) -> str | None:
        return self.nodes.get(sp_id) if self.nodes is not None else None

    def _helper_read_task(self, loop: EventLoop, sp_id: int, ck: int,
                          blob_id: int, chunkset: int, sub_ids=None):
        """One background helper read: request over the backbone, a disk
        slot in the background class (under the SP's slot-share budget),
        then the payload back over the helper's NIC and the trunks."""
        sp = self.sps[sp_id]
        node = self._node_of(sp_id)
        networked = node is not None and loop.network is not None
        if networked:
            yield Transfer(self.coordinator_node, node, REQUEST_BYTES)
        if sub_ids is not None:
            resp = sp.serve_subchunks(blob_id, chunkset, ck, sub_ids)
        else:
            resp = sp.serve_chunk(blob_id, chunkset, ck)
        if resp is None:
            if networked:
                yield Transfer(node, self.coordinator_node, NACK_BYTES)
            return None
        data, _ = resp
        prio = sp.service.background.priority
        yield Acquire(("sp", sp_id), sp.service.slots, priority=prio,
                      limit=sp.bg_slots())
        try:
            yield Sleep(sp.service_ms())
        finally:
            yield from safe_release(Release(("sp", sp_id), priority=prio))
        if networked:
            yield Transfer(node, self.coordinator_node, data.nbytes)
        return data

    def repair_chunk_task(self, loop: EventLoop, blob_id: int, chunkset: int,
                          chunk: int, label: str = "repair"):
        """Task: the same MSR-first/MDS-fallback repair, with helper reads
        as concurrent background tasks moving real bytes.  Returns the
        :class:`RepairReport`; raises :class:`RepairError` when the chunk
        is unrecoverable (callers — e.g. ``RepairPlane`` — record it)."""
        meta = self.contract.blobs[blob_id]
        lay = self.layout
        code = lay.code
        t0 = loop.now
        helpers_alive = self._alive_helpers(meta, blob_id, chunkset, chunk)

        bytes_read = 0
        rejected = 0
        repaired = None
        mode = ""
        if len(helpers_alive) == lay.n - 1:
            ids = code.repair_subchunk_ids(chunk)
            handles = [
                (ck, loop.spawn(
                    self._helper_read_task(loop, sp.sp_id, ck, blob_id,
                                           chunkset, sub_ids=ids),
                    label=f"{label}/msr{ck}"))
                for ck, sp in sorted(helpers_alive.items())
            ]
            subs: dict[int, object] = {}
            vanished = False
            for ck, h in handles:  # harvest every leg before deciding —
                data = yield Join(h)  # delivered bytes count even when the
                if data is None:  # MSR plan dies (they crossed the links)
                    vanished = True
                else:
                    subs[ck] = data
                    bytes_read += data.nbytes
            if not vanished:
                candidate = code.repair(chunk, subs)
                if self._verify_chunk(meta, chunkset, chunk, candidate):
                    repaired, mode = candidate, "msr"

        if repaired is None:
            if len(helpers_alive) < lay.k:
                raise RepairError(
                    f"unrecoverable: {len(helpers_alive)} helpers < k={lay.k} "
                    f"for chunk ({blob_id},{chunkset},{chunk})"
                )
            # MDS fallback in waves: k concurrent verified reads, replacing
            # rejected/missing helpers from the remaining candidates
            remaining = deque(sorted(helpers_alive))
            shards: dict[int, object] = {}
            while len(shards) < lay.k and remaining:
                wave = []
                while remaining and len(shards) + len(wave) < lay.k:
                    wave.append(remaining.popleft())
                handles = [
                    (ck, loop.spawn(
                        self._helper_read_task(loop, helpers_alive[ck].sp_id,
                                               ck, blob_id, chunkset),
                        label=f"{label}/mds{ck}"))
                    for ck in wave
                ]
                for ck, h in handles:
                    data = yield Join(h)
                    if data is None:
                        continue
                    bytes_read += data.nbytes
                    if not self._verify_chunk(meta, chunkset, ck, data):
                        rejected += 1
                        continue
                    shards[ck] = data
            if len(shards) < lay.k:
                raise RepairError(
                    f"unrecoverable: only {len(shards)} verified helpers < "
                    f"k={lay.k} for chunk ({blob_id},{chunkset},{chunk}) "
                    f"({rejected} rejected by commitment check)"
                )
            repaired, mode = code.decode(shards)[chunk], "mds"

        if not self._verify_chunk(meta, chunkset, chunk, repaired):
            raise RepairError("repaired chunk fails commitment check")
        new_sp = self._place(meta, blob_id, chunkset, chunk)
        # re-dispersal: ship the rebuilt chunk and occupy the new SP's disk
        # for the write — still background class
        dst_sp = self.sps[new_sp]
        dst_node = self._node_of(new_sp)
        if dst_node is not None and loop.network is not None:
            yield Transfer(self.coordinator_node, dst_node, int(repaired.nbytes))
        prio = dst_sp.service.background.priority
        yield Acquire(("sp", new_sp), dst_sp.service.slots, priority=prio,
                      limit=dst_sp.bg_slots())
        try:
            yield Sleep(dst_sp.service_ms())
        finally:
            yield from safe_release(Release(("sp", new_sp), priority=prio))
        dst_sp.store_chunk(blob_id, chunkset, chunk, repaired)

        report = RepairReport(blob_id, chunkset, chunk, mode, bytes_read,
                              new_sp, True, helpers_rejected=rejected,
                              sim_ms=loop.now - t0)
        self.reports.append(report)
        return report
