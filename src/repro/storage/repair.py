"""Repair planner (§3.3 "Flexible Repair Structure" + "Repair Coordination").

Detect-and-repair for lost/corrupted chunks:

* **MSR path** — when all d = n-1 helpers are alive, read only the
  alpha/q repair-plane sub-chunks from each helper (the Clay optimum; the
  coordination layer "allows planning for bandwidth-optimal recoveries").
* **MDS fallback** — "when the optimal repair pattern cannot be followed,
  Shelby can fall back to the MDS property (any k chunks recover data) even
  if it must temporarily sacrifice repair bandwidth efficiency."

The planner also re-verifies the repaired chunk against its on-chain root
before re-dispersal, and reports exact helper-bytes-read so the repair
bandwidth benchmark measures the real data path, not a formula.
"""
from __future__ import annotations

import dataclasses


from repro.core import commitments as cm
from repro.core.contract import ShelbyContract
from repro.storage.blob import BlobLayout
from repro.storage.sp import StorageProvider


class RepairError(Exception):
    pass


@dataclasses.dataclass
class RepairReport:
    blob_id: int
    chunkset: int
    chunk: int
    mode: str  # "msr" | "mds"
    helper_bytes_read: int
    new_sp: int
    verified: bool


class RepairCoordinator:
    def __init__(self, contract: ShelbyContract, sps: dict[int, StorageProvider], layout: BlobLayout):
        self.contract = contract
        self.sps = sps
        self.layout = layout
        self.reports: list[RepairReport] = []

    # -- detection (§2.4 audits / Appendix A "trivial to detect") -----------------
    def scan_lost_chunks(self) -> list[tuple[int, int, int]]:
        lost = []
        for meta in self.contract.blobs.values():
            for (cs, ck), sp_id in meta.placement.items():
                sp = self.sps.get(sp_id)
                if sp is None or sp.behavior.crashed or not sp.has_chunk(meta.blob_id, cs, ck):
                    lost.append((meta.blob_id, cs, ck))
        return lost

    # -- repair ---------------------------------------------------------------------
    def repair_chunk(self, blob_id: int, chunkset: int, chunk: int) -> RepairReport:
        meta = self.contract.blobs[blob_id]
        lay = self.layout
        code = lay.code
        helpers_alive = {}
        for ck in range(lay.n):
            if ck == chunk:
                continue
            sp = self.sps.get(meta.placement[(chunkset, ck)])
            if sp is not None and not sp.behavior.crashed and sp.has_chunk(blob_id, chunkset, ck):
                helpers_alive[ck] = sp

        bytes_read = 0
        if len(helpers_alive) == lay.n - 1:
            # MSR: every helper ships only the repair-plane sub-chunks
            ids = code.repair_subchunk_ids(chunk)
            subs = {}
            for ck, sp in helpers_alive.items():
                resp = sp.serve_subchunks(blob_id, chunkset, ck, ids)
                if resp is None:
                    raise RepairError("helper vanished mid-repair")
                subs[ck] = resp[0]
                bytes_read += resp[0].nbytes
            repaired = code.repair(chunk, subs)
            mode = "msr"
        elif len(helpers_alive) >= lay.k:
            # MDS fallback: full chunks from any k helpers
            shards = {}
            for ck, sp in list(helpers_alive.items())[: lay.k]:
                resp = sp.serve_chunk(blob_id, chunkset, ck)
                shards[ck] = resp[0]
                bytes_read += resp[0].nbytes
            repaired = code.decode(shards)[chunk]
            mode = "mds"
        else:
            raise RepairError(
                f"unrecoverable: {len(helpers_alive)} helpers < k={lay.k} "
                f"for chunk ({blob_id},{chunkset},{chunk})"
            )

        # verify against the on-chain commitment before re-dispersal
        commit, _ = cm.commit_chunk(repaired)
        verified = commit.root == meta.chunk_roots[(chunkset, chunk)]
        if not verified:
            raise RepairError("repaired chunk fails commitment check")

        # place on a fresh SP (contract randomness) and store
        old_sp = meta.placement[(chunkset, chunk)]
        old = self.sps.get(old_sp)
        if old is not None and not old.behavior.crashed and not old.has_chunk(blob_id, chunkset, chunk):
            new_sp = old_sp  # same SP lost one chunk: restore in place
        else:
            new_sp = self.contract.reassign_chunk(blob_id, chunkset, chunk)
        self.sps[new_sp].store_chunk(blob_id, chunkset, chunk, repaired)

        report = RepairReport(blob_id, chunkset, chunk, mode, bytes_read, new_sp, verified)
        self.reports.append(report)
        return report

    def repair_all(self) -> list[RepairReport]:
        return [self.repair_chunk(*lost) for lost in self.scan_lost_chunks()]
