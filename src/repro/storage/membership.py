"""Membership plane: epoch-scale churn + contract reconfiguration (§2.5,
Appendix A; Walrus-style epoch reconfiguration).

The SP fleet is a living thing: providers join, announce departure, crash,
or get slashed-and-ejected — and the durability story ("erasure coding with
low replication overhead and minimal repair bandwidth") is only credible
when repair RACES that churn while paid serving continues.  This module
drives exactly that on the shared :class:`~repro.net.events.EventLoop`:

* :class:`ChurnSpec` — a seeded per-SP per-epoch churn process
  (crash / announced-departure / slash probabilities, joins per epoch),
  plus explicitly *scripted* events for deterministic scenarios.  Draws
  are content-addressed per (epoch, SP) from the contract's epoch seed,
  so a higher churn rate fails a SUPERSET of the SPs a lower rate fails
  under the same seed — lost-chunkset probability is provably monotone in
  the churn rate, per seed (the coupling the property tests assert).
* :class:`MembershipPlane` — a background plane (same ``spawn(loop)`` /
  ``records`` contract as the audit/repair planes): mid-epoch it applies
  crashes and slashes at seeded times and registers joiners with the
  contract, the backbone and the serving fleet; at each epoch boundary it
  finalizes departures, takes a **census** (a chunkset with fewer than k
  live chunk holders is counted LOST — measured, not computed), asks the
  contract to :meth:`~repro.core.contract.ShelbyContract.reconfigure_epoch`
  the displaced placement entries, and enqueues the resulting
  **re-dispersal backlog** through a :class:`RepairPlane` under the SPs'
  existing :class:`~repro.storage.sp.BackgroundSpec` budget.  Every event
  appends a ``kind="member"`` :class:`BackgroundRecord`, so WHO churned
  and WHAT was remapped ride the replay determinism digest.
* :func:`measure_durability` — the measured lost-chunksets-vs-churn-rate
  series (`core.durability.ChurnPoint`): tiny seeded worlds churned for a
  few epochs, losses *counted* from the census and set against the
  analytic no-repair binomial tail.

Serving keeps running throughout: a crashed/departed SP NACKs, the hedged
k-of-n read path recovers from surviving code symbols mid-epoch, the RPC
hot caches version-check entries against ``contract.placement_version``
(no read is served off a stale member set), and pay-on-delivery means a
dead SP is never paid.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import placement as placement_mod
from repro.core.contract import BlobState, ShelbyContract
from repro.core.placement import SPInfo
from repro.net.events import EventLoop, Sleep
from repro.net.workloads import BackgroundRecord
from repro.storage.background import RepairPlane
from repro.storage.repair import RepairCoordinator
from repro.storage.sp import ServiceSpec, StorageProvider

# deterministic application order for same-instant events: joins first
# (capacity arrives before demand), then failures
_KIND_RANK = {"join": 0, "announce": 1, "crash": 2, "slash": 3}


@dataclasses.dataclass(frozen=True)
class ChurnSpec:
    """Seeded churn process knobs (per SP, per epoch).

    ``p_crash`` / ``p_leave`` / ``p_slash`` are evaluated per live SP per
    epoch from ONE uniform draw each (content-addressed by epoch seed and
    sp_id, independent of iteration order), with crash taking precedence
    over leave over slash.  ``joins_per_epoch`` registers that many fresh
    SPs at seeded mid-epoch times.  ``min_active`` caps removals so the
    fleet never shrinks below it (``None`` = no floor).  ``scripted``
    pins explicit (epoch, kind, sp_id) events — kind is one of
    ``join|announce|crash|slash``, sp_id is ignored for joins, and an
    optional 4th element fixes the in-epoch time fraction — applied in
    ADDITION to the probabilistic draws (and exempt from the floor), for
    deterministic benchmark scenarios.
    """

    p_crash: float = 0.0
    p_leave: float = 0.0
    p_slash: float = 0.0
    joins_per_epoch: int = 0
    min_active: int | None = None
    seed: int = 0
    join_stake: float = 1000.0
    scripted: tuple[tuple[int, str, int], ...] = ()


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """One membership transition on the simulated clock."""

    kind: str  # join | announce | leave | crash | slash
    epoch: int
    t_ms: float
    sp_id: int


@dataclasses.dataclass
class EpochStats:
    """Boundary summary of one churned epoch."""

    epoch: int
    boundary_ms: float
    joins: int = 0
    crashes: int = 0
    departures: int = 0
    slashes: int = 0
    reassigned: int = 0
    enqueued: int = 0
    lost_new: int = 0
    handles: list = dataclasses.field(default_factory=list, repr=False)

    def drain_ms(self) -> float:
        """Boundary -> last repair of this epoch's backlog landed (NaN
        while any repair is still in flight; 0 for an empty backlog)."""
        if not self.handles:
            return 0.0
        return max(h.finished_ms for h in self.handles) - self.boundary_ms


class MembershipPlane:
    """Epoch-scale churn + reconfiguration as a background plane.

    Spawn it (optionally alongside its ``repair`` plane — see
    :meth:`planes`) on the same loop as a foreground replay and the churn
    process, the boundary reconfigurations and the re-dispersal backlog
    all contend with paid serving.

    ``repair``: a :class:`RepairCoordinator` to rebuild displaced chunks
    through (``None`` disables re-dispersal — the no-repair durability
    measurement).  ``fleet`` / ``backbone`` / ``nodes`` / ``nic`` wire
    joiners into serving: contract registration always happens; with a
    fleet the joiner gets payment channels + transport routes, with a
    backbone it gets a NIC'd node (and ``nodes`` gains the sp->node id
    the audit/repair planes route by).  ``lost`` may be a shared set when
    one logical churn run spans several replay loops (``run_sim``).
    """

    def __init__(
        self,
        contract: ShelbyContract,
        sps: dict[int, StorageProvider],
        layout,
        churn: ChurnSpec,
        *,
        repair: RepairCoordinator | None = None,
        repair_pace_ms: float | None = None,
        fleet=None,
        backbone=None,
        nodes: dict[int, str] | None = None,
        nic=None,
        epochs: int = 1,
        epoch_ms: float = 250.0,
        start_epoch: int = 0,
        num_dcs: int = 3,
        racks_per_dc: int = 4,
        service_factory=None,
        lost: set[tuple[int, int]] | None = None,
    ):
        self.contract = contract
        self.sps = sps
        self.layout = layout
        self.churn = churn
        self.repair = (
            RepairPlane(repair, lost=[], pace_ms=repair_pace_ms)
            if repair is not None else None
        )
        self.fleet = fleet
        self.backbone = backbone
        self.nodes = nodes
        self.nic = nic
        self.epochs = epochs
        self.epoch_ms = epoch_ms
        self.start_epoch = start_epoch
        self.num_dcs = num_dcs
        self.racks_per_dc = racks_per_dc
        self.service_factory = service_factory or ServiceSpec
        # lost chunksets are PERMANENT: a shared set lets one churn run
        # span several replay loops without re-counting old losses
        self.lost: set[tuple[int, int]] = lost if lost is not None else set()
        self.events: list[MembershipEvent] = []
        self.records: list[BackgroundRecord] = []
        self.epoch_stats: list[EpochStats] = []
        self.reassigned_total = 0
        self.joined: list[int] = []
        self._crashed: set[int] = set()  # crashes awaiting boundary finalize
        self._announced: set[int] = set()
        self._repairing: dict[tuple[int, int, int], object] = {}

    # -- plane contract ----------------------------------------------------------
    def planes(self) -> list:
        """What to pass as ``background=``: this plane + its repair plane
        (so backlog repairs land in the same replay's records/digest)."""
        return [self] if self.repair is None else [self, self.repair]

    def spawn(self, loop: EventLoop) -> None:
        loop.spawn(self._epochs_task(loop), at_ms=loop.now, label="membership")

    @property
    def lost_chunksets(self) -> int:
        return len(self.lost)

    # -- the churn process -------------------------------------------------------
    def _draw_epoch(self, epoch: int) -> list[tuple[float, str, int]]:
        """(t_frac, kind, sp_id) events for one epoch — content-addressed
        draws, so the failure set at rate p is a superset of the failure
        set at rate p' < p under the same seed (monotone coupling)."""
        spec = self.churn
        seed = self.contract.epoch_seed(epoch)
        dead = self.contract.dead_sps() | self._crashed
        alive = [i for i in sorted(self.sps)
                 if i not in dead and not self.sps[i].behavior.crashed]
        removals: list[tuple[float, str, int]] = []
        for sp_id in alive:
            rng = placement_mod._rng(seed, b"churn", spec.seed, sp_id)
            u_crash, u_leave, u_slash, u_t = (float(x) for x in rng.random(4))
            if u_crash < spec.p_crash:
                removals.append((u_t, "crash", sp_id))
            elif u_leave < spec.p_leave:
                removals.append((u_t, "announce", sp_id))
            elif u_slash < spec.p_slash:
                removals.append((u_t, "slash", sp_id))
        if spec.min_active is not None:
            allowed = max(0, len(alive) - spec.min_active)
            removals = sorted(removals)[:allowed]
        events = list(removals)
        for j in range(spec.joins_per_epoch):
            rng = placement_mod._rng(seed, b"churn-join", spec.seed, j)
            events.append((float(rng.random()), "join", -1))
        for idx, ev in enumerate(spec.scripted):
            e, kind, sp_id = ev[0], ev[1], ev[2]
            if e != epoch:
                continue
            if len(ev) > 3:
                t_frac = float(ev[3])
            else:
                rng = placement_mod._rng(seed, b"scripted", spec.seed, idx)
                t_frac = float(rng.random())
            events.append((t_frac, kind, sp_id))
        return sorted(events, key=lambda ev: (ev[0], _KIND_RANK[ev[1]], ev[2]))

    def _epochs_task(self, loop: EventLoop):
        for e in range(self.start_epoch, self.start_epoch + self.epochs):
            yield from self._one_epoch(loop, e)

    def _one_epoch(self, loop: EventLoop, epoch: int):
        t0 = loop.now
        stats = EpochStats(epoch=epoch, boundary_ms=t0 + self.epoch_ms)
        for t_frac, kind, sp_id in self._draw_epoch(epoch):
            target = t0 + t_frac * self.epoch_ms
            if target > loop.now:
                yield Sleep(target - loop.now)
            self._apply(loop, epoch, kind, sp_id, stats)
        end = t0 + self.epoch_ms
        if end > loop.now:
            yield Sleep(end - loop.now)
        self._boundary(loop, epoch, stats)
        self.epoch_stats.append(stats)

    def _record(self, loop: EventLoop, epoch: int, kind: str, tag,
                ok: bool = True, nbytes: int = 0) -> None:
        self.records.append(BackgroundRecord(
            kind="member", key=f"e{epoch}/{kind}/{tag}",
            t_ms=loop.now, finish_ms=loop.now, ok=ok, nbytes=nbytes,
        ))

    def _apply(self, loop: EventLoop, epoch: int, kind: str, sp_id: int,
               stats: EpochStats) -> None:
        if kind == "join":
            sp_id = self._admit_joiner(epoch)
            stats.joins += 1
        elif kind == "crash":
            # mid-epoch availability fault; detection is the boundary census
            if sp_id not in self.sps or self.sps[sp_id].behavior.crashed:
                return
            self.sps[sp_id].crash()
            self._crashed.add(sp_id)
            stats.crashes += 1
        elif kind == "announce":
            # graceful intent: the SP keeps serving until the boundary
            if sp_id in self.contract.dead_sps() or sp_id in self._announced:
                return
            self.contract.announce_departure(sp_id)
            self._announced.add(sp_id)
            stats.departures += 1
        elif kind == "slash":
            # protocol violation: full-stake slash ejects NOW; an ejected
            # SP is off the serving set immediately (no boundary grace)
            if sp_id in self.contract.ejected:
                return
            stake = self.contract.stakes.get(sp_id, 0.0)
            self.contract.slash(sp_id, max(stake, 1.0))
            if sp_id in self.sps:
                self.sps[sp_id].crash()
            stats.slashes += 1
        else:  # pragma: no cover - guarded by _KIND_RANK
            raise ValueError(f"unknown membership event kind {kind!r}")
        self.events.append(MembershipEvent(kind, epoch, loop.now, sp_id))
        self._record(loop, epoch, kind, f"sp{sp_id}")

    def _admit_joiner(self, epoch: int) -> int:
        """Register a fresh SP with the contract and wire it into serving
        (backbone node + NIC, fleet payment channels, repair routing)."""
        sp_id = max(self.contract.sps, default=-1) + 1
        rng = placement_mod._rng(
            self.contract.epoch_seed(epoch), b"join-domain", self.churn.seed, sp_id
        )
        dc = f"dc{int(rng.integers(self.num_dcs))}"
        rack = f"r{int(rng.integers(self.racks_per_dc))}"
        self.contract.register_sp(
            SPInfo(sp_id=sp_id, stake=self.churn.join_stake, dc=dc, rack=rack)
        )
        sp = StorageProvider(sp_id, service=self.service_factory())
        self.sps[sp_id] = sp
        node = None
        if self.backbone is not None:
            node = f"sp{sp_id}"
            self.backbone.register_node(node, dc, nic=self.nic)
            if self.nodes is not None:
                self.nodes[sp_id] = node
        if self.fleet is not None:
            self.fleet.admit_sp(sp_id, sp, node)
        self.joined.append(sp_id)
        return sp_id

    # -- epoch boundary: finalize, census, reconfigure, enqueue -------------------
    def _boundary(self, loop: EventLoop, epoch: int, stats: EpochStats) -> None:
        # 1) finalize announced departures (the node powers off) and fold
        #    detected crashes into the departed set — both are permanent
        for sp_id in sorted(self._announced):
            self.contract.finalize_departure(sp_id)
            self.sps[sp_id].decommission()
            self.events.append(MembershipEvent("leave", epoch, loop.now, sp_id))
            self._record(loop, epoch, "leave", f"sp{sp_id}")
        self._announced.clear()
        # fold ANY crashed SP into the departed set (churn-crashed this
        # epoch, or pre-existing faults the census just detected) so the
        # reconfiguration below remaps its placement entries
        for sp_id in sorted(self.sps):
            if (self.sps[sp_id].behavior.crashed
                    and sp_id not in self.contract.dead_sps()):
                self.contract.finalize_departure(sp_id)
        self._crashed.clear()

        # 2) census: COUNT each READY chunkset's live chunk holders; below
        #    k it is lost — permanently (measured durability, not a formula)
        newly_lost = self._census()
        stats.lost_new = newly_lost
        self._record(loop, epoch, "lost", "census", ok=newly_lost == 0,
                     nbytes=newly_lost)

        # 3) reconfigure: remap displaced placement entries to survivors
        #    (bumps placement_version -> serving caches invalidate)
        reassigned = self.contract.reconfigure_epoch(
            epoch, skip_chunksets=self.lost
        )
        stats.reassigned = len(reassigned)
        self.reassigned_total += len(reassigned)
        self._record(loop, epoch, "reconfig", "placement",
                     nbytes=len(reassigned))

        # 4) enqueue the re-dispersal backlog: every non-lost chunk whose
        #    assigned live SP lacks its bytes and is not already in flight
        #    (covers fresh reassignments AND retries of failed repairs),
        #    most-fragile chunksets first so the paced launch schedule
        #    shrinks the window where one more failure loses data
        if self.repair is not None:
            items = self.repair.rc.risk_order(self._redispersal_items())
            handles = self.repair.enqueue(loop, items)
            self._repairing.update(zip(items, handles))
            stats.enqueued = len(items)
            stats.handles = handles
            self._record(loop, epoch, "enqueue", "backlog", nbytes=len(items))

    def _census(self) -> int:
        newly_lost = 0
        for blob_id in sorted(self.contract.blobs):
            meta = self.contract.blobs[blob_id]
            if meta.state is not BlobState.READY:
                continue
            for cs in range(meta.num_chunksets):
                if (blob_id, cs) in self.lost:
                    continue
                alive = 0
                for ck in range(meta.n):
                    sp = self.sps.get(meta.placement.get((cs, ck)))
                    if (sp is not None and not sp.behavior.crashed
                            and sp.has_chunk(blob_id, cs, ck)):
                        alive += 1
                if alive < meta.k:
                    self.lost.add((blob_id, cs))
                    newly_lost += 1
        return newly_lost

    def _redispersal_items(self) -> list[tuple[int, int, int]]:
        items = []
        for blob_id in sorted(self.contract.blobs):
            meta = self.contract.blobs[blob_id]
            if meta.state is not BlobState.READY:
                continue
            for (cs, ck) in sorted(meta.placement):
                if (blob_id, cs) in self.lost:
                    continue
                sp = self.sps.get(meta.placement[(cs, ck)])
                if sp is None or sp.behavior.crashed:
                    continue  # still unplaced (no candidate had room)
                if sp.has_chunk(blob_id, cs, ck):
                    continue
                key = (blob_id, cs, ck)
                h = self._repairing.get(key)
                if h is not None and math.isnan(h.finished_ms):
                    continue  # already racing in the backlog
                items.append(key)
        return items


# ---------------------------------------------------------------------------
# measured durability: lost-chunkset probability vs churn rate
# ---------------------------------------------------------------------------
def measure_durability(
    churn_rates,
    *,
    seeds=(0, 1, 2),
    epochs: int = 3,
    num_sps: int = 10,
    num_blobs: int = 2,
    layout=None,
    epoch_ms: float = 100.0,
    repair: bool = True,
    min_active: int | None = None,
):
    """Measure lost-chunkset probability at each churn rate by COUNTING.

    Builds a tiny direct-transport world per (rate, seed) — contract, SPs,
    dispersed blobs — churns it for `epochs` epochs of crash-rate `rate`
    (with the re-dispersal backlog racing the failures when ``repair``),
    and counts census losses.  Returns one
    :class:`~repro.core.durability.ChurnPoint` per rate, carrying the
    matching analytic no-repair binomial tail for comparison.
    """
    import numpy as np

    from repro.core import durability
    from repro.storage.blob import BlobLayout
    from repro.storage.rpc import RPCNode

    layout = layout or BlobLayout(k=4, m=2, chunkset_bytes_target=16 * 1024)
    points = []
    for rate in churn_rates:
        lost = 0
        chunksets = 0
        for seed in seeds:
            contract = ShelbyContract()
            sps: dict[int, StorageProvider] = {}
            for i in range(num_sps):
                contract.register_sp(
                    SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 3}", rack=f"r{i % 2}")
                )
                sps[i] = StorageProvider(i)
            writer = RPCNode(f"writer{seed}", contract, sps, layout)
            rng = np.random.default_rng(seed * 541 + 7)
            from repro.storage.sdk import ShelbyClient

            client = ShelbyClient(contract, writer, deposit=1e9)
            for _ in range(num_blobs):
                data = rng.integers(
                    0, 256, 2 * layout.chunkset_bytes, dtype=np.uint8
                ).tobytes()
                client.put(data)
            rc = (
                RepairCoordinator(contract, sps, layout) if repair else None
            )
            plane = MembershipPlane(
                contract, sps, layout,
                ChurnSpec(p_crash=float(rate), seed=seed, min_active=min_active),
                repair=rc, epochs=epochs, epoch_ms=epoch_ms,
            )
            loop = EventLoop()
            plane.spawn(loop)
            if plane.repair is not None:
                plane.repair.spawn(loop)
            loop.run()
            lost += plane.lost_chunksets
            chunksets += sum(m.num_chunksets for m in contract.blobs.values())  # simlint: ok SIM007 integer chunkset counts, order-exact
        points.append(durability.ChurnPoint(
            churn_rate=float(rate),
            epochs=epochs,
            seeds=len(tuple(seeds)),
            chunksets=chunksets,
            lost=lost,
            analytic_no_repair=1.0 - (
                1.0 - durability.p_chunkset_loss_per_epoch(
                    layout.n, layout.k, float(rate)
                )
            ) ** epochs,
        ))
    return points
