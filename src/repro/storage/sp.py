"""Storage-provider node simulation (§2.4).

An SP stores assigned chunks, serves *paid* chunk reads, answers audit
challenges with Merkle possession proofs, audits peers (recording a
scoreboard and retaining proofs for two epochs — §4.1), and can misbehave
in every way the paper's adversary model contemplates:

* ``crashed``           — stops answering (availability fault)
* ``drop_fraction``     — silently deletes a fraction of assigned chunks
                          (the §5.4 "fake storage" adversary)
* ``corrupt``           — serves bit-flipped data (detected via commitments)
* ``lazy_auditor``      — reports '1' without verifying / without retaining
                          proofs (the audit-the-auditor target, Thm 2)
* ``latency_ms``        — per-request latency for hedging/straggler tests
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.core import commitments as cm
from repro.core.audit import Challenge, Scoreboard
from repro.core.contract import ShelbyContract


@dataclasses.dataclass
class SPBehavior:
    crashed: bool = False
    drop_fraction: float = 0.0
    corrupt: bool = False
    lazy_auditor: bool = False
    retain_proofs: bool = True
    latency_ms: float = 1.0


@dataclasses.dataclass(frozen=True)
class BackgroundSpec:
    """Per-SP budget for the background planes (§4 audits + §3.3 repair).

    Background work — audit proof generation, repair helper reads,
    re-dispersal writes — runs on the same event loop and the same disk
    slots as paid serving, but in a deferrable scheduling class:

    * ``slot_share`` — the max fraction of the SP's ``ServiceSpec.slots``
      background work may hold concurrently (at least 1 slot, so the
      planes always make progress).  Free slots beyond the share are left
      idle for foreground reads rather than soaked up by audits.
    * ``pace_ms``   — minimum gap between background operations a plane
      launches (token pacing: audits/repairs trickle instead of bursting).
    * ``priority``  — event-loop scheduling class (foreground is 0);
      queued foreground reads always wake ahead of background waiters.

    The net effect is the paper's "auditing without compromising
    performance": audits and repair brown out before serving does.
    """

    slot_share: float = 0.5
    pace_ms: float = 2.0
    priority: int = 1

    def max_slots(self, slots: int) -> int:
        """Concurrent disk slots background work may hold on this SP."""
        return max(1, min(slots, int(round(slots * self.slot_share))))


@dataclasses.dataclass
class ServiceSpec:
    """The SP's service model on the event engine (§2.4 serving).

    ``disk_ms_per_chunk`` is the per-chunk-read service time (``None``
    defers to ``SPBehavior.latency_ms`` so straggler injection keeps
    working); ``slots`` is how many chunk reads the SP's disks serve
    concurrently.  On a shared event loop the slots are a FIFO resource
    — a hot SP *queues* excess requests instead of answering every one
    after a flat latency, so tail latency under load comes from queueing
    theory, not from a constant.

    ``audit_ms_per_proof`` is the disk time to pull an audit sample and
    build its Merkle proof (``None`` = one chunk-read service interval);
    ``background`` budgets how audit/repair work shares the slots with
    paid reads (see :class:`BackgroundSpec`).
    """

    disk_ms_per_chunk: float | None = None
    slots: int = 4
    audit_ms_per_proof: float | None = None
    background: BackgroundSpec = dataclasses.field(default_factory=BackgroundSpec)


@dataclasses.dataclass(frozen=True)
class AuditProof:
    """What an auditee broadcasts (§4.1): the sample + its Merkle proof."""

    auditee: int
    blob_id: int
    chunkset: int
    chunk: int
    sample_index: int
    sample: bytes
    proof: cm.MerkleProof


class StorageProvider:
    def __init__(self, sp_id: int, behavior: SPBehavior | None = None, tree_cache: int = 256,
                 service: ServiceSpec | None = None):
        self.sp_id = sp_id
        self.behavior = behavior or SPBehavior()
        self.service = service or ServiceSpec()
        self._chunks: dict[tuple[int, int, int], np.ndarray] = {}
        # DAS share plane (core/extend2d.py): shares are stored alongside —
        # not inside — `_chunks`, so chunk audits and repair accounting are
        # untouched by the sampling regime.  Withheld coordinates keep their
        # bytes (the adversary HAS the data, it just won't serve it — the
        # case chunk-possession audits structurally cannot catch).
        self._das_shares: dict[tuple[int, int, int], np.ndarray] = {}
        self._das_proofs: dict[tuple[int, int, int], object] = {}
        self._das_withheld: set[tuple[int, int, int]] = set()
        self._trees: OrderedDict[tuple[int, int, int], cm.MerkleTree] = OrderedDict()
        self._tree_cache = tree_cache
        self._rng = np.random.default_rng(sp_id * 7919 + 13)
        # auditor state
        self.scoreboard = Scoreboard(owner=sp_id)
        self.retained: dict[tuple[int, int], AuditProof] = {}  # (auditee,pos)->proof
        # serving income, channel-accounted (§3.2): `earned_reads` is the
        # accrued micropayment balance (refunds held but not broadcast);
        # `settled_income` is what channel settlement actually realized.
        self.earned_reads = 0.0
        self.settled_income = 0.0

    # -- write path -------------------------------------------------------------
    def store_chunk(self, blob_id: int, chunkset: int, chunk: int, data: np.ndarray) -> bool:
        if self.behavior.crashed:
            return False
        key = (blob_id, chunkset, chunk)
        if self.behavior.drop_fraction > 0 and self._rng.random() < self.behavior.drop_fraction:
            # pretends to store (acks) but drops the bytes — §5.4 adversary
            return True
        self._chunks[key] = np.array(data, dtype=np.uint8)
        return True

    def has_chunk(self, blob_id: int, chunkset: int, chunk: int) -> bool:
        return (blob_id, chunkset, chunk) in self._chunks

    def stored_chunks(self) -> int:
        return len(self._chunks)

    def _tree(self, key: tuple[int, int, int]) -> cm.MerkleTree:
        if key in self._trees:
            self._trees.move_to_end(key)
            return self._trees[key]
        _, tree = cm.commit_chunk(self._chunks[key])
        self._trees[key] = tree
        if len(self._trees) > self._tree_cache:
            self._trees.popitem(last=False)
        return tree

    # -- read path (paid, §2.4) ----------------------------------------------------
    def service_ms(self) -> float:
        """Per-chunk disk service time (the event engine sleeps this long
        while holding one of the SP's `service.slots`)."""
        if self.service.disk_ms_per_chunk is not None:
            return self.service.disk_ms_per_chunk
        return self.behavior.latency_ms

    def audit_service_ms(self) -> float:
        """Disk time to answer one audit challenge (sample read + proof)."""
        if self.service.audit_ms_per_proof is not None:
            return self.service.audit_ms_per_proof
        return self.service_ms()

    def bg_slots(self) -> int:
        """Disk slots the background class may hold concurrently here."""
        return self.service.background.max_slots(self.service.slots)

    def serve_chunk(self, blob_id: int, chunkset: int, chunk: int):
        """Returns (chunk_bytes, latency_ms) or None.

        Payment is NOT taken here: the reader pays on delivery, after the
        chunk verified against its commitment (see `receive_payment`) — a
        crashed or corrupt SP earns nothing.
        """
        if self.behavior.crashed:
            return None
        key = (blob_id, chunkset, chunk)
        if key not in self._chunks:
            return None
        data = self._chunks[key]
        if self.behavior.corrupt:
            data = data.copy()
            data.reshape(-1)[0] ^= 0xFF
        return data, self.service_ms()

    # -- DAS share plane (paid tiny reads, core/extend2d.py) -----------------------
    def store_share(self, blob_id: int, row: int, col: int, share: np.ndarray,
                    proof) -> bool:
        """Accept one DAS share + its pre-built commitment proof."""
        if self.behavior.crashed:
            return False
        key = (blob_id, row, col)
        self._das_shares[key] = np.array(share, dtype=np.uint8)
        self._das_proofs[key] = proof
        return True

    def withhold_share(self, blob_id: int, row: int, col: int) -> None:
        """Go silent on one coordinate (data retained — withholding, not loss)."""
        self._das_withheld.add((blob_id, row, col))

    def stored_shares(self) -> int:
        return len(self._das_shares)

    def serve_share(self, blob_id: int, row: int, col: int):
        """Returns (share_bytes, proof, latency_ms) or None.

        Same pay-on-delivery contract as `serve_chunk`: the sampler pays
        only after the share verifies against the blob's DAS root, so a
        withholding or corrupting SP earns nothing from the sample — and
        the refusal itself IS the availability signal.
        """
        if self.behavior.crashed:
            return None
        key = (blob_id, row, col)
        if key not in self._das_shares or key in self._das_withheld:
            return None
        data = self._das_shares[key]
        if self.behavior.corrupt:
            data = data.copy()
            data.reshape(-1)[0] ^= 0xFF
        return data, self._das_proofs[key], self.service_ms()

    def serve_subchunks(self, blob_id: int, chunkset: int, chunk: int, ids: list[int]):
        """MSR repair helper read: only the requested sub-chunks (planes)."""
        if self.behavior.crashed:
            return None
        key = (blob_id, chunkset, chunk)
        if key not in self._chunks:
            return None
        return self._chunks[key][ids], self.service_ms()

    def receive_payment(self, amount: float) -> None:
        """A channel micropayment arrived (fresh refund signed over to us)."""
        self.earned_reads += amount

    def credit_settlement(self, amount: float) -> None:
        """An RPC->SP channel settled on-chain; income is now realized."""
        self.settled_income += amount

    # -- auditee role (§4.1) ---------------------------------------------------------
    def respond_challenge(self, ch: Challenge) -> AuditProof | None:
        if self.behavior.crashed:
            return None
        key = (ch.blob_id, ch.chunkset, ch.chunk)
        if key not in self._chunks:
            return None  # cannot fabricate a valid Merkle proof (§4.4)
        tree = self._tree(key)
        samples = cm.chunk_samples(self._chunks[key])
        idx = ch.sample % len(samples)
        return AuditProof(
            auditee=self.sp_id,
            blob_id=ch.blob_id,
            chunkset=ch.chunkset,
            chunk=ch.chunk,
            sample_index=idx,
            sample=samples[idx],
            proof=tree.prove(idx),
        )

    # -- auditor role (§4.1) ----------------------------------------------------------
    def audit_peer(self, ch: Challenge, proof: AuditProof | None, contract: ShelbyContract):
        """Verify a broadcast proof, record the outcome, retain the proof."""
        if self.behavior.lazy_auditor:
            # rational deviation candidate: blind '1', no verification
            self.scoreboard.record(ch.auditee, True)
            if self.behavior.retain_proofs and proof is not None:
                self._retain(ch.auditee, proof)
            return
        ok = (
            proof is not None
            and proof.sample_index == proof.proof.index
            and contract.verify_possession_proof(
                ch.blob_id, ch.chunkset, ch.chunk, proof.sample, proof.proof
            )
        )
        self.scoreboard.record(ch.auditee, ok)
        if ok and self.behavior.retain_proofs:
            self._retain(ch.auditee, proof)
        if proof is not None and not ok:
            # provably invalid proof -> submit slashing evidence (§4.2)
            contract.submit_evidence(
                self.sp_id, ch.auditee, ch.blob_id, ch.chunkset, ch.chunk,
                proof.sample, proof.proof,
            )

    def _retain(self, auditee: int, proof: AuditProof):
        # position = index of the just-recorded entry in THIS auditor's
        # scoreboard bit vector for the auditee — the same coordinate
        # `select_ata_entries` samples from `Scoreboard.ones()`, so
        # audit-the-auditor lookups land on the right proof even when the
        # auditee's history mixes successes and failures (failed audits
        # occupy a bit position but retain nothing)
        pos = len(self.scoreboard.bits[auditee]) - 1
        self.retained[(auditee, pos)] = proof

    def reproduce_proof(self, auditee: int, position: int):
        """Audit-the-auditor response (§4.2)."""
        p = self.retained.get((auditee, position))
        if p is None:
            return None
        return (p.blob_id, p.chunkset, p.chunk, p.sample, p.proof)

    # -- failure injection --------------------------------------------------------------
    def crash(self):
        self.behavior.crashed = True

    def decommission(self):
        """Graceful exit (announced departure finalized at an epoch
        boundary): the node powers off — same serving behavior as a crash,
        but the distinction matters upstream (a departure was re-dispersed
        proactively; a crash races the repair plane)."""
        self.behavior.crashed = True

    def recover(self):
        self.behavior.crashed = False

    def wipe(self):
        self._chunks.clear()
        self._trees.clear()
        self._das_shares.clear()
        self._das_proofs.clear()
        self._das_withheld.clear()
