"""Clay (Coupled-LAYer) codes — the paper's storage code (§3.3).

Faithful implementation of the construction of Vajha et al., FAST'18 (paper
ref [20]): an ``(n = k+m, k, d = n-1)`` MSR+MDS code obtained by coupling
``alpha = q^t`` layers of an ``[N, N-m]`` scalar MDS base code, where

    q = d - k + 1 = m,      t = ceil(n / q),      N = q * t,

with ``s = N - n`` *shortened* (virtual, all-zero) nodes when q does not
divide n.  Every node is a point ``(x, y)`` on a q x t grid; every sub-chunk
of a node is indexed by ``z in [q]^t``; vertex ``(x, y, z)`` is *unpaired*
("diagonal") iff ``z_y == x`` and otherwise is coupled with its partner
``(z_y, y, z(y -> x))`` through the invertible pairwise transform

    C_a = U_a + g*U_b          U_a = th*(C_a + g*C_b)
    C_b = g*U_a + U_b          U_b = th*(g*C_a + C_b)        th = inv(1+g^2)

(char-2 field; a = smaller-x member of the pair; g = GAMMA).  The defining
property: for every plane ``z`` the *uncoupled* symbols across all N nodes
form a codeword of the base MDS code.

One generic *plane-schedule* engine (`_solve`) performs encoding (unknowns =
parity nodes), arbitrary erasure decoding (unknowns = erased nodes, any
``<= m``), exploiting the intersection-score (IS) ordering of planes; a
dedicated `repair` implements the bandwidth-optimal single-node repair that
reads only ``alpha/q`` sub-chunks from each of the ``d = n-1`` helpers —
the MSR property responsible for the paper's "~60% less repair bandwidth
than Reed-Solomon" claim (we measure exact bytes in
``benchmarks/repair_bandwidth.py``).

Storage layout: a chunk is ``(alpha, w)`` bytes; a codeword is ``(n, alpha, w)``.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools

import numpy as np

from repro.core import gf
from repro.core.rs import MDSCode

GAMMA = 2  # gamma^2 != 1  ->  1 + gamma^2 = 5 != 0 in GF(256)
_THETA = int(gf.inv(np.uint8(1 ^ gf.pow_(GAMMA, 2))))  # inv(1 + g^2)
_ONE_PLUS_G2 = 1 ^ gf.pow_(GAMMA, 2)
_INV_GAMMA = int(gf.inv(np.uint8(GAMMA)))


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class ClayCode:
    """(n=k+m, k, d=n-1) Clay code over GF(2^8)."""

    k: int
    m: int

    def __post_init__(self):
        assert self.k >= 1 and self.m >= 1

    # -- derived parameters ---------------------------------------------------
    @property
    def n(self) -> int:
        return self.k + self.m

    @property
    def d(self) -> int:
        return self.n - 1

    @property
    def q(self) -> int:
        return self.m

    @functools.cached_property
    def t(self) -> int:
        return _ceil_div(self.n, self.q)

    @property
    def N(self) -> int:  # extended (padded) code length
        return self.q * self.t

    @property
    def num_virtual(self) -> int:
        return self.N - self.n

    @functools.cached_property
    def alpha(self) -> int:  # sub-packetization
        return self.q**self.t

    @functools.cached_property
    def base(self) -> MDSCode:
        return MDSCode(n=self.N, k=self.N - self.m)

    # -- node indexing --------------------------------------------------------
    # Extended flat index f = y*q + x.  Real chunks occupy:
    #   data chunks   0..k-1        -> flats 0..k-1
    #   virtual zeros               -> flats k..K'-1   (K' = N - m)
    #   parity chunks k..n-1        -> flats K'..N-1
    @functools.cached_property
    def real_to_flat(self) -> tuple[int, ...]:
        kprime = self.N - self.m
        return tuple(range(self.k)) + tuple(range(kprime, self.N))

    @functools.cached_property
    def virtual_flats(self) -> tuple[int, ...]:
        return tuple(range(self.k, self.N - self.m))

    def _xy(self, flat: int) -> tuple[int, int]:
        return flat % self.q, flat // self.q

    def _flat(self, x: int, y: int) -> int:
        return y * self.q + x

    # -- z-plane utilities ----------------------------------------------------
    @functools.cached_property
    def planes(self) -> list[tuple[int, ...]]:
        return [tuple(z) for z in itertools.product(range(self.q), repeat=self.t)]

    @functools.cached_property
    def plane_index(self) -> dict[tuple[int, ...], int]:
        return {z: i for i, z in enumerate(self.planes)}

    def _partner(self, x: int, y: int, z: tuple[int, ...]):
        """Partner vertex of (x,y,z) or None if diagonal (z_y == x)."""
        if z[y] == x:
            return None
        zp = list(z)
        zp[y] = x
        return z[y], y, tuple(zp)

    def _pair_order(self, x_a: int, x_b: int) -> bool:
        """True if vertex with x_a is the 'a' (smaller-x) member."""
        return x_a < x_b

    @staticmethod
    def _u_from_pair(c_self, c_partner, self_is_a: bool):
        """Uncoupled value of `self` from both coupled values."""
        if self_is_a:
            return gf.mul(_THETA, c_self ^ gf.mul(GAMMA, c_partner))
        return gf.mul(_THETA, gf.mul(GAMMA, c_partner) ^ c_self)

    @staticmethod
    def _c_from_pair_u(u_self, u_partner, self_is_a: bool):
        """Coupled value of `self` from both uncoupled values."""
        if self_is_a:
            return u_self ^ gf.mul(GAMMA, u_partner)
        return gf.mul(GAMMA, u_partner) ^ u_self

    @staticmethod
    def _c_from_own_u_and_partner_c(u_self, c_partner):
        """C_self = (1+g^2)*U_self + g*C_partner (both orderings)."""
        return gf.mul(_ONE_PLUS_G2, u_self) ^ gf.mul(GAMMA, c_partner)

    # -- the generic plane-schedule engine -------------------------------------
    def _is_score(self, z: tuple[int, ...], unknown: frozenset[int]) -> int:
        return sum(1 for y in range(self.t) if self._flat(z[y], y) in unknown)

    @functools.lru_cache(maxsize=64)
    def _decode_mats(self, unknown: tuple[int, ...]) -> tuple[np.ndarray, tuple[int, ...]]:
        """(R, known_used): per-plane solver U_unknown = R @ U_known_used."""
        e = len(unknown)
        known = tuple(i for i in range(self.N) if i not in set(unknown))
        h = self.base.parity_check[:e, :]
        he = h[:, list(unknown)]
        hk = h[:, list(known)]
        r = gf.matmul_np(gf.mat_inv(he), hk)
        return r, known

    def _solve(
        self, c: np.ndarray, unknown_flats: frozenset[int], matmul=None
    ) -> np.ndarray:
        """Fill in coupled values of `unknown_flats` given all other nodes.

        c: (N, alpha, w) uint8 with known nodes' coupled values populated
        (virtual nodes are zero).  Returns c with unknowns filled.
        Precondition: len(unknown_flats) <= m.

        `matmul` swaps the GF backend for the per-group linear solves
        ((M,K) x (K,N) -> (M,N) over GF(2^8)); defaults to the numpy
        table path, and accepts `repro.kernels.ops.gf_matmul_np` to route
        the wide payload product through the Pallas kernel.
        """
        matmul = matmul or gf.matmul_np
        assert len(unknown_flats) <= self.m, "more erasures than parities"
        if not unknown_flats:
            return c
        q, t, alpha = self.q, self.t, self.alpha
        c = c.copy()
        u = np.zeros_like(c)  # uncoupled values, filled lazily
        have_u = np.zeros((self.N, alpha), dtype=bool)

        r_mat, known_used = self._decode_mats(tuple(sorted(unknown_flats)))
        # group planes by intersection score, ascending
        groups: dict[int, list[tuple[int, ...]]] = {}
        for z in self.planes:
            groups.setdefault(self._is_score(z, unknown_flats), []).append(z)

        for score in sorted(groups):
            zs = groups[score]
            # 1) uncoupled values of all KNOWN nodes in these planes
            for z in zs:
                zi = self.plane_index[z]
                for f in range(self.N):
                    if f in unknown_flats:
                        continue
                    x, y = self._xy(f)
                    p = self._partner(x, y, z)
                    if p is None:
                        u[f, zi] = c[f, zi]
                    else:
                        px, py, pz = p
                        pf = self._flat(px, py)
                        # partner C is known: either a known node, or an
                        # unknown node whose plane has IS score-1 (already
                        # computed in a previous group).
                        u[f, zi] = self._u_from_pair(
                            c[f, zi], c[pf, self.plane_index[pz]], self._pair_order(x, px)
                        )
                    have_u[f, zi] = True
            # 2) per plane, solve the base code for unknown U
            #    (batch all planes of the group through one GF matmul)
            zis = [self.plane_index[z] for z in zs]
            kn = u[list(known_used)][:, zis]  # (K', G, w)
            kn2 = kn.reshape(len(known_used), -1)
            rec = matmul(r_mat, kn2).reshape(len(unknown_flats), len(zis), -1)
            for row, f in enumerate(sorted(unknown_flats)):
                for gi, zi in enumerate(zis):
                    u[f, zi] = rec[row, gi]
                    have_u[f, zi] = True
            # 3) convert unknown nodes' U -> C
            for z in zs:
                zi = self.plane_index[z]
                for f in sorted(unknown_flats):
                    x, y = self._xy(f)
                    p = self._partner(x, y, z)
                    if p is None:
                        c[f, zi] = u[f, zi]
                        continue
                    px, py, pz = p
                    pf = self._flat(px, py)
                    if pf in unknown_flats:
                        # partner plane is in the same IS group: use both U's
                        c[f, zi] = self._c_from_pair_u(
                            u[f, zi], u[pf, self.plane_index[pz]], self._pair_order(x, px)
                        )
                    else:
                        c[f, zi] = self._c_from_own_u_and_partner_c(
                            u[f, zi], c[pf, self.plane_index[pz]]
                        )
        return c

    # -- public API -------------------------------------------------------------
    def _blank(self, w: int) -> np.ndarray:
        return np.zeros((self.N, self.alpha, w), dtype=np.uint8)

    def encode(self, data: np.ndarray) -> np.ndarray:
        """data: (k, alpha, w) -> full codeword (n, alpha, w)."""
        data = np.asarray(data, dtype=np.uint8)
        assert data.shape[:2] == (self.k, self.alpha), data.shape
        c = self._blank(data.shape[2])
        c[: self.k] = data
        unknown = frozenset(self.real_to_flat[self.k :])
        c = self._solve(c, unknown)
        return c[list(self.real_to_flat)]

    def decode(self, shards: dict[int, np.ndarray]) -> np.ndarray:
        """Reconstruct all n chunks from any >= k of them (MDS property)."""
        if len(shards) < self.k:
            raise ValueError(f"need >= k={self.k} shards, got {len(shards)}")
        w = next(iter(shards.values())).shape[-1]
        c = self._blank(w)
        present = set(shards)
        for real, flat in enumerate(self.real_to_flat):
            if real in present:
                c[flat] = shards[real]
        erased = [self.real_to_flat[i] for i in range(self.n) if i not in present]
        # keep only m unknowns: with > k shards present this is automatic
        c = self._solve(c, frozenset(erased))
        return c[list(self.real_to_flat)]

    def reconstruct_data(self, shards: dict[int, np.ndarray]) -> np.ndarray:
        return self.decode(shards)[: self.k]

    # -- batched decode (§3.5 erasure-coding acceleration) -------------------------
    def decode_batch(
        self, shard_sets: list[dict[int, np.ndarray]], *, matmul=None
    ) -> list[np.ndarray]:
        """Decode many chunksets' shard sets through few wide GF calls.

        Chunksets sharing an *erasure pattern* are stacked along the byte
        (w) axis and pushed through the plane-schedule engine once, so each
        IS-group linear solve becomes a single (e, K') x (K', G*B*w) GF
        matmul instead of B narrow ones — wide enough to amortize a Pallas
        `gf_matmul` dispatch (pass ``matmul=repro.kernels.ops.gf_matmul_np``).
        Byte-identical to calling `decode` per chunkset.
        """
        if not shard_sets:
            return []
        out: list[np.ndarray | None] = [None] * len(shard_sets)
        groups: dict[tuple[int, ...], list[int]] = {}
        for i, shards in enumerate(shard_sets):
            if len(shards) < self.k:
                raise ValueError(f"need >= k={self.k} shards, got {len(shards)}")
            erased = tuple(
                self.real_to_flat[r] for r in range(self.n) if r not in shards
            )
            groups.setdefault(erased, []).append(i)
        for erased, idxs in groups.items():
            w = next(iter(shard_sets[idxs[0]].values())).shape[-1]
            c = np.zeros((self.N, self.alpha, w * len(idxs)), dtype=np.uint8)
            for b, i in enumerate(idxs):
                for real, shard in shard_sets[i].items():
                    assert shard.shape == (self.alpha, w), shard.shape
                    c[self.real_to_flat[real], :, b * w : (b + 1) * w] = shard
            c = self._solve(c, frozenset(erased), matmul=matmul)
            full = c[list(self.real_to_flat)]
            for b, i in enumerate(idxs):
                out[i] = np.ascontiguousarray(full[:, :, b * w : (b + 1) * w])
        return out

    def reconstruct_data_batch(
        self, shard_sets: list[dict[int, np.ndarray]], *, matmul=None
    ) -> list[np.ndarray]:
        return [cw[: self.k] for cw in self.decode_batch(shard_sets, matmul=matmul)]

    # -- bandwidth-optimal single-node repair -------------------------------------
    def repair_planes(self, failed_real: int) -> list[tuple[int, ...]]:
        x0, y0 = self._xy(self.real_to_flat[failed_real])
        return [z for z in self.planes if z[y0] == x0]

    def repair_subchunk_ids(self, failed_real: int) -> list[int]:
        """Sub-chunk indices every helper must transmit (alpha/q of them)."""
        return [self.plane_index[z] for z in self.repair_planes(failed_real)]

    def repair_bandwidth_bytes(self, chunk_bytes: int) -> int:
        """Helper bytes read to repair ONE chunk (MSR optimum, d = n-1)."""
        return (self.n - 1) * (chunk_bytes // self.q)

    def repair(
        self,
        failed_real: int,
        helper_subchunks: dict[int, np.ndarray],
    ) -> np.ndarray:
        """Repair chunk `failed_real` from helpers' repair-plane sub-chunks.

        helper_subchunks: {real_idx: (alpha/q, w)} — ONLY the sub-chunks whose
        plane z satisfies z_{y0} == x0, in `repair_subchunk_ids` order.
        Requires all d = n-1 helpers (optimal-bandwidth regime); for fewer
        helpers fall back to `decode` (MDS path), as §3.3 prescribes.
        """
        f_flat = self.real_to_flat[failed_real]
        x0, y0 = self._xy(f_flat)
        rplanes = self.repair_planes(failed_real)
        if set(helper_subchunks) != set(range(self.n)) - {failed_real}:
            raise ValueError("optimal repair needs all n-1 helpers")
        w = next(iter(helper_subchunks.values())).shape[-1]

        # Coupled values on repair planes, indexed by extended flat id and
        # *local* repair-plane position (virtual nodes: zeros).
        rp_index = {z: i for i, z in enumerate(rplanes)}
        c_rp = np.zeros((self.N, len(rplanes), w), dtype=np.uint8)
        for real, sub in helper_subchunks.items():
            assert sub.shape == (len(rplanes), w), sub.shape
            c_rp[self.real_to_flat[real]] = sub

        # Column-y0 nodes hold the per-plane unknown uncoupled values.
        col_nodes = [self._flat(x, y0) for x in range(self.q)]
        col_set = set(col_nodes)
        known_nodes = [f for f in range(self.N) if f not in col_set]

        # U of non-column nodes: partners stay inside the repair-plane set.
        u_rp = np.zeros_like(c_rp)
        for z in rplanes:
            ri = rp_index[z]
            for f in known_nodes:
                x, y = self._xy(f)
                p = self._partner(x, y, z)
                if p is None:
                    u_rp[f, ri] = c_rp[f, ri]
                else:
                    px, py, pz = p
                    u_rp[f, ri] = self._u_from_pair(
                        c_rp[f, ri],
                        c_rp[self._flat(px, py), rp_index[pz]],
                        self._pair_order(x, px),
                    )

        # Solve the q unknown column-U values per plane with the base code.
        e = len(col_nodes)
        h = self.base.parity_check[:e, :]
        r_mat = gf.matmul_np(gf.mat_inv(h[:, col_nodes]), h[:, known_nodes])
        kn = u_rp[known_nodes].reshape(len(known_nodes), -1)
        sol = gf.matmul_np(r_mat, kn).reshape(e, len(rplanes), w)
        u_col = {f: sol[i] for i, f in enumerate(col_nodes)}

        # Assemble the failed chunk.
        out = np.zeros((self.alpha, w), dtype=np.uint8)
        for z in self.planes:
            zi = self.plane_index[z]
            if z[y0] == x0:
                # repair plane: failed vertex is diagonal -> C = U
                out[zi] = u_col[f_flat][rp_index[z]]
            else:
                # paired with helper vertex p in a repair plane
                x1 = z[y0]
                pz = list(z)
                pz[y0] = x0
                pz = tuple(pz)
                pf = self._flat(x1, y0)
                c_p = c_rp[pf, rp_index[pz]]
                u_p = u_col[pf][rp_index[pz]]
                if self._pair_order(x1, x0):
                    # partner p is 'a', failed vertex is 'b':
                    # U_b = (C_a + U_a)/g ;  C_b = g*U_a + U_b
                    u_b = gf.mul(_INV_GAMMA, c_p ^ u_p)
                    out[zi] = gf.mul(GAMMA, u_p) ^ u_b
                else:
                    # partner p is 'b', failed vertex is 'a':
                    # U_a = (C_b + U_b)/g ;  C_a = U_a + g*U_b
                    u_a = gf.mul(_INV_GAMMA, c_p ^ u_p)
                    out[zi] = u_a ^ gf.mul(GAMMA, u_p)
        return out
