"""Chunk placement (§2.5): randomized, failure-domain-aware assignment.

"The smart contract randomly assigns Chunks to SPs" — with the Appendix-A
availability model in mind we spread the n chunks of each chunkset across as
many distinct (datacenter, rack) failure domains as the SP set allows, and we
randomize *within* that constraint using the contract's verifiable
randomness (so no SP controls which data it can censor — Appendix A).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from collections import Counter

import numpy as np


@dataclasses.dataclass(frozen=True)
class SPInfo:
    sp_id: int
    stake: float
    dc: str = "dc0"
    rack: str = "r0"
    capacity_chunks: int = 1 << 30


def _rng(seed: bytes, *tags) -> np.random.Generator:
    h = hashlib.sha256(seed + b"|" + b"|".join(str(t).encode() for t in tags)).digest()
    return np.random.default_rng(np.frombuffer(h[:8], dtype=np.uint64)[0])


def assign_chunkset(
    seed: bytes,
    blob_id: int,
    chunkset: int,
    sps: list[SPInfo],
    n: int,
    used: dict[int, int] | None = None,
) -> list[int]:
    """Assign the n chunks of one chunkset to n distinct SPs.

    Greedy spread: iterate domains (dc, then rack) round-robin in a seeded
    random order, skipping SPs that are at capacity.  Raises if fewer than n
    SPs have room (the contract rejects the write — §2.5).
    """
    used = used or {}
    rng = _rng(seed, blob_id, chunkset)
    eligible = [s for s in sps if used.get(s.sp_id, 0) < s.capacity_chunks]
    if len(eligible) < n:
        raise ValueError(f"placement needs {n} SPs, only {len(eligible)} eligible")

    # two-level spread: round-robin across DCs first, racks within a DC
    by_dc: dict[str, list[SPInfo]] = {}
    for s in eligible:
        by_dc.setdefault(s.dc, []).append(s)
    dcs = list(by_dc)
    rng.shuffle(dcs)
    for dc in dcs:
        # within a DC, interleave racks (randomized) for rack-level spread
        by_rack: dict[str, list[SPInfo]] = {}
        for s in by_dc[dc]:
            by_rack.setdefault(s.rack, []).append(s)
        racks = list(by_rack)
        rng.shuffle(racks)
        for r in racks:
            rng.shuffle(by_rack[r])
        ordered = []
        for layer in itertools.count():
            got = False
            for r in racks:
                if layer < len(by_rack[r]):
                    ordered.append(by_rack[r][layer])
                    got = True
            if not got:
                break
        by_dc[dc] = ordered

    picked: list[int] = []
    for layer in itertools.count():
        progressed = False
        for dc in dcs:
            if len(picked) == n:
                return picked
            if layer < len(by_dc[dc]):
                picked.append(by_dc[dc][layer].sp_id)
                progressed = True
        if not progressed:
            break
    assert len(picked) == n
    return picked


def replacement_sp(
    seed: bytes,
    blob_id: int,
    chunkset: int,
    chunk: int,
    candidates: list[SPInfo],
    holders: list[SPInfo],
) -> int | None:
    """Pick ONE replacement SP for a chunk displaced by churn.

    Same failure-domain objective as :func:`assign_chunkset`, applied
    incrementally: among `candidates` (already filtered to live non-holders)
    prefer SPs whose datacenter — then rack — holds the fewest of the
    chunkset's surviving chunks, breaking ties with the contract's seeded
    randomness so no SP controls where displaced data lands.  Returns
    ``None`` when no candidate exists (the chunk stays on its dead SP until
    the fleet grows — the "unplaced" backlog).
    """
    if not candidates:
        return None
    rng = _rng(seed, b"reassign", blob_id, chunkset, chunk)
    dc_load = Counter(h.dc for h in holders)
    rack_load = Counter((h.dc, h.rack) for h in holders)
    order = [int(i) for i in rng.permutation(len(candidates))]
    best = min(
        order,
        key=lambda i: (dc_load[candidates[i].dc],
                       rack_load[(candidates[i].dc, candidates[i].rack)]),
    )
    return candidates[best].sp_id
