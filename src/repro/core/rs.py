"""Systematic MDS base code over GF(2^8) with a Vandermonde parity check.

This is both (a) the Reed-Solomon baseline that the paper compares Clay codes
against (repair bandwidth benchmark) and (b) the per-plane base code of the
coupled-layer (Clay) construction in ``clay.py``.

Code definition: an ``[n, k]`` code with ``m = n - k`` parity symbols and a
parity-check matrix ``H`` (m x n).  A vector ``c`` (length n, per byte column)
is a codeword iff ``H @ c = 0`` over GF(2^8).  ``H`` is Vandermonde on distinct
nonzero points, so every ``m x m`` column submatrix (of the full row set) is
invertible -> the code is MDS: any ``k`` symbols determine the rest.

The *data path* (multiplying a small decode/encode matrix into wide byte
arrays) is delegated to ``repro.kernels.gf_matmul`` (Pallas) or to the pure
numpy path — selectable so the coordination layer never needs a device.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import gf


@dataclasses.dataclass(frozen=True)
class MDSCode:
    n: int
    k: int

    @property
    def m(self) -> int:
        return self.n - self.k

    @functools.cached_property
    def parity_check(self) -> np.ndarray:
        """H: (m, n) Vandermonde parity-check matrix."""
        return gf.vandermonde(self.m, self.n)

    # -- encode -------------------------------------------------------------
    @functools.cached_property
    def encode_matrix(self) -> np.ndarray:
        """(m, k) matrix P with parity = P @ data (systematic encoding).

        From H = [Hd | Hp] (split at k): Hd @ d + Hp @ p = 0
        -> p = inv(Hp) @ Hd @ d.
        """
        h = self.parity_check
        hd, hp = h[:, : self.k], h[:, self.k :]
        return gf.matmul_np(gf.mat_inv(hp), hd)

    def encode(self, data: np.ndarray, matmul=gf.matmul_np) -> np.ndarray:
        """data: (k, nbytes) -> codeword (n, nbytes), systematic."""
        data = np.asarray(data, dtype=np.uint8)
        assert data.shape[0] == self.k, (data.shape, self.k)
        parity = matmul(self.encode_matrix, data)
        return np.concatenate([data, np.asarray(parity, np.uint8)], axis=0)

    # -- erasure decode -----------------------------------------------------
    def decode_matrix(self, known: tuple[int, ...]) -> tuple[np.ndarray, tuple[int, ...]]:
        """Solve for the erased symbols from any >= k known symbols.

        Returns (R, erased) with erased values = R @ known_values, where
        ``known`` lists the available symbol indices (uses the first k).
        """
        known = tuple(sorted(known))[: self.k]
        if len(known) < self.k:
            raise ValueError(f"need >= k={self.k} known symbols, got {len(known)}")
        erased = tuple(i for i in range(self.n) if i not in set(known))
        e = len(erased)
        if e == 0:
            return np.zeros((0, self.k), np.uint8), erased
        h = self.parity_check[:e, :]  # e rows suffice (row-prefix Vandermonde)
        he = h[:, list(erased)]  # (e, e) invertible (MDS)
        hk = h[:, list(known)]  # (e, k)
        r = gf.matmul_np(gf.mat_inv(he), hk)  # (e, k)
        return r, erased

    def decode(
        self,
        shards: dict[int, np.ndarray],
        matmul=gf.matmul_np,
    ) -> np.ndarray:
        """Reconstruct full codeword (n, nbytes) from any k of n shards."""
        known = tuple(sorted(shards))[: self.k]
        r, erased = self.decode_matrix(known)
        nbytes = next(iter(shards.values())).shape[-1]
        out = np.zeros((self.n, nbytes), dtype=np.uint8)
        for i in known:
            out[i] = shards[i]
        if erased:
            stacked = np.stack([shards[i] for i in known], axis=0)
            rec = np.asarray(matmul(r, stacked), np.uint8)
            for row, i in enumerate(erased):
                out[i] = rec[row]
        return out

    def reconstruct_data(self, shards: dict[int, np.ndarray], matmul=gf.matmul_np) -> np.ndarray:
        return self.decode(shards, matmul=matmul)[: self.k]

    # -- repair (RS has no better option than full decode) -------------------
    def repair_bandwidth_bytes(self, shard_bytes: int) -> int:
        """Bytes read from helpers to repair ONE lost shard (= k full shards)."""
        return self.k * shard_bytes
