"""Durability & availability closed forms (paper Appendix A).

Reproduces the paper's worked example exactly:

    P(data loss) ~= (16 * 0.50) * C(15,6) * (0.50 * (24+12)/8760)^6
                 ~= 3.01e-12                    (11+ nines durability)

    P(unavail)   ~= P(loss) + 30/525600 + P(<3 of 5 DCs online)
                 ~= 1.35e-4                     (~3 nines availability)

plus general-form functions used by the repair planner and the SP failure
injector (drive/host/rack/DC failure rates from the appendix).
"""
from __future__ import annotations

import dataclasses
import math

HOURS_PER_YEAR = 8760
MINUTES_PER_YEAR = 525_600


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Appendix-A hardware failure assumptions."""

    drive_afr: float = 0.02  # 2 %/yr
    latent_sector_lifetime: float = 0.0345  # 3.45 % of drives, lifetime
    host_afr: float = 0.03  # 1-5 %/yr
    rack_afr: float = 0.05  # availability only
    dc_afr: float = 0.02  # availability only
    systemic_events_per_year: float = 1.0
    systemic_mttr_minutes: float = 30.0


@dataclasses.dataclass(frozen=True)
class DurabilityParams:
    """The appendix's worked example for a (10, 6) code."""

    k: int = 10
    m: int = 6
    chunk_loss_prob: float = 0.50  # "nodes have a (very high) 50% likelihood"
    mttd_hours: float = 24.0
    mttr_hours: float = 12.0

    @property
    def n(self) -> int:
        return self.k + self.m


def p_data_loss(p: DurabilityParams) -> float:
    """Appendix A: first trigger * P(m more of remaining n-1 inside T_crit)."""
    t_crit = (p.mttd_hours + p.mttr_hours) / HOURS_PER_YEAR
    per_node = p.chunk_loss_prob * t_crit
    trigger = p.n * p.chunk_loss_prob
    return trigger * math.comb(p.n - 1, p.m) * per_node**p.m


def durability_nines(p: DurabilityParams) -> float:
    return -math.log10(p_data_loss(p))


def p_fewer_than_k_dcs(num_dcs: int = 5, dc_uptime: float = 0.98, need: int = 3) -> float:
    """P(< `need` of `num_dcs` online), iid uptime."""
    p_ok = 0.0
    for up in range(need, num_dcs + 1):
        p_ok += math.comb(num_dcs, up) * dc_uptime**up * (1 - dc_uptime) ** (num_dcs - up)
    return 1.0 - p_ok


def p_unavailable(
    p: DurabilityParams,
    num_dcs: int = 5,
    dc_uptime: float = 0.98,
    need_dcs: int = 3,
    systemic_minutes: float = 30.0,
) -> float:
    return (
        p_data_loss(p)
        + systemic_minutes / MINUTES_PER_YEAR
        + p_fewer_than_k_dcs(num_dcs, dc_uptime, need_dcs)
    )


def availability(p: DurabilityParams, **kw) -> float:
    return 1.0 - p_unavailable(p, **kw)


# ---------------------------------------------------------------------------
# churn durability: measured series + analytic per-epoch reference
# ---------------------------------------------------------------------------
def p_chunkset_loss_per_epoch(n: int, k: int, p_node_loss: float) -> float:
    """Analytic per-epoch chunkset-loss probability under iid node churn.

    A chunkset with an (n, k) code dies in an epoch when MORE than n-k of
    its n holders are lost before repair: the binomial tail
    ``sum_{j=m+1..n} C(n,j) p^j (1-p)^(n-j)`` with m = n-k.  This is the
    no-repair bound the *measured* series (a churned simulation with the
    re-dispersal backlog racing the failures) is compared against.
    """
    if not 0.0 <= p_node_loss <= 1.0:
        raise ValueError("p_node_loss must be a probability")
    m = n - k
    return sum(
        math.comb(n, j) * p_node_loss**j * (1.0 - p_node_loss) ** (n - j)
        for j in range(m + 1, n + 1)
    )


@dataclasses.dataclass(frozen=True)
class ChurnPoint:
    """One measured point of the lost-chunksets-vs-churn-rate curve.

    Produced by running a seeded churn process against a real simulated
    world (``repro.storage.membership.measure_durability``) and *counting*
    chunksets that fell below k live holders — not by evaluating a formula.
    ``analytic_no_repair`` carries the matching closed-form tail for the
    same (n, k, rate) so benchmarks can plot measured vs analytic.
    """

    churn_rate: float  # per-SP per-epoch loss probability driven
    epochs: int
    seeds: int
    chunksets: int  # total chunksets exposed across all runs
    lost: int  # chunksets measured below k live holders
    analytic_no_repair: float = 0.0

    @property
    def loss_probability(self) -> float:
        return self.lost / self.chunksets if self.chunksets else 0.0


def measured_loss_series(points: list[ChurnPoint]) -> dict:
    """JSON-shaped summary of a measured churn sweep (benchmark emission)."""
    return {
        "churn_rates": [p.churn_rate for p in points],
        "loss_probability": [p.loss_probability for p in points],
        "lost": [p.lost for p in points],
        "chunksets": [p.chunksets for p in points],
        "analytic_no_repair": [p.analytic_no_repair for p in points],
    }
