"""Closed-form economics from §5 ("Economic Opportunity") + §5.4.

Every inequality the paper states as a design constraint is a function here,
so the parameter calibration is executable and testable against the paper's
own numerical examples:

* Lemma 1 bound:            p_a >= c_s / c_r
* AWS-number instantiation: p_a >= 0.0076 / day (k = 5 helper reads)
* on-chain detection:       P_Sa >= 1 - (1-pf)^((1-(1-pf)^2) * C)   (> 0.63
                            at pf = 0.1, C = 50)
* audit-the-auditor:        S_ata >= rwd_au / (p_ata * eps)
* fee normalization:        rwd_st + n_a * rwd_au = W
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Reference costs (defaults = the paper's AWS S3 numbers, §5.4)."""

    storage_per_mb_day: float = 0.023 / 1024 / 30  # $0.023/GB/mo ~ 7.7e-7/MB/day
    read_per_mb: float = 0.02 / 1024  # $0.02/GB ~ 2e-5/MB
    k_reads_for_repair: int = 5  # ">= k = 5 distinct Chunks to read"


def min_audit_probability(costs: CostModel, chunk_mb: float = 1.0) -> float:
    """Lemma 1 / §5.4: smallest per-day audit probability making
    delete-and-refetch irrational:  p_a >= c_s / c_r."""
    c_s = costs.storage_per_mb_day * chunk_mb
    c_r = costs.k_reads_for_repair * costs.read_per_mb * chunk_mb
    return c_s / c_r


def retrieval_strategy_cost(p_a: float, costs: CostModel, chunk_mb: float = 1.0) -> float:
    """Expected per-day cost of the deviant delete-and-refetch strategy."""
    return p_a * costs.k_reads_for_repair * costs.read_per_mb * chunk_mb


def storage_strategy_cost(costs: CostModel, chunk_mb: float = 1.0) -> float:
    return costs.storage_per_mb_day * chunk_mb


def expected_onchain_samples(prct_fake: float, C: int) -> float:
    """§5.4(3): expected on-chain sample size for score = 1 - prct_fake."""
    score = 1.0 - prct_fake
    return (1.0 - score**2) * C


def detection_probability(prct_fake: float, C: int) -> float:
    """§5.4(3): P_Sa >= 1 - (1 - pf)^samples  (sampling w/o replacement bound)."""
    if prct_fake <= 0:
        return 0.0
    samples = expected_onchain_samples(prct_fake, C)
    return 1.0 - (1.0 - prct_fake) ** samples


def fake_storage_slashing_bound(
    p_a: float, rwd_st: float, prct_fake: float, total_committed: float, C: int
) -> float:
    """Minimum slashing penalty S_a so faking `prct_fake` is irrational:
    P_Sa * S_a > (1 - p_a) * rwd_st * prct_fake * total_committed."""
    p_det = detection_probability(prct_fake, C)
    rhs = (1.0 - p_a) * rwd_st * prct_fake * total_committed
    return rhs / max(p_det, 1e-12)


def min_ata_slashing(rwd_au: float, p_ata: float, eps: float) -> float:
    """§4.4 / §5.4(4): S_ata >= rwd_au / (p_ata * eps)."""
    return rwd_au / (p_ata * eps)


def fee_split(W: float, n_a: float, rwd_au: float) -> float:
    """§5.1: rwd_st from  rwd_st + n_a * rwd_au = W  (per GB per month)."""
    rwd_st = W - n_a * rwd_au
    if rwd_st < 0:
        raise ValueError("audit rewards exceed the storage fee")
    return rwd_st


def audits_per_gb_month(
    p_a_per_epoch: float, chunks_per_gb: float, auditors_per_audit: int, epochs_per_month: float
) -> float:
    """§5.1: n_a = (p_a * chunks/GB) * auditors-per-audit * epochs/month."""
    return p_a_per_epoch * chunks_per_gb * auditors_per_audit * epochs_per_month
