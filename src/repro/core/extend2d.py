"""2-D Reed-Solomon extension for data-availability sampling (DAS).

The DA_ERASURE blueprint (SNIPPETS.md): a k x k *data square* of
fixed-size shares is extended along both axes with the systematic
``[2k, k]`` RS code of ``core/rs.py`` (extension factor 2 per axis),
producing a 2k x 2k *extended square* in which

* every **row** is a codeword of the row code,
* every **column** is a codeword of the column code, and
* any k complete rows (or any k complete columns) determine the whole
  square — so a data-withholding adversary must hide more than a
  (1 - 1/4)-ish fraction of shares before reconstruction fails, and
  hiding ANY share is detectable by uniform sampling.

Commitments bind the square for light clients: one Merkle tree per row
over its 2k share byte-strings, one per column, and a *DAS root* over
the 2*side concatenated row+column roots.  A :class:`ShareProof` carries
the share's path inside its row (or column) tree plus that root's path
inside the DAS tree, so a sampler holding only ``das_root`` verifies a
single share in O(log side) hashes — the proof-carrying tiny read.

The GF data path is the same pluggable matmul the Clay decode uses:
pure numpy (`gf.matmul_np`) or the Pallas ``gf_matmul`` kernel via
``repro.kernels.ops.gf_matmul_np``.  :meth:`Extend2D.extend_batch`
deliberately concatenates MANY squares along the byte axis so thousands
of per-share GF ops become ONE small-and-wide (k x k) @ (k x B*k*S)
kernel call — the opposite kernel regime from the few-and-large
chunkset decodes.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import commitments as cm
from repro.core import gf
from repro.core.rs import MDSCode


def detection_probability(q: float, s: int) -> float:
    """P[>= 1 of s uniform with-replacement samples hits a withheld share]
    when a fraction ``q`` of the extended square is withheld."""
    return 1.0 - (1.0 - q) ** s


@dataclasses.dataclass(frozen=True)
class ShareProof:
    """Proof that share (row, col) belongs to a square with a given DAS root.

    ``axis`` names the tree the leaf path runs through ("row" or "col");
    ``leaf_path`` authenticates the share inside that axis tree (whose
    root is ``axis_root``), and ``root_path`` authenticates ``axis_root``
    inside the DAS tree (row roots first, then column roots).  The
    coordinates are *bound*: verification checks the leaf index equals
    the in-axis coordinate and the root index equals the axis position,
    so a valid proof for share (r, c) cannot be replayed at (r', c').
    """

    row: int
    col: int
    axis: str  # "row" | "col"
    axis_root: bytes
    leaf_path: cm.MerkleProof  # share -> axis_root
    root_path: cm.MerkleProof  # axis_root -> das_root

    @property
    def nbytes(self) -> int:
        """Modeled wire size: coordinates + both paths + the axis root."""
        return 8 + self.leaf_path.nbytes + len(self.axis_root) + self.root_path.nbytes


@dataclasses.dataclass(frozen=True)
class SquareCommitment:
    side: int
    share_bytes: int
    row_roots: tuple[bytes, ...]
    col_roots: tuple[bytes, ...]
    das_root: bytes


class CommittedSquare:
    """An extended square plus the Merkle machinery to prove its shares."""

    def __init__(self, ext: np.ndarray):
        side = ext.shape[0]
        assert ext.shape[1] == side and ext.ndim == 3
        self.ext = ext
        self.row_trees = [
            cm.MerkleTree([ext[r, c].tobytes() for c in range(side)])
            for r in range(side)
        ]
        self.col_trees = [
            cm.MerkleTree([ext[r, c].tobytes() for r in range(side)])
            for c in range(side)
        ]
        row_roots = tuple(t.root for t in self.row_trees)
        col_roots = tuple(t.root for t in self.col_trees)
        self.das_tree = cm.MerkleTree(list(row_roots) + list(col_roots))
        self.commitment = SquareCommitment(
            side=side,
            share_bytes=int(ext.shape[2]),
            row_roots=row_roots,
            col_roots=col_roots,
            das_root=self.das_tree.root,
        )

    def share(self, row: int, col: int) -> np.ndarray:
        return self.ext[row, col]

    def prove(self, row: int, col: int, axis: str = "row") -> ShareProof:
        side = self.commitment.side
        if axis == "row":
            leaf_path = self.row_trees[row].prove(col)
            axis_root = self.commitment.row_roots[row]
            root_path = self.das_tree.prove(row)
        elif axis == "col":
            leaf_path = self.col_trees[col].prove(row)
            axis_root = self.commitment.col_roots[col]
            root_path = self.das_tree.prove(side + col)
        else:
            raise ValueError(f"axis must be row|col, got {axis!r}")
        return ShareProof(row=row, col=col, axis=axis, axis_root=axis_root,
                          leaf_path=leaf_path, root_path=root_path)


def verify_share(das_root: bytes, side: int, share: bytes,
                 proof: ShareProof) -> bool:
    """Light-client share verification against the DAS root alone.

    Checks the coordinate binding (leaf/root indices match the claimed
    (row, col) and axis), the share's membership in its axis tree, and
    the axis root's membership in the DAS tree.
    """
    if proof.axis == "row":
        if proof.leaf_path.index != proof.col or proof.root_path.index != proof.row:
            return False
    elif proof.axis == "col":
        if (proof.leaf_path.index != proof.row
                or proof.root_path.index != side + proof.col):
            return False
    else:
        return False
    if not cm.verify(proof.axis_root, share, proof.leaf_path):
        return False
    return cm.verify(das_root, proof.axis_root, proof.root_path)


@dataclasses.dataclass(frozen=True)
class Extend2D:
    """The 2-D extension layout: k x k data -> 2k x 2k shares."""

    k: int

    @property
    def side(self) -> int:
        return 2 * self.k

    @functools.cached_property
    def code(self) -> MDSCode:
        return MDSCode(n=self.side, k=self.k)

    # -- encode ---------------------------------------------------------------
    def pad_square(self, data: bytes, share_bytes: int) -> np.ndarray:
        """Zero-pad ``data`` into the (k, k, share_bytes) data square."""
        need = self.k * self.k * share_bytes
        flat = np.frombuffer(data[:need], dtype=np.uint8)
        if flat.size < need:
            flat = np.concatenate([flat, np.zeros(need - flat.size, np.uint8)])
        return flat.reshape(self.k, self.k, share_bytes)

    def extend(self, square: np.ndarray, matmul=None) -> np.ndarray:
        """(k, k, S) data square -> (2k, 2k, S) extended square."""
        return self.extend_batch([square], matmul=matmul)[0]

    def extend_batch(self, squares: list[np.ndarray], matmul=None) -> list[np.ndarray]:
        """Extend MANY squares with TWO wide GF matmuls total.

        Each axis extension is mathematically ``parity = P @ flat`` with
        the same (m, k) systematic parity matrix; concatenating every
        square's flat bytes along the wide axis turns B tiny encodes into
        one (k, k) @ (k, B*k*S) call — the small-and-wide kernel shape.
        """
        matmul = matmul or gf.matmul_np
        if not squares:
            return []
        k, side = self.k, self.side
        shapes = {sq.shape for sq in squares}
        assert all(s[0] == k and s[1] == k for s in shapes), shapes
        widths = [sq.shape[2] for sq in squares]
        # columns first: parity rows k..2k-1 from the k data rows
        flat = np.concatenate(
            [np.ascontiguousarray(sq, np.uint8).reshape(k, -1) for sq in squares],
            axis=1,
        )
        parity = np.asarray(matmul(self.code.encode_matrix, flat), np.uint8)
        col_ext: list[np.ndarray] = []
        off = 0
        for sq, w in zip(squares, widths):
            span = k * w
            top = np.asarray(sq, np.uint8)
            bot = parity[:, off : off + span].reshape(k, k, w)
            col_ext.append(np.concatenate([top, bot], axis=0))  # (2k, k, S)
            off += span
        # then rows: every one of the 2k rows extends from k to 2k shares;
        # transpose so the row axis is the symbol axis of one wide encode
        flat = np.concatenate(
            [e.transpose(1, 0, 2).reshape(k, -1) for e in col_ext], axis=1
        )
        parity = np.asarray(matmul(self.code.encode_matrix, flat), np.uint8)
        out: list[np.ndarray] = []
        off = 0
        for e, w in zip(col_ext, widths):
            span = side * w
            right = parity[:, off : off + span].reshape(k, side, w)
            full = np.concatenate([e.transpose(1, 0, 2), right], axis=0)
            out.append(np.ascontiguousarray(full.transpose(1, 0, 2)))  # (2k, 2k, S)
            off += span
        return out

    # -- reconstruct ----------------------------------------------------------
    def reconstruct_from_rows(self, rows: dict[int, np.ndarray],
                              matmul=None) -> np.ndarray:
        """Any k complete rows (each (2k, S)) -> the full (2k, 2k, S) square.

        Every column is a codeword of the column code with the same known
        symbol pattern, so ONE decode matrix applied to the stacked known
        rows recovers every missing row in one wide GF call.
        """
        return self._reconstruct_axis(rows, axis=0, matmul=matmul)

    def reconstruct_from_cols(self, cols: dict[int, np.ndarray],
                              matmul=None) -> np.ndarray:
        """Any k complete columns (each (2k, S)) -> the full square."""
        return self._reconstruct_axis(cols, axis=1, matmul=matmul)

    def _reconstruct_axis(self, lines: dict[int, np.ndarray], axis: int,
                          matmul=None) -> np.ndarray:
        matmul = matmul or gf.matmul_np
        side = self.side
        known = tuple(sorted(lines))[: self.k]
        if len(known) < self.k:
            raise ValueError(f"need >= k={self.k} lines, got {len(lines)}")
        share_bytes = lines[known[0]].shape[-1]
        r, erased = self.code.decode_matrix(known)
        stacked = np.stack(
            [np.asarray(lines[i], np.uint8).reshape(-1) for i in known], axis=0
        )  # (k, 2k*S)
        out = np.zeros((side, side, share_bytes), np.uint8)
        for i, line in zip(known, stacked):
            out[i] = line.reshape(side, share_bytes)
        if erased:
            rec = np.asarray(matmul(r, stacked), np.uint8)
            for j, i in enumerate(erased):
                out[i] = rec[j].reshape(side, share_bytes)
        if axis == 1:
            out = out.transpose(1, 0, 2)
        return np.ascontiguousarray(out)


def commit_square(ext: np.ndarray) -> CommittedSquare:
    """Row/column/DAS commitments over an extended square."""
    return CommittedSquare(ext)
