"""Hybrid audit protocol (§4): internal audits + on-chain audit-the-auditor.

Three cooperating pieces:

1. **Challenge derivation** — publicly verifiable randomness (an epoch seed
   from the coordination layer) deterministically maps to (auditee, chunk,
   sample, auditors) tuples, so every honest party derives the same schedule.
2. **Scoreboards + BFT aggregation** (§4.1/§4.3) — each SP keeps an
   (n-1)-row bit-vector scoreboard of its peers' audit outcomes; epoch close
   aggregates per-auditee columns with a *trimmed mean* (drop top f and
   bottom f evaluations, f = floor((n-1)/3)) so Byzantine raters cannot move
   an honest SP's score outside the honest range.
3. **On-chain layer** (§4.2) — auditees with low scores get
   ``ceil((1 - score^2) * C)`` direct challenges; every published '1' entry
   is re-verified with probability ``p_ata`` (audit-the-auditor); failures
   slash; peer-submitted invalid-proof evidence slashes and rewards the
   reporter.

The module is deliberately free of I/O: the smart-contract sim
(``contract.py``) and the storage nodes (``storage/sp.py``) drive it, and the
game-theoretic property tests (``tests/test_audit_ic.py``) instantiate it
with adversarial strategies.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


# ---------------------------------------------------------------------------
# challenge derivation (publicly verifiable randomness -> schedule)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Challenge:
    epoch: int
    auditee: int  # SP id
    blob_id: int
    chunkset: int
    chunk: int  # real chunk index within chunkset
    sample: int  # sample index within chunk
    auditors: tuple[int, ...]  # SP ids assigned to verify the broadcast proof


def _rng_from(seed: bytes, *tags) -> np.random.Generator:
    h = hashlib.sha256(seed + b"|" + b"|".join(str(t).encode() for t in tags)).digest()
    return np.random.default_rng(np.frombuffer(h[:8], dtype=np.uint64)[0])


def derive_challenges(
    epoch_seed: bytes,
    epoch: int,
    holdings: list[tuple[int, int, int, int, int]],  # (sp, blob, chunkset, chunk, num_samples)
    sp_ids: list[int],
    p_a: float,
    auditors_per_audit: int,
) -> list[Challenge]:
    """Each stored chunk is challenged i.i.d. w.p. ``p_a`` per epoch (§4.1)."""
    out = []
    for sp, blob, cs, ck, nsamp in holdings:
        rng = _rng_from(epoch_seed, epoch, sp, blob, cs, ck)
        if rng.random() >= p_a:
            continue
        sample = int(rng.integers(nsamp))
        pool = [s for s in sp_ids if s != sp]
        k = min(auditors_per_audit, len(pool))
        auditors = tuple(int(x) for x in rng.choice(pool, size=k, replace=False))
        out.append(Challenge(epoch, sp, blob, cs, ck, sample, auditors))
    return out


# ---------------------------------------------------------------------------
# scoreboards
# ---------------------------------------------------------------------------
class Scoreboard:
    """One auditor's per-epoch record: auditee -> list of 0/1 outcomes.

    Published on-chain at epoch end; §4.1 notes the bit vectors are highly
    regular — ``packed()`` returns the compressed submission and its size so
    benchmarks can report the on-chain footprint.
    """

    def __init__(self, owner: int):
        self.owner = owner
        self.bits: dict[int, list[int]] = {}

    def record(self, auditee: int, ok: bool):
        self.bits.setdefault(auditee, []).append(1 if ok else 0)

    def ones(self) -> list[tuple[int, int]]:
        """(auditee, position) of every claimed success."""
        return [(a, i) for a, v in self.bits.items() for i, b in enumerate(v) if b == 1]

    def packed(self) -> tuple[bytes, int]:
        """Compressed on-chain form (run-length of the regular bit vectors)."""
        payload = bytearray()
        for auditee in sorted(self.bits):
            vec = np.asarray(self.bits[auditee], dtype=np.uint8)
            packed = np.packbits(vec).tobytes()
            payload += auditee.to_bytes(4, "little") + len(vec).to_bytes(4, "little") + packed
        raw = bytes(payload)
        return raw, len(raw)


def trim_f(num_evaluators: int) -> int:
    """f = floor((n-1)/3): max Byzantine raters tolerated (§4.3)."""
    return num_evaluators // 3


def aggregate_scores(
    per_auditor_rates: dict[int, dict[int, float]],
    sp_ids: list[int],
) -> dict[int, float]:
    """Trimmed-mean audit score per SP (§4.1/§4.3).

    per_auditor_rates[auditor][auditee] = fraction of that auditee's
    challenges the auditor observed as successful (missing '1' counts 0 —
    an auditor that saw no challenge for an auditee simply has no entry).
    SPs never rate themselves.  SPs with no evaluations score 1.0 (nothing
    was asked of them).
    """
    scores: dict[int, float] = {}
    for j in sp_ids:
        evals = [
            rates[j]
            for auditor, rates in per_auditor_rates.items()
            if auditor != j and j in rates
        ]
        if not evals:
            scores[j] = 1.0
            continue
        evals.sort()
        f = trim_f(len(evals))
        kept = evals[f : len(evals) - f] if len(evals) > 2 * f else evals
        scores[j] = float(np.mean(kept))
    return scores


# ---------------------------------------------------------------------------
# on-chain layer (§4.2)
# ---------------------------------------------------------------------------
def num_auditee_challenges(score: float, C: int) -> int:
    """(1 - score^2) * C — the quadratic scrutiny schedule."""
    return int(np.ceil((1.0 - score**2) * C))


def select_ata_entries(
    epoch_seed: bytes, epoch: int, auditor: int, ones: list[tuple[int, int]], p_ata: float
) -> list[tuple[int, int]]:
    """Sample the '1' entries the auditor must re-prove on-chain."""
    out = []
    for auditee, pos in ones:
        rng = _rng_from(epoch_seed, b"ata", epoch, auditor, auditee, pos)
        if rng.random() < p_ata:
            out.append((auditee, pos))
    return out


@dataclasses.dataclass
class EpochOutcome:
    scores: dict[int, float]
    storage_rewards: dict[int, float]
    auditor_rewards: dict[int, float]
    slashed: dict[int, float]
    onchain_challenges: dict[int, int]
    evidence_rewards: dict[int, float]
    # on-chain publication fees: gas debited per auditor for landing its
    # packed scoreboard bytes on the coordination layer (§4.3 cost story)
    publish_costs: dict[int, float] = dataclasses.field(default_factory=dict)

    def utility(self, sp: int) -> float:
        return (
            self.storage_rewards.get(sp, 0.0)
            + self.auditor_rewards.get(sp, 0.0)
            + self.evidence_rewards.get(sp, 0.0)
            - self.slashed.get(sp, 0.0)
            - self.publish_costs.get(sp, 0.0)
        )


@dataclasses.dataclass(frozen=True)
class AuditParams:
    """Calibration knobs; defaults satisfy every §5.4 inequality (validated
    in tests/test_audit_ic.py)."""

    p_a: float = 0.05  # per-epoch chunk audit probability
    auditors_per_audit: int = 4
    C: int = 50  # on-chain challenge budget scale
    p_ata: float = 0.02  # audit-the-auditor sampling rate
    eps: float = 0.01  # auditor certainty threshold
    rwd_st_per_chunk: float = 1.0  # storage reward / chunk / epoch
    rwd_au: float = 0.01  # per successful reported audit
    S_a: float = 2000.0  # slash: failed on-chain storage audit
    S_ata: float = 100.0  # slash: failed audit-the-auditor (>= rwd_au/(p_ata*eps)=50)
    r_slash: float = 5.0  # reporter's share for valid evidence
    proof_retention_epochs: int = 2
    # gas per packed scoreboard byte at publication (§4.3: submissions are
    # "highly regular" and cheap — a fee small enough that honest auditing
    # stays profitable, but real enough that the §5.4 inequalities hold NET
    # of publication; rwd_au=0.01/report vs ~10 packed bytes/report here)
    gas_per_scoreboard_byte: float = 1e-4
