"""The Shelby smart contract (coordination layer, §2.5) — simulated.

Owns exactly the state the paper assigns to it: SP/RPC participation, blob
metadata + lifecycle (PENDING -> READY -> EXPIRED), chunk placement, epoch
randomness, audit schedules, scoreboard submissions, on-chain verification,
slashing and reward settlement.  It never touches bulk data — only
commitments and proofs — preserving the control-plane/data-plane split that
the paper inherits from Web2 storage design.

Epoch randomness is a hash chain (a stand-in for Aptos's native randomness):
``seed(e+1) = H(seed(e))`` — deterministic, publicly derivable, and
unpredictable to SPs at commitment time in the real system.
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
from collections import defaultdict

from repro.core import audit as audit_mod
from repro.core import commitments as cm
from repro.core import placement as placement_mod
from repro.core.audit import AuditParams, Challenge, EpochOutcome, Scoreboard
from repro.core.placement import SPInfo


class BlobState(enum.Enum):
    PENDING = "pending"
    READY = "ready"
    EXPIRED = "expired"


@dataclasses.dataclass
class BlobMetadata:
    blob_id: int
    owner: str
    size_bytes: int
    num_chunksets: int
    n: int  # chunks per chunkset (erasure-coded)
    k: int
    blob_root: bytes
    chunkset_roots: list[bytes]
    chunk_roots: dict[tuple[int, int], bytes]  # (chunkset, chunk) -> root
    chunk_num_samples: dict[tuple[int, int], int]
    placement: dict[tuple[int, int], int]  # (chunkset, chunk) -> sp_id
    state: BlobState = BlobState.PENDING
    paid_epochs: int = 0


@dataclasses.dataclass(frozen=True)
class Reassignment:
    """One chunk remapped off a dead SP at an epoch boundary."""

    blob_id: int
    chunkset: int
    chunk: int
    old_sp: int
    new_sp: int


@dataclasses.dataclass(frozen=True)
class DASRecord:
    """On-chain record of a blob's 2-D DAS extension (see core/extend2d.py).

    Only the DAS root and the share placement live on chain — the row and
    column trees stay with the storage providers, who attach per-share
    Merkle paths to sampled reads.  ``proof_bytes`` is the fixed modeled
    wire size of one share proof (constant for a given ``side``), used by
    transports to bill proof bandwidth without shipping the object graph.
    """

    blob_id: int
    side: int  # 2k
    share_bytes: int
    das_root: bytes
    placement: dict[tuple[int, int], int]  # (row, col) -> sp_id
    proof_bytes: int


class ShelbyContract:
    """All critical state … recorded and enforced via the Shelby smart
    contract (§1)."""

    def __init__(self, params: AuditParams | None = None, genesis: bytes = b"shelby-genesis"):
        self.params = params or AuditParams()
        self._seed0 = hashlib.sha256(genesis).digest()
        self.sps: dict[int, SPInfo] = {}
        self.rpcs: set[str] = set()
        self.balances: dict[int, float] = defaultdict(float)
        self.stakes: dict[int, float] = {}
        self.blobs: dict[int, BlobMetadata] = {}
        self._next_blob = 0
        self.epoch = 0
        self.treasury = 0.0
        self.ejected: set[int] = set()
        # membership lifecycle (epoch reconfiguration): an SP that ANNOUNCES
        # departure keeps serving until the next epoch boundary finalizes it
        # into `departed`; both sets stay keyed in `sps`/`stakes` forever so
        # history (placement, channels, scores) still resolves
        self.departing: set[int] = set()
        self.departed: set[int] = set()
        # (blob_id, chunkset) -> bump count: incremented on every placement
        # remap so RPC hot caches can version-check entries cheaply instead
        # of re-reading the whole placement map
        self.placement_version: dict[tuple[int, int], int] = defaultdict(int)
        self.unplaced_chunks = 0  # displaced chunks no live SP could take
        # per-epoch submissions
        self._scoreboards: dict[int, dict[int, Scoreboard]] = defaultdict(dict)
        self.outcomes: dict[int, EpochOutcome] = {}
        # blob_id -> DAS extension record (data-availability sampling)
        self.das: dict[int, DASRecord] = {}

    # -- participation ---------------------------------------------------------
    def register_sp(self, info: SPInfo):
        if info.stake <= 0:
            raise ValueError("SP must stake")
        self.sps[info.sp_id] = info
        self.stakes[info.sp_id] = info.stake

    def register_rpc(self, rpc_id: str):
        self.rpcs.add(rpc_id)

    def register_das(self, record: DASRecord):
        """Publish a blob's DAS root + share placement (tiny: roots only)."""
        if record.blob_id not in self.blobs:
            raise KeyError(f"unknown blob {record.blob_id}")
        self.das[record.blob_id] = record

    def active_sps(self) -> list[SPInfo]:
        dead = self.ejected | self.departed
        return [s for i, s in sorted(self.sps.items()) if i not in dead]

    # -- membership lifecycle (epoch reconfiguration) ---------------------------
    def announce_departure(self, sp_id: int) -> None:
        """An SP signals intent to leave; it serves until the boundary."""
        if sp_id not in self.sps:
            raise KeyError(f"unknown SP {sp_id}")
        self.departing.add(sp_id)

    def finalize_departure(self, sp_id: int) -> None:
        """Epoch boundary: the SP is out of the active set for good."""
        if sp_id not in self.sps:
            raise KeyError(f"unknown SP {sp_id}")
        self.departing.discard(sp_id)
        self.departed.add(sp_id)

    def slash(self, sp_id: int, amount: float) -> bool:
        """Protocol-violation slashing entry (outside `close_epoch`, e.g. a
        membership plane ejecting a provably-misbehaving SP); the stake
        burns to the treasury.  Returns True when the SP was ejected."""
        burn = min(amount, max(self.stakes.get(sp_id, 0.0), 0.0))
        self.treasury += burn
        self._slash(sp_id, amount)
        return sp_id in self.ejected

    def dead_sps(self) -> set[int]:
        """SPs whose chunks need re-dispersal: ejected or departed."""
        return self.ejected | self.departed

    def reconfigure_epoch(
        self,
        epoch: int,
        extra_dead: set[int] | frozenset[int] = frozenset(),
        skip_chunksets: set[tuple[int, int]] | frozenset = frozenset(),
    ) -> list[Reassignment]:
        """Epoch-boundary reassignment: remap every READY placement entry
        sitting on a dead SP (ejected ∪ departed ∪ `extra_dead`, e.g.
        crashes detected this epoch) to a surviving/new SP, failure-domain
        aware and seeded by the epoch randomness.

        Only metadata moves here — the data itself is rebuilt by the repair
        backlog the caller enqueues from the returned list.  Chunksets in
        `skip_chunksets` ((blob_id, chunkset) keys, e.g. already counted as
        lost) are left untouched; a chunk with no eligible candidate stays
        put and is counted in ``unplaced_chunks``.  Every remap bumps the
        chunkset's ``placement_version`` so serving caches invalidate.
        """
        dead = self.dead_sps() | set(extra_dead)
        seed = self.epoch_seed(epoch)
        live = [s for s in self.active_sps() if s.sp_id not in dead]
        out: list[Reassignment] = []
        for blob_id in sorted(self.blobs):
            meta = self.blobs[blob_id]
            if meta.state is not BlobState.READY:
                continue
            for (cs, ck) in sorted(meta.placement):
                old_sp = meta.placement[(cs, ck)]
                if old_sp not in dead or (blob_id, cs) in skip_chunksets:
                    continue
                holders = {
                    meta.placement[(cs, c)]
                    for c in range(meta.n)
                    if (cs, c) in meta.placement
                }
                new_sp = placement_mod.replacement_sp(
                    seed, blob_id, cs, ck,
                    [s for s in live if s.sp_id not in holders],
                    [self.sps[h] for h in holders if h not in dead],
                )
                if new_sp is None:
                    self.unplaced_chunks += 1
                    continue
                meta.placement[(cs, ck)] = new_sp
                self.placement_version[(blob_id, cs)] += 1
                out.append(Reassignment(blob_id, cs, ck, old_sp, new_sp))
        return out

    # -- randomness --------------------------------------------------------------
    def epoch_seed(self, epoch: int) -> bytes:
        s = self._seed0
        for _ in range(epoch):
            s = hashlib.sha256(s).digest()
        return s

    # -- blob lifecycle (writes, §2.5) --------------------------------------------
    def begin_write(
        self,
        owner: str,
        size_bytes: int,
        n: int,
        k: int,
        blob_root: bytes,
        chunkset_roots: list[bytes],
        chunk_roots: dict[tuple[int, int], bytes],
        chunk_num_samples: dict[tuple[int, int], int],
        payment: float,
        epochs: int,
    ) -> BlobMetadata:
        """Client submits payment + commitments; contract assigns placement."""
        if payment <= 0 or epochs <= 0:
            raise ValueError("storage must be paid for a positive duration")
        blob_id = self._next_blob
        self._next_blob += 1
        placement: dict[tuple[int, int], int] = {}
        used: dict[int, int] = defaultdict(int)
        for key, sp in self._holdings_count().items():
            used[key] = sp
        sps = self.active_sps()
        for cs in range(len(chunkset_roots)):
            assigned = placement_mod.assign_chunkset(
                self.epoch_seed(self.epoch), blob_id, cs, sps, n, used
            )
            for ck, sp_id in enumerate(assigned):
                placement[(cs, ck)] = sp_id
                used[sp_id] += 1
        meta = BlobMetadata(
            blob_id=blob_id,
            owner=owner,
            size_bytes=size_bytes,
            num_chunksets=len(chunkset_roots),
            n=n,
            k=k,
            blob_root=blob_root,
            chunkset_roots=list(chunkset_roots),
            chunk_roots=dict(chunk_roots),
            chunk_num_samples=dict(chunk_num_samples),
            placement=placement,
        )
        self.blobs[blob_id] = meta
        self.treasury += payment
        meta.paid_epochs = epochs
        return meta

    def mark_ready(self, blob_id: int, rpc_id: str):
        if rpc_id not in self.rpcs:
            raise PermissionError("unknown RPC node")
        self.blobs[blob_id].state = BlobState.READY

    def reassign_chunk(self, blob_id: int, chunkset: int, chunk: int) -> int:
        """Move a chunk off an ejected/failed SP (repair placement)."""
        meta = self.blobs[blob_id]
        current = set(
            meta.placement[(chunkset, c)]
            for c in range(meta.n)
            if (chunkset, c) in meta.placement
        )
        candidates = [s for s in self.active_sps() if s.sp_id not in current]
        if not candidates:
            raise ValueError("no SP available for repair placement")
        rng = placement_mod._rng(self.epoch_seed(self.epoch), b"repair", blob_id, chunkset, chunk)
        new_sp = int(rng.choice([s.sp_id for s in candidates]))
        meta.placement[(chunkset, chunk)] = new_sp
        self.placement_version[(blob_id, chunkset)] += 1
        return new_sp

    # -- catalog (read path never mutates; RPCs mirror this locally, §5.2) --------
    def catalog(self) -> dict[int, BlobMetadata]:
        return dict(self.blobs)

    def _holdings_count(self) -> dict[int, int]:
        c: dict[int, int] = defaultdict(int)
        for meta in self.blobs.values():
            for sp in meta.placement.values():
                c[sp] += 1
        return c

    def holdings(self) -> list[tuple[int, int, int, int, int]]:
        """(sp, blob, chunkset, chunk, num_samples) for every READY chunk."""
        out = []
        for meta in self.blobs.values():
            if meta.state is not BlobState.READY:
                continue
            for (cs, ck), sp in meta.placement.items():
                out.append((sp, meta.blob_id, cs, ck, meta.chunk_num_samples[(cs, ck)]))
        return out

    # -- audit epoch machinery (§4) ------------------------------------------------
    def internal_challenges(self, epoch: int) -> list[Challenge]:
        sp_ids = [s.sp_id for s in self.active_sps()]
        return audit_mod.derive_challenges(
            self.epoch_seed(epoch),
            epoch,
            self.holdings(),
            sp_ids,
            self.params.p_a,
            self.params.auditors_per_audit,
        )

    def submit_scoreboard(self, epoch: int, sb: Scoreboard):
        self._scoreboards[epoch][sb.owner] = sb

    def chunk_root(self, blob_id: int, chunkset: int, chunk: int) -> bytes:
        return self.blobs[blob_id].chunk_roots[(chunkset, chunk)]

    def verify_possession_proof(
        self, blob_id: int, chunkset: int, chunk: int, sample: bytes, proof: cm.MerkleProof
    ) -> bool:
        """On-chain Merkle verification (cheap enough for consensus, §3.4)."""
        return cm.verify(self.chunk_root(blob_id, chunkset, chunk), sample, proof)

    def submit_evidence(
        self, reporter: int, accused: int, blob_id: int, chunkset: int, chunk: int,
        sample: bytes, proof: cm.MerkleProof,
    ) -> bool:
        """Peer-submitted invalid-proof evidence (§4.2): reporter is rewarded
        iff the proof indeed fails verification against on-chain roots."""
        valid = self.verify_possession_proof(blob_id, chunkset, chunk, sample, proof)
        if valid:
            return False  # evidence rejected; honest peers are safe
        self._slash(accused, self.params.S_ata)
        self.balances[reporter] += self.params.r_slash
        return True

    def _slash(self, sp: int, amount: float):
        self.stakes[sp] = self.stakes.get(sp, 0.0) - amount
        if self.stakes[sp] <= 0:
            self.ejected.add(sp)

    def close_epoch(
        self,
        epoch: int,
        respond_onchain_storage,  # (sp, blob, cs, ck, sample_idx) -> (bytes, proof)|None
        respond_ata,  # (auditor, auditee, position) -> (blob, cs, ck, bytes, proof)|None
    ) -> EpochOutcome:
        """§4.2: score aggregation, quadratic auditee challenges, ATA checks,
        slashing, and reward distribution — all 'on-chain'."""
        p = self.params
        sp_ids = [s.sp_id for s in self.active_sps()]
        boards = self._scoreboards.get(epoch, {})

        # 1) trimmed-mean scores from published scoreboards
        rates: dict[int, dict[int, float]] = {}
        for auditor, sb in boards.items():
            rates[auditor] = {
                a: (sum(v) / len(v)) for a, v in sb.bits.items() if len(v) > 0
            }
        scores = audit_mod.aggregate_scores(rates, sp_ids)

        slashed: dict[int, float] = defaultdict(float)
        onchain: dict[int, int] = {}
        seed = self.epoch_seed(epoch)

        # 2) auditee audits: (1 - score^2) * C randomized storage challenges
        holdings_by_sp: dict[int, list] = defaultdict(list)
        for h in self.holdings():
            holdings_by_sp[h[0]].append(h)
        for sp in sp_ids:
            nch = audit_mod.num_auditee_challenges(scores[sp], p.C)
            onchain[sp] = nch
            held = holdings_by_sp.get(sp, [])
            if not held or nch == 0:
                continue
            rng = placement_mod._rng(seed, b"auditee", epoch, sp)
            for _ in range(nch):
                _, blob, cs, ck, nsamp = held[int(rng.integers(len(held)))]
                sidx = int(rng.integers(nsamp))
                resp = respond_onchain_storage(sp, blob, cs, ck, sidx)
                ok = (
                    resp is not None
                    and resp[1].index == sidx
                    and self.verify_possession_proof(blob, cs, ck, resp[0], resp[1])
                )
                if not ok:
                    slashed[sp] += p.S_a
                    self._slash(sp, p.S_a)

        # 3) audit-the-auditor: reproduce sampled '1' entries
        for auditor, sb in boards.items():
            picked = audit_mod.select_ata_entries(seed, epoch, auditor, sb.ones(), p.p_ata)
            for auditee, pos in picked:
                resp = respond_ata(auditor, auditee, pos)
                ok = resp is not None and self.verify_possession_proof(
                    resp[0], resp[1], resp[2], resp[3], resp[4]
                )
                if not ok:
                    slashed[auditor] += p.S_ata
                    self._slash(auditor, p.S_ata)

        # 4) rewards: storage (volume * score) + auditor (per reported success)
        held_count = self._holdings_count()
        storage_rwd = {
            sp: held_count.get(sp, 0) * p.rwd_st_per_chunk * scores[sp] for sp in sp_ids
        }
        auditor_rwd = {
            auditor: p.rwd_au * sum(sum(v) for v in sb.bits.values())  # simlint: ok SIM007 integer bit counts, order-exact
            for auditor, sb in boards.items()
        }
        for sp, amt in storage_rwd.items():
            self.balances[sp] += amt
        for sp, amt in auditor_rwd.items():
            self.balances[sp] += amt

        # 5) scoreboard publication gas (§4.3): landing the packed bit
        # vectors on chain costs each auditor gas proportional to its
        # compressed submission size — debited to the treasury, so the
        # audit economy nets publication out of auditor profit
        publish_costs: dict[int, float] = {}
        for auditor, sb in boards.items():
            _, nbytes = sb.packed()
            cost = nbytes * p.gas_per_scoreboard_byte
            if cost > 0:
                publish_costs[auditor] = cost
                self.balances[auditor] -= cost
                self.treasury += cost

        outcome = EpochOutcome(
            scores=scores,
            storage_rewards=storage_rwd,
            auditor_rewards=auditor_rwd,
            slashed=dict(slashed),
            onchain_challenges=onchain,
            evidence_rewards={},
            publish_costs=publish_costs,
        )
        self.outcomes[epoch] = outcome
        self.epoch = max(self.epoch, epoch + 1)
        return outcome
