"""Multi-epoch audit/economics simulation (§4.4 theorems, §5 calibration).

Builds a full simulated deployment (contract + SPs + RPC + blobs), runs
audit epochs end to end — internal challenges, proof broadcast, peer
verification, scoreboard publication, epoch close with on-chain challenges,
audit-the-auditor and slashing — and accounts each SP's *total utility*:

The data-plane half of each epoch runs on the shared event engine: the
audit challenge→proof→verify flow is a paced background plane
(:class:`~repro.storage.background.AuditPlane`) spawned on the SAME loop
as the epoch's paid-read storm, so audit work holds real SP disk slots
(background class, capped by :class:`~repro.storage.sp.BackgroundSpec`)
and contends with serving instead of being free.

    utility = storage rewards + auditor rewards + evidence rewards
              - slashing - storage costs (+ saved costs for cheaters)

This is the engine behind the empirical checks of Theorem 1 (honest is a
Nash equilibrium), Theorem 2 (mutual dishonesty is not), Theorem 3
(coalition resistance) and the §5.4 parameter calibration.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.audit import AuditParams, Challenge
from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.events import EventLoop
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.net.workloads import zipf_hotset
from repro.storage.background import AuditPlane
from repro.storage.blob import BlobLayout
from repro.storage.membership import ChurnSpec, MembershipPlane
from repro.storage.repair import RepairCoordinator
from repro.storage.rpc import RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import BackgroundSpec, SPBehavior, ServiceSpec, StorageProvider


@dataclasses.dataclass
class SimResult:
    utilities: dict[int, float]
    scores: dict[int, float]  # last-epoch scores
    slashed: dict[int, float]
    ejected: set[int]
    bytes_served: int = 0  # read traffic through the RPC fleet (if any)
    read_p99_ms: float = 0.0  # simulated, from the fleet's request log
    # paid-read economics ("reads are paid", §2.2/§3.2): serving income
    # flows client->RPC->SP through settled micropayment channels only
    sp_serving_income: dict[int, float] = dataclasses.field(default_factory=dict)
    rpc_serving_income: dict[str, float] = dataclasses.field(default_factory=dict)
    client_read_payments: float = 0.0  # sum over ReadReceipt payments
    # overload outcomes of the per-epoch read storms (admission control +
    # single-flight dedup): shed reads debit nothing; coalesced misses rode
    # another request's in-flight fetch
    reads_shed: int = 0
    reads_coalesced: int = 0
    # the audit plane on the event loop: challenge→proof→verify tasks that
    # ran CONCURRENTLY with the paid-read storm, holding auditee disk slots
    # in the background class (a failed op = no proof, e.g. a dropped chunk)
    audit_ops: int = 0
    audit_failures: int = 0
    # membership plane (churn != None): epoch-scale joins/departures/crashes,
    # boundary reconfigurations and the re-dispersal backlog they queued
    membership_events: int = 0
    chunksets_lost: int = 0
    repairs_enqueued: int = 0
    repairs_completed: int = 0
    sps_joined: int = 0
    sps_departed: int = 0
    # DAS sampling plane (das != None): per-epoch light-client sampling
    # rounds over every blob's 2-D extension — pay-per-sample through the
    # same session channels, detections = blobs flagged unavailable
    das_samples: int = 0
    das_detections: int = 0
    das_proof_bytes: int = 0

    def utility(self, sp: int) -> float:
        return self.utilities[sp]


def run_sim(
    behaviors: dict[int, SPBehavior],
    *,
    params: AuditParams | None = None,
    epochs: int = 2,
    num_blobs: int = 6,
    blob_bytes: int = 200_000,
    storage_cost_per_chunk_epoch: float = 0.05,
    layout: BlobLayout | None = None,
    seed: int = 0,
    num_rpcs: int = 1,
    read_requests_per_epoch: int = 0,
    decode_matmul=None,  # e.g. configs.shelby.resolve_decode_matmul("pallas")
    admission=None,  # storage.rpc.AdmissionSpec: shed past saturation
    single_flight: bool = True,  # collapse concurrent same-chunkset misses
    background: BackgroundSpec | None = None,  # per-SP audit/repair budget
    churn: ChurnSpec | None = None,  # epoch-scale membership churn plane
    epoch_ms: float = 250.0,  # simulated wall span of one churned epoch
    das=None,  # storage.das.DASSpec: extend blobs + sample every epoch
    engine: str | None = None,  # event-queue discipline (calendar|heap)
    sanitize: bool | None = None,  # simsan: per-epoch payment conservation
) -> SimResult:
    if sanitize is None:
        sanitize = bool(os.environ.get("SHELBY_SIMSAN"))
    params = params or AuditParams(p_a=0.5, auditors_per_audit=4, C=50, p_ata=0.3)
    layout = layout or BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
    background = background or BackgroundSpec()
    n = len(behaviors)
    contract = ShelbyContract(params)
    sps: dict[int, StorageProvider] = {}
    for i in range(n):
        contract.register_sp(SPInfo(sp_id=i, stake=10_000.0, dc=f"dc{i % 3}"))
        sps[i] = StorageProvider(i, behaviors.get(i, SPBehavior()),
                                 service=ServiceSpec(background=background))
    rpcs = [
        RPCNode(f"rpc{r}", contract, sps, layout, decode_matmul=decode_matmul,
                admission=admission, single_flight=single_flight)
        for r in range(num_rpcs)
    ]
    fleet = RPCFleet(rpcs, CacheAffinityPolicy())
    client = ShelbyClient(contract, fleet, deposit=1e9, das=das)

    # crashes take effect AFTER the write phase (the contract would never
    # assign chunks to an SP that is already down)
    crashed_later = [i for i, b in behaviors.items() if b.crashed]
    for i in crashed_later:
        sps[i].behavior.crashed = False

    rng = np.random.default_rng(seed)
    for _ in range(num_blobs):
        client.put(rng.integers(0, 256, blob_bytes, dtype=np.uint8).tobytes())

    for i in crashed_later:
        sps[i].behavior.crashed = True

    utilities = {i: 0.0 for i in range(n)}
    reads_shed = 0
    audit_ops = 0
    audit_failures = 0
    # storage costs: cheaters with drop_fraction save proportionally
    held = {}
    for meta in contract.blobs.values():
        for sp in meta.placement.values():
            held[sp] = held.get(sp, 0) + 1

    # membership churn: ONE repair coordinator and ONE permanent lost-set
    # span all epochs (losses must never be double counted across the
    # per-epoch replay loops); each epoch gets a one-epoch plane slice
    repair_coord = RepairCoordinator(contract, sps, layout) if churn else None
    lost_chunksets: set[tuple[int, int]] = set()
    membership_events = 0
    repairs_enqueued = 0
    repairs_completed = 0
    sps_joined = 0
    sps_departed = 0

    das_samples = 0
    das_detections = 0
    das_proof_bytes = 0

    last = None
    for epoch in range(epochs):
        # the audit plane: challenge→proof→verify as paced background tasks
        # on the event loop — CONCURRENT with the epoch's paid-read storm,
        # holding auditee disk slots in the background class, instead of the
        # old zero-cost serial pass
        challenges = contract.internal_challenges(epoch)
        plane = AuditPlane(contract, sps, challenges)
        mplane = None
        planes: list = [plane]
        if churn is not None:
            mplane = MembershipPlane(
                contract, sps, layout, churn,
                repair=repair_coord, fleet=fleet,
                epochs=1, epoch_ms=epoch_ms, start_epoch=epoch,
                service_factory=lambda: ServiceSpec(background=background),
                lost=lost_chunksets,
            )
            planes.extend(mplane.planes())
        if read_requests_per_epoch:
            # paid Zipf read traffic through the client session, replayed as
            # a CONCURRENT open-loop Poisson process on the shared event
            # heap: in-flight requests' hedge timers and SP disk queues
            # interleave — and now contend with the audit plane.  The client
            # pays serving RPC nodes on delivery ("reads are paid"); a
            # dropped request debits nothing.
            metas = list(contract.blobs.values())
            reqs = zipf_hotset(
                metas,
                clients=["user"],
                num_requests=read_requests_per_epoch,
                seed=seed * 1009 + epoch,
                arrival="poisson",
            )
            _, replay = client.replay(reqs, background=planes, engine=engine)
            reads_shed += replay.shed
        else:
            loop = EventLoop(engine=engine)
            for p in planes:
                p.spawn(loop)
            loop.run()
        audit_ops += len(plane.records)
        audit_failures += sum(1 for r in plane.records if not r.ok)
        if mplane is not None:
            membership_events += len(mplane.events)
            sps_joined += len(mplane.joined)
            sps_departed += sum(
                1 for e in mplane.events if e.kind in ("leave", "crash", "slash")
            )
            if mplane.repair is not None:
                repairs_enqueued += mplane.repair.enqueued_total
                repairs_completed += sum(
                    1 for r in mplane.repair.records if r.ok
                )
        if das is not None and das.extension and contract.das:
            # the light-client sampling round: every blob's extension is
            # probed with s seeded coordinates through the same session —
            # pay-per-sample flows through settlement conservation below
            verdicts = client.current_session.sample_availability(
                epoch=epoch, seed=seed * 733 + epoch
            )
            das_samples += sum(v.verified + v.failures for v in verdicts)
            das_detections += sum(1 for v in verdicts if not v.available)
            das_proof_bytes += sum(v.proof_bytes for v in verdicts)
        for i, sp in sps.items():
            if i not in contract.dead_sps():
                contract.submit_scoreboard(epoch, sp.scoreboard)

        def respond_storage(sp, blob, cs, ck, sidx):
            pr = sps[sp].respond_challenge(Challenge(epoch, sp, blob, cs, ck, sidx, ()))
            return (pr.sample, pr.proof) if pr else None

        def respond_ata(auditor, auditee, pos):
            return sps[auditor].reproduce_proof(auditee, pos)

        if sanitize:
            # simsan: the settlement invariant (every channel debit backed
            # by a receipt) must already hold at EVERY epoch boundary, not
            # just at close() — catching the first epoch that breaks it
            # names the plane that leaked value
            from repro.analysis.simsan import check_payment_conservation
            check_payment_conservation(client.current_session,
                                       where=f"epoch {epoch}")

        last = contract.close_epoch(epoch, respond_storage, respond_ata)
        for i in sorted(sps):  # sps may have grown mid-epoch (joiners)
            utilities[i] = utilities.get(i, 0.0) + last.utility(i)
            stored = sps[i].stored_chunks()
            utilities[i] -= stored * storage_cost_per_chunk_epoch
        for sp in sps.values():  # fresh scoreboards next epoch
            sp.scoreboard.bits.clear()

    # settle the read session: client->RPC channels broadcast their freshest
    # refunds and the RPC->SP channels cascade, so serving income reaches SP
    # utilities exclusively through settled channels (no earned_reads shortcut)
    session = client.current_session
    receipts = list(session.receipts)
    settlement = client.settle()
    for i, amt in settlement.sp_income.items():
        utilities[i] = utilities.get(i, 0.0) + amt

    slashed_total = {i: 10_000.0 - contract.stakes.get(i, 10_000.0) for i in range(n)}
    p99 = fleet.latency_percentiles(99.0)[0] if fleet.request_latencies_ms else 0.0
    return SimResult(
        utilities=utilities,
        scores=last.scores if last else {},
        slashed=slashed_total,
        ejected=set(contract.ejected),
        bytes_served=fleet.bytes_served,
        read_p99_ms=p99,
        sp_serving_income=dict(settlement.sp_income),
        rpc_serving_income=dict(settlement.node_income),
        client_read_payments=sum(r.total_paid for r in receipts),
        reads_shed=reads_shed,
        reads_coalesced=fleet.coalesced(),
        audit_ops=audit_ops,
        audit_failures=audit_failures,
        membership_events=membership_events,
        chunksets_lost=len(lost_chunksets),
        repairs_enqueued=repairs_enqueued,
        repairs_completed=repairs_completed,
        sps_joined=sps_joined,
        sps_departed=sps_departed,
        das_samples=das_samples,
        das_detections=das_detections,
        das_proof_bytes=das_proof_bytes,
    )


def honest_population(n: int) -> dict[int, SPBehavior]:
    return {i: SPBehavior() for i in range(n)}
