"""Vector commitments (§3.4): Merkle trees over erasure-coded chunks.

Two hash paths, one API (see DESIGN.md §3):

* **protocol-grade** — SHA-256 (hashlib). Used for everything whose digest is
  bound on-chain: chunk roots, blob roots, audit-proof verification by the
  smart contract.
* **bulk** — the vectorized xxhash32-style digest (Pallas kernel
  ``repro.kernels.sample_hash``) for high-volume off-chain sample
  fingerprinting (dedup, scoreboard noise checks).  Never used where
  collision resistance is security-critical.

Layout (paper §2.1 + Figure 2):
  Chunk  = alpha x w bytes  ->  SAMPLE_BYTES samples  ->  Merkle root_chunk
  Chunkset -> n chunks      ->  Merkle over chunk roots  ->  root_chunkset
  Blob   -> chunksets       ->  Merkle over chunkset roots -> root_blob
Audit proofs are (sample bytes, path-to-chunk-root) plus the chunk->blob
binding kept in on-chain metadata.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

SAMPLE_BYTES = 1024  # "around 1 KiB" (§2.1)


def h(data: bytes) -> bytes:
    return hashlib.sha256(data).digest()


def _pair(a: bytes, b: bytes) -> bytes:
    return h(b"\x01" + a + b)


def _leaf(data: bytes) -> bytes:
    return h(b"\x00" + data)


@dataclasses.dataclass(frozen=True)
class MerkleProof:
    index: int
    path: tuple[bytes, ...]  # sibling hashes, leaf -> root

    @property
    def nbytes(self) -> int:
        return 4 + sum(len(p) for p in self.path)


class MerkleTree:
    """Binary Merkle tree with duplicate-last padding to a power of two."""

    def __init__(self, leaves: list[bytes]):
        if not leaves:
            raise ValueError("empty tree")
        hashes = [_leaf(x) for x in leaves]
        self.num_leaves = len(hashes)
        size = 1
        while size < len(hashes):
            size *= 2
        hashes = hashes + [hashes[-1]] * (size - len(hashes))
        levels = [hashes]
        while len(levels[-1]) > 1:
            prev = levels[-1]
            levels.append([_pair(prev[i], prev[i + 1]) for i in range(0, len(prev), 2)])
        self.levels = levels

    @property
    def root(self) -> bytes:
        return self.levels[-1][0]

    def prove(self, index: int) -> MerkleProof:
        assert 0 <= index < self.num_leaves
        path = []
        i = index
        for level in self.levels[:-1]:
            sib = i ^ 1
            path.append(level[sib])
            i //= 2
        return MerkleProof(index=index, path=tuple(path))


def verify(root: bytes, leaf_data: bytes, proof: MerkleProof) -> bool:
    node = _leaf(leaf_data)
    i = proof.index
    for sib in proof.path:
        node = _pair(node, sib) if i % 2 == 0 else _pair(sib, node)
        i //= 2
    return node == root


# -- chunk / chunkset / blob commitment stack ---------------------------------
def chunk_samples(chunk: np.ndarray) -> list[bytes]:
    """Split a chunk (uint8, any shape) into SAMPLE_BYTES-sized samples."""
    flat = np.ascontiguousarray(chunk, dtype=np.uint8).reshape(-1)
    pad = -flat.size % SAMPLE_BYTES
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.uint8)])
    return [flat[i : i + SAMPLE_BYTES].tobytes() for i in range(0, flat.size, SAMPLE_BYTES)]


@dataclasses.dataclass(frozen=True)
class ChunkCommitment:
    root: bytes
    num_samples: int


def commit_chunk(chunk: np.ndarray) -> tuple[ChunkCommitment, MerkleTree]:
    samples = chunk_samples(chunk)
    tree = MerkleTree(samples)
    return ChunkCommitment(root=tree.root, num_samples=len(samples)), tree


def commit_roots(roots: list[bytes]) -> tuple[bytes, MerkleTree]:
    tree = MerkleTree(list(roots))
    return tree.root, tree


# -- bulk (vectorized) sample digests ----------------------------------------
def bulk_sample_digests(samples: np.ndarray, seed: int = 0) -> np.ndarray:
    """samples: (L, SAMPLE_BYTES) uint8 -> (L,) uint32 via the Pallas kernel."""
    from repro.kernels import ops

    assert samples.ndim == 2 and samples.shape[1] % 4 == 0
    words = samples.view(np.uint32) if samples.dtype == np.uint8 else samples
    words = np.ascontiguousarray(samples, np.uint8).reshape(samples.shape[0], -1)
    words = words.view(np.uint32)
    return np.asarray(ops.sample_hash(words, seed=seed))
