"""GF(2^8) arithmetic for erasure coding.

Three execution paths share one semantic:

* ``numpy`` path (``mul``, ``matmul_np``…)  — used by the coordination layer
  and by small setup-time linear algebra (matrix inversion for decode plans).
* ``jnp`` path (``mul_jnp``, ``matmul_jnp``) — pure-jnp oracle used as the
  Pallas kernel reference and for small on-device coding.
* Pallas kernel (``repro.kernels.gf_matmul``) — the bulk data-path encoder /
  decoder; validated against ``matmul_jnp``.

Field: GF(2^8) with the AES-adjacent polynomial x^8+x^4+x^3+x^2+1 (0x11D),
the standard choice of ISA-L / jerasure / Ceph's clay plugin.
"""
from __future__ import annotations

import functools

import numpy as np

POLY = 0x11D  # x^8 + x^4 + x^3 + x^2 + 1 (primitive)
GENERATOR = 2


def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= POLY
    exp[255:510] = exp[:255]  # wraparound so exp[(la+lb)] needs no mod
    return exp, log


EXP_TABLE, LOG_TABLE = _build_tables()


# ---------------------------------------------------------------------------
# numpy path
# ---------------------------------------------------------------------------
def mul(a, b):
    """Element-wise GF(2^8) multiply on uint8 numpy arrays (broadcasting)."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    out = EXP_TABLE[LOG_TABLE[a] + LOG_TABLE[b]]
    return np.where((a == 0) | (b == 0), np.uint8(0), out)


def inv(a):
    """Multiplicative inverse (a must be nonzero)."""
    a = np.asarray(a, dtype=np.uint8)
    if np.any(a == 0):
        raise ZeroDivisionError("gf.inv(0)")
    return EXP_TABLE[255 - LOG_TABLE[a]]


def div(a, b):
    return mul(a, inv(b))


def pow_(a: int, e: int) -> int:
    if e == 0:
        return 1
    if a == 0:
        return 0
    return int(EXP_TABLE[(int(LOG_TABLE[a]) * e) % 255])


def matmul_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """GF(2^8) matrix product: (M,K) x (K,N) -> (M,N), uint8."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    assert a.ndim == 2 and b.ndim == 2 and a.shape[1] == b.shape[0]
    out = np.zeros((a.shape[0], b.shape[1]), dtype=np.uint8)
    for k in range(a.shape[1]):
        col = a[:, k : k + 1]  # (M,1)
        if not col.any():
            continue
        out ^= mul(col, b[k : k + 1, :])
    return out


def mat_inv(a: np.ndarray) -> np.ndarray:
    """Invert a square GF(2^8) matrix by Gauss-Jordan elimination."""
    a = np.array(a, dtype=np.uint8)
    n = a.shape[0]
    assert a.shape == (n, n)
    aug = np.concatenate([a, np.eye(n, dtype=np.uint8)], axis=1)
    for col in range(n):
        piv = None
        for r in range(col, n):
            if aug[r, col] != 0:
                piv = r
                break
        if piv is None:
            raise np.linalg.LinAlgError("singular GF(256) matrix")
        if piv != col:
            aug[[col, piv]] = aug[[piv, col]]
        aug[col] = mul(aug[col], inv(aug[col, col]))
        for r in range(n):
            if r != col and aug[r, col] != 0:
                aug[r] ^= mul(aug[r, col], aug[col])
    return aug[:, n:]


def solve(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Solve a @ x = b over GF(2^8) (a square, invertible)."""
    return matmul_np(mat_inv(a), b)


def vandermonde(rows: int, cols: int, points: np.ndarray | None = None) -> np.ndarray:
    """Vandermonde matrix V[i,j] = points[j]^i; any `rows` distinct columns of a
    row-prefix are invertible, so it serves as an MDS parity-check."""
    if points is None:
        points = np.arange(1, cols + 1, dtype=np.uint8)  # distinct nonzero
    points = np.asarray(points, dtype=np.uint8)
    assert len(points) == cols and len(np.unique(points)) == cols
    v = np.zeros((rows, cols), dtype=np.uint8)
    v[0, :] = 1
    for i in range(1, rows):
        v[i] = mul(v[i - 1], points)
    return v


# ---------------------------------------------------------------------------
# jnp path (oracle for the Pallas kernel; carry-less multiply, no tables)
# ---------------------------------------------------------------------------
@functools.lru_cache(None)
def _jnp():
    import jax.numpy as jnp

    return jnp


def mul_jnp(a, b):
    """Branchless GF(2^8) multiply: 8-step shift/xor (Russian peasant).

    Operates on int32 arrays holding byte values; mirrors exactly what the
    Pallas kernel does on the VPU (no gathers/tables).
    """
    jnp = _jnp()
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    acc = jnp.zeros(jnp.broadcast_shapes(a.shape, b.shape), jnp.int32)
    for _ in range(8):
        acc = acc ^ (jnp.where((a & 1) != 0, b, 0))
        a = a >> 1
        carry = (b & 0x80) != 0
        b = (b << 1) & 0xFF
        b = jnp.where(carry, b ^ (POLY & 0xFF), b)
    return acc


def matmul_jnp(a, b):
    """GF(2^8) matmul on int-valued jnp arrays: (M,K) x (K,N) -> (M,N)."""
    jnp = _jnp()
    prod = mul_jnp(a[:, :, None], b[None, :, :])  # (M,K,N)
    out = prod[:, 0, :]
    for k in range(1, prod.shape[1]):
        out = out ^ prod[:, k, :]
    return out
