"""Micropayment channels (§3.2): unidirectional client->server channels.

Faithful to the paper's description (which follows the classic Bitcoin
rapidly-adjusted micropayments contract [14]):

* open: funds move into a 2-of-2 multisig; server hands the client an initial
  *refund transaction* (full amount back to client, settle-time T0).
* pay: the client signs a new refund with a *smaller* refund amount and a
  *slightly earlier* allowed settlement time; the server keeps the latest.
* settle: either party broadcasts; the most recently signed refund (earliest
  valid settle time / highest paid amount) wins.

Signatures are HMAC stubs (this is a protocol simulation, not a wallet), but
the *state-machine safety properties* the paper relies on are enforced and
tested: payments are monotone, can never exceed the deposit, a stale refund
can never beat a fresher one, and an uncooperative party loses at most the
last unpaid increment ("value at risk is small").

Used in two places, exactly as in §2: client->RPC channels (SDK) and
RPC->SP channels (read path, one per SP).
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
import itertools

_ids = itertools.count()


class ChannelError(Exception):
    pass


@dataclasses.dataclass(frozen=True)
class RefundTx:
    channel_id: int
    refund_amount: float  # what flows BACK to the client at settlement
    settle_time: float  # earliest time this refund may be enforced
    seq: int
    sig_client: bytes
    sig_server: bytes


def _sign(key: bytes, payload: str) -> bytes:
    return hmac.new(key, payload.encode(), hashlib.sha256).digest()


class MicropaymentChannel:
    """Unidirectional channel; amounts in abstract $ (paper: ~1e-9 / payment)."""

    def __init__(self, deposit: float, initial_settle_time: float = 1e9):
        if deposit <= 0:
            raise ChannelError("deposit must be positive")
        self.channel_id = next(_ids)
        self.deposit = float(deposit)
        self._client_key = hashlib.sha256(f"c{self.channel_id}".encode()).digest()
        self._server_key = hashlib.sha256(f"s{self.channel_id}".encode()).digest()
        self._seq = 0
        self._settle_time = initial_settle_time
        self.latest_refund = self._make_refund(deposit, initial_settle_time, 0)
        self.settled = False
        self.paid = 0.0

    def _make_refund(self, refund_amount: float, settle_time: float, seq: int) -> RefundTx:
        payload = f"{self.channel_id}:{refund_amount:.12f}:{settle_time}:{seq}"
        return RefundTx(
            channel_id=self.channel_id,
            refund_amount=refund_amount,
            settle_time=settle_time,
            seq=seq,
            sig_client=_sign(self._client_key, payload),
            sig_server=_sign(self._server_key, payload),
        )

    def pay(self, amount: float) -> RefundTx:
        """Client pays `amount` more; returns the fresh refund the server keeps."""
        if self.settled:
            raise ChannelError("channel settled")
        if amount <= 0:
            raise ChannelError("payment must be positive")
        if self.paid + amount > self.deposit + 1e-12:
            raise ChannelError("payment exceeds deposit")
        self.paid += amount
        self._seq += 1
        self._settle_time -= 1.0  # "slightly earlier allowed settlement time"
        self.latest_refund = self._make_refund(
            self.deposit - self.paid, self._settle_time, self._seq
        )
        return self.latest_refund

    def verify_refund(self, tx: RefundTx) -> bool:
        payload = f"{tx.channel_id}:{tx.refund_amount:.12f}:{tx.settle_time}:{tx.seq}"
        return (
            tx.channel_id == self.channel_id
            and hmac.compare_digest(tx.sig_client, _sign(self._client_key, payload))
            and hmac.compare_digest(tx.sig_server, _sign(self._server_key, payload))
        )

    def settle(self, tx: RefundTx) -> tuple[float, float]:
        """Enforce a refund tx; returns (client_gets, server_gets).

        The channel accepts only the *freshest* refund it has co-signed: a
        stale tx (lower seq) is rejected because the newer one has an earlier
        settle time and would preempt it on-chain.
        """
        if self.settled:
            raise ChannelError("already settled")
        if not self.verify_refund(tx):
            raise ChannelError("bad signature")
        if tx.seq < self.latest_refund.seq:
            raise ChannelError("stale refund preempted by a fresher one")
        self.settled = True
        client_gets = tx.refund_amount
        return client_gets, self.deposit - client_gets


class PaymentLedger:
    """Aggregates read payments across channels (RPC->SP or client->RPC)."""

    def __init__(self):
        self.channels: dict[str, MicropaymentChannel] = {}
        self.totals: dict[str, float] = {}

    def open(self, peer: str, deposit: float) -> MicropaymentChannel:
        ch = MicropaymentChannel(deposit)
        self.channels[peer] = ch
        self.totals.setdefault(peer, 0.0)
        return ch

    def pay(self, peer: str, amount: float) -> RefundTx:
        ch = self.channels[peer]
        tx = ch.pay(amount)
        self.totals[peer] += amount
        return tx

    def total_paid(self) -> float:
        # sorted so the float sum is independent of channel insertion order
        return sum(self.totals[k] for k in sorted(self.totals))
