"""Mamba-1 selective SSM (falcon-mamba / hymba's SSM heads).

Training path: depthwise causal conv + selective scan via
``jax.lax.associative_scan`` over the sequence (the classic
``(a, b) ∘ (a', b') = (a a', a b' ... )`` linear-recurrence composition),
with the inner dim sharded over ``model`` so the (B, S, d_inner, state)
intermediates stay within HBM budgets.

Decode path: O(1) per token — carry (conv_state, ssm_state); this is what
makes the ``long_500k`` cell sub-quadratic for the SSM/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import cast
from repro.sharding import ParamSpec


def ssm_specs(cfg, layers: int | None = None, d_override: int | None = None):
    s = cfg.ssm
    d = d_override or cfg.d_model
    di, n, cw = s.d_inner, s.state, s.conv_width
    r = s.dt_rank or max(cfg.d_model // 16, 1)
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "in_proj": ParamSpec(lead + (d, 2 * di), la + ("embed", "ssm_inner"), init="scaled"),
        "conv_w": ParamSpec(lead + (cw, di), la + ("conv", "ssm_inner"), init="scaled"),
        "conv_b": ParamSpec(lead + (di,), la + ("ssm_inner",), init="zeros"),
        "x_proj": ParamSpec(lead + (di, r + 2 * n), la + ("ssm_inner", None), init="scaled"),
        "dt_proj": ParamSpec(lead + (r, di), la + ("ssm_dt", "ssm_inner"), init="scaled"),
        "dt_bias": ParamSpec(lead + (di,), la + ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec(lead + (di, n), la + ("ssm_inner", "ssm_state"), init="ones"),
        "d_skip": ParamSpec(lead + (di,), la + ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec(lead + (di, d), la + ("ssm_inner", "embed"), init="scaled"),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B, S, di), w (cw, di)."""
    cw = w.shape[0]
    s = x.shape[1]
    out = jnp.zeros_like(x)
    for i in range(cw):  # tap i multiplies x[t - (cw-1-i)]
        shifted = jnp.pad(x, ((0, 0), (cw - 1 - i, 0), (0, 0)))[:, :s]
        out = out + shifted * w[i]
    return out + b


def _ssm_params(p, x_in, cfg):
    """Common projections: returns (dt, a, b_in, c_out) for scan/step."""
    s = cfg.ssm
    r = s.dt_rank or max(cfg.d_model // 16, 1)
    xdb = x_in @ cast(p["x_proj"])  # (..., r + 2n)
    dt_r, b_ssm, c_ssm = jnp.split(xdb, [r, r + s.state], axis=-1)
    dt = jax.nn.softplus(dt_r @ cast(p["dt_proj"]) + cast(p["dt_bias"]))  # (..., di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, n)
    return dt, a, b_ssm, c_ssm


def apply_ssm(p, x, cfg, ctx):
    """Full-sequence selective scan. x: (B, S, D_in) -> (B, S, D_in)."""
    xz = x @ cast(p["in_proj"])  # (B, S, 2di)
    xz = ctx.constrain(xz, "batch", "seq", "ssm_inner")
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = jax.nn.silu(_causal_conv(x_in, cast(p["conv_w"]), cast(p["conv_b"])))
    dt, a, b_ssm, c_ssm = _ssm_params(p, x_in, cfg)

    # linear recurrence h_t = A_t h_{t-1} + B_t, associative over t
    a_bar = jnp.exp(dt[..., None].astype(jnp.float32) * a)  # (B,S,di,n)
    # b_ssm: (B,S,n) -> (B,S,1,n); dt*x: (B,S,di) -> (B,S,di,1)
    b_bar = (dt * x_in).astype(jnp.float32)[..., None] * b_ssm.astype(jnp.float32)[..., None, :]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a_bar, b_bar), axis=1)
    y = (h * c_ssm.astype(jnp.float32)[..., None, :]).sum(-1)  # (B,S,di)
    y = y.astype(x.dtype) + cast(p["d_skip"]) * x_in
    y = y * jax.nn.silu(z)
    return y @ cast(p["out_proj"])


def init_ssm_cache_shape(cfg, batch: int):
    s = cfg.ssm
    return {
        "conv": (batch, s.conv_width - 1, s.d_inner),
        "h": (batch, s.d_inner, s.state),
    }


def apply_ssm_decode(p, x, cache, cfg, ctx):
    """One-token step. x: (B, 1, D_in); cache: {'conv','h'}."""
    s = cfg.ssm
    xz = x @ cast(p["in_proj"])
    x_in, z = jnp.split(xz[:, 0], 2, axis=-1)  # (B, di)
    # conv ring: window = [conv_state, x_in]
    win = jnp.concatenate([cache["conv"], x_in[:, None]], axis=1)  # (B, cw, di)
    conv_out = (win * cast(p["conv_w"])[None]).sum(1) + cast(p["conv_b"])
    x_c = jax.nn.silu(conv_out)
    dt, a, b_ssm, c_ssm = _ssm_params(p, x_c, cfg)
    a_bar = jnp.exp(dt[..., None].astype(jnp.float32) * a)  # (B,di,n)
    b_bar = (dt * x_c).astype(jnp.float32)[..., None] * b_ssm.astype(jnp.float32)[..., None, :]
    h = a_bar * cache["h"] + b_bar  # (B,di,n)
    y = (h * c_ssm.astype(jnp.float32)[..., None, :]).sum(-1).astype(x.dtype)
    y = y + cast(p["d_skip"]) * x_c
    y = y * jax.nn.silu(z)
    out = (y @ cast(p["out_proj"]))[:, None]
    new_cache = {"conv": win[:, 1:], "h": h}
    return out, new_cache
