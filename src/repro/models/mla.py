"""Multi-head Latent Attention (DeepSeek-V2): train + absorbed decode.

The KV cache stores only the low-rank latent ``c_kv`` (kv_lora_rank) plus a
single shared RoPE key per position — the compressed-cache property that
makes MLA the serving-side analogue of the paper's "store less, serve fast"
philosophy.  Decode uses the *absorbed* formulation: W_uk folds into the
query and W_uv into the output projection, so attention runs directly in
the latent space and the cache is never expanded.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import MaskSpec, apply_norm, apply_rope, cast, flash_attention
from repro.sharding import ParamSpec


def mla_specs(cfg, layers: int):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    la = ("layers",)
    lead = (layers,)
    return {
        "wq": ParamSpec(lead + (d, h, qk), la + ("embed", "heads", "head_dim"), init="scaled"),
        "w_dkv": ParamSpec(
            lead + (d, m.kv_lora_rank + m.qk_rope_head_dim), la + ("embed", "kv_lora"), init="scaled"
        ),
        "kv_norm": ParamSpec(lead + (m.kv_lora_rank,), la + ("kv_lora",), init="ones"),
        "w_uk": ParamSpec(
            lead + (m.kv_lora_rank, h, m.qk_nope_head_dim), la + ("kv_lora", "heads", "head_dim"),
            init="scaled",
        ),
        "w_uv": ParamSpec(
            lead + (m.kv_lora_rank, h, m.v_head_dim), la + ("kv_lora", "heads", "head_dim"),
            init="scaled",
        ),
        "wo": ParamSpec(lead + (h, m.v_head_dim, d), la + ("heads", "head_dim", "embed"), init="scaled"),
    }


def _latents(p, x, cfg):
    """x -> (c_kv normalized, k_rope) latents."""
    m = cfg.mla
    dkv = x @ cast(p["w_dkv"])  # (B,S,rank+rope)
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = apply_norm({"scale": p["kv_norm"]}, c_kv, "rmsnorm")
    return c_kv, k_rope


def _queries(p, x, cfg, positions):
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def apply_mla_full(p, x, cfg, ctx, positions=None):
    """Training/prefill path (expanded keys/values). Returns (out, cache)."""
    m = cfg.mla
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    c_kv, k_rope = _latents(p, x, cfg)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,rope)
    q_nope, q_rope = _queries(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, cast(p["w_uk"]))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, cast(p["w_uv"]))
    h = cfg.num_heads
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    q = ctx.constrain(q, "batch", "seq", "heads", "head_dim")
    o = flash_attention(
        q, k, v, mask=MaskSpec(causal=True),
        scale=1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim),
    )
    out = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"]))
    cache = {"c_kv": c_kv, "k_rope": k_rope[:, :, 0]}
    return out, cache


def init_mla_cache_shape(cfg, batch: int, cache_len: int):
    m = cfg.mla
    return {"c_kv": (batch, cache_len, m.kv_lora_rank), "k_rope": (batch, cache_len, m.qk_rope_head_dim)}


def apply_mla_decode(p, x, cache, pos, cfg, ctx):
    """Absorbed single-token decode. cache: {'c_kv','k_rope'}."""
    m = cfg.mla
    b = x.shape[0]
    from repro.models.attention import cache_update

    posv = jnp.full((1,), pos)
    c_kv_new, k_rope_new = _latents(p, x, cfg)  # (B,1,rank), (B,1,rope)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], posv, cfg.rope_theta)[:, :, 0]
    c_cache = cache_update(cache["c_kv"], c_kv_new, pos, ctx, ("batch", "cache_seq", "kv_lora"))
    r_cache = cache_update(cache["k_rope"], k_rope_new, pos, ctx, ("batch", "cache_seq", "head_dim"))

    q_nope, q_rope = _queries(p, x, cfg, posv)  # (B,1,H,*)
    # absorb W_uk into the query: score space becomes the latent space
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, cast(p["w_uk"]))  # (B,1,H,rank)
    s_lat = jnp.einsum("bshr,btr->bhst", q_lat, c_cache)  # (B,H,1,S)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, r_cache)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (s_lat + s_rope).astype(jnp.float32) * scale
    s_cache_len = c_cache.shape[1]
    valid = jnp.arange(s_cache_len) <= pos
    s = jnp.where(valid[None, None, None], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs.astype(c_cache.dtype), c_cache)  # (B,1,H,rank)
    o = jnp.einsum("bshr,rhk->bshk", ctx_lat, cast(p["w_uv"]))  # absorb W_uv
    out = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"]))
    return out, {"c_kv": c_cache, "k_rope": r_cache}
