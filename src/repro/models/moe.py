"""Routed mixture-of-experts (GShard/Switch-style top-k with capacity).

Two execution paths with identical math:

* **local** (ctx.mesh is None): plain jnp, used by CPU smoke tests.
* **shard_map EP** (mesh present): experts are sharded over the ``model``
  axis (expert parallelism).  Each device routes its (data-sharded,
  model-replicated) tokens, builds a capacity-bounded buffer **only for its
  local experts**, runs the expert FFNs, and the per-rank partial outputs
  are ``psum``'d over ``model`` — one all-reduce per MoE layer, the same
  collective a Megatron row-parallel MLP costs, with expert weights also
  FSDP-sharded over ``data`` and all-gathered in-layer.

Dispatch is sort-free *scatter-by-position*: positions inside each expert
come from a stable argsort of the (token, k) expert assignments, overflow
beyond capacity is dropped (token keeps its other experts / residual),
exactly the GShard capacity-factor semantics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import cast
from repro.sharding import ParamSpec


def moe_specs(cfg, layers: int):
    m = cfg.moe
    d = cfg.d_model
    out = {
        "router": ParamSpec((layers, d, m.num_experts), ("layers", "embed_act", None), init="scaled"),
        "gate": ParamSpec(
            (layers, m.num_experts, d, m.expert_d_ff),
            ("layers", "experts", "expert_embed", "expert_mlp"), init="scaled",
        ),
        "up": ParamSpec(
            (layers, m.num_experts, d, m.expert_d_ff),
            ("layers", "experts", "expert_embed", "expert_mlp"), init="scaled",
        ),
        "down": ParamSpec(
            (layers, m.num_experts, m.expert_d_ff, d),
            ("layers", "experts", "expert_mlp", "expert_embed"), init="scaled",
        ),
    }
    if m.num_shared:
        f_sh = m.shared_d_ff or m.expert_d_ff * m.num_shared
        out["shared_gate"] = ParamSpec((layers, d, f_sh), ("layers", "embed", "mlp"), init="scaled")
        out["shared_up"] = ParamSpec((layers, d, f_sh), ("layers", "embed", "mlp"), init="scaled")
        out["shared_down"] = ParamSpec((layers, f_sh, d), ("layers", "mlp", "embed"), init="scaled")
    return out


def _route(x_flat, router_w, top_k: int):
    """(T, D) -> (idx (T,k), weights (T,k), aux_loss scalar)."""
    logits = (x_flat.astype(jnp.float32)) @ router_w.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e(fraction_e * prob_e)
    e = probs.shape[-1]
    frac = jnp.zeros(e).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = e * jnp.sum(frac * probs.mean(0))
    return idx, weights.astype(x_flat.dtype), aux


def _dispatch_indices(idx, num_experts: int, capacity: int, lo: int, hi: int):
    """(T, k) expert ids -> scatter destinations into an (hi-lo)*C buffer.

    Entries routed to experts outside [lo, hi) or beyond capacity map to the
    drop slot (= size).  Returns (dest (T*k,), src_token (T*k,)).
    """
    t, k = idx.shape
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    pos_in_e = jnp.arange(t * k) - first[sorted_e]
    local = (sorted_e >= lo) & (sorted_e < hi) & (pos_in_e < capacity)
    size = (hi - lo) * capacity
    dest_sorted = jnp.where(local, (sorted_e - lo) * capacity + pos_in_e, size)
    inv = jnp.argsort(order, stable=True)
    dest = dest_sorted[inv]  # back to (token, k) order
    src_token = jnp.arange(t * k) // k
    return dest, src_token


def _expert_ffn(buf, gate_w, up_w, down_w):
    """buf: (E_loc, C, D) -> (E_loc, C, D) via per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", buf, cast(gate_w))
    u = jnp.einsum("ecd,edf->ecf", buf, cast(up_w))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, cast(down_w))


def _moe_local(x_flat, params_l, cfg, lo: int, hi: int, capacity: int):
    """Token dispatch + expert FFN for experts [lo, hi). Pure jnp."""
    m = cfg.moe
    d = x_flat.shape[-1]
    idx, weights, aux = _route(x_flat, params_l["router"], m.top_k)
    dest, src = _dispatch_indices(idx, m.num_experts, capacity, lo, hi)
    e_loc = hi - lo
    size = e_loc * capacity
    buf = jnp.zeros((size + 1, d), x_flat.dtype).at[dest].set(x_flat[src], mode="drop")
    buf = buf[:size].reshape(e_loc, capacity, d)
    out_buf = _expert_ffn(buf, params_l["gate"][lo:hi], params_l["up"][lo:hi], params_l["down"][lo:hi])
    padded = jnp.concatenate([out_buf.reshape(size, d), jnp.zeros((1, d), x_flat.dtype)])
    vals = padded[jnp.minimum(dest, size)]
    vals = jnp.where((dest < size)[:, None], vals, 0.0)
    t = x_flat.shape[0]
    y = (vals.reshape(t, m.top_k, d) * weights[..., None]).sum(1)
    return y, aux


def apply_moe(params_l, x, cfg, ctx):
    """x: (B, S, D) -> (out, aux_loss).  params_l: this layer's slice."""
    m = cfg.moe
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)

    if ctx.mesh is None or "model" not in ctx.mesh.shape:
        capacity = max(int(math.ceil(x_flat.shape[0] * m.top_k / m.num_experts * m.capacity_factor)), m.top_k)
        y, aux = _moe_local(x_flat, params_l, cfg, 0, m.num_experts, capacity)
    else:
        mesh = ctx.mesh
        ep = mesh.shape["model"]
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        dp = math.prod(mesh.shape[a] for a in dp_axes)
        t_local = max(x_flat.shape[0] // dp, 1)
        capacity = max(int(math.ceil(t_local * m.top_k / m.num_experts * m.capacity_factor)), m.top_k)
        e_loc = m.num_experts // ep
        e_rule = ctx.rules.get("expert_embed") or ()
        fsdp_axes = tuple(a for a in e_rule if a in mesh.shape)  # FSDP over data?
        fsdp = bool(fsdp_axes) and d % dp == 0 and "model" not in e_rule

        tok_spec = P(dp_axes if x_flat.shape[0] % dp == 0 else None, None)
        w_spec = P("model", fsdp_axes, None) if fsdp else P("model", None, None)
        wd_spec = P("model", None, fsdp_axes) if fsdp else P("model", None, None)

        def shard_fn(xf, router_w, gate_w, up_w, down_w):
            rank = jax.lax.axis_index("model")
            if fsdp:
                gate_w = jax.lax.all_gather(gate_w, fsdp_axes, axis=1, tiled=True)
                up_w = jax.lax.all_gather(up_w, fsdp_axes, axis=1, tiled=True)
                down_w = jax.lax.all_gather(down_w, fsdp_axes, axis=2, tiled=True)
            idx, weights, aux = _route(xf, router_w, m.top_k)
            lo = rank * e_loc
            dest, src = _dispatch_indices(idx, m.num_experts, capacity, 0, m.num_experts)
            # localize: only this rank's expert range lands in the buffer
            local = (dest >= lo * capacity) & (dest < (lo + e_loc) * capacity)
            size = e_loc * capacity
            dest_l = jnp.where(local, dest - lo * capacity, size)
            buf = jnp.zeros((size + 1, d), xf.dtype).at[dest_l].set(xf[src], mode="drop")
            buf = buf[:size].reshape(e_loc, capacity, d)
            out_buf = _expert_ffn(buf, gate_w, up_w, down_w)
            padded = jnp.concatenate([out_buf.reshape(size, d), jnp.zeros((1, d), xf.dtype)])
            vals = padded[jnp.minimum(dest_l, size)]
            vals = jnp.where((dest_l < size)[:, None], vals, 0.0)
            t = xf.shape[0]
            y = (vals.reshape(t, m.top_k, d) * weights[..., None]).sum(1)
            y = jax.lax.psum(y, "model")  # combine expert contributions (EP)
            aux = jax.lax.pmean(aux, tuple(mesh.shape))  # replicated scalar
            return y, aux

        y, aux = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(tok_spec, P(None, None), w_spec, w_spec, wd_spec),
            out_specs=(tok_spec, P()),
            check_vma=False,
        )(x_flat, params_l["router"], params_l["gate"], params_l["up"], params_l["down"])

    out = y.reshape(b, s, d)
    # shared experts (DeepSeek): a dense SwiGLU alongside the routed path
    if m.num_shared:
        g = x @ cast(params_l["shared_gate"])
        u = x @ cast(params_l["shared_up"])
        out = out + (jax.nn.silu(g) * u) @ cast(params_l["shared_down"])
    return out, aux
