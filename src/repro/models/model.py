"""Unified model assembly: every assigned architecture behind one interface.

``build(cfg)`` returns a model object exposing:

* ``param_specs()``                         — ParamSpec tree (shapes+axes)
* ``loss(params, batch, ctx)``              — training loss (+metrics)
* ``prefill(params, inputs, ctx)``          — full forward, returns cache
* ``cache_specs(batch, cache_len)``         — ParamSpec tree for the cache
* ``decode_step(params, cache, tok, pos, ctx)`` — one-token serve step

All layer stacks run under ``jax.lax.scan`` with per-layer ``jax.checkpoint``
(remat), so HLO size is O(1) in depth and activation memory is O(sqrt)-ish.
MoE aux losses ride the scan carry.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba as ssm_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import (
    MaskSpec,
    apply_mlp,
    apply_norm,
    cast,
    mlp_specs,
    norm_specs,
)
from repro.sharding import ParamSpec


def _embed_specs(cfg):
    v = cfg.padded_vocab  # Megatron-style padding so vocab shards (see base.py)
    out = {"embed": ParamSpec((v, cfg.d_model), ("vocab", "embed"), init="normal")}
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamSpec((v, cfg.d_model), ("vocab", "embed"), init="scaled")
    return out


def _logits(params, h, cfg, ctx):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", h, cast(table))
    if cfg.padded_vocab != cfg.vocab:  # mask padding ids out of the softmax
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
    return ctx.constrain(logits, "batch", "seq", "vocab")


def _xent(logits, labels):
    """Mean cross-entropy; logits (B,S,V) bf16 -> f32 stats."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


# =============================================================================
# decoder-only LM (dense / moe / mla_moe / ssm / hybrid)
# =============================================================================
class DecoderLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- parameter tree ---------------------------------------------------------
    def _block_specs(self, layers: int):
        cfg = self.cfg
        fam = cfg.family
        out = {"ln1": norm_specs(cfg.d_model, cfg.norm, layers=layers)}
        if fam in ("dense", "moe", "hybrid"):
            out["attn"] = attn.attn_specs(cfg, layers=layers)
            if cfg.use_qk_norm:
                hd = cfg.head_dim_
                out["attn"]["q_norm"] = ParamSpec((layers, hd), ("layers", "head_dim"), init="ones")
                out["attn"]["k_norm"] = ParamSpec((layers, hd), ("layers", "head_dim"), init="ones")
        if fam == "mla_moe":
            out["mla"] = mla_mod.mla_specs(cfg, layers)
        if fam in ("ssm", "hybrid"):
            out["ssm"] = ssm_mod.ssm_specs(cfg, layers=layers)
        if fam == "hybrid":
            out["ln_attn_out"] = norm_specs(cfg.d_model, cfg.norm, layers=layers)
            out["ln_ssm_out"] = norm_specs(cfg.d_model, cfg.norm, layers=layers)
        # second half: MLP / MoE (ssm family has none — pure mamba blocks)
        if fam in ("dense", "hybrid"):
            if not cfg.parallel_block:  # command-r shares ln1 across attn+mlp
                out["ln2"] = norm_specs(cfg.d_model, cfg.norm, layers=layers)
            out["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp, layers=layers, bias=cfg.use_bias)
        elif fam in ("moe", "mla_moe"):
            out["ln2"] = norm_specs(cfg.d_model, cfg.norm, layers=layers)
            out["moe"] = moe_mod.moe_specs(cfg, layers)
        return out

    def param_specs(self):
        cfg = self.cfg
        n_dense0 = cfg.moe.first_dense_layers if cfg.moe else 0
        specs = dict(_embed_specs(cfg))
        specs["final_norm"] = norm_specs(cfg.d_model, cfg.norm)
        specs["blocks"] = self._block_specs(cfg.num_layers - n_dense0)
        if n_dense0:
            d0 = {
                "ln1": norm_specs(cfg.d_model, cfg.norm, layers=n_dense0),
                "mla": mla_mod.mla_specs(cfg, n_dense0),
                "ln2": norm_specs(cfg.d_model, cfg.norm, layers=n_dense0),
                "mlp": mlp_specs(cfg.d_model, cfg.moe.first_dense_d_ff, "swiglu", layers=n_dense0),
            }
            specs["dense0"] = d0
        return specs

    # -- one block, full-sequence ---------------------------------------------------
    def _block_full(self, block, h, ctx, *, mask: MaskSpec, dense_mlp: bool = False):
        cfg = self.cfg
        fam = cfg.family if not dense_mlp else "mla_dense"
        x = apply_norm(block["ln1"], h, cfg.norm)
        aux = jnp.zeros((), jnp.float32)

        if fam in ("dense", "moe", "hybrid"):
            a_out = self._attn_full(block["attn"], x, ctx, mask)
        if fam in ("mla_moe", "mla_dense"):
            a_out, _ = mla_mod.apply_mla_full(block["mla"], x, cfg, ctx)
        if fam == "ssm":
            a_out = ssm_mod.apply_ssm(block["ssm"], x, cfg, ctx)
        if fam == "hybrid":
            s_out = ssm_mod.apply_ssm(block["ssm"], x, cfg, ctx)
            a_out = 0.5 * (
                apply_norm(block["ln_attn_out"], a_out, cfg.norm)
                + apply_norm(block["ln_ssm_out"], s_out, cfg.norm)
            )

        if cfg.parallel_block:  # command-r: attn and mlp read the same norm
            m_out = apply_mlp(block["mlp"], x, cfg.mlp, ctx)
            return h + a_out + m_out, aux

        h = h + a_out
        if fam == "ssm":
            return h, aux
        x2 = apply_norm(block["ln2"], h, cfg.norm)
        if fam in ("moe", "mla_moe"):
            m_out, aux = moe_mod.apply_moe(block["moe"], x2, cfg, ctx)
        else:
            m_out = apply_mlp(block["mlp"], x2, cfg.mlp, ctx)
        return h + m_out, aux

    def _attn_full(self, ap, x, ctx, mask):
        return attn.attn_full(ap, x, self.cfg, ctx, mask=mask)

    # -- scan over layers --------------------------------------------------------------
    @staticmethod
    def _ckpt(fn, ctx):
        if getattr(ctx, "remat_policy", None) is not None:
            return jax.checkpoint(fn, policy=ctx.remat_policy)
        return jax.checkpoint(fn)

    def _run_stack(self, params, h, ctx, *, mask: MaskSpec):
        cfg = self.cfg

        def body(carry, layer_params):
            h, aux = carry
            h2, aux2 = self._block_full(layer_params, h, ctx, mask=mask)
            return (h2, aux + aux2), None

        if "dense0" in params:
            def body0(carry, layer_params):
                h, aux = carry
                h2, aux2 = self._block_full(layer_params, h, ctx, mask=mask, dense_mlp=True)
                return (h2, aux + aux2), None

            (h, aux0), _ = jax.lax.scan(
                self._ckpt(body0, ctx), (h, jnp.zeros((), jnp.float32)), params["dense0"]
            )
        else:
            aux0 = jnp.zeros((), jnp.float32)
        (h, aux), _ = jax.lax.scan(self._ckpt(body, ctx), (h, aux0), params["blocks"])
        return h, aux

    def _inputs_to_h(self, params, batch, ctx):
        if self.cfg.input_mode == "embeddings":
            h = cast(batch["embeddings"])
        else:
            h = cast(params["embed"])[batch["tokens"]]
        return ctx.constrain(h, "batch", "seq", "embed_act")

    # -- public: train loss ------------------------------------------------------------
    def loss(self, params, batch, ctx):
        cfg = self.cfg
        h = self._inputs_to_h(params, batch, ctx)
        h, aux = self._run_stack(params, h, ctx, mask=MaskSpec(causal=True))
        h = apply_norm(params["final_norm"], h, cfg.norm)
        logits = _logits(params, h, cfg, ctx)
        loss = _xent(logits, batch["labels"])
        if cfg.moe:
            loss = loss + cfg.moe.router_aux_weight * aux / max(cfg.num_layers, 1)
        return loss, {"xent": loss, "aux": aux}

    # -- public: prefill ------------------------------------------------------------------
    def prefill(self, params, batch, ctx):
        """Returns (last-position logits, populated cache)."""
        cfg = self.cfg
        h = self._inputs_to_h(params, batch, ctx)
        mask = MaskSpec(causal=True)
        caches = []

        def body(carry, layer_params):
            h, aux = carry
            x = apply_norm(layer_params["ln1"], h, cfg.norm)
            cache = {}
            if cfg.family in ("dense", "moe", "hybrid"):
                a_out, kv = attn.attn_prefill(layer_params["attn"], x, cfg, ctx, mask=mask)
                cache.update(kv)
            if cfg.family == "mla_moe":
                a_out, kv = mla_mod.apply_mla_full(layer_params["mla"], x, cfg, ctx)
                cache.update(kv)
            if cfg.family in ("ssm", "hybrid"):
                s_out = ssm_mod.apply_ssm(layer_params["ssm"], x, cfg, ctx)
                # terminal ssm state for decode continuation
                if cfg.family == "hybrid":
                    a_out = 0.5 * (
                        apply_norm(layer_params["ln_attn_out"], a_out, cfg.norm)
                        + apply_norm(layer_params["ln_ssm_out"], s_out, cfg.norm)
                    )
                else:
                    a_out = s_out
            if cfg.parallel_block:
                h = h + a_out + apply_mlp(layer_params["mlp"], x, cfg.mlp, ctx)
                return (h, aux), cache
            h = h + a_out
            if cfg.family == "ssm":
                return (h, aux), cache
            x2 = apply_norm(layer_params["ln2"], h, cfg.norm)
            if cfg.family in ("moe", "mla_moe"):
                m_out, aux2 = moe_mod.apply_moe(layer_params["moe"], x2, cfg, ctx)
                aux = aux + aux2
            else:
                m_out = apply_mlp(layer_params["mlp"], x2, cfg.mlp, ctx)
            return (h + m_out, aux), cache

        aux0 = jnp.zeros((), jnp.float32)
        if "dense0" in params:
            def body0(carry, lp):
                h, aux = carry
                h2, aux2 = self._block_full(lp, h, ctx, mask=mask, dense_mlp=True)
                return (h2, aux + aux2), None
            (h, aux0), _ = jax.lax.scan(self._ckpt(body0, ctx), (h, aux0), params["dense0"])
        (h, _), cache = jax.lax.scan(self._ckpt(body, ctx), (h, aux0), params["blocks"])
        h = apply_norm(params["final_norm"], h, cfg.norm)
        logits = _logits(params, h[:, -1:], cfg, ctx)
        return logits, cache

    # -- public: decode -------------------------------------------------------------------
    def cache_specs(self, batch: int, cache_len: int, *, long_mode: bool = False):
        cfg = self.cfg
        L = cfg.num_layers - (cfg.moe.first_dense_layers if cfg.moe else 0)
        out = {}
        eff_len = min(cache_len, cfg.long_window) if (long_mode and cfg.long_window) else cache_len
        if cfg.family in ("dense", "moe", "hybrid"):
            b_, s_, hkv, hd = attn.init_cache_shape(cfg, batch, eff_len)
            kv_axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
            out["k"] = ParamSpec((L, b_, s_, hkv, hd), kv_axes, dtype=jnp.bfloat16, init="zeros")
            out["v"] = ParamSpec((L, b_, s_, hkv, hd), kv_axes, dtype=jnp.bfloat16, init="zeros")
        if cfg.family == "mla_moe":
            shapes = mla_mod.init_mla_cache_shape(cfg, batch, cache_len)
            out["c_kv"] = ParamSpec((L,) + shapes["c_kv"], ("layers", "batch", "cache_seq", "kv_lora"),
                                    dtype=jnp.bfloat16, init="zeros")
            out["k_rope"] = ParamSpec((L,) + shapes["k_rope"], ("layers", "batch", "cache_seq", None),
                                      dtype=jnp.bfloat16, init="zeros")
            d0 = cfg.moe.first_dense_layers
            if d0:
                out["c_kv0"] = ParamSpec((d0,) + shapes["c_kv"], ("layers", "batch", "cache_seq", "kv_lora"),
                                         dtype=jnp.bfloat16, init="zeros")
                out["k_rope0"] = ParamSpec((d0,) + shapes["k_rope"], ("layers", "batch", "cache_seq", None),
                                           dtype=jnp.bfloat16, init="zeros")
        if cfg.family in ("ssm", "hybrid"):
            shapes = ssm_mod.init_ssm_cache_shape(cfg, batch)
            out["conv"] = ParamSpec((L,) + shapes["conv"], ("layers", "batch", "conv", "ssm_inner"),
                                    dtype=jnp.bfloat16, init="zeros")
            out["h_ssm"] = ParamSpec((L,) + shapes["h"], ("layers", "batch", "ssm_inner", "ssm_state"),
                                     dtype=jnp.float32, init="zeros")
        return out

    def decode_step(self, params, cache, tokens, pos, ctx, *, long_mode: bool = False):
        """tokens: (B, 1) int32; pos: scalar. Returns (logits, new_cache)."""
        cfg = self.cfg
        window = cfg.long_window if (long_mode and cfg.long_window) else 0
        h = cast(params["embed"])[tokens]
        h = ctx.constrain(h, "batch", "seq", "embed_act")

        def body(carry, xs):
            h, _ = carry
            lp, lc = xs
            x = apply_norm(lp["ln1"], h, cfg.norm)
            ncache = {}
            if cfg.family in ("dense", "moe", "hybrid"):
                a_out, kv = self._attn_decode(lp["attn"], x, {"k": lc["k"], "v": lc["v"]}, pos, ctx, window)
                ncache.update(kv)
            if cfg.family == "mla_moe":
                a_out, kv = mla_mod.apply_mla_decode(
                    lp["mla"], x, {"c_kv": lc["c_kv"], "k_rope": lc["k_rope"]}, pos, cfg, ctx)
                ncache.update(kv)
            if cfg.family in ("ssm", "hybrid"):
                s_out, sc = ssm_mod.apply_ssm_decode(
                    lp["ssm"], x, {"conv": lc["conv"], "h": lc["h_ssm"]}, cfg, ctx)
                ncache["conv"], ncache["h_ssm"] = sc["conv"], sc["h"]
                if cfg.family == "hybrid":
                    a_out = 0.5 * (
                        apply_norm(lp["ln_attn_out"], a_out, cfg.norm)
                        + apply_norm(lp["ln_ssm_out"], s_out, cfg.norm)
                    )
                else:
                    a_out = s_out
            if cfg.parallel_block:
                h = h + a_out + apply_mlp(lp["mlp"], x, cfg.mlp, ctx)
                return (h, jnp.zeros((), jnp.float32)), ncache
            h = h + a_out
            if cfg.family == "ssm":
                return (h, jnp.zeros((), jnp.float32)), ncache
            x2 = apply_norm(lp["ln2"], h, cfg.norm)
            if cfg.family in ("moe", "mla_moe"):
                m_out, _ = moe_mod.apply_moe(lp["moe"], x2, cfg, ctx)
            else:
                m_out = apply_mlp(lp["mlp"], x2, cfg.mlp, ctx)
            return (h + m_out, jnp.zeros((), jnp.float32)), ncache

        new_cache = dict(cache)
        if "dense0" in params:
            def body0(carry, xs):
                h, _ = carry
                lp, lc = xs
                x = apply_norm(lp["ln1"], h, cfg.norm)
                a_out, kv = mla_mod.apply_mla_decode(
                    lp["mla"], x, {"c_kv": lc["c_kv0"], "k_rope": lc["k_rope0"]}, pos, cfg, ctx)
                h = h + a_out
                x2 = apply_norm(lp["ln2"], h, cfg.norm)
                h = h + apply_mlp(lp["mlp"], x2, cfg.mlp, ctx)
                return (h, jnp.zeros((), jnp.float32)), {"c_kv0": kv["c_kv"], "k_rope0": kv["k_rope"]}

            cache0 = {"c_kv0": cache["c_kv0"], "k_rope0": cache["k_rope0"]}
            (h, _), nc0 = jax.lax.scan(body0, (h, jnp.zeros((), jnp.float32)),
                                       (params["dense0"], cache0))
            new_cache.update(nc0)

        main_cache = {k: v for k, v in cache.items() if not k.endswith("0")}
        (h, _), nc = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                  (params["blocks"], main_cache))
        new_cache.update(nc)
        h = apply_norm(params["final_norm"], h, cfg.norm)
        logits = _logits(params, h, cfg, ctx)
        return logits, new_cache

    def _attn_decode(self, ap, x, lc, pos, ctx, window):
        return attn.attn_decode(ap, x, lc, pos, self.cfg, ctx, window=window)


# =============================================================================
# encoder-decoder (whisper-style; stub audio frontend supplies frame embeds)
# =============================================================================
class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def param_specs(self):
        cfg = self.cfg
        enc = {
            "ln1": norm_specs(cfg.d_model, cfg.norm, layers=cfg.enc_layers),
            "attn": attn.attn_specs(cfg, layers=cfg.enc_layers),
            "ln2": norm_specs(cfg.d_model, cfg.norm, layers=cfg.enc_layers),
            "mlp": mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp, layers=cfg.enc_layers, bias=cfg.use_bias),
        }
        dec = {
            "ln1": norm_specs(cfg.d_model, cfg.norm, layers=cfg.num_layers),
            "self_attn": attn.attn_specs(cfg, layers=cfg.num_layers),
            "ln_x": norm_specs(cfg.d_model, cfg.norm, layers=cfg.num_layers),
            "cross_attn": attn.attn_specs(cfg, layers=cfg.num_layers, cross=True),
            "ln2": norm_specs(cfg.d_model, cfg.norm, layers=cfg.num_layers),
            "mlp": mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp, layers=cfg.num_layers, bias=cfg.use_bias),
        }
        return {
            **_embed_specs(cfg),
            "enc_blocks": enc,
            "enc_norm": norm_specs(cfg.d_model, cfg.norm),
            "dec_blocks": dec,
            "final_norm": norm_specs(cfg.d_model, cfg.norm),
        }

    def encode(self, params, frames, ctx):
        """frames: (B, S_enc, D) stub frontend output -> encoder states."""
        cfg = self.cfg
        h = cast(frames)
        h = ctx.constrain(h, "batch", "seq", "embed_act")
        mask = MaskSpec(causal=False)

        def body(h, lp):
            x = apply_norm(lp["ln1"], h, cfg.norm)
            h = h + attn.attn_full(lp["attn"], x, cfg, ctx, mask=mask)
            x2 = apply_norm(lp["ln2"], h, cfg.norm)
            h = h + apply_mlp(lp["mlp"], x2, cfg.mlp, ctx)
            return h, None

        h, _ = jax.lax.scan(DecoderLM._ckpt(body, ctx), h, params["enc_blocks"])
        return apply_norm(params["enc_norm"], h, cfg.norm)

    def _decoder(self, params, tokens, enc_out, ctx, collect_cache: bool = False):
        cfg = self.cfg
        h = cast(params["embed"])[tokens]
        h = ctx.constrain(h, "batch", "seq", "embed_act")

        def body(h, lp):
            x = apply_norm(lp["ln1"], h, cfg.norm)
            cache = {}
            if collect_cache:
                a_out, kv = attn.attn_prefill(lp["self_attn"], x, cfg, ctx, mask=MaskSpec(causal=True))
                cache.update({"k": kv["k"], "v": kv["v"]})
            else:
                a_out = attn.attn_full(lp["self_attn"], x, cfg, ctx, mask=MaskSpec(causal=True))
            h = h + a_out
            xx = apply_norm(lp["ln_x"], h, cfg.norm)
            h = h + attn.attn_full(
                lp["cross_attn"], xx, cfg, ctx, mask=MaskSpec(causal=False),
                rope=False, kv_source=enc_out,
            )
            x2 = apply_norm(lp["ln2"], h, cfg.norm)
            h = h + apply_mlp(lp["mlp"], x2, cfg.mlp, ctx)
            return h, cache if collect_cache else None

        h, caches = jax.lax.scan(DecoderLM._ckpt(body, ctx), h, params["dec_blocks"])
        return apply_norm(params["final_norm"], h, cfg.norm), caches

    def loss(self, params, batch, ctx):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"], ctx)
        h, _ = self._decoder(params, batch["tokens"], enc_out, ctx)
        logits = _logits(params, h, cfg, ctx)
        loss = _xent(logits, batch["labels"])
        return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}

    def prefill(self, params, batch, ctx):
        """prefill_32k = encoder pass over the frame sequence (+1-tok dec)."""
        enc_out = self.encode(params, batch["frames"], ctx)
        bos = jnp.zeros((enc_out.shape[0], 1), jnp.int32)
        h, caches = self._decoder(params, bos, enc_out, ctx, collect_cache=True)
        logits = _logits(params, h[:, -1:], self.cfg, ctx)
        return logits, {"k": caches["k"], "v": caches["v"], "enc_out": enc_out}

    def cache_specs(self, batch: int, cache_len: int, *, long_mode: bool = False):
        cfg = self.cfg
        b_, s_, hkv, hd = attn.init_cache_shape(cfg, batch, cache_len)
        L = cfg.num_layers
        kv_axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        return {
            "k": ParamSpec((L, b_, s_, hkv, hd), kv_axes, dtype=jnp.bfloat16, init="zeros"),
            "v": ParamSpec((L, b_, s_, hkv, hd), kv_axes, dtype=jnp.bfloat16, init="zeros"),
            "enc_out": ParamSpec((batch, cfg.enc_seq, cfg.d_model),
                                 ("batch", "frames", "embed_act"), dtype=jnp.bfloat16, init="zeros"),
        }

    def decode_step(self, params, cache, tokens, pos, ctx, *, long_mode: bool = False):
        cfg = self.cfg
        h = cast(params["embed"])[tokens]
        enc_out = cache["enc_out"]

        def body(carry, xs):
            h, _ = carry
            lp, lc = xs
            x = apply_norm(lp["ln1"], h, cfg.norm)
            a_out, kv = attn.attn_decode(lp["self_attn"], x, {"k": lc["k"], "v": lc["v"]}, pos, cfg, ctx)
            h = h + a_out
            xx = apply_norm(lp["ln_x"], h, cfg.norm)
            h = h + attn.attn_full(
                lp["cross_attn"], xx, cfg, ctx, mask=MaskSpec(causal=False),
                rope=False, kv_source=enc_out,
            )
            x2 = apply_norm(lp["ln2"], h, cfg.norm)
            h = h + apply_mlp(lp["mlp"], x2, cfg.mlp, ctx)
            return (h, jnp.zeros((), jnp.float32)), kv

        (h, _), nc = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                  (params["dec_blocks"], {"k": cache["k"], "v": cache["v"]}))
        h = apply_norm(params["final_norm"], h, cfg.norm)
        logits = _logits(params, h, cfg, ctx)
        return logits, {"k": nc["k"], "v": nc["v"], "enc_out": enc_out}


def build(cfg: ArchConfig):
    return EncDecLM(cfg) if cfg.is_encdec else DecoderLM(cfg)
