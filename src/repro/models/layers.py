"""Shared layers: norms, RoPE, MLPs, embeddings, chunked (flash) attention.

Everything is a pure function over explicit param pytrees; parameter shapes
live in ParamSpec trees (see ``repro.sharding``).  Compute dtype is bf16
(params f32, cast at use — standard mixed precision), softmax/norm
statistics in f32.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.sharding import ParamSpec

COMPUTE_DTYPE = jnp.bfloat16


def cast(x):
    return x.astype(COMPUTE_DTYPE)


# -- norms ---------------------------------------------------------------------
def norm_specs(d: int, kind: str, prefix_axes=("layers",), layers: int | None = None):
    shape = ((layers,) if layers else ()) + (d,)
    axes = (prefix_axes if layers else ()) + ("embed_act",)
    out = {"scale": ParamSpec(shape, axes, init="ones")}
    if kind == "layernorm":
        out["bias"] = ParamSpec(shape, axes, init="zeros")
    return out


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
        out = out + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# -- rotary position embeddings --------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) broadcastable to x.shape[:-2]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # heads axis; batch dims left-broadcast
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLPs -------------------------------------------------------------------------
def mlp_specs(d: int, f: int, kind: str, layers: int | None = None, bias: bool = False):
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    out = {}
    if kind == "swiglu":
        out["gate"] = ParamSpec(lead + (d, f), lax_ + ("embed", "mlp"), init="scaled")
        out["up"] = ParamSpec(lead + (d, f), lax_ + ("embed", "mlp"), init="scaled")
        out["down"] = ParamSpec(lead + (f, d), lax_ + ("mlp", "embed"), init="scaled")
    else:  # gelu
        out["up"] = ParamSpec(lead + (d, f), lax_ + ("embed", "mlp"), init="scaled")
        out["down"] = ParamSpec(lead + (f, d), lax_ + ("mlp", "embed"), init="scaled")
        if bias:
            out["up_b"] = ParamSpec(lead + (f,), lax_ + ("mlp",), init="zeros")
            out["down_b"] = ParamSpec(lead + (d,), lax_ + ("embed_act",), init="zeros")
    return out


def apply_mlp(params, x, kind: str, ctx=None):
    if kind == "swiglu":
        g = x @ cast(params["gate"])
        u = x @ cast(params["up"])
        if ctx is not None:
            g = ctx.constrain(g, "batch", "seq", "mlp")
            u = ctx.constrain(u, "batch", "seq", "mlp")
        h = jax.nn.silu(g) * u
    else:
        h = x @ cast(params["up"])
        if "up_b" in params:
            h = h + cast(params["up_b"])
        if ctx is not None:
            h = ctx.constrain(h, "batch", "seq", "mlp")
        h = jax.nn.gelu(h)
    out = h @ cast(params["down"])
    if "down_b" in params:
        out = out + cast(params["down_b"])
    return out


# -- chunked (flash-style) attention ------------------------------------------------
NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    causal: bool = True
    window: int = 0  # 0 = unlimited; >0 = sliding window (causal only)


def _block_mask(q_pos, k_pos, spec: MaskSpec):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if spec.causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if spec.window:
        m &= q_pos[:, None] - k_pos[None, :] < spec.window
    return m


def flash_attention(
    q, k, v, *, mask: MaskSpec, q_positions=None, k_positions=None,
    q_chunk: int = 1024, kv_chunk: int = 1024, scale: float | None = None,
):
    """Memory-chunked attention with online softmax (pure JAX, lax.scan).

    q: (B, Sq, H, hd); k: (B, Sk, Hkv, hd); v: (B, Sk, Hkv, hd_v) with
    H % Hkv == 0 (hd_v may differ from hd, e.g. MLA).
    Returns (B, Sq, H, hd_v).  Memory high-water: one (B, H, qc, kc) block.
    """
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    if hkv != h:
        # Expand KV to full query heads: the (hkv, g) factorization breaks
        # XLA head-sharding whenever neither factor divides the model axis
        # (e.g. command-r 96 = 8 x 12 on a 16-way mesh -> replicated score
        # blocks, +17 GB/device).  Flat heads shard; KV expansion is a small
        # transient relative to the score traffic it keeps sharded.
        g = h // hkv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        hkv = h
    g = 1
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if q_positions is None:
        q_positions = jnp.arange(sq)
    if k_positions is None:
        k_positions = jnp.arange(sk)

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    nq, nk = -(-sq // qc), -(-sk // kc)
    pad_q, pad_k = nq * qc - sq, nk * kc - sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad_k), constant_values=2**30)

    # (B, nq, qc, Hkv, g, hd)
    qr = q.reshape(b, nq, qc, hkv, g, hd)
    kr = k.reshape(b, nk, kc, hkv, hd)
    vr = v.reshape(b, nk, kc, hkv, hd_v)
    qp = q_positions.reshape(nq, qc)
    kp = k_positions.reshape(nk, kc)

    def q_block(qi):
        qb = qr[:, qi]  # (B, qc, Hkv, g, hd)
        qpos = qp[qi]

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kb, vb, kpos = kr[:, ki], vr[:, ki], kp[ki]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32) * scale
            mask_blk = _block_mask(qpos, kpos, mask)
            s = jnp.where(mask_blk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qc, hd_v), jnp.float32)
        # remat: backward recomputes the (qc, kc) score block instead of
        # storing one per kv step (flash-attention backward semantics)
        (m_f, l_f, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out.astype(q.dtype)  # bf16 at the map boundary (stacked nq x block)

    blocks = jax.lax.map(q_block, jnp.arange(nq))  # (nq, B, Hkv, g, qc, hd)
    out = jnp.moveaxis(blocks, 0, 1)  # (B, nq, Hkv, g, qc, hd)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, nq * qc, h, hd_v)
    return out[:, :sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, k_positions, cur_pos, *, window: int = 0,
                     scale: float | None = None):
    """Single-token attention against a cache.

    q: (B, 1, H, hd); caches: (B, S, Hkv, hd); k_positions: (S,) absolute
    positions held in each cache slot (ring buffers permute them);
    cur_pos: scalar current position.  Masked to k_pos <= cur_pos (and
    sliding window if set).
    """
    b, _, h, hd = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qr = q.reshape(b, hkv, g, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache).astype(jnp.float32) * scale
    valid = k_positions <= cur_pos
    if window:
        valid &= k_positions > cur_pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, hd)
