"""GQA/MHA attention module: specs + train / prefill / decode paths."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (
    MaskSpec,
    apply_rope,
    cast,
    decode_attention,
    flash_attention,
)
from repro.sharding import ParamSpec, logical_to_spec


def cache_update(cache, new, slot, ctx, axes: tuple[str, ...]):
    """dynamic_update_slice into a cache whose seq dim may be sharded.

    A plain DUS with a traced index into a sharded dimension makes XLA SPMD
    all-gather the whole cache (gigabytes per layer).  When ``cache_seq`` is
    sharded we instead shard_map the update: each rank checks whether the
    slot lands in its shard and writes locally — zero communication.
    """
    seq_dim = axes.index("cache_seq")
    mesh = ctx.mesh if ctx is not None else None
    start = [0] * cache.ndim

    def plain():
        start[seq_dim] = slot
        return jax.lax.dynamic_update_slice(cache, new, tuple(start))

    if mesh is None:
        return plain()
    spec = logical_to_spec(axes, cache.shape, ctx.rules, mesh)
    parts = list(spec) + [None] * (cache.ndim - len(spec))
    seq_axis = parts[seq_dim]
    if seq_axis is None:
        return plain()
    new_parts = list(parts)
    new_parts[seq_dim] = None
    cache_spec, new_spec = P(*parts), P(*new_parts)

    def fn(c, n, s):
        rank = jax.lax.axis_index(seq_axis)
        s_loc = c.shape[seq_dim]
        off = s - rank * s_loc
        safe = jnp.clip(off, 0, s_loc - 1)
        st = [0] * c.ndim
        st[seq_dim] = safe
        old = jax.lax.dynamic_slice(c, st, n.shape)
        val = jnp.where((off >= 0) & (off < s_loc), n, old)
        return jax.lax.dynamic_update_slice(c, val, tuple(st))

    return jax.shard_map(
        fn, mesh=mesh, in_specs=(cache_spec, new_spec, P()), out_specs=cache_spec,
        check_vma=False,
    )(cache, new, slot)


def attn_specs(cfg, layers: int | None = None, cross: bool = False):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()
    out = {
        "wq": ParamSpec(lead + (d, h, hd), lax_ + ("embed", "heads", "head_dim"), init="scaled"),
        "wk": ParamSpec(lead + (d, hkv, hd), lax_ + ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wv": ParamSpec(lead + (d, hkv, hd), lax_ + ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wo": ParamSpec(lead + (h, hd, d), lax_ + ("heads", "head_dim", "embed"), init="scaled"),
    }
    if cfg.use_bias:
        out["bq"] = ParamSpec(lead + (h, hd), lax_ + ("heads", "head_dim"), init="zeros")
        out["bk"] = ParamSpec(lead + (hkv, hd), lax_ + ("kv_heads", "head_dim"), init="zeros")
        out["bv"] = ParamSpec(lead + (hkv, hd), lax_ + ("kv_heads", "head_dim"), init="zeros")
        out["bo"] = ParamSpec(lead + (d,), lax_ + ("embed_act",), init="zeros")
    return out


def _qkv(params, x, kv_source=None):
    from repro.models.layers import apply_norm

    kv_in = x if kv_source is None else kv_source
    q = jnp.einsum("bsd,dhk->bshk", x, cast(params["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", kv_in, cast(params["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", kv_in, cast(params["wv"]))
    if "bq" in params:
        q = q + cast(params["bq"])
        k = k + cast(params["bk"])
        v = v + cast(params["bv"])
    if "q_norm" in params:  # qwen3-style per-head q/k RMSNorm
        q = apply_norm({"scale": params["q_norm"]}, q, "rmsnorm")
        k = apply_norm({"scale": params["k_norm"]}, k, "rmsnorm")
    return q, k, v


def _out(params, o):
    res = jnp.einsum("bshk,hkd->bsd", o, cast(params["wo"]))
    if "bo" in params:
        res = res + cast(params["bo"])
    return res


def attn_full(params, x, cfg, ctx, *, positions=None, mask: MaskSpec | None = None,
              rope: bool = True, kv_source=None, kv_positions=None):
    """Training / encoder path over a full sequence (chunked internally)."""
    b, s, _ = x.shape
    mask = mask or MaskSpec(causal=True)
    q, k, v = _qkv(params, x, kv_source)
    q = ctx.constrain(q, "batch", "seq", "heads", "head_dim")
    k = ctx.constrain(k, "batch", "seq", "kv_heads", "head_dim")
    if positions is None:
        positions = jnp.arange(s)
    if kv_positions is None:
        kv_positions = jnp.arange(k.shape[1])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    o = flash_attention(q, k, v, mask=mask, q_positions=positions, k_positions=kv_positions)
    return _out(params, o)


def init_cache_shape(cfg, batch: int, cache_len: int):
    return (batch, cache_len, cfg.num_kv_heads, cfg.head_dim_)


def attn_prefill(params, x, cfg, ctx, *, mask: MaskSpec | None = None, rope: bool = True):
    """Like attn_full but also returns the populated KV cache (pre-rope k)."""
    b, s, _ = x.shape
    mask = mask or MaskSpec(causal=True)
    q, k, v = _qkv(params, x)
    positions = jnp.arange(s)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, mask=mask, q_positions=positions, k_positions=positions)
    return _out(params, o), {"k": k, "v": v}


def attn_decode(params, x, cache, pos, cfg, ctx, *, window: int = 0, rope: bool = True):
    """x: (B, 1, D); cache: {'k','v'}: (B, S, Hkv, hd); pos: scalar int.

    Uses a ring buffer when `window > 0` (slot = pos % S), otherwise writes
    at `pos`.  Returns (out, new_cache).
    """
    q, k, v = _qkv(params, x)
    if rope:
        posv = jnp.full((1,), pos)
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
    s_cache = cache["k"].shape[1]
    slot = pos % s_cache if window else pos
    kv_axes = ("batch", "cache_seq", "kv_heads", "head_dim")
    k_new = cache_update(cache["k"], k, slot, ctx, kv_axes)
    v_new = cache_update(cache["v"], v, slot, ctx, kv_axes)
    if window:
        # ring buffer: slot i holds absolute position i + S*floor(...) —
        # reconstruct: positions = slot_idx + S * ((pos - slot_idx) // S)
        idx = jnp.arange(s_cache)
        k_positions = idx + s_cache * ((pos - idx + s_cache) // s_cache) - s_cache
        k_positions = jnp.where(k_positions < 0, 2**30, k_positions)  # unwritten
    else:
        k_positions = jnp.arange(s_cache)
    o = decode_attention(q, k_new, v_new, k_positions, pos, window=window)
    return _out(params, o), {"k": k_new, "v": v_new}
