"""Logical-axis sharding (MaxText-style rules with divisibility fallback).

Every parameter / activation dimension carries a *logical* name
(``'embed'``, ``'heads'``, ``'vocab'``, ``'batch'``, …).  A per-config rule
table maps logical names to mesh axes.  ``logical_to_spec`` drops any
mapping whose mesh-axis product does not divide the dimension (e.g. hymba's
25 heads on a 16-way model axis -> replicated), which is what lets one model
zoo serve ten architectures and three mesh layouts without per-arch
special cases.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# -- default rule tables -------------------------------------------------------
# TRAIN: weights TP over 'model' + FSDP over ('pod','data') on the d_model
# axis (gathered per scanned layer); activations batch over ('pod','data').
TRAIN_RULES: dict[str, tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": ("pod", "data"),  # FSDP shard of weights' d_model dim
    "expert_embed": ("pod", "data"),  # MoE expert weights' d_model dim
    "embed_act": None,  # activations' d_model dim stays replicated
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_mlp": None,
    "ssm_inner": ("model",),
    "ssm_state": None,
    "ssm_dt": None,
    "layers": None,
    "q_lora": None,
    "kv_lora": None,
    "conv": None,
    "frames": None,
    "cache_seq": None,
    "window": None,
}

# PREFILL (compute-bound): Megatron TP over 'model' (heads/mlp/vocab),
# weights replicated over 'data'; KV cache written out sequence-sharded.
SERVE_RULES = dict(TRAIN_RULES)
SERVE_RULES.update({
    "embed": None,
    "expert_embed": None,
    "batch": ("pod", "data"),
    "cache_seq": ("model",),
    "heads": ("model",),
})

# DECODE (memory-bound, tiny activations).  §Perf-optimized default:
# weights row-parallel over 'model' only — the original 2D ('data','model')
# variant made XLA all-gather 400 GB of weights per step for command-r
# (kept as the recorded baseline in results/dryrun; see EXPERIMENTS.md §Perf,
# cr_decode_tp: collective term 8.04 s -> 0.004 s).  KV cache stays
# sequence-sharded over 'model' with a shard_map-local update.
DECODE_RULES = dict(TRAIN_RULES)
DECODE_RULES.update({
    "embed": ("model",),
    "expert_embed": None,  # expert weights stay EP-sharded only (no re-gather)
    "heads": None,
    "kv_heads": None,
    "mlp": None,
    "vocab": ("model",),
    "batch": ("pod", "data"),
    "cache_seq": ("model",),
})

# long-context decode (global_batch=1): batch replicated, state TP-sharded
LONG_RULES = dict(DECODE_RULES)
LONG_RULES.update({"batch": None})


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + logical axes + init."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _axis_size(mesh: Mesh, names: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[n] for n in names)


def logical_to_spec(
    axes: tuple[str | None, ...],
    shape: tuple[int, ...],
    rules: dict[str, tuple[str, ...] | None],
    mesh: Mesh,
) -> P:
    """Build a PartitionSpec, dropping non-dividing or conflicting axes."""
    used: set[str] = set()
    parts: list[tuple[str, ...] | None] = []
    for dim, name in zip(shape, axes):
        entry = rules.get(name) if name else None
        if entry is None:
            parts.append(None)
            continue
        entry = tuple(a for a in entry if a in mesh.shape and a not in used)
        if not entry or dim % _axis_size(mesh, entry) != 0:
            parts.append(None)
            continue
        used.update(entry)
        parts.append(entry if len(entry) > 1 else entry[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_pspecs(specs, rules, mesh):
    """Pytree of ParamSpec -> pytree of PartitionSpec."""
    return jax.tree.map(
        lambda s: logical_to_spec(s.axes, s.shape, rules, mesh),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def tree_shardings(specs, rules, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, logical_to_spec(s.axes, s.shape, rules, mesh)),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def tree_shape_structs(specs, sharding_tree=None):
    """Pytree of ParamSpec -> ShapeDtypeStruct (for .lower() without alloc)."""
    if sharding_tree is None:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
            specs,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs,
        sharding_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def init_params(specs, key: jax.Array, dtype=None):
    """Materialize parameters (smoke tests / real training, not dry-runs)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        dt = dtype or spec.dtype
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dt))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dt))
        else:
            scale = spec.scale
            if spec.init == "scaled" and len(spec.shape) >= 2:
                scale = 1.0 / math.sqrt(spec.shape[-2])
            out.append((jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def with_sharding_constraint(x, axes: tuple[str | None, ...], rules, mesh):
    """Activation constraint by logical axes (no-op off-mesh)."""
    if mesh is None:
        return x
    spec = logical_to_spec(axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


class AxisCtx:
    """Threaded through model code so layers can annotate activations."""

    def __init__(self, rules=None, mesh: Mesh | None = None, remat_policy=None):
        self.rules = rules or TRAIN_RULES
        self.mesh = mesh
        self.remat_policy = remat_policy  # jax.checkpoint policy (perf knob)

    def constrain(self, x, *axes):
        if self.mesh is None:
            return x
        return with_sharding_constraint(x, axes, self.rules, self.mesh)
