"""simlint — AST rules that keep the sim path bit-deterministic.

The whole reproduction hangs on one property: a discrete-event run is a
pure function of its seed.  The determinism digest
(``ReplayResult.digest``) can tell you *that* two runs diverged, never
*where*; these rules flag the constructs that historically cause such
divergence — wall-clock reads, unseeded RNG, set-iteration order,
``id()`` tie-breaks, leaked resource slots, swallowed ``GeneratorExit``,
dict-order float reductions, and out-of-band mutation of engine-owned
accounting — at the line that introduces them.

Scope: only *sim-path* packages under ``src/repro`` are linted
(``net/``, ``storage/``, ``core/``, ``scenarios/``).  Host-path code
(``train/``, ``launch/``, ``kernels/`` …) legitimately reads wall-clock
and machine RNG; it is excluded by path, not by pragma — see
``docs/simlint.md``.

Suppression, two tiers:

* a pragma on (or one line above) the offending line::

      t0 = time.perf_counter()  # simlint: ok SIM001 wall telemetry only

  The reason is mandatory — a bare ``# simlint: ok SIM001`` still
  reports (with a "pragma missing reason" note).
* the committed baseline (``simlint.baseline`` next to this file) for
  grandfathered benign hits, keyed by ``path:rule:scope`` (no line
  numbers, so unrelated edits don't churn it).  ``--check`` fails on
  *new* findings AND on *stale* baseline entries, so the baseline can
  only shrink.

Implementation is stdlib-only (``ast`` + ``tokenize``): no new deps.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
import tokenize
from collections import Counter

#: packages under src/repro that run inside (or feed) the event loop.
SIM_SCOPE_PACKAGES = ("net", "storage", "core", "scenarios")

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
BASELINE_PATH = pathlib.Path(__file__).with_name("simlint.baseline")

RULES = {
    "SIM001": "wall-clock read in sim-path code",
    "SIM002": "module-level / unseeded RNG instead of a threaded Generator",
    "SIM003": "iteration over an unordered set feeding downstream order",
    "SIM004": "id()/hash() identity used where a stable key is needed",
    "SIM005": "Acquire without a try/finally-guarded Release in a task",
    "SIM006": "bare/broad except that can swallow GeneratorExit in a task",
    "SIM007": "dict-order-dependent reduction over .values()/.items()",
    "SIM008": "engine-owned resource/link accounting mutated off-loop",
}

_PRAGMA_RE = re.compile(
    r"#\s*simlint:\s*ok\s+(?P<rules>SIM\d{3}(?:\s*,\s*SIM\d{3})*)(?P<reason>.*)"
)

_WALL_CLOCK_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.process_time_ns",
}
_DATETIME_TAILS = {"now", "utcnow", "today"}

_RANDOM_MODULE_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "expovariate",
    "betavariate", "seed", "getrandbits", "triangular", "vonmisesvariate",
}
_NP_LEGACY_FNS = {
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "poisson", "exponential", "binomial", "seed",
    "bytes", "geometric", "gamma", "beta",
}

_SET_METHODS = {"union", "intersection", "difference", "symmetric_difference"}
#: call wrappers whose argument order is *observable* downstream — feeding
#: one of these a set leaks hash order into scheduling / results.
_ORDER_SINK_CALLS = {"list", "tuple", "iter", "enumerate", "sum", "reversed"}

#: Resource/link telemetry the event loop owns; writes anywhere else are
#: almost certainly bypassing Acquire/Release (or Backbone.transfer).
_RESOURCE_ATTRS = {
    "in_use", "in_use_by_class", "acquired", "acquired_by_class",
    "wait_ms_total", "wait_ms_by_class", "max_queue",
}
_LINK_ATTRS = {"link_bytes", "nic_bytes"}
_RESOURCE_OWNER = "events.py"
_LINK_OWNER = "backbone.py"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule hit, printable and baseline-addressable."""

    path: str  # repo-relative posix path
    line: int
    col: int
    rule: str
    message: str
    scope: str  # enclosing function qualname, or "<module>"

    @property
    def baseline_key(self) -> str:
        return f"{self.path}:{self.rule}:{self.scope}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message} [in {self.scope}]")


def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _contains_yield(node: ast.AST) -> bool:
    """True iff ``node`` yields in *this* function (nested defs excluded)."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        if isinstance(child, (ast.Yield, ast.YieldFrom)):
            return True
        if _contains_yield(child):
            return True
    return False


def _handler_types(handler: ast.ExceptHandler) -> list[str]:
    t = handler.type
    if t is None:
        return ["<bare>"]
    elts = t.elts if isinstance(t, ast.Tuple) else [t]
    return [d for d in (_dotted(e) for e in elts) if d is not None]


def _has_bare_raise(node: ast.AST) -> bool:
    for child in ast.walk(node):
        if isinstance(child, ast.Raise) and child.exc is None:
            return True
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, filename: str):
        self.path = path
        self.filename = filename
        self.findings: list[Finding] = []
        self.scope_stack: list[str] = []
        # per-function: is it a generator (sim task)?
        self.genfunc_stack: list[bool] = []
        # alias -> canonical module name ("import numpy as np")
        self.module_aliases: dict[str, str] = {}
        # bare name -> canonical dotted origin ("from time import time")
        self.from_imports: dict[str, str] = {}
        # nodes inside a `finally:` block (SIM005)
        self.finally_depth = 0

    # -- bookkeeping -----------------------------------------------------------
    @property
    def scope(self) -> str:
        return ".".join(self.scope_stack) or "<module>"

    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(Finding(
            path=self.path, line=node.lineno, col=node.col_offset,
            rule=rule, message=message, scope=self.scope,
        ))

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            self.module_aliases[alias.asname or alias.name.split(".")[0]] = \
                alias.name
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module:
            for alias in node.names:
                self.from_imports[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"
        self.generic_visit(node)

    def _resolve(self, dotted: str | None) -> str | None:
        """Map through import aliases: 'np.random.rand' -> 'numpy.random.rand'."""
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        if head in self.from_imports:
            origin = self.from_imports[head]
            return f"{origin}.{rest}" if rest else origin
        if head in self.module_aliases:
            mod = self.module_aliases[head]
            return f"{mod}.{rest}" if rest else mod
        return dotted

    # -- function scopes (SIM005 / SIM006 need generator-ness) -----------------
    def _visit_func(self, node) -> None:
        self.scope_stack.append(node.name)
        self.genfunc_stack.append(_contains_yield(node))
        self._check_sim005(node)
        self.generic_visit(node)
        self.genfunc_stack.pop()
        self.scope_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.scope_stack.append(node.name)
        self.generic_visit(node)
        self.scope_stack.pop()

    # -- SIM001 / SIM002 / SIM003(sinks) / SIM004 / SIM007 ---------------------
    def visit_Call(self, node: ast.Call) -> None:
        raw = _dotted(node.func)
        resolved = self._resolve(raw)

        if resolved is not None:
            self._check_sim001(node, resolved)
            self._check_sim002(node, resolved)

        if isinstance(node.func, ast.Name):
            if node.func.id == "id" and node.args:
                self._emit(node, "SIM004",
                           "id() is a memory address — not stable across runs; "
                           "order by an explicit (priority, seq) key instead")
            elif node.func.id == "hash" and node.args:
                self._emit(node, "SIM004",
                           "hash() of str/bytes depends on PYTHONHASHSEED; "
                           "use a stable key (sorted tuple, explicit id) instead")
            elif node.func.id in _ORDER_SINK_CALLS and node.args:
                if self._is_unordered(node.args[0]):
                    self._emit(node, "SIM003",
                               f"{node.func.id}() over a set leaks hash order "
                               "downstream; wrap in sorted(...) first")
        if (isinstance(node.func, ast.Attribute) and node.func.attr == "join"
                and node.args and self._is_unordered(node.args[0])):
            self._emit(node, "SIM003",
                       "str.join over a set leaks hash order; sort first")

        self._check_sim007(node, resolved)
        self.generic_visit(node)

    def _check_sim001(self, node: ast.Call, resolved: str) -> None:
        hit = resolved in _WALL_CLOCK_CALLS
        if not hit and resolved.startswith("datetime."):
            hit = resolved.rsplit(".", 1)[-1] in _DATETIME_TAILS
        if hit:
            self._emit(node, "SIM001",
                       f"{resolved}() reads the wall clock — sim code must "
                       "derive time from loop.now (or gate telemetry behind "
                       "a pragma)")

    def _check_sim002(self, node: ast.Call, resolved: str) -> None:
        parts = resolved.split(".")
        if parts[0] == "random" and len(parts) == 2 and parts[1] in _RANDOM_MODULE_FNS:
            self._emit(node, "SIM002",
                       f"{resolved}() uses the process-global RNG; thread a "
                       "seeded np.random.Generator (or random.Random(seed)) "
                       "through instead")
        elif (len(parts) >= 3 and parts[-3] in ("numpy", "np")
                and parts[-2] == "random" and parts[-1] in _NP_LEGACY_FNS):
            self._emit(node, "SIM002",
                       f"{resolved}() hits numpy's legacy global RNG; use a "
                       "seeded default_rng(seed) Generator")
        elif parts[-1] == "default_rng" and "random" in parts and not node.args:
            self._emit(node, "SIM002",
                       "default_rng() without a seed draws OS entropy; pass "
                       "an explicit seed derived from the run seed")

    def _is_unordered(self, expr: ast.AST) -> bool:
        """Expressions whose iteration order is hash-dependent."""
        if isinstance(expr, (ast.Set, ast.SetComp)):
            return True
        if isinstance(expr, ast.Call):
            d = _dotted(expr.func)
            if d in ("set", "frozenset"):
                return True
            if (isinstance(expr.func, ast.Attribute)
                    and expr.func.attr in _SET_METHODS):
                return True
        if isinstance(expr, ast.BinOp) and isinstance(
                expr.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            # a | b, a - b … where either side is visibly a set
            return self._is_unordered(expr.left) or self._is_unordered(expr.right)
        return False

    def visit_For(self, node: ast.For) -> None:
        if self._is_unordered(node.iter):
            self._emit(node, "SIM003",
                       "iterating a set: order follows hash seed / insertion "
                       "history, not a stable key — use sorted(...)")
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            if self._is_unordered(gen.iter):
                self._emit(node, "SIM003",
                           "comprehension over a set leaks hash order into "
                           "the result; use sorted(...)")
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_GeneratorExp = _visit_comp
    visit_DictComp = _visit_comp

    def _check_sim007(self, node: ast.Call, resolved: str | None) -> None:
        if resolved not in ("sum", "math.fsum", "fsum"):
            return
        if not node.args:
            return
        arg = node.args[0]
        if self._is_dict_view(arg):
            self._emit(node, "SIM007",
                       "reduction over dict .values()/.items(): float sums "
                       "are order-sensitive — iterate sorted(d) (or pragma "
                       "if provably integer/commutative)")
        elif isinstance(arg, (ast.GeneratorExp, ast.ListComp)):
            if any(self._is_dict_view(g.iter) for g in arg.generators):
                # len(...) elements are exact ints: order can't matter
                elt = arg.elt
                if isinstance(elt, ast.Call) and _dotted(elt.func) == "len":
                    return
                self._emit(node, "SIM007",
                           "reduction over dict .values()/.items(): float "
                           "sums are order-sensitive — iterate sorted(d) "
                           "(or pragma if provably integer/commutative)")

    @staticmethod
    def _is_dict_view(expr: ast.AST) -> bool:
        return (isinstance(expr, ast.Call)
                and isinstance(expr.func, ast.Attribute)
                and expr.func.attr in ("values", "items"))

    # -- SIM005: Acquire without finally-guarded Release -----------------------
    def _check_sim005(self, node) -> None:
        acquires: list[ast.AST] = []
        releases: list[tuple[ast.AST, bool]] = []

        def walk(n: ast.AST, in_finally: bool) -> None:
            for child in ast.iter_child_nodes(n):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                    continue
                if isinstance(child, (ast.Yield, ast.YieldFrom)) \
                        and child.value is not None:
                    d = _dotted(getattr(child.value, "func", None)) \
                        if isinstance(child.value, ast.Call) else None
                    if d is not None:
                        tail = d.rsplit(".", 1)[-1]
                        if tail == "Acquire":
                            acquires.append(child)
                        elif tail in ("Release", "safe_release"):
                            # `yield from safe_release(Release(...))` is the
                            # close-safe finally idiom (see events.safe_release)
                            releases.append((child, in_finally))
                if isinstance(child, ast.Try):
                    for part in (child.body, child.handlers, child.orelse):
                        for sub in part:
                            walk(sub, in_finally)
                    for sub in child.finalbody:
                        walk(sub, True)
                else:
                    walk(child, in_finally)

        walk(node, False)
        if not acquires:
            return
        if not releases:
            for acq in acquires:
                self._emit(acq, "SIM005",
                           "task acquires a resource slot but never yields "
                           "Release — a thrown exception leaks the slot; "
                           "wrap the critical section in try/finally")
        elif not any(fin for _, fin in releases):
            for acq in acquires:
                self._emit(acq, "SIM005",
                           "Release is not inside a finally: block — an "
                           "exception between Acquire and Release leaks the "
                           "slot; use try/finally")

    # -- SIM006: except clauses that can swallow GeneratorExit -----------------
    def visit_Try(self, node: ast.Try) -> None:
        in_genfunc = bool(self.genfunc_stack) and self.genfunc_stack[-1]
        body_yields = any(_contains_yield(s) for s in node.body)
        control_flow_reraised = any(
            ("GeneratorExit" in _handler_types(han)
             or "KeyboardInterrupt" in _handler_types(han))
            and _has_bare_raise(han)
            for han in node.handlers
        )
        for han in node.handlers:
            types = _handler_types(han)
            if "<bare>" in types or "BaseException" in types:
                if not _has_bare_raise(han):
                    self._emit(han, "SIM006",
                               "bare/BaseException except swallows "
                               "GeneratorExit and KeyboardInterrupt — catch "
                               "Exception, or re-raise control-flow "
                               "exceptions explicitly")
            elif (in_genfunc and body_yields and "Exception" in types
                  and not control_flow_reraised):
                self._emit(han, "SIM006",
                           "broad `except Exception` around a yielding "
                           "region in a loop task: add `except "
                           "(GeneratorExit, KeyboardInterrupt): raise` above "
                           "it so task teardown/interrupt always propagates")
        self.generic_visit(node)

    # -- SIM008: off-loop mutation of engine-owned accounting ------------------
    def _check_sim008_target(self, target: ast.AST, node: ast.AST) -> None:
        if not isinstance(target, ast.Attribute):
            return
        attr = target.attr
        if attr in _RESOURCE_ATTRS and self.filename != _RESOURCE_OWNER:
            self._emit(node, "SIM008",
                       f"direct write to Resource.{attr} outside the event "
                       "loop engine — go through Acquire/Release effects so "
                       "accounting (and simsan) stays consistent")
        elif attr in _LINK_ATTRS and self.filename not in (
                _LINK_OWNER, _RESOURCE_OWNER):
            self._emit(node, "SIM008",
                       f"direct write to link accounting .{attr} outside the "
                       "backbone — use Backbone.transfer / Transfer effects")

    def visit_Assign(self, node: ast.Assign) -> None:
        for target in node.targets:
            self._check_sim008_target(target, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_sim008_target(node.target, node)
        self.generic_visit(node)


# -- pragmas ---------------------------------------------------------------------
def _collect_pragmas(source: str) -> dict[int, tuple[set[str], bool]]:
    """line -> (suppressed rules, has_reason)."""
    out: dict[int, tuple[set[str], bool]] = {}
    try:
        tokens = tokenize.generate_tokens(iter(source.splitlines(True)).__next__)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _PRAGMA_RE.search(tok.string)
            if m is None:
                continue
            rules = {r.strip() for r in m.group("rules").split(",")}
            has_reason = bool(m.group("reason").strip())
            out[tok.start[0]] = (rules, has_reason)
    except tokenize.TokenError:
        pass
    return out


def _apply_pragmas(findings: list[Finding], source: str) -> list[Finding]:
    pragmas = _collect_pragmas(source)
    kept: list[Finding] = []
    for f in findings:
        hit = pragmas.get(f.line) or pragmas.get(f.line - 1)
        if hit is not None and f.rule in hit[0]:
            if hit[1]:
                continue  # suppressed with a reason
            f = dataclasses.replace(
                f, message=f.message + " (pragma present but missing a "
                                       "reason — add one after the rule code)")
        kept.append(f)
    return kept


# -- entry points ----------------------------------------------------------------
def in_scope(path: pathlib.Path, root: pathlib.Path = REPO_ROOT) -> bool:
    """Sim-path test: src/repro/{net,storage,core,scenarios}/**.py only."""
    try:
        rel = path.resolve().relative_to(root / "src" / "repro")
    except ValueError:
        return False
    return bool(rel.parts) and rel.parts[0] in SIM_SCOPE_PACKAGES


def lint_source(source: str, path: str) -> list[Finding]:
    """Lint one file's source; ``path`` is repo-relative (posix)."""
    tree = ast.parse(source, filename=path)
    linter = _Linter(path=path, filename=pathlib.PurePosixPath(path).name)
    linter.visit(tree)
    return _apply_pragmas(linter.findings, source)


def iter_target_files(paths: list[pathlib.Path] | None = None,
                      root: pathlib.Path = REPO_ROOT) -> list[pathlib.Path]:
    if not paths:
        paths = [root / "src" / "repro"]
    out: list[pathlib.Path] = []
    for p in paths:
        if p.is_dir():
            out.extend(sorted(f for f in p.rglob("*.py")
                              if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            out.append(p)
    return [f for f in out if in_scope(f, root)]


def lint_paths(paths: list[pathlib.Path] | None = None,
               root: pathlib.Path = REPO_ROOT) -> list[Finding]:
    findings: list[Finding] = []
    for f in iter_target_files(paths, root):
        rel = f.resolve().relative_to(root).as_posix()
        findings.extend(lint_source(f.read_text(), rel))
    return findings


# -- baseline --------------------------------------------------------------------
def load_baseline(path: pathlib.Path = BASELINE_PATH) -> Counter:
    """Multiset of grandfathered ``path:rule:scope`` keys (trailing
    ``# reason`` comments and blank lines ignored)."""
    if not path.exists():
        return Counter()
    entries: Counter = Counter()
    for line in path.read_text().splitlines():
        entry = line.split("#", 1)[0].strip()
        if entry:
            entries[entry] += 1
    return entries


def diff_baseline(findings: list[Finding],
                  baseline: Counter) -> tuple[list[Finding], list[str]]:
    """(new findings not in baseline, stale baseline keys with no hit)."""
    remaining = Counter(baseline)
    new: list[Finding] = []
    for f in findings:
        if remaining.get(f.baseline_key, 0) > 0:
            remaining[f.baseline_key] -= 1
        else:
            new.append(f)
    stale = sorted(remaining.elements())
    return new, stale


def write_baseline(findings: list[Finding],
                   path: pathlib.Path = BASELINE_PATH) -> None:
    lines = [
        "# simlint baseline: grandfathered benign findings, one",
        "# path:RULE:scope key per hit.  Regenerate with",
        "#   python -m repro.analysis --write-baseline",
        "# New code should use inline pragmas instead; this file should",
        "# only ever shrink.",
    ]
    lines.extend(sorted(f.baseline_key for f in findings))
    path.write_text("\n".join(lines) + "\n")
