"""Determinism tooling: ``simlint`` (static rules) + ``simsan`` (runtime
sanitizer for the event loop).  CLI: ``python -m repro.analysis --check``.
See ``docs/simlint.md`` for the rule catalog and workflow."""
from repro.analysis.simlint import (  # noqa: F401
    Finding, RULES, lint_paths, lint_source,
)
from repro.analysis.simsan import (  # noqa: F401
    SanitizerError, check_payment_conservation,
)
