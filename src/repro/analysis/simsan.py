"""simsan — runtime sanitizer for the deterministic event loop.

Static rules (``simlint``) catch *constructs*; this module catches
*behaviour* the rules can't see, TSan-style, by instrumenting the engine
when a loop is built with ``EventLoop(sanitize=True)`` (or globally via
``SHELBY_SIMSAN=1``):

* **pop-order audit** — every pop must be ``(time, seq)``-monotone:
  non-decreasing time, strictly ascending seq within a timestamp, finite
  times only, and pushes must never target the past.  Any violation
  means the queue discipline fell back to an unstable ordering — exactly
  the bug class the calendar/heap equivalence guarantee forbids.
* **resource-slot accounting** — a ``Release`` that would drive a
  resource's ``in_use`` negative (or a class's count negative) raises at
  the releasing step; at full drain (``run()``), any resource with slots
  still held raises, naming the holder tasks and their acquire times.
  ``run_until`` deliberately abandons stragglers, so the drain check
  only runs on ``run()``.
* **off-loop mutation** — sanitized loops build ``GuardedResource``s
  whose scalar accounting fields reject writes outside an engine
  operation (naming the mutating task and sim-time); dict-valued fields
  are shadow-snapshotted and re-checked at every engine touch and at
  drain, naming the window in which the out-of-band write happened.
* **payment conservation** — :func:`check_payment_conservation` replays
  the SDK's settlement invariant (per-node receipts vs. channel debits)
  mid-run, so ``repro.core.simulation.run_sim`` can assert it per epoch
  instead of only at ``close()``.

Violations raise :class:`SanitizerError` — an ``AssertionError``
subclass, so a sanitized CI smoke fails loudly — with the task label,
sim-time, and resource key in the message.  Zero overhead when off: the
engine's hooks are all behind ``if self._san is not None``.
"""
from __future__ import annotations

import math
import sys
from typing import TYPE_CHECKING, Any

from repro.net.events import Resource

if TYPE_CHECKING:  # pragma: no cover
    from repro.net.events import EventLoop, TaskHandle


class SanitizerError(AssertionError):
    """A simulation-safety invariant was violated (simsan)."""


class GuardedResource(Resource):
    """A :class:`Resource` whose scalar accounting fields only accept
    writes while the engine has an operation open — any other write is an
    off-loop mutation and raises immediately, naming the task whose step
    is executing."""

    __slots__ = ("_san",)

    #: scalar fields the engine owns; dict fields (``in_use_by_class`` …)
    #: can't be guarded by ``__setattr__`` and are shadow-checked instead.
    _PROTECTED = frozenset({
        "in_use", "capacity", "acquired", "wait_ms_total", "max_queue",
    })

    def __init__(self, key: Any, capacity: int, san: "Sanitizer"):
        object.__setattr__(self, "_san", None)  # disarm during base init
        super().__init__(key, capacity)
        object.__setattr__(self, "_san", san)

    def __setattr__(self, name: str, value: Any) -> None:
        san = getattr(self, "_san", None)
        if san is not None and name in self._PROTECTED and not san.in_engine_op:
            san.off_loop_scalar_write(self, name)
        object.__setattr__(self, name, value)


class _EngineOp:
    """Context manager flipping the sanitizer's engine-op flag (so
    GuardedResource accepts the engine's own accounting writes)."""

    __slots__ = ("san",)

    def __init__(self, san: "Sanitizer"):
        self.san = san

    def __enter__(self):
        self.san.in_engine_op = True
        return self

    def __exit__(self, *exc):
        self.san.in_engine_op = False
        return False


class Sanitizer:
    """Per-loop runtime checker; the engine calls the ``on_*`` hooks."""

    def __init__(self, loop: "EventLoop"):
        self.loop = loop
        self.in_engine_op = False
        self._last_t = -math.inf
        self._last_seq = -1
        # resource key -> (in_use_by_class copy, last engine-op t, label)
        self._shadow: dict[Any, tuple[dict[int, int], float, str]] = {}
        self.pops_audited = 0

    # -- plumbing --------------------------------------------------------------
    def violation(self, msg: str) -> None:
        raise SanitizerError(f"simsan: {msg}")

    def engine_op(self) -> _EngineOp:
        return _EngineOp(self)

    @staticmethod
    def _task_name(handle: "TaskHandle | None") -> str:
        return handle.label if handle is not None else "<off-task>"

    # -- pop-order / causality audit -------------------------------------------
    def on_push(self, t_ms: float, handle: "TaskHandle") -> None:
        if not math.isfinite(t_ms):
            self.violation(
                f"task {handle.label!r} scheduled at non-finite time "
                f"{t_ms!r} (now={self.loop.now})")
        if t_ms < self.loop.now:
            self.violation(
                f"causality: task {handle.label!r} scheduled at t={t_ms} "
                f"which is before now={self.loop.now}")

    def on_pop(self, t_ms: float, seq: int) -> None:
        self.pops_audited += 1
        if t_ms < self._last_t:
            self.violation(
                f"pop order went backwards in time: t={t_ms} after "
                f"t={self._last_t} (engine={self.loop.engine!r})")
        if t_ms == self._last_t and seq <= self._last_seq:
            self.violation(
                f"ambiguous same-timestamp pop order at t={t_ms}: seq {seq} "
                f"popped after seq {self._last_seq} — the (time, seq) total "
                "order broke (unstable tie-break in the queue discipline)")
        self._last_t, self._last_seq = t_ms, seq

    # -- resource accounting ---------------------------------------------------
    def on_release(self, res: Resource, priority: int,
                   handle: "TaskHandle | None") -> None:
        """Validate a release *before* the engine decrements."""
        if res.in_use <= 0:
            self.violation(
                f"release without acquire: task {self._task_name(handle)!r} "
                f"released resource {res.key!r} at t={self.loop.now} with "
                f"in_use={res.in_use}")
        if res.in_use_by_class.get(priority, 0) <= 0:
            self.violation(
                f"class-mismatched release: task {self._task_name(handle)!r} "
                f"released resource {res.key!r} class {priority} at "
                f"t={self.loop.now}, but that class holds no slots "
                f"(in_use_by_class={dict(res.in_use_by_class)})")

    def on_touch(self, res: Resource, handle: "TaskHandle | None") -> None:
        """Engine is about to operate on ``res``: verify its dict-valued
        accounting still matches the shadow from the last engine op."""
        snap = self._shadow.get(res.key)
        if snap is not None and snap[0] != res.in_use_by_class:
            self.violation(
                f"off-loop mutation of resource {res.key!r}: "
                f"in_use_by_class changed from {snap[0]} to "
                f"{dict(res.in_use_by_class)} outside the engine, between "
                f"t={snap[1]} (last engine op, task {snap[2]!r}) and "
                f"t={self.loop.now} (task {self._task_name(handle)!r})")

    def record(self, res: Resource, handle: "TaskHandle | None") -> None:
        """Engine finished operating on ``res``: refresh its shadow."""
        self._shadow[res.key] = (
            dict(res.in_use_by_class), self.loop.now, self._task_name(handle))

    def off_loop_scalar_write(self, res: Resource, field: str) -> None:
        cur = getattr(self.loop, "_current", None)
        self.violation(
            f"off-loop mutation: Resource({res.key!r}).{field} written "
            f"directly at t={self.loop.now} by task "
            f"{self._task_name(cur)!r} — resource accounting may only "
            "change through Acquire/Release effects")

    # -- drain-time checks -----------------------------------------------------
    def on_drain(self) -> None:
        """After ``run()`` fully drains: no slot may still be held."""
        for key in sorted(self._shadow, key=repr):
            res = self.loop._resources.get(key)
            if res is not None:
                self.on_touch(res, None)
        leaks = []
        for key in sorted(self.loop._resources, key=repr):
            res = self.loop._resources[key]
            if res.in_use != 0:
                holders = [
                    f"{h.label!r} (acquired t={t_acq}, class {prio})"
                    for h in self.loop._tasks
                    for k, prio, t_acq in h.held
                    if k == key
                ]
                leaks.append(
                    f"resource {key!r}: in_use={res.in_use} "
                    f"(by class {dict(res.in_use_by_class)}) at drain "
                    f"t={self.loop.now}; held by "
                    f"{', '.join(holders) or '<no live holder recorded>'}")
        if leaks:
            self.violation(
                "resource slot leak(s) at loop drain — every Acquire must "
                "be matched by a Release (try/finally), even on the error "
                "path:\n  " + "\n  ".join(leaks))


# -- payment conservation (per-epoch settlement invariant) -----------------------
def check_payment_conservation(session: Any, *, where: str = "") -> None:
    """Assert, mid-session, that every channel debit is backed by receipts.

    This is the same invariant ``ShelbySession.close()`` enforces at
    settlement — per serving node, the sum of receipt payments (read
    receipts, DAS sample receipts, and batched background receipts) must
    equal the channel's ``paid`` within float tolerance — hoisted out so
    ``run_sim`` can assert it at every epoch boundary under simsan.  A
    mismatch means value was created or destroyed between a read and its
    receipt: the exact bug class the paper's payment protocol (§ payments)
    exists to rule out."""
    expected: dict[Any, float] = {}
    for r in getattr(session, "receipts", []):
        for rpc_id, amount in getattr(r, "payments", {}).items():
            expected[rpc_id] = expected.get(rpc_id, 0.0) + amount
    for rb in getattr(session, "receipt_batches", []):
        for rpc_id, amount in getattr(rb, "paid_by_node", {}).items():
            expected[rpc_id] = expected.get(rpc_id, 0.0) + amount

    channels = getattr(session, "channels", {})
    label = f" ({where})" if where else ""
    for rpc_id in sorted(set(expected) | set(channels)):
        ch = channels.get(rpc_id)
        if ch is None:
            raise SanitizerError(
                f"simsan: payment conservation{label}: receipts pay node "
                f"{rpc_id!r} {expected[rpc_id]:.6g} but the session has no "
                "channel to it")
        want = expected.get(rpc_id, 0.0)
        # same tolerance shape as ShelbySession.close(): absolute floor for
        # tiny flows, relative to the deposit for large ones
        tol = max(1e-9, 128 * sys.float_info.epsilon * ch.deposit)
        if abs(ch.paid - want) > tol:
            raise SanitizerError(
                f"simsan: payment conservation{label}: node {rpc_id!r} "
                f"channel debited {ch.paid:.9g} but receipts account for "
                f"{want:.9g} (|diff|={abs(ch.paid - want):.3g} > tol "
                f"{tol:.3g}) — a payment bypassed the receipt path")
