"""CLI for the determinism lint pass.

Exit codes (CI distinguishes them):
  0 — clean (no unbaselined findings, no stale baseline entries)
  1 — findings (new hits, pragmas missing reasons, or stale baseline rows)
  2 — internal error (linter crash, unparseable file, bad usage)
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import traceback

from repro.analysis import simlint


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="simlint: determinism & simulation-safety rules for the "
                    "sim path (net/ storage/ core/ scenarios/)")
    ap.add_argument("paths", nargs="*", type=pathlib.Path,
                    help="files/dirs to lint (default: src/repro sim path)")
    ap.add_argument("--check", action="store_true",
                    help="baseline-aware gate (this is also the default "
                         "behaviour; the flag exists for explicit CI lines)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the committed baseline from current hits")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code in sorted(simlint.RULES):
            print(f"{code}  {simlint.RULES[code]}")
        return 0

    findings = simlint.lint_paths(args.paths or None)

    if args.write_baseline:
        simlint.write_baseline(findings)
        print(f"wrote {len(findings)} baseline entries to "
              f"{simlint.BASELINE_PATH}")
        return 0

    if args.no_baseline:
        new, stale = findings, []
    else:
        new, stale = simlint.diff_baseline(findings, simlint.load_baseline())

    for f in new:
        print(f.render())
    for key in stale:
        print(f"stale baseline entry (no matching finding): {key}")
    n_files = len(simlint.iter_target_files(args.paths or None))
    if new or stale:
        print(f"simlint: {len(new)} finding(s), {len(stale)} stale baseline "
              f"entr(ies) across {n_files} sim-path files", file=sys.stderr)
        return 1
    print(f"simlint: clean ({n_files} sim-path files, "
          f"{len(findings)} baselined hit(s))")
    return 0


if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except SystemExit:
        raise
    except BaseException:
        traceback.print_exc()
        raise SystemExit(2)
