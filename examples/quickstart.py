"""Quickstart: the Shelby write/read/audit/repair lifecycle in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.storage.blob import BlobLayout
from repro.storage.repair import RepairCoordinator
from repro.storage.rpc import RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider

# 1. a small Shelby deployment: contract + 8 SPs across 3 DCs + one RPC node
layout = BlobLayout(k=4, m=2, chunkset_bytes_target=256 * 1024)  # 1.5x overhead
contract = ShelbyContract()
sps = {}
for i in range(8):
    contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 3}", rack=f"r{i % 4}"))
    sps[i] = StorageProvider(i)
rpc = RPCNode("rpc0", contract, sps, layout)
client = ShelbyClient(contract, rpc)

# 2. write a blob: partition -> Clay-encode -> commit -> pay -> disperse
data = np.random.default_rng(7).integers(0, 256, 1_000_000, dtype=np.uint8).tobytes()
meta = client.put(data, payment=1.0, epochs=12)
print(f"stored blob {meta.blob_id}: {meta.size_bytes} bytes as {meta.num_chunksets} "
      f"chunksets x {meta.n} chunks (overhead {layout.replication_overhead:.2f}x), "
      f"state={meta.state.value}")

# 3. paid, verified reads (any byte range): every read returns a receipt
receipt = client.read(meta.blob_id)
assert receipt.data == data
assert client.get(meta.blob_id, 123_456, 789) == data[123_456 : 123_456 + 789]
print(f"reads ok; paid ${receipt.total_paid:.9f} to {list(receipt.payments)} "
      f"(sim latency {receipt.latency_ms:.1f} ms); RPC paid SPs "
      f"${rpc.stats.payments:.6f} over micropayment channels")

# 4. kill an SP: reads still work (MDS: any k of n), then repair at MSR bandwidth
victim = meta.placement[(0, 0)]
sps[victim].crash()
rpc._cache.clear()
assert client.get(meta.blob_id) == data
print(f"SP {victim} down -> reads fine ({rpc.stats.chunks_requested} chunk requests)")

sps[victim].recover()
sps[victim].wipe()
reports = RepairCoordinator(contract, sps, layout).repair_all()
msr = sum(r.mode == "msr" for r in reports)
print(f"repaired {len(reports)} chunks ({msr} at MSR bandwidth, "
      f"{sum(r.helper_bytes_read for r in reports)} helper bytes)")

# 5. corruption is detected, not served — and the corrupt chunk is NOT paid
evil = meta.placement[(0, 1)]
sps[evil].behavior.corrupt = True
rpc._cache.clear()
assert client.get(meta.blob_id) == data
print(f"corrupt SP detected: {rpc.stats.chunks_bad} bad chunks rejected by commitments")

# 6. close the session: broadcast the freshest refunds; conservation holds
settlement = client.settle()
assert abs(settlement.total_deposited
           - (settlement.total_refunded + settlement.total_node_income)) < 1e-6
print(f"settled: client refunded ${settlement.total_refunded:.6f}, RPC income "
      f"${settlement.total_node_income:.9f}, SPs realized "
      f"${sum(settlement.sp_income.values()):.6f}")
