"""Multi-RPC CDN demo: a fleet serving Zipf-hot content over the backbone.

Three datacenters, one RPC node in each with its own decoded hot-cache,
twelve SPs, Zipf-popular traffic from clients in all three regions.
Cache-affinity routing (rendezvous hashing) gives every chunkset one home
node, so the fleet's caches compose instead of duplicating — the §5.3
hot-cache story at fleet scale, with a straggler and a dead SP thrown in.

    PYTHONPATH=src python examples/multi_rpc_cdn.py
"""
import numpy as np

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.net.backbone import Backbone
from repro.net.fleet import CacheAffinityPolicy, RPCFleet
from repro.net.workloads import zipf_hotset
from repro.storage.blob import BlobLayout
from repro.storage.rpc import BackboneTransport, RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider

layout = BlobLayout(k=4, m=2, chunkset_bytes_target=64 * 1024)
contract = ShelbyContract()
backbone = Backbone.mesh(3, base_latency_ms=6.0, gbps=25.0)
rng = np.random.default_rng(7)

sps = {}
for i in range(12):
    dc = f"dc{i % 3}"
    contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=dc, rack=f"r{i % 4}"))
    sps[i] = StorageProvider(i)
    sps[i].behavior.latency_ms = float(rng.uniform(1.0, 10.0))
    backbone.register_node(f"sp{i}", dc)
for c in range(3):
    backbone.register_node(f"client{c}", f"dc{c}")

rpcs = []
for r in range(3):
    node = f"rpc{r}"
    backbone.register_node(node, f"dc{r}")
    rpcs.append(RPCNode(node, contract, sps, layout, cache_chunksets=16,
                        transport=BackboneTransport(sps, backbone, node)))
fleet = RPCFleet(rpcs, CacheAffinityPolicy(), backbone=backbone)

print("uploading a hot content library (8 objects)...")
client = ShelbyClient(contract, fleet, deposit=1e9)  # fleet-first client
blobs = {}
metas = []
for b in range(8):
    data = rng.integers(0, 256, 4 * layout.chunkset_bytes, dtype=np.uint8).tobytes()
    meta = client.put(data)
    blobs[meta.blob_id] = data
    metas.append(meta)

# adversity after the write phase: one straggler, one dead SP
sps[2].behavior.latency_ms = 250.0
sps[5].crash()

print("serving 300 Zipf-distributed requests from 3 regions...")
reqs = zipf_hotset(metas, clients=["client0", "client1", "client2"],
                   num_requests=300, seed=11)
with client.session() as session:
    for req in reqs:
        receipt = session.read(req.blob_id, req.offset, req.length,
                               client=req.client, t_ms=req.t_ms)
        expect = blobs[req.blob_id][req.offset : req.offset + req.length]
        assert receipt.data == expect, "served bytes must match stored content"
settlement = session.settlement

p50, p99 = fleet.latency_percentiles(50.0, 99.0)
print(f"cache hit rate: {fleet.cache_hit_rate():.0%} "
      f"(per-node hits: {[r.stats.cache_hits for r in rpcs]})")
print(f"simulated latency: p50={p50:.1f} ms, p99={p99:.1f} ms "
      f"(straggler at 250 ms never gates a read)")
print(f"hedged requests wasted: {fleet.hedged_wasted()}; "
      f"routed per node: {fleet.routed}")
print("settled per-node serving income: "
      + ", ".join(f"{nid}=${amt:.9f}" for nid, amt in sorted(settlement.node_income.items())))
print(f"RPC->SP income realized at settlement: "
      f"${sum(settlement.sp_income.values()):.6f} across {len(settlement.sp_income)} SPs")
assert abs(settlement.total_deposited
           - (settlement.total_refunded + settlement.total_node_income)) < 1e-3
assert p99 < 250.0
assert fleet.cache_hit_rate() > 0.5
print("CDN serving over the dedicated backbone: OK")
