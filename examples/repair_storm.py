"""Appendix-A scenario: correlated failures and the repair pipeline.

A rack-level event knocks out several SPs at once; the repair coordinator
rebuilds every lost chunk — MSR path where all n-1 helpers survive, MDS
fallback where two chunks of a chunkset are gone — and we account the exact
helper bytes against the Reed-Solomon counterfactual (§3.3's claim, live).

    PYTHONPATH=src python examples/repair_storm.py
"""
import numpy as np

from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.storage.blob import BlobLayout
from repro.storage.repair import RepairCoordinator
from repro.storage.rpc import RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider

layout = BlobLayout(k=10, m=6, chunkset_bytes_target=512 * 1024)
contract = ShelbyContract()
sps = {}
for i in range(24):
    contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 4}", rack=f"r{i % 8}"))
    sps[i] = StorageProvider(i)
rpc = RPCNode("rpc0", contract, sps, layout)
client = ShelbyClient(contract, rpc)

rng = np.random.default_rng(3)
blobs = [client.put(rng.integers(0, 256, 1_500_000, dtype=np.uint8).tobytes())
         for _ in range(3)]
total_chunks = sum(len(m.placement) for m in blobs)
print(f"stored {len(blobs)} blobs = {total_chunks} chunks on 24 SPs across 4 DCs")

# rack r3 loses power: every SP on it wipes (data loss, not just downtime)
victims = [i for i in range(24) if i % 8 == 3]
for v in victims:
    sps[v].wipe()
print(f"rack event: SPs {victims} lost all chunks")

rc = RepairCoordinator(contract, sps, layout)
lost = rc.scan_lost_chunks()
print(f"detected {len(lost)} lost chunks")
reports = rc.repair_all()

msr = [r for r in reports if r.mode == "msr"]
mds = [r for r in reports if r.mode == "mds"]
helper_bytes = sum(r.helper_bytes_read for r in reports)
rs_bytes = len(reports) * layout.k * layout.chunk_bytes
print(f"repaired {len(reports)} chunks: {len(msr)} MSR + {len(mds)} MDS-fallback")
print(f"helper bytes read: {helper_bytes/1e6:.1f} MB vs Reed-Solomon {rs_bytes/1e6:.1f} MB "
      f"({1 - helper_bytes/rs_bytes:.0%} saved)")
assert not rc.scan_lost_chunks(), "all chunks restored"

# end-to-end integrity after the storm: one batched fleet pass, paid on
# delivery, then settle the session
rpc._cache.clear()
receipts = client.get_many([(meta.blob_id, 0, None) for meta in blobs])
for meta, receipt in zip(blobs, receipts):
    assert len(receipt.data) == meta.size_bytes
settlement = client.settle()
print(f"post-storm reads verified: OK (paid ${settlement.total_node_income:.9f}, "
      f"SPs realized ${sum(settlement.sp_income.values()):.6f} at settlement)")
