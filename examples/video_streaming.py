"""The paper's canonical workload (§1): 4K video streaming at >= 40 Mbps.

Stores a simulated video, then "plays" it through the session API's
streaming path — ``client.stream`` yields one :class:`ReadReceipt` per
segment (sequential chunkset reads with hedged k-of-n fetches under the
hood) while one SP is a heavy straggler and another is dead.  Reports
achieved throughput against the 40 Mbps bar and the micropayments that
flowed per serving node ("reads are paid"), then settles the session and
checks conservation.

    PYTHONPATH=src python examples/video_streaming.py
    VIDEO_SMOKE=1 PYTHONPATH=src python examples/video_streaming.py  # CI-sized
"""
import os
import time

import numpy as np

from repro.configs.shelby import CONFIG, resolve_decode_matmul
from repro.core.contract import ShelbyContract
from repro.core.placement import SPInfo
from repro.storage.blob import BlobLayout
from repro.storage.rpc import RPCNode
from repro.storage.sdk import ShelbyClient
from repro.storage.sp import StorageProvider

SMOKE = bool(int(os.environ.get("VIDEO_SMOKE", "0")))
VIDEO_BYTES = (4 if SMOKE else 24) * 1024 * 1024
CHUNKSET = (512 if SMOKE else 1024) * 1024
RTT_BUDGET_MS = 20.0  # dedicated-backbone round trip per segment

layout = BlobLayout(k=10, m=6, chunkset_bytes_target=CHUNKSET)  # paper (10,6)
contract = ShelbyContract()
sps = {}
for i in range(20):
    contract.register_sp(SPInfo(sp_id=i, stake=1000.0, dc=f"dc{i % 5}", rack=f"r{i % 4}"))
    sps[i] = StorageProvider(i)
rpc = RPCNode("rpc0", contract, sps, layout, hedge=2, cache_chunksets=4,
              decode_matmul=resolve_decode_matmul(CONFIG.decode_matmul))
client = ShelbyClient(contract, rpc)

print(f"uploading 'video' ({layout.replication_overhead:.1f}x replication overhead)...")
video = np.random.default_rng(1).integers(0, 256, VIDEO_BYTES, dtype=np.uint8).tobytes()
meta = client.put(video, payment=2.0, epochs=30)

# adversity: one SP dead, one straggling 250 ms/request
dead = meta.placement[(0, 2)]
slow = meta.placement[(0, 5)]
sps[dead].crash()
sps[slow].behavior.latency_ms = 250.0

# "play": stream segment receipts through the seekable reader path
with client.open(meta.blob_id) as probe:  # BlobReader: seek + peek the header
    header = probe.read(16)
    assert header == video[:16]
    probe.seek(0)

played = bytearray()
t0 = time.time()
sim_latency_ms = 0.0
segments = 0
for receipt in client.stream(meta.blob_id, chunk_size=layout.chunkset_bytes):
    played += receipt.data
    sim_latency_ms += receipt.latency_ms + RTT_BUDGET_MS
    segments += 1
wall = time.time() - t0
played = bytes(played)
assert played == video, "bitstream must be intact"

mbits = meta.size_bytes * 8 / 1e6
sim_s = sim_latency_ms / 1e3
print(f"streamed {mbits:.0f} Mbit in {segments} segments, {sim_s:.2f} s simulated "
      f"network time ({mbits / sim_s:.0f} Mbps vs 40 Mbps requirement) "
      f"[decode wall {wall:.1f}s on 1 CPU core]")
print(f"hedged requests wasted: {rpc.stats.hedged_wasted}, bad/slow SPs never stalled playback")

settlement = client.settle()
assert abs(settlement.total_deposited
           - (settlement.total_refunded + settlement.total_node_income)) < 1e-6
print(f"micropayments: client->RPC ${settlement.total_node_income:.9f} (settled), "
      f"RPC->SPs ${sum(settlement.sp_income.values()):.6f} across "
      f"{len(settlement.sp_income)} SPs ({rpc.stats.chunks_requested} chunk requests)")
assert mbits / sim_s >= 40, "4K streaming bar"
print("4K streaming requirement met under failures: OK")
